//! Shard-fleet demo (ISSUE 7): TWO shard pools behind real TCP listeners,
//! a client pool with one remote member per shard, live RTT probing
//! feeding measured placement, and the shard-side operand cache turning
//! steady-state CONV traffic into descriptor-only frames.
//!
//! ```sh
//! cargo run --release --example shard_fleet -- [--frames 4] [--rounds 6]
//! ```
//!
//! Three pools run in one process over real sockets:
//! * **fleet-a** and **fleet-b** — independent 2-NEON pools, each behind
//!   its own `ShardServer` (own listener, own shared operand cache);
//! * a **client pool**: the default ZC702 platform plus two remote-member
//!   clusters dialing the fleet, with `probe_interval_ms` enabled so the
//!   prober threads feed measured RTT + far-end service rate into every
//!   fleet link's `LinkCost` cell.
//!
//! The run proves, in order: probes deliver measured link costs on both
//! fleet links; mixed zoo traffic (full mnist + mpcnn forwards) validates
//! against the reference; repeated CONV rounds over the same packed
//! operand planes warm both shard caches (weights ship once, tiles ship
//! 137-byte descriptors); and at shutdown — zero lost jobs, zero
//! evictions, each shard's ledger balancing its client member's row
//! exactly, and a nonzero cache hit rate on both shards.

use std::sync::Arc;
use std::time::{Duration, Instant};

use synergy::accel::{register_config_shards, AccelClass, BackendRegistry};
use synergy::config::{zoo, ClusterCfg, HwConfig};
use synergy::mm::job::{gather_results, jobs_for_gemm, Job, JobClass};
use synergy::mm::TileGrid;
use synergy::nn::Network;
use synergy::rt::{ComputeMode, DelegatePool, PoolOptions, PoolRouter};
use synergy::runtime::default_artifacts_dir;
use synergy::sched::static_map;
use synergy::serve::ShardServer;
use synergy::util::argparse::Args;
use synergy::util::rng::XorShift64Star;

/// One fleet member: a 2-NEON pool behind an ephemeral-port listener.
fn start_shard(name: &str) -> anyhow::Result<ShardServer> {
    let mut hw = HwConfig::default_zc702();
    hw.clusters = vec![ClusterCfg {
        name: name.into(),
        neon: 2,
        big_neon: 0,
        remote: Vec::new(),
        pes: Vec::new(),
    }];
    ShardServer::start(
        "127.0.0.1:0",
        &PoolOptions::new(hw, ComputeMode::Native, false),
    )
}

fn main() -> anyhow::Result<()> {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&raw, &[]).map_err(anyhow::Error::msg)?;
    let frames = args.get_usize("frames", 4).map_err(anyhow::Error::msg)? as u64;
    let rounds = args.get_usize("rounds", 6).map_err(anyhow::Error::msg)?;

    // 1. The fleet: two independent shard pools on localhost.
    let shard_a = start_shard("fleet-a")?;
    let shard_b = start_shard("fleet-b")?;
    println!("fleet listening on {} and {}", shard_a.addr(), shard_b.addr());

    // 2. The client: default ZC702 + one remote cluster per shard, with
    //    the serving default's live probing switched on.
    let mut hw = HwConfig::default_zc702();
    for (name, addr) in [("offload-a", shard_a.addr()), ("offload-b", shard_b.addr())] {
        hw.clusters.push(ClusterCfg {
            name: name.into(),
            neon: 0,
            big_neon: 0,
            remote: vec![addr.to_string()],
            pes: Vec::new(),
        });
    }
    let mut registry =
        BackendRegistry::with_defaults(default_artifacts_dir(), hw.big_neon_threads);
    register_config_shards(&mut registry, &hw);
    let mut options = PoolOptions::new(hw, ComputeMode::Native, true);
    options.registry = Some(Arc::new(registry));
    options.probe_interval_ms = 10;
    let pool = Arc::new(DelegatePool::start(&options)?);
    let dispatcher = pool.dispatcher();
    let accels = pool.accels();
    let id_for = |want: String| {
        accels
            .iter()
            .find(|a| matches!(&a.class, AccelClass::Remote { addr } if *addr == want))
            .expect("fleet member in the client pool")
            .id
    };
    let id_a = id_for(shard_a.addr().to_string());
    let id_b = id_for(shard_b.addr().to_string());
    let n_clusters = pool.clusters().len();
    let fleet_clusters = [n_clusters - 2, n_clusters - 1];

    // 3. Measured placement goes live: every fleet link must report a
    //    probed RTT and the far pool's advertised service rate.
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        let ready = fleet_clusters.iter().all(|&c| {
            pool.routes()[c]
                .members()
                .iter()
                .all(|m| m.link.probes() > 0 && m.link.measured_rate_ksteps().is_some())
        });
        if ready {
            break;
        }
        assert!(
            Instant::now() < deadline,
            "probes never delivered measured link costs"
        );
        std::thread::sleep(Duration::from_millis(5));
    }
    for &c in &fleet_clusters {
        for m in pool.routes()[c].members() {
            println!(
                "cluster {c}: measured overhead {:.1} k-steps, rate {:.0} k-steps/s \
                 after {} probe(s)",
                m.link.overhead_ksteps(),
                m.link.measured_rate_ksteps().unwrap_or(0.0),
                m.link.probes(),
            );
        }
    }

    // 4. Mixed zoo traffic: full forwards through two networks, validated
    //    against the reference (the static mapper hands the fleet — the
    //    strongest clusters by aggregate rate — their share of CONV work).
    for (ni, name) in ["mnist", "mpcnn"].iter().enumerate() {
        let net = Network::new(zoo::load(name)?, 32)?;
        let assignment = static_map::assign(&net.conv_infos(), pool.clusters());
        let router = PoolRouter::new(&net, pool.dispatcher(), &assignment);
        let mut max_err = 0f32;
        for f in 0..frames {
            let x = net.make_input(ni as u64 * 100 + f);
            let y = net.forward_with(&x, &router.frame(f));
            max_err = max_err.max(y.max_abs_diff(&net.forward_reference(&x)));
        }
        assert!(max_err < 1e-3, "{name} diverged from reference: {max_err}");
        println!("{name}: {frames} frame(s) forwarded, max |err| = {max_err:.2e}");
    }

    // 5. Warm the fleet: the same packed planes dispatched round after
    //    round, one hinted job set per shard — after each shard's cold
    //    PUTs, every further tile is a descriptor-only frame resolved
    //    from its operand cache.
    let grid = TileGrid::new(64, 800, 196, 32);
    let a = Arc::new(XorShift64Star::new(1).fill_f32(64 * 800, 1.0));
    let b = Arc::new(XorShift64Star::new(2).fill_f32(800 * 196, 1.0));
    let want = synergy::mm::gemm::gemm_blocked(
        &synergy::tensor::Tensor::from_vec(&[64, 800], (*a).clone()),
        &synergy::tensor::Tensor::from_vec(&[800, 196], (*b).clone()),
    );
    let mut next = dispatcher.reserve_job_ids(2 * grid.num_jobs() as u64);
    let hinted: Vec<Vec<Job>> = fleet_clusters
        .iter()
        .map(|&c| {
            jobs_for_gemm(0, 0, grid, Arc::clone(&a), Arc::clone(&b), &mut next)
                .into_iter()
                .map(|j| j.placed(Some(c)))
                .collect()
        })
        .collect();
    for _ in 0..rounds {
        for jobs in &hinted {
            let c = gather_results(grid, &dispatcher.execute_jobs(jobs.clone()));
            let got = synergy::tensor::Tensor::from_vec(&[64, 196], c);
            assert!(
                want.allclose(&got, 1e-3, 1e-3),
                "fleet round diverged by {}",
                want.max_abs_diff(&got)
            );
        }
    }
    println!("{rounds} warm round(s) × {} tiles per shard completed", grid.num_jobs());

    // 6. Cache health: both shards must hold entries and serve hits.
    for (name, stats) in [("fleet-a", shard_a.cache_stats()), ("fleet-b", shard_b.cache_stats())]
    {
        let hit_rate =
            stats.hits as f64 / ((stats.hits + stats.misses) as f64).max(1.0);
        println!(
            "{name} cache: {} entries ({} f32), {} hits / {} misses \
             ({:.1}% hit rate), {} eviction(s)",
            stats.entries,
            stats.elems,
            stats.hits,
            stats.misses,
            100.0 * hit_rate,
            stats.evictions,
        );
        assert!(stats.entries >= 2, "{name}: operand cache never filled");
        assert!(stats.hits > 0, "{name}: operand cache never hit");
        assert!(hit_rate > 0.5, "{name}: cache thrashing ({hit_rate})");
    }

    // 7. Reports: client first (connection threads exit when their peers
    //    hang up), then the fleet — and the ledgers must balance per
    //    shard, class by class.
    let pool = Arc::try_unwrap(pool).unwrap_or_else(|_| panic!("pool still shared"));
    let report = pool.shutdown()?;
    assert_eq!(report.inline_fallbacks, 0, "inline fallback fired");
    assert_eq!(report.delegate_failures, 0, "a delegate died");
    assert_eq!(report.requeued_jobs, 0, "jobs were requeued unexpectedly");
    assert_eq!(report.evicted_members, 0, "a healthy fleet must not evict");
    let rows = [
        report.per_accel_by_class[id_a],
        report.per_accel_by_class[id_b],
    ];
    for (name, row, shard) in [("fleet-a", rows[0], shard_a), ("fleet-b", rows[1], shard_b)] {
        let rep = shard.shutdown()?;
        println!(
            "{name}: {} conv-tile + {} fused-FC job(s) served",
            rep.per_class_jobs[JobClass::ConvTile.index()],
            rep.per_class_jobs[JobClass::FcGemmBatch.index()],
        );
        assert!(
            row[JobClass::ConvTile.index()] > 0,
            "{name} never served CONV work"
        );
        assert_eq!(
            rep.per_class_jobs[JobClass::ConvTile.index()],
            row[JobClass::ConvTile.index()],
            "{name}: conv ledger mismatch between client and shard"
        );
        assert_eq!(
            rep.per_class_jobs[JobClass::FcGemmBatch.index()],
            row[JobClass::FcGemmBatch.index()],
            "{name}: fused-FC ledger mismatch between client and shard"
        );
        assert_eq!(rep.inline_fallbacks, 0);
        assert_eq!(rep.delegate_failures, 0);
    }
    println!("\nzero lost jobs; both fleet ledgers balance; caches hit ✓");
    Ok(())
}
