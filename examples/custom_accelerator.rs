//! Custom accelerator design flow (paper §3.3 / Fig 8): write a
//! `.hw_config`, run the hardware architecture generator, inspect the
//! synthesis-style resource report, then simulate the custom architecture
//! against the default one.
//!
//! ```sh
//! cargo run --release --example custom_accelerator
//! ```

use synergy::accel::build_clusters;
use synergy::config::{zoo, HwConfig};
use synergy::hwgen;
use synergy::nn::Network;
use synergy::sim::{simulate, SimSpec};

/// An experienced designer's custom architecture: fewer, beefier F-PEs and
/// a NEON-heavy first cluster, one MMU per PE.
const CUSTOM_HW: &str = "
[device]
name = xc7z020
fpga_mhz = 100
cpu_mhz = 667
tile_size = 32

[pe_type]
name = XL-PE
kind = fast
pipeline_loop = loop2
ii = 1
unroll = 1
array_partition = 16

[cluster]
name = neon_side
neon = 2
pe = XL-PE:1

[cluster]
name = fpga_side
pe = XL-PE:4

[memory]
mmus = 5
pes_per_mmu = 1
tlb_entries = 16
ddr_bytes_per_cycle = 8
ddr_latency_cycles = 20
burst_beats = 64
";

fn main() -> anyhow::Result<()> {
    // 1. Parse the designer's configuration.
    let custom = HwConfig::parse("custom", CUSTOM_HW)?;
    println!(
        "custom architecture: {} PEs + {} NEONs across {} clusters",
        custom.total_pes(),
        custom.total_neons(),
        custom.clusters.len()
    );

    // 2. Run the generator (PE HLS sources, wiring, resource report,
    //    bitstream manifest).
    let out = std::env::temp_dir().join(format!("synergy_custom_{}", std::process::id()));
    let design = hwgen::generate(&custom, &out)?;
    println!("\ngenerated into {}:", design.dir.display());
    for (name, path) in &design.pe_sources {
        println!("  {} -> {}", name, path.display());
    }
    println!("\n{}", design.report.render());

    // 3. Compare against the default ZC702 architecture in simulation.
    let default_hw = HwConfig::default_zc702();
    println!("{:<16} {:>12} {:>12}", "model", "default fps", "custom fps");
    for name in zoo::ZOO {
        let net = Network::new(zoo::load(name)?, 32)?;
        let d = simulate(&SimSpec::synergy(&net, 30), &net);
        let mut spec = SimSpec::synergy(&net, 30);
        spec.hw = custom.clone();
        spec.clusters = build_clusters(&custom);
        let assignment =
            synergy::sched::static_map::assign(&net.conv_infos(), &spec.clusters);
        spec.mapping = synergy::sched::Mapping::WorkStealing(assignment);
        let c = simulate(&spec, &net);
        println!("{:<16} {:>12.1} {:>12.1}", name, d.fps, c.fps);
    }

    std::fs::remove_dir_all(&out).ok();
    println!("\n(the default 8-PE architecture generally wins — the custom one trades\n PEs for per-PE strength, which Table 5's DSE shows is rarely optimal)");
    Ok(())
}
