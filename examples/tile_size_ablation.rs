//! Ablation: tile size sensitivity (paper §4.1: "the tile size is set to
//! be 32 based on empirical evaluation").  Sweeps TS ∈ {8, 16, 32, 64}
//! across the zoo on the simulated ZC702 and reports throughput plus the
//! two opposing costs: per-job control overhead (small tiles → many jobs)
//! and border padding waste (large tiles → ragged GEMMs waste MACs).
//!
//! ```sh
//! cargo run --release --example tile_size_ablation
//! ```

use synergy::accel::build_clusters;
use synergy::config::{zoo, HwConfig};
use synergy::nn::Network;
use synergy::sched::{static_map, Mapping};
use synergy::sim::{simulate, SimSpec};
use synergy::util::bench::{fmt, Table};
use synergy::util::stats;

fn padding_waste(net: &Network) -> f64 {
    // fraction of nominal job MACs spent on zero-padded lanes
    let mut useful = 0f64;
    let mut padded = 0f64;
    for ci in net.conv_infos() {
        let g = ci.grid;
        useful += (g.m * g.n * g.p) as f64;
        padded += (g.rows() * g.ts * g.k_tiles() * g.ts * g.cols() * g.ts) as f64;
    }
    1.0 - useful / padded
}

fn main() -> anyhow::Result<()> {
    let mut table = Table::new(&["TS", "mean fps", "mean jobs/frame", "padding waste", "mean util"]);
    for ts in [8usize, 16, 32, 64] {
        let mut hw = HwConfig::default_zc702();
        hw.tile_size = ts;
        let mut fps = Vec::new();
        let mut jobs = Vec::new();
        let mut waste = Vec::new();
        let mut util = Vec::new();
        for name in zoo::ZOO {
            let net = Network::new(zoo::load(name)?, ts)?;
            let clusters = build_clusters(&hw);
            let assignment = static_map::assign(&net.conv_infos(), &clusters);
            let spec = SimSpec {
                hw: hw.clone(),
                clusters,
                mapping: Mapping::WorkStealing(assignment),
                pipelined: true,
                cpu_cores: 2,
                frames: 30,
                conv_on_cpu: false,
            };
            let r = simulate(&spec, &net);
            fps.push(r.fps);
            jobs.push(
                net.conv_infos()
                    .iter()
                    .map(|ci| ci.grid.num_jobs())
                    .sum::<usize>() as f64,
            );
            waste.push(padding_waste(&net));
            util.push(r.cluster_util);
        }
        table.row(vec![
            ts.to_string(),
            fmt(stats::geomean(&fps)),
            fmt(stats::mean(&jobs)),
            format!("{:.1}%", 100.0 * stats::mean(&waste)),
            format!("{:.1}%", 100.0 * stats::mean(&util)),
        ]);
    }
    table.print();
    println!(
        "\nTS=8 drowns in job-control overhead, TS=64 in border padding waste\n\
         (and leaves too few jobs to balance).  The optimum sits at TS=16-32 on\n\
         this simulated testbed; the paper picked 32 empirically — on real HLS\n\
         hardware smaller tiles also cost BRAM banking and burst efficiency,\n\
         which pushes the optimum up from 16 to 32."
    );
    Ok(())
}
