//! SLO-tier soak: a mixed model-zoo serving run that layers every load
//! shape the tiered stack must survive — a slow standard-tier drip, bursty
//! batch-tier floods, a steady interactive foreground, a tight-deadline
//! storm, and ONE mid-run zero-downtime weight hot-swap — then asserts the
//! per-tier envelopes on exit:
//!
//! * interactive and standard traffic is **never shed**, no matter how
//!   hard the batch lanes flood (bounded lanes shed bulk, not foreground);
//! * every admitted request is accounted: completed + expired == admitted,
//!   and the server's shed ledger equals the clients' rejected submits;
//! * interactive p99 stays at or below batch p99 while the batch lanes
//!   are backlogged (tier precedence is visible in the tail);
//! * the hot-swap loses nothing: exactly one swap, responses pin the
//!   version current at their batch's formation, and every response is
//!   `allclose` to ITS version's reference forward.
//!
//! ```sh
//! cargo run --release --example serving_soak -- [--short]
//! ```
//!
//! `--short` is the CI shape: the same phases at a fraction of the volume.

use std::sync::Arc;
use std::time::{Duration, Instant};

use synergy::config::zoo;
use synergy::nn::Network;
use synergy::serve::request::frame_tag;
use synergy::serve::{Request, RequestStream, ServeOptions, Server, SloTier};
use synergy::util::argparse::Args;

fn main() -> anyhow::Result<()> {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&raw, &["short"]).map_err(anyhow::Error::msg)?;
    let short = args.has_flag("short");

    // Volumes per phase (short = the CI shape).
    let n_interactive = if short { 32 } else { 160 };
    let n_standard = if short { 16 } else { 80 };
    let n_batch = if short { 60 } else { 300 };
    let n_storm = if short { 24 } else { 96 };
    let burst = 20usize;

    // Mixed zoo: mpcnn (net 0) + mnist (net 1) served side by side.
    let nets: Vec<Arc<Network>> = ["mpcnn", "mnist"]
        .iter()
        .map(|n| Ok(Arc::new(Network::new(zoo::load(n)?, 32)?)))
        .collect::<anyhow::Result<_>>()?;
    // The swap payload: same architecture/tile/input shape as net 1, but a
    // different config name, hence different deterministic weights — the
    // swap is observable in the outputs, not just a counter.
    let mut v1_cfg = zoo::load("mnist")?;
    v1_cfg.name = "mnist_v1".into();
    let swapped = Arc::new(Network::new(v1_cfg, 32)?);

    let mut options = ServeOptions::default();
    options.batch.max_batch = 4;
    options.batch.window = Duration::from_micros(1500);
    options.admission_depth = 512;
    println!(
        "soak: {} interactive + {} standard + {} batch (bursts of {burst}) \
         + {} storm requests per net pair, one mid-run hot-swap{}",
        2 * n_interactive,
        2 * n_standard,
        2 * n_batch,
        n_storm,
        if short { " [--short]" } else { "" }
    );

    let server = Arc::new(Server::start(nets.clone(), options)?);
    let mut clients = Vec::new();

    // Steady interactive foreground: one stream per net, generous
    // deadline (it exists to exercise EDF + headroom tracking, not to
    // expire on a loaded CI box).
    for (stream_id, net_id) in [(0usize, 0usize), (1, 1)] {
        let server = Arc::clone(&server);
        let mut stream = RequestStream::new(
            stream_id,
            net_id,
            Arc::clone(&nets[net_id]),
            300.0,
            n_interactive as u64,
        )
        .with_tier(SloTier::Interactive)
        .with_deadline(Duration::from_secs(30));
        clients.push(std::thread::spawn(move || {
            let (mut ok, mut shed) = (0u64, 0u64);
            while let Some((gap, req)) = stream.next_arrival() {
                std::thread::sleep(gap);
                if server.submit(req) {
                    ok += 1;
                } else {
                    shed += 1;
                }
            }
            (ok, shed)
        }));
    }

    // Slow standard-tier drip: the default tier, no deadline.
    for (stream_id, net_id) in [(2usize, 0usize), (3, 1)] {
        let server = Arc::clone(&server);
        let mut stream = RequestStream::new(
            stream_id,
            net_id,
            Arc::clone(&nets[net_id]),
            100.0,
            n_standard as u64,
        );
        clients.push(std::thread::spawn(move || {
            let (mut ok, mut shed) = (0u64, 0u64);
            while let Some((gap, req)) = stream.next_arrival() {
                std::thread::sleep(gap);
                if server.submit(req) {
                    ok += 1;
                } else {
                    shed += 1;
                }
            }
            (ok, shed)
        }));
    }

    // Bursty batch-tier floods: submit back-to-back bursts, then idle —
    // the load shape that MUST shed only in its own lanes.
    for (stream_id, net_id) in [(4usize, 0usize), (5, 1)] {
        let server = Arc::clone(&server);
        let mut stream = RequestStream::new(
            stream_id,
            net_id,
            Arc::clone(&nets[net_id]),
            1e6, // gaps ignored below; the burst structure is explicit
            n_batch as u64,
        )
        .with_tier(SloTier::Batch);
        clients.push(std::thread::spawn(move || {
            let (mut ok, mut shed) = (0u64, 0u64);
            let mut in_burst = 0usize;
            while let Some((_, req)) = stream.next_arrival() {
                if server.submit(req) {
                    ok += 1;
                } else {
                    shed += 1;
                }
                in_burst += 1;
                if in_burst == burst {
                    in_burst = 0;
                    std::thread::sleep(Duration::from_millis(40));
                }
            }
            (ok, shed)
        }));
    }

    // Mid-run: swap net 1's weights with zero downtime, then fire the
    // deadline storm at the swapped network — tight budgets under a fresh
    // version, all of it racing the still-running drip and floods.  The
    // storm (≤96 requests) fits the interactive lane (depth 512), so the
    // foreground-never-shed envelope below stays a fair assertion.
    std::thread::sleep(Duration::from_millis(if short { 120 } else { 400 }));
    let version = server.hot_swap(1, Arc::clone(&swapped))?;
    anyhow::ensure!(version == 1, "expected the first swap to mint version 1");
    println!("hot-swapped net 1 → version {version} (mid-run)");

    {
        let server = Arc::clone(&server);
        let net = Arc::clone(&nets[1]);
        clients.push(std::thread::spawn(move || {
            let (mut ok, mut shed) = (0u64, 0u64);
            for seq in 0..n_storm as u64 {
                let req = Request::new(90, seq, 1, net.make_input(frame_tag(90, seq)))
                    .with_tier(SloTier::Interactive)
                    .with_deadline(Duration::from_millis(3));
                if server.submit(req) {
                    ok += 1;
                } else {
                    shed += 1;
                }
            }
            (ok, shed)
        }));
    }

    let (mut admitted, mut client_shed) = (0u64, 0u64);
    for c in clients {
        let (ok, shed) = c.join().expect("client thread");
        admitted += ok;
        client_shed += shed;
    }

    // Tail: a few post-join standard requests against net 1 guarantee at
    // least one response is served under the swapped version even if every
    // storm request expired.
    let t0 = Instant::now();
    for seq in 0..4u64 {
        let req = Request::new(91, seq, 1, nets[1].make_input(frame_tag(91, seq)));
        if server.submit(req) {
            admitted += 1;
        } else {
            client_shed += 1;
        }
    }

    let server = match Arc::try_unwrap(server) {
        Ok(s) => s,
        Err(_) => anyhow::bail!("client threads still hold server handles"),
    };
    let (stats, responses) = server.shutdown()?;
    println!("drained the tail + shutdown in {:.0?}", t0.elapsed());
    println!("\n=== soak report ===");
    print!("{}", stats.render());

    // --- Correctness across the swap: each response must match the
    // reference forward of the version it was pinned to.
    let mut max_err = 0f32;
    let mut v1_served = 0u64;
    for resp in &responses {
        let input = nets[resp.net_id].make_input(resp.frame);
        let reference = if resp.net_id == 1 && resp.version == 1 {
            v1_served += 1;
            swapped.forward_reference(&input)
        } else {
            nets[resp.net_id].forward_reference(&input)
        };
        max_err = max_err.max(resp.output.max_abs_diff(&reference));
    }
    println!("max |err|      : {max_err:.2e} vs per-version reference forwards");
    assert!(max_err < 1e-3, "serving diverged from reference: {max_err}");
    assert!(v1_served >= 1, "no response was served under the swapped weights");
    assert_eq!(stats.hot_swaps, 1);

    // --- Per-tier envelopes.
    let (i, s, b) = (
        SloTier::Interactive.index(),
        SloTier::Standard.index(),
        SloTier::Batch.index(),
    );
    assert_eq!(
        stats.shed_by_tier[i], 0,
        "interactive traffic shed while batch lanes flooded"
    );
    assert_eq!(stats.shed_by_tier[s], 0, "standard drip shed");
    assert_eq!(stats.shed, client_shed, "shed ledger vs client-observed rejects");
    assert_eq!(
        stats.completed + stats.expired,
        admitted,
        "lost requests: {admitted} admitted, {} completed, {} expired",
        stats.completed,
        stats.expired
    );
    assert_eq!(stats.completed as usize, responses.len());
    // Tier precedence must be visible in the tail whenever the floods
    // actually backlogged the batch lanes behind foreground traffic.
    if stats.completed_by_tier[b] > 0 && stats.completed_by_tier[i] > 0 {
        assert!(
            stats.tier_p99_ms[i] <= stats.tier_p99_ms[b],
            "interactive p99 {:.2}ms above batch p99 {:.2}ms",
            stats.tier_p99_ms[i],
            stats.tier_p99_ms[b]
        );
    }
    println!(
        "envelopes held: foreground shed 0, {} admitted fully accounted, \
         interactive p99 {:.2}ms ≤ batch p99 {:.2}ms, {} responses on v1",
        admitted, stats.tier_p99_ms[i], stats.tier_p99_ms[b], v1_served
    );
    if stats.expired_by_tier[i] > 0 {
        println!(
            "deadline storm: {} of {} storm requests expired in-lane (counted, not lost)",
            stats.expired_by_tier[i], n_storm
        );
    }
    if stats.window_shrinks + stats.window_widens > 0 {
        println!(
            "adaptive windows: {} shrinks / {} widens under the soak",
            stats.window_shrinks, stats.window_widens
        );
    }
    Ok(())
}
