//! Multi-stream serving load test: N open-loop client streams submit
//! inference requests against ≥2 networks served concurrently by one
//! Synergy accelerator pool, through the admission → micro-batcher →
//! pipeline stack.
//!
//! ```sh
//! cargo run --release --example serving_load -- \
//!     [--models mpcnn,mnist] [--streams 4] [--requests 40] [--rate 400] \
//!     [--max-batch 4] [--window-us 2000] [--depth 256] [--deadline-ms 0] \
//!     [--duration-ms 0] [--expect-no-shed]
//! ```
//!
//! Every response is cross-checked against the reference forward, and the
//! run asserts zero lost requests under the admission limits.
//! `--duration-ms N` caps each stream's submission phase at N ms of wall
//! clock (0 = submit all `--requests`), so CI can bound the run; with
//! `--expect-no-shed` the run additionally fails if ANY request was shed
//! at admission — zero shed AND zero lost, asserted on exit.

use std::sync::Arc;
use std::time::{Duration, Instant};

use synergy::config::zoo;
use synergy::nn::Network;
use synergy::serve::{RequestStream, ServeOptions, Server};
use synergy::util::argparse::Args;

fn main() -> anyhow::Result<()> {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&raw, &["no-steal", "expect-no-shed"]).map_err(anyhow::Error::msg)?;
    let model_list = args.get_or("models", "mpcnn,mnist");
    let n_streams = args.get_usize("streams", 4).map_err(anyhow::Error::msg)?;
    let n_requests = args.get_usize("requests", 40).map_err(anyhow::Error::msg)?;
    let rate = args.get_f64("rate", 400.0).map_err(anyhow::Error::msg)?;
    let max_batch = args.get_usize("max-batch", 4).map_err(anyhow::Error::msg)?;
    let window_us = args.get_usize("window-us", 2000).map_err(anyhow::Error::msg)?;
    let depth = args.get_usize("depth", 256).map_err(anyhow::Error::msg)?;
    let deadline_ms = args.get_usize("deadline-ms", 0).map_err(anyhow::Error::msg)?;
    let duration_ms = args.get_usize("duration-ms", 0).map_err(anyhow::Error::msg)?;
    let expect_no_shed = args.has_flag("expect-no-shed");

    // ≥2 networks served side by side from the model zoo.
    let names: Vec<&str> = model_list.split(',').map(|s| s.trim()).collect();
    anyhow::ensure!(names.len() >= 2, "--models needs ≥2 comma-separated zoo names");
    let mut nets = Vec::new();
    for name in &names {
        nets.push(Arc::new(Network::new(zoo::load(name)?, 32)?));
    }

    let mut options = ServeOptions::default();
    options.batch.max_batch = max_batch;
    options.batch.window = Duration::from_micros(window_us as u64);
    options.admission_depth = depth;
    options.work_stealing = !args.has_flag("no-steal");
    println!(
        "serving {:?} — {} streams × {} req @ {:.0} req/s/stream, \
         max_batch {} window {}µs depth {}",
        names, n_streams, n_requests, rate, max_batch, window_us, depth
    );

    let server = Arc::new(Server::start(nets.clone(), options)?);

    // Open-loop client threads; streams round-robin over the networks.
    let mut clients = Vec::new();
    for stream_id in 0..n_streams {
        let net_id = stream_id % nets.len();
        let server = Arc::clone(&server);
        let mut stream = RequestStream::new(
            stream_id,
            net_id,
            Arc::clone(&nets[net_id]),
            rate,
            n_requests as u64,
        );
        if deadline_ms > 0 {
            stream = stream.with_deadline(Duration::from_millis(deadline_ms as u64));
        }
        clients.push(std::thread::spawn(move || {
            let mut submitted = 0u64;
            let mut shed = 0u64;
            let t0 = Instant::now();
            while let Some((gap, req)) = stream.next_arrival() {
                // Optional wall-clock cap on the submission phase (CI runs
                // bounded loads; everything submitted still drains fully).
                if duration_ms > 0 && t0.elapsed() >= Duration::from_millis(duration_ms as u64)
                {
                    break;
                }
                std::thread::sleep(gap);
                if server.submit(req) {
                    submitted += 1;
                } else {
                    shed += 1;
                }
            }
            (submitted, shed)
        }));
    }
    let mut admitted = 0u64;
    let mut client_shed = 0u64;
    for c in clients {
        let (s, d) = c.join().expect("client thread");
        admitted += s;
        client_shed += d;
    }

    // Let the pipelines drain, then collect the report.
    let server = match Arc::try_unwrap(server) {
        Ok(s) => s,
        Err(_) => anyhow::bail!("client threads still hold server handles"),
    };
    let (stats, responses) = server.shutdown()?;

    // Validate every response against the reference forward.
    let mut max_err = 0f32;
    for resp in &responses {
        let want = nets[resp.net_id].forward_reference(&nets[resp.net_id].make_input(resp.frame));
        max_err = max_err.max(resp.output.max_abs_diff(&want));
    }
    assert!(max_err < 1e-3, "serving diverged from reference: {max_err}");

    println!("\n=== serving report ===");
    print!("{}", stats.render());
    println!("max |err|      : {max_err:.2e} vs reference forward");
    let batched: u64 = responses.iter().filter(|r| r.batch_size > 1).count() as u64;
    println!(
        "batched        : {batched}/{} responses rode in a batch > 1",
        responses.len()
    );

    // Zero lost requests under admission limits: everything admitted either
    // completed or was an explicit deadline expiry.
    assert_eq!(stats.shed, client_shed, "shed accounting mismatch");
    if expect_no_shed {
        assert_eq!(client_shed, 0, "--expect-no-shed: {client_shed} requests shed");
    }
    assert_eq!(
        stats.completed + stats.expired,
        admitted,
        "lost requests: {} admitted, {} completed, {} expired",
        admitted,
        stats.completed,
        stats.expired
    );
    if stats.max_batch > 1 {
        println!("micro-batching observed: max batch {}", stats.max_batch);
    } else {
        println!("warning: no batch > 1 formed (rate too low for the window)");
    }
    println!("zero lost requests: {admitted} admitted == {} accounted", stats.completed + stats.expired);
    Ok(())
}
