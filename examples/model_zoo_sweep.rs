//! Model-zoo sweep on the simulated ZC702: throughput, latency, energy,
//! utilization and speedup over the CPU baseline for all seven benchmark
//! CNNs (paper Table 2 workloads; the headline numbers of Figs 9/10).
//!
//! ```sh
//! cargo run --release --example model_zoo_sweep
//! ```

use synergy::config::zoo;
use synergy::nn::Network;
use synergy::sim::{simulate, SimSpec};
use synergy::util::bench::{fmt, Table};
use synergy::util::stats;

fn main() -> anyhow::Result<()> {
    let mut table = Table::new(&[
        "model",
        "CPU fps",
        "Synergy fps",
        "speedup",
        "latency ms",
        "util %",
        "W",
        "mJ/frame",
        "GOPS",
    ]);
    let mut speedups = Vec::new();
    for name in zoo::ZOO {
        let net = Network::new(zoo::load(name)?, 32)?;
        let base = simulate(&SimSpec::cpu_only(&net, 8), &net);
        let syn = simulate(&SimSpec::synergy(&net, 60), &net);
        speedups.push(syn.fps / base.fps);
        table.row(vec![
            name.to_string(),
            fmt(base.fps),
            fmt(syn.fps),
            format!("{:.2}x", syn.fps / base.fps),
            fmt(syn.mean_latency_s * 1e3),
            format!("{:.1}", 100.0 * syn.cluster_util),
            fmt(syn.energy.avg_power_w),
            fmt(syn.energy.energy_per_frame_mj),
            fmt(syn.gops),
        ]);
    }
    table.print();
    println!(
        "\nmean speedup {:.2}x (paper: 7.3x) — throughput range {:.0}–{:.0} fps (paper: 39.5–136.4)",
        stats::mean(&speedups),
        // recompute quickly for the footer
        zoo::ZOO
            .iter()
            .map(|n| {
                let net = Network::new(zoo::load(n).unwrap(), 32).unwrap();
                simulate(&SimSpec::synergy(&net, 30), &net).fps
            })
            .fold(f64::INFINITY, f64::min),
        zoo::ZOO
            .iter()
            .map(|n| {
                let net = Network::new(zoo::load(n).unwrap(), 32).unwrap();
                simulate(&SimSpec::synergy(&net, 30), &net).fps
            })
            .fold(0.0, f64::max),
    );
    Ok(())
}
