//! Remote-shard demo: the `Accelerator` registry's first out-of-tree
//! backend, end to end over real TCP on localhost.
//!
//! ```sh
//! cargo run --release --example remote_shard -- [--frames 6] [--rounds 40]
//! ```
//!
//! Two pools run in one process, talking over a real socket:
//! * a **shard pool** (2 NEONs) behind a `ShardServer`, executing jobs
//!   shipped to it;
//! * a **client pool**: the default ZC702 platform plus a third cluster
//!   whose one member is `remote = 127.0.0.1:<port>` — registered through
//!   the public registry API (`register_config_shards`), never
//!   special-cased in the runtime.
//!
//! Phase 1 streams frames through a full network forward (the static
//! mapper hands the shard — the strongest cluster by aggregate rate — its
//! share of CONV layers) and validates every output against the reference
//! forward.  Phase 2 bursts un-hinted CONV GEMMs + fused FC batches from
//! several threads until the shipping-cost routing demonstrably offloads
//! BOTH classes to the shard.  The run asserts zero lost jobs, zero
//! inline fallbacks, zero delegate failures, and that the client's
//! remote-member ledger balances the shard pool's own report exactly.

use std::sync::Arc;

use synergy::accel::{register_config_shards, AccelClass, BackendRegistry};
use synergy::config::{zoo, ClusterCfg, HwConfig};
use synergy::mm::job::{gather_results, jobs_for_gemm, Job, JobClass};
use synergy::mm::TileGrid;
use synergy::nn::Network;
use synergy::rt::{ComputeMode, DelegatePool, PoolOptions, PoolRouter};
use synergy::runtime::default_artifacts_dir;
use synergy::sched::static_map;
use synergy::serve::ShardServer;
use synergy::util::argparse::Args;
use synergy::util::rng::XorShift64Star;

fn main() -> anyhow::Result<()> {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&raw, &[]).map_err(anyhow::Error::msg)?;
    let frames = args.get_usize("frames", 6).map_err(anyhow::Error::msg)? as u64;
    let max_rounds = args.get_usize("rounds", 40).map_err(anyhow::Error::msg)?;

    // 1. The remote end: a 2-NEON pool behind a TCP listener.
    let mut shard_hw = HwConfig::default_zc702();
    shard_hw.clusters = vec![ClusterCfg {
        name: "shard-pool".into(),
        neon: 2,
        big_neon: 0,
        remote: Vec::new(),
        pes: Vec::new(),
    }];
    let shard = ShardServer::start(
        "127.0.0.1:0",
        &PoolOptions::new(shard_hw, ComputeMode::Native, false),
    )?;
    println!("shard pool listening on {}", shard.addr());

    // 2. The client: default ZC702 + one remote member dialing the shard.
    let mut hw = HwConfig::default_zc702();
    hw.clusters.push(ClusterCfg {
        name: "offload".into(),
        neon: 0,
        big_neon: 0,
        remote: vec![shard.addr().to_string()],
        pes: Vec::new(),
    });
    let mut registry =
        BackendRegistry::with_defaults(default_artifacts_dir(), hw.big_neon_threads);
    register_config_shards(&mut registry, &hw);
    let mut options = PoolOptions::new(hw, ComputeMode::Native, true);
    options.registry = Some(Arc::new(registry));
    let pool = Arc::new(DelegatePool::start(&options)?);
    let accels = pool.accels();
    let remote_id = accels
        .iter()
        .find(|a| matches!(a.class, AccelClass::Remote { .. }))
        .expect("remote member in the client pool")
        .id;

    // 3. Phase 1 — full network forwards with the static mapping.
    let net = Arc::new(Network::new(zoo::load("mnist")?, 32)?);
    let assignment = static_map::assign(&net.conv_infos(), pool.clusters());
    println!(
        "mnist CONV layers → clusters {assignment:?} (cluster 2 is the shard)"
    );
    let router = PoolRouter::new(&net, pool.dispatcher(), &assignment);
    let mut max_err = 0f32;
    for f in 0..frames {
        let x = net.make_input(f);
        let y = net.forward_with(&x, &router.frame(f));
        max_err = max_err.max(y.max_abs_diff(&net.forward_reference(&x)));
    }
    assert!(max_err < 1e-3, "forward diverged from reference: {max_err}");
    println!("{frames} frames forwarded; max |err| vs reference = {max_err:.2e}");

    // 4. Phase 2 — un-hinted load bursts until the shipping-cost routing
    //    offloads both CONV tiles and fused FC batches to the shard.
    let grid = TileGrid::new(128, 512, 128, 32);
    let a = Arc::new(XorShift64Star::new(1).fill_f32(128 * 512, 1.0));
    let b = Arc::new(XorShift64Star::new(2).fill_f32(512 * 128, 1.0));
    let w = Arc::new(XorShift64Star::new(3).fill_f32(64 * 128, 1.0));
    let xb = Arc::new(XorShift64Star::new(4).fill_f32(128 * 8, 1.0));
    let mut rounds = 0usize;
    loop {
        rounds += 1;
        assert!(
            rounds <= max_rounds,
            "routing never offloaded both classes after {max_rounds} rounds: {:?}",
            pool.snapshot().per_accel_by_class[remote_id]
        );
        let workers: Vec<_> = (0..3usize)
            .map(|t| {
                let pool = Arc::clone(&pool);
                let (a, b) = (Arc::clone(&a), Arc::clone(&b));
                let (w, xb) = (Arc::clone(&w), Arc::clone(&xb));
                std::thread::spawn(move || {
                    // Un-hinted jobs through the one generic entry point:
                    // pack once, reserve ids, let the cost model route.
                    let dispatcher = pool.dispatcher();
                    let mut next_id = dispatcher.reserve_job_ids(grid.num_jobs() as u64);
                    let jobs = jobs_for_gemm(t, t as u64, grid, a, b, &mut next_id);
                    let c = gather_results(grid, &dispatcher.execute_jobs(jobs));
                    let id = dispatcher.reserve_job_ids(1);
                    let y = dispatcher
                        .execute_job(Job::fc_batch(id, t, t as u64, 64, 128, 8, w, xb, 32))
                        .data;
                    (c.len(), y.len())
                })
            })
            .collect();
        for h in workers {
            let (c_len, y_len) = h.join().expect("load worker");
            assert_eq!(c_len, 128 * 128);
            assert_eq!(y_len, 64 * 8);
        }
        let ledger = pool.snapshot().per_accel_by_class[remote_id];
        if ledger[JobClass::ConvTile.index()] > 0 && ledger[JobClass::FcGemmBatch.index()] > 0
        {
            break;
        }
    }
    println!("offload observed after {rounds} load round(s)");

    // 5. Reports: shut the client down first (the shard's connection
    //    threads exit when their peers hang up), then the shard.
    let pool = Arc::try_unwrap(pool).unwrap_or_else(|_| panic!("pool still shared"));
    let report = pool.shutdown()?;
    println!("\n=== client pool ===");
    println!("{:<14} {:>10} {:>10} {:>10} {:>10}", "accel", "conv", "fc", "im2col", "fc-batch");
    for accel in &accels {
        let row = &report.per_accel_by_class[accel.id];
        println!(
            "{:<14} {:>10} {:>10} {:>10} {:>10}",
            accel.name,
            row[JobClass::ConvTile.index()],
            row[JobClass::FcGemm.index()],
            row[JobClass::Im2col.index()],
            row[JobClass::FcGemmBatch.index()],
        );
    }
    let remote_row = report.per_accel_by_class[remote_id];
    let shard_report = shard.shutdown()?;
    println!("\n=== shard pool ===");
    println!(
        "executed {} job(s): {} conv-tile, {} fc-gemm-batch",
        shard_report.jobs_executed,
        shard_report.per_class_jobs[JobClass::ConvTile.index()],
        shard_report.per_class_jobs[JobClass::FcGemmBatch.index()],
    );

    // Zero shed/lost work, and the two ledgers balance exactly.
    assert_eq!(report.inline_fallbacks, 0, "inline fallback fired");
    assert_eq!(report.delegate_failures, 0, "a delegate died");
    assert_eq!(report.requeued_jobs, 0, "jobs were requeued unexpectedly");
    assert!(remote_row[JobClass::ConvTile.index()] > 0);
    assert!(remote_row[JobClass::FcGemmBatch.index()] > 0);
    assert_eq!(
        shard_report.per_class_jobs[JobClass::ConvTile.index()],
        remote_row[JobClass::ConvTile.index()],
        "conv ledger mismatch between client and shard"
    );
    assert_eq!(
        shard_report.per_class_jobs[JobClass::FcGemmBatch.index()],
        remote_row[JobClass::FcGemmBatch.index()],
        "fused-FC ledger mismatch between client and shard"
    );
    println!("\nzero lost jobs; client remote ledger == shard pool ledger ✓");
    Ok(())
}
