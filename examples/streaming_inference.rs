//! End-to-end driver (the EXPERIMENTS.md §E2E run): serve a continuous
//! stream of frames through the full three-layer stack — Rust coordinator
//! (layer threads, cluster queues, work stealing) executing the **AOT
//! Pallas tiled-MM kernel through PJRT** on every FPGA-PE delegate — and
//! report latency/throughput like a serving system.
//!
//! ```sh
//! make artifacts && cargo run --release --example streaming_inference -- \
//!     [--model mpcnn] [--frames 64] [--native]
//! ```
//!
//! Every output is cross-checked against the Rust reference forward, so a
//! full run is also a numerical validation of all layers composing.

use std::sync::Arc;
use std::time::Instant;

use synergy::config::zoo;
use synergy::nn::Network;
use synergy::rt::{self, ComputeMode, RtOptions};
use synergy::runtime::default_artifacts_dir;
use synergy::tensor::Tensor;
use synergy::util::argparse::Args;
use synergy::util::stats;

fn main() -> anyhow::Result<()> {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&raw, &["native"]).map_err(anyhow::Error::msg)?;
    let model = args.get_or("model", "mpcnn");
    let n_frames = args.get_usize("frames", 64).map_err(anyhow::Error::msg)?;
    let native = args.has_flag("native");

    if !native && !default_artifacts_dir().join("manifest.json").exists() {
        anyhow::bail!("artifacts not built — run `make artifacts` (or pass --native)");
    }

    let net = Arc::new(Network::new(zoo::load(model)?, 32)?);
    println!(
        "serving {} ({} layers, {:.1} MOP/frame) — compute: {}",
        model,
        net.config.layers.len(),
        net.mops(),
        if native { "native" } else { "AOT Pallas kernel via PJRT" }
    );

    // Request stream (deterministic synthetic frames).
    let frames: Vec<(u64, Tensor)> = (0..n_frames as u64)
        .map(|f| (f, net.make_input(f)))
        .collect();

    let options = RtOptions {
        compute: if native {
            ComputeMode::Native
        } else {
            ComputeMode::Pjrt
        },
        ..Default::default()
    };
    let t0 = Instant::now();
    let report = rt::driver::run_stream(Arc::clone(&net), options, frames)?;
    let wall = t0.elapsed().as_secs_f64();

    // Validate every response against the reference forward.
    let mut max_err = 0f32;
    for (frame, out) in &report.outputs {
        let want = net.forward_reference(&net.make_input(*frame));
        max_err = max_err.max(out.max_abs_diff(&want));
    }
    assert!(max_err < 1e-3, "stream diverged from reference: {max_err}");

    // Serving-style report.
    let per_frame_ms = wall * 1e3 / report.outputs.len() as f64;
    println!("\n=== serving report ===");
    println!("frames served : {}", report.outputs.len());
    println!("wall time     : {wall:.3} s (startup included: {:.3} s)", report.wall_seconds);
    println!("throughput    : {:.1} frames/s", report.fps);
    println!("per-frame     : {per_frame_ms:.2} ms (pipeline-amortized)");
    println!("jobs executed : {} ({} stolen)", report.jobs_executed, report.jobs_stolen);
    println!("max |err|     : {max_err:.2e} vs reference forward");
    let per_accel: Vec<f64> = report.per_accel_jobs.iter().map(|&j| j as f64).collect();
    println!(
        "accel balance : mean {:.1} jobs/accel (σ {:.1}) across {} accelerators",
        stats::mean(&per_accel),
        {
            let m = stats::mean(&per_accel);
            (per_accel.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / per_accel.len() as f64)
                .sqrt()
        },
        per_accel.len()
    );
    println!("\nall layers compose: L1 Pallas kernel -> L2 JAX lowering -> L3 rust coordinator OK");
    Ok(())
}
