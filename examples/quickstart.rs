//! Quickstart: load a benchmark CNN, stream a few frames through the real
//! threaded Synergy pipeline (layer threads + cluster job queues + delegate
//! threads + work-stealing thief), and print classifications + throughput.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```
//!
//! Uses native compute so it works without `make artifacts`; pass `--pjrt`
//! to execute PE jobs through the AOT Pallas kernel on PJRT.

use std::sync::Arc;

use synergy::config::zoo;
use synergy::nn::Network;
use synergy::rt::{self, ComputeMode, RtOptions};
use synergy::tensor::Tensor;

fn main() -> anyhow::Result<()> {
    let use_pjrt = std::env::args().any(|a| a == "--pjrt");

    // 1. Load a network from the model zoo (paper Table 2).
    let net = Arc::new(Network::new(zoo::load("mnist")?, 32)?);
    println!(
        "loaded {}: {} layers ({} CONV), {:.1} MOP/frame",
        net.config.name,
        net.config.layers.len(),
        net.config.num_conv_layers(),
        net.mops()
    );

    // 2. Make a small synthetic frame stream (deterministic).
    let frames: Vec<(u64, Tensor)> = (0..10).map(|f| (f, net.make_input(f))).collect();

    // 3. Run it through the full coordinator.
    let options = RtOptions {
        compute: if use_pjrt {
            ComputeMode::Pjrt
        } else {
            ComputeMode::Native
        },
        ..Default::default()
    };
    let report = rt::driver::run_stream(Arc::clone(&net), options, frames)?;

    // 4. Results.
    for (frame, probs) in &report.outputs {
        let (class, p) = probs
            .data()
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap();
        println!("frame {frame}: class {class} (p = {p:.4})");
    }
    println!(
        "\n{} frames in {:.3}s — {:.1} frames/s (host wall clock)",
        report.outputs.len(),
        report.wall_seconds,
        report.fps
    );
    println!(
        "{} tiled-MM jobs executed across {} accelerators; {} stolen by the thief",
        report.jobs_executed,
        report.per_accel_jobs.len(),
        report.jobs_stolen
    );
    Ok(())
}
