#!/usr/bin/env python3
"""Fold measured timings from a CI bench artifact into BENCH_hotpath.json.

The committed baseline pins the *deterministic* byte ledgers (operand-plane
copies, shard wire bytes) and deliberately leaves the machine-dependent
timing fields (`mean_us`, `fps_host`) null.  CI's bench-sweep job uploads a
fully measured ``bench_hotpath.json`` per run; this tool merges exactly
those timing fields into the baseline — and **refuses** if any byte ledger
of the measured file disagrees with the committed one, because a timing
refresh must never smuggle in a ledger drift.

Usage:
    python3 tools/refresh_bench_baseline.py --measured rust/bench_hotpath.json \
        [--baseline BENCH_hotpath.json] [--output BENCH_hotpath.refreshed.json] \
        [--note "ci run 12345"]

With no --output the baseline file is rewritten in place.  Exit codes:
0 = merged, 1 = ledger mismatch or malformed input.
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

# (section, phase, field) triples whose equality gates the refresh.
LEDGER_FIELDS = [
    ("operand_plane", "before", "bytes_copied"),
    ("operand_plane", "before", "copy_events"),
    ("operand_plane", "after", "bytes_copied"),
    ("operand_plane", "after", "copy_events"),
    ("shard_wire", "baseline", "wire_bytes"),
    ("shard_wire", "cold", "wire_bytes"),
    ("shard_wire", "warm", "wire_bytes"),
    ("shard_wire_q8", "baseline", "wire_bytes"),
    ("shard_wire_q8", "cold", "wire_bytes"),
    ("shard_wire_q8", "warm", "wire_bytes"),
    ("shard_wire_q8", None, "operand_put_bytes"),
    ("shard_wire_q8", None, "f32_operand_put_bytes"),
]

# (section, phase-or-None, field) timing slots the refresh copies over.
TIMING_FIELDS = [
    ("operand_plane", "before", "mean_us"),
    ("operand_plane", "after", "mean_us"),
    ("pipeline", None, "fps_host"),
]


def dig(doc: dict, section: str, phase: str | None, field: str):
    node = doc[section] if phase is None else doc[section][phase]
    return node[field]


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--measured", required=True, help="CI artifact (bench_hotpath.json)")
    ap.add_argument("--baseline", default="BENCH_hotpath.json", help="committed baseline")
    ap.add_argument("--output", default=None, help="write here instead of in place")
    ap.add_argument("--note", default=None, help="provenance note, e.g. the CI run id")
    args = ap.parse_args()

    measured = json.loads(Path(args.measured).read_text())
    baseline_path = Path(args.baseline)
    baseline = json.loads(baseline_path.read_text())

    if measured.get("bench") != baseline.get("bench"):
        print(
            f"refusing: bench id mismatch "
            f"({measured.get('bench')!r} vs {baseline.get('bench')!r})",
            file=sys.stderr,
        )
        return 1
    if measured.get("schema_version") != baseline.get("schema_version"):
        print("refusing: schema_version mismatch", file=sys.stderr)
        return 1

    # Gate: every deterministic byte ledger must match the committed
    # baseline exactly before any timing is taken from the measured file.
    mismatches = []
    for section, phase, field in LEDGER_FIELDS:
        try:
            got = dig(measured, section, phase, field)
            want = dig(baseline, section, phase, field)
        except KeyError as missing:
            print(f"refusing: {args.measured} lacks {section}.{phase}.{field} ({missing})",
                  file=sys.stderr)
            return 1
        if got != want:
            mismatches.append(f"{section}.{phase}.{field}: measured {got} != baseline {want}")
    if mismatches:
        print("refusing: byte ledgers drifted — fix the regression (or, if the",
              file=sys.stderr)
        print("change is intentional, re-derive the baseline ledgers by hand):",
              file=sys.stderr)
        for line in mismatches:
            print(f"  {line}", file=sys.stderr)
        return 1

    # Merge exactly the timing slots; a null measured timing means the
    # artifact is unusable for a refresh.
    for section, phase, field in TIMING_FIELDS:
        value = dig(measured, section, phase, field)
        if value is None:
            print(f"refusing: measured {section}.{phase or ''}.{field} is null",
                  file=sys.stderr)
            return 1
        node = baseline[section] if phase is None else baseline[section][phase]
        node[field] = value

    quick = " (--quick run)" if measured.get("quick") else ""
    note = f" [{args.note}]" if args.note else ""
    baseline["provenance"] = (
        "ledgers: deterministic byte counts pinned by the committed baseline; "
        f"timings: refreshed from a measured CI artifact{quick}{note} via "
        "tools/refresh_bench_baseline.py — machine-dependent, compare trends "
        "only across the same runner class."
    )

    out = Path(args.output) if args.output else baseline_path
    out.write_text(json.dumps(baseline, indent=2) + "\n")
    refreshed = ", ".join(
        f"{s}.{p + '.' if p else ''}{f}" for s, p, f in TIMING_FIELDS
    )
    print(f"wrote {out}: ledgers verified, refreshed {refreshed}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
