//! A minimal Rust lexer — just enough structure for the lint rules.
//!
//! Zero dependencies by design (the build container has no registry, so
//! `syn` is off the table) and resilient by construction: the rules only
//! need identifiers, punctuation, and line numbers, with comments and
//! string/char literals kept out of the token stream so `"lock()"` inside
//! a diagnostic message can never trip a rule.  String literals are kept
//! as tokens (rule 5 reads the `"key" =>` arms of the config parser);
//! `//` comments are collected separately (the `lint: allow(...)` escapes
//! live there).

/// Token class.  `Str` carries the literal's *content* (quotes stripped).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TokKind {
    Ident,
    Punct,
    Str,
    Char,
    Lifetime,
    Num,
}

#[derive(Debug, Clone)]
pub struct Tok {
    /// 1-based source line of the token's first character.
    pub line: u32,
    pub kind: TokKind,
    pub text: String,
}

/// A `//` comment and the line it starts on.
#[derive(Debug, Clone)]
pub struct LineComment {
    pub line: u32,
    pub text: String,
}

pub struct Lexed {
    pub toks: Vec<Tok>,
    pub comments: Vec<LineComment>,
}

fn is_ident_start(c: char) -> bool {
    c.is_ascii_alphabetic() || c == '_'
}

fn is_ident_cont(c: char) -> bool {
    c.is_ascii_alphanumeric() || c == '_'
}

/// Tokenize `text`.  Never fails: unterminated constructs run to EOF, and
/// any unrecognized byte becomes a one-char `Punct` token.
pub fn lex(text: &str) -> Lexed {
    let cs: Vec<char> = text.chars().collect();
    let n = cs.len();
    let mut toks = Vec::new();
    let mut comments = Vec::new();
    let mut i = 0usize;
    let mut line = 1u32;

    let push = |toks: &mut Vec<Tok>, line: u32, kind: TokKind, text: String| {
        toks.push(Tok { line, kind, text });
    };

    while i < n {
        let c = cs[i];
        if c == '\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c == ' ' || c == '\t' || c == '\r' {
            i += 1;
            continue;
        }
        // Line comment (collected for the allow-escapes).
        if c == '/' && i + 1 < n && cs[i + 1] == '/' {
            let start = i;
            while i < n && cs[i] != '\n' {
                i += 1;
            }
            comments.push(LineComment {
                line,
                text: cs[start..i].iter().collect(),
            });
            continue;
        }
        // Block comment (nesting, dropped).
        if c == '/' && i + 1 < n && cs[i + 1] == '*' {
            let mut depth = 1usize;
            i += 2;
            while i < n && depth > 0 {
                if cs[i] == '/' && i + 1 < n && cs[i + 1] == '*' {
                    depth += 1;
                    i += 2;
                } else if cs[i] == '*' && i + 1 < n && cs[i + 1] == '/' {
                    depth -= 1;
                    i += 2;
                } else {
                    if cs[i] == '\n' {
                        line += 1;
                    }
                    i += 1;
                }
            }
            continue;
        }
        // Raw strings: r"..." / r#"..."# / br#"..."# (any hash count).
        if c == 'r' || (c == 'b' && i + 1 < n && cs[i + 1] == 'r') {
            let mut j = i + if c == 'b' { 2 } else { 1 };
            let mut hashes = 0usize;
            while j < n && cs[j] == '#' {
                hashes += 1;
                j += 1;
            }
            if j < n && cs[j] == '"' {
                let start_line = line;
                j += 1;
                let content_start = j;
                'scan: while j < n {
                    if cs[j] == '"' {
                        let mut ok = true;
                        for h in 0..hashes {
                            if j + 1 + h >= n || cs[j + 1 + h] != '#' {
                                ok = false;
                                break;
                            }
                        }
                        if ok {
                            break 'scan;
                        }
                    }
                    if cs[j] == '\n' {
                        line += 1;
                    }
                    j += 1;
                }
                push(
                    &mut toks,
                    start_line,
                    TokKind::Str,
                    cs[content_start..j.min(n)].iter().collect(),
                );
                i = (j + 1 + hashes).min(n);
                continue;
            }
            // not a raw string: fall through to the ident branch below
        }
        // Plain / byte strings.
        if c == '"' || (c == 'b' && i + 1 < n && cs[i + 1] == '"') {
            let start_line = line;
            let mut j = i + if c == 'b' { 2 } else { 1 };
            let mut content = String::new();
            while j < n && cs[j] != '"' {
                if cs[j] == '\\' {
                    j += 2;
                    continue;
                }
                if cs[j] == '\n' {
                    line += 1;
                }
                content.push(cs[j]);
                j += 1;
            }
            push(&mut toks, start_line, TokKind::Str, content);
            i = j + 1;
            continue;
        }
        // Lifetime vs char literal.
        if c == '\'' {
            let next_is_ident = i + 1 < n && is_ident_start(cs[i + 1]);
            let closes_as_char = i + 2 < n && cs[i + 2] == '\'';
            if next_is_ident && !closes_as_char {
                let mut j = i + 1;
                while j < n && is_ident_cont(cs[j]) {
                    j += 1;
                }
                push(
                    &mut toks,
                    line,
                    TokKind::Lifetime,
                    cs[i..j].iter().collect(),
                );
                i = j;
                continue;
            }
            let mut j = i + 1;
            while j < n {
                if cs[j] == '\\' {
                    j += 2;
                    continue;
                }
                if cs[j] == '\'' {
                    break;
                }
                j += 1;
            }
            push(
                &mut toks,
                line,
                TokKind::Char,
                cs[i..(j + 1).min(n)].iter().collect(),
            );
            i = (j + 1).min(n);
            continue;
        }
        if is_ident_start(c) {
            let mut j = i;
            while j < n && is_ident_cont(cs[j]) {
                j += 1;
            }
            push(&mut toks, line, TokKind::Ident, cs[i..j].iter().collect());
            i = j;
            continue;
        }
        if c.is_ascii_digit() {
            let mut j = i;
            while j < n {
                if is_ident_cont(cs[j]) {
                    j += 1;
                } else if cs[j] == '.' && j + 1 < n && cs[j + 1].is_ascii_digit() {
                    j += 1;
                } else {
                    break;
                }
            }
            push(&mut toks, line, TokKind::Num, cs[i..j].iter().collect());
            i = j;
            continue;
        }
        push(&mut toks, line, TokKind::Punct, c.to_string());
        i += 1;
    }
    Lexed { toks, comments }
}

/// Line spans `(start, end)` covered by `#[cfg(..test..)]` / `#[test]`
/// items.  The rules skip findings inside these: test code may spawn
/// threads, hold bare locks, and match loosely.
pub fn test_regions(toks: &[Tok]) -> Vec<(u32, u32)> {
    let mut spans = Vec::new();
    let n = toks.len();
    let mut i = 0usize;
    while i < n {
        if toks[i].text == "#" && i + 1 < n && toks[i + 1].text == "[" {
            let (is_test, mut j) = attr_is_test(toks, i);
            let mut test = is_test;
            // Stacked attributes on the same item.
            while j + 1 < n && toks[j].text == "#" && toks[j + 1].text == "[" {
                let (t2, j2) = attr_is_test(toks, j);
                test |= t2;
                j = j2;
            }
            if test && j < n {
                // Skip the annotated item: to `;` or the matching `{}`.
                let start_line = toks[j].line;
                let mut bd = 0i32;
                let mut k = j;
                let mut end_line = start_line;
                while k < n {
                    match toks[k].text.as_str() {
                        "{" => bd += 1,
                        "}" => {
                            bd -= 1;
                            if bd == 0 {
                                end_line = toks[k].line;
                                break;
                            }
                        }
                        ";" if bd == 0 => {
                            end_line = toks[k].line;
                            break;
                        }
                        _ => {}
                    }
                    k += 1;
                }
                spans.push((start_line, end_line));
                i = k + 1;
                continue;
            }
            i = j;
            continue;
        }
        i += 1;
    }
    spans
}

/// Parse the attribute starting at `#`/`[` index `at`; return whether it
/// marks test code and the index just past its closing `]`.
fn attr_is_test(toks: &[Tok], at: usize) -> (bool, usize) {
    let n = toks.len();
    let mut j = at + 2;
    let mut depth = 1i32;
    let mut names: Vec<&str> = Vec::new();
    while j < n && depth > 0 {
        match toks[j].text.as_str() {
            "[" => depth += 1,
            "]" => {
                depth -= 1;
                if depth == 0 {
                    break;
                }
            }
            _ => {
                if toks[j].kind == TokKind::Ident {
                    names.push(&toks[j].text);
                }
            }
        }
        j += 1;
    }
    let has_test = names.iter().any(|s| *s == "test");
    let has_cfg = names.iter().any(|s| *s == "cfg");
    // `#[test]` (lone ident) or any `#[cfg(...)]` mentioning `test`,
    // which covers `#[cfg(all(test, not(loom)))]`.
    let is_test = has_test && (has_cfg || names.len() == 1);
    (is_test, j + 1)
}

pub fn in_spans(line: u32, spans: &[(u32, u32)]) -> bool {
    spans.iter().any(|&(a, b)| a <= line && line <= b)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn comments_and_strings_stay_out_of_the_token_stream() {
        let lx = lex("let a = \"lock().unwrap()\"; // spawn here\n/* match _ */ b");
        let idents: Vec<&str> = lx
            .toks
            .iter()
            .filter(|t| t.kind == TokKind::Ident)
            .map(|t| t.text.as_str())
            .collect();
        assert_eq!(idents, ["let", "a", "b"]);
        assert_eq!(lx.comments.len(), 1);
        assert!(lx.comments[0].text.contains("spawn here"));
    }

    #[test]
    fn raw_strings_and_lifetimes() {
        let lx = lex("let s = r#\"a \" b\"#; fn f<'a>(x: &'a str) -> char { 'x' }");
        let strs: Vec<&str> = lx
            .toks
            .iter()
            .filter(|t| t.kind == TokKind::Str)
            .map(|t| t.text.as_str())
            .collect();
        assert_eq!(strs, ["a \" b"]);
        assert!(lx
            .toks
            .iter()
            .any(|t| t.kind == TokKind::Lifetime && t.text == "'a"));
        assert!(lx.toks.iter().any(|t| t.kind == TokKind::Char));
    }

    #[test]
    fn multiline_tokens_keep_line_numbers() {
        let lx = lex("a\n  .lock()\n  .unwrap()");
        let unwrap = lx.toks.iter().find(|t| t.text == "unwrap").unwrap();
        assert_eq!(unwrap.line, 3);
    }

    #[test]
    fn cfg_test_regions_cover_mod_and_fn() {
        let src = "fn live() {}\n#[cfg(all(test, not(loom)))]\nmod tests {\n  fn x() {}\n}\n#[test]\nfn t() {}\nfn live2() {}";
        let lx = lex(src);
        let spans = test_regions(&lx.toks);
        assert_eq!(spans.len(), 2);
        assert!(in_spans(4, &spans), "inside mod tests");
        assert!(in_spans(7, &spans), "inside #[test] fn");
        assert!(!in_spans(1, &spans));
        assert!(!in_spans(8, &spans));
    }
}
