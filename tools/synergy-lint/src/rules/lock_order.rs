//! Rule 2 — lock-order discipline.
//!
//! Builds a static acquisition-order graph: an edge `A -> B` means some
//! function acquires lock `B` while a guard on lock `A` is lexically
//! live.  A cycle in that graph is a potential ABBA deadlock and fails
//! the lint.  Locks are labelled `EnclosingImplType::field` (file stem
//! when acquired in a free function), which is exact for the codebase's
//! style of `lock_clean(&self.field)` / `self.field.lock()` acquisition.
//!
//! Lexical liveness: a guard bound by `let [mut] g = <acquire>` lives
//! until its block closes or an explicit `drop(g)`; an unbound acquisition
//! (`lock_clean(&self.x).field`) is a statement temporary — it picks up
//! incoming edges from held guards but is never itself "held".
//! The analysis is per-function and intra-procedural by design; guards
//! passed across function boundaries (`fn f(k: &mut Kernel)`) are the
//! caller's to order.

use std::collections::{BTreeMap, BTreeSet};

use crate::lexer::{in_spans, Tok, TokKind};
use crate::rules::Finding;

/// Acquisition graph across the whole tree: edge -> first witness site.
#[derive(Default)]
pub struct LockGraph {
    edges: BTreeMap<String, BTreeSet<String>>,
    sites: BTreeMap<(String, String), (String, u32)>,
}

struct Guard {
    name: String,
    depth: i32,
    label: String,
}

pub fn scan(
    rel: &str,
    toks: &[Tok],
    spans: &[(u32, u32)],
    graph: &mut LockGraph,
    findings: &mut Vec<Finding>,
) {
    let n = toks.len();
    let file_tag = rel
        .rsplit('/')
        .next()
        .unwrap_or(rel)
        .trim_end_matches(".rs")
        .to_string();
    let mut impl_type: Option<String> = None;
    let mut impl_depth = 0i32;
    let mut depth = 0i32;
    let mut guards: Vec<Guard> = Vec::new();
    let mut i = 0usize;

    while i < n {
        let t = &toks[i];
        match t.text.as_str() {
            "{" => depth += 1,
            "}" => {
                depth -= 1;
                guards.retain(|g| g.depth <= depth);
                if impl_type.is_some() && depth < impl_depth {
                    impl_type = None;
                }
            }
            "impl" if t.kind == TokKind::Ident && impl_type.is_none() => {
                let (ty, next) = parse_impl_header(toks, i);
                impl_type = ty;
                impl_depth = depth + 1;
                i = next;
                continue;
            }
            "drop" if t.kind == TokKind::Ident => {
                // drop(g) releases g early.
                if i + 3 < n
                    && toks[i + 1].text == "("
                    && toks[i + 2].kind == TokKind::Ident
                    && toks[i + 3].text == ")"
                {
                    let name = &toks[i + 2].text;
                    guards.retain(|g| g.name != *name);
                }
            }
            "lock_clean" if t.kind == TokKind::Ident && !in_spans(t.line, spans) => {
                // lock_clean(&CHAIN): label from the chain's last ident.
                let mut field = None;
                let mut j = i + 1;
                if j < n && toks[j].text == "(" {
                    j += 1;
                    while j < n && toks[j].text != ")" {
                        if toks[j].kind == TokKind::Ident {
                            field = Some(toks[j].text.clone());
                        }
                        j += 1;
                    }
                }
                if let Some(field) = field {
                    let label = label(&impl_type, &file_tag, &field);
                    record(rel, t.line, &label, &guards, graph, findings);
                    bind_guard(toks, i, depth, &label, &mut guards);
                }
            }
            "lock"
                if t.kind == TokKind::Ident
                    && i >= 2
                    && toks[i - 1].text == "."
                    && i + 2 < n
                    && toks[i + 1].text == "("
                    && toks[i + 2].text == ")"
                    && !in_spans(t.line, spans) =>
            {
                // CHAIN.lock(): std-style acquisition (util/, model code).
                if toks[i - 2].kind == TokKind::Ident {
                    let label = label(&impl_type, &file_tag, &toks[i - 2].text);
                    record(rel, t.line, &label, &guards, graph, findings);
                    bind_guard(toks, i, depth, &label, &mut guards);
                }
            }
            _ => {}
        }
        i += 1;
    }
}

fn label(impl_type: &Option<String>, file_tag: &str, field: &str) -> String {
    match impl_type {
        Some(t) => format!("{t}::{field}"),
        None => format!("{file_tag}::{field}"),
    }
}

fn record(
    rel: &str,
    line: u32,
    new_label: &str,
    guards: &[Guard],
    graph: &mut LockGraph,
    findings: &mut Vec<Finding>,
) {
    for g in guards {
        if g.label == new_label {
            findings.push(Finding {
                file: rel.to_string(),
                line,
                rule: "lock-order",
                message: format!("re-acquires `{new_label}` while already held (self-deadlock)"),
            });
            continue;
        }
        graph
            .edges
            .entry(g.label.clone())
            .or_default()
            .insert(new_label.to_string());
        graph
            .sites
            .entry((g.label.clone(), new_label.to_string()))
            .or_insert_with(|| (rel.to_string(), line));
    }
}

/// `impl [<..>] Type [for Type2]` — returns the implemented-on type name
/// and the index of the opening `{` (or wherever parsing stopped).
fn parse_impl_header(toks: &[Tok], at: usize) -> (Option<String>, usize) {
    let n = toks.len();
    let mut j = at + 1;
    if j < n && toks[j].text == "<" {
        let mut d = 1i32;
        j += 1;
        while j < n && d > 0 {
            match toks[j].text.as_str() {
                "<" => d += 1,
                ">" => d -= 1,
                _ => {}
            }
            j += 1;
        }
    }
    let mut tname: Option<String> = None;
    let mut for_t: Option<String> = None;
    let mut seen_for = false;
    while j < n && toks[j].text != "{" && toks[j].text != "where" {
        if toks[j].kind == TokKind::Ident {
            if toks[j].text == "for" {
                seen_for = true;
            } else if seen_for {
                if for_t.is_none() {
                    for_t = Some(toks[j].text.clone());
                }
            } else if tname.is_none() || toks[j - 1].text == ":" {
                tname = Some(toks[j].text.clone());
            }
        }
        j += 1;
    }
    (for_t.or(tname), j)
}

/// If the acquisition at token `i` is the RHS of `let [mut] NAME = ...`,
/// register NAME as a live guard (shadowing any same-named one).
fn bind_guard(toks: &[Tok], i: usize, depth: i32, label: &str, guards: &mut Vec<Guard>) {
    let mut j = i;
    let mut back = 0;
    while j > 0 && back < 12 {
        j -= 1;
        back += 1;
        match toks[j].text.as_str() {
            "=" => {
                if j >= 1 && toks[j - 1].kind == TokKind::Ident {
                    let name = &toks[j - 1].text;
                    let is_let = (j.saturating_sub(3)..j - 1)
                        .any(|k| toks[k].text == "let");
                    if is_let && name != "mut" {
                        guards.retain(|g| g.name != *name);
                        guards.push(Guard {
                            name: name.clone(),
                            depth,
                            label: label.to_string(),
                        });
                    }
                }
                return;
            }
            ";" | "{" | "}" | "," => return,
            _ => {}
        }
    }
}

impl LockGraph {
    /// DFS cycle check; report the first cycle found with witness sites.
    pub fn check(&self, findings: &mut Vec<Finding>) {
        #[derive(Clone, Copy, PartialEq)]
        enum Color {
            White,
            Grey,
            Black,
        }
        let mut color: BTreeMap<&str, Color> = BTreeMap::new();
        let mut path: Vec<&str> = Vec::new();

        fn dfs<'a>(
            u: &'a str,
            edges: &'a BTreeMap<String, BTreeSet<String>>,
            color: &mut BTreeMap<&'a str, Color>,
            path: &mut Vec<&'a str>,
        ) -> Option<Vec<String>> {
            color.insert(u, Color::Grey);
            path.push(u);
            if let Some(vs) = edges.get(u) {
                for v in vs {
                    match color.get(v.as_str()).copied().unwrap_or(Color::White) {
                        Color::Grey => {
                            let start = path.iter().position(|p| *p == v).unwrap();
                            let mut cyc: Vec<String> =
                                path[start..].iter().map(|s| s.to_string()).collect();
                            cyc.push(v.clone());
                            return Some(cyc);
                        }
                        Color::White => {
                            if let Some(c) = dfs(v, edges, color, path) {
                                return Some(c);
                            }
                        }
                        Color::Black => {}
                    }
                }
            }
            path.pop();
            color.insert(u, Color::Black);
            None
        }

        for u in self.edges.keys() {
            if color.get(u.as_str()).copied().unwrap_or(Color::White) == Color::White {
                if let Some(cyc) = dfs(u, &self.edges, &mut color, &mut path) {
                    let witness: Vec<String> = cyc
                        .windows(2)
                        .filter_map(|w| {
                            self.sites
                                .get(&(w[0].clone(), w[1].clone()))
                                .map(|(f, l)| format!("{f}:{l}"))
                        })
                        .collect();
                    let (file, line) = self
                        .sites
                        .get(&(cyc[0].clone(), cyc[1].clone()))
                        .cloned()
                        .unwrap_or_else(|| ("<graph>".to_string(), 0));
                    findings.push(Finding {
                        file,
                        line,
                        rule: "lock-order",
                        message: format!(
                            "lock acquisition cycle {} (acquired at {})",
                            cyc.join(" -> "),
                            witness.join(", ")
                        ),
                    });
                    return;
                }
            }
        }
    }

    /// Edges as `A -> B` strings (for --verbose / debugging).
    pub fn edge_list(&self) -> Vec<String> {
        self.edges
            .iter()
            .flat_map(|(a, bs)| bs.iter().map(move |b| format!("{a} -> {b}")))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lexer::{lex, test_regions};

    fn run(src: &str) -> (LockGraph, Vec<Finding>) {
        let lx = lex(src);
        let spans = test_regions(&lx.toks);
        let mut graph = LockGraph::default();
        let mut f = Vec::new();
        scan("x/t.rs", &lx.toks, &spans, &mut graph, &mut f);
        graph.check(&mut f);
        (graph, f)
    }

    #[test]
    fn abba_cycle_is_reported() {
        let src = "impl Two {\n\
            fn ab(&self) { let gx = self.x.lock().unwrap(); let _gy = self.y.lock().unwrap(); drop(gx); }\n\
            fn ba(&self) { let gy = self.y.lock().unwrap(); let _gx = self.x.lock().unwrap(); drop(gy); }\n\
        }";
        let (_g, f) = run(src);
        assert_eq!(f.len(), 1, "{f:?}");
        assert!(f[0].message.contains("Two::x -> Two::y -> Two::x")
            || f[0].message.contains("Two::y -> Two::x -> Two::y"));
    }

    #[test]
    fn consistent_order_and_scoped_guards_are_clean() {
        let src = "impl Two {\n\
            fn ab(&self) { let _gx = lock_clean(&self.x); let _gy = lock_clean(&self.y); }\n\
            fn also_ab(&self) { let _gx = lock_clean(&self.x); let _gy = lock_clean(&self.y); }\n\
            fn scoped(&self) { { let _gy = lock_clean(&self.y); } let _gx = lock_clean(&self.x); }\n\
        }";
        let (g, f) = run(src);
        assert!(f.is_empty(), "{f:?}");
        assert_eq!(g.edge_list(), ["Two::x -> Two::y"]);
    }

    #[test]
    fn drop_releases_and_reacquire_is_self_deadlock() {
        let src = "impl One {\n\
            fn ok(&self) { let g = lock_clean(&self.m); drop(g); let _h = lock_clean(&self.m); }\n\
            fn bad(&self) { let _g = lock_clean(&self.m); let _h = lock_clean(&self.m); }\n\
        }";
        let (_g, f) = run(src);
        assert_eq!(f.len(), 1, "{f:?}");
        assert!(f[0].message.contains("self-deadlock"));
        assert_eq!(f[0].line, 3);
    }
}
