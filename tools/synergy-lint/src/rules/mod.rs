//! The five synergy-lint rules.  Each consumes the lexed token stream of
//! one source file (plus the file's test-region spans) and appends
//! [`Finding`]s; `lock_order` additionally accumulates a cross-file
//! acquisition graph checked once at the end.

pub mod bare_lock;
pub mod dispatch;
pub mod knobs;
pub mod lock_order;
pub mod spawn;

use std::fmt;

/// One diagnostic: `file:line: rule: message`.
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord)]
pub struct Finding {
    pub file: String,
    pub line: u32,
    pub rule: &'static str,
    pub message: String,
}

impl fmt::Display for Finding {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{}:{}: {}: {}",
            self.file, self.line, self.rule, self.message
        )
    }
}

/// Scan the collected `//` comments for `// lint: allow(<what>): <why>`
/// escapes with a non-empty justification; return the lines they sit on.
pub fn allow_lines(comments: &[crate::lexer::LineComment], what: &str) -> Vec<u32> {
    let needle = format!("allow({what})");
    comments
        .iter()
        .filter(|c| {
            let t = &c.text;
            let Some(lint_at) = t.find("lint:") else {
                return false;
            };
            let rest = &t[lint_at..];
            let Some(open) = rest.find(&needle) else {
                return false;
            };
            // Justification: non-whitespace after the `):`.
            rest[open + needle.len()..]
                .strip_prefix(':')
                .is_some_and(|j| !j.trim().is_empty())
        })
        .map(|c| c.line)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lexer::lex;

    #[test]
    fn allow_escape_requires_a_justification() {
        let lx = lex(
            "// lint: allow(thread-spawn): real reason\n\
             // lint: allow(thread-spawn):\n\
             // lint: allow(thread-spawn)\n\
             // allow(thread-spawn): missing lint: prefix\n",
        );
        assert_eq!(allow_lines(&lx.comments, "thread-spawn"), vec![1]);
        assert!(allow_lines(&lx.comments, "bare-lock").is_empty());
    }
}
