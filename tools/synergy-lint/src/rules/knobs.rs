//! Rule 5 — knob/README parity.
//!
//! Every `[device]` / `[cluster]` / `[serving]` / `[quant]` key the `.hw_config`
//! parser accepts must appear in a README table row with a non-empty
//! default.  The knobs are the system's operational surface; an
//! undocumented one is a knob nobody can responsibly turn.  The keys are
//! read from the `"key" =>` match arms inside `Sec::Device` /
//! `Sec::Cluster` / `Sec::Serving` / `Sec::Quant` in `config/hw_config.rs`, so the
//! check tracks the parser — adding a knob without documenting it fails
//! CI, with no list to keep in sync by hand.

use crate::lexer::{Tok, TokKind};
use crate::rules::Finding;

#[derive(Debug, PartialEq, Eq)]
pub struct Knob {
    pub section: String,
    pub key: String,
    pub line: u32,
}

/// Extract the accepted keys from the lexed `hw_config.rs` tokens.
pub fn parsed_keys(toks: &[Tok]) -> Vec<Knob> {
    let n = toks.len();
    let mut section: Option<String> = None;
    let mut keys = Vec::new();
    let mut i = 0usize;
    while i < n {
        // `Sec::X =>` in arm-pattern position opens section X.  A
        // `=> Sec::X` value (the section-name dispatch) has `,`/`}` after
        // it instead and must not switch sections.
        if toks[i].text == "Sec"
            && i + 5 < n
            && toks[i + 1].text == ":"
            && toks[i + 2].text == ":"
            && toks[i + 3].kind == TokKind::Ident
            && toks[i + 4].text == "="
            && toks[i + 5].text == ">"
        {
            let sec = toks[i + 3].text.as_str();
            section = if matches!(sec, "Device" | "Cluster" | "Serving" | "Quant") {
                Some(sec.to_string())
            } else {
                None
            };
            i += 6;
            continue;
        }
        if let Some(sec) = &section {
            if toks[i].kind == TokKind::Str
                && i + 2 < n
                && toks[i + 1].text == "="
                && toks[i + 2].text == ">"
            {
                keys.push(Knob {
                    section: sec.clone(),
                    key: toks[i].text.clone(),
                    line: toks[i].line,
                });
            }
        }
        i += 1;
    }
    keys
}

/// Check every knob against the README's tables.  A knob is documented
/// when some table row (a line starting with `|`) carries `` `key` `` in
/// its first cell and a non-empty default in its second.
pub fn check(hw_rel: &str, knobs: &[Knob], readme: &str, findings: &mut Vec<Finding>) {
    let rows: Vec<&str> = readme
        .lines()
        .filter(|l| l.trim_start().starts_with('|'))
        .collect();
    for knob in knobs {
        let tag = format!("`{}`", knob.key);
        let documented = rows.iter().any(|row| {
            let cells: Vec<&str> = row.split('|').map(str::trim).collect();
            // ["", key, default, meaning, ""] for a well-formed row.
            cells.len() >= 4
                && cells[1].contains(&tag)
                && !cells[2].is_empty()
                && cells[2].chars().any(|c| c != '-')
        });
        if !documented {
            findings.push(Finding {
                file: hw_rel.to_string(),
                line: knob.line,
                rule: "knob-doc",
                message: format!(
                    "[{}] key `{}` has no README table row with a default",
                    knob.section.to_lowercase(),
                    knob.key
                ),
            });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lexer::lex;

    const PARSER: &str = r#"
        match kind.as_str() {
            "device" => Sec::Device,
            "cluster" => Sec::Cluster,
        }
        match sec {
            Sec::Device => match k {
                "tile_size" => 1,
                "fpga_mhz" => 2,
                other => bail!("unknown"),
            },
            Sec::PeType => match k {
                "ii" => 3,
                other => bail!("unknown"),
            },
            Sec::Serving => match k {
                "max_batch" => 4,
                other => bail!("unknown"),
            },
            Sec::None => bail!("outside"),
        }
    "#;

    #[test]
    fn keys_come_from_arm_position_sections_only() {
        let lx = lex(PARSER);
        let keys = parsed_keys(&lx.toks);
        let got: Vec<(&str, &str)> = keys
            .iter()
            .map(|k| (k.section.as_str(), k.key.as_str()))
            .collect();
        // No "device"/"cluster" section-name strings, no PeType keys.
        assert_eq!(
            got,
            [
                ("Device", "tile_size"),
                ("Device", "fpga_mhz"),
                ("Serving", "max_batch")
            ]
        );
    }

    #[test]
    fn undocumented_and_defaultless_keys_are_flagged() {
        let lx = lex(PARSER);
        let keys = parsed_keys(&lx.toks);
        let readme = "\
            | knob | default | meaning |\n\
            |---|---|---|\n\
            | `tile_size` | 32 | tile edge |\n\
            | `fpga_mhz` |  | no default given |\n";
        let mut f = Vec::new();
        check("config/hw_config.rs", &keys, readme, &mut f);
        let flagged: Vec<&str> = f.iter().map(|x| x.rule).collect();
        assert_eq!(flagged, ["knob-doc", "knob-doc"], "{f:?}");
        assert!(f[0].message.contains("`fpga_mhz`"));
        assert!(f[1].message.contains("`max_batch`"));
    }
}
