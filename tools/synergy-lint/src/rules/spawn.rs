//! Rule 1 — thread-spawn containment.
//!
//! All runtime threads must be born in the delegate/pool layer (or the
//! serving front door): that is where panics are caught, reports are
//! joined, and shutdown is sequenced.  A `thread::spawn` anywhere else is
//! an unmanaged thread the teardown story does not know about.  The
//! escape hatch is a justified `// lint: allow(thread-spawn): <why>`
//! within the six lines above the spawn.

use crate::lexer::{in_spans, LineComment, Tok, TokKind};
use crate::rules::{allow_lines, Finding};

/// Files allowed to spawn threads freely (relative to the src root).
pub const ALLOWED: &[&str] = &[
    "rt/pool.rs",
    "rt/delegate.rs",
    "accel/backend.rs",
    "serve/server.rs",
    "serve/shard_server.rs",
];

pub fn check(
    rel: &str,
    toks: &[Tok],
    comments: &[LineComment],
    spans: &[(u32, u32)],
    findings: &mut Vec<Finding>,
) {
    if ALLOWED.contains(&rel) {
        return;
    }
    let allows = allow_lines(comments, "thread-spawn");
    for i in 1..toks.len() {
        let t = &toks[i];
        if t.kind != TokKind::Ident || t.text != "spawn" {
            continue;
        }
        let prev = toks[i - 1].text.as_str();
        if prev != "." && prev != ":" {
            continue;
        }
        // The receiver chain (back to the statement start) must mention
        // `thread` or `Builder` — `pool::spawn(...)` and friends are this
        // crate's own managed entry points, not OS spawns.
        let mut is_thread = false;
        let mut j = i - 1;
        let mut back = 0;
        loop {
            let tt = &toks[j];
            if matches!(tt.text.as_str(), ";" | "{" | "}") || back >= 40 {
                break;
            }
            if tt.kind == TokKind::Ident && (tt.text == "thread" || tt.text == "Builder") {
                is_thread = true;
                break;
            }
            if j == 0 {
                break;
            }
            j -= 1;
            back += 1;
        }
        if !is_thread || in_spans(t.line, spans) {
            continue;
        }
        if allows.iter().any(|&al| al + 6 >= t.line && al <= t.line) {
            continue;
        }
        findings.push(Finding {
            file: rel.to_string(),
            line: t.line,
            rule: "thread-spawn",
            message: "thread spawned outside the delegate/pool allowlist \
                      (escape: `// lint: allow(thread-spawn): <why>`)"
                .to_string(),
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lexer::{lex, test_regions};

    fn run(rel: &str, src: &str) -> Vec<Finding> {
        let lx = lex(src);
        let spans = test_regions(&lx.toks);
        let mut f = Vec::new();
        check(rel, &lx.toks, &lx.comments, &spans, &mut f);
        f
    }

    #[test]
    fn flags_bare_and_builder_spawns() {
        let src = "fn f() {\n  std::thread::spawn(|| {});\n  \
                   std::thread::Builder::new().name(n).spawn(|| {}).unwrap();\n}";
        let f = run("sim/clock.rs", src);
        assert_eq!(f.len(), 2);
        assert_eq!(f[0].line, 2);
        assert_eq!(f[1].line, 3);
    }

    #[test]
    fn allowlist_escape_and_tests_are_exempt() {
        let allowed = run("rt/pool.rs", "fn f() { std::thread::spawn(|| {}); }");
        assert!(allowed.is_empty());
        let escaped = run(
            "sim/clock.rs",
            "fn f() {\n  // lint: allow(thread-spawn): managed elsewhere.\n  \
             std::thread::spawn(|| {});\n}",
        );
        assert!(escaped.is_empty());
        let test_code = run(
            "sim/clock.rs",
            "#[cfg(test)]\nmod tests {\n  fn t() { std::thread::spawn(|| {}); }\n}",
        );
        assert!(test_code.is_empty());
    }

    #[test]
    fn own_spawn_helpers_do_not_trip() {
        let f = run(
            "rt/driver.rs",
            "fn f() { delegate::spawn(cfg); pool.spawn_all(); }",
        );
        assert!(f.is_empty());
    }
}
