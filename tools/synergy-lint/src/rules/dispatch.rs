//! Rule 4 — exhaustive `JobClass` / `JobKind` dispatch.
//!
//! Scheduling correctness rests on every job class being routed, stolen,
//! and executed deliberately.  A `_` (or lone-binding) arm in a match
//! over `JobClass`/`JobKind` silently inherits whatever the wildcard does
//! when a new class is added — exactly how a class ends up unroutable or
//! executed inline.  In dispatch/steal code (mm/, sched/, rt/, accel/),
//! such matches must spell every class; the compiler then points at every
//! dispatch decision when the enum grows.

use crate::lexer::{in_spans, Tok, TokKind};
use crate::rules::Finding;

/// Dispatch/steal module prefixes (relative to the src root).
pub const SCOPE: &[&str] = &["mm/", "sched/", "rt/", "accel/"];

pub fn check(rel: &str, toks: &[Tok], spans: &[(u32, u32)], findings: &mut Vec<Finding>) {
    if !SCOPE.iter().any(|p| rel.starts_with(p)) {
        return;
    }
    let n = toks.len();
    let mut i = 0usize;
    while i < n {
        if toks[i].kind == TokKind::Ident && toks[i].text == "match" {
            // Advance by one (not past the match): nested matches inside
            // arm bodies are then rediscovered and parsed on their own.
            if let Some((arms, _next)) = parse_match(toks, i) {
                let mentions = arms.iter().flat_map(|(pat, _)| pat.iter()).any(|p| {
                    p.kind == TokKind::Ident && (p.text == "JobClass" || p.text == "JobKind")
                });
                if mentions {
                    for (pat, line) in &arms {
                        if in_spans(*line, spans) {
                            continue;
                        }
                        if is_wildcard(pat) {
                            findings.push(Finding {
                                file: rel.to_string(),
                                line: *line,
                                rule: "dispatch-wildcard",
                                message: "wildcard arm in a JobClass/JobKind match: \
                                          spell every class so adding one forces a \
                                          dispatch decision"
                                    .to_string(),
                            });
                        }
                    }
                }
            }
        }
        i += 1;
    }
}

fn is_wildcard(pat: &[Tok]) -> bool {
    if pat.len() != 1 {
        return false;
    }
    let t = &pat[0];
    t.text == "_"
        || (t.kind == TokKind::Ident
            && t.text.chars().next().is_some_and(|c| c.is_ascii_lowercase())
            && t.text != "true"
            && t.text != "false")
}

/// Parse the match expression at token `at`; return each arm's pattern
/// tokens (guard included — `_ if cond` is more than one token, so a
/// guarded wildcard does not count as bare, the conservative direction)
/// with its first line, plus the index just past the match.  Arm bodies
/// are skipped, not descended into; the caller rediscovers nested
/// matches by continuing its token scan one past the `match` keyword.
fn parse_match(toks: &[Tok], at: usize) -> Option<(Vec<(Vec<Tok>, u32)>, usize)> {
    let n = toks.len();
    // Scrutinee: scan to the `{` at bracket depth 0.
    let mut j = at + 1;
    let mut d = 0i32;
    loop {
        if j >= n {
            return None;
        }
        match toks[j].text.as_str() {
            "(" | "[" => d += 1,
            ")" | "]" => d -= 1,
            "{" if d == 0 => break,
            ";" => return None,
            _ => {}
        }
        j += 1;
    }
    let mut arms: Vec<(Vec<Tok>, u32)> = Vec::new();
    let mut k = j + 1;
    let mut pat: Vec<Tok> = Vec::new();
    let mut d = 0i32;
    while k < n {
        let t = &toks[k];
        if d == 0 && t.text == "}" {
            return Some((arms, k + 1));
        }
        if d == 0 && t.text == "=" && k + 1 < n && toks[k + 1].text == ">" {
            let line = pat.first().map(|p| p.line).unwrap_or(t.line);
            arms.push((std::mem::take(&mut pat), line));
            k += 2;
            // Skip the arm body: a block, or an expression up to `,`.
            if k < n && toks[k].text == "{" {
                let mut bd = 1i32;
                k += 1;
                while k < n && bd > 0 {
                    match toks[k].text.as_str() {
                        "{" => bd += 1,
                        "}" => bd -= 1,
                        _ => {}
                    }
                    k += 1;
                }
                if k < n && toks[k].text == "," {
                    k += 1;
                }
            } else {
                let mut bd = 0i32;
                while k < n {
                    match toks[k].text.as_str() {
                        "(" | "[" | "{" => bd += 1,
                        ")" | "]" => bd -= 1,
                        "}" => {
                            if bd == 0 {
                                break; // the match's own closing brace
                            }
                            bd -= 1;
                        }
                        "," if bd == 0 => {
                            k += 1;
                            break;
                        }
                        _ => {}
                    }
                    k += 1;
                }
            }
            continue;
        }
        match t.text.as_str() {
            "(" | "[" | "{" => d += 1,
            ")" | "]" | "}" => d -= 1,
            _ => {}
        }
        pat.push(t.clone());
        k += 1;
    }
    Some((arms, k))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lexer::{lex, test_regions};

    fn run(rel: &str, src: &str) -> Vec<Finding> {
        let lx = lex(src);
        let spans = test_regions(&lx.toks);
        let mut f = Vec::new();
        check(rel, &lx.toks, &spans, &mut f);
        f
    }

    #[test]
    fn flags_underscore_and_binding_arms() {
        let src = "fn f(c: JobClass) -> u32 {\n  match c {\n    JobClass::ConvTile => 0,\n    \
                   _ => 9,\n  }\n}\n\
                   fn g(c: JobClass) -> u32 {\n  match c {\n    JobClass::ConvTile => 0,\n    \
                   other => 9,\n  }\n}";
        let f = run("mm/job.rs", src);
        assert_eq!(f.len(), 2, "{f:?}");
        assert_eq!((f[0].line, f[1].line), (4, 10));
    }

    #[test]
    fn exhaustive_struct_patterns_and_or_arms_pass() {
        let src = "fn f(k: &JobKind) -> u32 {\n  match k {\n    \
                   JobKind::ConvTile { a_tiles, b_tiles } => a_tiles + b_tiles,\n    \
                   JobKind::FcGemm { .. } | JobKind::Im2col { .. } => 1,\n  }\n}";
        assert!(run("mm/job.rs", src).is_empty());
    }

    #[test]
    fn unrelated_matches_and_out_of_scope_files_pass() {
        let src = "fn f(n: u32) -> u32 { match n { 0 => 1, _ => 2 } }";
        assert!(run("mm/job.rs", src).is_empty());
        let job = "fn f(c: JobClass) -> u32 { match c { _ => 9 } }";
        assert!(run("serve/server.rs", job).is_empty());
    }

    #[test]
    fn nested_match_in_arm_body_is_still_checked() {
        let src = "fn f(c: JobClass, n: u32) -> u32 {\n  match n {\n    0 => match c {\n      \
                   JobClass::ConvTile => 0,\n      _ => 1,\n    },\n    _ => 2,\n  }\n}";
        let f = run("mm/job.rs", src);
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].line, 5);
    }
}
