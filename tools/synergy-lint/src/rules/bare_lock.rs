//! Rule 3 — poison hygiene.
//!
//! Delegate threads die with locks held when a backend panics; a bare
//! `.lock().unwrap()` anywhere a delegate (or a thread observing a dead
//! delegate's state) can reach then turns one dead accelerator into a
//! poison cascade.  Those modules must use `util::sync::lock_clean`,
//! which makes the recover-the-data decision once, in one place.  The
//! escape is a justified `// lint: allow(bare-lock): <why>` within the
//! three lines above.  `util/` itself is out of scope: the model
//! checker's internal std locks are the mechanism the facade is built on.

use crate::lexer::{in_spans, LineComment, Tok, TokKind};
use crate::rules::{allow_lines, Finding};

/// Module prefixes a delegate can reach (relative to the src root).
pub const SCOPE: &[&str] = &[
    "mm/", "cluster/", "pipeline/", "rt/", "sched/", "serve/", "accel/",
];

pub fn check(
    rel: &str,
    toks: &[Tok],
    comments: &[LineComment],
    spans: &[(u32, u32)],
    findings: &mut Vec<Finding>,
) {
    if !SCOPE.iter().any(|p| rel.starts_with(p)) {
        return;
    }
    let allows = allow_lines(comments, "bare-lock");
    let n = toks.len();
    for i in 1..n.saturating_sub(4) {
        // `.lock().unwrap()` — token-wise, so line breaks inside the
        // chain cannot hide it.
        if toks[i].kind == TokKind::Ident
            && toks[i].text == "lock"
            && toks[i - 1].text == "."
            && toks[i + 1].text == "("
            && toks[i + 2].text == ")"
            && toks[i + 3].text == "."
            && toks[i + 4].text == "unwrap"
        {
            let line = toks[i].line;
            if in_spans(line, spans) {
                continue;
            }
            if allows.iter().any(|&al| al + 3 >= line && al <= line) {
                continue;
            }
            findings.push(Finding {
                file: rel.to_string(),
                line,
                rule: "bare-lock",
                message: "bare `.lock().unwrap()` in a delegate-reachable module; \
                          use `util::sync::lock_clean` (escape: \
                          `// lint: allow(bare-lock): <why>`)"
                    .to_string(),
            });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lexer::{lex, test_regions};

    fn run(rel: &str, src: &str) -> Vec<Finding> {
        let lx = lex(src);
        let spans = test_regions(&lx.toks);
        let mut f = Vec::new();
        check(rel, &lx.toks, &lx.comments, &spans, &mut f);
        f
    }

    #[test]
    fn flags_single_and_multi_line_bare_locks() {
        let f = run(
            "serve/stats.rs",
            "fn f(m: &M) {\n  m.lock().unwrap();\n  m\n    .lock()\n    .unwrap();\n}",
        );
        assert_eq!(f.len(), 2);
        assert_eq!((f[0].line, f[1].line), (2, 4));
    }

    #[test]
    fn out_of_scope_escaped_and_test_code_pass() {
        assert!(run("util/model.rs", "fn f(m: &M) { m.lock().unwrap(); }").is_empty());
        assert!(run(
            "rt/pool.rs",
            "fn f(m: &M) {\n  // lint: allow(bare-lock): poisoning is fatal here anyway.\n  \
             m.lock().unwrap();\n}",
        )
        .is_empty());
        assert!(run(
            "rt/pool.rs",
            "#[cfg(test)]\nmod tests {\n  fn t(m: &M) { m.lock().unwrap(); }\n}",
        )
        .is_empty());
    }

    #[test]
    fn lock_clean_is_the_blessed_spelling() {
        assert!(run("serve/stats.rs", "fn f(m: &M) { let g = lock_clean(m); }").is_empty());
    }
}
