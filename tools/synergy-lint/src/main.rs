//! synergy-lint — machine-checks the concurrency and documentation
//! invariants the runtime's correctness rests on:
//!
//! 1. **thread-spawn** — threads are born only in the delegate/pool layer
//!    (allowlist in `rules::spawn`), or carry a justified
//!    `// lint: allow(thread-spawn): <why>`.
//! 2. **lock-order** — the static lock-acquisition graph is acyclic (no
//!    ABBA deadlocks, no lexical self-deadlocks).
//! 3. **bare-lock** — delegate-reachable modules use
//!    `util::sync::lock_clean`, never bare `.lock().unwrap()` (escape:
//!    `// lint: allow(bare-lock): <why>`).
//! 4. **dispatch-wildcard** — matches over `JobClass`/`JobKind` in
//!    dispatch/steal code spell every class; no `_` arms.
//! 5. **knob-doc** — every `[device]`/`[cluster]`/`[serving]` key the
//!    `.hw_config` parser accepts is documented in the README with a
//!    default.
//!
//! Usage (defaults fit an invocation from the repo root):
//!
//! ```sh
//! synergy-lint [--src rust/src] [--readme README.md] \
//!              [--hw-config <src>/config/hw_config.rs] [--verbose]
//! ```
//!
//! Prints `file:line: rule: message` per finding; exit code 1 if any.

mod lexer;
mod rules;

use std::fs;
use std::path::{Path, PathBuf};
use std::process::ExitCode;

use rules::lock_order::LockGraph;
use rules::Finding;

struct Args {
    src: PathBuf,
    readme: PathBuf,
    hw_config: PathBuf,
    verbose: bool,
}

fn parse_args() -> Result<Args, String> {
    let mut src = PathBuf::from("rust/src");
    let mut readme = PathBuf::from("README.md");
    let mut hw_config: Option<PathBuf> = None;
    let mut verbose = false;
    let mut it = std::env::args().skip(1);
    while let Some(a) = it.next() {
        let mut val = |name: &str| {
            it.next()
                .ok_or_else(|| format!("{name} needs a value"))
                .map(PathBuf::from)
        };
        match a.as_str() {
            "--src" => src = val("--src")?,
            "--readme" => readme = val("--readme")?,
            "--hw-config" => hw_config = Some(val("--hw-config")?),
            "--verbose" => verbose = true,
            "--help" | "-h" => {
                return Err("usage: synergy-lint [--src DIR] [--readme FILE] \
                            [--hw-config FILE] [--verbose]"
                    .to_string())
            }
            other => return Err(format!("unknown argument {other}")),
        }
    }
    let hw_config = hw_config.unwrap_or_else(|| src.join("config/hw_config.rs"));
    Ok(Args {
        src,
        readme,
        hw_config,
        verbose,
    })
}

fn rust_files(root: &Path) -> Vec<PathBuf> {
    let mut out = Vec::new();
    let mut stack = vec![root.to_path_buf()];
    while let Some(dir) = stack.pop() {
        let Ok(entries) = fs::read_dir(&dir) else {
            continue;
        };
        for e in entries.flatten() {
            let p = e.path();
            if p.is_dir() {
                stack.push(p);
            } else if p.extension().is_some_and(|x| x == "rs") {
                out.push(p);
            }
        }
    }
    out.sort();
    out
}

/// Run all rules over `src` + `readme` + `hw_config`; pure so the
/// integration tests drive it against fixture trees.
fn run(args: &Args) -> Result<Vec<Finding>, String> {
    let mut findings = Vec::new();
    let mut graph = LockGraph::default();
    for path in rust_files(&args.src) {
        let rel = path
            .strip_prefix(&args.src)
            .unwrap_or(&path)
            .to_string_lossy()
            .replace('\\', "/");
        let text = fs::read_to_string(&path)
            .map_err(|e| format!("reading {}: {e}", path.display()))?;
        let lx = lexer::lex(&text);
        let spans = lexer::test_regions(&lx.toks);
        rules::spawn::check(&rel, &lx.toks, &lx.comments, &spans, &mut findings);
        rules::lock_order::scan(&rel, &lx.toks, &spans, &mut graph, &mut findings);
        rules::bare_lock::check(&rel, &lx.toks, &lx.comments, &spans, &mut findings);
        rules::dispatch::check(&rel, &lx.toks, &spans, &mut findings);
    }
    graph.check(&mut findings);
    if args.verbose {
        for e in graph.edge_list() {
            eprintln!("lock edge: {e}");
        }
    }

    let hw_text = fs::read_to_string(&args.hw_config)
        .map_err(|e| format!("reading {}: {e}", args.hw_config.display()))?;
    let readme_text = fs::read_to_string(&args.readme)
        .map_err(|e| format!("reading {}: {e}", args.readme.display()))?;
    let knobs = rules::knobs::parsed_keys(&lexer::lex(&hw_text).toks);
    if args.verbose {
        eprintln!("knob keys parsed: {}", knobs.len());
    }
    let hw_rel = args.hw_config.to_string_lossy().replace('\\', "/");
    rules::knobs::check(&hw_rel, &knobs, &readme_text, &mut findings);

    findings.sort();
    findings.dedup();
    Ok(findings)
}

fn main() -> ExitCode {
    let args = match parse_args() {
        Ok(a) => a,
        Err(msg) => {
            eprintln!("{msg}");
            return ExitCode::from(2);
        }
    };
    match run(&args) {
        Ok(findings) if findings.is_empty() => {
            eprintln!("synergy-lint: clean");
            ExitCode::SUCCESS
        }
        Ok(findings) => {
            for f in &findings {
                println!("{f}");
            }
            eprintln!("synergy-lint: {} finding(s)", findings.len());
            ExitCode::FAILURE
        }
        Err(msg) => {
            eprintln!("synergy-lint: {msg}");
            ExitCode::from(2)
        }
    }
}
