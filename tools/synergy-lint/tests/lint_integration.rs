//! Integration tests: drive the built `synergy-lint` binary against the
//! bad-fixture tree (every rule must fire with its expected diagnostic)
//! and against the real repository tree (which must be clean — fixing the
//! tree to pass its own linter was part of landing the linter).

use std::path::{Path, PathBuf};
use std::process::{Command, Output};

fn manifest_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
}

fn run_lint(src: &Path, readme: &Path) -> Output {
    Command::new(env!("CARGO_BIN_EXE_synergy-lint"))
        .arg("--src")
        .arg(src)
        .arg("--readme")
        .arg(readme)
        .output()
        .expect("run synergy-lint")
}

#[test]
fn bad_fixtures_produce_every_expected_diagnostic() {
    let fx = manifest_dir().join("tests/fixtures");
    let out = run_lint(&fx.join("bad_src"), &fx.join("README.md"));
    assert_eq!(out.status.code(), Some(1), "findings must exit 1");
    let stdout = String::from_utf8_lossy(&out.stdout);

    let expected = [
        // rule 1: both spawn shapes, outside the allowlist.
        "rogue_spawn.rs:2: thread-spawn:",
        "rogue_spawn.rs:5: thread-spawn:",
        // rule 2: the ABBA cycle between Two::x and Two::y.
        "lock_cycle.rs:", // file carries the witness site
        // rule 3: single-line and split-across-lines bare locks.
        "serve/bare_lock.rs:2: bare-lock:",
        "serve/bare_lock.rs:6: bare-lock:",
        // rule 4: `_` arm, lone-binding arm, and a lone-binding arm in a
        // quantized JobKind match.
        "mm/wildcard_match.rs:4: dispatch-wildcard:",
        "mm/wildcard_match.rs:10: dispatch-wildcard:",
        "mm/wildcard_match.rs:29: dispatch-wildcard:",
        // rule 5: the knob missing from the fixture README.
        "knob-doc: [serving] key `undocumented_knob`",
    ];
    for needle in expected {
        assert!(
            stdout.contains(needle),
            "missing diagnostic {needle:?} in:\n{stdout}"
        );
    }
    assert!(
        stdout.contains("lock-order") && stdout.contains("Two::"),
        "lock cycle not reported:\n{stdout}"
    );

    let absent = [
        // escaped spawn (line 8) and escaped bare lock (line 11).
        "rogue_spawn.rs:8:",
        "bare_lock.rs:11:",
        // spawn inside #[cfg(test)] (line 15).
        "rogue_spawn.rs:15:",
        // allowlisted file may spawn.
        "pool.rs",
        // exhaustive + unrelated matches are fine, including the
        // seven-class q8 dispatch.
        "wildcard_match.rs:16:",
        "wildcard_match.rs:23:",
        "wildcard_match.rs:34:",
        // documented knob is fine.
        "`max_batch`",
    ];
    for needle in absent {
        assert!(
            !stdout.contains(needle),
            "unexpected diagnostic {needle:?} in:\n{stdout}"
        );
    }
}

#[test]
fn shipped_tree_is_clean() {
    let repo = manifest_dir().join("../..");
    let out = run_lint(&repo.join("rust/src"), &repo.join("README.md"));
    let stdout = String::from_utf8_lossy(&out.stdout);
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert_eq!(
        out.status.code(),
        Some(0),
        "the shipped tree must pass its own linter:\n{stdout}\n{stderr}"
    );
}

#[test]
fn missing_src_dir_is_a_usage_error_not_a_pass() {
    let fx = manifest_dir().join("tests/fixtures");
    let out = run_lint(&fx.join("does_not_exist"), &fx.join("README.md"));
    // No .rs files found is vacuously lintable, but the hw-config read
    // must fail loudly rather than reporting a clean run.
    assert_eq!(out.status.code(), Some(2), "expected a usage error");
}
