pub struct Two {
    x: Mutex<u32>,
    y: Mutex<u32>,
}
impl Two {
    pub fn ab(&self) {
        let _gx = lock_clean(&self.x);
        let _gy = lock_clean(&self.y);
    }
    pub fn ba(&self) {
        let _gy = lock_clean(&self.y);
        let _gx = lock_clean(&self.x);
    }
}
