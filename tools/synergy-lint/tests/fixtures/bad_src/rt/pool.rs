// Fixture: this path is on the spawn allowlist, so this is legal.
pub fn managed() {
    std::thread::spawn(|| {});
}
