pub fn bad(c: JobClass) -> u32 {
    match c {
        JobClass::ConvTile => 0,
        _ => 9,
    }
}
pub fn bad_binding(c: JobClass) -> u32 {
    match c {
        JobClass::ConvTile => 0,
        other => 9,
    }
}
pub fn good(c: JobClass) -> u32 {
    match c {
        JobClass::ConvTile => 0,
        JobClass::FcGemm => 1,
        JobClass::Im2col | JobClass::FcGemmBatch => 2,
    }
}
pub fn unrelated(n: u32) -> u32 {
    match n {
        0 => 1,
        _ => 2,
    }
}
