pub fn bad(c: JobClass) -> u32 {
    match c {
        JobClass::ConvTile => 0,
        _ => 9,
    }
}
pub fn bad_binding(c: JobClass) -> u32 {
    match c {
        JobClass::ConvTile => 0,
        other => 9,
    }
}
pub fn good(c: JobClass) -> u32 {
    match c {
        JobClass::ConvTile => 0,
        JobClass::FcGemm => 1,
        JobClass::Im2col | JobClass::FcGemmBatch => 2,
    }
}
pub fn unrelated(n: u32) -> u32 {
    match n {
        0 => 1,
        _ => 2,
    }
}
pub fn bad_q8(k: &JobKind) -> u32 {
    match k {
        JobKind::ConvTileQ8 { .. } => 0,
        other => 9,
    }
}
pub fn good_q8(c: JobClass) -> u32 {
    match c {
        JobClass::ConvTile | JobClass::ConvTileQ8 => 0,
        JobClass::FcGemm | JobClass::FcGemmQ8 => 1,
        JobClass::Im2col => 2,
        JobClass::FcGemmBatch | JobClass::FcGemmBatchQ8 => 3,
    }
}
