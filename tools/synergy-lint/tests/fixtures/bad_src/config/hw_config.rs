// Fixture: a minimal parser shape with one undocumented serving knob.
pub fn apply(sec: Sec, k: &str) -> u32 {
    match sec {
        Sec::Serving => match k {
            "max_batch" => 1,
            "undocumented_knob" => 2,
            other => 0,
        },
        Sec::None => 0,
    }
}
