pub fn sneaky() {
    std::thread::spawn(|| {});
    let _h = std::thread::Builder::new()
        .name("x".into())
        .spawn(|| {})
        .unwrap();
    // lint: allow(thread-spawn): justified helper thread for the fixture.
    std::thread::spawn(|| {});
}

#[cfg(test)]
mod tests {
    #[test]
    fn in_tests_is_fine() {
        std::thread::spawn(|| {}).join().unwrap();
    }
}
