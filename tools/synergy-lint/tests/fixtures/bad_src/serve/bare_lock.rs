pub fn naked(m: &Mutex<u32>) -> u32 {
    *m.lock().unwrap()
}
pub fn naked_multiline(m: &Mutex<u32>) -> u32 {
    *m
        .lock()
        .unwrap()
}
pub fn excused(m: &Mutex<u32>) -> u32 {
    // lint: allow(bare-lock): fixture demonstrates a justified bare lock.
    *m.lock().unwrap()
}
