//! `cargo bench --bench fig07_mmu` — regenerates paper Fig 7 (single- vs multi-MMU scaling).
use std::time::Instant;

fn main() {
    let t0 = Instant::now();
    let report = synergy::experiments::fig07_mmu::run();
    report.print();
    println!("[bench] fig07_mmu regenerated in {:.2}s", t0.elapsed().as_secs_f64());
}
