//! `cargo bench --bench serve_throughput` — sustained multi-stream serving
//! throughput (admission → micro-batcher → pipelines → shared pool) vs the
//! single-stream driver baseline, across batch policies, plus the
//! `[serving]` knob sweep (`drain_extra` × `steal_min_victim`).  The
//! shipped defaults (`drain_extra = 3`, `steal_min_victim = 0` = the
//! batch-derived threshold) are provisional until this sweep runs on the
//! target hardware.

use std::sync::Arc;
use std::time::{Duration, Instant};

use synergy::config::zoo;
use synergy::nn::Network;
use synergy::rt::{self, RtOptions};
use synergy::serve::{RequestStream, ServeOptions, Server};
use synergy::tensor::Tensor;
use synergy::util::bench::{fmt, Table};

const STREAMS: usize = 4;
const REQUESTS_PER_STREAM: u64 = 16;
const RATE_RPS: f64 = 1000.0;

fn serve_run(nets: &[Arc<Network>], max_batch: usize) -> (f64, f64, f64, f64) {
    serve_run_knobs(nets, max_batch, None, None)
}

/// One serving run with optional `[serving]` knob overrides
/// (`None` = the shipped defaults from `ServeCfg`).
fn serve_run_knobs(
    nets: &[Arc<Network>],
    max_batch: usize,
    drain_extra: Option<usize>,
    steal_min_victim: Option<usize>,
) -> (f64, f64, f64, f64) {
    let mut options = ServeOptions::default();
    options.batch.max_batch = max_batch;
    options.batch.window = Duration::from_micros(1500);
    options.admission_depth = 1024;
    if let Some(d) = drain_extra {
        options.hw.serving.drain_extra = d;
    }
    if let Some(s) = steal_min_victim {
        options.hw.serving.steal_min_victim = s;
    }
    let server = Arc::new(Server::start(nets.to_vec(), options).unwrap());
    let mut clients = Vec::new();
    for stream_id in 0..STREAMS {
        let net_id = stream_id % nets.len();
        let server = Arc::clone(&server);
        let mut stream = RequestStream::new(
            stream_id,
            net_id,
            Arc::clone(&nets[net_id]),
            RATE_RPS,
            REQUESTS_PER_STREAM,
        );
        clients.push(std::thread::spawn(move || {
            while let Some((gap, req)) = stream.next_arrival() {
                std::thread::sleep(gap);
                server.submit(req);
            }
        }));
    }
    for c in clients {
        c.join().unwrap();
    }
    let server = match Arc::try_unwrap(server) {
        Ok(s) => s,
        Err(_) => panic!("server still shared"),
    };
    let (stats, responses) = server.shutdown().unwrap();
    assert_eq!(stats.completed as usize, responses.len());
    assert_eq!(stats.completed, STREAMS as u64 * REQUESTS_PER_STREAM);
    (
        stats.throughput_rps,
        stats.p50_ms,
        stats.p99_ms,
        stats.mean_batch,
    )
}

fn main() {
    let t0 = Instant::now();
    let nets: Vec<Arc<Network>> = ["mpcnn", "mnist"]
        .iter()
        .map(|n| Arc::new(Network::new(zoo::load(n).unwrap(), 32).unwrap()))
        .collect();

    // Baseline: the single-stream driver at the same total frame count.
    let total = (STREAMS as u64 * REQUESTS_PER_STREAM) / 2;
    let mut baseline_fps = 0.0;
    for net in &nets {
        let frames: Vec<(u64, Tensor)> =
            (0..total).map(|f| (f, net.make_input(f))).collect();
        let report =
            rt::driver::run_stream(Arc::clone(net), RtOptions::default(), frames).unwrap();
        baseline_fps += report.fps;
    }

    let mut table = Table::new(&[
        "configuration",
        "req/s",
        "p50 ms",
        "p99 ms",
        "mean batch",
    ]);
    table.row(vec![
        "driver 1-stream/net (sum)".into(),
        fmt(baseline_fps),
        "-".into(),
        "-".into(),
        "1.00".into(),
    ]);
    for max_batch in [1, 4, 8] {
        let (rps, p50, p99, mean_batch) = serve_run(&nets, max_batch);
        table.row(vec![
            format!("serve {STREAMS} streams, max_batch {max_batch}"),
            fmt(rps),
            fmt(p50),
            fmt(p99),
            fmt(mean_batch),
        ]);
    }
    table.print();

    // `[serving]` knob sweep: delegate drain depth × thief steal
    // threshold (0 = the batch-derived `StealPolicy::batched` default).
    // The shipped defaults (drain_extra = 3, steal_min_victim = 0) are
    // provisional; run this sweep on target hardware to pick real ones.
    let mut sweep = Table::new(&[
        "drain_extra",
        "steal_min_victim",
        "req/s",
        "p99 ms",
    ]);
    for drain in [0usize, 3, 7] {
        for steal_min in [0usize, 8] {
            let (rps, _p50, p99, _mb) =
                serve_run_knobs(&nets, 4, Some(drain), Some(steal_min));
            sweep.row(vec![
                drain.to_string(),
                if steal_min == 0 {
                    "auto".into()
                } else {
                    steal_min.to_string()
                },
                fmt(rps),
                fmt(p99),
            ]);
        }
    }
    sweep.print();
    println!(
        "[bench] serve_throughput finished in {:.2}s",
        t0.elapsed().as_secs_f64()
    );
}
