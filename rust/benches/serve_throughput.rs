//! `cargo bench --bench serve_throughput` — sustained multi-stream serving
//! throughput (admission → micro-batcher → pipelines → shared pool) vs the
//! single-stream driver baseline, across batch policies, plus the
//! `[serving]` knob sweep (`drain_extra` × `steal_min_victim`).  The
//! shipped defaults (`drain_extra = 3`, `steal_min_victim = 0` = the
//! batch-derived threshold) are provisional until this sweep runs on the
//! target hardware.
//!
//! ```sh
//! cargo bench --bench serve_throughput -- [--quick] [--json out.json]
//! ```

use std::sync::Arc;
use std::time::{Duration, Instant};

use synergy::config::zoo;
use synergy::mm::job::JobClass;
use synergy::mm::operand::copied_bytes;
use synergy::nn::Network;
use synergy::rt::{self, RtOptions};
use synergy::serve::{RequestStream, ServeOptions, Server, ServerStats, SloTier};
use synergy::tensor::Tensor;
use synergy::util::argparse::Args;
use synergy::util::bench::{fmt, Table};
use synergy::util::json::{arr, num, obj, s, Json};

const STREAMS: usize = 4;
const RATE_RPS: f64 = 1000.0;

/// One serving run with optional `[serving]` knob overrides
/// (`None` = the shipped defaults from `ServeCfg`).
fn serve_run_knobs(
    nets: &[Arc<Network>],
    requests_per_stream: u64,
    max_batch: usize,
    drain_extra: Option<usize>,
    steal_min_victim: Option<usize>,
) -> ServerStats {
    let mut options = ServeOptions::default();
    options.batch.max_batch = max_batch;
    options.batch.window = Duration::from_micros(1500);
    options.admission_depth = 1024;
    if let Some(d) = drain_extra {
        options.hw.serving.drain_extra = d;
    }
    if let Some(st) = steal_min_victim {
        options.hw.serving.steal_min_victim = st;
    }
    let server = Arc::new(Server::start(nets.to_vec(), options).unwrap());
    let mut clients = Vec::new();
    for stream_id in 0..STREAMS {
        let net_id = stream_id % nets.len();
        let server = Arc::clone(&server);
        let mut stream = RequestStream::new(
            stream_id,
            net_id,
            Arc::clone(&nets[net_id]),
            RATE_RPS,
            requests_per_stream,
        );
        clients.push(std::thread::spawn(move || {
            while let Some((gap, req)) = stream.next_arrival() {
                std::thread::sleep(gap);
                server.submit(req);
            }
        }));
    }
    for c in clients {
        c.join().unwrap();
    }
    let server = match Arc::try_unwrap(server) {
        Ok(sv) => sv,
        Err(_) => panic!("server still shared"),
    };
    let (stats, responses) = server.shutdown().unwrap();
    assert_eq!(stats.completed as usize, responses.len());
    assert_eq!(stats.completed, STREAMS as u64 * requests_per_stream);
    stats
}

/// JSON row for one serving configuration: throughput, latency tail,
/// batching, per-class job rates, and fusion accounting.
fn config_json(label: &str, stats: &ServerStats) -> Json {
    let per_class = |class: JobClass| stats.per_class_jobs[class.index()] as f64;
    let rate = |jobs: f64| {
        if stats.wall_seconds > 0.0 {
            jobs / stats.wall_seconds
        } else {
            0.0
        }
    };
    // Per-SLO-tier latency tail + shed/expiry accounting (all-Standard
    // runs report zeros for the other tiers).
    let tiers = obj(
        SloTier::ALL
            .iter()
            .map(|t| {
                let i = t.index();
                (
                    t.label(),
                    obj(vec![
                        ("p50_ms", num(stats.tier_p50_ms[i])),
                        ("p99_ms", num(stats.tier_p99_ms[i])),
                        ("completed", num(stats.completed_by_tier[i] as f64)),
                        ("shed", num(stats.shed_by_tier[i] as f64)),
                        ("expired", num(stats.expired_by_tier[i] as f64)),
                    ]),
                )
            })
            .collect(),
    );
    obj(vec![
        ("configuration", s(label)),
        ("throughput_rps", num(stats.throughput_rps)),
        ("p50_ms", num(stats.p50_ms)),
        ("p99_ms", num(stats.p99_ms)),
        ("mean_batch", num(stats.mean_batch)),
        ("shed", num(stats.shed as f64)),
        ("tiers", tiers),
        ("jobs_executed", num(stats.jobs_executed as f64)),
        ("fused_fc_rows", num(stats.fused_fc_rows as f64)),
        (
            "job_rates_per_s",
            obj(vec![
                ("conv_tile", num(rate(per_class(JobClass::ConvTile)))),
                ("fc_gemm", num(rate(per_class(JobClass::FcGemm)))),
                ("im2col", num(rate(per_class(JobClass::Im2col)))),
                ("fc_gemm_batch", num(rate(per_class(JobClass::FcGemmBatch)))),
            ]),
        ),
    ])
}

fn main() -> anyhow::Result<()> {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    // `cargo bench` appends a bare `--bench` to harness=false binaries;
    // accept it as a valueless flag so it can't swallow the next arg.
    let args = Args::parse(&raw, &["quick", "bench"]).map_err(anyhow::Error::msg)?;
    let quick = args.has_flag("quick");
    let requests_per_stream: u64 = if quick { 4 } else { 16 };

    let t0 = Instant::now();
    let bytes_at_start = copied_bytes();
    let nets: Vec<Arc<Network>> = ["mpcnn", "mnist"]
        .iter()
        .map(|n| Arc::new(Network::new(zoo::load(n).unwrap(), 32).unwrap()))
        .collect();

    // Baseline: the single-stream driver at the same total frame count.
    let total = (STREAMS as u64 * requests_per_stream) / 2;
    let mut baseline_fps = 0.0;
    for net in &nets {
        let frames: Vec<(u64, Tensor)> =
            (0..total).map(|f| (f, net.make_input(f))).collect();
        let report =
            rt::driver::run_stream(Arc::clone(net), RtOptions::default(), frames).unwrap();
        baseline_fps += report.fps;
    }

    let mut table = Table::new(&[
        "configuration",
        "req/s",
        "p50 ms",
        "p99 ms",
        "mean batch",
    ]);
    table.row(vec![
        "driver 1-stream/net (sum)".into(),
        fmt(baseline_fps),
        "-".into(),
        "-".into(),
        "1.00".into(),
    ]);
    let mut configs: Vec<Json> = Vec::new();
    let batch_points: &[usize] = if quick { &[4] } else { &[1, 4, 8] };
    for &max_batch in batch_points {
        let stats = serve_run_knobs(&nets, requests_per_stream, max_batch, None, None);
        let label = format!("serve {STREAMS} streams, max_batch {max_batch}");
        table.row(vec![
            label.clone(),
            fmt(stats.throughput_rps),
            fmt(stats.p50_ms),
            fmt(stats.p99_ms),
            fmt(stats.mean_batch),
        ]);
        configs.push(config_json(&label, &stats));
    }
    table.print();

    // `[serving]` knob sweep: delegate drain depth × thief steal
    // threshold (0 = the batch-derived `StealPolicy::batched` default).
    // The shipped defaults (drain_extra = 3, steal_min_victim = 0) are
    // provisional; run this sweep on target hardware to pick real ones.
    let mut sweep = Table::new(&[
        "drain_extra",
        "steal_min_victim",
        "req/s",
        "p99 ms",
    ]);
    let drains: &[usize] = if quick { &[3] } else { &[0, 3, 7] };
    let steals: &[usize] = if quick { &[0] } else { &[0, 8] };
    for &drain in drains {
        for &steal_min in steals {
            let stats = serve_run_knobs(
                &nets,
                requests_per_stream,
                4,
                Some(drain),
                Some(steal_min),
            );
            sweep.row(vec![
                drain.to_string(),
                if steal_min == 0 {
                    "auto".into()
                } else {
                    steal_min.to_string()
                },
                fmt(stats.throughput_rps),
                fmt(stats.p99_ms),
            ]);
            let label = format!("sweep drain_extra={drain} steal_min={steal_min}");
            configs.push(config_json(&label, &stats));
        }
    }
    sweep.print();
    println!(
        "[bench] serve_throughput finished in {:.2}s",
        t0.elapsed().as_secs_f64()
    );

    if let Some(path) = args.get("json") {
        let doc = obj(vec![
            ("bench", s("serve_throughput")),
            ("schema_version", num(1.0)),
            ("quick", Json::Bool(quick)),
            ("provenance", s("measured")),
            ("streams", num(STREAMS as f64)),
            ("requests_per_stream", num(requests_per_stream as f64)),
            ("baseline_driver_fps", num(baseline_fps)),
            // Whole-process operand copy ledger across every run above:
            // how many bytes the operand plane actually materialized
            // (packs + wire only — views move zero bytes).
            (
                "bytes_copied_total",
                num((copied_bytes() - bytes_at_start) as f64),
            ),
            ("configurations", arr(configs)),
        ]);
        std::fs::write(path, doc.to_string() + "\n")?;
        println!("[bench] wrote {path}");
    }
    Ok(())
}
