//! `cargo bench --bench fig13_worksteal` — regenerates paper Fig 13 (work stealing vs SF/SC).
use std::time::Instant;

fn main() {
    let t0 = Instant::now();
    let report = synergy::experiments::fig13_worksteal::run(40);
    report.print();
    println!("[bench] fig13_worksteal regenerated in {:.2}s", t0.elapsed().as_secs_f64());
}
