//! `cargo bench --bench fig11_latency` — regenerates paper Fig 11 (non-pipelined latency ablation).
use std::time::Instant;

fn main() {
    let t0 = Instant::now();
    let report = synergy::experiments::fig11_latency::run(12);
    report.print();
    println!("[bench] fig11_latency regenerated in {:.2}s", t0.elapsed().as_secs_f64());
}
