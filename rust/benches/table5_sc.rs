//! `cargo bench --bench table5_sc` — regenerates paper Table 5 (SC cluster DSE).
use std::time::Instant;

fn main() {
    let t0 = Instant::now();
    let report = synergy::experiments::table5_sc::run(16);
    report.print();
    println!("[bench] table5_sc regenerated in {:.2}s", t0.elapsed().as_secs_f64());
}
