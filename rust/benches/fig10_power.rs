//! `cargo bench --bench fig10_power` — regenerates paper Fig 10 (power distribution + energy).
use std::time::Instant;

fn main() {
    let t0 = Instant::now();
    let report = synergy::experiments::fig10_power::run(60);
    report.print();
    println!("[bench] fig10_power regenerated in {:.2}s", t0.elapsed().as_secs_f64());
}
