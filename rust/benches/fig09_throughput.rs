//! `cargo bench --bench fig09_throughput` — regenerates paper Fig 9 (throughput vs Darknet baseline).
use std::time::Instant;

fn main() {
    let t0 = Instant::now();
    let report = synergy::experiments::fig09_throughput::run(60);
    report.print();
    println!("[bench] fig09_throughput regenerated in {:.2}s", t0.elapsed().as_secs_f64());
}
