//! `cargo bench --bench table6_util` — regenerates paper Table 6 (cluster utilization).
use std::time::Instant;

fn main() {
    let t0 = Instant::now();
    let report = synergy::experiments::table6_util::run(40);
    report.print();
    println!("[bench] table6_util regenerated in {:.2}s", t0.elapsed().as_secs_f64());
}
