//! `cargo bench --bench fig14_balance` — regenerates paper Fig 14 (CIFAR_Alex cluster balance).
use std::time::Instant;

fn main() {
    let t0 = Instant::now();
    let report = synergy::experiments::fig14_balance::run(60);
    report.print();
    println!("[bench] fig14_balance regenerated in {:.2}s", t0.elapsed().as_secs_f64());
}
