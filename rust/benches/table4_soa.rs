//! `cargo bench --bench table4_soa` — regenerates paper Table 4 (state-of-the-art comparison).
use std::time::Instant;

fn main() {
    let t0 = Instant::now();
    let report = synergy::experiments::table4_soa::run(60);
    report.print();
    println!("[bench] table4_soa regenerated in {:.2}s", t0.elapsed().as_secs_f64());
}
