//! `cargo bench --bench perf_hotpath` — L3 hot-path microbenchmarks
//! (the §Perf deliverable): GEMM micro-kernel, tile packing, job queue
//! throughput, steal latency, mailbox hop, the operand-plane before/after
//! (per-job re-extraction vs pack-once + zero-copy views), and end-to-end
//! native pipeline throughput.  Results feed EXPERIMENTS.md §Perf and,
//! via `--json`, the committed `BENCH_hotpath.json` artifact:
//!
//! ```sh
//! cargo bench --bench perf_hotpath -- [--quick] [--json out.json]
//! ```

use std::sync::Arc;

use synergy::accel::remote::{duplex_pair, serve_transport, wire, RemoteShard};
use synergy::accel::{Accelerator, BigNeonGemm, NativeGemm};
use synergy::cluster::JobQueue;
use synergy::config::zoo;
use synergy::mm::gemm::{gemm_blocked, gemm_naive};
use synergy::mm::job::{jobs_for_gemm, jobs_from_packs_q8, pack_fc_columns, Job};
use synergy::mm::operand::{copied_bytes, copy_events, OperandView};
use synergy::mm::tile::{job_mm_native, TileGrid};
use synergy::nn::im2col::im2col;
use synergy::nn::{quantize, quantize_scale, Network};
use synergy::pipeline::Mailbox;
use synergy::rt::{self, RtOptions};
use synergy::tensor::Tensor;
use synergy::util::argparse::Args;
use synergy::util::bench::{fmt, BenchResult, Bencher, Table};
use synergy::util::json::{arr, num, obj, s, Json};
use synergy::util::rng::XorShift64Star;

fn main() -> anyhow::Result<()> {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    // `cargo bench` appends a bare `--bench` to harness=false binaries;
    // accept it as a valueless flag so it can't swallow the next arg.
    let args = Args::parse(&raw, &["quick", "bench"]).map_err(anyhow::Error::msg)?;
    let quick = args.has_flag("quick");
    let b = if quick {
        Bencher::quick()
    } else {
        Bencher::default()
    };
    let mut table = Table::new(&["benchmark", "mean µs", "throughput"]);
    let mut results: Vec<BenchResult> = Vec::new();

    // GEMM micro-kernels on a conv2-shaped problem (64x800x196).
    let a = Tensor::from_vec(&[64, 800], XorShift64Star::new(1).fill_f32(64 * 800, 1.0));
    let bm = Tensor::from_vec(&[800, 196], XorShift64Star::new(2).fill_f32(800 * 196, 1.0));
    let flops = 2.0 * 64.0 * 800.0 * 196.0;
    let r = b.run("gemm_naive 64x800x196", || {
        std::hint::black_box(gemm_naive(&a, &bm));
    });
    table.row(vec![r.name.clone(), fmt(r.mean_us()), format!("{:.2} GFLOP/s", flops / r.mean_ns)]);
    results.push(r);
    let r = b.run("gemm_blocked 64x800x196", || {
        std::hint::black_box(gemm_blocked(&a, &bm));
    });
    table.row(vec![r.name.clone(), fmt(r.mean_us()), format!("{:.2} GFLOP/s", flops / r.mean_ns)]);
    results.push(r);

    // Job kernel (K=25) — the NEON-path inner loop.
    let grid = TileGrid::new(64, 800, 196, 32);
    let at = grid.extract_a_tiles(a.data(), 0);
    let bt = grid.extract_b_tiles(bm.data(), 0);
    let jflops = 2.0 * 32.0 * 32.0 * 32.0 * grid.k_tiles() as f64;
    let r = b.run("job_mm_native k=25", || {
        std::hint::black_box(job_mm_native(&at, &bt, grid.k_tiles(), 32));
    });
    table.row(vec![r.name.clone(), fmt(r.mean_us()), format!("{:.2} GFLOP/s", jflops / r.mean_ns)]);
    results.push(r);

    // Tile packing (the PE fetch path).
    let r = b.run("extract_a_tiles k=25", || {
        std::hint::black_box(grid.extract_a_tiles(a.data(), 0));
    });
    table.row(vec![r.name.clone(), fmt(r.mean_us()), format!("{:.1} MB/s", (at.len() * 4) as f64 / 1e6 / (r.mean_ns / 1e9))]);
    results.push(r);

    // Operand plane, before vs after the zero-copy redesign on the same
    // conv2-shaped GEMM: the legacy hot path re-extracted both operand
    // panels per (t1,t2) job; `jobs_for_gemm` now packs each operand ONCE
    // and hands every job `OperandView` slices of the pack.  The copy
    // counters are process-wide and deterministic, so snapshot them
    // around one un-timed pass of each path before timing the same work.
    let arc_a = Arc::new(a.data().to_vec());
    let arc_b = Arc::new(bm.data().to_vec());
    let (bytes0, events0) = (copied_bytes(), copy_events());
    for t1 in 0..grid.rows() {
        for t2 in 0..grid.cols() {
            std::hint::black_box(grid.extract_a_tiles(a.data(), t1));
            std::hint::black_box(grid.extract_b_tiles(bm.data(), t2));
        }
    }
    let (bytes1, events1) = (copied_bytes(), copy_events());
    let mut id = 0u64;
    std::hint::black_box(jobs_for_gemm(
        0,
        0,
        grid,
        Arc::clone(&arc_a),
        Arc::clone(&arc_b),
        &mut id,
    ));
    let (bytes2, events2) = (copied_bytes(), copy_events());
    let legacy_bytes = bytes1 - bytes0;
    let legacy_events = events1 - events0;
    let view_bytes = bytes2 - bytes1;
    let view_events = events2 - events1;

    let legacy = b.run(
        &format!("operand legacy: extract per job x{}", grid.num_jobs()),
        || {
            for t1 in 0..grid.rows() {
                for t2 in 0..grid.cols() {
                    std::hint::black_box(grid.extract_a_tiles(a.data(), t1));
                    std::hint::black_box(grid.extract_b_tiles(bm.data(), t2));
                }
            }
        },
    );
    table.row(vec![
        legacy.name.clone(),
        fmt(legacy.mean_us()),
        format!("{} B copied / GEMM", legacy_bytes),
    ]);
    let packed = b.run(
        &format!("operand views: pack once + slice x{}", grid.num_jobs()),
        || {
            let mut id = 0u64;
            std::hint::black_box(jobs_for_gemm(
                0,
                0,
                grid,
                Arc::clone(&arc_a),
                Arc::clone(&arc_b),
                &mut id,
            ));
        },
    );
    table.row(vec![
        packed.name.clone(),
        fmt(packed.mean_us()),
        format!(
            "{} B copied / GEMM ({:.2}x fewer)",
            view_bytes,
            legacy_bytes as f64 / view_bytes as f64
        ),
    ]);
    results.push(legacy.clone());
    results.push(packed.clone());

    // Shard wire plane: the operand-cache protocol on the same conv2
    // GEMM, read off the client's exact `wire_bytes()` ledger (sent +
    // received frame bytes).  Three deterministic passes: the per-tile
    // full-fetch-set baseline, the cold cached round (both packs PUT
    // once + descriptor frames), and the warm steady-state round a
    // serving pool lives in (137-byte descriptors + results, nothing
    // else on the wire).
    let mut id = 0u64;
    let wire_jobs = jobs_for_gemm(0, 0, grid, Arc::clone(&arc_a), Arc::clone(&arc_b), &mut id);
    let ship_rounds = |cache: bool, rounds: usize| -> u64 {
        let (client, mut server) = duplex_pair();
        let shard_thread = std::thread::spawn(move || {
            serve_transport(&mut server, |job| Ok(job.execute_native())).unwrap()
        });
        let mut shard = RemoteShard::over_duplex("remote:bench", client).with_operand_cache(cache);
        for _ in 0..rounds {
            for job in &wire_jobs {
                std::hint::black_box(shard.execute(job).unwrap());
            }
        }
        let bytes = shard.wire_bytes();
        drop(shard);
        shard_thread.join().unwrap();
        bytes
    };
    let base_wire = ship_rounds(false, 1);
    let cold_wire = ship_rounds(true, 1);
    let warm_wire = ship_rounds(true, 2) - cold_wire;
    table.row(vec![
        String::from("shard wire: full fetch set / tile"),
        String::from("-"),
        format!("{base_wire} B / GEMM"),
    ]);
    table.row(vec![
        String::from("shard wire: cold (PUT packs + refs)"),
        String::from("-"),
        format!("{cold_wire} B / GEMM"),
    ]);
    table.row(vec![
        String::from("shard wire: warm (refs + results)"),
        String::from("-"),
        format!(
            "{warm_wire} B / GEMM ({:.2}x fewer)",
            base_wire as f64 / warm_wire as f64
        ),
    ]);

    // Int8 shard wire plane: the SAME conv2 GEMM quantized per-layer
    // symmetric (one scale per operand pack) and shipped as i8 code
    // planes — one byte per element on the wire, so the operand PUTs
    // shrink ~4x against the f32 PUT rows above while the warm round
    // stays descriptor-sized (Q8 refs carry the scale, +4 B per frame).
    let a_scale = quantize_scale(a.data());
    let b_scale = quantize_scale(bm.data());
    let a_codes = quantize(&grid.pack_a_tiles(a.data()), a_scale);
    let b_codes = quantize(&grid.pack_b_tiles(bm.data()), b_scale);
    let mut id = 0u64;
    let wire_jobs_q8 = jobs_from_packs_q8(
        0,
        0,
        grid,
        OperandView::from(a_codes),
        OperandView::from(b_codes),
        a_scale * b_scale,
        &mut id,
    );
    let ship_rounds_q8 = |cache: bool, rounds: usize| -> u64 {
        let (client, mut server) = duplex_pair();
        let shard_thread = std::thread::spawn(move || {
            serve_transport(&mut server, |job| Ok(job.execute_native())).unwrap()
        });
        let mut shard =
            RemoteShard::over_duplex("remote:bench-q8", client).with_operand_cache(cache);
        for _ in 0..rounds {
            for job in &wire_jobs_q8 {
                std::hint::black_box(shard.execute(job).unwrap());
            }
        }
        let bytes = shard.wire_bytes();
        drop(shard);
        shard_thread.join().unwrap();
        bytes
    };
    let base_wire_q8 = ship_rounds_q8(false, 1);
    let cold_wire_q8 = ship_rounds_q8(true, 1);
    let warm_wire_q8 = ship_rounds_q8(true, 2) - cold_wire_q8;
    let put_q8 = cold_wire_q8 - warm_wire_q8;
    let put_f32 = cold_wire - warm_wire;
    table.row(vec![
        String::from("shard wire q8: inline i8 frames / tile"),
        String::from("-"),
        format!("{base_wire_q8} B / GEMM"),
    ]);
    table.row(vec![
        String::from("shard wire q8: cold (PUT i8 packs + refs)"),
        String::from("-"),
        format!("{cold_wire_q8} B / GEMM"),
    ]);
    table.row(vec![
        String::from("shard wire q8: warm (refs + results)"),
        String::from("-"),
        format!("{warm_wire_q8} B / GEMM"),
    ]);
    table.row(vec![
        String::from("shard wire q8: operand PUT bytes"),
        String::from("-"),
        format!(
            "{put_q8} B vs {put_f32} B f32 ({:.2}x fewer)",
            put_f32 as f64 / put_q8 as f64
        ),
    ]);

    // im2col (CPU preprocessing).
    let x = Tensor::from_vec(&[32, 14, 14], XorShift64Star::new(3).fill_f32(32 * 14 * 14, 1.0));
    let r = b.run("im2col 32x14x14 k5 p2", || {
        std::hint::black_box(im2col(&x, 5, 1, 2));
    });
    table.row(vec![r.name.clone(), fmt(r.mean_us()), format!("{:.1} Melem/s", (32.0 * 25.0 * 196.0) / 1e6 / (r.mean_ns / 1e9))]);
    results.push(r);

    // Job queue push/pop throughput.
    let r = b.run("jobqueue push+pop x1000", || {
        let q: JobQueue<u64> = JobQueue::new();
        for i in 0..1000u64 {
            q.push(i);
        }
        for _ in 0..1000 {
            std::hint::black_box(q.try_pop());
        }
    });
    table.row(vec![r.name.clone(), fmt(r.mean_us()), format!("{:.1} Mops/s", 2000.0 / 1e6 / (r.mean_ns / 1e9))]);
    results.push(r);

    // Steal batch.
    let r = b.run("jobqueue steal 500 of 1000", || {
        let q: JobQueue<u64> = JobQueue::new();
        for i in 0..1000u64 {
            q.push(i);
        }
        std::hint::black_box(q.steal(500));
    });
    table.row(vec![r.name.clone(), fmt(r.mean_us()), String::from("-")]);
    results.push(r);

    // Mailbox hop (send+recv).
    let mb: Mailbox<u64> = Mailbox::new(4);
    let r = b.run("mailbox send+recv", || {
        mb.send(1);
        std::hint::black_box(mb.recv());
    });
    table.row(vec![r.name.clone(), fmt(r.mean_us()), format!("{:.2} Mhops/s", 1.0 / 1e6 / (r.mean_ns / 1e9))]);
    results.push(r);

    // Fused-vs-per-sample FC sweep (the batch-level FC fusion claim):
    // one (OUT,IN)×(IN,B) FcGemmBatch job vs B single-column FC jobs, on
    // the plain NEON backend and on the persistent big-NEON team.  The
    // "throughput" column reports the fused path's speedup over the
    // per-sample path at each B.
    let (out_n, in_n) = (128, 3136); // mnist fc1 geometry
    let w = Arc::new(XorShift64Star::new(40).fill_f32(out_n * in_n, 1.0));
    let xs: Vec<Vec<f32>> = (0..16)
        .map(|j| XorShift64Star::new(50 + j).fill_f32(in_n, 1.0))
        .collect();
    let mut backends: Vec<(&str, Box<dyn Accelerator>)> = vec![
        ("neon", Box::new(NativeGemm)),
        ("big-neon x4", Box::new(BigNeonGemm::new(4))),
    ];
    let batch_sizes: &[usize] = if quick { &[1, 8] } else { &[1, 2, 4, 8, 16] };
    for (label, backend) in &mut backends {
        for &bsz in batch_sizes {
            let cols: Vec<&[f32]> = xs[..bsz].iter().map(|x| x.as_slice()).collect();
            let fused_job = Job::fc_batch(
                0,
                0,
                0,
                out_n,
                in_n,
                bsz,
                Arc::clone(&w),
                Arc::new(pack_fc_columns(&cols)),
                32,
            );
            let single_jobs: Vec<Job> = (0..bsz)
                .map(|j| {
                    Job::fc(
                        j as u64,
                        0,
                        0,
                        out_n,
                        in_n,
                        Arc::clone(&w),
                        Arc::new(xs[j].clone()),
                        32,
                    )
                })
                .collect();
            let per_sample = b.run(&format!("fc per-sample B={bsz} ({label})"), || {
                for job in &single_jobs {
                    std::hint::black_box(backend.execute(job).unwrap());
                }
            });
            let fused = b.run(&format!("fc fused B={bsz} ({label})"), || {
                std::hint::black_box(backend.execute(&fused_job).unwrap());
            });
            table.row(vec![
                per_sample.name.clone(),
                fmt(per_sample.mean_us()),
                String::from("-"),
            ]);
            table.row(vec![
                fused.name.clone(),
                fmt(fused.mean_us()),
                format!("{:.2}x vs per-sample", per_sample.mean_ns / fused.mean_ns),
            ]);
            results.push(per_sample);
            results.push(fused);
        }
    }
    drop(backends); // join the big-NEON team before the pipeline run

    // End-to-end native pipeline throughput (host wall clock, mpcnn).
    let frames_n: u64 = if quick { 6 } else { 24 };
    let net = Arc::new(Network::new(zoo::load("mpcnn").unwrap(), 32).unwrap());
    let frames: Vec<(u64, Tensor)> = (0..frames_n).map(|f| (f, net.make_input(f))).collect();
    let t0 = std::time::Instant::now();
    let report = rt::driver::run_stream(Arc::clone(&net), RtOptions::default(), frames).unwrap();
    let wall = t0.elapsed().as_secs_f64();
    table.row(vec![
        format!("rt pipeline mpcnn x{frames_n} (native)"),
        fmt(wall * 1e6 / frames_n as f64),
        format!("{:.1} frames/s host", report.fps),
    ]);

    table.print();

    if let Some(path) = args.get("json") {
        let case = |r: &BenchResult| {
            obj(vec![
                ("name", s(&r.name)),
                ("mean_us", num(r.mean_us())),
                ("median_us", num(r.median_ns / 1e3)),
                ("iters", num(r.iters as f64)),
            ])
        };
        let doc = obj(vec![
            ("bench", s("perf_hotpath")),
            ("schema_version", num(1.0)),
            ("quick", Json::Bool(quick)),
            ("provenance", s("measured")),
            (
                "operand_plane",
                obj(vec![
                    (
                        "grid",
                        obj(vec![
                            ("m", num(grid.m as f64)),
                            ("n", num(grid.n as f64)),
                            ("p", num(grid.p as f64)),
                            ("ts", num(grid.ts as f64)),
                            ("num_jobs", num(grid.num_jobs() as f64)),
                        ]),
                    ),
                    (
                        "before",
                        obj(vec![
                            ("path", s("per-job extract_a_tiles + extract_b_tiles")),
                            ("bytes_copied", num(legacy_bytes as f64)),
                            ("copy_events", num(legacy_events as f64)),
                            ("mean_us", num(legacy.mean_us())),
                        ]),
                    ),
                    (
                        "after",
                        obj(vec![
                            ("path", s("pack once per operand + OperandView slices")),
                            ("bytes_copied", num(view_bytes as f64)),
                            ("copy_events", num(view_events as f64)),
                            ("mean_us", num(packed.mean_us())),
                        ]),
                    ),
                    (
                        "bytes_ratio",
                        num(legacy_bytes as f64 / view_bytes as f64),
                    ),
                ]),
            ),
            (
                "shard_wire",
                obj(vec![
                    (
                        "grid",
                        obj(vec![
                            ("m", num(grid.m as f64)),
                            ("n", num(grid.n as f64)),
                            ("p", num(grid.p as f64)),
                            ("ts", num(grid.ts as f64)),
                            ("num_jobs", num(grid.num_jobs() as f64)),
                        ]),
                    ),
                    (
                        "baseline",
                        obj(vec![
                            ("path", s("full packed fetch set in every tile frame")),
                            ("wire_bytes", num(base_wire as f64)),
                        ]),
                    ),
                    (
                        "cold",
                        obj(vec![
                            ("path", s("PUT both packs once + descriptor frames")),
                            ("wire_bytes", num(cold_wire as f64)),
                        ]),
                    ),
                    (
                        "warm",
                        obj(vec![
                            ("path", s("descriptor-only frames + results")),
                            ("wire_bytes", num(warm_wire as f64)),
                            ("ref_frame_bytes", num(wire::REF_FRAME_BYTES as f64)),
                        ]),
                    ),
                    ("bytes_ratio", num(base_wire as f64 / warm_wire as f64)),
                ]),
            ),
            (
                "shard_wire_q8",
                obj(vec![
                    (
                        "grid",
                        obj(vec![
                            ("m", num(grid.m as f64)),
                            ("n", num(grid.n as f64)),
                            ("p", num(grid.p as f64)),
                            ("ts", num(grid.ts as f64)),
                            ("num_jobs", num(grid.num_jobs() as f64)),
                        ]),
                    ),
                    (
                        "baseline",
                        obj(vec![
                            ("path", s("inline i8 code planes in every tile frame")),
                            ("wire_bytes", num(base_wire_q8 as f64)),
                        ]),
                    ),
                    (
                        "cold",
                        obj(vec![
                            ("path", s("PUT both i8 packs once + Q8 descriptor frames")),
                            ("wire_bytes", num(cold_wire_q8 as f64)),
                        ]),
                    ),
                    (
                        "warm",
                        obj(vec![
                            ("path", s("Q8 descriptor-only frames + results")),
                            ("wire_bytes", num(warm_wire_q8 as f64)),
                            ("ref_frame_bytes", num(wire::Q8_REF_FRAME_BYTES as f64)),
                        ]),
                    ),
                    ("operand_put_bytes", num(put_q8 as f64)),
                    ("f32_operand_put_bytes", num(put_f32 as f64)),
                    (
                        "operand_bytes_ratio",
                        num(put_f32 as f64 / put_q8 as f64),
                    ),
                ]),
            ),
            (
                "pipeline",
                obj(vec![
                    ("model", s("mpcnn")),
                    ("frames", num(frames_n as f64)),
                    ("fps_host", num(report.fps)),
                ]),
            ),
            ("cases", arr(results.iter().map(case).collect())),
        ]);
        std::fs::write(path, doc.to_string() + "\n")?;
        println!("[bench] wrote {path}");
    }
    Ok(())
}
