//! `cargo bench --bench fig12_pipeline` — regenerates paper Fig 12 (pipelined throughput ablation).
use std::time::Instant;

fn main() {
    let t0 = Instant::now();
    let report = synergy::experiments::fig12_pipeline::run(60);
    report.print();
    println!("[bench] fig12_pipeline regenerated in {:.2}s", t0.elapsed().as_secs_f64());
}
