//! `cargo bench --bench table3_energy` — regenerates paper Table 3 (energy + GOPS/W).
use std::time::Instant;

fn main() {
    let t0 = Instant::now();
    let report = synergy::experiments::table3_energy::run(60);
    report.print();
    println!("[bench] table3_energy regenerated in {:.2}s", t0.elapsed().as_secs_f64());
}
