//! Minimal dense f32 tensor — the data currency between layers.
//!
//! The coordinator only ever needs row-major f32 with up-to-4-D shapes
//! (feature maps are (C,H,W), GEMM operands are (rows, cols)), so this stays
//! deliberately small instead of growing a full ndarray.

/// Dense row-major f32 tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Self {
        let n = shape.iter().product();
        Self {
            shape: shape.to_vec(),
            data: vec![0.0; n],
        }
    }

    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Self {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape {:?} != data len {}",
            shape,
            data.len()
        );
        Self {
            shape: shape.to_vec(),
            data,
        }
    }

    pub fn scalar(v: f32) -> Self {
        Self::from_vec(&[1], vec![v])
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    /// Reinterpret with a new shape of the same element count.
    pub fn reshaped(mut self, shape: &[usize]) -> Self {
        assert_eq!(shape.iter().product::<usize>(), self.data.len());
        self.shape = shape.to_vec();
        self
    }

    /// 2-D element access (row-major).
    #[inline]
    pub fn at2(&self, r: usize, c: usize) -> f32 {
        debug_assert_eq!(self.shape.len(), 2);
        self.data[r * self.shape[1] + c]
    }

    #[inline]
    pub fn set2(&mut self, r: usize, c: usize, v: f32) {
        debug_assert_eq!(self.shape.len(), 2);
        self.data[r * self.shape[1] + c] = v;
    }

    /// 3-D element access for (C,H,W) feature maps.
    #[inline]
    pub fn at3(&self, c: usize, y: usize, x: usize) -> f32 {
        debug_assert_eq!(self.shape.len(), 3);
        let (h, w) = (self.shape[1], self.shape[2]);
        self.data[(c * h + y) * w + x]
    }

    #[inline]
    pub fn set3(&mut self, c: usize, y: usize, x: usize, v: f32) {
        debug_assert_eq!(self.shape.len(), 3);
        let (h, w) = (self.shape[1], self.shape[2]);
        self.data[(c * h + y) * w + x] = v;
    }

    /// Max |a-b| across elements (for allclose-style assertions).
    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape);
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    /// Relative allclose check mirroring numpy's semantics.
    pub fn allclose(&self, other: &Tensor, rtol: f32, atol: f32) -> bool {
        if self.shape != other.shape {
            return false;
        }
        self.data
            .iter()
            .zip(&other.data)
            .all(|(a, b)| (a - b).abs() <= atol + rtol * b.abs())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construct_and_index() {
        let mut t = Tensor::zeros(&[2, 3]);
        t.set2(1, 2, 5.0);
        assert_eq!(t.at2(1, 2), 5.0);
        assert_eq!(t.at2(0, 0), 0.0);
        assert_eq!(t.len(), 6);
    }

    #[test]
    fn chw_indexing() {
        let mut t = Tensor::zeros(&[2, 3, 4]);
        t.set3(1, 2, 3, 7.0);
        assert_eq!(t.at3(1, 2, 3), 7.0);
        // row-major: offset = ((1*3)+2)*4+3 = 23
        assert_eq!(t.data()[23], 7.0);
    }

    #[test]
    fn reshape_preserves_data() {
        let t = Tensor::from_vec(&[2, 3], (0..6).map(|i| i as f32).collect());
        let r = t.clone().reshaped(&[3, 2]);
        assert_eq!(r.shape(), &[3, 2]);
        assert_eq!(r.data(), t.data());
    }

    #[test]
    #[should_panic]
    fn from_vec_shape_mismatch_panics() {
        Tensor::from_vec(&[2, 2], vec![1.0]);
    }

    #[test]
    fn allclose_tolerances() {
        let a = Tensor::from_vec(&[2], vec![1.0, 100.0]);
        let b = Tensor::from_vec(&[2], vec![1.0001, 100.001]);
        assert!(a.allclose(&b, 1e-3, 1e-3));
        assert!(!a.allclose(&b, 1e-7, 1e-7));
        let c = Tensor::zeros(&[3]);
        assert!(!a.allclose(&c, 1.0, 1.0)); // shape mismatch
    }

    #[test]
    fn max_abs_diff() {
        let a = Tensor::from_vec(&[3], vec![1.0, 2.0, 3.0]);
        let b = Tensor::from_vec(&[3], vec![1.0, 2.5, 2.0]);
        assert_eq!(a.max_abs_diff(&b), 1.0);
    }
}
