//! Remote-shard accelerator backend — the registry's first out-of-tree
//! plug-in, and the first piece of multi-machine sharding.
//!
//! A [`RemoteShard`] implements [`Accelerator`] by *shipping* each job to a
//! peer over a [`ShardTransport`] and blocking for the framed result, so a
//! second machine's accelerator pool joins the local pool as one more
//! cluster member (NEURAghe generalizes the paper's CPU–FPGA split across
//! Zynq variants via a stable accelerator interface; co-scheduling across
//! physically separate compute domains is the mobile-SoC study's
//! throughput lever — a LAN shard is the rust_pallas analogue of both).
//!
//! Everything here goes through the **public registry API**: nothing in
//! `rt/` knows this backend exists.  `[cluster] remote = "host:port"` in a
//! hardware config spawns a member whose registry key is
//! [`shard_backend_name`]; callers register that key (usually via
//! [`register_config_shards`]) before starting the pool, exactly like any
//! other custom backend.
//!
//! Two transports ship in-tree:
//! * [`ChannelTransport`] — in-process duplex mpsc channels
//!   ([`duplex_pair`]), the deterministic test harness;
//! * [`TcpTransport`] — length-prefixed frames over a TCP stream, the real
//!   thing ([`crate::serve::ShardServer`] hosts the far end: a second
//!   `DelegatePool` executing shipped jobs).
//!
//! ## Capability and cost
//!
//! The remote mask is deliberately narrow ([`remote_class_mask`]:
//! CONV-tile + fused batched FC, in both f32 and int8 flavors): a round
//! trip costs hundreds of microseconds, so only job classes that carry
//! whole-tile or whole-batch work amortize it — single-column FC GEMMs and im2col stay local by
//! *capability*, and the dispatcher/thief keep small backlogs local by
//! *cost* ([`REMOTE_OVERHEAD_KSTEPS`] feeds the routing penalty and the
//! thief's ship gate through the registry's `overhead_ksteps` metadata;
//! [`RemoteShard`]'s `Accelerator::cost` reports the same number).
//!
//! ## Shard-side operand cache
//!
//! A CONV tile's packed fetch set is pure layer state: the A panel comes
//! from the network's load-time weight prepack, the B panel from the
//! frame's packed activation — and every tile of a layer aliases windows
//! of those same two allocations (the zero-copy operand plane made the
//! identities stable).  Shipping them per tile re-sends each panel
//! K-tile-reuse-factor times, so the wire protocol is content-addressed:
//! the client PUTs each backing buffer **once** per
//! [`crate::mm::operand_key`] (≙ (network, layer, pack-generation) — a
//! repack mints a new key), then ships 137-byte descriptor-only
//! [`wire::REF_FRAME_BYTES`] frames referencing `(key, offset, len)`
//! windows of the cached buffers.  The shard holds a bounded LRU
//! ([`ShardCache`], shared across every client connection); eviction is
//! recoverable in-band (a `CACHE_MISS` reply makes the client re-PUT and
//! retry — results stay bit-identical), and a pack-generation bump is an
//! explicit `OPERAND_DROP` invalidation frame followed by exactly one
//! re-ship of the new buffer (NEURAghe's weights-resident-on-the-
//! accelerator discipline, arXiv:1712.00994).  Quantized CONV tiles ride
//! the same protocol with i8 code planes — one byte per element on the
//! PUT (4× fewer operand wire bytes) and a fixed
//! [`wire::Q8_REF_FRAME_BYTES`]-byte descriptor frame per tile.
//!
//! ## Failure
//!
//! A dropped transport makes `execute` return an error; the delegate then
//! **requeues** the failed job and the rest of its drained run onto the
//! cluster bank and dies (`rt::delegate`), so surviving members drain the
//! work — zero jobs lost, proven by `tests/remote_shard.rs` and the
//! `failure_injection` harness.  (Jobs of a class NO survivor covers are
//! dropped instead, failing blocking callers fast — see the delegate's
//! rescue mask.)  Requeue is safe because jobs are pure: in the worst
//! case a job whose result frame was lost in flight computes twice, and
//! exactly one result reaches the reply channel.  The pool additionally
//! **evicts** the dead member from routing (`LinkCost::evict`) so no
//! further work is placed toward it — and the client's shipped-key state
//! dies with the delegate's `RemoteShard`, so a reconnect re-ships from a
//! clean slate.

use std::collections::{HashMap, HashSet};
use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc};

use anyhow::{anyhow, bail, ensure, Context, Result};

use crate::accel::backend::{Accelerator, BackendRegistry, BackendSpec};
use crate::config::HwConfig;
use crate::mm::job::{ClassMask, Job, JobClass, JobDesc, JobKind, JobResult};
use crate::mm::operand::{operand_key, OperandKey, OperandView, Plane};
use crate::mm::TileGrid;
use crate::util::sync::{lock_clean, Mutex};

/// Job classes a remote shard advertises: only the classes whose per-job
/// work amortizes a transport round trip (see the module docs).  The int8
/// twins of the two amortizing classes are included — a quantized CONV
/// tile ships i8 code panels (4× fewer operand bytes than f32) and a
/// fused q8 FC batch carries whole-batch work; the single-column
/// [`JobClass::FcGemmQ8`] stays local by capability exactly like its f32
/// sibling.
pub fn remote_class_mask() -> ClassMask {
    ClassMask::of(&[
        JobClass::ConvTile,
        JobClass::FcGemmBatch,
        JobClass::ConvTileQ8,
        JobClass::FcGemmBatchQ8,
    ])
}

/// Fixed per-job shipping overhead in k-step equivalents — serialization
/// plus two one-way LAN latencies.  20 k-steps of the modelled remote rate
/// (`PerfModel::remote`, ts = 32 at 667 MHz) is ≈ 0.5 ms, matching that
/// model's `job_overhead_seconds`.  Registered as the backend's
/// `overhead_ksteps` metadata, which the dispatcher's routing penalty and
/// the thief's ship gate consume; `RemoteShard::cost` reports the same
/// number per job.
pub const REMOTE_OVERHEAD_KSTEPS: f64 = 20.0;

/// Fraction of the cold per-job shipping overhead a *warm* CONV tile still
/// pays once the shard's operand cache holds the layer's fetch set: the
/// descriptor-only frame ([`wire::REF_FRAME_BYTES`] = 137 B vs ~200 KiB of
/// packed panels at ts = 32) leaves the two one-way latencies and the
/// handshake, but no panel serialization.  Consumed by the virtual-clock
/// simulator's remote service model.
pub const REMOTE_CACHED_OVERHEAD_FRACTION: f64 = 0.2;

/// Registry key of the shard backend dialing `addr` — the name
/// `rt::pool::backend_key` resolves for an `AccelClass::Remote` member.
pub fn shard_backend_name(addr: &str) -> String {
    format!("remote:{addr}")
}

// ------------------------------------------------------------- transport

/// One frame in, one frame out: the byte pipe a [`RemoteShard`] ships jobs
/// over.  Implementations own their framing (the TCP impl length-prefixes;
/// the channel impl sends whole frames as messages).  Errors mean the peer
/// is gone — the caller treats the shard as dead, never retries.
pub trait ShardTransport: Send {
    /// Ship one frame.  Errors when the peer has gone away.
    fn send(&mut self, frame: &[u8]) -> Result<()>;

    /// Block for the next frame.  Errors when the peer has gone away.
    fn recv(&mut self) -> Result<Vec<u8>>;
}

/// In-process duplex transport over mpsc channels: deterministic tests
/// exercise the full ship → decode → execute → encode → reply path with no
/// sockets.  Dropping either end kills the link (the other side's
/// `send`/`recv` starts failing), which is exactly how the failure tests
/// sever a shard mid-batch.
pub struct ChannelTransport {
    tx: mpsc::Sender<Vec<u8>>,
    rx: mpsc::Receiver<Vec<u8>>,
}

/// Build a connected pair of in-process transports.
pub fn duplex_pair() -> (ChannelTransport, ChannelTransport) {
    let (tx_ab, rx_ab) = mpsc::channel();
    let (tx_ba, rx_ba) = mpsc::channel();
    (
        ChannelTransport {
            tx: tx_ab,
            rx: rx_ba,
        },
        ChannelTransport {
            tx: tx_ba,
            rx: rx_ab,
        },
    )
}

impl ShardTransport for ChannelTransport {
    fn send(&mut self, frame: &[u8]) -> Result<()> {
        self.tx
            .send(frame.to_vec())
            .map_err(|_| anyhow!("shard transport closed (peer dropped)"))
    }

    fn recv(&mut self) -> Result<Vec<u8>> {
        self.rx
            .recv()
            .map_err(|_| anyhow!("shard transport closed (peer dropped)"))
    }
}

/// Upper bound on one frame (operands of the largest zoo FC layer fit with
/// two orders of magnitude to spare); a peer announcing more is broken or
/// hostile, not busy.
const MAX_FRAME_BYTES: usize = 1 << 30;

/// Length-prefixed framing over a TCP stream: each frame is a little-endian
/// `u32` byte count followed by the payload.
pub struct TcpTransport {
    stream: TcpStream,
}

impl TcpTransport {
    /// Dial a shard server (used inside the delegate thread by the builder
    /// [`register_tcp_shard`] installs — one connection per delegate).
    pub fn connect(addr: &str) -> Result<TcpTransport> {
        let stream = TcpStream::connect(addr)
            .with_context(|| format!("dialing remote shard at {addr}"))?;
        // Job/result frames are the unit of progress; coalescing them
        // behind Nagle only adds round-trip latency.
        let _ = stream.set_nodelay(true);
        Ok(TcpTransport { stream })
    }

    /// Wrap an accepted connection (the server side).
    pub fn from_stream(stream: TcpStream) -> TcpTransport {
        let _ = stream.set_nodelay(true);
        TcpTransport { stream }
    }
}

impl ShardTransport for TcpTransport {
    fn send(&mut self, frame: &[u8]) -> Result<()> {
        let len = u32::try_from(frame.len()).context("shard frame exceeds u32 length")?;
        self.stream
            .write_all(&len.to_le_bytes())
            .context("writing shard frame length")?;
        self.stream
            .write_all(frame)
            .context("writing shard frame")?;
        Ok(())
    }

    fn recv(&mut self) -> Result<Vec<u8>> {
        let mut len_bytes = [0u8; 4];
        self.stream
            .read_exact(&mut len_bytes)
            .context("reading shard frame length")?;
        let len = u32::from_le_bytes(len_bytes) as usize;
        ensure!(len <= MAX_FRAME_BYTES, "oversized shard frame ({len} bytes)");
        let mut frame = vec![0u8; len];
        self.stream
            .read_exact(&mut frame)
            .context("reading shard frame")?;
        Ok(frame)
    }
}

// ------------------------------------------------------------------ wire

/// The job/result byte format shipped over a [`ShardTransport`].
///
/// Hand-rolled little-endian encoding (no serialization crate in the
/// offline registry): a one-byte kind tag, the [`JobDesc`] as nine `u64`s
/// (job/layer/frame ids, tile coordinates, grid geometry), then the
/// operand buffers as length-prefixed `f32` runs.  Decoding rebuilds the
/// exact [`Job`] value, so `execute_native` on the far end is bit-identical
/// to local execution — the property `tests/remote_shard.rs` pins across
/// the model zoo.
pub mod wire {
    use super::*;

    const KIND_CONV_TILE: u8 = 0;
    const KIND_FC_GEMM: u8 = 1;
    const KIND_IM2COL: u8 = 2;
    const KIND_FC_GEMM_BATCH: u8 = 3;
    /// Cache-protocol frames (fire-and-forget except the REF): PUT ships
    /// one whole backing buffer under its operand key, DROP invalidates a
    /// key (pack-generation bump), REF is the descriptor-only CONV-tile
    /// job frame, PROBE is the health/RTT ping.  PUT and DROP carry no
    /// reply — the transport is ordered, so the shard has processed them
    /// before the REF that relies on them arrives.
    const KIND_OPERAND_PUT: u8 = 4;
    const KIND_OPERAND_DROP: u8 = 5;
    const KIND_CONV_TILE_REF: u8 = 6;
    const KIND_PROBE: u8 = 7;
    /// Int8 twins of the operand-cache and job frames.  PUT_I8 ships a
    /// whole i8 code plane (one byte per element — 4× fewer operand wire
    /// bytes than the f32 PUT for the same panel); the Q8 job tags carry
    /// inline i8 runs plus the shared dequantization scale; Q8_REF is the
    /// descriptor-only cached quantized CONV frame.  Results stay f32 in
    /// every case — the shard dequantizes at the tile boundary, so reply
    /// frames are unchanged.  The codec is total over [`JobKind`] (a
    /// single-column [`JobKind::FcGemmQ8`] encodes fine); it is the
    /// *capability mask* ([`remote_class_mask`]) that keeps classes whose
    /// work cannot amortize a round trip off the wire.
    const KIND_OPERAND_PUT_I8: u8 = 8;
    const KIND_CONV_TILE_Q8: u8 = 9;
    const KIND_FC_GEMM_Q8: u8 = 10;
    const KIND_FC_GEMM_BATCH_Q8: u8 = 11;
    const KIND_CONV_TILE_Q8_REF: u8 = 12;

    /// Result frames lead with a status byte so a shard can answer with a
    /// readable error instead of dropping the connection.
    const STATUS_OK: u8 = 0;
    const STATUS_ERR: u8 = 1;
    /// The shard no longer holds a key a REF frame referenced (LRU
    /// eviction, or a restarted shard): echoes the job descriptor plus the
    /// missing keys so the client can re-PUT and retry — a recoverable
    /// in-band miss, not an error.
    const STATUS_CACHE_MISS: u8 = 2;
    /// Reply to [`KIND_PROBE`]: echoes the ping sequence and reports the
    /// shard's service rate + jobs served, feeding the prober's
    /// `LinkCost` cells.
    const STATUS_PROBE_ACK: u8 = 3;

    /// Decoder-side cap on one announced buffer (f32 elements): a frame
    /// already passed the transport's byte cap, this guards the
    /// allocation a corrupt length field would request.
    const MAX_ELEMS: usize = 1 << 27;

    fn put_u64(buf: &mut Vec<u8>, v: u64) {
        buf.extend_from_slice(&v.to_le_bytes());
    }

    fn put_f32s(buf: &mut Vec<u8>, data: &[f32]) {
        put_u64(buf, data.len() as u64);
        for v in data {
            buf.extend_from_slice(&v.to_le_bytes());
        }
    }

    fn put_f32(buf: &mut Vec<u8>, v: f32) {
        buf.extend_from_slice(&v.to_le_bytes());
    }

    fn put_i8s(buf: &mut Vec<u8>, data: &[i8]) {
        put_u64(buf, data.len() as u64);
        buf.extend(data.iter().map(|&v| v as u8));
    }

    fn put_desc(buf: &mut Vec<u8>, desc: &JobDesc) {
        put_u64(buf, desc.job_id);
        put_u64(buf, desc.layer_id as u64);
        put_u64(buf, desc.frame_id);
        put_u64(buf, desc.t1 as u64);
        put_u64(buf, desc.t2 as u64);
        put_u64(buf, desc.grid.m as u64);
        put_u64(buf, desc.grid.n as u64);
        put_u64(buf, desc.grid.p as u64);
        put_u64(buf, desc.grid.ts as u64);
    }

    /// Bounds-checked little-endian reader.
    struct Rd<'a> {
        buf: &'a [u8],
        pos: usize,
    }

    impl<'a> Rd<'a> {
        fn new(buf: &'a [u8]) -> Rd<'a> {
            Rd { buf, pos: 0 }
        }

        fn u8(&mut self) -> Result<u8> {
            let b = *self
                .buf
                .get(self.pos)
                .ok_or_else(|| anyhow!("truncated shard frame"))?;
            self.pos += 1;
            Ok(b)
        }

        fn u64(&mut self) -> Result<u64> {
            let end = self.pos + 8;
            let bytes = self
                .buf
                .get(self.pos..end)
                .ok_or_else(|| anyhow!("truncated shard frame"))?;
            self.pos = end;
            Ok(u64::from_le_bytes(bytes.try_into().expect("8-byte slice")))
        }

        fn usize(&mut self) -> Result<usize> {
            usize::try_from(self.u64()?).context("field exceeds usize")
        }

        fn f32(&mut self) -> Result<f32> {
            let end = self.pos + 4;
            let bytes = self
                .buf
                .get(self.pos..end)
                .ok_or_else(|| anyhow!("truncated shard frame"))?;
            self.pos = end;
            Ok(f32::from_le_bytes(bytes.try_into().expect("4-byte slice")))
        }

        fn i8s(&mut self) -> Result<Vec<i8>> {
            let n = self.usize()?;
            ensure!(n <= MAX_ELEMS, "shard frame announces {n} i8s");
            let end = self.pos + n;
            let bytes = self
                .buf
                .get(self.pos..end)
                .ok_or_else(|| anyhow!("truncated shard frame"))?;
            self.pos = end;
            Ok(bytes.iter().map(|&b| b as i8).collect())
        }

        fn f32s(&mut self) -> Result<Vec<f32>> {
            let n = self.usize()?;
            ensure!(n <= MAX_ELEMS, "shard frame announces {n} f32s");
            let end = self.pos + n * 4;
            let bytes = self
                .buf
                .get(self.pos..end)
                .ok_or_else(|| anyhow!("truncated shard frame"))?;
            self.pos = end;
            Ok(bytes
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes(c.try_into().expect("4-byte chunk")))
                .collect())
        }

        fn bytes(&mut self) -> Result<&'a [u8]> {
            let n = self.usize()?;
            // Bound before adding: a corrupt length must error, not
            // overflow the cursor.
            ensure!(
                n <= self.buf.len() - self.pos,
                "truncated shard frame"
            );
            let end = self.pos + n;
            let bytes = self
                .buf
                .get(self.pos..end)
                .ok_or_else(|| anyhow!("truncated shard frame"))?;
            self.pos = end;
            Ok(bytes)
        }

        fn desc(&mut self) -> Result<JobDesc> {
            let job_id = self.u64()?;
            let layer_id = self.usize()?;
            let frame_id = self.u64()?;
            let t1 = self.usize()?;
            let t2 = self.usize()?;
            let m = self.usize()?;
            let n = self.usize()?;
            let p = self.usize()?;
            let ts = self.usize()?;
            // Each dimension bounded by the element cap: products of two
            // stay well inside usize, so the operand-size cross-checks
            // below can never overflow.
            ensure!(
                ts > 0 && ts.is_power_of_two() && m > 0 && n > 0 && p > 0,
                "shard frame carries a degenerate grid ({m}x{n}x{p}, ts {ts})"
            );
            ensure!(
                m <= MAX_ELEMS && n <= MAX_ELEMS && p <= MAX_ELEMS && ts <= MAX_ELEMS,
                "shard frame carries an oversized grid ({m}x{n}x{p}, ts {ts})"
            );
            Ok(JobDesc {
                job_id,
                layer_id,
                frame_id,
                t1,
                t2,
                grid: TileGrid::new(m, n, p, ts),
            })
        }

        fn done(&self) -> Result<()> {
            ensure!(
                self.pos == self.buf.len(),
                "{} trailing bytes in shard frame",
                self.buf.len() - self.pos
            );
            Ok(())
        }
    }

    /// Serialized [`JobDesc`] size: nine `u64` fields.  Public so the
    /// wire-bytes regression tests can compute exact expected frame sizes
    /// (`1 + DESC_BYTES + Σ (8 + 4·len)` per operand run).
    pub const DESC_BYTES: usize = 9 * 8;

    /// Serialized [`OperandKey`] size: origin + sequence.
    pub const KEY_BYTES: usize = 2 * 8;

    /// Exact size of a descriptor-only CONV-tile frame: tag + descriptor +
    /// two `(key, offset, len)` operand references.  This is the whole
    /// per-tile wire cost once the layer's fetch sets are cached — the
    /// size the cache-protocol regression tests pin.
    pub const REF_FRAME_BYTES: usize = 1 + DESC_BYTES + 2 * (KEY_BYTES + 2 * 8);

    /// Exact size of a descriptor-only **quantized** CONV-tile frame: the
    /// f32 REF frame plus the 4-byte dequantization scale.  Like
    /// [`REF_FRAME_BYTES`], this is the whole per-tile wire cost once the
    /// layer's i8 code planes are cached shard-side.
    pub const Q8_REF_FRAME_BYTES: usize = REF_FRAME_BYTES + 4;

    /// A `(key, offset, len)` window into a cached operand buffer — the
    /// wire form of an [`OperandView`] whose backing buffer was PUT.
    #[derive(Debug, Clone, Copy, PartialEq, Eq)]
    pub struct KeyRef {
        pub key: OperandKey,
        pub off: usize,
        pub len: usize,
    }

    fn put_key(buf: &mut Vec<u8>, key: OperandKey) {
        put_u64(buf, key.0);
        put_u64(buf, key.1);
    }

    /// Ship one whole backing buffer under its content-address.  No reply.
    pub fn encode_operand_put(key: OperandKey, data: &[f32]) -> Vec<u8> {
        let mut buf = Vec::with_capacity(1 + KEY_BYTES + 8 + data.len() * 4);
        buf.push(KIND_OPERAND_PUT);
        put_key(&mut buf, key);
        put_f32s(&mut buf, data);
        buf
    }

    /// Ship one whole i8 code plane under its content-address: one byte
    /// per element on the wire, 4× fewer operand bytes than the f32 PUT
    /// of the same panel.  No reply.
    pub fn encode_operand_put_i8(key: OperandKey, data: &[i8]) -> Vec<u8> {
        let mut buf = Vec::with_capacity(1 + KEY_BYTES + 8 + data.len());
        buf.push(KIND_OPERAND_PUT_I8);
        put_key(&mut buf, key);
        put_i8s(&mut buf, data);
        buf
    }

    /// Invalidate one cached key (pack-generation bump).  No reply.
    pub fn encode_operand_drop(key: OperandKey) -> Vec<u8> {
        let mut buf = Vec::with_capacity(1 + KEY_BYTES);
        buf.push(KIND_OPERAND_DROP);
        put_key(&mut buf, key);
        buf
    }

    /// The descriptor-only CONV-tile job frame: exactly
    /// [`REF_FRAME_BYTES`] bytes, independent of the panels it references.
    pub fn encode_conv_tile_ref(desc: &JobDesc, a: KeyRef, b: KeyRef) -> Vec<u8> {
        let mut buf = Vec::with_capacity(REF_FRAME_BYTES);
        buf.push(KIND_CONV_TILE_REF);
        put_desc(&mut buf, desc);
        for r in [a, b] {
            put_key(&mut buf, r.key);
            put_u64(&mut buf, r.off as u64);
            put_u64(&mut buf, r.len as u64);
        }
        debug_assert_eq!(buf.len(), REF_FRAME_BYTES);
        buf
    }

    /// The descriptor-only quantized CONV-tile job frame: exactly
    /// [`Q8_REF_FRAME_BYTES`] bytes — descriptor, shared dequantization
    /// scale, and two `(key, offset, len)` references into cached i8
    /// planes.
    pub fn encode_conv_tile_q8_ref(desc: &JobDesc, scale: f32, a: KeyRef, b: KeyRef) -> Vec<u8> {
        let mut buf = Vec::with_capacity(Q8_REF_FRAME_BYTES);
        buf.push(KIND_CONV_TILE_Q8_REF);
        put_desc(&mut buf, desc);
        put_f32(&mut buf, scale);
        for r in [a, b] {
            put_key(&mut buf, r.key);
            put_u64(&mut buf, r.off as u64);
            put_u64(&mut buf, r.len as u64);
        }
        debug_assert_eq!(buf.len(), Q8_REF_FRAME_BYTES);
        buf
    }

    /// Health/RTT ping carrying a client-chosen sequence number.
    pub fn encode_probe(seq: u64) -> Vec<u8> {
        let mut buf = Vec::with_capacity(1 + 8);
        buf.push(KIND_PROBE);
        put_u64(&mut buf, seq);
        buf
    }

    /// The shard's recoverable "re-ship these keys" reply to a REF whose
    /// operands fell out of the cache.
    pub fn encode_cache_miss(desc: &JobDesc, missing: &[OperandKey]) -> Vec<u8> {
        let mut buf = Vec::with_capacity(1 + DESC_BYTES + 8 + missing.len() * KEY_BYTES);
        buf.push(STATUS_CACHE_MISS);
        put_desc(&mut buf, desc);
        put_u64(&mut buf, missing.len() as u64);
        for key in missing {
            put_key(&mut buf, *key);
        }
        buf
    }

    /// The shard's reply to a probe: echoed sequence, service rate in
    /// k-steps/s (0 = unknown), and jobs served on this connection.
    pub fn encode_probe_ack(seq: u64, rate_ksteps: f64, served: u64) -> Vec<u8> {
        let mut buf = Vec::with_capacity(1 + 3 * 8);
        buf.push(STATUS_PROBE_ACK);
        put_u64(&mut buf, seq);
        put_u64(&mut buf, rate_ksteps.to_bits());
        put_u64(&mut buf, served);
        buf
    }

    /// Every frame a shard server can receive, decoded.  Legacy job tags
    /// (0–3) decode through [`decode_job`]; the cache-protocol tags decode
    /// here.  Offsets/lengths of a REF are bounds-checked against the
    /// decoded geometry by the server (it owns the cached buffers), not
    /// here.
    pub enum ShardFrame {
        Job(Job),
        OperandPut { key: OperandKey, data: Vec<f32> },
        OperandPutI8 { key: OperandKey, data: Vec<i8> },
        OperandDrop { key: OperandKey },
        ConvTileRef { desc: JobDesc, a: KeyRef, b: KeyRef },
        ConvTileQ8Ref { desc: JobDesc, scale: f32, a: KeyRef, b: KeyRef },
        Probe { seq: u64 },
    }

    /// True for the tags [`decode_job`] owns: the four f32 job kinds plus
    /// the three inline int8 job kinds.
    fn is_job_tag(tag: u8) -> bool {
        tag <= KIND_FC_GEMM_BATCH || (KIND_CONV_TILE_Q8..=KIND_FC_GEMM_BATCH_Q8).contains(&tag)
    }

    /// Decode one client→shard frame of any kind.
    pub fn decode_shard_frame(frame: &[u8]) -> Result<ShardFrame> {
        match frame.first() {
            Some(&tag) if is_job_tag(tag) => Ok(ShardFrame::Job(decode_job(frame)?)),
            Some(&KIND_OPERAND_PUT) => {
                let mut rd = Rd::new(frame);
                rd.u8()?;
                let key = (rd.u64()?, rd.u64()?);
                let data = rd.f32s()?;
                rd.done()?;
                Ok(ShardFrame::OperandPut { key, data })
            }
            Some(&KIND_OPERAND_PUT_I8) => {
                let mut rd = Rd::new(frame);
                rd.u8()?;
                let key = (rd.u64()?, rd.u64()?);
                let data = rd.i8s()?;
                rd.done()?;
                Ok(ShardFrame::OperandPutI8 { key, data })
            }
            Some(&KIND_OPERAND_DROP) => {
                let mut rd = Rd::new(frame);
                rd.u8()?;
                let key = (rd.u64()?, rd.u64()?);
                rd.done()?;
                Ok(ShardFrame::OperandDrop { key })
            }
            Some(&KIND_CONV_TILE_REF) => {
                let mut rd = Rd::new(frame);
                rd.u8()?;
                let desc = rd.desc()?;
                let mut refs = [KeyRef {
                    key: (0, 0),
                    off: 0,
                    len: 0,
                }; 2];
                for r in refs.iter_mut() {
                    r.key = (rd.u64()?, rd.u64()?);
                    r.off = rd.usize()?;
                    r.len = rd.usize()?;
                    ensure!(r.len <= MAX_ELEMS, "oversized operand reference");
                }
                rd.done()?;
                ensure!(
                    desc.t1 < desc.grid.rows() && desc.t2 < desc.grid.cols(),
                    "tile coordinates outside the grid in shard frame"
                );
                Ok(ShardFrame::ConvTileRef {
                    desc,
                    a: refs[0],
                    b: refs[1],
                })
            }
            Some(&KIND_CONV_TILE_Q8_REF) => {
                let mut rd = Rd::new(frame);
                rd.u8()?;
                let desc = rd.desc()?;
                let scale = rd.f32()?;
                ensure!(
                    scale.is_finite(),
                    "non-finite dequantization scale in shard frame"
                );
                let mut refs = [KeyRef {
                    key: (0, 0),
                    off: 0,
                    len: 0,
                }; 2];
                for r in refs.iter_mut() {
                    r.key = (rd.u64()?, rd.u64()?);
                    r.off = rd.usize()?;
                    r.len = rd.usize()?;
                    ensure!(r.len <= MAX_ELEMS, "oversized operand reference");
                }
                rd.done()?;
                ensure!(
                    desc.t1 < desc.grid.rows() && desc.t2 < desc.grid.cols(),
                    "tile coordinates outside the grid in shard frame"
                );
                Ok(ShardFrame::ConvTileQ8Ref {
                    desc,
                    scale,
                    a: refs[0],
                    b: refs[1],
                })
            }
            Some(&KIND_PROBE) => {
                let mut rd = Rd::new(frame);
                rd.u8()?;
                let seq = rd.u64()?;
                rd.done()?;
                Ok(ShardFrame::Probe { seq })
            }
            Some(&other) => bail!("unknown shard frame tag {other}"),
            None => bail!("empty shard frame"),
        }
    }

    /// Every frame a client can receive back, decoded.
    pub enum ShardReply {
        Result(JobResult),
        CacheMiss {
            desc: JobDesc,
            missing: Vec<OperandKey>,
        },
        ProbeAck {
            seq: u64,
            rate_ksteps: f64,
            served: u64,
        },
    }

    /// Decode one shard→client frame of any status (errors still surface
    /// as `Err`, like [`decode_result`]).
    pub fn decode_reply(frame: &[u8]) -> Result<ShardReply> {
        match frame.first() {
            Some(&STATUS_CACHE_MISS) => {
                let mut rd = Rd::new(frame);
                rd.u8()?;
                let desc = rd.desc()?;
                let n = rd.usize()?;
                ensure!(n <= 2, "cache-miss frame announces {n} keys");
                let mut missing = Vec::with_capacity(n);
                for _ in 0..n {
                    missing.push((rd.u64()?, rd.u64()?));
                }
                rd.done()?;
                Ok(ShardReply::CacheMiss { desc, missing })
            }
            Some(&STATUS_PROBE_ACK) => {
                let mut rd = Rd::new(frame);
                rd.u8()?;
                let seq = rd.u64()?;
                let rate_ksteps = f64::from_bits(rd.u64()?);
                let served = rd.u64()?;
                rd.done()?;
                ensure!(
                    rate_ksteps.is_finite() && rate_ksteps >= 0.0,
                    "probe ack carries a non-finite rate"
                );
                Ok(ShardReply::ProbeAck {
                    seq,
                    rate_ksteps,
                    served,
                })
            }
            _ => Ok(ShardReply::Result(decode_result(frame)?)),
        }
    }

    /// Encode one job for shipping.  The frame size is known up front, so
    /// the buffer is reserved once — megabyte operand runs must not pay
    /// log₂(n) reallocation copies on the per-job shipping path.
    ///
    /// Operands are serialized **straight from the job's views**: a
    /// CONV-tile frame carries exactly the packed `(K,TS,TS)` fetch set
    /// the job aliases (paper Listing 3 steps ①–②) with no intermediate
    /// re-pack or staging `Vec` — the wire codec is the single place in
    /// the operand plane where view bytes are materialized.
    pub fn encode_job(job: &Job) -> Vec<u8> {
        let payload = match &job.kind {
            JobKind::ConvTile { a_tiles, b_tiles } => 16 + (a_tiles.len() + b_tiles.len()) * 4,
            JobKind::FcGemm { a, b } | JobKind::FcGemmBatch { a, b } => {
                16 + (a.len() + b.len()) * 4
            }
            JobKind::Im2col { input, .. } => 8 + input.len() * 4 + 6 * 8,
            JobKind::ConvTileQ8 {
                a_tiles, b_tiles, ..
            } => 4 + 16 + a_tiles.len() + b_tiles.len(),
            JobKind::FcGemmQ8 { a, b, .. } | JobKind::FcGemmBatchQ8 { a, b, .. } => {
                4 + 16 + a.len() + b.len()
            }
        };
        let mut buf = Vec::with_capacity(1 + DESC_BYTES + payload);
        match &job.kind {
            JobKind::ConvTile { a_tiles, b_tiles } => {
                buf.push(KIND_CONV_TILE);
                put_desc(&mut buf, &job.desc);
                put_f32s(&mut buf, a_tiles);
                put_f32s(&mut buf, b_tiles);
            }
            JobKind::FcGemm { a, b } => {
                buf.push(KIND_FC_GEMM);
                put_desc(&mut buf, &job.desc);
                put_f32s(&mut buf, a);
                put_f32s(&mut buf, b);
            }
            JobKind::FcGemmBatch { a, b } => {
                buf.push(KIND_FC_GEMM_BATCH);
                put_desc(&mut buf, &job.desc);
                put_f32s(&mut buf, a);
                put_f32s(&mut buf, b);
            }
            JobKind::Im2col {
                input,
                chw,
                size,
                stride,
                pad,
            } => {
                buf.push(KIND_IM2COL);
                put_desc(&mut buf, &job.desc);
                put_f32s(&mut buf, input);
                put_u64(&mut buf, chw.0 as u64);
                put_u64(&mut buf, chw.1 as u64);
                put_u64(&mut buf, chw.2 as u64);
                put_u64(&mut buf, *size as u64);
                put_u64(&mut buf, *stride as u64);
                put_u64(&mut buf, *pad as u64);
            }
            JobKind::ConvTileQ8 {
                a_tiles,
                b_tiles,
                scale,
            } => {
                buf.push(KIND_CONV_TILE_Q8);
                put_desc(&mut buf, &job.desc);
                put_f32(&mut buf, *scale);
                put_i8s(&mut buf, a_tiles);
                put_i8s(&mut buf, b_tiles);
            }
            JobKind::FcGemmQ8 { a, b, scale } => {
                buf.push(KIND_FC_GEMM_Q8);
                put_desc(&mut buf, &job.desc);
                put_f32(&mut buf, *scale);
                put_i8s(&mut buf, a);
                put_i8s(&mut buf, b);
            }
            JobKind::FcGemmBatchQ8 { a, b, scale } => {
                buf.push(KIND_FC_GEMM_BATCH_Q8);
                put_desc(&mut buf, &job.desc);
                put_f32(&mut buf, *scale);
                put_i8s(&mut buf, a);
                put_i8s(&mut buf, b);
            }
        }
        buf
    }

    /// Decode one shipped job back into the exact [`Job`] value.  Operand
    /// sizes are re-validated against the decoded geometry, so a corrupt
    /// frame is an error here, never a panic in a kernel.
    pub fn decode_job(frame: &[u8]) -> Result<Job> {
        let mut rd = Rd::new(frame);
        let tag = rd.u8()?;
        let desc = rd.desc()?;
        let g = desc.grid;
        let kind = match tag {
            KIND_CONV_TILE => {
                // A CONV-tile frame carries the job's packed fetch set:
                // one (K,TS,TS) panel per operand, not the dense layer
                // matrices.  k_tiles derives from the decoded grid (n and
                // ts are both ≤ MAX_ELEMS, so the product cannot wrap).
                let a = rd.f32s()?;
                let b = rd.f32s()?;
                let panel = desc.k_tiles() * g.ts * g.ts;
                ensure!(a.len() == panel, "A fetch-set size mismatch in shard frame");
                ensure!(b.len() == panel, "B fetch-set size mismatch in shard frame");
                ensure!(
                    desc.t1 < g.rows() && desc.t2 < g.cols(),
                    "tile coordinates outside the grid in shard frame"
                );
                JobKind::ConvTile {
                    a_tiles: a.into(),
                    b_tiles: b.into(),
                }
            }
            KIND_FC_GEMM | KIND_FC_GEMM_BATCH => {
                let a = rd.f32s()?;
                let b = rd.f32s()?;
                ensure!(a.len() == g.m * g.n, "A operand size mismatch in shard frame");
                ensure!(b.len() == g.n * g.p, "B operand size mismatch in shard frame");
                if tag == KIND_FC_GEMM {
                    JobKind::FcGemm {
                        a: a.into(),
                        b: b.into(),
                    }
                } else {
                    JobKind::FcGemmBatch {
                        a: a.into(),
                        b: b.into(),
                    }
                }
            }
            KIND_IM2COL => {
                let input = rd.f32s()?;
                let chw = (rd.usize()?, rd.usize()?, rd.usize()?);
                let size = rd.usize()?;
                let stride = rd.usize()?;
                let pad = rd.usize()?;
                ensure!(
                    chw.0 <= MAX_ELEMS && chw.1 <= MAX_ELEMS && chw.2 <= MAX_ELEMS,
                    "oversized im2col shape in shard frame"
                );
                ensure!(
                    input.len() == chw.0.saturating_mul(chw.1).saturating_mul(chw.2),
                    "im2col input size mismatch in shard frame"
                );
                ensure!(
                    size > 0 && stride > 0,
                    "degenerate im2col geometry in shard frame"
                );
                JobKind::Im2col {
                    input: input.into(),
                    chw,
                    size,
                    stride,
                    pad,
                }
            }
            KIND_CONV_TILE_Q8 => {
                let scale = rd.f32()?;
                ensure!(
                    scale.is_finite(),
                    "non-finite dequantization scale in shard frame"
                );
                let a = rd.i8s()?;
                let b = rd.i8s()?;
                let panel = desc.k_tiles() * g.ts * g.ts;
                ensure!(a.len() == panel, "A fetch-set size mismatch in shard frame");
                ensure!(b.len() == panel, "B fetch-set size mismatch in shard frame");
                ensure!(
                    desc.t1 < g.rows() && desc.t2 < g.cols(),
                    "tile coordinates outside the grid in shard frame"
                );
                JobKind::ConvTileQ8 {
                    a_tiles: a.into(),
                    b_tiles: b.into(),
                    scale,
                }
            }
            KIND_FC_GEMM_Q8 | KIND_FC_GEMM_BATCH_Q8 => {
                let scale = rd.f32()?;
                ensure!(
                    scale.is_finite(),
                    "non-finite dequantization scale in shard frame"
                );
                let a = rd.i8s()?;
                let b = rd.i8s()?;
                ensure!(a.len() == g.m * g.n, "A operand size mismatch in shard frame");
                ensure!(b.len() == g.n * g.p, "B operand size mismatch in shard frame");
                if tag == KIND_FC_GEMM_Q8 {
                    JobKind::FcGemmQ8 {
                        a: a.into(),
                        b: b.into(),
                        scale,
                    }
                } else {
                    JobKind::FcGemmBatchQ8 {
                        a: a.into(),
                        b: b.into(),
                        scale,
                    }
                }
            }
            other => bail!("unknown shard job kind tag {other}"),
        };
        rd.done()?;
        // Placement hints address the *sender's* clusters; they are never
        // serialized, and a decoded job routes fresh on the host pool.
        Ok(Job {
            desc,
            kind,
            placement: None,
        })
    }

    /// Encode one finished result.
    pub fn encode_result(result: &JobResult) -> Vec<u8> {
        let mut buf = Vec::with_capacity(1 + DESC_BYTES + 8 + result.data.len() * 4);
        buf.push(STATUS_OK);
        put_desc(&mut buf, &result.desc);
        put_f32s(&mut buf, &result.data);
        buf
    }

    /// Encode an execution error (the shard stays up; the client surfaces
    /// the message as its `execute` error).
    pub fn encode_error(msg: &str) -> Vec<u8> {
        let mut buf = vec![STATUS_ERR];
        put_u64(&mut buf, msg.len() as u64);
        buf.extend_from_slice(msg.as_bytes());
        buf
    }

    /// Decode a result frame (or the shard's error report).
    pub fn decode_result(frame: &[u8]) -> Result<JobResult> {
        let mut rd = Rd::new(frame);
        match rd.u8()? {
            STATUS_OK => {
                let desc = rd.desc()?;
                let data = rd.f32s()?;
                rd.done()?;
                Ok(JobResult { desc, data })
            }
            STATUS_ERR => {
                let msg = String::from_utf8_lossy(rd.bytes()?).into_owned();
                bail!("remote shard reported: {msg}")
            }
            other => bail!("unknown shard result status {other}"),
        }
    }
}

// ----------------------------------------------------------------- cache

/// The shard-side operand cache: a bounded LRU from [`OperandKey`] to the
/// shipped backing buffer.  One instance is shared by every connection a
/// [`crate::serve::ShardServer`] accepts (a client pool opens one
/// connection per delegate, and all of them reference the same prepacks),
/// so a buffer PUT over one connection serves REFs from all of them.
///
/// Capacity is in f32-equivalent elements (an i8 code plane accounts its
/// bytes at a quarter element each — see [`plane_elems`]).  `put` always
/// stores the new buffer,
/// evicting least-recently-used peers down to capacity — but never below
/// the **two** most-recent entries, so the fetch-set *pair* one CONV tile
/// references can always coexist and a miss→re-PUT→retry cycle converges
/// (at worst one bounded overshoot) instead of thrashing when the nominal
/// capacity is smaller than a single working set.
pub struct ShardCache {
    capacity_elems: usize,
    inner: Mutex<CacheInner>,
}

#[derive(Default)]
struct CacheInner {
    entries: HashMap<OperandKey, (Plane, u64)>,
    elems: usize,
    tick: u64,
    hits: u64,
    misses: u64,
    evictions: u64,
}

/// Capacity accounting in f32-equivalent elements: an f32 buffer counts
/// its length, an i8 code plane counts a quarter of it (rounded up) — the
/// cache bounds *bytes*, and the knob stays in the f32 units every
/// existing configuration uses.
fn plane_elems(plane: &Plane) -> usize {
    plane.bytes().div_ceil(4)
}

/// Point-in-time cache counters (diagnostics + the fleet example's
/// hit-rate assertion).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ShardCacheStats {
    pub entries: usize,
    pub elems: usize,
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
}

impl ShardCache {
    /// A cache bounded to `capacity_elems` f32 elements.
    pub fn with_capacity_elems(capacity_elems: usize) -> Arc<ShardCache> {
        Arc::new(ShardCache {
            capacity_elems: capacity_elems.max(1),
            inner: Mutex::new(CacheInner::default()),
        })
    }

    /// A cache bounded to `mb` MiB of f32 payload (the `[serving]
    /// shard_cache_mb` knob).
    pub fn with_capacity_mb(mb: usize) -> Arc<ShardCache> {
        ShardCache::with_capacity_elems(mb.max(1) * (1 << 20) / 4)
    }

    /// Insert (or refresh) an f32 buffer under `key`; evicts LRU peers
    /// until the rest fits.
    pub fn put(&self, key: OperandKey, data: Vec<f32>) {
        self.put_plane(key, Plane::F32(Arc::new(data)));
    }

    /// Insert (or refresh) an i8 code plane under `key` — the quantized
    /// twin of [`ShardCache::put`], sharing the same budget and LRU order.
    pub fn put_i8(&self, key: OperandKey, data: Vec<i8>) {
        self.put_plane(key, Plane::I8(Arc::new(data)));
    }

    fn put_plane(&self, key: OperandKey, plane: Plane) {
        let mut inner = lock_clean(&self.inner);
        inner.tick += 1;
        let tick = inner.tick;
        let added = plane_elems(&plane);
        if let Some((old, _)) = inner.entries.insert(key, (plane, tick)) {
            inner.elems -= plane_elems(&old);
        }
        inner.elems += added;
        while inner.elems > self.capacity_elems && inner.entries.len() > 2 {
            // Global LRU victim; the just-put key holds the newest tick,
            // so it is never selected while older peers exist.
            let victim = inner
                .entries
                .iter()
                .min_by_key(|(_, (_, t))| *t)
                .map(|(k, _)| *k);
            match victim {
                Some(v) => {
                    if let Some((buf, _)) = inner.entries.remove(&v) {
                        inner.elems -= plane_elems(&buf);
                        inner.evictions += 1;
                    }
                }
                None => break,
            }
        }
    }

    /// Dtype-filtered lookup, bumping recency on a hit.  An entry of the
    /// wrong dtype counts as a miss — the server answers `CACHE_MISS` and
    /// the client re-PUTs, exactly like an eviction (keys are minted per
    /// buffer, so this is defensive: it cannot happen in-protocol).
    fn lookup<R>(&self, key: OperandKey, pick: impl Fn(&Plane) -> Option<R>) -> Option<R> {
        let mut inner = lock_clean(&self.inner);
        inner.tick += 1;
        let tick = inner.tick;
        match inner.entries.get_mut(&key) {
            Some((plane, t)) => match pick(plane) {
                Some(r) => {
                    *t = tick;
                    inner.hits += 1;
                    Some(r)
                }
                None => {
                    inner.misses += 1;
                    None
                }
            },
            None => {
                inner.misses += 1;
                None
            }
        }
    }

    /// Look an f32 key up, bumping its recency.  Counts a hit or a miss.
    pub fn get(&self, key: OperandKey) -> Option<Arc<Vec<f32>>> {
        self.lookup(key, |p| p.as_f32().cloned())
    }

    /// Look an i8 key up, bumping its recency.  Counts a hit or a miss.
    pub fn get_i8(&self, key: OperandKey) -> Option<Arc<Vec<i8>>> {
        self.lookup(key, |p| p.as_i8().cloned())
    }

    /// Drop a key (the client's explicit invalidation frame).
    pub fn remove(&self, key: OperandKey) {
        let mut inner = lock_clean(&self.inner);
        if let Some((buf, _)) = inner.entries.remove(&key) {
            inner.elems -= plane_elems(&buf);
        }
    }

    pub fn stats(&self) -> ShardCacheStats {
        let inner = lock_clean(&self.inner);
        ShardCacheStats {
            entries: inner.entries.len(),
            elems: inner.elems,
            hits: inner.hits,
            misses: inner.misses,
            evictions: inner.evictions,
        }
    }
}

// ----------------------------------------------------------------- shard

/// Client-side cache-protocol counters of one [`RemoteShard`].
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ClientCacheStats {
    /// Whole-buffer PUT frames shipped.
    pub puts: u64,
    /// Invalidation DROP frames shipped (pack-generation bumps).
    pub drops: u64,
    /// Descriptor-only REF frames shipped.
    pub refs: u64,
    /// CACHE_MISS replies recovered from (re-PUT + retry).
    pub misses: u64,
}

/// The remote-shard backend: ships each job over its transport and blocks
/// for the result.  Built inside the delegate thread (one connection per
/// delegate) like every other backend; the delegate drives it purely
/// through the [`Accelerator`] trait.
pub struct RemoteShard {
    id: String,
    caps: ClassMask,
    overhead_ksteps: f64,
    transport: Box<dyn ShardTransport>,
    /// Bytes this client put on (and took off) the wire: request + result
    /// frame payloads, accumulated per `execute`.  Shareable so a test or
    /// an operator can hold the ledger while the shard lives inside its
    /// delegate thread — the proof that shipped bytes equal the jobs'
    /// packed fetch-set sizes, with no double-buffering inflation.
    wire_bytes: Arc<AtomicU64>,
    /// Ship CONV tiles through the operand-cache protocol (default on).
    /// Off, every job uses the legacy full-fetch-set frame — the mode the
    /// exact per-tile wire-byte tests pin as the baseline.
    cache_conv: bool,
    /// Keys this connection has PUT and not DROPped — the client's view of
    /// what the shard holds (optimistic: an LRU eviction shows up as a
    /// CACHE_MISS reply and removes the key here).
    shipped: HashSet<OperandKey>,
    /// Last key shipped per (layer, operand-role) slot.  A CONV tile whose
    /// buffer keys differently than its slot's previous binding *is* a
    /// pack-generation bump: DROP the old key, PUT the new one — exactly
    /// one re-ship.
    by_slot: HashMap<(usize, u8), OperandKey>,
    cache_stats: ClientCacheStats,
}

impl RemoteShard {
    /// Wrap a connected transport.  `caps`/`overhead_ksteps` should match
    /// the values the backend was registered with (the registry metadata
    /// is what routing and stealing consult; the instance is what
    /// executes).
    pub fn new(
        id: String,
        caps: ClassMask,
        overhead_ksteps: f64,
        transport: Box<dyn ShardTransport>,
    ) -> RemoteShard {
        RemoteShard {
            id,
            caps,
            overhead_ksteps,
            transport,
            wire_bytes: Arc::new(AtomicU64::new(0)),
            cache_conv: true,
            shipped: HashSet::new(),
            by_slot: HashMap::new(),
            cache_stats: ClientCacheStats::default(),
        }
    }

    /// The default-shaped shard over an in-process transport (tests).
    pub fn over_duplex(id: &str, transport: ChannelTransport) -> RemoteShard {
        RemoteShard::new(
            id.to_string(),
            remote_class_mask(),
            REMOTE_OVERHEAD_KSTEPS,
            Box::new(transport),
        )
    }

    /// Share `ledger` as this shard's wire-bytes counter (builder-style;
    /// used by registrations that want the ledger to outlive the delegate
    /// thread the shard is built in).
    pub fn with_wire_ledger(mut self, ledger: Arc<AtomicU64>) -> RemoteShard {
        self.wire_bytes = ledger;
        self
    }

    /// Enable/disable the CONV operand-cache protocol (builder-style).
    /// Disabled, every tile ships its full packed fetch set — the legacy
    /// per-tile baseline the wire-byte regression tests measure against.
    pub fn with_operand_cache(mut self, enabled: bool) -> RemoteShard {
        self.cache_conv = enabled;
        self
    }

    /// Total frame bytes sent plus received by this client so far.
    pub fn wire_bytes(&self) -> u64 {
        self.wire_bytes.load(Ordering::Relaxed)
    }

    /// Client-side cache-protocol counters.
    pub fn cache_stats(&self) -> ClientCacheStats {
        self.cache_stats
    }

    /// Ship one frame, folding its size into the wire ledger.
    fn send_counted(&mut self, frame: &[u8]) -> Result<()> {
        self.wire_bytes
            .fetch_add(frame.len() as u64, Ordering::Relaxed);
        self.transport.send(frame)
    }

    /// Receive one frame, folding its size into the wire ledger.
    fn recv_counted(&mut self) -> Result<Vec<u8>> {
        let frame = self.transport.recv()?;
        self.wire_bytes
            .fetch_add(frame.len() as u64, Ordering::Relaxed);
        Ok(frame)
    }

    /// Make sure `view`'s backing buffer is cached shard-side under its
    /// operand key, DROPping the slot's previous binding if the key
    /// changed (pack-generation bump), and return the wire reference.
    fn ensure_shipped(
        &mut self,
        layer_id: usize,
        role: u8,
        view: &OperandView,
    ) -> Result<wire::KeyRef> {
        let key = operand_key(view.buffer());
        if let Some(&old) = self.by_slot.get(&(layer_id, role)) {
            if old != key && self.shipped.remove(&old) {
                self.send_counted(&wire::encode_operand_drop(old))?;
                self.cache_stats.drops += 1;
            }
        }
        self.by_slot.insert((layer_id, role), key);
        if !self.shipped.contains(&key) {
            self.send_counted(&wire::encode_operand_put(key, view.buffer()))?;
            self.cache_stats.puts += 1;
            self.shipped.insert(key);
        }
        Ok(wire::KeyRef {
            key,
            off: view.offset(),
            len: view.len(),
        })
    }

    /// [`RemoteShard::ensure_shipped`] for i8 code planes: the same
    /// slot-tracking and pack-generation-bump protocol, one byte per
    /// element on the wire.  Q8 slots use their own role ids so a layer
    /// running mixed-precision frames never aliases its f32 bindings.
    fn ensure_shipped_i8(
        &mut self,
        layer_id: usize,
        role: u8,
        view: &OperandView<i8>,
    ) -> Result<wire::KeyRef> {
        let key = operand_key(view.buffer());
        if let Some(&old) = self.by_slot.get(&(layer_id, role)) {
            if old != key && self.shipped.remove(&old) {
                self.send_counted(&wire::encode_operand_drop(old))?;
                self.cache_stats.drops += 1;
            }
        }
        self.by_slot.insert((layer_id, role), key);
        if !self.shipped.contains(&key) {
            self.send_counted(&wire::encode_operand_put_i8(key, view.buffer()))?;
            self.cache_stats.puts += 1;
            self.shipped.insert(key);
        }
        Ok(wire::KeyRef {
            key,
            off: view.offset(),
            len: view.len(),
        })
    }

    /// The cached CONV-tile path: PUT-on-first-use, then a descriptor-only
    /// REF frame per tile; a CACHE_MISS reply re-PUTs the evicted keys and
    /// retries, so results are bit-identical to the uncached path.
    fn execute_conv_cached(
        &mut self,
        job: &Job,
        a_view: &OperandView,
        b_view: &OperandView,
    ) -> Result<JobResult> {
        let layer = job.desc.layer_id;
        let a = self.ensure_shipped(layer, 0, a_view)?;
        let b = self.ensure_shipped(layer, 1, b_view)?;
        // One re-ship round per referenced operand is all an LRU miss can
        // need (`ShardCache::put` never evicts the buffer it just stored);
        // more means the shard is broken, not busy.
        for _ in 0..3 {
            self.send_counted(&wire::encode_conv_tile_ref(&job.desc, a, b))?;
            self.cache_stats.refs += 1;
            let frame = self.recv_counted()?;
            match wire::decode_reply(&frame)? {
                wire::ShardReply::Result(result) => {
                    ensure!(
                        result.desc.job_id == job.desc.job_id,
                        "{} answered job {} while executing job {}",
                        self.id,
                        result.desc.job_id,
                        job.desc.job_id
                    );
                    return Ok(JobResult {
                        desc: job.desc,
                        data: result.data,
                    });
                }
                wire::ShardReply::CacheMiss { desc, missing } => {
                    ensure!(
                        desc.job_id == job.desc.job_id,
                        "{} reported a cache miss for job {} while executing job {}",
                        self.id,
                        desc.job_id,
                        job.desc.job_id
                    );
                    self.cache_stats.misses += 1;
                    for key in missing {
                        self.shipped.remove(&key);
                        let view = if key == a.key {
                            a_view
                        } else if key == b.key {
                            b_view
                        } else {
                            bail!("{} reported a miss for a key job {} never referenced",
                                self.id, job.desc.job_id)
                        };
                        self.send_counted(&wire::encode_operand_put(key, view.buffer()))?;
                        self.cache_stats.puts += 1;
                        self.shipped.insert(key);
                    }
                }
                wire::ShardReply::ProbeAck { .. } => {
                    bail!("{} answered job {} with a probe ack", self.id, job.desc.job_id)
                }
            }
        }
        bail!(
            "{} kept missing job {}'s operands after re-shipping them",
            self.id,
            job.desc.job_id
        )
    }

    /// The cached **quantized** CONV-tile path: i8 code planes are PUT
    /// once (4× fewer operand bytes than their f32 twins), then every
    /// tile ships a fixed [`wire::Q8_REF_FRAME_BYTES`] descriptor frame.
    /// Results come back f32 — the shard dequantizes at the tile
    /// boundary — and the miss→re-PUT→retry recovery matches the f32
    /// path's bit-for-bit.
    fn execute_conv_q8_cached(
        &mut self,
        job: &Job,
        a_view: &OperandView<i8>,
        b_view: &OperandView<i8>,
        scale: f32,
    ) -> Result<JobResult> {
        let layer = job.desc.layer_id;
        let a = self.ensure_shipped_i8(layer, 2, a_view)?;
        let b = self.ensure_shipped_i8(layer, 3, b_view)?;
        for _ in 0..3 {
            self.send_counted(&wire::encode_conv_tile_q8_ref(&job.desc, scale, a, b))?;
            self.cache_stats.refs += 1;
            let frame = self.recv_counted()?;
            match wire::decode_reply(&frame)? {
                wire::ShardReply::Result(result) => {
                    ensure!(
                        result.desc.job_id == job.desc.job_id,
                        "{} answered job {} while executing job {}",
                        self.id,
                        result.desc.job_id,
                        job.desc.job_id
                    );
                    return Ok(JobResult {
                        desc: job.desc,
                        data: result.data,
                    });
                }
                wire::ShardReply::CacheMiss { desc, missing } => {
                    ensure!(
                        desc.job_id == job.desc.job_id,
                        "{} reported a cache miss for job {} while executing job {}",
                        self.id,
                        desc.job_id,
                        job.desc.job_id
                    );
                    self.cache_stats.misses += 1;
                    for key in missing {
                        self.shipped.remove(&key);
                        let view = if key == a.key {
                            a_view
                        } else if key == b.key {
                            b_view
                        } else {
                            bail!("{} reported a miss for a key job {} never referenced",
                                self.id, job.desc.job_id)
                        };
                        self.send_counted(&wire::encode_operand_put_i8(key, view.buffer()))?;
                        self.cache_stats.puts += 1;
                        self.shipped.insert(key);
                    }
                }
                wire::ShardReply::ProbeAck { .. } => {
                    bail!("{} answered job {} with a probe ack", self.id, job.desc.job_id)
                }
            }
        }
        bail!(
            "{} kept missing job {}'s operands after re-shipping them",
            self.id,
            job.desc.job_id
        )
    }
}

impl Accelerator for RemoteShard {
    fn id(&self) -> &str {
        &self.id
    }

    fn supports(&self, class: JobClass) -> bool {
        self.caps.supports(class)
    }

    /// Round-trip-inclusive cost: the fixed shipping overhead plus the
    /// job's k-steps — the same `overhead_ksteps` the registry advertises
    /// for this backend, so the dispatcher's penalty, the thief's ship
    /// gate, and the per-job estimate all agree.
    fn cost(&self, job: &Job) -> f64 {
        self.overhead_ksteps + job.ksteps() as f64
    }

    fn execute(&mut self, job: &Job) -> Result<JobResult> {
        // CONV tiles go through the operand-cache protocol: their packed
        // fetch sets are stable layer state every tile re-references, so
        // steady state ships 137-byte descriptor frames instead of
        // megabyte panels.  Other classes ship whole frames — a fused FC
        // batch's activation pack is fresh per micro-batch, so caching it
        // would only add round trips.
        if self.cache_conv {
            if let JobKind::ConvTile { a_tiles, b_tiles } = &job.kind {
                return self
                    .execute_conv_cached(job, a_tiles, b_tiles)
                    .with_context(|| format!("shipping job {} to {}", job.desc.job_id, self.id));
            }
            if let JobKind::ConvTileQ8 {
                a_tiles,
                b_tiles,
                scale,
            } = &job.kind
            {
                return self
                    .execute_conv_q8_cached(job, a_tiles, b_tiles, *scale)
                    .with_context(|| format!("shipping job {} to {}", job.desc.job_id, self.id));
            }
        }
        // The codec serializes straight from the job's operand views — a
        // CONV tile's frame IS its packed fetch set (the job has carried
        // exactly that since the operand-plane redesign; the old
        // per-dispatch re-tiling pass is gone).
        let frame = wire::encode_job(job);
        self.wire_bytes
            .fetch_add(frame.len() as u64, Ordering::Relaxed);
        self.transport
            .send(&frame)
            .with_context(|| format!("shipping job {} to {}", job.desc.job_id, self.id))?;
        let frame = self
            .transport
            .recv()
            .with_context(|| format!("awaiting job {} from {}", job.desc.job_id, self.id))?;
        self.wire_bytes
            .fetch_add(frame.len() as u64, Ordering::Relaxed);
        let result = wire::decode_result(&frame)?;
        ensure!(
            result.desc.job_id == job.desc.job_id,
            "{} answered job {} while executing job {}",
            self.id,
            result.desc.job_id,
            job.desc.job_id
        );
        Ok(JobResult {
            desc: job.desc,
            data: result.data,
        })
    }
}

// ---------------------------------------------------------- registration

/// Register a TCP-dialing shard backend for `addr` under
/// [`shard_backend_name`]`(addr)`.  Each delegate resolving the entry
/// dials its own connection inside its thread; a refused connection fails
/// pool startup cleanly (the builder's error propagates).
pub fn register_tcp_shard(registry: &mut BackendRegistry, addr: &str) {
    let name = shard_backend_name(addr);
    let id = name.clone();
    let target = addr.to_string();
    registry.register(
        BackendSpec::new(&name, move || {
            let transport = TcpTransport::connect(&target)?;
            Ok(Box::new(RemoteShard::new(
                id.clone(),
                remote_class_mask(),
                REMOTE_OVERHEAD_KSTEPS,
                Box::new(transport),
            )) as Box<dyn Accelerator>)
        })
        .caps(remote_class_mask())
        .overhead_ksteps(REMOTE_OVERHEAD_KSTEPS),
    );
}

/// Register a TCP shard backend for every `[cluster] remote = "host:port"`
/// member of `hw` — the one call a config-driven deployment makes before
/// starting its pool.
pub fn register_config_shards(registry: &mut BackendRegistry, hw: &HwConfig) {
    for cluster in &hw.clusters {
        for addr in &cluster.remote {
            register_tcp_shard(registry, addr);
        }
    }
}

// --------------------------------------------------------------- service

/// Service one transport: receive jobs, execute through `exec`, reply with
/// framed results, until the peer goes away.  Returns the number of jobs
/// **executed** (cache-maintenance and probe frames don't count).
/// Transport errors are a normal disconnect (`Ok`); a decode failure is a
/// protocol error (`Err`); an `exec` error is reported to the peer in-band
/// and ends the session (`Err`) — the peer's delegate requeues and the far
/// pool stays consistent.
///
/// Cache-protocol frames are handled here, against `cache` (shared across
/// a server's connections): PUT/DROP maintain it silently, a REF
/// reconstructs the job's operand views zero-copy over the cached buffers
/// (or answers `CACHE_MISS` so the client re-ships), and a PROBE is
/// answered with `rate_ksteps` + the served count.
pub fn serve_shard_transport(
    transport: &mut dyn ShardTransport,
    cache: &ShardCache,
    rate_ksteps: f64,
    mut exec: impl FnMut(&Job) -> Result<JobResult>,
) -> Result<u64> {
    let mut served = 0u64;
    let mut run = |job: &Job,
                   transport: &mut dyn ShardTransport,
                   served: &mut u64|
     -> Result<bool> {
        match exec(job) {
            Ok(result) => {
                if transport.send(&wire::encode_result(&result)).is_err() {
                    return Ok(false); // peer gone: clean disconnect
                }
                *served += 1;
                Ok(true)
            }
            Err(e) => {
                let _ = transport.send(&wire::encode_error(&format!("{e:#}")));
                Err(e)
            }
        }
    };
    loop {
        let frame = match transport.recv() {
            Ok(frame) => frame,
            Err(_) => return Ok(served), // peer closed: a clean disconnect
        };
        match wire::decode_shard_frame(&frame)? {
            wire::ShardFrame::OperandPut { key, data } => cache.put(key, data),
            wire::ShardFrame::OperandPutI8 { key, data } => cache.put_i8(key, data),
            wire::ShardFrame::OperandDrop { key } => cache.remove(key),
            wire::ShardFrame::Probe { seq } => {
                if transport
                    .send(&wire::encode_probe_ack(seq, rate_ksteps, served))
                    .is_err()
                {
                    return Ok(served);
                }
            }
            wire::ShardFrame::ConvTileRef { desc, a, b } => {
                let (a_buf, b_buf) = (cache.get(a.key), cache.get(b.key));
                let missing: Vec<OperandKey> = [(a, &a_buf), (b, &b_buf)]
                    .iter()
                    .filter(|(_, buf)| buf.is_none())
                    .map(|(r, _)| r.key)
                    .collect();
                if !missing.is_empty() {
                    if transport
                        .send(&wire::encode_cache_miss(&desc, &missing))
                        .is_err()
                    {
                        return Ok(served);
                    }
                    continue;
                }
                // Re-validate geometry exactly like the full-frame decoder
                // before touching the buffers: a bad reference is a
                // protocol error here, never a panic in a kernel.
                let panel = desc.k_tiles() * desc.grid.ts * desc.grid.ts;
                let mut views = Vec::with_capacity(2);
                for (r, buf) in [(a, a_buf.unwrap()), (b, b_buf.unwrap())] {
                    ensure!(
                        r.len == panel,
                        "fetch-set reference size mismatch in shard frame"
                    );
                    ensure!(
                        r.off.checked_add(r.len).is_some_and(|end| end <= buf.len()),
                        "operand reference outside its cached buffer"
                    );
                    views.push(OperandView::new(buf, r.off, r.len));
                }
                let b_tiles = views.pop().expect("two views");
                let a_tiles = views.pop().expect("two views");
                let job = Job {
                    desc,
                    kind: JobKind::ConvTile { a_tiles, b_tiles },
                    placement: None,
                };
                if !run(&job, transport, &mut served)? {
                    return Ok(served);
                }
            }
            wire::ShardFrame::ConvTileQ8Ref { desc, scale, a, b } => {
                let (a_buf, b_buf) = (cache.get_i8(a.key), cache.get_i8(b.key));
                let missing: Vec<OperandKey> = [(a, &a_buf), (b, &b_buf)]
                    .iter()
                    .filter(|(_, buf)| buf.is_none())
                    .map(|(r, _)| r.key)
                    .collect();
                if !missing.is_empty() {
                    if transport
                        .send(&wire::encode_cache_miss(&desc, &missing))
                        .is_err()
                    {
                        return Ok(served);
                    }
                    continue;
                }
                // Same geometry re-validation as the f32 REF: a bad
                // reference is a protocol error, never a kernel panic.
                let panel = desc.k_tiles() * desc.grid.ts * desc.grid.ts;
                let mut views = Vec::with_capacity(2);
                for (r, buf) in [(a, a_buf.unwrap()), (b, b_buf.unwrap())] {
                    ensure!(
                        r.len == panel,
                        "fetch-set reference size mismatch in shard frame"
                    );
                    ensure!(
                        r.off.checked_add(r.len).is_some_and(|end| end <= buf.len()),
                        "operand reference outside its cached buffer"
                    );
                    views.push(OperandView::new(buf, r.off, r.len));
                }
                let b_tiles = views.pop().expect("two views");
                let a_tiles = views.pop().expect("two views");
                let job = Job {
                    desc,
                    kind: JobKind::ConvTileQ8 {
                        a_tiles,
                        b_tiles,
                        scale,
                    },
                    placement: None,
                };
                if !run(&job, transport, &mut served)? {
                    return Ok(served);
                }
            }
            wire::ShardFrame::Job(job) => {
                if !run(&job, transport, &mut served)? {
                    return Ok(served);
                }
            }
        }
    }
}

/// [`serve_shard_transport`] with a private per-connection cache and no
/// advertised rate — the shape in-process tests and single-connection
/// tools use.  `ShardServer` passes its shared cache instead.
pub fn serve_transport(
    transport: &mut dyn ShardTransport,
    exec: impl FnMut(&Job) -> Result<JobResult>,
) -> Result<u64> {
    let cache = ShardCache::with_capacity_mb(64);
    serve_shard_transport(transport, &cache, 0.0, exec)
}

/// One health/RTT ping over `transport`: returns the measured round trip
/// in seconds plus the shard's self-reported `(rate_ksteps, served)`.
/// Used by the pool's prober thread over its own connection.
pub fn probe_shard(transport: &mut dyn ShardTransport, seq: u64) -> Result<(f64, f64, u64)> {
    let start = std::time::Instant::now();
    transport.send(&wire::encode_probe(seq))?;
    let frame = transport.recv()?;
    let rtt = start.elapsed().as_secs_f64();
    match wire::decode_reply(&frame)? {
        wire::ShardReply::ProbeAck {
            seq: echoed,
            rate_ksteps,
            served,
        } => {
            ensure!(echoed == seq, "probe ack echoed {echoed}, expected {seq}");
            Ok((rtt, rate_ksteps, served))
        }
        _ => bail!("shard answered a probe with a non-ack frame"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mm::job::{jobs_for_gemm, jobs_from_packs_q8};
    use crate::util::rng::XorShift64Star;
    use std::sync::Arc;

    fn sample_jobs() -> Vec<Job> {
        let mut jobs = Vec::new();
        let grid = TileGrid::new(40, 50, 60, 32);
        let a = Arc::new(XorShift64Star::new(1).fill_f32(40 * 50, 1.0));
        let b = Arc::new(XorShift64Star::new(2).fill_f32(50 * 60, 1.0));
        let mut id = 0;
        jobs.extend(jobs_for_gemm(3, 7, grid, a, b, &mut id));
        let w = Arc::new(XorShift64Star::new(3).fill_f32(16 * 24, 1.0));
        let x = Arc::new(XorShift64Star::new(4).fill_f32(24, 1.0));
        jobs.push(Job::fc(id, 1, 2, 16, 24, w, x, 32));
        id += 1;
        let wb = Arc::new(XorShift64Star::new(5).fill_f32(16 * 24, 1.0));
        let xb = Arc::new(XorShift64Star::new(6).fill_f32(24 * 3, 1.0));
        jobs.push(Job::fc_batch(id, 1, 2, 16, 24, 3, wb, xb, 32));
        id += 1;
        let input = Arc::new(XorShift64Star::new(7).fill_f32(3 * 8 * 8, 1.0));
        jobs.push(Job::im2col(id, 0, 4, (3, 8, 8), 3, 1, 1, input, 32));
        jobs
    }

    fn codes(seed: u64, n: usize) -> Vec<i8> {
        XorShift64Star::new(seed)
            .fill_f32(n, 1.0)
            .iter()
            .map(|&v| (v * 127.0).round().clamp(-127.0, 127.0) as i8)
            .collect()
    }

    /// One job per quantized class — including the single-column FC the
    /// capability mask keeps local, because the codec is total over
    /// [`JobKind`] even where routing never ships a class.
    fn sample_q8_jobs() -> Vec<Job> {
        let mut jobs = Vec::new();
        let grid = TileGrid::new(40, 50, 60, 32);
        let panel = grid.panel_elems();
        let a = codes(31, grid.rows() * panel);
        let b = codes(32, grid.cols() * panel);
        let mut id = 0;
        jobs.extend(jobs_from_packs_q8(
            3,
            7,
            grid,
            a.into(),
            b.into(),
            0.02,
            &mut id,
        ));
        jobs.push(Job::fc_q8(
            id,
            1,
            2,
            16,
            24,
            codes(33, 16 * 24),
            codes(34, 24),
            0.05,
            32,
        ));
        id += 1;
        jobs.push(Job::fc_batch_q8(
            id,
            1,
            2,
            16,
            24,
            3,
            codes(35, 16 * 24),
            codes(36, 24 * 3),
            0.05,
            32,
        ));
        jobs
    }

    #[test]
    fn wire_round_trips_every_job_class_bitwise() {
        for job in sample_jobs() {
            let decoded = wire::decode_job(&wire::encode_job(&job)).unwrap();
            assert_eq!(decoded.desc, job.desc);
            assert_eq!(decoded.class(), job.class());
            // Executing the decoded job is bit-identical to executing the
            // original — the remote-execution fidelity contract.
            let local = job.execute_native();
            let shipped = decoded.execute_native();
            assert_eq!(local.data, shipped.data, "{:?}", job.class());

            let result = wire::decode_result(&wire::encode_result(&local)).unwrap();
            assert_eq!(result.desc, local.desc);
            assert_eq!(result.data, local.data);
        }
    }

    #[test]
    fn wire_round_trips_q8_jobs_bitwise() {
        for job in sample_q8_jobs() {
            let decoded = wire::decode_job(&wire::encode_job(&job)).unwrap();
            assert_eq!(decoded.desc, job.desc);
            assert_eq!(decoded.class(), job.class());
            let local = job.execute_native();
            let shipped = decoded.execute_native();
            assert_eq!(local.data, shipped.data, "{:?}", job.class());
            let result = wire::decode_result(&wire::encode_result(&local)).unwrap();
            assert_eq!(result.data, local.data);
        }
    }

    #[test]
    fn q8_conv_frame_ships_one_byte_per_code() {
        // An inline quantized CONV tile carries the same panel *geometry*
        // as its f32 twin but one byte per element plus the 4-byte scale:
        // tag + descriptor + scale + two length-prefixed i8 runs.
        for job in sample_q8_jobs()
            .into_iter()
            .filter(|j| j.class() == JobClass::ConvTileQ8)
        {
            let panel = job.desc.k_tiles() * job.desc.grid.ts * job.desc.grid.ts;
            let want = 1 + wire::DESC_BYTES + 4 + 2 * (8 + panel);
            assert_eq!(wire::encode_job(&job).len(), want);
        }
    }

    #[test]
    fn conv_tile_frame_is_exactly_the_packed_fetch_set() {
        // Ragged border tiles included: 40×50×60 at ts=32 has partial
        // tiles on every edge — every tile still ships the same padded
        // (K·TS·TS)-element panels, so every frame has the same exact
        // size: tag + descriptor + two length-prefixed operand runs.  No
        // intermediate staging buffer can inflate this without failing
        // the equality.
        for job in sample_jobs()
            .into_iter()
            .filter(|j| j.class() == JobClass::ConvTile)
        {
            let panel = job.desc.k_tiles() * job.desc.grid.ts * job.desc.grid.ts;
            let want = 1 + wire::DESC_BYTES + 2 * (8 + 4 * panel);
            assert_eq!(
                wire::encode_job(&job).len(),
                want,
                "tile ({}, {})",
                job.desc.t1,
                job.desc.t2
            );
        }
    }

    #[test]
    fn wire_rejects_corrupt_frames() {
        let jobs = sample_jobs();
        let frame = wire::encode_job(&jobs[0]);
        // Truncations at every prefix length must error, never panic.
        for cut in 0..frame.len().min(64) {
            assert!(wire::decode_job(&frame[..cut]).is_err(), "cut {cut}");
        }
        // Trailing garbage is rejected.
        let mut padded = frame.clone();
        padded.push(0);
        assert!(wire::decode_job(&padded).is_err());
        // Unknown tags, statuses, and error frames decode as errors.
        assert!(wire::decode_job(&[99]).is_err());
        assert!(wire::decode_result(&[7]).is_err());
        let err = wire::decode_result(&wire::encode_error("kernel fault"))
            .expect_err("error frame must surface");
        assert!(err.to_string().contains("kernel fault"), "{err}");
    }

    #[test]
    fn duplex_shard_executes_jobs_and_dies_cleanly() {
        let (client, mut server) = duplex_pair();
        let shard_thread = std::thread::spawn(move || {
            serve_transport(&mut server, |job| Ok(job.execute_native())).unwrap()
        });
        let mut shard = RemoteShard::over_duplex("remote:test", client);
        assert!(shard.supports(JobClass::ConvTile));
        assert!(shard.supports(JobClass::FcGemmBatch));
        assert!(!shard.supports(JobClass::FcGemm));
        assert!(!shard.supports(JobClass::Im2col));
        let jobs = sample_jobs();
        for job in &jobs {
            let got = shard.execute(job).unwrap();
            let want = job.execute_native();
            assert_eq!(got.data, want.data, "{:?}", job.class());
            // Round-trip-inclusive cost: overhead + k-steps, matching the
            // registered metadata.
            assert_eq!(
                shard.cost(job),
                REMOTE_OVERHEAD_KSTEPS + job.ksteps() as f64
            );
        }
        drop(shard); // closes the client end → the server loop returns
        assert_eq!(shard_thread.join().unwrap(), jobs.len() as u64);
    }

    #[test]
    fn dropped_transport_surfaces_as_execute_error() {
        let (client, server) = duplex_pair();
        drop(server);
        let mut shard = RemoteShard::over_duplex("remote:dead", client);
        let jobs = sample_jobs();
        let err = shard.execute(&jobs[0]).expect_err("dead link must error");
        assert!(err.to_string().contains("job 0"), "{err}");
    }

    #[test]
    fn shard_exec_error_reaches_the_client_in_band() {
        let (client, mut server) = duplex_pair();
        let shard_thread = std::thread::spawn(move || {
            serve_transport(&mut server, |_| anyhow::bail!("injected shard fault"))
        });
        let mut shard = RemoteShard::over_duplex("remote:faulty", client);
        let err = shard
            .execute(&sample_jobs()[0])
            .expect_err("shard fault must propagate");
        assert!(err.to_string().contains("injected shard fault"), "{err}");
        assert!(shard_thread.join().unwrap().is_err());
    }

    #[test]
    fn tcp_transport_frames_round_trip() {
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let echo = std::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            let mut t = TcpTransport::from_stream(stream);
            // Echo two frames back, then hang up.
            for _ in 0..2 {
                let frame = t.recv().unwrap();
                t.send(&frame).unwrap();
            }
        });
        let mut t = TcpTransport::connect(&addr.to_string()).unwrap();
        t.send(b"hello shard").unwrap();
        assert_eq!(t.recv().unwrap(), b"hello shard");
        t.send(&[]).unwrap(); // empty frames are legal
        assert_eq!(t.recv().unwrap(), Vec::<u8>::new());
        echo.join().unwrap();
        // The peer hung up: the next receive errors instead of blocking.
        assert!(t.recv().is_err());
    }

    #[test]
    fn register_config_shards_names_every_remote_member() {
        let text = "
[device]
tile_size = 32
[pe_type]
name = F-PE
[cluster]
name = c0
neon = 1
remote = 10.0.0.7:9000
[cluster]
name = c1
pe = F-PE:1
remote = 10.0.0.8:9000
[memory]
mmus = 1
";
        let hw = HwConfig::parse("t", text).unwrap();
        let mut reg = BackendRegistry::new();
        register_config_shards(&mut reg, &hw);
        for addr in ["10.0.0.7:9000", "10.0.0.8:9000"] {
            let entry = reg
                .get(&shard_backend_name(addr))
                .unwrap_or_else(|| panic!("missing shard entry for {addr}"));
            assert_eq!(entry.caps, remote_class_mask());
            assert_eq!(entry.overhead_ksteps(), REMOTE_OVERHEAD_KSTEPS);
            assert!(entry.link().is_alive());
        }
        // The builder dials lazily: registration itself needs no listener.
        assert_eq!(reg.names().len(), 2);
    }

    #[test]
    fn ref_frames_are_descriptor_sized_and_round_trip() {
        let desc = JobDesc {
            job_id: 42,
            layer_id: 3,
            frame_id: 7,
            t1: 1,
            t2: 1,
            grid: TileGrid::new(40, 50, 60, 32),
        };
        let a = wire::KeyRef {
            key: (11, 22),
            off: 2048,
            len: 2048,
        };
        let b = wire::KeyRef {
            key: (11, 23),
            off: 0,
            len: 2048,
        };
        let frame = wire::encode_conv_tile_ref(&desc, a, b);
        // The whole point: a cached CONV tile costs a fixed 137 bytes on
        // the wire, independent of the panels it references.
        assert_eq!(frame.len(), wire::REF_FRAME_BYTES);
        assert_eq!(wire::REF_FRAME_BYTES, 137);
        match wire::decode_shard_frame(&frame).unwrap() {
            wire::ShardFrame::ConvTileRef {
                desc: d,
                a: da,
                b: db,
            } => {
                assert_eq!(d, desc);
                assert_eq!(da, a);
                assert_eq!(db, b);
            }
            _ => panic!("REF frame decoded as a different kind"),
        }
        // Truncations error cleanly, like every other frame kind.
        for cut in 0..frame.len() {
            assert!(wire::decode_shard_frame(&frame[..cut]).is_err(), "cut {cut}");
        }
    }

    #[test]
    fn q8_ref_frames_are_fixed_size_and_round_trip() {
        let desc = JobDesc {
            job_id: 42,
            layer_id: 3,
            frame_id: 7,
            t1: 1,
            t2: 1,
            grid: TileGrid::new(40, 50, 60, 32),
        };
        let a = wire::KeyRef {
            key: (11, 22),
            off: 2048,
            len: 2048,
        };
        let b = wire::KeyRef {
            key: (11, 23),
            off: 0,
            len: 2048,
        };
        let frame = wire::encode_conv_tile_q8_ref(&desc, 0.125, a, b);
        // A cached quantized CONV tile costs a fixed 141 bytes on the
        // wire — the f32 REF plus the 4-byte dequantization scale.
        assert_eq!(frame.len(), wire::Q8_REF_FRAME_BYTES);
        assert_eq!(wire::Q8_REF_FRAME_BYTES, 141);
        match wire::decode_shard_frame(&frame).unwrap() {
            wire::ShardFrame::ConvTileQ8Ref {
                desc: d,
                scale,
                a: da,
                b: db,
            } => {
                assert_eq!(d, desc);
                assert_eq!(scale, 0.125);
                assert_eq!(da, a);
                assert_eq!(db, b);
            }
            _ => panic!("Q8 REF frame decoded as a different kind"),
        }
        for cut in 0..frame.len() {
            assert!(wire::decode_shard_frame(&frame[..cut]).is_err(), "cut {cut}");
        }
    }

    #[test]
    fn shard_cache_lru_evicts_but_keeps_a_working_pair() {
        let cache = ShardCache::with_capacity_elems(100);
        cache.put((1, 1), vec![1.0; 60]);
        cache.put((1, 2), vec![2.0; 60]);
        // Over capacity but only two entries: the working pair survives.
        assert_eq!(cache.stats().entries, 2);
        assert!(cache.get((1, 1)).is_some());
        assert!(cache.get((1, 2)).is_some());
        // A third buffer evicts the LRU — (1,1) was touched before (1,2).
        cache.put((1, 3), vec![3.0; 60]);
        let stats = cache.stats();
        assert_eq!(stats.entries, 2);
        assert_eq!(stats.evictions, 1);
        assert!(cache.get((1, 1)).is_none(), "LRU entry evicted");
        assert_eq!(cache.get((1, 3)).unwrap()[0], 3.0);
        // Explicit invalidation removes without counting an eviction.
        cache.remove((1, 3));
        assert!(cache.get((1, 3)).is_none());
        assert_eq!(cache.stats().evictions, 1);
        let stats = cache.stats();
        assert!(stats.hits >= 3 && stats.misses >= 2, "{stats:?}");
    }

    #[test]
    fn cached_conv_ships_each_panel_once_with_exact_wire_bytes() {
        let (client, mut server) = duplex_pair();
        let shard_thread = std::thread::spawn(move || {
            serve_transport(&mut server, |job| Ok(job.execute_native())).unwrap()
        });
        let mut shard = RemoteShard::over_duplex("remote:cache", client);
        let conv: Vec<Job> = sample_jobs()
            .into_iter()
            .filter(|j| j.class() == JobClass::ConvTile)
            .collect();
        assert_eq!(conv.len(), 4, "40x50x60 at ts=32 is a 2x2 tile grid");
        for job in &conv {
            let got = shard.execute(job).unwrap();
            assert_eq!(got.data, job.execute_native().data);
        }
        let stats = shard.cache_stats();
        assert_eq!(stats.puts, 2, "one A pack + one B pack, shipped once");
        assert_eq!(stats.refs, 4);
        assert_eq!(stats.drops, 0);
        assert_eq!(stats.misses, 0);
        // Exact ledger: 2 PUTs carrying the packs, 4 fixed-size REFs, 4
        // result frames — nothing else.
        let pack = 2 * 2 * 32 * 32; // m_tiles(p_tiles) × k_tiles × ts²
        let put = 1 + wire::KEY_BYTES + 8 + 4 * pack;
        let result = 1 + wire::DESC_BYTES + 8 + 4 * 32 * 32;
        let want = 2 * put + 4 * wire::REF_FRAME_BYTES + 4 * result;
        assert_eq!(shard.wire_bytes(), want as u64);
        drop(shard);
        assert_eq!(shard_thread.join().unwrap(), 4);
    }

    #[test]
    fn cached_q8_conv_ships_i8_planes_once_with_exact_wire_bytes() {
        let (client, mut server) = duplex_pair();
        let shard_thread = std::thread::spawn(move || {
            serve_transport(&mut server, |job| Ok(job.execute_native())).unwrap()
        });
        let mut shard = RemoteShard::over_duplex("remote:q8-cache", client);
        assert!(shard.supports(JobClass::ConvTileQ8));
        assert!(shard.supports(JobClass::FcGemmBatchQ8));
        assert!(!shard.supports(JobClass::FcGemmQ8), "single-column q8 FC stays local");
        let conv: Vec<Job> = sample_q8_jobs()
            .into_iter()
            .filter(|j| j.class() == JobClass::ConvTileQ8)
            .collect();
        assert_eq!(conv.len(), 4, "40x50x60 at ts=32 is a 2x2 tile grid");
        for job in &conv {
            let got = shard.execute(job).unwrap();
            assert_eq!(got.data, job.execute_native().data);
        }
        let stats = shard.cache_stats();
        assert_eq!(stats.puts, 2, "one A plane + one B plane, shipped once");
        assert_eq!(stats.refs, 4);
        assert_eq!(stats.misses, 0);
        // Exact ledger: 2 i8 PUTs at one byte per code, 4 fixed-size Q8
        // REFs, 4 f32 result frames — nothing else.
        let pack = 2 * 2 * 32 * 32; // m_tiles(p_tiles) × k_tiles × ts²
        let put = 1 + wire::KEY_BYTES + 8 + pack;
        let result = 1 + wire::DESC_BYTES + 8 + 4 * 32 * 32;
        let want = 2 * put + 4 * wire::Q8_REF_FRAME_BYTES + 4 * result;
        assert_eq!(shard.wire_bytes(), want as u64);
        // The int8 PUT saves exactly three bytes per element over its f32
        // twin — the 4× operand-plane shrink the ledger rows pin.
        let f32_put = 1 + wire::KEY_BYTES + 8 + 4 * pack;
        assert_eq!(f32_put - put, 3 * pack);
        drop(shard);
        assert_eq!(shard_thread.join().unwrap(), 4);
    }

    #[test]
    fn q8_cache_miss_reships_and_stays_bit_identical() {
        let (client, mut server) = duplex_pair();
        // 1500 f32-equivalent elements hold one layer's i8 planes
        // (2 × 4096 bytes = 2048 equivalents) only via the keep-a-pair
        // floor, so the second layer's PUTs evict the first's — re-running
        // layer 0 exercises the q8 miss → re-PUT(i8) → retry recovery.
        let cache = ShardCache::with_capacity_elems(1500);
        let server_cache = Arc::clone(&cache);
        let shard_thread = std::thread::spawn(move || {
            serve_shard_transport(&mut server, &server_cache, 0.0, |job| {
                Ok(job.execute_native())
            })
            .unwrap()
        });
        let grid = TileGrid::new(40, 50, 60, 32);
        let panel = grid.panel_elems();
        let mut id = 0;
        let layer0 = jobs_from_packs_q8(
            0,
            1,
            grid,
            codes(41, grid.rows() * panel).into(),
            codes(42, grid.cols() * panel).into(),
            0.02,
            &mut id,
        );
        let layer1 = jobs_from_packs_q8(
            1,
            1,
            grid,
            codes(43, grid.rows() * panel).into(),
            codes(44, grid.cols() * panel).into(),
            0.03,
            &mut id,
        );
        let mut shard = RemoteShard::over_duplex("remote:q8-tiny-cache", client);
        let mut served = 0u64;
        for round in [&layer0, &layer1, &layer0, &layer1] {
            for job in round {
                let got = shard.execute(job).unwrap();
                assert_eq!(got.data, job.execute_native().data, "job {}", job.desc.job_id);
                served += 1;
            }
        }
        let stats = shard.cache_stats();
        assert!(stats.misses > 0, "tiny cache must force at least one miss");
        assert!(
            stats.puts > 4,
            "misses re-ship planes beyond the initial four: {stats:?}"
        );
        assert!(cache.stats().evictions > 0);
        drop(shard);
        assert_eq!(shard_thread.join().unwrap(), served);
    }

    #[test]
    fn duplex_shard_executes_inline_q8_jobs() {
        let (client, mut server) = duplex_pair();
        let shard_thread = std::thread::spawn(move || {
            serve_transport(&mut server, |job| Ok(job.execute_native())).unwrap()
        });
        let mut shard = RemoteShard::over_duplex("remote:q8-inline", client);
        // The fused q8 batch ships as an inline job frame (its activation
        // pack is fresh per micro-batch, so it skips the operand cache);
        // the single-column q8 FC also round-trips — the codec is total,
        // capability masks are what keep it local in production.
        let q8: Vec<Job> = sample_q8_jobs()
            .into_iter()
            .filter(|j| j.class() != JobClass::ConvTileQ8)
            .collect();
        assert_eq!(q8.len(), 2);
        for job in &q8 {
            let got = shard.execute(job).unwrap();
            assert_eq!(got.data, job.execute_native().data, "{:?}", job.class());
            assert_eq!(
                shard.cost(job),
                REMOTE_OVERHEAD_KSTEPS + job.ksteps() as f64
            );
        }
        drop(shard);
        assert_eq!(shard_thread.join().unwrap(), 2);
    }

    #[test]
    fn cache_miss_reships_and_stays_bit_identical() {
        let (client, mut server) = duplex_pair();
        // A cache smaller than two layers' packs: layer 1's PUTs evict
        // layer 0's, so re-running layer 0 exercises the full
        // miss → re-PUT → retry recovery.
        let cache = ShardCache::with_capacity_elems(4096);
        let server_cache = Arc::clone(&cache);
        let shard_thread = std::thread::spawn(move || {
            serve_shard_transport(&mut server, &server_cache, 0.0, |job| {
                Ok(job.execute_native())
            })
            .unwrap()
        });
        let grid = TileGrid::new(40, 50, 60, 32);
        let a0 = Arc::new(XorShift64Star::new(11).fill_f32(40 * 50, 1.0));
        let b0 = Arc::new(XorShift64Star::new(12).fill_f32(50 * 60, 1.0));
        let a1 = Arc::new(XorShift64Star::new(13).fill_f32(40 * 50, 1.0));
        let b1 = Arc::new(XorShift64Star::new(14).fill_f32(50 * 60, 1.0));
        let mut id = 0;
        let layer0 = jobs_for_gemm(0, 1, grid, a0, b0, &mut id);
        let layer1 = jobs_for_gemm(1, 1, grid, a1, b1, &mut id);
        let mut shard = RemoteShard::over_duplex("remote:tiny-cache", client);
        let mut served = 0u64;
        for round in [&layer0, &layer1, &layer0, &layer1] {
            for job in round {
                let got = shard.execute(job).unwrap();
                assert_eq!(got.data, job.execute_native().data, "job {}", job.desc.job_id);
                served += 1;
            }
        }
        let stats = shard.cache_stats();
        assert!(stats.misses > 0, "tiny cache must force at least one miss");
        assert!(
            stats.puts > 4,
            "misses re-ship panels beyond the initial four: {stats:?}"
        );
        assert!(cache.stats().evictions > 0);
        drop(shard);
        assert_eq!(shard_thread.join().unwrap(), served);
    }

    #[test]
    fn pack_generation_bump_drops_and_reships_once() {
        let (client, mut server) = duplex_pair();
        let shard_thread = std::thread::spawn(move || {
            serve_transport(&mut server, |job| Ok(job.execute_native())).unwrap()
        });
        let mut shard = RemoteShard::over_duplex("remote:repack", client);
        let grid = TileGrid::new(40, 50, 60, 32);
        let a = Arc::new(XorShift64Star::new(21).fill_f32(40 * 50, 1.0));
        let b = Arc::new(XorShift64Star::new(22).fill_f32(50 * 60, 1.0));
        let mut id = 0;
        let gen0 = jobs_for_gemm(5, 1, grid, Arc::clone(&a), Arc::clone(&b), &mut id);
        // Same layer, same bytes, fresh allocations: a pack-generation
        // bump as the runtime produces one (repack → new Arc identity).
        let gen1 = jobs_for_gemm(5, 2, grid, a, b, &mut id);
        let mut served = 0u64;
        for job in gen0.iter().chain(&gen0) {
            shard.execute(job).unwrap();
            served += 1;
        }
        let before = shard.cache_stats();
        assert_eq!((before.puts, before.drops), (2, 0));
        for job in gen1.iter().chain(&gen1) {
            let got = shard.execute(job).unwrap();
            assert_eq!(got.data, job.execute_native().data);
            served += 1;
        }
        let after = shard.cache_stats();
        // Each changed slot invalidates its old key and re-ships exactly
        // once; re-running gen1 adds nothing.
        assert_eq!((after.puts, after.drops), (4, 2), "{after:?}");
        assert_eq!(after.misses, 0);
        drop(shard);
        assert_eq!(shard_thread.join().unwrap(), served);
    }

    #[test]
    fn probe_round_trip_reports_rate_and_served() {
        let (mut client, mut server) = duplex_pair();
        let cache = ShardCache::with_capacity_mb(1);
        let shard_thread = std::thread::spawn(move || {
            serve_shard_transport(&mut server, &cache, 321.5, |job| Ok(job.execute_native()))
                .unwrap()
        });
        let (rtt, rate, served) = probe_shard(&mut client, 9).unwrap();
        assert!(rtt >= 0.0 && rtt.is_finite());
        assert_eq!(rate, 321.5);
        assert_eq!(served, 0);
        // Executed jobs move the served counter the next ack reports.
        let job = &sample_jobs()[0];
        client.send(&wire::encode_job(job)).unwrap();
        let reply = client.recv().unwrap();
        assert!(matches!(
            wire::decode_reply(&reply).unwrap(),
            wire::ShardReply::Result(_)
        ));
        let (_, _, served) = probe_shard(&mut client, 10).unwrap();
        assert_eq!(served, 1);
        drop(client);
        assert_eq!(shard_thread.join().unwrap(), 1);
    }
}
