//! The unified execution-backend abstraction (paper §3.1 "unified
//! abstraction of heterogeneous accelerators").
//!
//! Every delegate thread drives one [`Accelerator`] — an object-safe trait
//! whose implementors execute pool [`Job`]s and advertise capability
//! ([`Accelerator::supports`]) and cost ([`Accelerator::cost`]) metadata.
//! Three backends ship in-tree:
//!
//! * [`NativeGemm`] — the blocked-GEMM "NEON" software accelerator;
//! * [`BigNeonGemm`] — a multi-threaded tiled-SIMD GEMM modelling a
//!   big-core NEON cluster, fanning each job's output rows across a
//!   **persistent worker team** built once per delegate;
//! * `PjrtPe` — the FPGA PE path: the AOT Pallas job kernel through PJRT
//!   (compiled under the `pjrt` cargo feature; without it the registry
//!   entry falls back to [`NativeGemm`]).
//!
//! Backends are looked up by name in a [`BackendRegistry`], keyed from the
//! `[cluster]` sections of the hardware config: each cluster member's
//! accelerator class resolves to a registry key
//! (see `rt::pool`), so a future backend (GPU, remote shard) plugs in by
//! registering a name — no driver rewrite.  Registration goes through ONE
//! surface — a [`BackendSpec`] built from the backend's name and builder,
//! with capability mask, fixed overhead, per-class steal costs, and Q8
//! (int8) capability layered on as builder methods.

use std::path::PathBuf;
use std::sync::mpsc;
use std::sync::Arc;

use anyhow::Result;

use crate::mm::job::{ClassMask, Job, JobClass, JobKind, JobResult};
use crate::mm::OperandView;

/// An execution backend a delegate thread drives.  Object-safe so the pool
/// holds `Box<dyn Accelerator>` uniformly; implementors need not be `Send`
/// (each is built *inside* its delegate thread — the PJRT engine is
/// `Rc`-backed, and hardware-wise each PE is its own kernel instance).
pub trait Accelerator {
    /// Registry key / display name, e.g. "neon" or "pjrt-pe".
    fn id(&self) -> &str;

    /// Can this backend execute jobs of `class`?
    fn supports(&self, class: JobClass) -> bool;

    /// Relative service-cost estimate for `job` (k-steps scaled by the
    /// backend's parallelism; comparable across backends of one pool).
    ///
    /// The statically-known component of this estimate — the fixed
    /// per-job overhead in k-step equivalents — is ALSO registered as
    /// [`BackendEntry::overhead_ksteps`] (declared with
    /// [`BackendSpec::overhead_ksteps`]), and that metadata IS
    /// consumed: the dispatcher adds it to a cluster's routing load so
    /// small jobs stay on zero-overhead local members, and the thief's
    /// ship gate refuses to move backlogs that drain faster than they
    /// ship (`rt::pool::ClusterRoute`, `sched::worksteal`).  Implementors
    /// with a fixed overhead (e.g. a remote shard's transport round trip)
    /// must report the same constant both places; the per-job method here
    /// additionally scales with the job's size.
    fn cost(&self, job: &Job) -> f64 {
        job.ksteps() as f64
    }

    /// Execute one job.  Errors are fatal to the delegate (a backend that
    /// cannot compute is a broken accelerator, not a scheduling event).
    fn execute(&mut self, job: &Job) -> Result<JobResult>;
}

/// The native blocked-GEMM software accelerator (the paper's NEON path).
pub struct NativeGemm;

impl Accelerator for NativeGemm {
    fn id(&self) -> &str {
        "neon"
    }

    fn supports(&self, _class: JobClass) -> bool {
        true
    }

    fn execute(&mut self, job: &Job) -> Result<JobResult> {
        Ok(job.execute_native())
    }
}

/// A big-core NEON cluster: `threads` cores running the row-chunked
/// multi-threaded tiled-SIMD GEMM.  GEMM work — whole-matrix FC jobs,
/// fused batched-FC jobs, and CONV tiles alike — fans its output rows
/// across the cores (keeping the backend consistent with
/// `PerfModel::big_neon`'s thread-scaled rate); im2col is pure data
/// movement and runs on one core.
///
/// The fan-out runs on a **persistent worker team** built once per
/// delegate (`threads − 1` parked worker threads plus the delegate thread
/// itself as worker 0): each job sends the workers a row-range work
/// descriptor over their channels and gathers the finished chunks, so the
/// per-job cost is a channel hop instead of the old scoped spawn+join.
/// That is why the old `MT_MIN_MACS` fan-out threshold is gone — even a
/// modest fused FC batch fans out profitably.
pub struct BigNeonGemm {
    threads: usize,
    workers: Vec<TeamWorker>,
}

/// One parked team member: its work-order channel and join handle.
struct TeamWorker {
    orders: mpsc::Sender<WorkOrder>,
    handle: std::thread::JoinHandle<()>,
}

/// A work order: the descriptor plus the channel the finished chunk goes
/// back on.
struct WorkOrder {
    desc: WorkDesc,
    done: mpsc::Sender<(usize, Vec<f32>)>,
}

/// One worker's share of a fanned-out job: a contiguous output-row range.
/// Operands ride as [`OperandView`]s — refcounted windows shared with the
/// job and the other workers, so fanning a job out moves zero operand
/// bytes; every chunk runs the same [`gemm_blocked_into`] kernel over its
/// rows, so per-row accumulation order — and therefore the f32 result —
/// is identical to the single-core path regardless of the split.
///
/// [`gemm_blocked_into`]: crate::mm::gemm::gemm_blocked_into
enum WorkDesc {
    /// Rows `row0..row0+rows` of C(M,P) = A(M,N)·B(N,P).
    Rows {
        a: OperandView,
        b: OperandView,
        row0: usize,
        rows: usize,
        n: usize,
        p: usize,
        chunk: usize,
    },
    /// Rows `row0..row0+rows` of a CONV output tile over packed (K,TS,TS)
    /// operands, accumulating across the K inner tiles.
    TileRows {
        at: OperandView,
        bt: OperandView,
        k_tiles: usize,
        ts: usize,
        row0: usize,
        rows: usize,
        chunk: usize,
    },
}

/// Execute one work descriptor (runs on a worker or the delegate thread).
fn run_order(desc: &WorkDesc) -> (usize, Vec<f32>) {
    match desc {
        WorkDesc::Rows {
            a,
            b,
            row0,
            rows,
            n,
            p,
            chunk,
        } => {
            let mut c = vec![0.0f32; rows * p];
            crate::mm::gemm::gemm_blocked_into(
                &a[row0 * n..(row0 + rows) * n],
                b,
                &mut c,
                *rows,
                *n,
                *p,
            );
            (*chunk, c)
        }
        WorkDesc::TileRows {
            at,
            bt,
            k_tiles,
            ts,
            row0,
            rows,
            chunk,
        } => {
            let mut c = vec![0.0f32; rows * ts];
            for kt in 0..*k_tiles {
                let tile = kt * ts * ts;
                crate::mm::gemm::gemm_blocked_into(
                    &at[tile + row0 * ts..tile + (row0 + rows) * ts],
                    &bt[tile..tile + ts * ts],
                    &mut c,
                    *rows,
                    *ts,
                    *ts,
                );
            }
            (*chunk, c)
        }
    }
}

impl BigNeonGemm {
    /// Build the backend and its persistent team: `threads − 1` parked
    /// workers (the caller's thread is the team's worker 0).  Called from
    /// inside the delegate thread by the registry builder, so each
    /// delegate owns exactly one team for its lifetime.
    pub fn new(threads: usize) -> BigNeonGemm {
        let threads = threads.max(1);
        let workers = (1..threads)
            .map(|i| {
                let (orders, rx) = mpsc::channel::<WorkOrder>();
                let handle = std::thread::Builder::new()
                    .name(format!("big-neon-worker-{i}"))
                    .spawn(move || {
                        // Park on the channel until an order (or team
                        // teardown closes it).
                        while let Ok(order) = rx.recv() {
                            // The delegate may have given up on a job only
                            // at teardown; a dead reply side is fine.
                            let _ = order.done.send(run_order(&order.desc));
                        }
                    })
                    .expect("spawn big-neon worker");
                TeamWorker { orders, handle }
            })
            .collect();
        BigNeonGemm { threads, workers }
    }

    /// Team width (cores modelled).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Fan `m` output rows across the team and gather the (m,`p`) result:
    /// chunk 0 runs on the calling (delegate) thread while chunks 1..
    /// run on the parked workers.  `mk` builds the descriptor for one
    /// row range.
    fn run_fanned(
        &self,
        m: usize,
        p: usize,
        mk: impl Fn(usize, usize, usize) -> WorkDesc,
    ) -> Vec<f32> {
        let parts = self.threads.clamp(1, m.max(1));
        let rows_per = m.div_ceil(parts);
        let n_chunks = m.div_ceil(rows_per.max(1)).max(1);
        if n_chunks <= 1 || self.workers.is_empty() {
            return run_order(&mk(0, m, 0)).1;
        }
        let mut c = vec![0.0f32; m * p];
        let (done, done_rx) = mpsc::channel();
        // parts ≤ threads ⇒ n_chunks − 1 ≤ workers.len(): one chunk per
        // parked worker, no queuing behind a busy teammate.
        for chunk in 1..n_chunks {
            let row0 = chunk * rows_per;
            let rows = rows_per.min(m - row0);
            self.workers[chunk - 1]
                .orders
                .send(WorkOrder {
                    desc: mk(row0, rows, chunk),
                    done: done.clone(),
                })
                .expect("big-neon worker alive");
        }
        drop(done);
        // Worker 0 (this thread) computes the first chunk concurrently.
        let (_, first) = run_order(&mk(0, rows_per, 0));
        c[..first.len()].copy_from_slice(&first);
        for _ in 1..n_chunks {
            let (chunk, data) = done_rx.recv().expect("big-neon worker result");
            let off = chunk * rows_per * p;
            c[off..off + data.len()].copy_from_slice(&data);
        }
        c
    }
}

impl Drop for BigNeonGemm {
    fn drop(&mut self) {
        // Close each worker's order channel, then join it.
        for w in self.workers.drain(..) {
            drop(w.orders);
            let _ = w.handle.join();
        }
    }
}

impl Accelerator for BigNeonGemm {
    fn id(&self) -> &str {
        "big-neon"
    }

    fn supports(&self, _class: JobClass) -> bool {
        true
    }

    fn cost(&self, job: &Job) -> f64 {
        match job.class() {
            JobClass::FcGemm | JobClass::FcGemmBatch | JobClass::ConvTile => {
                job.ksteps() as f64 / self.threads.max(1) as f64
            }
            // Q8 jobs run the single-core integer kernel (already ~half
            // the k-steps of their f32 twins); im2col is data movement.
            JobClass::Im2col
            | JobClass::ConvTileQ8
            | JobClass::FcGemmQ8
            | JobClass::FcGemmBatchQ8 => job.ksteps() as f64,
        }
    }

    fn execute(&mut self, job: &Job) -> Result<JobResult> {
        let g = job.desc.grid;
        let data = match &job.kind {
            // Single-column FC, fused batched FC: fan the M output rows
            // across the team.
            JobKind::FcGemm { a, b } | JobKind::FcGemmBatch { a, b } => {
                let (a, b) = (a.clone(), b.clone());
                let (n, p) = (g.n, g.p);
                self.run_fanned(g.m, p, move |row0, rows, chunk| WorkDesc::Rows {
                    a: a.clone(),
                    b: b.clone(),
                    row0,
                    rows,
                    n,
                    p,
                    chunk,
                })
            }
            // CONV tile: fan the TS output rows, each chunk accumulating
            // over the K inner tiles.  The job already carries its packed
            // (K,TS,TS) fetch set as views — the old per-dispatch re-pack
            // is gone; workers alias the same backing buffers.
            JobKind::ConvTile { a_tiles, b_tiles } => {
                let (at, bt) = (a_tiles.clone(), b_tiles.clone());
                let (k_tiles, ts) = (job.desc.k_tiles(), g.ts);
                self.run_fanned(ts, ts, move |row0, rows, chunk| WorkDesc::TileRows {
                    at: at.clone(),
                    bt: bt.clone(),
                    k_tiles,
                    ts,
                    row0,
                    rows,
                    chunk,
                })
            }
            // im2col is pure data movement, and Q8 jobs run the integer
            // kernel single-core (matching `cost` above): one core each.
            JobKind::Im2col { .. }
            | JobKind::ConvTileQ8 { .. }
            | JobKind::FcGemmQ8 { .. }
            | JobKind::FcGemmBatchQ8 { .. } => return Ok(job.execute_native()),
        };
        Ok(JobResult {
            desc: job.desc,
            data,
        })
    }
}

/// The FPGA PE backend: the AOT Pallas job kernel executed through PJRT.
/// Only speaks CONV tiles — exactly what the hardware kernel computes.
#[cfg(feature = "pjrt")]
pub struct PjrtPe {
    engine: Box<crate::runtime::PeEngine>,
}

#[cfg(feature = "pjrt")]
impl PjrtPe {
    pub fn new(engine: crate::runtime::PeEngine) -> PjrtPe {
        PjrtPe {
            engine: Box::new(engine),
        }
    }
}

#[cfg(feature = "pjrt")]
impl Accelerator for PjrtPe {
    fn id(&self) -> &str {
        "pjrt-pe"
    }

    fn supports(&self, class: JobClass) -> bool {
        class == JobClass::ConvTile
    }

    fn execute(&mut self, job: &Job) -> Result<JobResult> {
        if job.class() != JobClass::ConvTile {
            anyhow::bail!("pjrt-pe cannot execute {} jobs", job.class().label());
        }
        let (at, bt) = job.tile_operands();
        let data = self.engine.execute_job(at, bt, job.desc.k_tiles())?;
        Ok(JobResult {
            desc: job.desc,
            data,
        })
    }
}

/// Shared constructor for registered backends.  `Fn` (not `FnOnce`): one
/// entry builds one backend instance per delegate thread.
pub type BackendBuilder = Arc<dyn Fn() -> Result<Box<dyn Accelerator>> + Send + Sync>;

/// Everything a backend declares about itself at registration — THE one
/// registration surface (the old `register`/`register_with_cost` split is
/// gone).  Build with [`BackendSpec::new`] (name + per-delegate builder),
/// then layer on metadata:
///
/// ```
/// # use synergy::accel::{Accelerator, BackendRegistry, BackendSpec, NativeGemm};
/// # use synergy::mm::{ClassMask, JobClass};
/// let mut reg = BackendRegistry::new();
/// reg.register(
///     BackendSpec::new("my-dsp", || Ok(Box::new(NativeGemm) as Box<dyn Accelerator>))
///         .caps(ClassMask::of(&[JobClass::ConvTile]))
///         .quantized(true)      // also claim the int8 twin classes
///         .overhead_ksteps(2.0) // fixed per-job shipping cost
/// );
/// ```
///
/// Defaults: all f32+Q8 classes ([`ClassMask::all`]), zero overhead, no
/// per-class steal-cost override.
pub struct BackendSpec {
    name: String,
    caps: ClassMask,
    overhead_ksteps: f64,
    class_cost: Option<[f64; JobClass::COUNT]>,
    builder: BackendBuilder,
}

impl BackendSpec {
    /// A spec for `name` with the given per-delegate builder and default
    /// metadata (every class, zero overhead, no cost override).
    pub fn new<F>(name: &str, builder: F) -> BackendSpec
    where
        F: Fn() -> Result<Box<dyn Accelerator>> + Send + Sync + 'static,
    {
        BackendSpec {
            name: name.to_string(),
            caps: ClassMask::all(),
            overhead_ksteps: 0.0,
            class_cost: None,
            builder: Arc::new(builder),
        }
    }

    /// Replace the capability mask (which [`JobClass`]es the backend's
    /// delegates accept; the pool routes and the thief filters on it).
    pub fn caps(mut self, caps: ClassMask) -> BackendSpec {
        self.caps = caps;
        self
    }

    /// Declare (or revoke) int8 capability: adds or strips the Q8 twin
    /// classes ([`ClassMask::Q8`]) from the capability mask without
    /// touching the f32 bits.  Apply AFTER [`BackendSpec::caps`].
    pub fn quantized(mut self, quantized: bool) -> BackendSpec {
        self.caps = if quantized {
            self.caps.union(ClassMask::Q8)
        } else {
            ClassMask::Q8.classes().fold(self.caps, |m, c| m.without(c))
        };
        self
    }

    /// Fixed per-job overhead in k-step equivalents (a remote shard's
    /// transport round trip).  Seeds the entry's live
    /// [`crate::accel::timing::LinkCost`] cell; measured probes refine it
    /// after the pool starts.
    pub fn overhead_ksteps(mut self, ksteps: f64) -> BackendSpec {
        self.overhead_ksteps = ksteps;
        self
    }

    /// Per-class steal-cost weights (k-steps per unit of
    /// [`Job::ksteps`]), indexed by [`JobClass::index`].  When any
    /// registered member of a pool provides this, the pool's thief prices
    /// victim backlogs with the element-wise MAX over the provided tables
    /// (conservative: never under-prices a steal) instead of the derived
    /// [`crate::sched::DEFAULT_CLASS_COST`].
    pub fn class_cost(mut self, cost: [f64; JobClass::COUNT]) -> BackendSpec {
        self.class_cost = Some(cost);
        self
    }
}

/// One registered backend: name, capability mask and live link-cost cell
/// (the mask and the cost's static seed are known *before* any instance
/// exists, so the pool can route and the thief can filter/gate), and the
/// per-delegate builder.
pub struct BackendEntry {
    name: String,
    pub caps: ClassMask,
    /// Live per-job cost cell, seeded with the registered static overhead
    /// in k-step equivalents of this backend's service rate — 0 for
    /// in-tree local backends, the transport round trip for a remote
    /// shard.  The pool's prober refines remote members' cells from
    /// measured RTTs (and flips them dead on failure); the dispatcher's
    /// routing penalty and the thief's ship gate read them live.
    link: Arc<crate::accel::timing::LinkCost>,
    /// Optional per-class steal-cost table ([`BackendSpec::class_cost`]).
    class_cost: Option<[f64; JobClass::COUNT]>,
    builder: BackendBuilder,
}

impl BackendEntry {
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Current fixed per-job overhead in k-step equivalents (the static
    /// seed until a probe lands; `f64::INFINITY` once the link is
    /// evicted).  Matches what the backend's [`Accelerator::cost`] reports
    /// as its constant term while the static seed holds.
    pub fn overhead_ksteps(&self) -> f64 {
        self.link.overhead_ksteps()
    }

    /// The live cost cell itself — shared with the pool's routes and the
    /// prober thread.
    pub fn link(&self) -> Arc<crate::accel::timing::LinkCost> {
        Arc::clone(&self.link)
    }

    /// Clone the builder handle (moved into a delegate thread).
    pub fn builder(&self) -> BackendBuilder {
        Arc::clone(&self.builder)
    }

    /// The registered per-class steal-cost table, if any.
    pub fn class_cost(&self) -> Option<[f64; JobClass::COUNT]> {
        self.class_cost
    }
}

/// Name-keyed backend registry.  [`BackendRegistry::with_defaults`]
/// registers the three in-tree backends; callers may register additional
/// ones (latest registration of a name wins) before starting a pool.
#[derive(Default)]
pub struct BackendRegistry {
    entries: Vec<BackendEntry>,
}

impl BackendRegistry {
    /// An empty registry.
    pub fn new() -> BackendRegistry {
        BackendRegistry::default()
    }

    /// The stock registry: "neon", "big-neon" (with `big_threads` cores),
    /// and "pjrt-pe" (loading AOT artifacts from `artifacts`; a native
    /// fallback when the `pjrt` feature is off — its capability mask stays
    /// conservative at CONV-tile-only either way, so routing decisions do
    /// not depend on the feature flag).
    pub fn with_defaults(artifacts: PathBuf, big_threads: usize) -> BackendRegistry {
        let mut reg = BackendRegistry::new();
        // NEON-class members claim everything — Q8 twins included (the
        // integer kernels run on the same SIMD units).
        reg.register(BackendSpec::new("neon", || {
            Ok(Box::new(NativeGemm) as Box<dyn Accelerator>)
        }));
        let threads = big_threads.max(1);
        reg.register(BackendSpec::new("big-neon", move || {
            // Builder runs inside the delegate thread: one persistent
            // worker team per delegate, alive for the delegate's lifetime.
            Ok(Box::new(BigNeonGemm::new(threads)) as Box<dyn Accelerator>)
        }));
        let art = artifacts;
        // The PE bitstream computes f32 CONV tiles and nothing else: no
        // FC, no im2col, and no Q8 — quantized nets route their Q8 work
        // to capable members or fall back to the dequantized f32 path.
        reg.register(
            BackendSpec::new("pjrt-pe", move || {
                #[cfg(feature = "pjrt")]
                {
                    use anyhow::Context;
                    let engine = crate::runtime::PeEngine::load(&art, None)
                        .context("loading PE engine (run `make artifacts`)")?;
                    Ok(Box::new(PjrtPe::new(engine)) as Box<dyn Accelerator>)
                }
                #[cfg(not(feature = "pjrt"))]
                {
                    // Native-GEMM fallback: the `pjrt` feature is off, so
                    // PE delegates compute natively.
                    let _ = &art;
                    Ok(Box::new(NativeGemm) as Box<dyn Accelerator>)
                }
            })
            .caps(ClassMask::of(&[JobClass::ConvTile])),
        );
        reg
    }

    /// Register (or replace — latest registration of a name wins) a
    /// backend from its [`BackendSpec`].
    pub fn register(&mut self, spec: BackendSpec) {
        self.entries.retain(|e| e.name != spec.name);
        self.entries.push(BackendEntry {
            name: spec.name,
            caps: spec.caps,
            link: crate::accel::timing::LinkCost::fixed(spec.overhead_ksteps),
            class_cost: spec.class_cost,
            builder: spec.builder,
        });
    }

    pub fn get(&self, name: &str) -> Option<&BackendEntry> {
        self.entries.iter().find(|e| e.name == name)
    }

    pub fn names(&self) -> Vec<&str> {
        self.entries.iter().map(|e| e.name.as_str()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mm::job::jobs_for_gemm;
    use crate::mm::TileGrid;
    use crate::util::rng::XorShift64Star;

    fn native_spec(name: &str) -> BackendSpec {
        BackendSpec::new(name, || Ok(Box::new(NativeGemm) as Box<dyn Accelerator>))
    }

    #[test]
    fn default_registry_has_all_three_backends() {
        let reg = BackendRegistry::with_defaults(PathBuf::from("/nonexistent"), 4);
        for name in ["neon", "big-neon", "pjrt-pe"] {
            assert!(reg.get(name).is_some(), "{name}");
        }
        assert!(reg.get("neon").unwrap().caps.supports(JobClass::FcGemm));
        assert!(!reg
            .get("pjrt-pe")
            .unwrap()
            .caps
            .supports(JobClass::FcGemm));
        assert!(reg.get("gpu").is_none());
        // Quantized capability per backend: NEON-class members claim the
        // Q8 twins, the PE (a f32 CONV bitstream) does not.
        for name in ["neon", "big-neon"] {
            let caps = reg.get(name).unwrap().caps;
            assert_eq!(caps.intersect(ClassMask::Q8), ClassMask::Q8, "{name}");
        }
        assert!(reg
            .get("pjrt-pe")
            .unwrap()
            .caps
            .intersect(ClassMask::Q8)
            .is_empty());
    }

    #[test]
    fn registration_latest_wins() {
        let mut reg = BackendRegistry::new();
        reg.register(native_spec("x"));
        reg.register(native_spec("x").caps(ClassMask::of(&[JobClass::Im2col])));
        assert_eq!(reg.names(), vec!["x"]);
        assert_eq!(reg.get("x").unwrap().caps, ClassMask::of(&[JobClass::Im2col]));
    }

    #[test]
    fn spec_builder_layers_metadata_over_defaults() {
        let mut reg = BackendRegistry::new();
        // Defaults: every class (Q8 included), zero overhead, no table.
        reg.register(native_spec("plain"));
        let entry = reg.get("plain").unwrap();
        assert_eq!(entry.caps, ClassMask::all());
        assert_eq!(entry.overhead_ksteps(), 0.0);
        assert!(entry.class_cost().is_none());

        // `.quantized(false)` strips exactly the Q8 bits; `.quantized
        // (true)` grafts them onto a restricted mask.
        reg.register(native_spec("no-q8").quantized(false));
        let caps = reg.get("no-q8").unwrap().caps;
        assert!(caps.intersect(ClassMask::Q8).is_empty());
        assert!(caps.supports(JobClass::ConvTile) && caps.supports(JobClass::Im2col));
        reg.register(
            native_spec("dsp")
                .caps(ClassMask::of(&[JobClass::ConvTile]))
                .quantized(true),
        );
        assert_eq!(
            reg.get("dsp").unwrap().caps,
            ClassMask::of(&[JobClass::ConvTile]).union(ClassMask::Q8)
        );

        // Cost table round-trips.
        let mut table = [1.0f64; JobClass::COUNT];
        table[JobClass::ConvTile.index()] = 9.0;
        reg.register(native_spec("priced").class_cost(table));
        assert_eq!(reg.get("priced").unwrap().class_cost(), Some(table));
    }

    #[test]
    fn overhead_metadata_defaults_to_zero_and_registers_explicitly() {
        let mut reg = BackendRegistry::with_defaults(PathBuf::from("/nonexistent"), 2);
        // Every in-tree backend is local: no fixed shipping overhead.
        for name in ["neon", "big-neon", "pjrt-pe"] {
            assert_eq!(reg.get(name).unwrap().overhead_ksteps(), 0.0, "{name}");
        }
        reg.register(native_spec("shippy").overhead_ksteps(12.5));
        let entry = reg.get("shippy").unwrap();
        assert_eq!(entry.overhead_ksteps(), 12.5);
        // The metadata is a live cell: eviction poisons the read cost.
        assert!(entry.link().is_alive());
        entry.link().evict();
        assert_eq!(entry.overhead_ksteps(), f64::INFINITY);
    }

    /// Q8 jobs through the big-NEON team: single-core integer kernel,
    /// bit-identical to native, and costed at plain k-steps (no thread
    /// scaling — there is no fan-out to pay for).
    #[test]
    fn big_neon_runs_q8_jobs_natively() {
        let mut big = BigNeonGemm::new(4);
        let w: Vec<i8> = (0..24 * 48)
            .map(|i| ((i * 37 + 11) % 255) as i8)
            .collect();
        let x: Vec<i8> = (0..48).map(|i| ((i * 13 + 5) % 255) as i8).collect();
        let job = Job::fc_q8(0, 0, 0, 24, 48, w, x, 0.25, 32);
        assert_eq!(big.cost(&job), job.ksteps() as f64);
        let got = big.execute(&job).unwrap();
        assert_eq!(got.data, job.execute_native().data);
    }

    #[test]
    fn big_neon_team_matches_native_on_every_class() {
        let mut big = BigNeonGemm::new(4);
        assert_eq!(big.threads(), 4);
        let mut native = NativeGemm;
        // CONV tile jobs — including ragged border tiles.
        let grid = TileGrid::new(40, 50, 60, 32);
        let a = std::sync::Arc::new(XorShift64Star::new(1).fill_f32(40 * 50, 1.0));
        let b = std::sync::Arc::new(XorShift64Star::new(2).fill_f32(50 * 60, 1.0));
        let mut id = 0;
        for job in jobs_for_gemm(0, 0, grid, a, b, &mut id) {
            let x = big.execute(&job).unwrap();
            let y = native.execute(&job).unwrap();
            assert_eq!(x.data, y.data);
        }
        // FC jobs fan out UNCONDITIONALLY on the persistent team — there
        // is no minimum-size threshold anymore.  Small and large shapes,
        // including m smaller than the team, all bit-match native.
        for (out_n, in_n) in [(3, 7), (10, 20), (37, 83), (2048, 1024)] {
            let w =
                std::sync::Arc::new(XorShift64Star::new(3).fill_f32(out_n * in_n, 1.0));
            let x = std::sync::Arc::new(XorShift64Star::new(4).fill_f32(in_n, 1.0));
            let job = Job::fc(0, 0, 0, out_n, in_n, w, x, 32);
            assert!(big.cost(&job) < native.cost(&job));
            let got = big.execute(&job).unwrap();
            let want = native.execute(&job).unwrap();
            assert_eq!(got.data, want.data, "fc {out_n}x{in_n}");
        }
        // Fused batched-FC jobs ride the same fan-out.
        let (out_n, in_n, batch) = (64, 128, 5);
        let w = std::sync::Arc::new(XorShift64Star::new(5).fill_f32(out_n * in_n, 1.0));
        let xb =
            std::sync::Arc::new(XorShift64Star::new(6).fill_f32(in_n * batch, 1.0));
        let job = Job::fc_batch(0, 0, 0, out_n, in_n, batch, w, xb, 32);
        let got = big.execute(&job).unwrap();
        let want = native.execute(&job).unwrap();
        assert_eq!(got.data, want.data);

        // Heavy CONV tile (K=32) exercises the per-chunk K accumulation.
        let grid = TileGrid::new(32, 1024, 32, 32);
        let a = std::sync::Arc::new(XorShift64Star::new(7).fill_f32(32 * 1024, 1.0));
        let b = std::sync::Arc::new(XorShift64Star::new(8).fill_f32(1024 * 32, 1.0));
        let mut id = 0;
        let jobs = jobs_for_gemm(0, 0, grid, a, b, &mut id);
        let got = big.execute(&jobs[0]).unwrap();
        let want = native.execute(&jobs[0]).unwrap();
        assert_eq!(got.data, want.data);
    }

    /// The team survives many consecutive jobs (workers are reused, not
    /// respawned) and tears down cleanly on drop.
    #[test]
    fn big_neon_team_is_reusable_and_drops_cleanly() {
        let mut big = BigNeonGemm::new(3);
        let w = std::sync::Arc::new(XorShift64Star::new(9).fill_f32(24 * 48, 1.0));
        for i in 0..50u64 {
            let x = std::sync::Arc::new(XorShift64Star::new(10 + i).fill_f32(48, 1.0));
            let job = Job::fc(i, 0, 0, 24, 48, std::sync::Arc::clone(&w), x, 32);
            let got = big.execute(&job).unwrap();
            let want = job.execute_native();
            assert_eq!(got.data, want.data, "job {i}");
        }
        drop(big); // joins the workers; a hang here fails the test harness
    }

    /// A single-thread team degrades to the plain kernel (no workers).
    #[test]
    fn big_neon_single_thread_has_no_workers() {
        let mut big = BigNeonGemm::new(1);
        let w = std::sync::Arc::new(XorShift64Star::new(11).fill_f32(8 * 8, 1.0));
        let x = std::sync::Arc::new(XorShift64Star::new(12).fill_f32(8, 1.0));
        let job = Job::fc(0, 0, 0, 8, 8, w, x, 32);
        let got = big.execute(&job).unwrap();
        assert_eq!(got.data, job.execute_native().data);
    }
}
