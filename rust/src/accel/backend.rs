//! The unified execution-backend abstraction (paper §3.1 "unified
//! abstraction of heterogeneous accelerators").
//!
//! Every delegate thread drives one [`Accelerator`] — an object-safe trait
//! whose implementors execute pool [`Job`]s and advertise capability
//! ([`Accelerator::supports`]) and cost ([`Accelerator::cost`]) metadata.
//! Three backends ship in-tree:
//!
//! * [`NativeGemm`] — the blocked-GEMM "NEON" software accelerator;
//! * [`BigNeonGemm`] — a multi-threaded tiled-SIMD GEMM modelling a
//!   big-core NEON cluster (row-chunked [`gemm_blocked_mt`]);
//! * `PjrtPe` — the FPGA PE path: the AOT Pallas job kernel through PJRT
//!   (compiled under the `pjrt` cargo feature; without it the registry
//!   entry falls back to [`NativeGemm`]).
//!
//! Backends are looked up by name in a [`BackendRegistry`], keyed from the
//! `[cluster]` sections of the hardware config: each cluster member's
//! accelerator class resolves to a registry key
//! (see `rt::pool`), so a future backend (GPU, remote shard) plugs in by
//! registering a name — no driver rewrite.
//!
//! [`gemm_blocked_mt`]: crate::mm::gemm::gemm_blocked_mt

use std::path::PathBuf;
use std::sync::Arc;

use anyhow::Result;

use crate::mm::job::{ClassMask, Job, JobClass, JobKind, JobResult};

/// An execution backend a delegate thread drives.  Object-safe so the pool
/// holds `Box<dyn Accelerator>` uniformly; implementors need not be `Send`
/// (each is built *inside* its delegate thread — the PJRT engine is
/// `Rc`-backed, and hardware-wise each PE is its own kernel instance).
pub trait Accelerator {
    /// Registry key / display name, e.g. "neon" or "pjrt-pe".
    fn id(&self) -> &str;

    /// Can this backend execute jobs of `class`?
    fn supports(&self, class: JobClass) -> bool;

    /// Relative service-cost estimate for `job` (k-steps scaled by the
    /// backend's parallelism; comparable across backends of one pool).
    /// Advisory metadata with a k-steps default: current routing uses
    /// cluster-level `PerfModel` service rates and the thief uses
    /// `StealPolicy::class_cost`, so implementors should not expect
    /// per-job routing effects from this yet (a cost-aware dispatcher is
    /// the intended consumer) — override only when the backend's
    /// parallelism skews cost away from raw k-steps.
    fn cost(&self, job: &Job) -> f64 {
        job.ksteps() as f64
    }

    /// Execute one job.  Errors are fatal to the delegate (a backend that
    /// cannot compute is a broken accelerator, not a scheduling event).
    fn execute(&mut self, job: &Job) -> Result<JobResult>;
}

/// The native blocked-GEMM software accelerator (the paper's NEON path).
pub struct NativeGemm;

impl Accelerator for NativeGemm {
    fn id(&self) -> &str {
        "neon"
    }

    fn supports(&self, _class: JobClass) -> bool {
        true
    }

    fn execute(&mut self, job: &Job) -> Result<JobResult> {
        Ok(job.execute_native())
    }
}

/// A big-core NEON cluster: `threads` cores running the row-chunked
/// multi-threaded tiled-SIMD GEMM.  GEMM work — whole-matrix FC jobs and
/// CONV tiles alike — fans its output rows across the cores (keeping the
/// backend consistent with `PerfModel::big_neon`'s thread-scaled rate);
/// im2col is pure data movement and runs on one core.
///
/// Fan-out only pays above [`MT_MIN_MACS`]: scoped spawn+join costs tens
/// of µs, so small jobs run single-core (a persistent per-backend worker
/// team that removes this threshold is a ROADMAP item).
pub struct BigNeonGemm {
    pub threads: usize,
}

/// Minimum MACs before [`BigNeonGemm`] fans a job across its thread team
/// (~1 MMAC ≈ hundreds of µs of work: enough to amortize spawn+join).
pub const MT_MIN_MACS: u64 = 1 << 20;

/// Row-parallel CONV-tile kernel over packed (K,TS,TS) operands: thread
/// `t` owns a contiguous row range of the output tile and runs the shared
/// [`gemm_blocked_into`] kernel over its slice of every inner tile — same
/// per-row accumulation order as the single-core path, and one GEMM
/// kernel to maintain.
///
/// [`gemm_blocked_into`]: crate::mm::gemm::gemm_blocked_into
fn conv_tile_mt(at: &[f32], bt: &[f32], k_tiles: usize, ts: usize, threads: usize) -> Vec<f32> {
    let threads = threads.clamp(1, ts);
    if threads == 1 {
        return crate::mm::tile::job_mm_native(at, bt, k_tiles, ts);
    }
    let mut c = vec![0.0f32; ts * ts];
    let rows_per = ts.div_ceil(threads);
    std::thread::scope(|s| {
        for (i, c_chunk) in c.chunks_mut(rows_per * ts).enumerate() {
            let r0 = i * rows_per;
            s.spawn(move || {
                let rows = c_chunk.len() / ts;
                for kt in 0..k_tiles {
                    let tile = kt * ts * ts;
                    let a_sub = &at[tile + r0 * ts..tile + (r0 + rows) * ts];
                    let b_tile = &bt[tile..tile + ts * ts];
                    crate::mm::gemm::gemm_blocked_into(a_sub, b_tile, c_chunk, rows, ts, ts);
                }
            });
        }
    });
    c
}

impl Accelerator for BigNeonGemm {
    fn id(&self) -> &str {
        "big-neon"
    }

    fn supports(&self, _class: JobClass) -> bool {
        true
    }

    fn cost(&self, job: &Job) -> f64 {
        match job.class() {
            JobClass::FcGemm | JobClass::ConvTile => {
                job.ksteps() as f64 / self.threads.max(1) as f64
            }
            JobClass::Im2col => job.ksteps() as f64,
        }
    }

    fn execute(&mut self, job: &Job) -> Result<JobResult> {
        let g = job.desc.grid;
        match &job.kind {
            JobKind::FcGemm { a, b } if (g.m * g.n * g.p) as u64 >= MT_MIN_MACS => {
                let data =
                    crate::mm::gemm::gemm_blocked_mt(a, b, g.m, g.n, g.p, self.threads);
                Ok(JobResult {
                    desc: job.desc,
                    data,
                })
            }
            JobKind::ConvTile { .. }
                if (job.desc.k_tiles() * g.ts * g.ts * g.ts) as u64 >= MT_MIN_MACS =>
            {
                let (at, bt) = job.pack_tiles();
                let data =
                    conv_tile_mt(&at, &bt, job.desc.k_tiles(), g.ts, self.threads);
                Ok(JobResult {
                    desc: job.desc,
                    data,
                })
            }
            // Small GEMMs and im2col: single-core, fan-out would not pay.
            _ => Ok(job.execute_native()),
        }
    }
}

/// The FPGA PE backend: the AOT Pallas job kernel executed through PJRT.
/// Only speaks CONV tiles — exactly what the hardware kernel computes.
#[cfg(feature = "pjrt")]
pub struct PjrtPe {
    engine: Box<crate::runtime::PeEngine>,
}

#[cfg(feature = "pjrt")]
impl PjrtPe {
    pub fn new(engine: crate::runtime::PeEngine) -> PjrtPe {
        PjrtPe {
            engine: Box::new(engine),
        }
    }
}

#[cfg(feature = "pjrt")]
impl Accelerator for PjrtPe {
    fn id(&self) -> &str {
        "pjrt-pe"
    }

    fn supports(&self, class: JobClass) -> bool {
        class == JobClass::ConvTile
    }

    fn execute(&mut self, job: &Job) -> Result<JobResult> {
        if job.class() != JobClass::ConvTile {
            anyhow::bail!("pjrt-pe cannot execute {} jobs", job.class().label());
        }
        let (at, bt) = job.pack_tiles();
        let data = self.engine.execute_job(&at, &bt, job.desc.k_tiles())?;
        Ok(JobResult {
            desc: job.desc,
            data,
        })
    }
}

/// Shared constructor for registered backends.  `Fn` (not `FnOnce`): one
/// entry builds one backend instance per delegate thread.
pub type BackendBuilder = Arc<dyn Fn() -> Result<Box<dyn Accelerator>> + Send + Sync>;

/// One registered backend: name, capability mask (known *before* any
/// instance exists, so the pool can route and the thief can filter), and
/// the per-delegate builder.
pub struct BackendEntry {
    name: String,
    pub caps: ClassMask,
    builder: BackendBuilder,
}

impl BackendEntry {
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Clone the builder handle (moved into a delegate thread).
    pub fn builder(&self) -> BackendBuilder {
        Arc::clone(&self.builder)
    }
}

/// Name-keyed backend registry.  [`BackendRegistry::with_defaults`]
/// registers the three in-tree backends; callers may register additional
/// ones (latest registration of a name wins) before starting a pool.
#[derive(Default)]
pub struct BackendRegistry {
    entries: Vec<BackendEntry>,
}

impl BackendRegistry {
    /// An empty registry.
    pub fn new() -> BackendRegistry {
        BackendRegistry::default()
    }

    /// The stock registry: "neon", "big-neon" (with `big_threads` cores),
    /// and "pjrt-pe" (loading AOT artifacts from `artifacts`; a native
    /// fallback when the `pjrt` feature is off — its capability mask stays
    /// conservative at CONV-tile-only either way, so routing decisions do
    /// not depend on the feature flag).
    pub fn with_defaults(artifacts: PathBuf, big_threads: usize) -> BackendRegistry {
        let mut reg = BackendRegistry::new();
        reg.register("neon", ClassMask::all(), || {
            Ok(Box::new(NativeGemm) as Box<dyn Accelerator>)
        });
        let threads = big_threads.max(1);
        reg.register("big-neon", ClassMask::all(), move || {
            Ok(Box::new(BigNeonGemm { threads }) as Box<dyn Accelerator>)
        });
        let art = artifacts;
        reg.register(
            "pjrt-pe",
            ClassMask::of(&[JobClass::ConvTile]),
            move || {
                #[cfg(feature = "pjrt")]
                {
                    use anyhow::Context;
                    let engine = crate::runtime::PeEngine::load(&art, None)
                        .context("loading PE engine (run `make artifacts`)")?;
                    Ok(Box::new(PjrtPe::new(engine)) as Box<dyn Accelerator>)
                }
                #[cfg(not(feature = "pjrt"))]
                {
                    // Native-GEMM fallback: the `pjrt` feature is off, so
                    // PE delegates compute natively.
                    let _ = &art;
                    Ok(Box::new(NativeGemm) as Box<dyn Accelerator>)
                }
            },
        );
        reg
    }

    /// Register (or replace) a backend under `name`.
    pub fn register<F>(&mut self, name: &str, caps: ClassMask, builder: F)
    where
        F: Fn() -> Result<Box<dyn Accelerator>> + Send + Sync + 'static,
    {
        self.entries.retain(|e| e.name != name);
        self.entries.push(BackendEntry {
            name: name.to_string(),
            caps,
            builder: Arc::new(builder),
        });
    }

    pub fn get(&self, name: &str) -> Option<&BackendEntry> {
        self.entries.iter().find(|e| e.name == name)
    }

    pub fn names(&self) -> Vec<&str> {
        self.entries.iter().map(|e| e.name.as_str()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mm::job::jobs_for_gemm;
    use crate::mm::TileGrid;
    use crate::util::rng::XorShift64Star;

    #[test]
    fn default_registry_has_all_three_backends() {
        let reg = BackendRegistry::with_defaults(PathBuf::from("/nonexistent"), 4);
        for name in ["neon", "big-neon", "pjrt-pe"] {
            assert!(reg.get(name).is_some(), "{name}");
        }
        assert!(reg.get("neon").unwrap().caps.supports(JobClass::FcGemm));
        assert!(!reg
            .get("pjrt-pe")
            .unwrap()
            .caps
            .supports(JobClass::FcGemm));
        assert!(reg.get("gpu").is_none());
    }

    #[test]
    fn registration_latest_wins() {
        let mut reg = BackendRegistry::new();
        reg.register("x", ClassMask::all(), || {
            Ok(Box::new(NativeGemm) as Box<dyn Accelerator>)
        });
        reg.register("x", ClassMask::of(&[JobClass::Im2col]), || {
            Ok(Box::new(NativeGemm) as Box<dyn Accelerator>)
        });
        assert_eq!(reg.names(), vec!["x"]);
        assert_eq!(reg.get("x").unwrap().caps, ClassMask::of(&[JobClass::Im2col]));
    }

    #[test]
    fn big_neon_matches_native_on_every_class() {
        let mut big = BigNeonGemm { threads: 4 };
        let mut native = NativeGemm;
        // CONV tile jobs.
        let grid = TileGrid::new(40, 50, 60, 32);
        let a = std::sync::Arc::new(XorShift64Star::new(1).fill_f32(40 * 50, 1.0));
        let b = std::sync::Arc::new(XorShift64Star::new(2).fill_f32(50 * 60, 1.0));
        let mut id = 0;
        for job in jobs_for_gemm(0, 0, grid, a, b, &mut id) {
            let x = big.execute(&job).unwrap();
            let y = native.execute(&job).unwrap();
            assert_eq!(x.data, y.data);
        }
        // FC job: multi-threaded path, bit-identical to single-threaded.
        // 2048×1024 ≥ MT_MIN_MACS, so this exercises the fan-out branch.
        let (out_n, in_n) = (2048, 1024);
        let w = std::sync::Arc::new(XorShift64Star::new(3).fill_f32(out_n * in_n, 1.0));
        let x = std::sync::Arc::new(XorShift64Star::new(4).fill_f32(in_n, 1.0));
        let job = Job::fc(0, 0, 0, out_n, in_n, w, x, 32);
        assert!((out_n * in_n) as u64 >= MT_MIN_MACS);
        assert!(big.cost(&job) < native.cost(&job));
        let got = big.execute(&job).unwrap();
        let want = native.execute(&job).unwrap();
        assert_eq!(got.data, want.data);

        // Heavy CONV tile (K=32 ⇒ 1 MMAC): exercises conv_tile_mt.
        let grid = TileGrid::new(32, 1024, 32, 32);
        let a = std::sync::Arc::new(XorShift64Star::new(5).fill_f32(32 * 1024, 1.0));
        let b = std::sync::Arc::new(XorShift64Star::new(6).fill_f32(1024 * 32, 1.0));
        let mut id = 0;
        let jobs = jobs_for_gemm(0, 0, grid, a, b, &mut id);
        assert!((jobs[0].desc.k_tiles() * 32 * 32 * 32) as u64 >= MT_MIN_MACS);
        let got = big.execute(&jobs[0]).unwrap();
        let want = native.execute(&jobs[0]).unwrap();
        assert_eq!(got.data, want.data);
    }
}
