//! Heterogeneous accelerators: FPGA processing engines (PEs), NEON
//! software accelerators, and big-core NEON clusters (paper §3.1.1
//! "Heterogeneous Accelerators").
//!
//! Split cleanly into:
//! * a **timing model** ([`PerfModel`], `timing.rs`) — the paper's HLS
//!   latency analysis (§3.2.1) turned into per-job service times, used by
//!   the virtual-clock simulator that regenerates the paper's figures;
//! * an **execution abstraction** ([`Accelerator`], `backend.rs`) — the
//!   object-safe trait every delegate thread drives, plus the name-keyed
//!   [`BackendRegistry`] the pool resolves `[cluster]` members through:
//!   the AOT Pallas kernel on PJRT (PE path), the native blocked GEMM
//!   (NEON path), or the multi-threaded big-core GEMM.

pub mod backend;
pub mod remote;
pub mod timing;

pub use backend::{
    Accelerator, BackendBuilder, BackendEntry, BackendRegistry, BackendSpec, BigNeonGemm,
    NativeGemm,
};
pub use remote::{
    register_config_shards, register_tcp_shard, ChannelTransport, RemoteShard, ShardTransport,
    TcpTransport,
};
pub use timing::{AccelClass, LinkCost, PerfModel};

use crate::config::{ClusterCfg, HwConfig};
use crate::mm::job::{ClassMask, JobClass};

/// Job classes an accelerator class executes *as hardware*: FPGA PEs only
/// speak f32 CONV tiles (that is what the HLS kernel computes — no Q8),
/// NEON-class software accelerators execute every class (the int8 twins
/// run on the same SIMD units), and remote shards advertise only the
/// classes whose work amortizes a transport round trip (CONV-tile +
/// fused batched FC, f32 and Q8 — [`remote::remote_class_mask`]).  The
/// threaded runtime derives member masks from the backend registry
/// instead (compute-mode aware); this is the physical view the
/// virtual-clock simulator uses.
pub fn hw_class_mask(class: &AccelClass) -> ClassMask {
    match class {
        AccelClass::FpgaPe { .. } => ClassMask::of(&[JobClass::ConvTile]),
        AccelClass::Neon | AccelClass::BigNeon => ClassMask::all(),
        AccelClass::Remote { .. } => remote::remote_class_mask(),
    }
}

/// Identity + placement of one accelerator instance.
#[derive(Debug, Clone)]
pub struct AccelSpec {
    /// Dense id, unique across the whole platform.
    pub id: usize,
    /// Cluster index this accelerator belongs to.
    pub cluster: usize,
    /// Display name, e.g. "F-PE#3" or "NEON#0".
    pub name: String,
    pub class: AccelClass,
    pub perf: PerfModel,
    /// MMU channel this accelerator's memory traffic uses (NEONs use the
    /// CPU's coherent path, modelled as channel None).
    pub mmu: Option<usize>,
}

impl AccelSpec {
    pub fn is_fpga(&self) -> bool {
        matches!(self.class, AccelClass::FpgaPe { .. })
    }
}

/// A cluster instantiated from config: its member accelerators.
#[derive(Debug, Clone)]
pub struct ClusterSpec {
    pub index: usize,
    pub name: String,
    pub members: Vec<AccelSpec>,
}

impl ClusterSpec {
    /// Aggregate k-steps/second — the "power" of a cluster, used by the
    /// static mapper to rank clusters.
    pub fn throughput(&self) -> f64 {
        self.members.iter().map(|a| 1.0 / a.perf.kstep_seconds).sum()
    }

    /// Aggregate k-steps/second of the members whose *hardware* class can
    /// execute `class` (member-level routing in the simulator: FC/im2col
    /// load only competes for the NEON-class members).
    pub fn throughput_for(&self, class: JobClass) -> f64 {
        self.members
            .iter()
            .filter(|a| hw_class_mask(&a.class).supports(class))
            .map(|a| 1.0 / a.perf.kstep_seconds)
            .sum()
    }
}

/// Instantiate all clusters + accelerators from a hardware config.
/// MMU channels are assigned round-robin, `pes_per_mmu` PEs per channel
/// (paper §3.2.2 "at most two PEs sharing an MMU").
pub fn build_clusters(hw: &HwConfig) -> Vec<ClusterSpec> {
    let mut clusters = Vec::new();
    let mut next_id = 0;
    let mut next_pe_global = 0; // PE ordinal across clusters, for MMU binding
    for (ci, ccfg) in hw.clusters.iter().enumerate() {
        let mut members = Vec::new();
        for (type_name, count) in &ccfg.pes {
            let pt = hw
                .pe_type(type_name)
                .expect("validated config references known pe types");
            for _ in 0..*count {
                let mmu = next_pe_global / hw.memsub.pes_per_mmu.max(1);
                members.push(AccelSpec {
                    id: next_id,
                    cluster: ci,
                    name: format!("{}#{}", type_name, next_pe_global),
                    class: AccelClass::FpgaPe {
                        type_name: type_name.clone(),
                    },
                    perf: PerfModel::fpga_pe(pt, hw.tile_size, hw.fpga_mhz),
                    mmu: Some(mmu.min(hw.memsub.mmus - 1)),
                });
                next_id += 1;
                next_pe_global += 1;
            }
        }
        for n in 0..ccfg.neon {
            members.push(AccelSpec {
                id: next_id,
                cluster: ci,
                name: format!("NEON#{n}@c{ci}"),
                class: AccelClass::Neon,
                perf: PerfModel::neon(hw.tile_size, hw.cpu_mhz),
                mmu: None,
            });
            next_id += 1;
        }
        for n in 0..ccfg.big_neon {
            members.push(AccelSpec {
                id: next_id,
                cluster: ci,
                name: format!("BIG#{n}@c{ci}"),
                class: AccelClass::BigNeon,
                perf: PerfModel::big_neon(hw.tile_size, hw.cpu_mhz, hw.big_neon_threads),
                mmu: None,
            });
            next_id += 1;
        }
        for (n, addr) in ccfg.remote.iter().enumerate() {
            members.push(AccelSpec {
                id: next_id,
                cluster: ci,
                name: format!("RSHARD#{n}@c{ci}"),
                class: AccelClass::Remote { addr: addr.clone() },
                perf: PerfModel::remote(hw.tile_size, hw.cpu_mhz),
                // Traffic rides the transport, not an FPGA MMU channel.
                mmu: None,
            });
            next_id += 1;
        }
        clusters.push(ClusterSpec {
            index: ci,
            name: ccfg.name.clone(),
            members,
        });
    }
    clusters
}

/// Flatten clusters into one accelerator list (id-indexed).
pub fn all_accels(clusters: &[ClusterSpec]) -> Vec<AccelSpec> {
    let mut v: Vec<AccelSpec> = clusters
        .iter()
        .flat_map(|c| c.members.iter().cloned())
        .collect();
    v.sort_by_key(|a| a.id);
    v
}

/// Filter helper: keep only members matching `keep` (used to build the
/// CPU+NEON / CPU+FPGA ablations of Fig 11/12).
pub fn filter_clusters<F: Fn(&AccelSpec) -> bool>(
    clusters: &[ClusterSpec],
    keep: F,
) -> Vec<ClusterSpec> {
    let mut out = Vec::new();
    for c in clusters {
        let members: Vec<AccelSpec> = c.members.iter().filter(|a| keep(a)).cloned().collect();
        out.push(ClusterSpec {
            index: c.index,
            name: c.name.clone(),
            members,
        });
    }
    // Drop clusters left empty; reindex clusters AND re-number accelerator
    // ids densely (ids must stay usable as vector indices downstream).
    let mut filtered: Vec<ClusterSpec> =
        out.into_iter().filter(|c| !c.members.is_empty()).collect();
    let mut next_id = 0;
    for (i, c) in filtered.iter_mut().enumerate() {
        c.index = i;
        for m in &mut c.members {
            m.cluster = i;
            m.id = next_id;
            next_id += 1;
        }
    }
    filtered
}

/// `(cluster_cfg, …)` pretty description, e.g. "2N+2S | 6F" (a "+xB"
/// suffix appears when big-core NEON clusters are configured, "+xR" when
/// remote shard members are).
pub fn describe(clusters: &[ClusterSpec]) -> String {
    clusters
        .iter()
        .map(|c| {
            let neon = c
                .members
                .iter()
                .filter(|m| m.class == AccelClass::Neon)
                .count();
            let big = c
                .members
                .iter()
                .filter(|m| m.class == AccelClass::BigNeon)
                .count();
            let shards = c
                .members
                .iter()
                .filter(|m| matches!(m.class, AccelClass::Remote { .. }))
                .count();
            let spe = c
                .members
                .iter()
                .filter(|m| m.name.starts_with("S-PE"))
                .count();
            let fpe = c
                .members
                .iter()
                .filter(|m| m.name.starts_with("F-PE"))
                .count();
            let mut s = format!("{}N+{}S+{}F", neon, spe, fpe);
            if big > 0 {
                s.push_str(&format!("+{}B", big));
            }
            if shards > 0 {
                s.push_str(&format!("+{}R", shards));
            }
            s
        })
        .collect::<Vec<_>>()
        .join(" | ")
}

/// Build clusters for a given cluster-config tuple list
/// `(neon, s_pe, f_pe)` — used by the SC design-space exploration.
pub fn clusters_from_tuples(hw: &HwConfig, tuples: &[(usize, usize, usize)]) -> Vec<ClusterSpec> {
    let mut cfg = hw.clone();
    cfg.clusters = tuples
        .iter()
        .enumerate()
        .map(|(i, (neon, spe, fpe))| {
            let mut pes = Vec::new();
            if *spe > 0 {
                pes.push(("S-PE".to_string(), *spe));
            }
            if *fpe > 0 {
                pes.push(("F-PE".to_string(), *fpe));
            }
            ClusterCfg {
                name: format!("cluster{i}"),
                neon: *neon,
                big_neon: 0,
                remote: Vec::new(),
                pes,
            }
        })
        .collect();
    build_clusters(&cfg)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_builds_paper_architecture() {
        let hw = HwConfig::default_zc702();
        let clusters = build_clusters(&hw);
        assert_eq!(clusters.len(), 2);
        // Cluster-0: 2 S-PE + 2 NEON; Cluster-1: 6 F-PE.
        assert_eq!(clusters[0].members.len(), 4);
        assert_eq!(clusters[1].members.len(), 6);
        assert_eq!(describe(&clusters), "2N+2S+0F | 0N+0S+6F");
        // ids unique and dense
        let all = all_accels(&clusters);
        for (i, a) in all.iter().enumerate() {
            assert_eq!(a.id, i);
        }
    }

    #[test]
    fn mmu_assignment_two_pes_per_mmu() {
        let hw = HwConfig::default_zc702();
        let clusters = build_clusters(&hw);
        let all = all_accels(&clusters);
        let pes: Vec<&AccelSpec> = all.iter().filter(|a| a.is_fpga()).collect();
        assert_eq!(pes.len(), 8);
        for (i, pe) in pes.iter().enumerate() {
            assert_eq!(pe.mmu, Some(i / 2), "{}", pe.name);
        }
        // NEONs bypass the FPGA MMUs
        assert!(all.iter().filter(|a| !a.is_fpga()).all(|a| a.mmu.is_none()));
    }

    #[test]
    fn cluster_throughput_ranks_fpe_highest() {
        let hw = HwConfig::default_zc702();
        let clusters = build_clusters(&hw);
        // 6 F-PEs out-throughput 2 S-PE + 2 NEON.
        assert!(clusters[1].throughput() > clusters[0].throughput());
    }

    #[test]
    fn hw_class_masks_split_by_member_kind() {
        let hw = HwConfig::default_zc702();
        let clusters = build_clusters(&hw);
        for a in all_accels(&clusters) {
            let mask = hw_class_mask(&a.class);
            assert!(mask.supports(JobClass::ConvTile), "{}", a.name);
            assert_eq!(!a.is_fpga(), mask.supports(JobClass::FcGemm), "{}", a.name);
            // Q8 capability: the f32 PE bitstream has none; NEON-class
            // members claim the whole int8 twin set.
            assert_eq!(
                !a.is_fpga(),
                mask.supports(JobClass::ConvTileQ8),
                "{}",
                a.name
            );
        }
        // The mixed cluster keeps full FC throughput via its NEONs; the
        // pure-PE cluster has none.
        assert!(clusters[0].throughput_for(JobClass::FcGemm) > 0.0);
        assert_eq!(clusters[1].throughput_for(JobClass::FcGemm), 0.0);
        assert!(
            clusters[0].throughput_for(JobClass::ConvTile)
                > clusters[0].throughput_for(JobClass::FcGemm)
        );
    }

    #[test]
    fn filter_builds_ablations() {
        let hw = HwConfig::default_zc702();
        let clusters = build_clusters(&hw);
        let fpga_only = filter_clusters(&clusters, |a| a.is_fpga());
        assert_eq!(fpga_only.iter().map(|c| c.members.len()).sum::<usize>(), 8);
        let neon_only = filter_clusters(&clusters, |a| !a.is_fpga());
        assert_eq!(neon_only.len(), 1); // cluster1 had no NEONs → dropped
        assert_eq!(neon_only[0].index, 0);
        assert!(neon_only[0].members.iter().all(|m| m.cluster == 0));
    }

    #[test]
    fn big_neon_members_built_from_config() {
        let mut hw = HwConfig::default_zc702();
        hw.clusters[0].big_neon = 1;
        hw.big_neon_threads = 4;
        let clusters = build_clusters(&hw);
        assert_eq!(clusters[0].members.len(), 5);
        let big: Vec<&AccelSpec> = clusters[0]
            .members
            .iter()
            .filter(|m| m.class == AccelClass::BigNeon)
            .collect();
        assert_eq!(big.len(), 1);
        assert!(big[0].name.starts_with("BIG#"));
        assert!(!big[0].is_fpga());
        assert!(big[0].mmu.is_none());
        assert!(describe(&clusters).starts_with("2N+2S+0F+1B"));
        // ids stay dense
        for (i, a) in all_accels(&clusters).iter().enumerate() {
            assert_eq!(a.id, i);
        }
    }

    #[test]
    fn remote_members_built_from_config() {
        let mut hw = HwConfig::default_zc702();
        hw.clusters.push(ClusterCfg {
            name: "shard".into(),
            neon: 0,
            big_neon: 0,
            remote: vec!["10.0.0.9:7000".into()],
            pes: Vec::new(),
        });
        let clusters = build_clusters(&hw);
        assert_eq!(clusters.len(), 3);
        let shard = &clusters[2].members[0];
        assert!(shard.name.starts_with("RSHARD#"));
        assert!(!shard.is_fpga());
        assert!(shard.mmu.is_none());
        assert_eq!(
            shard.class,
            AccelClass::Remote {
                addr: "10.0.0.9:7000".into()
            }
        );
        // The hardware view: CONV tiles + fused batched FC only (their Q8
        // twins included — i8 planes ship 4× fewer operand bytes, so the
        // round-trip amortization only improves).
        let mask = hw_class_mask(&shard.class);
        assert!(mask.supports(JobClass::ConvTile));
        assert!(mask.supports(JobClass::FcGemmBatch));
        assert!(mask.supports(JobClass::ConvTileQ8));
        assert!(mask.supports(JobClass::FcGemmBatchQ8));
        assert!(!mask.supports(JobClass::FcGemm));
        assert!(!mask.supports(JobClass::FcGemmQ8));
        assert!(!mask.supports(JobClass::Im2col));
        assert_eq!(clusters[2].throughput_for(JobClass::FcGemm), 0.0);
        assert!(clusters[2].throughput_for(JobClass::ConvTile) > 0.0);
        assert!(describe(&clusters).ends_with("0N+0S+0F+1R"));
        // ids stay dense across the remote member
        for (i, a) in all_accels(&clusters).iter().enumerate() {
            assert_eq!(a.id, i);
        }
    }

    #[test]
    fn tuples_builder() {
        let hw = HwConfig::default_zc702();
        let clusters = clusters_from_tuples(&hw, &[(0, 2, 1), (2, 0, 5)]);
        assert_eq!(describe(&clusters), "0N+2S+1F | 2N+0S+5F");
    }
}
