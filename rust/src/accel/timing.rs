//! HLS latency model (paper §3.2.1) → per-job service times.
//!
//! The paper's analysis: with loop pipelining at `loop2` the merged
//! `loop1×loop2` nest retires one output element per II cycles,
//! `lat_kernel = (TS²−1)·II + lat_loop3`.  The *effective* MAC rate of a PE
//! is therefore `TS·min(parallelism)/II` MACs/cycle, bounded by the BRAM
//! ports opened by array partitioning (2 read ports per bank).
//!
//! Calibration (documented in DESIGN.md §6 and EXPERIMENTS.md): the
//! absolute MAC/cycle of the paper's f32 PEs is back-derived from the GOPS
//! it reports on ZC702 (Table 4: ~2 GOPS total at 100 MHz over 8 PEs + 2
//! NEONs → ≈1.5 MAC/cycle/PE), because a ZC702 cannot physically hold
//! 8 PEs × 32 parallel f32 MACs.  The *ratios* (F-PE : S-PE : NEON) follow
//! the pragma configuration, which is what the experiments exercise.

use crate::config::{PeKind, PeTypeCfg};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;

/// Accelerator class tag.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum AccelClass {
    FpgaPe { type_name: String },
    Neon,
    /// Big-core NEON cluster: several application cores running the
    /// multi-threaded tiled-SIMD GEMM backend (`accel::backend::BigNeonGemm`).
    BigNeon,
    /// Remote accelerator shard reached over a transport
    /// (`accel::remote::RemoteShard`): a second machine's pool joining
    /// this one as a cluster member.  `addr` is the `host:port` the
    /// member's registry key (`remote:<addr>`) dials.
    Remote { addr: String },
}

/// Live cost cell of one pool member's link: the registry seeds it with
/// the static `PerfModel` prior, the prober thread updates it from
/// measured RTT/service-rate pings, and the router/thief read it on every
/// placement decision.  All fields are atomics so the prober, the
/// dispatcher, and the thief share one `Arc<LinkCost>` without locking.
///
/// Health lives here too: a probe failure (or a delegate dying on a
/// transport error) flips `alive` off, and every routing read of an
/// evicted link returns an infinite overhead — the shard disappears from
/// placement instead of being rediscovered via requeue.
#[derive(Debug)]
pub struct LinkCost {
    /// Per-job shipping overhead in k-steps of the member's rate (f64 bits).
    overhead_bits: AtomicU64,
    /// Measured far-side service rate in k-steps/s (f64 bits; 0 = no
    /// measurement yet — consumers fall back to the static model).
    rate_bits: AtomicU64,
    alive: AtomicBool,
    probes: AtomicU64,
}

/// EWMA weight of a fresh probe against the running estimate: heavy
/// enough to converge in a handful of pings, light enough that one
/// scheduler-induced outlier RTT does not yank placement around.
const PROBE_EWMA_ALPHA: f64 = 0.3;

impl LinkCost {
    /// A cell seeded from a static prior (local members keep it forever;
    /// remote members get it refined by the prober).
    pub fn fixed(overhead_ksteps: f64) -> Arc<LinkCost> {
        Arc::new(LinkCost {
            overhead_bits: AtomicU64::new(overhead_ksteps.to_bits()),
            rate_bits: AtomicU64::new(0.0f64.to_bits()),
            alive: AtomicBool::new(true),
            probes: AtomicU64::new(0),
        })
    }

    /// Current shipping overhead in k-steps; `f64::INFINITY` once evicted,
    /// which prunes the member from every cost comparison for free.
    pub fn overhead_ksteps(&self) -> f64 {
        if !self.is_alive() {
            return f64::INFINITY;
        }
        f64::from_bits(self.overhead_bits.load(Ordering::Relaxed))
    }

    /// Measured far-side rate in k-steps/s, if any probe reported one.
    pub fn measured_rate_ksteps(&self) -> Option<f64> {
        let r = f64::from_bits(self.rate_bits.load(Ordering::Relaxed));
        (r > 0.0 && self.is_alive()).then_some(r)
    }

    /// Fold one measured round trip into the estimate.  `rtt_seconds` is
    /// the ping's wall-clock round trip, `kstep_seconds` converts it into
    /// this member's k-step currency, `rate_ksteps` is the far side's
    /// self-reported service rate (≤ 0 to leave the rate untouched).
    pub fn record_probe(&self, rtt_seconds: f64, kstep_seconds: f64, rate_ksteps: f64) {
        if kstep_seconds > 0.0 && rtt_seconds.is_finite() && rtt_seconds >= 0.0 {
            let measured = rtt_seconds / kstep_seconds;
            let prev = f64::from_bits(self.overhead_bits.load(Ordering::Relaxed));
            let blended = if self.probes.load(Ordering::Relaxed) == 0 || !prev.is_finite() {
                measured
            } else {
                prev + PROBE_EWMA_ALPHA * (measured - prev)
            };
            self.overhead_bits
                .store(blended.to_bits(), Ordering::Relaxed);
        }
        if rate_ksteps > 0.0 && rate_ksteps.is_finite() {
            self.rate_bits.store(rate_ksteps.to_bits(), Ordering::Relaxed);
        }
        self.probes.fetch_add(1, Ordering::Relaxed);
    }

    pub fn is_alive(&self) -> bool {
        self.alive.load(Ordering::Relaxed)
    }

    /// Mark the link dead.  Returns `true` exactly once (the first caller
    /// to flip it), so eviction accounting never double-counts a shard
    /// whose delegate and prober both notice the failure.
    pub fn evict(&self) -> bool {
        self.alive.swap(false, Ordering::SeqCst)
    }

    /// Number of probes folded in (diagnostics + tests).
    pub fn probes(&self) -> u64 {
        self.probes.load(Ordering::Relaxed)
    }
}

/// Timing model of one accelerator.
#[derive(Debug, Clone, PartialEq)]
pub struct PerfModel {
    /// Seconds to execute one k-step (one (TS,TS)·(TS,TS) tile MAC pass).
    pub kstep_seconds: f64,
    /// Fixed per-job overhead: job request/ack handshake with the delegate
    /// thread via the control FIFOs (paper Fig 5), in seconds.
    pub job_overhead_seconds: f64,
    /// Bytes fetched per k-step (two operand tiles).
    pub bytes_per_kstep: u64,
    /// Bytes written back per job (one output tile).
    pub writeback_bytes: u64,
    /// True if transfers go through the FPGA memory subsystem (PEs);
    /// NEONs use the CPU cache path and skip MMU contention.
    pub uses_fpga_mmu: bool,
    /// Nominal MAC throughput (for roofline accounting).
    pub macs_per_cycle: f64,
    /// Clock this accelerator runs at (Hz).
    pub clock_hz: f64,
}

/// Calibrated absolute scale: effective f32 MAC/cycle of an F-PE-class
/// engine with full partitioning (see module docs).
const FPE_MACS_PER_CYCLE: f64 = 1.5;
/// Delegate-thread handshake + descriptor fetch per job (~OS mailbox round
/// trip measured in µs on ReconOS-class systems).
const JOB_OVERHEAD_S: f64 = 8e-6;

impl PerfModel {
    /// FPGA PE from its HLS pragma configuration.
    ///
    /// Scaling: `macs_per_cycle = FPE · (partition/16) · unroll_bonus / II`
    /// clamped to the paper's regimes — F-PE (partition 16, II 1) hits the
    /// full rate; S-PE (partition 4, II 4, unroll 2) lands ≈4× slower.
    pub fn fpga_pe(pt: &PeTypeCfg, ts: usize, fpga_mhz: f64) -> PerfModel {
        let clock_hz = fpga_mhz * 1e6;
        let partition_scale = (pt.array_partition as f64 / 16.0).min(1.0);
        let macs_per_cycle = match pt.kind {
            PeKind::Fast => FPE_MACS_PER_CYCLE * partition_scale.max(1.0 / 16.0),
            // The II of the pipelined loop3 divides throughput directly;
            // unrolling is what bought the II down, so it is not double
            // counted here.
            PeKind::Slow => FPE_MACS_PER_CYCLE * partition_scale / pt.ii.max(1) as f64,
        }
        .max(0.01);
        let macs_per_kstep = (ts * ts * ts) as f64;
        PerfModel {
            kstep_seconds: macs_per_kstep / (macs_per_cycle * clock_hz),
            job_overhead_seconds: JOB_OVERHEAD_S,
            bytes_per_kstep: (2 * ts * ts * 4) as u64,
            writeback_bytes: (ts * ts * 4) as u64,
            uses_fpga_mmu: true,
            macs_per_cycle,
            clock_hz,
        }
    }

    /// NEON software accelerator: f32 MM in NEON assembly on a Cortex-A9.
    ///
    /// Effective rate calibrated so 2 NEONs contribute the paper's +12–15%
    /// over the 8-PE FPGA complement (§4.2): ≈0.2 f32 MAC/cycle at the CPU
    /// clock — A9 NEON is not fully pipelined for f32 and the kernel is
    /// memory-bound on the 32-KiB L1.
    pub fn neon(ts: usize, cpu_mhz: f64) -> PerfModel {
        let clock_hz = cpu_mhz * 1e6;
        let macs_per_cycle = 0.2;
        let macs_per_kstep = (ts * ts * ts) as f64;
        PerfModel {
            kstep_seconds: macs_per_kstep / (macs_per_cycle * clock_hz),
            job_overhead_seconds: 2e-6, // plain function call + queue pop
            bytes_per_kstep: (2 * ts * ts * 4) as u64,
            writeback_bytes: (ts * ts * 4) as u64,
            uses_fpga_mmu: false,
            macs_per_cycle,
            clock_hz,
        }
    }

    /// Big-core NEON cluster: `threads` out-of-order application cores
    /// (A72-class) driving the multi-threaded tiled GEMM.  Per-core f32
    /// rate ≈0.5 MAC/cycle (dual-issue NEON, still memory-bound on large
    /// panels); the cores aggregate near-linearly on row-chunked GEMMs.
    /// Per-job overhead is higher than a plain NEON call: the backend
    /// fans work out across a thread team.
    pub fn big_neon(ts: usize, cpu_mhz: f64, threads: usize) -> PerfModel {
        let clock_hz = cpu_mhz * 1e6;
        let macs_per_cycle = 0.5 * threads.max(1) as f64;
        let macs_per_kstep = (ts * ts * ts) as f64;
        PerfModel {
            kstep_seconds: macs_per_kstep / (macs_per_cycle * clock_hz),
            job_overhead_seconds: 6e-6, // queue pop + thread-team fan-out
            bytes_per_kstep: (2 * ts * ts * 4) as u64,
            writeback_bytes: (ts * ts * 4) as u64,
            uses_fpga_mmu: false,
            macs_per_cycle,
            clock_hz,
        }
    }

    /// Remote accelerator shard: a peer machine's pool on the far end of a
    /// LAN link, modelled as a 4-wide big-core cluster (per-deployment
    /// calibration knob — the far pool's real rate is whatever its own
    /// `.hw_config` says) whose per-job overhead is a transport round trip
    /// (serialization + two one-way latencies, ≈ 0.5 ms on a switched
    /// LAN) instead of a local queue pop.  At ts = 32 / 667 MHz that
    /// overhead equals ≈ `accel::remote::REMOTE_OVERHEAD_KSTEPS` k-steps
    /// of this model's rate, keeping the registry's routing metadata and
    /// the simulator's service model consistent.
    pub fn remote(ts: usize, cpu_mhz: f64) -> PerfModel {
        let clock_hz = cpu_mhz * 1e6;
        let macs_per_cycle = 0.5 * 4.0;
        let macs_per_kstep = (ts * ts * ts) as f64;
        PerfModel {
            kstep_seconds: macs_per_kstep / (macs_per_cycle * clock_hz),
            job_overhead_seconds: 500e-6,
            bytes_per_kstep: (2 * ts * ts * 4) as u64,
            writeback_bytes: (ts * ts * 4) as u64,
            // Traffic rides the LAN, not the FPGA MMUs: the link cost is
            // folded into the per-job overhead.
            uses_fpga_mmu: false,
            macs_per_cycle,
            clock_hz,
        }
    }

    /// Compute-only service time of a job with `k` k-steps (no memory).
    pub fn compute_seconds(&self, k: usize) -> f64 {
        self.job_overhead_seconds + k as f64 * self.kstep_seconds
    }

    /// GFLOP/s this accelerator sustains on back-to-back jobs.
    pub fn gflops(&self, ts: usize) -> f64 {
        let flops_per_kstep = 2.0 * (ts * ts * ts) as f64;
        flops_per_kstep / self.kstep_seconds / 1e9
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::HwConfig;

    fn models() -> (PerfModel, PerfModel, PerfModel) {
        let hw = HwConfig::default_zc702();
        let f = PerfModel::fpga_pe(hw.pe_type("F-PE").unwrap(), 32, hw.fpga_mhz);
        let s = PerfModel::fpga_pe(hw.pe_type("S-PE").unwrap(), 32, hw.fpga_mhz);
        let n = PerfModel::neon(32, hw.cpu_mhz);
        (f, s, n)
    }

    #[test]
    fn fpe_rate_matches_calibration() {
        let (f, _, _) = models();
        // 32³ MACs at 1.5 MAC/cycle @100 MHz ≈ 218 µs per k-step.
        assert!((f.kstep_seconds - 218.5e-6).abs() < 5e-6, "{}", f.kstep_seconds);
        // ≈0.3 GFLOP/s per F-PE → system ≈ 2.1 GFLOP/s, Table 4 ballpark.
        let g = f.gflops(32);
        assert!((0.25..0.35).contains(&g), "{g}");
    }

    #[test]
    fn spe_about_4x_slower_than_fpe() {
        let (f, s, _) = models();
        let ratio = s.kstep_seconds / f.kstep_seconds;
        assert!((1.2..4.0).contains(&ratio), "S/F ratio {ratio}");
    }

    #[test]
    fn neon_slower_than_fpe_but_usable() {
        let (f, _, n) = models();
        let ratio = n.kstep_seconds / f.kstep_seconds;
        // A NEON is worth roughly 0.6–1.0 F-PE (→ 2 NEONs add 12–25%).
        assert!((1.0..2.0).contains(&ratio), "NEON/F ratio {ratio}");
        assert!(!n.uses_fpga_mmu);
    }

    #[test]
    fn compute_seconds_linear_in_k() {
        let (f, _, _) = models();
        let t1 = f.compute_seconds(1);
        let t10 = f.compute_seconds(10);
        assert!((t10 - t1 - 9.0 * f.kstep_seconds).abs() < 1e-12);
        assert!(t1 > f.job_overhead_seconds);
    }

    #[test]
    fn big_neon_scales_with_threads() {
        let one = PerfModel::big_neon(32, 1200.0, 1);
        let four = PerfModel::big_neon(32, 1200.0, 4);
        assert!((one.kstep_seconds / four.kstep_seconds - 4.0).abs() < 1e-9);
        assert!(!four.uses_fpga_mmu);
        // A 4-wide big cluster at 1.2 GHz out-runs one A9 NEON.
        let neon = PerfModel::neon(32, 667.0);
        assert!(four.kstep_seconds < neon.kstep_seconds);
    }

    #[test]
    fn remote_model_overhead_matches_registry_ksteps() {
        let r = PerfModel::remote(32, 667.0);
        assert!(!r.uses_fpga_mmu);
        // The RTT dominates small jobs: one k-step computes in ~25 µs but
        // the round trip costs ~0.5 ms.
        assert!(r.job_overhead_seconds > 10.0 * r.kstep_seconds);
        // The registry-side overhead (REMOTE_OVERHEAD_KSTEPS k-steps of
        // this rate) and the simulator-side overhead agree within a few
        // percent at the default clock/tile — one shipping cost, two
        // consumers.
        let registry_s = crate::accel::remote::REMOTE_OVERHEAD_KSTEPS * r.kstep_seconds;
        let rel = (registry_s - r.job_overhead_seconds).abs() / r.job_overhead_seconds;
        assert!(rel < 0.05, "registry {registry_s}s vs model {}s", r.job_overhead_seconds);
        // Faster than a lone A9 NEON, slower than it pretends on tiny jobs.
        assert!(r.kstep_seconds < PerfModel::neon(32, 667.0).kstep_seconds);
    }

    #[test]
    fn link_cost_seeds_static_and_converges_on_probes() {
        let link = LinkCost::fixed(20.0);
        assert!(link.is_alive());
        assert_eq!(link.overhead_ksteps(), 20.0);
        assert_eq!(link.measured_rate_ksteps(), None);
        assert_eq!(link.probes(), 0);

        // First probe replaces the prior outright; later probes blend.
        let kstep = 25e-6; // ≈ PerfModel::remote(32, 667 MHz)
        link.record_probe(1.0e-3, kstep, 150.0);
        assert_eq!(link.probes(), 1);
        let first = link.overhead_ksteps();
        assert!((first - 40.0).abs() < 1e-9, "{first}");
        assert_eq!(link.measured_rate_ksteps(), Some(150.0));
        link.record_probe(0.5e-3, kstep, 0.0);
        let second = link.overhead_ksteps();
        assert!(second < first && second > 20.0, "{second}");
        // Rate untouched by a rate-less ping.
        assert_eq!(link.measured_rate_ksteps(), Some(150.0));
    }

    #[test]
    fn link_eviction_flips_once_and_poisons_cost() {
        let link = LinkCost::fixed(20.0);
        assert!(link.evict(), "first eviction reports the flip");
        assert!(!link.evict(), "second eviction is a no-op");
        assert!(!link.is_alive());
        assert_eq!(link.overhead_ksteps(), f64::INFINITY);
        assert_eq!(link.measured_rate_ksteps(), None);
        // Probes after death do not resurrect routing cost.
        link.record_probe(1.0e-6, 25e-6, 500.0);
        assert_eq!(link.overhead_ksteps(), f64::INFINITY);
    }

    #[test]
    fn bytes_accounting() {
        let (f, _, _) = models();
        assert_eq!(f.bytes_per_kstep, 2 * 32 * 32 * 4);
        assert_eq!(f.writeback_bytes, 32 * 32 * 4);
    }
}
