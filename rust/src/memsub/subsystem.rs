//! Queueing model of the memory subsystem for the virtual-clock simulator.
//!
//! Each PE's tile fetches serialize on its assigned MMU channel (one
//! outstanding translation+burst at a time per MMU — the ReconOS MEMIF
//! structure), and all channels share the DDR bus.  This is what makes a
//! single shared MMU flatten multi-PE speedup (paper Fig 7a) while one MMU
//! per two PEs scales near-linearly (Fig 7b).

use crate::config::MemSubCfg;

use super::mmu::{Mmu, PageTable, PAGE_SIZE};

/// Aggregate transfer statistics.
#[derive(Debug, Default, Clone, Copy)]
pub struct TransferStats {
    pub requests: u64,
    pub bytes: u64,
    /// Seconds spent queueing behind other requests (contention).
    pub queue_seconds: f64,
    /// Seconds of pure service (translation + burst).
    pub service_seconds: f64,
    pub tlb_hits: u64,
    pub walks: u64,
    pub faults: u64,
}

/// One MMU + MEM-controller channel's queue state.
#[derive(Debug, Clone)]
struct Channel {
    busy_until: f64,
    mmu: Mmu,
}

/// The shared memory subsystem (virtual-time queueing model).
#[derive(Debug)]
pub struct MemSubsystem {
    cfg: MemSubCfg,
    fpga_hz: f64,
    channels: Vec<Channel>,
    /// Shared DDR bus availability.
    ddr_busy_until: f64,
    page_table: PageTable,
    /// Next synthetic VA to hand out to buffers.
    next_va: u64,
    pub stats: TransferStats,
}

impl MemSubsystem {
    pub fn new(cfg: &MemSubCfg, fpga_mhz: f64) -> Self {
        let channels = (0..cfg.mmus)
            .map(|_| Channel {
                busy_until: 0.0,
                mmu: Mmu::new(cfg.tlb_entries),
            })
            .collect();
        MemSubsystem {
            cfg: cfg.clone(),
            fpga_hz: fpga_mhz * 1e6,
            channels,
            ddr_busy_until: 0.0,
            page_table: PageTable::new(),
            next_va: 0x1000_0000,
            stats: TransferStats::default(),
        }
    }

    /// Allocate a synthetic user-space buffer and pre-map it (the host
    /// mmaps feature-map arrays before dispatch).  Returns its base VA.
    pub fn alloc_buffer(&mut self, len: u64) -> u64 {
        let base = self.next_va;
        self.page_table.map_range(base, len);
        // Page-align the next allocation.
        self.next_va += len.div_ceil(PAGE_SIZE) * PAGE_SIZE;
        base
    }

    fn cycles_to_seconds(&self, cycles: f64) -> f64 {
        cycles / self.fpga_hz
    }

    /// Request a transfer of `bytes` at virtual address `va` through MMU
    /// channel `chan`, issued at virtual time `now`.  Returns the completion
    /// time.  The request holds its MMU channel for the full service and
    /// the DDR bus for the burst portion.
    pub fn transfer(&mut self, chan: usize, va: u64, bytes: u64, now: f64) -> f64 {
        let chan_idx = chan % self.channels.len();

        // --- translation cost (per page touched) ---
        let pages = bytes.max(1).div_ceil(PAGE_SIZE).max(1);
        let mut walk_reads = 0usize;
        let mut faults = 0u64;
        {
            let ch = &mut self.channels[chan_idx];
            for pg in 0..pages {
                let r = ch.mmu.translate(va + pg * PAGE_SIZE, &mut self.page_table);
                walk_reads += r.ddr_reads();
                if matches!(r, super::mmu::WalkResult::Faulted(_)) {
                    faults += 1;
                }
            }
            self.stats.tlb_hits += ch.mmu.stats.tlb_hits;
            self.stats.walks = self.stats.walks.max(ch.mmu.stats.walks);
        }

        // --- service time in cycles ---
        // burst transfer: latency per burst + streaming at bus width
        let beats = bytes.div_ceil(8); // 64-bit AXI beats
        let bursts = beats.div_ceil(self.cfg.burst_beats as u64).max(1);
        let stream_cycles = bytes as f64 / self.cfg.ddr_bytes_per_cycle;
        let burst_cycles =
            bursts as f64 * self.cfg.ddr_latency_cycles as f64 + stream_cycles;
        // page-walk DDR reads: 2 random accesses each
        let walk_cycles = walk_reads as f64 * self.cfg.ddr_latency_cycles as f64;
        // page faults: CPU interrupt + kernel handling (≈3 µs)
        let fault_seconds = faults as f64 * 3e-6;
        let service = self.cycles_to_seconds(walk_cycles + burst_cycles) + fault_seconds;

        // --- queueing: wait for the MMU channel, then the DDR bus ---
        let ch_free = self.channels[chan_idx].busy_until;
        let start = now.max(ch_free);
        // DDR bus is only held for the burst portion; model it as a second
        // queue the request passes through after translation.
        let ddr_start = start.max(self.ddr_busy_until);
        let ddr_hold = self.cycles_to_seconds(stream_cycles);
        let done = ddr_start + service;
        self.channels[chan_idx].busy_until = done;
        self.ddr_busy_until = ddr_start + ddr_hold;

        self.stats.requests += 1;
        self.stats.bytes += bytes;
        self.stats.queue_seconds += ddr_start - now;
        self.stats.service_seconds += service;
        self.stats.faults += faults;
        done
    }

    /// Reset queue state (keep page table + TLB warm) — between runs.
    pub fn reset_clock(&mut self) {
        for ch in &mut self.channels {
            ch.busy_until = 0.0;
        }
        self.ddr_busy_until = 0.0;
        self.stats = TransferStats::default();
    }

    pub fn num_channels(&self) -> usize {
        self.channels.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::HwConfig;

    fn memsub(mmus: usize) -> MemSubsystem {
        let mut cfg = HwConfig::default_zc702().memsub;
        cfg.mmus = mmus;
        MemSubsystem::new(&cfg, 100.0)
    }

    #[test]
    fn single_transfer_time_reasonable() {
        let mut ms = memsub(1);
        let va = ms.alloc_buffer(1 << 20);
        let done = ms.transfer(0, va, 8192, 0.0);
        // 8 KiB at 8 B/cycle = 1024 cycles ≈ 10.2 µs + latency overheads.
        assert!(done > 10e-6 && done < 60e-6, "{done}");
        assert_eq!(ms.stats.requests, 1);
    }

    #[test]
    fn same_channel_serializes_different_channels_overlap() {
        let mut ms = memsub(2);
        let va = ms.alloc_buffer(1 << 22);
        let t1 = ms.transfer(0, va, 65536, 0.0);
        ms.reset_clock();
        ms.alloc_buffer(0); // no-op keep borrowck happy about reuse
        // two requests on the SAME channel: second waits for first
        let a = ms.transfer(0, va, 65536, 0.0);
        let b = ms.transfer(0, va + 65536, 65536, 0.0);
        assert!(b > a * 1.8, "serialized: {b} vs {a}");
        // two requests on DIFFERENT channels: DDR bus is the only coupling
        ms.reset_clock();
        let a2 = ms.transfer(0, va, 65536, 0.0);
        let b2 = ms.transfer(1, va + 65536, 65536, 0.0);
        assert!(b2 < b, "parallel channels faster: {b2} vs {b}");
        assert!(b2 >= a2 * 0.5);
        let _ = t1;
    }

    #[test]
    fn queueing_stats_accumulate() {
        let mut ms = memsub(1);
        let va = ms.alloc_buffer(1 << 22);
        for i in 0..8 {
            ms.transfer(0, va + i * 8192, 8192, 0.0);
        }
        assert!(ms.stats.queue_seconds > 0.0);
        assert_eq!(ms.stats.requests, 8);
        assert_eq!(ms.stats.bytes, 8 * 8192);
    }

    #[test]
    fn channel_wraps_modulo() {
        let mut ms = memsub(2);
        let va = ms.alloc_buffer(1 << 20);
        // channel index 5 on 2 channels → channel 1; must not panic
        let done = ms.transfer(5, va, 4096, 0.0);
        assert!(done > 0.0);
    }

    #[test]
    fn faults_cost_more_than_mapped_access() {
        let mut ms = memsub(1);
        // Unmapped VA → faults on every page.
        let t_fault = ms.transfer(0, 0xDEAD_0000, 16384, 0.0);
        ms.reset_clock();
        let va = ms.alloc_buffer(16384);
        // Different pages but pre-mapped (walks only, warm after).
        let t_mapped = ms.transfer(0, va, 16384, 0.0);
        assert!(t_fault > t_mapped, "{t_fault} vs {t_mapped}");
        assert!(ms.stats.faults == 0);
    }
}
