//! Functional MMU: ARM Cortex-A9 style two-level page-table walk with a
//! small TLB (paper Fig 6 "Virtual To Physical Address Translation").
//!
//! The Synergy PEs receive *user-space virtual addresses* inside jobs and
//! translate them in hardware; this model reproduces that mechanism so the
//! simulator can charge the right number of DDR accesses per translation
//! (2 reads per walk, amortized by the TLB) and raise page faults to the
//! shared Proc unit.

use std::collections::HashMap;

/// 4 KiB small pages (ARM short-descriptor format).
pub const PAGE_SIZE: u64 = 4096;
/// L1 table covers 1 MiB sections → index = va[31:20].
const L1_SHIFT: u32 = 20;
/// L2 covers 4 KiB pages → index = va[19:12].
const L2_SHIFT: u32 = 12;
const L2_MASK: u64 = 0xFF;

/// A two-level page table: L1 section entries pointing at L2 tables.
#[derive(Debug, Default, Clone)]
pub struct PageTable {
    /// l1\[va>>20\] = l2 table id
    l1: HashMap<u64, u64>,
    /// (l2 table id, va\[19:12\]) = physical frame number
    l2: HashMap<(u64, u64), u64>,
    next_l2: u64,
    next_frame: u64,
}

impl PageTable {
    pub fn new() -> Self {
        Self::default()
    }

    /// Map a virtual page (demand paging — called by the Proc unit on
    /// fault).  Returns the physical frame number.
    pub fn map(&mut self, va: u64) -> u64 {
        let l1_idx = va >> L1_SHIFT;
        let l2_id = *self.l1.entry(l1_idx).or_insert_with(|| {
            self.next_l2 += 1;
            self.next_l2
        });
        let l2_idx = (va >> L2_SHIFT) & L2_MASK;
        *self.l2.entry((l2_id, l2_idx)).or_insert_with(|| {
            self.next_frame += 1;
            self.next_frame
        })
    }

    /// Walk the tables (no side effects).  None = translation fault.
    pub fn walk(&self, va: u64) -> Option<u64> {
        let l2_id = self.l1.get(&(va >> L1_SHIFT))?;
        let frame = self.l2.get(&(*l2_id, (va >> L2_SHIFT) & L2_MASK))?;
        Some(frame * PAGE_SIZE + (va & (PAGE_SIZE - 1)))
    }

    /// Pre-map a contiguous buffer (what the host does when it allocates
    /// the feature-map arrays before dispatching jobs).
    pub fn map_range(&mut self, base: u64, len: u64) {
        let first = base / PAGE_SIZE;
        let last = (base + len.max(1) - 1) / PAGE_SIZE;
        for page in first..=last {
            self.map(page * PAGE_SIZE);
        }
    }
}

/// Result of one translation through the MMU.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WalkResult {
    /// TLB hit: no memory traffic.
    TlbHit(u64),
    /// TLB miss: physical address + 2 DDR reads for the walk.
    Walked(u64),
    /// Page fault: Proc-unit interrupt, then the walk succeeded.
    Faulted(u64),
}

impl WalkResult {
    pub fn phys(&self) -> u64 {
        match self {
            WalkResult::TlbHit(p) | WalkResult::Walked(p) | WalkResult::Faulted(p) => *p,
        }
    }

    /// DDR reads charged to this translation.
    pub fn ddr_reads(&self) -> usize {
        match self {
            WalkResult::TlbHit(_) => 0,
            WalkResult::Walked(_) | WalkResult::Faulted(_) => 2,
        }
    }
}

/// Per-MMU statistics.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct MmuStats {
    pub translations: u64,
    pub tlb_hits: u64,
    pub walks: u64,
    pub faults: u64,
}

/// An MMU instance: TLB + reference to the shared page table.
#[derive(Debug, Clone)]
pub struct Mmu {
    /// FIFO TLB of (vpage → frame).
    tlb: Vec<(u64, u64)>,
    capacity: usize,
    pub stats: MmuStats,
}

impl Mmu {
    pub fn new(tlb_entries: usize) -> Self {
        Self {
            tlb: Vec::with_capacity(tlb_entries),
            capacity: tlb_entries.max(1),
            stats: MmuStats::default(),
        }
    }

    /// Translate `va`; on fault, demand-map via the Proc unit (`table`).
    pub fn translate(&mut self, va: u64, table: &mut PageTable) -> WalkResult {
        self.stats.translations += 1;
        let vpage = va / PAGE_SIZE;
        if let Some((_, frame)) = self.tlb.iter().find(|(p, _)| *p == vpage) {
            let pa = frame * PAGE_SIZE + (va & (PAGE_SIZE - 1));
            self.stats.tlb_hits += 1;
            return WalkResult::TlbHit(pa);
        }
        match table.walk(va) {
            Some(pa) => {
                self.stats.walks += 1;
                self.tlb_insert(vpage, pa / PAGE_SIZE);
                WalkResult::Walked(pa)
            }
            None => {
                // Page fault: Proc unit interrupts the CPU, kernel maps the
                // page, MMU retries the walk (paper §3.2.2).
                self.stats.faults += 1;
                table.map(va);
                let pa = table.walk(va).expect("just mapped");
                self.tlb_insert(vpage, pa / PAGE_SIZE);
                WalkResult::Faulted(pa)
            }
        }
    }

    fn tlb_insert(&mut self, vpage: u64, frame: u64) {
        if self.tlb.len() == self.capacity {
            self.tlb.remove(0); // FIFO eviction
        }
        self.tlb.push((vpage, frame));
    }

    pub fn hit_rate(&self) -> f64 {
        if self.stats.translations == 0 {
            0.0
        } else {
            self.stats.tlb_hits as f64 / self.stats.translations as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn walk_after_map_roundtrips_offsets() {
        let mut pt = PageTable::new();
        pt.map(0x4000_1000);
        let pa = pt.walk(0x4000_1ABC).unwrap();
        assert_eq!(pa & 0xFFF, 0xABC); // page offset preserved
        assert!(pt.walk(0x4000_2000).is_none()); // unmapped page faults
    }

    #[test]
    fn map_range_covers_all_pages() {
        let mut pt = PageTable::new();
        pt.map_range(0x1000_0F00, 2 * PAGE_SIZE); // spans 3 pages
        assert!(pt.walk(0x1000_0F00).is_some());
        assert!(pt.walk(0x1000_1F00).is_some());
        assert!(pt.walk(0x1000_2EFF).is_some());
        assert!(pt.walk(0x1000_3000).is_none());
    }

    #[test]
    fn same_page_same_frame_different_pages_differ() {
        let mut pt = PageTable::new();
        pt.map(0x1000);
        pt.map(0x2000);
        let a1 = pt.walk(0x1000).unwrap();
        let a2 = pt.walk(0x1004).unwrap();
        let b = pt.walk(0x2000).unwrap();
        assert_eq!(a2 - a1, 4);
        assert_ne!(a1 / PAGE_SIZE, b / PAGE_SIZE);
    }

    #[test]
    fn tlb_hits_after_first_walk() {
        let mut pt = PageTable::new();
        pt.map(0x5000);
        let mut mmu = Mmu::new(4);
        let r1 = mmu.translate(0x5000, &mut pt);
        assert!(matches!(r1, WalkResult::Walked(_)));
        assert_eq!(r1.ddr_reads(), 2);
        let r2 = mmu.translate(0x5010, &mut pt);
        assert!(matches!(r2, WalkResult::TlbHit(_)));
        assert_eq!(r2.ddr_reads(), 0);
        assert_eq!(r2.phys() - r1.phys(), 0x10);
        assert_eq!(mmu.stats.tlb_hits, 1);
    }

    #[test]
    fn fault_then_mapped() {
        let mut pt = PageTable::new();
        let mut mmu = Mmu::new(2);
        let r = mmu.translate(0x9000, &mut pt);
        assert!(matches!(r, WalkResult::Faulted(_)));
        assert_eq!(mmu.stats.faults, 1);
        // second access: TLB hit, no fault
        let r2 = mmu.translate(0x9004, &mut pt);
        assert!(matches!(r2, WalkResult::TlbHit(_)));
    }

    #[test]
    fn tlb_fifo_eviction() {
        let mut pt = PageTable::new();
        let mut mmu = Mmu::new(2);
        for page in 0..3u64 {
            mmu.translate(page * PAGE_SIZE, &mut pt);
        }
        // page 0 evicted → walk again (not a fault: still mapped)
        let r = mmu.translate(0, &mut pt);
        assert!(matches!(r, WalkResult::Walked(_)));
        assert_eq!(mmu.stats.faults, 3);
    }

    #[test]
    fn streaming_tiles_hit_rate_is_high() {
        // A PE streaming a 8 KiB tile fetch touches 2–3 pages; with a
        // burst-per-256B request granularity the TLB should absorb most.
        let mut pt = PageTable::new();
        pt.map_range(0, 1 << 20);
        let mut mmu = Mmu::new(8);
        for req in 0..4096u64 {
            mmu.translate(req * 256, &mut pt);
        }
        assert!(mmu.hit_rate() > 0.9, "{}", mmu.hit_rate());
    }
}
