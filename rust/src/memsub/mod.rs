//! Memory subsystem model (paper §3.2.2 / Fig 5–7): MMUs performing the
//! ARM two-level page-table walk for PE virtual addresses, per-MMU
//! arbitration, AXI burst DDR transfers, and the shared Proc unit that
//! services page faults.
//!
//! Two layers:
//! * [`mmu`] — the *functional* model: page tables, TLB, two-level walk,
//!   fault handling (validated by unit tests against a software walk);
//! * [`subsystem`] — the *queueing* model used by the virtual-clock
//!   simulator: transfer requests serialize on their MMU channel and on
//!   the shared DDR bus, reproducing Fig 7's single- vs multi-MMU scaling.

pub mod mmu;
pub mod subsystem;

pub use mmu::{Mmu, PageTable, WalkResult, PAGE_SIZE};
pub use subsystem::{MemSubsystem, TransferStats};
