//! # Synergy — HW/SW co-designed CNN inference on heterogeneous SoC
//!
//! Reproduction of *Synergy: A HW/SW Framework for High Throughput CNNs on
//! Embedded Heterogeneous SoC* (Zhong, Dubey, Tan, Mitra — NUS, 2018).
//!
//! The crate is the **Layer-3 Rust coordinator** of a three-layer stack:
//! the compute hot-spot (tiled matrix multiplication) is authored as a
//! Pallas kernel (L1), embedded in a JAX model (L2), AOT-lowered to HLO
//! text at build time, and executed from here through the PJRT C API
//! (`runtime/`).  Python never runs at inference time.
//!
//! See `DESIGN.md` for the full system inventory and the per-experiment
//! index mapping every paper table/figure to a bench target.

pub mod accel;
pub mod cluster;
pub mod config;
pub mod mm;
pub mod experiments;
pub mod hwgen;
pub mod memsub;
pub mod nn;
pub mod pipeline;
pub mod rt;
pub mod runtime;
pub mod sim;
pub mod sched;
pub mod tensor;
pub mod util;
