//! # Synergy — HW/SW co-designed CNN inference on heterogeneous SoC
//!
//! Reproduction of *Synergy: A HW/SW Framework for High Throughput CNNs on
//! Embedded Heterogeneous SoC* (Zhong, Dubey, Tan, Mitra — NUS, 2018).
//!
//! The crate is the **Layer-3 Rust coordinator** of a three-layer stack:
//! the compute hot-spot (tiled matrix multiplication) is authored as a
//! Pallas kernel (L1), embedded in a JAX model (L2), AOT-lowered to HLO
//! text at build time, and executed from here through the PJRT C API
//! (`runtime/`).  Python never runs at inference time.
//!
//! See `DESIGN.md` for the full system inventory and the per-experiment
//! index mapping every paper table/figure to a bench target.

// Deliberate API shapes: queue timeouts signal with a unit error (the
// caller's only recourse is "try stealing"), and the numeric kernels use
// index loops that mirror the paper's pseudocode.
#![allow(clippy::result_unit_err)]
#![allow(clippy::needless_range_loop)]
#![allow(clippy::too_many_arguments)]

pub mod accel;
pub mod cluster;
pub mod config;
pub mod mm;
pub mod experiments;
pub mod hwgen;
pub mod memsub;
pub mod nn;
pub mod pipeline;
pub mod rt;
pub mod runtime;
pub mod serve;
pub mod sim;
pub mod sched;
pub mod tensor;
pub mod util;
