//! Table 6 — accelerator cluster utilization across designs: non-pipelined
//! vs SF vs SC vs Synergy.  Paper means: 56.05% → 92.46% → 96.47% → 99.80%.

use crate::accel::clusters_from_tuples;
use crate::config::HwConfig;
use crate::sched::dse;
use crate::sim::{simulate, SimSpec};
use crate::util::bench::Table;
use crate::util::stats;

use super::{zoo_networks, Report};

pub struct UtilRow {
    pub model: String,
    pub non_pipelined: f64,
    pub sf: f64,
    pub sc: f64,
    pub synergy: f64,
}

pub fn rows(frames: usize) -> Vec<UtilRow> {
    let hw = HwConfig::default_zc702();
    zoo_networks()
        .iter()
        .map(|net| {
            let non = simulate(&SimSpec::synergy(net, frames.min(12)).non_pipelined(), net);
            let sf = simulate(&SimSpec::static_fixed(net, frames), net);
            let best = dse::explore(net, frames.min(12));
            let sc_clusters = clusters_from_tuples(&hw, &best.best);
            let sc = simulate(&SimSpec::static_custom(net, sc_clusters, frames), net);
            let syn = simulate(&SimSpec::synergy(net, frames), net);
            UtilRow {
                model: net.config.name.clone(),
                non_pipelined: non.cluster_util,
                sf: sf.cluster_util,
                sc: sc.cluster_util,
                synergy: syn.cluster_util,
            }
        })
        .collect()
}

pub fn run(frames: usize) -> Report {
    let rows = rows(frames);
    let mut table = Table::new(&["model", "non-pipelined", "SF", "SC", "Synergy"]);
    let pct = |v: f64| format!("{:.1}%", 100.0 * v);
    for r in &rows {
        table.row(vec![
            r.model.clone(),
            pct(r.non_pipelined),
            pct(r.sf),
            pct(r.sc),
            pct(r.synergy),
        ]);
    }
    let mean = |f: fn(&UtilRow) -> f64| stats::mean(&rows.iter().map(f).collect::<Vec<_>>());
    table.row(vec![
        "mean".into(),
        pct(mean(|r| r.non_pipelined)),
        pct(mean(|r| r.sf)),
        pct(mean(|r| r.sc)),
        pct(mean(|r| r.synergy)),
    ]);
    Report {
        id: "Table 6",
        title: "accelerator cluster utilization across designs",
        table: table.render(),
        summary: format!(
            "paper means: 56.1% / 92.5% / 96.5% / 99.8%; measured means: \
             {:.1}% / {:.1}% / {:.1}% / {:.1}%",
            100.0 * mean(|r| r.non_pipelined),
            100.0 * mean(|r| r.sf),
            100.0 * mean(|r| r.sc),
            100.0 * mean(|r| r.synergy)
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn utilization_ordering_matches_table6() {
        let rows = rows(30);
        let mean = |f: fn(&UtilRow) -> f64| stats::mean(&rows.iter().map(f).collect::<Vec<_>>());
        let (non, sf, sc, syn) = (
            mean(|r| r.non_pipelined),
            mean(|r| r.sf),
            mean(|r| r.sc),
            mean(|r| r.synergy),
        );
        // Paper's ordering: non-pipelined ≪ SF ≤ SC ≤ Synergy.
        assert!(non < sf, "non {non} < sf {sf}");
        // SC is fps-optimal, not utilization-optimal, so allow a small
        // inversion vs the paper's ordering here.
        assert!(sf <= sc + 0.08, "sf {sf} vs sc {sc}");
        assert!(sc <= syn + 0.03, "sc {sc} vs synergy {syn}");
        // Synergy approaches full utilization (paper 99.8%; accept ≥85%).
        assert!(syn > 0.85, "synergy util {syn}");
        // Non-pipelined leaves accelerators idle much of the time.
        assert!(non < 0.85, "non-pipelined util {non}");
    }
}
