//! Fig 9 — throughput improvement of Synergy over the single-threaded
//! Darknet CPU baseline (paper: 7.3× average across the seven models).

use crate::sim::{simulate, SimSpec};
use crate::util::bench::{fmt, Table};
use crate::util::stats;

use super::{zoo_networks, Report, BASELINE_FRAMES};

/// (model, baseline fps, synergy fps, speedup) rows.
pub fn rows(frames: usize) -> Vec<(String, f64, f64, f64)> {
    zoo_networks()
        .iter()
        .map(|net| {
            let base = simulate(&SimSpec::cpu_only(net, BASELINE_FRAMES), net);
            let syn = simulate(&SimSpec::synergy(net, frames), net);
            (
                net.config.name.clone(),
                base.fps,
                syn.fps,
                syn.fps / base.fps,
            )
        })
        .collect()
}

pub fn run(frames: usize) -> Report {
    let rows = rows(frames);
    let mut table = Table::new(&["model", "CPU fps", "Synergy fps", "speedup"]);
    for (name, b, s, x) in &rows {
        table.row(vec![name.clone(), fmt(*b), fmt(*s), format!("{x:.2}x")]);
    }
    let mean = stats::mean(&rows.iter().map(|r| r.3).collect::<Vec<_>>());
    Report {
        id: "Fig 9",
        title: "throughput improvement over single-threaded Darknet",
        table: table.render(),
        summary: format!("paper: 7.3x average speedup; measured: {mean:.2}x average"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn average_speedup_in_paper_band() {
        let rows = rows(30);
        let mean = stats::mean(&rows.iter().map(|r| r.3).collect::<Vec<_>>());
        // paper: 7.3x; accept the 4–11x band for the simulated testbed
        assert!((4.0..11.0).contains(&mean), "mean speedup {mean}");
        for (name, _, _, x) in &rows {
            assert!(*x > 2.0, "{name}: speedup {x}");
        }
    }
}
