//! Fig 13 — the advantage of work stealing: CPU baseline vs SF (static
//! mapping + fixed architecture) vs SC (static mapping + custom per-model
//! architecture) vs Synergy (fixed architecture + work stealing).
//!
//! Paper: SF ≈ 6.1× over CPU; Synergy beats SF by 24% on average and SC by
//! 6% — job-granularity stealing balances better than any static split.

use crate::accel::clusters_from_tuples;
use crate::config::HwConfig;
use crate::sched::dse;
use crate::sim::{simulate, SimSpec};
use crate::util::bench::Table;
use crate::util::stats;

use super::{zoo_networks, Report, BASELINE_FRAMES};

pub struct StealRow {
    pub model: String,
    pub sf_x: f64,
    pub sc_x: f64,
    pub synergy_x: f64,
}

pub fn rows(frames: usize) -> Vec<StealRow> {
    let hw = HwConfig::default_zc702();
    zoo_networks()
        .iter()
        .map(|net| {
            let cpu = simulate(&SimSpec::cpu_only(net, BASELINE_FRAMES), net).fps;
            let sf = simulate(&SimSpec::static_fixed(net, frames), net).fps;
            let best = dse::explore(net, frames.min(16));
            let sc_clusters = clusters_from_tuples(&hw, &best.best);
            let sc = simulate(&SimSpec::static_custom(net, sc_clusters, frames), net).fps;
            let syn = simulate(&SimSpec::synergy(net, frames), net).fps;
            StealRow {
                model: net.config.name.clone(),
                sf_x: sf / cpu,
                sc_x: sc / cpu,
                synergy_x: syn / cpu,
            }
        })
        .collect()
}

pub fn run(frames: usize) -> Report {
    let rows = rows(frames);
    let mut table = Table::new(&["model", "SF (x)", "SC (x)", "Synergy (x)"]);
    for r in &rows {
        table.row(vec![
            r.model.clone(),
            format!("{:.2}", r.sf_x),
            format!("{:.2}", r.sc_x),
            format!("{:.2}", r.synergy_x),
        ]);
    }
    let sf_mean = stats::mean(&rows.iter().map(|r| r.sf_x).collect::<Vec<_>>());
    let over_sf = stats::mean(
        &rows
            .iter()
            .map(|r| r.synergy_x / r.sf_x - 1.0)
            .collect::<Vec<_>>(),
    );
    let over_sc = stats::mean(
        &rows
            .iter()
            .map(|r| r.synergy_x / r.sc_x - 1.0)
            .collect::<Vec<_>>(),
    );
    Report {
        id: "Fig 13",
        title: "work stealing vs static mapping (SF/SC)",
        table: table.render(),
        summary: format!(
            "paper: SF 6.1x over CPU, Synergy +24% over SF, +6% over SC; \
             measured: SF {sf_mean:.1}x, Synergy {:+.0}% over SF, {:+.0}% over SC",
            100.0 * over_sf,
            100.0 * over_sc
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synergy_beats_sf_and_matches_or_beats_sc() {
        let rows = rows(24);
        let over_sf = stats::mean(
            &rows
                .iter()
                .map(|r| r.synergy_x / r.sf_x - 1.0)
                .collect::<Vec<_>>(),
        );
        let over_sc = stats::mean(
            &rows
                .iter()
                .map(|r| r.synergy_x / r.sc_x - 1.0)
                .collect::<Vec<_>>(),
        );
        // paper: +24% over SF, +6% over SC (shape: positive, SF gap larger)
        assert!(over_sf > 0.02, "Synergy over SF: {over_sf}");
        assert!(over_sc > -0.05, "Synergy vs SC: {over_sc}");
        assert!(over_sf >= over_sc - 0.02, "SF gap should exceed SC gap");
    }
}
