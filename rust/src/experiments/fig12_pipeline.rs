//! Fig 12 — *pipelined* throughput speedups (multi-threaded, 2 ARM cores):
//! CPU+NEON, CPU+FPGA, CPU+Het vs the single-core CPU baseline.
//! Paper: CPU+Het achieves 15% better throughput than CPU+FPGA on average
//! (37% max, MNIST).

use crate::sim::{simulate, SimSpec};
use crate::util::bench::{fmt, Table};
use crate::util::stats;

use super::{zoo_networks, Report, BASELINE_FRAMES};

pub struct ThroughputRow {
    pub model: String,
    pub cpu_fps: f64,
    pub neon_x: f64,
    pub fpga_x: f64,
    pub het_x: f64,
}

pub fn rows(frames: usize) -> Vec<ThroughputRow> {
    zoo_networks()
        .iter()
        .map(|net| {
            let fps = |spec: &SimSpec| simulate(spec, net).fps;
            let cpu = fps(&SimSpec::cpu_only(net, BASELINE_FRAMES));
            let neon = fps(&SimSpec::synergy(net, frames).with_accels(net, |a| !a.is_fpga()));
            let fpga = fps(&SimSpec::synergy(net, frames).with_accels(net, |a| a.is_fpga()));
            let het = fps(&SimSpec::synergy(net, frames));
            ThroughputRow {
                model: net.config.name.clone(),
                cpu_fps: cpu,
                neon_x: neon / cpu,
                fpga_x: fpga / cpu,
                het_x: het / cpu,
            }
        })
        .collect()
}

pub fn run(frames: usize) -> Report {
    let rows = rows(frames);
    let mut table = Table::new(&[
        "model",
        "CPU fps",
        "CPU+NEON (x)",
        "CPU+FPGA (x)",
        "CPU+Het (x)",
    ]);
    for r in &rows {
        table.row(vec![
            r.model.clone(),
            fmt(r.cpu_fps),
            format!("{:.2}", r.neon_x),
            format!("{:.2}", r.fpga_x),
            format!("{:.2}", r.het_x),
        ]);
    }
    let het_over_fpga = stats::mean(
        &rows
            .iter()
            .map(|r| r.het_x / r.fpga_x - 1.0)
            .collect::<Vec<_>>(),
    );
    Report {
        id: "Fig 12",
        title: "pipelined throughput improvement vs CPU-only",
        table: table.render(),
        summary: format!(
            "paper: Het beats FPGA-only by 15% avg throughput; measured: {:.0}% avg",
            100.0 * het_over_fpga
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ordering_and_band() {
        let rows = rows(30);
        for r in &rows {
            // per-model: Het within noise of FPGA-only or better
            assert!(r.het_x >= r.fpga_x * 0.95, "{}: {} vs {}", r.model, r.het_x, r.fpga_x);
            assert!(r.fpga_x > r.neon_x, "{}", r.model);
        }
        let gain = stats::mean(
            &rows
                .iter()
                .map(|r| r.het_x / r.fpga_x - 1.0)
                .collect::<Vec<_>>(),
        );
        // paper: +15% average; accept 3–40%
        assert!((0.03..0.40).contains(&gain), "het over fpga: {gain}");
    }

    #[test]
    fn pipelined_beats_non_pipelined_counterpart() {
        // Fig 12 speedups must exceed Fig 11's for the same configs.
        let nets = zoo_networks();
        let net = nets.iter().find(|n| n.config.name == "cifar_full").unwrap();
        let non = simulate(&SimSpec::synergy(net, 8).non_pipelined(), net);
        let pip = simulate(&SimSpec::synergy(net, 30), net);
        assert!(pip.fps > non.fps);
    }
}
