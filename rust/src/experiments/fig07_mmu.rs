//! Fig 7 — single-MMU vs multi-MMU performance scaling.
//!
//! The paper's microbenchmark: PEs continuously execute tiled-MM work; with
//! ReconOS' single shared MMU the speedup flattens after a few PEs (every
//! fetch serializes on one translation/transfer channel); with Synergy's
//! one-MMU-per-two-PEs it scales near-linearly.
//!
//! This experiment uses a bandwidth-stressing PE variant (more MAC
//! parallelism than the default F-PE, and short AXI bursts — the ReconOS
//! MEMIF behaviour) so the memory subsystem, not compute, is the binding
//! constraint, as in the paper's figure.

use crate::config::HwConfig;
use crate::memsub::MemSubsystem;
use crate::util::bench::{fmt, Table};

use super::Report;

/// Per-PE per-job parameters of the stress kernel.
const K_TILES: usize = 4;
const COMPUTE_CYCLES_PER_KSTEP: f64 = 12288.0; // macs/cycle ≈ 2.7
const JOBS_TOTAL: usize = 512;

/// Makespan of `jobs` jobs over `n_pes` PEs with the given MMU layout.
pub fn makespan(n_pes: usize, pes_per_mmu: usize, jobs: usize) -> f64 {
    let mut cfg = HwConfig::default_zc702().memsub;
    cfg.mmus = n_pes.div_ceil(pes_per_mmu).max(1);
    cfg.burst_beats = 8; // ReconOS MEMIF-style short bursts
    let mut ms = MemSubsystem::new(&cfg, 100.0);
    let va = ms.alloc_buffer(16 << 20);
    let fpga_hz = 100.0e6;
    let compute = K_TILES as f64 * COMPUTE_CYCLES_PER_KSTEP / fpga_hz;
    let bytes = (K_TILES * 2 * 32 * 32 * 4) as u64;

    // Earliest-free PE takes the next job (pull scheduling).
    let mut pe_free = vec![0.0f64; n_pes];
    for j in 0..jobs {
        // argmin over free times
        let (pe, t) = pe_free
            .iter()
            .copied()
            .enumerate()
            .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
            .unwrap();
        let chan = pe / pes_per_mmu;
        let fetch_done = ms.transfer(chan, va + (j as u64 * bytes) % (8 << 20), bytes, t);
        // double buffering: compute overlaps the fetch of the next tiles
        pe_free[pe] = (t + compute).max(fetch_done);
    }
    pe_free.iter().cloned().fold(0.0, f64::max)
}

/// Speedup curves for 1..=8 PEs.
pub fn scaling() -> Vec<(usize, f64, f64)> {
    let base_single = makespan(1, 8, JOBS_TOTAL);
    let base_multi = makespan(1, 2, JOBS_TOTAL);
    (1..=8)
        .map(|n| {
            let s_single = base_single / makespan(n, 8, JOBS_TOTAL);
            let s_multi = base_multi / makespan(n, 2, JOBS_TOTAL);
            (n, s_single, s_multi)
        })
        .collect()
}

pub fn run() -> Report {
    let rows = scaling();
    let mut table = Table::new(&["#PEs", "speedup (1 MMU)", "speedup (MMU per 2 PEs)"]);
    for (n, s1, sm) in &rows {
        table.row(vec![n.to_string(), fmt(*s1), fmt(*sm)]);
    }
    let (_, s1_8, sm_8) = rows.last().copied().unwrap();
    Report {
        id: "Fig 7",
        title: "single- vs multi-MMU performance",
        table: table.render(),
        summary: format!(
            "paper: single MMU flattens (≈2–3x), multi-MMU near-linear; \
             measured at 8 PEs: single {:.2}x vs multi {:.2}x",
            s1_8, sm_8
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_mmu_flattens_multi_scales() {
        let rows = scaling();
        let (_, s1_8, sm_8) = rows[7];
        // Fig 7a: single MMU saturates well below linear.
        assert!(s1_8 < 5.0, "single-MMU speedup at 8 PEs: {s1_8}");
        // Fig 7b: multi-MMU keeps scaling (>5x at 8 PEs).
        assert!(sm_8 > 5.0, "multi-MMU speedup at 8 PEs: {sm_8}");
        assert!(sm_8 > s1_8 * 1.5, "multi must clearly beat single");
    }

    #[test]
    fn speedups_monotone_in_pe_count_for_multi() {
        let rows = scaling();
        for w in rows.windows(2) {
            // allow small discretization dips near DDR saturation
            assert!(w[1].2 >= w[0].2 * 0.90, "{:?}", rows);
        }
    }

    #[test]
    fn one_pe_speedup_is_one() {
        let rows = scaling();
        assert!((rows[0].1 - 1.0).abs() < 1e-9);
        assert!((rows[0].2 - 1.0).abs() < 1e-9);
    }
}
