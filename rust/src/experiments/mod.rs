//! Experiment harness: one module per table/figure of the paper's §4.
//!
//! Every module exposes `run(frames) -> Report`, where the report carries
//! the regenerated rows plus the paper's published values for side-by-side
//! comparison.  The `rust/benches/*.rs` binaries and the `synergy repro`
//! CLI subcommand are thin wrappers over these.
//!
//! Reproduction is **shape-level** (DESIGN.md §4): orderings, approximate
//! ratios and crossovers are asserted; absolute ZC702 milliseconds are not.

pub mod fig07_mmu;
pub mod fig09_throughput;
pub mod fig10_power;
pub mod fig11_latency;
pub mod fig12_pipeline;
pub mod fig13_worksteal;
pub mod fig14_balance;
pub mod table3_energy;
pub mod table4_soa;
pub mod table5_sc;
pub mod table6_util;

use crate::config::zoo;
use crate::nn::Network;

/// A rendered experiment result.
#[derive(Debug, Clone)]
pub struct Report {
    /// Paper artifact id, e.g. "Fig 9".
    pub id: &'static str,
    pub title: &'static str,
    /// Markdown table of regenerated rows.
    pub table: String,
    /// Headline comparison vs the paper (one-liner summary).
    pub summary: String,
}

impl Report {
    pub fn print(&self) {
        println!("== {} — {} ==", self.id, self.title);
        print!("{}", self.table);
        println!("{}", self.summary);
        println!();
    }
}

/// Load the Table 2 zoo as networks (tile size 32).
pub fn zoo_networks() -> Vec<Network> {
    zoo::load_all()
        .expect("zoo loads")
        .into_iter()
        .map(|cfg| Network::new(cfg, 32).expect("network builds"))
        .collect()
}

/// Default frame counts: enough for steady state, small enough for CI.
pub const BASELINE_FRAMES: usize = 8;
pub const PIPELINE_FRAMES: usize = 40;

/// Run every experiment (the `repro all` path).
pub fn run_all(frames: usize) -> Vec<Report> {
    vec![
        fig07_mmu::run(),
        fig09_throughput::run(frames),
        fig10_power::run(frames),
        fig11_latency::run(frames),
        fig12_pipeline::run(frames),
        fig13_worksteal::run(frames),
        fig14_balance::run(frames),
        table3_energy::run(frames),
        table4_soa::run(frames),
        table5_sc::run(frames.min(16)),
        table6_util::run(frames),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zoo_networks_load() {
        assert_eq!(zoo_networks().len(), 7);
    }
}
