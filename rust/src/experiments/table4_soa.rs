//! Table 4 — comparison with state-of-the-art FPGA CNN frameworks on the
//! same device class: CaffePresso [6], fpgaConvNet [19][20], DeepBurning
//! [21].  The published competitor numbers are constants from the paper;
//! our rows are measured on the simulated ZC702.

use crate::config::zoo;
use crate::nn::Network;
use crate::sim::{simulate, SimSpec};
use crate::util::bench::{fmt, Table};

use super::Report;

/// Published rows (from paper Table 4).  `None` = not reported.
pub struct PublishedRow {
    pub system: &'static str,
    pub benchmark: &'static str,
    pub latency_ms: Option<f64>,
    pub fps: Option<f64>,
    pub gops: Option<f64>,
    pub energy_mj: Option<f64>,
}

pub const PUBLISHED: &[PublishedRow] = &[
    PublishedRow { system: "CaffePresso [6] (7Z045!)", benchmark: "mnist", latency_ms: Some(16.0), fps: Some(62.5), gops: Some(1.19), energy_mj: Some(200.0) },
    PublishedRow { system: "CaffePresso [6] (7Z045!)", benchmark: "cifar_full", latency_ms: Some(28.0), fps: Some(35.7), gops: Some(0.94), energy_mj: Some(500.0) },
    PublishedRow { system: "fpgaConvNet [19][20]", benchmark: "mnist", latency_ms: None, fps: None, gops: Some(0.48), energy_mj: None },
    PublishedRow { system: "fpgaConvNet [19][20]", benchmark: "mpcnn", latency_ms: None, fps: None, gops: Some(0.74), energy_mj: None },
    PublishedRow { system: "DeepBurning [21]", benchmark: "mnist", latency_ms: Some(14.3), fps: Some(69.9), gops: Some(1.33), energy_mj: Some(150.0) },
    PublishedRow { system: "DeepBurning [21]", benchmark: "cifar_full", latency_ms: Some(21.4), fps: Some(46.7), gops: Some(1.23), energy_mj: Some(63.0) },
    PublishedRow { system: "Synergy (paper)", benchmark: "mnist", latency_ms: Some(24.3), fps: Some(96.2), gops: Some(2.15), energy_mj: Some(22.8) },
    PublishedRow { system: "Synergy (paper)", benchmark: "cifar_full", latency_ms: Some(33.2), fps: Some(63.5), gops: Some(1.67), energy_mj: Some(33.7) },
    PublishedRow { system: "Synergy (paper)", benchmark: "mpcnn", latency_ms: Some(12.2), fps: Some(136.4), gops: Some(1.33), energy_mj: Some(14.4) },
];

pub struct MeasuredRow {
    pub benchmark: String,
    pub latency_ms: f64,
    pub fps: f64,
    pub gops: f64,
    pub energy_mj: f64,
}

pub fn measured(frames: usize) -> Vec<MeasuredRow> {
    ["mnist", "cifar_full", "mpcnn"]
        .iter()
        .map(|name| {
            let net = Network::new(zoo::load(name).unwrap(), 32).unwrap();
            let r = simulate(&SimSpec::synergy(&net, frames), &net);
            MeasuredRow {
                benchmark: name.to_string(),
                latency_ms: r.mean_latency_s * 1e3,
                fps: r.fps,
                gops: r.gops,
                energy_mj: r.energy.energy_per_frame_mj,
            }
        })
        .collect()
}

pub fn run(frames: usize) -> Report {
    let mut table = Table::new(&["system", "benchmark", "latency ms", "fps", "GOPS", "mJ/frame"]);
    let cell = |v: Option<f64>| v.map(fmt).unwrap_or_else(|| "-".into());
    for p in PUBLISHED {
        table.row(vec![
            p.system.into(),
            p.benchmark.into(),
            cell(p.latency_ms),
            cell(p.fps),
            cell(p.gops),
            cell(p.energy_mj),
        ]);
    }
    for m in measured(frames) {
        table.row(vec![
            "Synergy (this repro)".into(),
            m.benchmark.clone(),
            fmt(m.latency_ms),
            fmt(m.fps),
            fmt(m.gops),
            fmt(m.energy_mj),
        ]);
    }
    Report {
        id: "Table 4",
        title: "comparison with state-of-the-art FPGA CNN frameworks",
        table: table.render(),
        summary: "paper's claim: Synergy (f32!) beats fixed-point competitors on fps, \
                  GOPS and energy; measured rows must preserve those wins"
            .into(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn published(system: &str, bench: &str) -> &'static PublishedRow {
        PUBLISHED
            .iter()
            .find(|p| p.system.starts_with(system) && p.benchmark == bench)
            .unwrap()
    }

    #[test]
    fn measured_beats_competitors_like_the_paper() {
        let rows = measured(30);
        for m in &rows {
            // Synergy's published wins that must survive: higher fps and
            // lower energy than DeepBurning/CaffePresso on shared benches.
            if m.benchmark == "mnist" {
                assert!(m.fps > published("DeepBurning", "mnist").fps.unwrap() * 0.6, "{}", m.fps);
                assert!(m.energy_mj < published("DeepBurning", "mnist").energy_mj.unwrap());
                assert!(m.gops > published("fpgaConvNet", "mnist").gops.unwrap());
            }
            if m.benchmark == "cifar_full" {
                assert!(m.energy_mj < published("DeepBurning", "cifar_full").energy_mj.unwrap());
                assert!(m.fps > published("CaffePresso", "cifar_full").fps.unwrap());
            }
            if m.benchmark == "mpcnn" {
                assert!(m.gops > published("fpgaConvNet", "mpcnn").gops.unwrap());
            }
        }
    }

    #[test]
    fn measured_close_to_paper_synergy_rows() {
        // within 2x of the paper's own Synergy numbers in both directions
        for m in measured(30) {
            let p = published("Synergy (paper)", &m.benchmark);
            let ratio = m.fps / p.fps.unwrap();
            assert!((0.4..2.5).contains(&ratio), "{}: fps ratio {ratio}", m.benchmark);
            let eratio = m.energy_mj / p.energy_mj.unwrap();
            assert!((0.3..2.5).contains(&eratio), "{}: energy ratio {eratio}", m.benchmark);
        }
    }
}
