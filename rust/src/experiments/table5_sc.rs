//! Table 5 — best cluster configurations per model under static mapping +
//! custom architectures (the SC designs), found by exhaustive DSE over all
//! two-cluster partitions of the accelerator pool.

use crate::sched::dse;
use crate::util::bench::Table;

use super::{zoo_networks, Report};

pub struct ScRow {
    pub model: String,
    pub cluster0: String,
    pub cluster1: String,
    pub fps: f64,
    pub evaluated: usize,
}

pub fn rows(frames: usize) -> Vec<ScRow> {
    zoo_networks()
        .iter()
        .map(|net| {
            let r = dse::explore(net, frames);
            ScRow {
                model: net.config.name.clone(),
                cluster0: dse::describe_tuple(&r.best[0]),
                cluster1: dse::describe_tuple(&r.best[1]),
                fps: r.best_fps,
                evaluated: r.evaluated,
            }
        })
        .collect()
}

pub fn run(frames: usize) -> Report {
    let rows = rows(frames);
    let mut table = Table::new(&["model", "cluster 0", "cluster 1", "fps", "configs tried"]);
    for r in &rows {
        table.row(vec![
            r.model.clone(),
            r.cluster0.clone(),
            r.cluster1.clone(),
            format!("{:.1}", r.fps),
            r.evaluated.to_string(),
        ]);
    }
    Report {
        id: "Table 5",
        title: "best SC cluster configurations (exhaustive DSE)",
        table: table.render(),
        summary: "paper: per-model optima differ (e.g. 2S+2F | 2N+4F); the point is \
                  that Synergy's ONE fixed config + stealing matches these (Fig 13)"
            .into(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dse_explores_full_space_and_uses_all_resources() {
        // One representative model (full 7-model DSE runs in the bench).
        let nets = super::super::zoo_networks();
        let net = nets.iter().find(|n| n.config.name == "mpcnn").unwrap();
        let r = dse::explore(net, 10);
        assert_eq!(r.evaluated, 61);
        let total: (usize, usize, usize) = r.best.iter().fold((0, 0, 0), |acc, t| {
            (acc.0 + t.0, acc.1 + t.1, acc.2 + t.2)
        });
        assert_eq!(total, (2, 2, 6), "best config must use the whole pool");
        assert!(r.best_fps > 0.0);
    }
}
