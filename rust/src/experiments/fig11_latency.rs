//! Fig 11 — per-frame latency of *non-pipelined* (single-threaded,
//! single-core) designs: CPU+NEON, CPU+FPGA, CPU+Het vs the CPU-only
//! baseline.  Paper: CPU+Het improves latency by 12% on average over
//! CPU+FPGA (45% max, MPCNN).

use crate::sim::{simulate, SimSpec};
use crate::util::bench::{fmt, Table};
use crate::util::stats;

use super::{zoo_networks, Report, BASELINE_FRAMES};

pub struct LatencyRow {
    pub model: String,
    pub cpu_ms: f64,
    pub neon_x: f64,
    pub fpga_x: f64,
    pub het_x: f64,
}

pub fn rows(_frames: usize) -> Vec<LatencyRow> {
    zoo_networks()
        .iter()
        .map(|net| {
            let frames = BASELINE_FRAMES;
            let lat = |spec: &SimSpec| simulate(spec, net).mean_latency_s * 1e3;
            let cpu = lat(&SimSpec::cpu_only(net, frames));
            let neon = lat(&SimSpec::synergy(net, frames)
                .with_accels(net, |a| !a.is_fpga())
                .non_pipelined());
            let fpga = lat(&SimSpec::synergy(net, frames)
                .with_accels(net, |a| a.is_fpga())
                .non_pipelined());
            let het = lat(&SimSpec::synergy(net, frames).non_pipelined());
            LatencyRow {
                model: net.config.name.clone(),
                cpu_ms: cpu,
                neon_x: cpu / neon,
                fpga_x: cpu / fpga,
                het_x: cpu / het,
            }
        })
        .collect()
}

pub fn run(frames: usize) -> Report {
    let rows = rows(frames);
    let mut table = Table::new(&[
        "model",
        "CPU (ms)",
        "CPU+NEON (x)",
        "CPU+FPGA (x)",
        "CPU+Het (x)",
    ]);
    for r in &rows {
        table.row(vec![
            r.model.clone(),
            fmt(r.cpu_ms),
            format!("{:.2}", r.neon_x),
            format!("{:.2}", r.fpga_x),
            format!("{:.2}", r.het_x),
        ]);
    }
    let het_over_fpga = stats::mean(
        &rows
            .iter()
            .map(|r| r.het_x / r.fpga_x - 1.0)
            .collect::<Vec<_>>(),
    );
    Report {
        id: "Fig 11",
        title: "non-pipelined latency improvement vs CPU-only",
        table: table.render(),
        summary: format!(
            "paper: heterogeneity (Het vs FPGA-only) improves latency 12% avg; \
             measured: {:.0}% avg",
            100.0 * het_over_fpga
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn het_beats_fpga_beats_neon() {
        for r in rows(8) {
            assert!(r.het_x >= r.fpga_x * 0.999, "{}: het {} vs fpga {}", r.model, r.het_x, r.fpga_x);
            assert!(r.fpga_x > r.neon_x, "{}: fpga {} vs neon {}", r.model, r.fpga_x, r.neon_x);
            assert!(r.neon_x > 1.0, "{}: neon {}", r.model, r.neon_x);
        }
    }

    #[test]
    fn het_gain_over_fpga_in_paper_band() {
        let rows = rows(8);
        let gain = stats::mean(
            &rows
                .iter()
                .map(|r| r.het_x / r.fpga_x - 1.0)
                .collect::<Vec<_>>(),
        );
        // paper: +12% average (max 45%); accept 3–35%
        assert!((0.03..0.35).contains(&gain), "het over fpga: {gain}");
    }
}
