//! Fig 10 — power distribution and energy consumption of the Synergy
//! system (paper: FPGA ≈27% of ≈2.08 W average; ARM + DDR dominate;
//! 14.4–55.8 mJ/frame across the zoo).

use crate::sim::{simulate, SimSpec};
use crate::util::bench::{fmt, Table};
use crate::util::stats;

use super::{zoo_networks, Report};

pub struct PowerRow {
    pub model: String,
    pub total_w: f64,
    pub fpga_frac: f64,
    pub arm_frac: f64,
    pub ddr_frac: f64,
    pub energy_mj: f64,
}

pub fn rows(frames: usize) -> Vec<PowerRow> {
    zoo_networks()
        .iter()
        .map(|net| {
            let r = simulate(&SimSpec::synergy(net, frames), net);
            let e = &r.energy;
            PowerRow {
                model: net.config.name.clone(),
                total_w: e.avg_power_w,
                fpga_frac: e.fpga_fraction(),
                arm_frac: (e.arm_w + e.neon_w) / e.avg_power_w,
                ddr_frac: e.ddr_w / e.avg_power_w,
                energy_mj: e.energy_per_frame_mj,
            }
        })
        .collect()
}

pub fn run(frames: usize) -> Report {
    let rows = rows(frames);
    let mut table = Table::new(&["model", "power (W)", "FPGA %", "ARM+NEON %", "DDR %", "mJ/frame"]);
    for r in &rows {
        table.row(vec![
            r.model.clone(),
            fmt(r.total_w),
            format!("{:.0}%", 100.0 * r.fpga_frac),
            format!("{:.0}%", 100.0 * r.arm_frac),
            format!("{:.0}%", 100.0 * r.ddr_frac),
            fmt(r.energy_mj),
        ]);
    }
    let mean_w = stats::mean(&rows.iter().map(|r| r.total_w).collect::<Vec<_>>());
    let mean_fpga = stats::mean(&rows.iter().map(|r| r.fpga_frac).collect::<Vec<_>>());
    Report {
        id: "Fig 10",
        title: "power distribution and energy consumption",
        table: table.render(),
        summary: format!(
            "paper: ≈2.08 W avg, FPGA ≈27%, 14.4–55.8 mJ/frame; \
             measured: {:.2} W avg, FPGA {:.0}%, {:.1}–{:.1} mJ/frame",
            mean_w,
            100.0 * mean_fpga,
            rows.iter().map(|r| r.energy_mj).fold(f64::INFINITY, f64::min),
            rows.iter().map(|r| r.energy_mj).fold(0.0, f64::max),
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn power_shape_matches_paper() {
        let rows = rows(30);
        for r in &rows {
            // total in the embedded-board band
            assert!((1.0..3.0).contains(&r.total_w), "{}: {} W", r.model, r.total_w);
            // FPGA is a minority share; ARM+DDR dominate (paper Fig 10)
            assert!(r.fpga_frac < 0.45, "{}: fpga {}", r.model, r.fpga_frac);
            assert!(r.arm_frac + r.ddr_frac > 0.4, "{}", r.model);
        }
        // energy band ≈ paper's 14.4–55.8 mJ (widened)
        let min = rows.iter().map(|r| r.energy_mj).fold(f64::INFINITY, f64::min);
        let max = rows.iter().map(|r| r.energy_mj).fold(0.0, f64::max);
        assert!(min > 5.0 && max < 80.0, "energy band {min}–{max}");
    }
}
