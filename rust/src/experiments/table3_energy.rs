//! Table 3 — energy and performance-per-watt: original single-threaded
//! Darknet vs Synergy.  Paper: −80.13% mean energy, 5.28× mean GOPS/W
//! speedup despite +36.63% power draw.

use crate::sim::{simulate, SimSpec};
use crate::util::bench::{fmt, Table};
use crate::util::stats;

use super::{zoo_networks, Report, BASELINE_FRAMES};

pub struct EnergyRow {
    pub model: String,
    pub orig_mj: f64,
    pub syn_mj: f64,
    pub reduction_pct: f64,
    pub orig_gops_w: f64,
    pub syn_gops_w: f64,
    pub gops_w_speedup: f64,
    pub power_increase_pct: f64,
}

pub fn rows(frames: usize) -> Vec<EnergyRow> {
    zoo_networks()
        .iter()
        .map(|net| {
            let base = simulate(&SimSpec::cpu_only(net, BASELINE_FRAMES), net);
            let syn = simulate(&SimSpec::synergy(net, frames), net);
            let orig_mj = base.energy.energy_per_frame_mj;
            let syn_mj = syn.energy.energy_per_frame_mj;
            EnergyRow {
                model: net.config.name.clone(),
                orig_mj,
                syn_mj,
                reduction_pct: 100.0 * (1.0 - syn_mj / orig_mj),
                orig_gops_w: base.gops / base.energy.avg_power_w,
                syn_gops_w: syn.gops / syn.energy.avg_power_w,
                gops_w_speedup: (syn.gops / syn.energy.avg_power_w)
                    / (base.gops / base.energy.avg_power_w),
                power_increase_pct: 100.0
                    * (syn.energy.avg_power_w / base.energy.avg_power_w - 1.0),
            }
        })
        .collect()
}

pub fn run(frames: usize) -> Report {
    let rows = rows(frames);
    let mut table = Table::new(&[
        "model",
        "orig mJ/f",
        "Synergy mJ/f",
        "reduction",
        "orig GOPS/W",
        "Syn GOPS/W",
        "speedup",
    ]);
    for r in &rows {
        table.row(vec![
            r.model.clone(),
            fmt(r.orig_mj),
            fmt(r.syn_mj),
            format!("-{:.1}%", r.reduction_pct),
            format!("{:.2}", r.orig_gops_w),
            format!("{:.2}", r.syn_gops_w),
            format!("{:.2}x", r.gops_w_speedup),
        ]);
    }
    let mean_red = stats::mean(&rows.iter().map(|r| r.reduction_pct).collect::<Vec<_>>());
    let mean_speedup = stats::mean(&rows.iter().map(|r| r.gops_w_speedup).collect::<Vec<_>>());
    let mean_pow = stats::mean(&rows.iter().map(|r| r.power_increase_pct).collect::<Vec<_>>());
    Report {
        id: "Table 3",
        title: "energy and performance-per-watt, Darknet vs Synergy",
        table: table.render(),
        summary: format!(
            "paper: -80.13% energy, 5.28x GOPS/W, +36.63% power; \
             measured: -{mean_red:.1}% energy, {mean_speedup:.2}x GOPS/W, \
             {mean_pow:+.1}% power"
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn energy_reduction_and_efficiency_in_band() {
        let rows = rows(30);
        let mean_red = stats::mean(&rows.iter().map(|r| r.reduction_pct).collect::<Vec<_>>());
        // paper: 80.13% mean reduction; accept 60–90%
        assert!((60.0..90.0).contains(&mean_red), "reduction {mean_red}%");
        for r in &rows {
            assert!(r.syn_mj < r.orig_mj, "{}", r.model);
            assert!(r.gops_w_speedup > 2.0, "{}: {}", r.model, r.gops_w_speedup);
            // Synergy draws MORE power but finishes MUCH faster.
            assert!(r.power_increase_pct > 0.0, "{}", r.model);
        }
    }
}
