//! Fig 14 — dynamic load balancing in CIFAR_Alex: per-cluster execution
//! time per frame under SF vs under Synergy (same cluster architecture).
//!
//! Paper: SF runs Cluster-0 at 24.3 ms vs Cluster-1 at 12.3 ms per frame
//! (imbalanced); work stealing balances them to 22.2 / 20.9 ms.
//! Our synthetic CIFAR_Alex has a different per-layer split, so the
//! *absolute* times and even the direction of the imbalance differ; the
//! reproduced property is: SF shows a large cluster imbalance ratio that
//! work stealing collapses.

use crate::config::zoo;
use crate::nn::Network;
use crate::sim::{simulate, SimResult, SimSpec};
use crate::util::bench::{fmt, Table};

use super::Report;

pub struct BalanceResult {
    pub sf_cluster_ms: Vec<f64>,
    pub ws_cluster_ms: Vec<f64>,
    pub sf_imbalance: f64,
    pub ws_imbalance: f64,
    pub sf: SimResult,
    pub ws: SimResult,
}

fn cluster_ms(r: &SimResult) -> Vec<f64> {
    r.cluster_layer_s_per_frame
        .iter()
        .map(|per_layer| per_layer.iter().sum::<f64>() * 1e3)
        .collect()
}

fn imbalance(ms: &[f64]) -> f64 {
    let max = ms.iter().cloned().fold(0.0, f64::max);
    let min = ms
        .iter()
        .cloned()
        .filter(|&v| v > 1e-9)
        .fold(f64::INFINITY, f64::min);
    if min.is_finite() {
        max / min
    } else {
        f64::INFINITY
    }
}

pub fn measure(frames: usize) -> BalanceResult {
    let net = Network::new(zoo::load("cifar_alex").unwrap(), 32).unwrap();
    let sf = simulate(&SimSpec::static_fixed(&net, frames), &net);
    let ws = simulate(&SimSpec::synergy(&net, frames), &net);
    let sf_ms = cluster_ms(&sf);
    let ws_ms = cluster_ms(&ws);
    BalanceResult {
        sf_imbalance: imbalance(&sf_ms),
        ws_imbalance: imbalance(&ws_ms),
        sf_cluster_ms: sf_ms,
        ws_cluster_ms: ws_ms,
        sf,
        ws,
    }
}

pub fn run(frames: usize) -> Report {
    let b = measure(frames);
    let mut table = Table::new(&["design", "cluster-0 ms/frame", "cluster-1 ms/frame", "imbalance"]);
    table.row(vec![
        "SF (static)".into(),
        fmt(b.sf_cluster_ms[0]),
        fmt(b.sf_cluster_ms[1]),
        format!("{:.2}", b.sf_imbalance),
    ]);
    table.row(vec![
        "Synergy (stealing)".into(),
        fmt(b.ws_cluster_ms[0]),
        fmt(b.ws_cluster_ms[1]),
        format!("{:.2}", b.ws_imbalance),
    ]);
    Report {
        id: "Fig 14",
        title: "dynamic load balancing in CIFAR_Alex",
        table: table.render(),
        summary: format!(
            "paper: SF 24.3/12.3 ms (1.98x imbalance) -> Synergy 22.2/20.9 ms \
             (1.06x); measured imbalance: SF {:.2}x -> Synergy {:.2}x \
             (jobs stolen: {})",
            b.sf_imbalance, b.ws_imbalance, b.ws.jobs_stolen
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stealing_collapses_cluster_imbalance() {
        let b = measure(30);
        assert!(
            b.sf_imbalance > 1.3,
            "SF should be imbalanced: {:.2}",
            b.sf_imbalance
        );
        assert!(
            b.ws_imbalance < b.sf_imbalance,
            "stealing must reduce imbalance: {:.2} -> {:.2}",
            b.sf_imbalance,
            b.ws_imbalance
        );
        assert!(b.ws.jobs_stolen > 0);
        // Throughput improves alongside balance (the Fig 13 link).
        assert!(b.ws.fps >= b.sf.fps);
    }
}
