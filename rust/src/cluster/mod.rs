//! Accelerator clusters (paper §3.1.1 "Accelerator Clusters"): each cluster
//! owns a private synchronized *job-queue bank*, split per job class;
//! members pull from the sub-queues their own backend supports (pull-based
//! round-robin: free accelerators take the next job they can execute, which
//! degenerates to round-robin under uniform service).  The work-stealing
//! thief thread rebalances across banks (`sched::worksteal`).

pub mod queue;

pub use queue::{JobQueue, QueueBank};
