//! Accelerator clusters (paper §3.1.1 "Accelerator Clusters"): each cluster
//! owns a private synchronized *job queue*; members pull jobs round-robin
//! (pull-based round-robin: free accelerators take the next job, which
//! degenerates to round-robin under uniform service).  The work-stealing
//! thief thread rebalances across queues (`sched::worksteal`).

pub mod queue;

pub use queue::JobQueue;
