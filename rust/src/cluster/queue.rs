//! Synchronized job queues — the paper's per-cluster "Job Queue" (a
//! synchronous buffer storing jobs), in two shapes:
//!
//! * [`JobQueue`] — the flat MPMC blocking deque (owners pop the front,
//!   thieves steal from the back), kept as the generic primitive;
//! * [`QueueBank`] — a [`ClassMask`]-indexed bank of per-class sub-queues.
//!   This is what clusters use under member-level routing: each delegate
//!   pops from the *union* of sub-queues its own backend supports
//!   ([`QueueBank::pop_any_timeout`]), so a NEON member of a NEON+PE
//!   cluster keeps serving FC/im2col jobs while the PE member drains CONV
//!   tiles.  The thief steals per sub-queue ([`QueueBank::steal_where`])
//!   filtered by the *idle member's* capability mask (intersected with the
//!   destination cluster's accept union as a safety net).

use crate::util::sync::{lock_clean, wait_clean, wait_timeout_clean, Condvar, Mutex};
use std::collections::VecDeque;
use std::time::{Duration, Instant};

use crate::mm::job::{ClassMask, Classed, JobClass};

struct Inner<T> {
    deque: VecDeque<T>,
    closed: bool,
}

/// MPMC blocking deque: owners pop the front, thieves steal from the back.
pub struct JobQueue<T> {
    inner: Mutex<Inner<T>>,
    cv: Condvar,
}

impl<T> Default for JobQueue<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> JobQueue<T> {
    pub fn new() -> Self {
        JobQueue {
            inner: Mutex::new(Inner {
                deque: VecDeque::new(),
                closed: false,
            }),
            cv: Condvar::new(),
        }
    }

    /// Push one job (to the back).  Returns false if the queue was closed.
    /// `notify_one` is sufficient here (unlike the broadcast queues):
    /// every waiter on `cv` is a popper with the same predicate — "the
    /// deque is non-empty" — and one pushed item satisfies exactly one
    /// popper, which consumes it without ever waiting for more room
    /// (the deque is unbounded, so there is no second waiter class whose
    /// predicate the woken thread could fail to satisfy).
    pub fn push(&self, item: T) -> bool {
        let mut g = lock_clean(&self.inner);
        if g.closed {
            return false;
        }
        g.deque.push_back(item);
        drop(g);
        self.cv.notify_one();
        true
    }

    /// Push a batch (used by the stealer to deposit stolen jobs).
    pub fn push_batch(&self, items: Vec<T>) -> bool {
        if items.is_empty() {
            return true;
        }
        let mut g = lock_clean(&self.inner);
        if g.closed {
            return false;
        }
        for it in items {
            g.deque.push_back(it);
        }
        drop(g);
        self.cv.notify_all();
        true
    }

    /// Blocking pop from the front; None once closed *and* drained.
    pub fn pop_blocking(&self) -> Option<T> {
        let mut g = lock_clean(&self.inner);
        loop {
            if let Some(item) = g.deque.pop_front() {
                return Some(item);
            }
            if g.closed {
                return None;
            }
            g = wait_clean(&self.cv, g);
        }
    }

    /// Blocking pop with timeout; `Ok(None)` = closed+drained, `Err(())` =
    /// timed out (caller may try stealing — the idle notification path).
    pub fn pop_timeout(&self, timeout: Duration) -> Result<Option<T>, ()> {
        let mut g = lock_clean(&self.inner);
        loop {
            if let Some(item) = g.deque.pop_front() {
                return Ok(Some(item));
            }
            if g.closed {
                return Ok(None);
            }
            let (guard, timed_out) = wait_timeout_clean(&self.cv, g, timeout);
            g = guard;
            if timed_out {
                if let Some(item) = g.deque.pop_front() {
                    return Ok(Some(item));
                }
                if g.closed {
                    return Ok(None);
                }
                return Err(());
            }
        }
    }

    /// Non-blocking pop.
    pub fn try_pop(&self) -> Option<T> {
        lock_clean(&self.inner).deque.pop_front()
    }

    /// Non-blocking pop of up to `n` jobs from the front (the owner side).
    /// Delegates use this to drain a micro-batch's jobs in one lock
    /// acquisition and execute them back-to-back.
    pub fn pop_upto(&self, n: usize) -> Vec<T> {
        let mut g = lock_clean(&self.inner);
        let take = n.min(g.deque.len());
        let mut out = Vec::with_capacity(take);
        for _ in 0..take {
            if let Some(item) = g.deque.pop_front() {
                out.push(item);
            }
        }
        out
    }

    /// Steal up to `n` jobs from the back (the victim side).
    pub fn steal(&self, n: usize) -> Vec<T> {
        self.steal_where(n, |_| true)
    }

    /// Steal up to `n` jobs from the back, taking only those matching
    /// `pred` (capability-aware stealing: a thief must not deposit jobs a
    /// destination cluster cannot execute).  Non-matching jobs keep their
    /// relative order.  Single linear back-to-front pass — the lock is
    /// held on the busiest queue, so no quadratic `remove` shifting.
    pub fn steal_where(&self, n: usize, pred: impl Fn(&T) -> bool) -> Vec<T> {
        if n == 0 {
            return Vec::new();
        }
        let mut g = lock_clean(&self.inner);
        let mut out = Vec::new();
        let mut skipped = Vec::new();
        while out.len() < n {
            match g.deque.pop_back() {
                Some(item) if pred(&item) => out.push(item),
                Some(item) => skipped.push(item),
                None => break,
            }
        }
        // Restore the non-matching tail in its original order.
        for item in skipped.into_iter().rev() {
            g.deque.push_back(item);
        }
        out
    }

    /// Snapshot of queue occupancy per job class: `result[i]` counts items
    /// whose `classify` index is `i` (out-of-range indices are dropped).
    /// Used by the thief's cost-weighted victim selection.
    pub fn class_counts(&self, n_classes: usize, classify: impl Fn(&T) -> usize) -> Vec<usize> {
        let g = lock_clean(&self.inner);
        let mut out = vec![0usize; n_classes];
        for item in &g.deque {
            let i = classify(item);
            if i < n_classes {
                out[i] += 1;
            }
        }
        out
    }

    pub fn len(&self) -> usize {
        lock_clean(&self.inner).deque.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Close: pops drain the remainder then return None.  Broadcast, not
    /// `notify_one` — every parked popper must wake to observe `closed`,
    /// or all but one of them sleep forever (push's single-wake argument
    /// does not apply: close satisfies *every* waiter at once).
    pub fn close(&self) {
        lock_clean(&self.inner).closed = true;
        self.cv.notify_all();
    }

    pub fn is_closed(&self) -> bool {
        lock_clean(&self.inner).closed
    }
}

// ------------------------------------------------------------------ bank

struct BankInner<T> {
    /// One sub-queue per [`JobClass`] dense index.
    subs: Vec<VecDeque<T>>,
    closed: bool,
    /// Round-robin cursors, one per capability mask (masks are dense
    /// `u8` bit-sets): the class a pop with that mask scans first.
    /// Keyed per mask — a single shared cursor would let a narrow-mask
    /// popper keep resetting a wider-mask popper's scan position and
    /// starve a class indefinitely; per mask, no eligible non-empty
    /// sub-queue is bypassed more than `JobClass::COUNT - 1` consecutive
    /// pops of that mask (bounded bypass).
    next: [usize; 1 << JobClass::COUNT],
}

impl<T> BankInner<T> {
    /// First eligible non-empty sub-queue at/after `mask`'s cursor, cyclic.
    fn pick(&self, mask: ClassMask) -> Option<usize> {
        let start = self.next[mask.bits() as usize];
        (0..JobClass::COUNT)
            .map(|off| (start + off) % JobClass::COUNT)
            .find(|&i| mask.supports_index(i) && !self.subs[i].is_empty())
    }

    fn pop_picked(&mut self, mask: ClassMask, i: usize) -> T {
        self.next[mask.bits() as usize] = (i + 1) % JobClass::COUNT;
        self.subs[i].pop_front().expect("picked sub-queue non-empty")
    }

    fn masked_len(&self, mask: ClassMask) -> usize {
        self.subs
            .iter()
            .enumerate()
            .filter(|(i, _)| mask.supports_index(*i))
            .map(|(_, q)| q.len())
            .sum()
    }
}

/// A per-cluster bank of per-class sub-queues under one lock, popped by
/// capability mask (see the module docs).  `T: Classed` decides which
/// sub-queue a pushed item lands in.
pub struct QueueBank<T> {
    inner: Mutex<BankInner<T>>,
    cv: Condvar,
}

impl<T: Classed> Default for QueueBank<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T: Classed> QueueBank<T> {
    pub fn new() -> Self {
        QueueBank {
            inner: Mutex::new(BankInner {
                subs: (0..JobClass::COUNT).map(|_| VecDeque::new()).collect(),
                closed: false,
                next: [0; 1 << JobClass::COUNT],
            }),
            cv: Condvar::new(),
        }
    }

    /// Push one item onto its class sub-queue.  False if the bank was
    /// closed.  Wake-ups are broadcast: a member whose mask excludes the
    /// pushed class must not swallow the only notification.
    pub fn push(&self, item: T) -> bool {
        let i = item.class_index();
        assert!(i < JobClass::COUNT, "job class index {i} out of range");
        let mut g = lock_clean(&self.inner);
        if g.closed {
            return false;
        }
        g.subs[i].push_back(item);
        drop(g);
        self.cv.notify_all();
        true
    }

    /// Push a batch in one lock acquisition (job generators and the
    /// thief's deposit path).
    pub fn push_batch(&self, items: Vec<T>) -> bool {
        if items.is_empty() {
            return true;
        }
        let mut g = lock_clean(&self.inner);
        if g.closed {
            return false;
        }
        for item in items {
            let i = item.class_index();
            assert!(i < JobClass::COUNT, "job class index {i} out of range");
            g.subs[i].push_back(item);
        }
        drop(g);
        self.cv.notify_all();
        true
    }

    /// Non-blocking pop from the union of sub-queues in `mask`
    /// (round-robin across classes, FIFO within one).
    pub fn try_pop_any(&self, mask: ClassMask) -> Option<T> {
        let mut g = lock_clean(&self.inner);
        g.pick(mask).map(|i| g.pop_picked(mask, i))
    }

    /// Blocking pop over the union of sub-queues in `mask`.  `Ok(None)` =
    /// closed and every eligible sub-queue drained (classes outside the
    /// caller's mask are not the caller's to wait for); `Err(())` = timed
    /// out (the idle-notification path).
    ///
    /// The deadline is fixed at entry: pushes of classes *outside* the
    /// caller's mask broadcast-wake every waiter, and re-arming the full
    /// timeout on each such wakeup would let sustained foreign-class
    /// traffic postpone the timeout forever — a CONV-only member would
    /// then never report idle and stealing would starve.
    pub fn pop_any_timeout(&self, mask: ClassMask, timeout: Duration) -> Result<Option<T>, ()> {
        let deadline = Instant::now() + timeout;
        let mut g = lock_clean(&self.inner);
        loop {
            if let Some(i) = g.pick(mask) {
                return Ok(Some(g.pop_picked(mask, i)));
            }
            if g.closed {
                return Ok(None);
            }
            let now = Instant::now();
            if now >= deadline {
                return Err(());
            }
            let (guard, _timed_out) = wait_timeout_clean(&self.cv, g, deadline - now);
            g = guard;
        }
    }

    /// Non-blocking pop of up to `n` items from the union of sub-queues in
    /// `mask`, one lock acquisition (delegate drain batches).  Round-robin
    /// across classes so one deep sub-queue cannot starve the others.
    pub fn pop_upto(&self, mask: ClassMask, n: usize) -> Vec<T> {
        let mut g = lock_clean(&self.inner);
        let mut out = Vec::new();
        while out.len() < n {
            match g.pick(mask) {
                Some(i) => out.push(g.pop_picked(mask, i)),
                None => break,
            }
        }
        out
    }

    /// Steal up to `n` items from the *backs* of the sub-queues in `mask`,
    /// heaviest sub-queue first (the victim side; owners keep the fronts).
    pub fn steal_where(&self, n: usize, mask: ClassMask) -> Vec<T> {
        if n == 0 {
            return Vec::new();
        }
        let mut g = lock_clean(&self.inner);
        let mut out = Vec::new();
        while out.len() < n {
            let heaviest = (0..JobClass::COUNT)
                .filter(|&i| mask.supports_index(i) && !g.subs[i].is_empty())
                .max_by_key(|&i| g.subs[i].len());
            match heaviest {
                Some(i) => out.push(g.subs[i].pop_back().expect("non-empty")),
                None => break,
            }
        }
        out
    }

    /// Occupancy per class sub-queue — O(classes), no walk (the thief's
    /// victim snapshot runs this on every queue).
    pub fn class_counts(&self) -> [usize; JobClass::COUNT] {
        let g = lock_clean(&self.inner);
        let mut out = [0usize; JobClass::COUNT];
        for (o, q) in out.iter_mut().zip(&g.subs) {
            *o = q.len();
        }
        out
    }

    /// Items across every sub-queue.
    pub fn len(&self) -> usize {
        lock_clean(&self.inner).subs.iter().map(|q| q.len()).sum()
    }

    /// Items across the sub-queues in `mask` (routing load probe).
    pub fn len_where(&self, mask: ClassMask) -> usize {
        lock_clean(&self.inner).masked_len(mask)
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Close: pops drain the remainder then return None.  Broadcast —
    /// waiters carry *different* masks, so waking any single one could
    /// hand the close notification to a member that pops its last
    /// eligible item and leaves, while a differently-masked member
    /// sleeps through shutdown.  `tests/loom_sync.rs` pins this.
    pub fn close(&self) {
        lock_clean(&self.inner).closed = true;
        self.cv.notify_all();
    }

    pub fn is_closed(&self) -> bool {
        lock_clean(&self.inner).closed
    }
}

// Thread/timing tests run on real OS scheduling; the loom build checks
// this module through `tests/loom_sync.rs` instead.
#[cfg(all(test, not(loom)))]
mod tests {
    use super::*;
    use std::sync::Arc;
    use std::thread;

    #[test]
    fn fifo_order_for_single_consumer() {
        let q = JobQueue::new();
        for i in 0..5 {
            assert!(q.push(i));
        }
        q.close();
        let mut got = Vec::new();
        while let Some(v) = q.pop_blocking() {
            got.push(v);
        }
        assert_eq!(got, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn steal_takes_from_back() {
        let q = JobQueue::new();
        for i in 0..6 {
            q.push(i);
        }
        let stolen = q.steal(2);
        assert_eq!(stolen, vec![5, 4]);
        assert_eq!(q.len(), 4);
        assert_eq!(q.try_pop(), Some(0)); // front untouched
    }

    #[test]
    fn steal_where_filters_and_preserves_order() {
        let q = JobQueue::new();
        for i in 0..8 {
            q.push(i);
        }
        // Steal evens only, from the back.
        let stolen = q.steal_where(2, |v| v % 2 == 0);
        assert_eq!(stolen, vec![6, 4]);
        // Remaining items keep FIFO order with the gaps closed.
        q.close();
        let mut rest = Vec::new();
        while let Some(v) = q.pop_blocking() {
            rest.push(v);
        }
        assert_eq!(rest, vec![0, 1, 2, 3, 5, 7]);
    }

    #[test]
    fn class_counts_snapshot() {
        let q = JobQueue::new();
        for i in 0..7 {
            q.push(i);
        }
        let counts = q.class_counts(2, |v| (v % 3) as usize);
        // 0,3,6 → class 0; 1,4 → class 1; 2,5 → class 2 (out of range, dropped)
        assert_eq!(counts, vec![3, 2]);
    }

    #[test]
    fn steal_more_than_available() {
        let q = JobQueue::new();
        q.push(1);
        assert_eq!(q.steal(10), vec![1]);
        assert!(q.steal(1).is_empty());
    }

    #[test]
    fn push_after_close_rejected() {
        let q = JobQueue::new();
        q.close();
        assert!(!q.push(1));
        assert!(!q.push_batch(vec![1, 2]));
        assert!(q.pop_blocking().is_none());
    }

    #[test]
    fn close_drains_remaining() {
        let q = JobQueue::new();
        q.push(7);
        q.close();
        assert_eq!(q.pop_blocking(), Some(7));
        assert_eq!(q.pop_blocking(), None);
    }

    #[test]
    fn pop_upto_takes_front_in_order() {
        let q = JobQueue::new();
        for i in 0..5 {
            q.push(i);
        }
        assert_eq!(q.pop_upto(3), vec![0, 1, 2]);
        assert_eq!(q.pop_upto(9), vec![3, 4]);
        assert!(q.pop_upto(1).is_empty());
    }

    #[test]
    fn pop_timeout_signals_empty() {
        let q: JobQueue<u32> = JobQueue::new();
        assert_eq!(q.pop_timeout(Duration::from_millis(5)), Err(()));
        q.push(3);
        assert_eq!(q.pop_timeout(Duration::from_millis(5)), Ok(Some(3)));
        q.close();
        assert_eq!(q.pop_timeout(Duration::from_millis(5)), Ok(None));
    }

    #[test]
    fn concurrent_producers_consumers_lose_nothing() {
        let q = Arc::new(JobQueue::new());
        let n_per = 500;
        let producers: Vec<_> = (0..4)
            .map(|p| {
                let q = Arc::clone(&q);
                thread::spawn(move || {
                    for i in 0..n_per {
                        q.push(p * n_per + i);
                    }
                })
            })
            .collect();
        let consumers: Vec<_> = (0..3)
            .map(|_| {
                let q = Arc::clone(&q);
                thread::spawn(move || {
                    let mut got = Vec::new();
                    while let Some(v) = q.pop_blocking() {
                        got.push(v);
                    }
                    got
                })
            })
            .collect();
        for p in producers {
            p.join().unwrap();
        }
        q.close();
        let mut all: Vec<i32> = consumers
            .into_iter()
            .flat_map(|c| c.join().unwrap())
            .collect();
        all.sort_unstable();
        let want: Vec<i32> = (0..4 * n_per).collect();
        assert_eq!(all, want);
    }

    /// Test item: (payload, class index).
    struct CItem(u64, usize);
    impl Classed for CItem {
        fn class_index(&self) -> usize {
            self.1
        }
    }

    #[test]
    fn bank_routes_pushes_to_class_sub_queues() {
        let b: QueueBank<CItem> = QueueBank::new();
        b.push(CItem(0, 0));
        b.push_batch(vec![CItem(1, 1), CItem(2, 1), CItem(3, 2)]);
        assert_eq!(b.class_counts(), [1, 2, 1, 0, 0, 0, 0]);
        assert_eq!(b.len(), 4);
        assert_eq!(b.len_where(ClassMask::of(&[JobClass::FcGemm])), 2);
        assert_eq!(b.len_where(ClassMask::all()), 4);
    }

    #[test]
    fn bank_pop_respects_mask_and_fifo() {
        let b: QueueBank<CItem> = QueueBank::new();
        for i in 0..4 {
            b.push(CItem(i, 0));
        }
        b.push(CItem(10, 1));
        let fc_only = ClassMask::of(&[JobClass::FcGemm]);
        assert_eq!(b.try_pop_any(fc_only).unwrap().0, 10);
        assert!(b.try_pop_any(fc_only).is_none(), "conv jobs must not leak");
        // Conv sub-queue drains FIFO.
        let conv = ClassMask::of(&[JobClass::ConvTile]);
        let got: Vec<u64> = (0..4).map(|_| b.try_pop_any(conv).unwrap().0).collect();
        assert_eq!(got, vec![0, 1, 2, 3]);
    }

    #[test]
    fn bank_round_robin_bounds_bypass() {
        let b: QueueBank<CItem> = QueueBank::new();
        for i in 0..6 {
            b.push(CItem(i, 0));
        }
        b.push(CItem(100, 2));
        // With the deep conv backlog, the im2col item is served within
        // JobClass::COUNT pops of the union mask.
        let mut gap = 0;
        loop {
            let item = b.try_pop_any(ClassMask::all()).expect("non-empty");
            if item.1 == 2 {
                break;
            }
            gap += 1;
            assert!(gap < JobClass::COUNT, "im2col item starved");
        }
    }

    #[test]
    fn bank_per_mask_cursors_prevent_cross_mask_starvation() {
        // A CONV-only popper interleaved with a union-mask popper: the
        // union popper's rotation must be its own, or the narrow popper
        // keeps resetting a shared cursor and the singleton im2col item
        // starves behind the deep FC backlog (regression test).
        let b: QueueBank<CItem> = QueueBank::new();
        for i in 0..10 {
            b.push(CItem(i, 0)); // deep conv backlog
        }
        for i in 0..10 {
            b.push(CItem(100 + i, 1)); // deep fc backlog
        }
        b.push(CItem(999, 2)); // single im2col item
        let conv_only = ClassMask::of(&[JobClass::ConvTile]);
        let all = ClassMask::all();
        let mut union_pops = 0;
        let mut seen_im2col = false;
        for _ in 0..8 {
            let _ = b.try_pop_any(conv_only);
            if let Some(item) = b.try_pop_any(all) {
                union_pops += 1;
                if item.1 == 2 {
                    seen_im2col = true;
                    break;
                }
            }
            assert!(
                union_pops <= JobClass::COUNT,
                "im2col starved by cross-mask cursor resets"
            );
        }
        assert!(seen_im2col);
    }

    #[test]
    fn bank_steal_takes_backs_heaviest_first() {
        let b: QueueBank<CItem> = QueueBank::new();
        for i in 0..5 {
            b.push(CItem(i, 0));
        }
        b.push(CItem(10, 1));
        // Steal only conv-class items: from the back, heaviest sub-queue.
        let stolen = b.steal_where(2, ClassMask::of(&[JobClass::ConvTile]));
        let ids: Vec<u64> = stolen.iter().map(|c| c.0).collect();
        assert_eq!(ids, vec![4, 3]);
        assert_eq!(b.class_counts(), [3, 1, 0, 0, 0, 0, 0]);
        // Empty-mask steal takes nothing.
        assert!(b.steal_where(5, ClassMask::NONE).is_empty());
    }

    #[test]
    fn bank_pop_timeout_and_close_semantics() {
        let b: QueueBank<CItem> = QueueBank::new();
        let mask = ClassMask::all();
        assert_eq!(
            b.pop_any_timeout(mask, Duration::from_millis(5)).err(),
            Some(())
        );
        b.push(CItem(1, 1));
        assert_eq!(
            b.pop_any_timeout(mask, Duration::from_millis(5))
                .unwrap()
                .unwrap()
                .0,
            1
        );
        // A caller whose mask excludes the only remaining class exits on
        // close instead of waiting for jobs it can never serve.
        b.push(CItem(2, 0));
        b.close();
        assert!(b
            .pop_any_timeout(ClassMask::of(&[JobClass::FcGemm]), Duration::from_millis(5))
            .unwrap()
            .is_none());
        // Closed banks still drain for capable callers, then reject pushes.
        assert_eq!(b.try_pop_any(mask).unwrap().0, 2);
        assert!(!b.push(CItem(3, 0)));
        assert!(!b.push_batch(vec![CItem(4, 0)]));
        assert!(b.is_closed());
    }

    #[test]
    fn bank_timeout_not_postponed_by_foreign_class_traffic() {
        // Pushes of classes outside the waiter's mask broadcast-wake it;
        // the deadline must hold even when they arrive faster than the
        // timeout (regression: re-arming the timeout per wakeup).
        let b: Arc<QueueBank<CItem>> = Arc::new(QueueBank::new());
        let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let pusher = {
            let b = Arc::clone(&b);
            let stop = Arc::clone(&stop);
            thread::spawn(move || {
                let mut i = 0;
                while !stop.load(std::sync::atomic::Ordering::Relaxed) {
                    b.push(CItem(i, 1));
                    i += 1;
                    thread::sleep(Duration::from_millis(2));
                }
            })
        };
        let t0 = Instant::now();
        let conv = ClassMask::of(&[JobClass::ConvTile]);
        let res = b.pop_any_timeout(conv, Duration::from_millis(20));
        assert!(matches!(res, Err(())), "must time out, not pop foreign class");
        assert!(
            t0.elapsed() < Duration::from_millis(500),
            "timeout postponed by foreign-class wakeups"
        );
        stop.store(true, std::sync::atomic::Ordering::Relaxed);
        pusher.join().unwrap();
    }

    #[test]
    fn bank_blocking_pop_crosses_threads() {
        let b: Arc<QueueBank<CItem>> = Arc::new(QueueBank::new());
        let consumer = {
            let b = Arc::clone(&b);
            thread::spawn(move || {
                let mut got = Vec::new();
                loop {
                    match b.pop_any_timeout(ClassMask::all(), Duration::from_millis(20)) {
                        Ok(Some(item)) => got.push(item.0),
                        Ok(None) => return got,
                        Err(()) => continue,
                    }
                }
            })
        };
        for i in 0..50 {
            assert!(b.push(CItem(i, (i % 3) as usize)));
        }
        b.close();
        let mut got = consumer.join().unwrap();
        got.sort_unstable();
        assert_eq!(got, (0..50).collect::<Vec<u64>>());
    }

    #[test]
    fn bank_pop_upto_respects_mask_and_bound() {
        let b: QueueBank<CItem> = QueueBank::new();
        for i in 0..4 {
            b.push(CItem(i, 0));
        }
        b.push(CItem(10, 2));
        let mask = ClassMask::of(&[JobClass::ConvTile, JobClass::Im2col]);
        let got = b.pop_upto(mask, 3);
        assert_eq!(got.len(), 3);
        assert!(got.iter().all(|c| c.1 != 1));
        assert_eq!(b.pop_upto(mask, 10).len(), 2);
        assert!(b.pop_upto(mask, 1).is_empty());
    }
}
