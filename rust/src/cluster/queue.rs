//! Synchronized job queue — the paper's per-cluster "Job Queue" (a
//! synchronous buffer storing jobs), with the steal operation the thief
//! thread uses (take from the back, opposite the owners' pop side).

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::Duration;

struct Inner<T> {
    deque: VecDeque<T>,
    closed: bool,
}

/// MPMC blocking deque: owners pop the front, thieves steal from the back.
pub struct JobQueue<T> {
    inner: Mutex<Inner<T>>,
    cv: Condvar,
}

impl<T> Default for JobQueue<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> JobQueue<T> {
    pub fn new() -> Self {
        JobQueue {
            inner: Mutex::new(Inner {
                deque: VecDeque::new(),
                closed: false,
            }),
            cv: Condvar::new(),
        }
    }

    /// Push one job (to the back).  Returns false if the queue was closed.
    pub fn push(&self, item: T) -> bool {
        let mut g = self.inner.lock().unwrap();
        if g.closed {
            return false;
        }
        g.deque.push_back(item);
        drop(g);
        self.cv.notify_one();
        true
    }

    /// Push a batch (used by the stealer to deposit stolen jobs).
    pub fn push_batch(&self, items: Vec<T>) -> bool {
        if items.is_empty() {
            return true;
        }
        let mut g = self.inner.lock().unwrap();
        if g.closed {
            return false;
        }
        for it in items {
            g.deque.push_back(it);
        }
        drop(g);
        self.cv.notify_all();
        true
    }

    /// Blocking pop from the front; None once closed *and* drained.
    pub fn pop_blocking(&self) -> Option<T> {
        let mut g = self.inner.lock().unwrap();
        loop {
            if let Some(item) = g.deque.pop_front() {
                return Some(item);
            }
            if g.closed {
                return None;
            }
            g = self.cv.wait(g).unwrap();
        }
    }

    /// Blocking pop with timeout; `Ok(None)` = closed+drained, `Err(())` =
    /// timed out (caller may try stealing — the idle notification path).
    pub fn pop_timeout(&self, timeout: Duration) -> Result<Option<T>, ()> {
        let mut g = self.inner.lock().unwrap();
        loop {
            if let Some(item) = g.deque.pop_front() {
                return Ok(Some(item));
            }
            if g.closed {
                return Ok(None);
            }
            let (guard, res) = self.cv.wait_timeout(g, timeout).unwrap();
            g = guard;
            if res.timed_out() {
                if let Some(item) = g.deque.pop_front() {
                    return Ok(Some(item));
                }
                if g.closed {
                    return Ok(None);
                }
                return Err(());
            }
        }
    }

    /// Non-blocking pop.
    pub fn try_pop(&self) -> Option<T> {
        self.inner.lock().unwrap().deque.pop_front()
    }

    /// Non-blocking pop of up to `n` jobs from the front (the owner side).
    /// Delegates use this to drain a micro-batch's jobs in one lock
    /// acquisition and execute them back-to-back.
    pub fn pop_upto(&self, n: usize) -> Vec<T> {
        let mut g = self.inner.lock().unwrap();
        let take = n.min(g.deque.len());
        let mut out = Vec::with_capacity(take);
        for _ in 0..take {
            if let Some(item) = g.deque.pop_front() {
                out.push(item);
            }
        }
        out
    }

    /// Steal up to `n` jobs from the back (the victim side).
    pub fn steal(&self, n: usize) -> Vec<T> {
        self.steal_where(n, |_| true)
    }

    /// Steal up to `n` jobs from the back, taking only those matching
    /// `pred` (capability-aware stealing: a thief must not deposit jobs a
    /// destination cluster cannot execute).  Non-matching jobs keep their
    /// relative order.  Single linear back-to-front pass — the lock is
    /// held on the busiest queue, so no quadratic `remove` shifting.
    pub fn steal_where(&self, n: usize, pred: impl Fn(&T) -> bool) -> Vec<T> {
        if n == 0 {
            return Vec::new();
        }
        let mut g = self.inner.lock().unwrap();
        let mut out = Vec::new();
        let mut skipped = Vec::new();
        while out.len() < n {
            match g.deque.pop_back() {
                Some(item) if pred(&item) => out.push(item),
                Some(item) => skipped.push(item),
                None => break,
            }
        }
        // Restore the non-matching tail in its original order.
        for item in skipped.into_iter().rev() {
            g.deque.push_back(item);
        }
        out
    }

    /// Snapshot of queue occupancy per job class: `result[i]` counts items
    /// whose `classify` index is `i` (out-of-range indices are dropped).
    /// Used by the thief's cost-weighted victim selection.
    pub fn class_counts(&self, n_classes: usize, classify: impl Fn(&T) -> usize) -> Vec<usize> {
        let g = self.inner.lock().unwrap();
        let mut out = vec![0usize; n_classes];
        for item in &g.deque {
            let i = classify(item);
            if i < n_classes {
                out[i] += 1;
            }
        }
        out
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().deque.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Close: pops drain the remainder then return None.
    pub fn close(&self) {
        self.inner.lock().unwrap().closed = true;
        self.cv.notify_all();
    }

    pub fn is_closed(&self) -> bool {
        self.inner.lock().unwrap().closed
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;
    use std::thread;

    #[test]
    fn fifo_order_for_single_consumer() {
        let q = JobQueue::new();
        for i in 0..5 {
            assert!(q.push(i));
        }
        q.close();
        let mut got = Vec::new();
        while let Some(v) = q.pop_blocking() {
            got.push(v);
        }
        assert_eq!(got, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn steal_takes_from_back() {
        let q = JobQueue::new();
        for i in 0..6 {
            q.push(i);
        }
        let stolen = q.steal(2);
        assert_eq!(stolen, vec![5, 4]);
        assert_eq!(q.len(), 4);
        assert_eq!(q.try_pop(), Some(0)); // front untouched
    }

    #[test]
    fn steal_where_filters_and_preserves_order() {
        let q = JobQueue::new();
        for i in 0..8 {
            q.push(i);
        }
        // Steal evens only, from the back.
        let stolen = q.steal_where(2, |v| v % 2 == 0);
        assert_eq!(stolen, vec![6, 4]);
        // Remaining items keep FIFO order with the gaps closed.
        q.close();
        let mut rest = Vec::new();
        while let Some(v) = q.pop_blocking() {
            rest.push(v);
        }
        assert_eq!(rest, vec![0, 1, 2, 3, 5, 7]);
    }

    #[test]
    fn class_counts_snapshot() {
        let q = JobQueue::new();
        for i in 0..7 {
            q.push(i);
        }
        let counts = q.class_counts(2, |v| (v % 3) as usize);
        // 0,3,6 → class 0; 1,4 → class 1; 2,5 → class 2 (out of range, dropped)
        assert_eq!(counts, vec![3, 2]);
    }

    #[test]
    fn steal_more_than_available() {
        let q = JobQueue::new();
        q.push(1);
        assert_eq!(q.steal(10), vec![1]);
        assert!(q.steal(1).is_empty());
    }

    #[test]
    fn push_after_close_rejected() {
        let q = JobQueue::new();
        q.close();
        assert!(!q.push(1));
        assert!(!q.push_batch(vec![1, 2]));
        assert!(q.pop_blocking().is_none());
    }

    #[test]
    fn close_drains_remaining() {
        let q = JobQueue::new();
        q.push(7);
        q.close();
        assert_eq!(q.pop_blocking(), Some(7));
        assert_eq!(q.pop_blocking(), None);
    }

    #[test]
    fn pop_upto_takes_front_in_order() {
        let q = JobQueue::new();
        for i in 0..5 {
            q.push(i);
        }
        assert_eq!(q.pop_upto(3), vec![0, 1, 2]);
        assert_eq!(q.pop_upto(9), vec![3, 4]);
        assert!(q.pop_upto(1).is_empty());
    }

    #[test]
    fn pop_timeout_signals_empty() {
        let q: JobQueue<u32> = JobQueue::new();
        assert_eq!(q.pop_timeout(Duration::from_millis(5)), Err(()));
        q.push(3);
        assert_eq!(q.pop_timeout(Duration::from_millis(5)), Ok(Some(3)));
        q.close();
        assert_eq!(q.pop_timeout(Duration::from_millis(5)), Ok(None));
    }

    #[test]
    fn concurrent_producers_consumers_lose_nothing() {
        let q = Arc::new(JobQueue::new());
        let n_per = 500;
        let producers: Vec<_> = (0..4)
            .map(|p| {
                let q = Arc::clone(&q);
                thread::spawn(move || {
                    for i in 0..n_per {
                        q.push(p * n_per + i);
                    }
                })
            })
            .collect();
        let consumers: Vec<_> = (0..3)
            .map(|_| {
                let q = Arc::clone(&q);
                thread::spawn(move || {
                    let mut got = Vec::new();
                    while let Some(v) = q.pop_blocking() {
                        got.push(v);
                    }
                    got
                })
            })
            .collect();
        for p in producers {
            p.join().unwrap();
        }
        q.close();
        let mut all: Vec<i32> = consumers
            .into_iter()
            .flat_map(|c| c.join().unwrap())
            .collect();
        all.sort_unstable();
        let want: Vec<i32> = (0..4 * n_per).collect();
        assert_eq!(all, want);
    }
}
