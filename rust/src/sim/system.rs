//! The full-system discrete-event simulation.
//!
//! Models the complete Synergy runtime in virtual time:
//! * frames stream through mailbox-connected **layer stages** (each stage
//!   processes one frame at a time — one software thread per layer);
//! * stage CPU work (pooling, batchnorm, softmax, …) is served FIFO by
//!   `cpu_cores` ARM cores ([`CpuModel`]);
//! * **all three job classes** flow through the cluster queues, mirroring
//!   the unified pool: CONV GEMMs lower to tile jobs, and FC GEMMs /
//!   im2col lowering dispatch as whole-matrix jobs to clusters with a
//!   NEON-class member (member-level capability: FPGA PEs only speak CONV
//!   tiles, so FC/im2col service time competes for the NEON members).
//!   When no capable accelerator exists (CPU-only baseline, FPGA-only
//!   ablation) those classes run on the CPU cores exactly as the original
//!   Darknet would;
//! * accelerator service time combines the HLS compute model
//!   ([`PerfModel`]) with queued MMU/DDR transfers ([`MemSubsystem`]);
//!   FC/im2col jobs are charged their [`CpuModel`] seconds scaled by the
//!   serving member's NEON-relative rate (a NEON software accelerator *is*
//!   an ARM core running NEON kernels);
//! * idle accelerators **steal** jobs their hardware class can execute
//!   from the busiest victim when the mapping is [`Mapping::WorkStealing`]
//!   (paper §3.1.3).
//!
//! Every §4 experiment is a [`SimSpec`] variation: baselines drop
//! accelerator classes, SF/SC pin layers to clusters, non-pipelined mode
//! caps frames-in-flight at 1 on a single core.

use std::cmp::Ordering;
use std::collections::{BinaryHeap, HashSet, VecDeque};

use crate::accel::remote::REMOTE_CACHED_OVERHEAD_FRACTION;
use crate::accel::{
    build_clusters, filter_clusters, hw_class_mask, AccelClass, AccelSpec, ClusterSpec, PerfModel,
};
use crate::config::HwConfig;
use crate::memsub::MemSubsystem;
use crate::mm::job::JobClass;
use crate::nn::network::Shape;
use crate::nn::Network;
use crate::sched::{static_map, worksteal, Mapping};
use crate::sim::cpu_model::CpuModel;
use crate::sim::power::{Activity, EnergyBreakdown, PowerModel};

/// What to simulate.
#[derive(Clone)]
pub struct SimSpec {
    pub hw: HwConfig,
    pub clusters: Vec<ClusterSpec>,
    pub mapping: Mapping,
    /// Multi-threaded pipelined mode (frames overlap across stages).
    pub pipelined: bool,
    /// ARM cores serving CPU work (paper: 1 non-pipelined, 2 pipelined).
    pub cpu_cores: usize,
    pub frames: usize,
    /// Run CONV GEMMs on the CPU instead of accelerators (the baseline).
    pub conv_on_cpu: bool,
    /// Serving-style FC fusion width: when > 1, FC-layer GEMMs dispatch
    /// as [`JobClass::FcGemmBatch`] jobs whose per-job dispatch overhead
    /// is amortized across `fc_batch` fused requests (the virtual-clock
    /// mirror of `serve/`'s batch-level FC fusion).  1 = per-request FC
    /// jobs, the single-stream driver's behavior.
    pub fc_batch: usize,
}

impl SimSpec {
    /// Full Synergy: default clusters, work stealing, pipelined, 2 cores.
    pub fn synergy(net: &Network, frames: usize) -> SimSpec {
        let hw = HwConfig::default_zc702();
        let clusters = build_clusters(&hw);
        let assignment = static_map::assign(&net.conv_infos(), &clusters);
        SimSpec {
            hw,
            clusters,
            mapping: Mapping::WorkStealing(assignment),
            pipelined: true,
            cpu_cores: 2,
            frames,
            conv_on_cpu: false,
            fc_batch: 1,
        }
    }

    /// SF: static mapping + fixed (default) architecture, pipelined.
    pub fn static_fixed(net: &Network, frames: usize) -> SimSpec {
        let mut s = SimSpec::synergy(net, frames);
        s.mapping = Mapping::Static(s.mapping.assignment().to_vec());
        s
    }

    /// SC: static mapping + custom cluster architecture.
    pub fn static_custom(net: &Network, clusters: Vec<ClusterSpec>, frames: usize) -> SimSpec {
        let mut s = SimSpec::synergy(net, frames);
        let assignment = static_map::assign(&net.conv_infos(), &clusters);
        s.clusters = clusters;
        s.mapping = Mapping::Static(assignment);
        s
    }

    /// Single-threaded CPU-only baseline (original Darknet).
    pub fn cpu_only(net: &Network, frames: usize) -> SimSpec {
        let mut s = SimSpec::synergy(net, frames);
        s.clusters = Vec::new();
        s.mapping = Mapping::Static(vec![0; net.conv_infos().len()]);
        s.pipelined = false;
        s.cpu_cores = 1;
        s.conv_on_cpu = true;
        s
    }

    /// Keep only a subset of accelerators (Fig 11/12 ablations).
    pub fn with_accels(mut self, net: &Network, keep: impl Fn(&AccelSpec) -> bool) -> SimSpec {
        self.clusters = filter_clusters(&self.clusters, keep);
        let assignment = if self.clusters.is_empty() {
            vec![0; net.conv_infos().len()]
        } else {
            static_map::assign(&net.conv_infos(), &self.clusters)
        };
        self.mapping = match self.mapping {
            Mapping::Static(_) => Mapping::Static(assignment),
            Mapping::WorkStealing(_) => Mapping::WorkStealing(assignment),
        };
        if self.clusters.is_empty() {
            self.conv_on_cpu = true;
        }
        self
    }

    /// Non-pipelined single-thread variant (Fig 11): 1 frame, 1 core.
    pub fn non_pipelined(mut self) -> SimSpec {
        self.pipelined = false;
        self.cpu_cores = 1;
        self
    }

    /// Serve FC layers as fused `fc_batch`-wide batched GEMM jobs (see
    /// [`SimSpec::fc_batch`]).
    pub fn with_fc_batch(mut self, fc_batch: usize) -> SimSpec {
        self.fc_batch = fc_batch.max(1);
        self
    }
}

/// Simulation output (the measurements every experiment reads).
#[derive(Debug, Clone)]
pub struct SimResult {
    pub frames: usize,
    pub makespan_s: f64,
    pub fps: f64,
    pub mean_latency_s: f64,
    /// Mean over clusters of the fraction of time the cluster is
    /// processing ≥1 job — the paper's "accelerator cluster utilization"
    /// (Table 6).
    pub cluster_util: f64,
    pub per_cluster_util: Vec<f64>,
    /// Mean per-accelerator occupancy (busy / makespan) — a stricter
    /// secondary metric.
    pub accel_util: f64,
    /// Per cluster, per CONV ordinal: busy seconds per frame (Fig 14).
    pub cluster_layer_s_per_frame: Vec<Vec<f64>>,
    pub cpu_util: f64,
    pub energy: EnergyBreakdown,
    /// Sustained GOP/s given the model's MOP/frame.
    pub gops: f64,
    pub jobs_executed: u64,
    /// Executed jobs per class ([`JobClass`] dense order) — the unified
    /// pool's per-class accounting, mirrored by the virtual clock.
    ///
    /// **Unit caveat for `FcGemmBatch`:** the frame-pipeline simulator
    /// counts one fused *share* per frame (see [`SimSpec::fc_batch`]),
    /// while the real serving pool's `PoolReport`/`ServerStats` count one
    /// job per B-request batch.  To compare against measured serving
    /// stats, divide this entry by the fusion width B.
    pub jobs_by_class: [u64; JobClass::COUNT],
    pub jobs_stolen: u64,
    pub mem_queue_s: f64,
    pub mem_bytes: u64,
}

// ---------------------------------------------------------------- events

#[derive(Debug, Clone, Copy)]
enum EvKind {
    CpuDone { core: usize },
    JobDone { accel: usize },
}

#[derive(Debug, Clone, Copy)]
struct Ev {
    t: f64,
    seq: u64,
    kind: EvKind,
}

impl PartialEq for Ev {
    fn eq(&self, other: &Self) -> bool {
        self.t == other.t && self.seq == other.seq
    }
}
impl Eq for Ev {}
impl PartialOrd for Ev {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Ev {
    fn cmp(&self, other: &Self) -> Ordering {
        // min-heap via reversal: earlier time = greater priority
        other
            .t
            .partial_cmp(&self.t)
            .unwrap_or(Ordering::Equal)
            .then(other.seq.cmp(&self.seq))
    }
}

#[derive(Debug, Clone, Copy)]
enum Cont {
    /// Stage's CPU work finished → stage complete.
    StageDone,
    /// CONV im2col finished on the CPU → dispatch tile jobs (or run the
    /// CPU GEMM in the baseline).
    ConvDispatch { conv_ord: usize },
    /// CPU GEMM finished → run post segment.
    ConvGemmDone { conv_ord: usize },
    /// Stage preamble finished → dispatch the im2col pool job.
    Im2colDispatch { conv_ord: usize },
    /// Stage preamble finished → dispatch the FC-GEMM pool job.
    FcDispatch,
}

#[derive(Debug, Clone, Copy)]
struct CpuTask {
    frame: usize,
    layer: usize,
    seconds: f64,
    cont: Cont,
}

#[derive(Debug, Clone, Copy)]
struct SimJob {
    frame: usize,
    /// Owning network layer (FC completion routes through it).
    layer: usize,
    /// CONV ordinal for ConvTile / Im2col jobs; unused for FC.
    conv_ord: usize,
    class: JobClass,
    /// Inner-tile count (ConvTile service + MMU traffic).
    k: usize,
    /// Single-A9-core seconds of this job's work (FC / im2col service
    /// basis on NEON-class members).
    cpu_seconds: f64,
    /// Fused requests this job's dispatch overhead amortizes across
    /// (1 for everything except [`JobClass::FcGemmBatch`]).
    batch: usize,
}

// ------------------------------------------------------------- simulator

struct Sim<'a> {
    spec: &'a SimSpec,
    net: &'a Network,
    cpu: CpuModel,
    accels: Vec<AccelSpec>,
    memsub: MemSubsystem,

    heap: BinaryHeap<Ev>,
    seq: u64,
    now: f64,

    // CPU cores
    core_task: Vec<Option<CpuTask>>,
    cpu_queue: VecDeque<CpuTask>,
    cpu_busy: f64,

    // stages
    stage_occupant: Vec<Option<usize>>,
    stage_waiting: Vec<VecDeque<usize>>,
    frame_layer: Vec<usize>,
    frame_start: Vec<f64>,
    frame_done: Vec<f64>,
    pending: VecDeque<usize>,
    in_flight: usize,

    // per-cluster job queues
    queues: Vec<VecDeque<SimJob>>,
    accel_job: Vec<Option<(SimJob, f64)>>, // (job, start time)
    accel_busy: Vec<f64>,
    // cluster-active accounting (Table 6: a cluster is "utilized" while it
    // is processing at least one job)
    cluster_active: Vec<usize>,
    cluster_last_change: Vec<f64>,
    cluster_active_s: Vec<f64>,
    cluster_layer_busy: Vec<Vec<f64>>,
    conv_remaining: Vec<Vec<usize>>, // [frame][conv_ord]
    conv_va: Vec<u64>,               // col buffer VA per conv ordinal
    /// (member, conv ordinal) pairs whose packed fetch set already shipped
    /// to the member's shard — the virtual-clock mirror of the client's
    /// shipped-key ledger: the first tile pays the cold round trip, warm
    /// tiles a descriptor-only one (`REMOTE_CACHED_OVERHEAD_FRACTION`).
    remote_warm: HashSet<(usize, usize)>,
    jobs_executed: u64,
    jobs_by_class: [u64; JobClass::COUNT],
    jobs_stolen: u64,
    /// Reference k-step time of a plain NEON at this clock: FC/im2col
    /// cpu-seconds scale by `accel.kstep / neon_ref` (1.0 on a NEON,
    /// <1 on a faster big-core member).
    neon_ref_kstep: f64,

    completed: usize,
}

impl<'a> Sim<'a> {
    fn new(spec: &'a SimSpec, net: &'a Network) -> Sim<'a> {
        let accels = crate::accel::all_accels(&spec.clusters);
        let mut memsub = MemSubsystem::new(&spec.hw.memsub, spec.hw.fpga_mhz);
        let convs = net.conv_infos();
        // Pre-map weight + col buffers (the host allocates them up front).
        let conv_va: Vec<u64> = convs
            .iter()
            .map(|ci| {
                let bytes = (ci.grid.n * ci.grid.p * 4) as u64;
                memsub.alloc_buffer(bytes.max(4096))
            })
            .collect();
        let n_layers = net.config.layers.len();
        Sim {
            spec,
            net,
            cpu: CpuModel::a9(spec.hw.cpu_mhz),
            memsub,
            heap: BinaryHeap::new(),
            seq: 0,
            now: 0.0,
            core_task: vec![None; spec.cpu_cores.max(1)],
            cpu_queue: VecDeque::new(),
            cpu_busy: 0.0,
            stage_occupant: vec![None; n_layers],
            stage_waiting: vec![VecDeque::new(); n_layers],
            frame_layer: vec![0; spec.frames],
            frame_start: vec![0.0; spec.frames],
            frame_done: vec![0.0; spec.frames],
            pending: (0..spec.frames).collect(),
            in_flight: 0,
            queues: vec![VecDeque::new(); spec.clusters.len().max(1)],
            accel_job: vec![None; accels.len()],
            accel_busy: vec![0.0; accels.len()],
            cluster_active: vec![0; spec.clusters.len().max(1)],
            cluster_last_change: vec![0.0; spec.clusters.len().max(1)],
            cluster_active_s: vec![0.0; spec.clusters.len().max(1)],
            cluster_layer_busy: vec![vec![0.0; convs.len()]; spec.clusters.len().max(1)],
            conv_remaining: vec![vec![0; convs.len()]; spec.frames],
            conv_va,
            remote_warm: HashSet::new(),
            jobs_executed: 0,
            jobs_by_class: [0; JobClass::COUNT],
            jobs_stolen: 0,
            neon_ref_kstep: PerfModel::neon(spec.hw.tile_size, spec.hw.cpu_mhz).kstep_seconds,
            completed: 0,
            accels,
        }
    }

    /// Whether `class` jobs go to the accelerator pool: some accelerator's
    /// hardware class must execute it (CPU-only baselines and FPGA-only
    /// ablations keep FC/im2col on the ARM cores).
    fn pool_serves(&self, class: JobClass) -> bool {
        !self.spec.conv_on_cpu
            && self
                .accels
                .iter()
                .any(|a| hw_class_mask(&a.class).supports(class))
    }

    /// Destination cluster for a `class` job: the mapping hint when its
    /// cluster has a capable member, else the capable cluster with the
    /// smallest backlog per unit of capable-member service rate.  Using
    /// the *total* queue length matches the dispatcher's `member_load`:
    /// the members capable of FC/im2col are NEON-class (full masks), so
    /// their drain set — the backlog competing with the new job — is the
    /// whole bank there too.
    fn route_job(&self, class: JobClass, preferred: Option<usize>) -> Option<usize> {
        if let Some(p) = preferred {
            if self
                .spec
                .clusters
                .get(p)
                .is_some_and(|c| c.throughput_for(class) > 0.0)
            {
                return Some(p);
            }
        }
        self.spec
            .clusters
            .iter()
            .filter(|c| c.throughput_for(class) > 0.0)
            .min_by(|a, b| {
                let la = self.queues[a.index].len() as f64 / a.throughput_for(class);
                let lb = self.queues[b.index].len() as f64 / b.throughput_for(class);
                la.partial_cmp(&lb).unwrap_or(Ordering::Equal)
            })
            .map(|c| c.index)
    }

    fn push_ev(&mut self, t: f64, kind: EvKind) {
        self.seq += 1;
        self.heap.push(Ev {
            t,
            seq: self.seq,
            kind,
        });
    }

    fn in_flight_limit(&self) -> usize {
        if self.spec.pipelined {
            self.net.config.layers.len().max(1)
        } else {
            1
        }
    }

    fn admit(&mut self) {
        while self.in_flight < self.in_flight_limit() {
            let Some(frame) = self.pending.pop_front() else {
                return;
            };
            self.in_flight += 1;
            self.frame_start[frame] = self.now;
            self.enter_stage(frame, 0);
        }
    }

    fn enter_stage(&mut self, frame: usize, layer: usize) {
        if self.stage_occupant[layer].is_some() {
            self.stage_waiting[layer].push_back(frame);
            return;
        }
        self.stage_occupant[layer] = Some(frame);
        self.start_stage_work(frame, layer);
    }

    fn start_stage_work(&mut self, frame: usize, layer: usize) {
        let in_shape = if layer == 0 {
            let (c, h, w) = self.net.input_shape();
            Shape::Chw(c, h, w)
        } else {
            self.net.shapes[layer - 1]
        };
        let spec = &self.net.config.layers[layer];
        let (mut pre, _gemm, _post) = self.cpu.layer_segments(spec, in_shape);
        let mut cont = Cont::StageDone;
        if spec.is_conv() {
            let conv_ord = self
                .net
                .conv_infos()
                .iter()
                .position(|ci| ci.layer_idx == layer)
                .expect("conv ordinal");
            if self.pool_serves(JobClass::Im2col) {
                // im2col runs as a pool job on a NEON-class member; the
                // stage's CPU preamble is only the (layer-0) normalize.
                pre = 0.0;
                cont = Cont::Im2colDispatch { conv_ord };
            } else {
                cont = Cont::ConvDispatch { conv_ord };
            }
        } else if matches!(spec, crate::config::LayerSpec::Connected { .. })
            && self.pool_serves(JobClass::FcGemm)
        {
            // The FC GEMM is a pool job; nothing left for the CPU.
            pre = 0.0;
            cont = Cont::FcDispatch;
        }
        if layer == 0 {
            // Input normalization preprocessing (paper §3.1.4).
            pre += self.cpu.normalize_seconds(in_shape.len());
        }
        self.schedule_cpu(CpuTask {
            frame,
            layer,
            seconds: pre,
            cont,
        });
    }

    fn schedule_cpu(&mut self, task: CpuTask) {
        if let Some(core) = self.core_task.iter().position(|t| t.is_none()) {
            self.start_cpu(core, task);
        } else {
            self.cpu_queue.push_back(task);
        }
    }

    fn start_cpu(&mut self, core: usize, task: CpuTask) {
        self.core_task[core] = Some(task);
        self.cpu_busy += task.seconds;
        self.push_ev(self.now + task.seconds, EvKind::CpuDone { core });
    }

    fn on_cpu_done(&mut self, core: usize) {
        let task = self.core_task[core].take().expect("core had a task");
        // Free the core for queued work before running the continuation
        // (the continuation may enqueue more CPU tasks).
        if let Some(next) = self.cpu_queue.pop_front() {
            self.start_cpu(core, next);
        }
        match task.cont {
            Cont::StageDone => self.complete_stage(task.frame, task.layer),
            Cont::ConvDispatch { conv_ord } => self.dispatch_conv(task.frame, task.layer, conv_ord),
            Cont::ConvGemmDone { conv_ord } => self.conv_post(task.frame, task.layer, conv_ord),
            Cont::Im2colDispatch { conv_ord } => {
                self.dispatch_im2col(task.frame, task.layer, conv_ord)
            }
            Cont::FcDispatch => self.dispatch_fc(task.frame, task.layer),
        }
    }

    fn dispatch_conv(&mut self, frame: usize, layer: usize, conv_ord: usize) {
        let info = &self.net.conv_infos()[conv_ord];
        if self.spec.conv_on_cpu {
            let gemm = self
                .cpu
                .gemm_seconds(info.grid.m, info.grid.n, info.grid.p);
            self.schedule_cpu(CpuTask {
                frame,
                layer,
                seconds: gemm,
                cont: Cont::ConvGemmDone { conv_ord },
            });
            return;
        }
        let grid = info.grid;
        let cluster = self.spec.mapping.assignment()[conv_ord].min(self.queues.len() - 1);
        let n_jobs = grid.num_jobs();
        self.conv_remaining[frame][conv_ord] = n_jobs;
        for _ in 0..n_jobs {
            self.queues[cluster].push_back(SimJob {
                frame,
                layer,
                conv_ord,
                class: JobClass::ConvTile,
                k: grid.k_tiles(),
                cpu_seconds: 0.0,
                batch: 1,
            });
        }
        self.kick_all();
    }

    /// Lower one CONV input as an im2col pool job on a NEON-capable
    /// cluster (preferring the CONV layer's mapped cluster).
    fn dispatch_im2col(&mut self, frame: usize, layer: usize, conv_ord: usize) {
        let info = &self.net.conv_infos()[conv_ord];
        let (c, _h, _w) = info.in_shape;
        let (_oc, oh, ow) = info.out_shape;
        let seconds = self.cpu.im2col_seconds(c, info.size, oh, ow);
        let preferred = self.spec.mapping.assignment()[conv_ord].min(self.queues.len() - 1);
        let cluster = self
            .route_job(JobClass::Im2col, Some(preferred))
            .expect("pool_serves(Im2col) checked at stage start");
        self.queues[cluster].push_back(SimJob {
            frame,
            layer,
            conv_ord,
            class: JobClass::Im2col,
            k: 0,
            cpu_seconds: seconds,
            batch: 1,
        });
        self.kick_all();
    }

    /// Dispatch one FC-layer GEMM as a pool job on a NEON-capable
    /// cluster.  With `fc_batch > 1` the job is a fused
    /// [`JobClass::FcGemmBatch`] share: the frame pipeline admits frames
    /// individually, so each frame carries its own compute seconds, but
    /// the per-job dispatch overhead is charged at 1/B — a B-wide fused
    /// job costs overhead + B·compute, and each frame pays its share
    /// (batch-scaled service).
    fn dispatch_fc(&mut self, frame: usize, layer: usize) {
        let in_n = if layer == 0 {
            let (c, h, w) = self.net.input_shape();
            c * h * w
        } else {
            self.net.shapes[layer - 1].len()
        };
        let out_n = self.net.shapes[layer].len();
        let seconds = self.cpu.fc_seconds(in_n, out_n);
        let batch = self.spec.fc_batch.max(1);
        let class = if batch > 1 {
            JobClass::FcGemmBatch
        } else {
            JobClass::FcGemm
        };
        let cluster = self
            .route_job(class, None)
            .expect("pool_serves(FcGemm) checked at stage start");
        self.queues[cluster].push_back(SimJob {
            frame,
            layer,
            conv_ord: usize::MAX,
            class,
            k: 0,
            cpu_seconds: seconds,
            batch,
        });
        self.kick_all();
    }

    fn conv_post(&mut self, frame: usize, layer: usize, conv_ord: usize) {
        let info = &self.net.conv_infos()[conv_ord];
        let (oc, oh, ow) = info.out_shape;
        let post = self.cpu.conv_post_seconds(oc, oh, ow);
        self.schedule_cpu(CpuTask {
            frame,
            layer,
            seconds: post,
            cont: Cont::StageDone,
        });
    }

    fn complete_stage(&mut self, frame: usize, layer: usize) {
        debug_assert_eq!(self.stage_occupant[layer], Some(frame));
        self.stage_occupant[layer] = None;
        if let Some(waiting) = self.stage_waiting[layer].pop_front() {
            self.stage_occupant[layer] = Some(waiting);
            self.start_stage_work(waiting, layer);
        }
        let next = layer + 1;
        self.frame_layer[frame] = next;
        if next == self.net.config.layers.len() {
            self.frame_done[frame] = self.now;
            self.completed += 1;
            self.in_flight -= 1;
            self.admit();
        } else {
            self.enter_stage(frame, next);
        }
    }

    /// Try to give every idle accelerator a job.
    fn kick_all(&mut self) {
        for i in 0..self.accels.len() {
            if self.accel_job[i].is_none() {
                self.try_dispatch(i);
            }
        }
    }

    fn try_dispatch(&mut self, accel_idx: usize) {
        // A completion continuation (im2col → tile dispatch → kick_all)
        // may have already re-armed this accelerator.
        if self.accel_job[accel_idx].is_some() {
            return;
        }
        let cluster = self.accels[accel_idx].cluster;
        let mask = hw_class_mask(&self.accels[accel_idx].class);
        // Member-level pop: take the first queued job this accelerator's
        // hardware class can execute (an FPGA PE skips past FC/im2col
        // jobs, which the cluster's NEON members will drain).
        let mut pos = self.queues[cluster]
            .iter()
            .position(|j| mask.supports(j.class));
        if pos.is_none() && self.spec.mapping.steals() {
            self.steal_into(cluster, accel_idx);
            pos = self.queues[cluster]
                .iter()
                .position(|j| mask.supports(j.class));
        }
        let Some(pos) = pos else {
            return;
        };
        let job = self.queues[cluster].remove(pos).expect("position valid");
        let accel = &self.accels[accel_idx];
        let done = match job.class {
            JobClass::ConvTile => {
                let compute = accel.perf.compute_seconds(job.k);
                if accel.perf.uses_fpga_mmu {
                    let bytes = job.k as u64 * accel.perf.bytes_per_kstep;
                    let va = self.conv_va[job.conv_ord];
                    let fetch_done = self
                        .memsub
                        .transfer(accel.mmu.unwrap_or(0), va, bytes, self.now);
                    let wb = accel.perf.writeback_bytes as f64
                        / (self.spec.hw.memsub.ddr_bytes_per_cycle * self.spec.hw.fpga_mhz * 1e6);
                    (self.now + compute).max(fetch_done) + wb
                } else {
                    let mut compute = compute;
                    // Remote member with a warm operand cache: the layer's
                    // packed fetch set already lives on the shard, so the
                    // steady-state tile ships a 137-B descriptor-only
                    // frame — the round trip keeps its latencies but loses
                    // the panel serialization.
                    if matches!(accel.class, AccelClass::Remote { .. })
                        && !self.remote_warm.insert((accel_idx, job.conv_ord))
                    {
                        compute -= accel.perf.job_overhead_seconds
                            * (1.0 - REMOTE_CACHED_OVERHEAD_FRACTION);
                    }
                    self.now + compute
                }
            }
            // FC / im2col / fused FC: ARM-core seconds scaled by the
            // member's NEON-relative rate (never lands on a PE — the mask
            // above).  A fused batched-FC share amortizes the per-job
            // dispatch overhead across its `batch` fused requests.
            JobClass::FcGemm | JobClass::Im2col | JobClass::FcGemmBatch => {
                let scale = accel.perf.kstep_seconds / self.neon_ref_kstep.max(1e-18);
                let overhead = accel.perf.job_overhead_seconds / job.batch.max(1) as f64;
                self.now + overhead + job.cpu_seconds * scale
            }
        };
        self.accel_job[accel_idx] = Some((job, self.now));
        self.cluster_mark(cluster, 1);
        self.push_ev(done, EvKind::JobDone { accel: accel_idx });
    }

    fn cluster_mark(&mut self, cluster: usize, delta: isize) {
        let dt = self.now - self.cluster_last_change[cluster];
        if self.cluster_active[cluster] > 0 {
            self.cluster_active_s[cluster] += dt;
        }
        self.cluster_last_change[cluster] = self.now;
        self.cluster_active[cluster] =
            (self.cluster_active[cluster] as isize + delta).max(0) as usize;
    }

    /// Steal from the busiest victim's queue into `cluster` for the idle
    /// accelerator `accel_idx` (paper Fig 4), filtered to the classes that
    /// member's hardware can execute — an idle PE never pulls an FC job.
    ///
    /// The virtual-clock thief steals ONE job per idle accelerator wake-up
    /// (pull granularity): batch transfers strand work on slow clusters and
    /// lengthen stage tails, while one-at-a-time keeps every accelerator
    /// fed with exactly as much remote work as it can absorb.  (The
    /// threaded runtime's thief uses steal-half batches — the actual paper
    /// mechanism — since real queue hops have per-transfer costs.)
    fn steal_into(&mut self, cluster: usize, accel_idx: usize) {
        let mask = hw_class_mask(&self.accels[accel_idx].class);
        // Stealable backlog per victim: only the classes this member runs.
        let lens: Vec<usize> = self
            .queues
            .iter()
            .map(|q| q.iter().filter(|j| mask.supports(j.class)).count())
            .collect();
        let mut idle = HashSet::new();
        idle.insert(cluster);
        if let Some(victim) = worksteal::choose_victim(&lens, &idle, 1) {
            if let Some(pos) = self.queues[victim]
                .iter()
                .rposition(|j| mask.supports(j.class))
            {
                let job = self.queues[victim].remove(pos).expect("position valid");
                self.queues[cluster].push_back(job);
                self.jobs_stolen += 1;
            }
        }
    }

    fn on_job_done(&mut self, accel_idx: usize) {
        let (job, start) = self.accel_job[accel_idx].take().expect("accel had a job");
        let busy = self.now - start;
        self.accel_busy[accel_idx] += busy;
        let cluster = self.accels[accel_idx].cluster;
        self.cluster_mark(cluster, -1);
        if job.conv_ord != usize::MAX {
            self.cluster_layer_busy[cluster][job.conv_ord] += busy;
        }
        self.jobs_executed += 1;
        self.jobs_by_class[job.class.index()] += 1;

        match job.class {
            JobClass::ConvTile => {
                let rem = &mut self.conv_remaining[job.frame][job.conv_ord];
                debug_assert!(*rem > 0);
                *rem -= 1;
                if *rem == 0 {
                    self.conv_post(job.frame, job.layer, job.conv_ord);
                }
            }
            // im2col done → the CONV GEMM's tile jobs can now dispatch.
            JobClass::Im2col => self.dispatch_conv(job.frame, job.layer, job.conv_ord),
            // FC GEMM (per-request or this frame's fused share) is the
            // whole stage's work.
            JobClass::FcGemm | JobClass::FcGemmBatch => {
                self.complete_stage(job.frame, job.layer)
            }
        }
        self.try_dispatch(accel_idx);
    }

    fn run(mut self) -> SimResult {
        self.admit();
        while let Some(ev) = self.heap.pop() {
            self.now = ev.t;
            match ev.kind {
                EvKind::CpuDone { core } => self.on_cpu_done(core),
                EvKind::JobDone { accel } => self.on_job_done(accel),
            }
        }
        assert_eq!(
            self.completed, self.spec.frames,
            "simulation deadlocked: {}/{} frames",
            self.completed, self.spec.frames
        );
        self.finish()
    }

    fn finish(self) -> SimResult {
        let makespan = self.now.max(1e-12);
        let frames = self.spec.frames;
        let mean_latency = (0..frames)
            .map(|f| self.frame_done[f] - self.frame_start[f])
            .sum::<f64>()
            / frames.max(1) as f64;

        let mut per_cluster_util = Vec::new();
        let mut accel_fracs = Vec::new();
        for c in &self.spec.clusters {
            per_cluster_util.push(self.cluster_active_s[c.index] / makespan);
            for m in &c.members {
                accel_fracs.push(self.accel_busy[m.id] / makespan);
            }
        }
        let cluster_util = if per_cluster_util.is_empty() {
            0.0
        } else {
            per_cluster_util.iter().sum::<f64>() / per_cluster_util.len() as f64
        };
        let accel_util = if accel_fracs.is_empty() {
            0.0
        } else {
            accel_fracs.iter().sum::<f64>() / accel_fracs.len() as f64
        };

        let cluster_layer_s_per_frame: Vec<Vec<f64>> = self
            .cluster_layer_busy
            .iter()
            .map(|per_layer| per_layer.iter().map(|s| s / frames.max(1) as f64).collect())
            .collect();

        // Energy accounting.
        let neon_busy: f64 = self
            .accels
            .iter()
            .filter(|a| !a.is_fpga())
            .map(|a| self.accel_busy[a.id])
            .sum();
        let pe_busy: f64 = self
            .accels
            .iter()
            .filter(|a| a.is_fpga())
            .map(|a| self.accel_busy[a.id])
            .sum();
        // CPU-side DDR traffic estimate: ~12 bytes per produced activation.
        let act_elems: usize = self.net.shapes.iter().map(|s| s.len()).sum();
        let cpu_bytes = (act_elems * 12 * frames) as u64;
        let activity = Activity {
            makespan,
            cpu_busy: self.cpu_busy,
            neon_busy,
            pe_busy,
            fpga_configured: self.accels.iter().any(|a| a.is_fpga()),
            ddr_bytes: self.memsub.stats.bytes + cpu_bytes,
            frames,
        };
        let energy = PowerModel::zc702().evaluate(&activity);

        let fps = frames as f64 / makespan;
        SimResult {
            frames,
            makespan_s: makespan,
            fps,
            mean_latency_s: mean_latency,
            cluster_util,
            per_cluster_util,
            accel_util,
            cluster_layer_s_per_frame,
            cpu_util: self.cpu_busy / (self.spec.cpu_cores.max(1) as f64 * makespan),
            energy,
            gops: self.net.mops() * fps / 1e3,
            jobs_executed: self.jobs_executed,
            jobs_by_class: self.jobs_by_class,
            jobs_stolen: self.jobs_stolen,
            mem_queue_s: self.memsub.stats.queue_seconds,
            mem_bytes: self.memsub.stats.bytes,
        }
    }
}

/// Run one simulation.
pub fn simulate(spec: &SimSpec, net: &Network) -> SimResult {
    Sim::new(spec, net).run()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::zoo;

    fn net(name: &str) -> Network {
        Network::new(zoo::load(name).unwrap(), 32).unwrap()
    }

    #[test]
    fn cpu_baseline_matches_cpu_model() {
        let n = net("mnist");
        let spec = SimSpec::cpu_only(&n, 5);
        let r = simulate(&spec, &n);
        let per_frame = r.makespan_s / 5.0;
        // within 5% of the closed-form CPU model (scheduling adds nothing)
        let want = CpuModel::a9(667.0)
            .frame_seconds_cpu_only(&n.config, &n.shapes);
        assert!(
            (per_frame - want).abs() / want < 0.05,
            "{per_frame} vs {want}"
        );
        assert_eq!(r.jobs_executed, 0);
        assert!(!r.energy.avg_power_w.is_nan());
    }

    #[test]
    fn synergy_beats_cpu_baseline_substantially() {
        for name in ["mnist", "mpcnn", "cifar_full"] {
            let n = net(name);
            let base = simulate(&SimSpec::cpu_only(&n, 8), &n);
            let syn = simulate(&SimSpec::synergy(&n, 30), &n);
            let speedup = syn.fps / base.fps;
            // Upper edge widened from 15 when FC/im2col moved off the
            // pipeline cores onto the pool (PR 3).
            assert!(
                (3.0..20.0).contains(&speedup),
                "{name}: speedup {speedup} (syn {} vs base {})",
                syn.fps,
                base.fps
            );
        }
    }

    #[test]
    fn pipelined_beats_non_pipelined() {
        let n = net("cifar_full");
        let pip = simulate(&SimSpec::synergy(&n, 30), &n);
        let non = simulate(&SimSpec::synergy(&n, 30).non_pipelined(), &n);
        assert!(pip.fps > non.fps * 1.1, "{} vs {}", pip.fps, non.fps);
        // Pipelining raises accelerator utilization (Table 6 shape).
        assert!(pip.cluster_util > non.cluster_util);
    }

    #[test]
    fn worksteal_beats_static_fixed() {
        let n = net("cifar_alex");
        let sf = simulate(&SimSpec::static_fixed(&n, 30), &n);
        let ws = simulate(&SimSpec::synergy(&n, 30), &n);
        assert!(ws.fps >= sf.fps, "ws {} vs sf {}", ws.fps, sf.fps);
        assert!(ws.jobs_stolen > 0, "stealing should trigger");
        assert_eq!(sf.jobs_stolen, 0);
    }

    #[test]
    fn all_jobs_execute_exactly_once() {
        let n = net("mnist");
        let frames = 10;
        let r = simulate(&SimSpec::synergy(&n, frames), &n);
        // The simulator mirrors the unified pool: CONV tiles, one im2col
        // job per CONV layer, one FC job per connected layer.
        let profile = n.pool_job_profile();
        let expected: usize = profile.iter().sum::<usize>() * frames;
        assert_eq!(r.jobs_executed, expected as u64);
        for class in JobClass::ALL {
            assert_eq!(
                r.jobs_by_class[class.index()],
                (profile[class.index()] * frames) as u64,
                "{}",
                class.label()
            );
        }
    }

    /// Batched-FC fusion in the virtual clock: the fused spec executes
    /// its FC work as FcGemmBatch shares (amortized dispatch overhead) and
    /// never slows the pipeline down relative to per-request FC jobs.
    #[test]
    fn fc_fusion_amortizes_overhead_and_reclasses_jobs() {
        let n = net("mnist"); // FC-heavy: 2 CONV + 2 FC layers
        let frames = 20;
        let unfused = simulate(&SimSpec::synergy(&n, frames), &n);
        let fused = simulate(&SimSpec::synergy(&n, frames).with_fc_batch(8), &n);
        // Per-class accounting moves wholesale from fc-gemm to the
        // batched class; every other class is untouched.
        let profile = n.pool_job_profile();
        assert_eq!(
            unfused.jobs_by_class[JobClass::FcGemm.index()],
            (profile[JobClass::FcGemm.index()] * frames) as u64
        );
        assert_eq!(unfused.jobs_by_class[JobClass::FcGemmBatch.index()], 0);
        assert_eq!(fused.jobs_by_class[JobClass::FcGemm.index()], 0);
        assert_eq!(
            fused.jobs_by_class[JobClass::FcGemmBatch.index()],
            (profile[JobClass::FcGemm.index()] * frames) as u64
        );
        assert_eq!(fused.jobs_executed, unfused.jobs_executed);
        // Amortized dispatch overhead helps throughput (a small margin
        // absorbs scheduling butterfly effects from the changed service
        // times).
        assert!(
            fused.fps >= unfused.fps * 0.95,
            "fused {} fps vs unfused {} fps",
            fused.fps,
            unfused.fps
        );
    }

    #[test]
    fn fpga_only_ablation_keeps_fc_on_cpu() {
        let n = net("mnist");
        let r = simulate(
            &SimSpec::synergy(&n, 10).with_accels(&n, |a| a.is_fpga()),
            &n,
        );
        // PEs only speak CONV tiles: FC/im2col stay on the ARM cores.
        assert_eq!(r.jobs_by_class[JobClass::FcGemm.index()], 0);
        assert_eq!(r.jobs_by_class[JobClass::Im2col.index()], 0);
        let conv_jobs: usize = n.conv_infos().iter().map(|ci| ci.grid.num_jobs()).sum();
        assert_eq!(
            r.jobs_by_class[JobClass::ConvTile.index()],
            (conv_jobs * 10) as u64
        );
    }

    #[test]
    fn het_beats_fpga_only_beats_neon_only() {
        let n = net("mnist");
        let het = simulate(&SimSpec::synergy(&n, 30), &n);
        let fpga = simulate(&SimSpec::synergy(&n, 30).with_accels(&n, |a| a.is_fpga()), &n);
        let neon = simulate(&SimSpec::synergy(&n, 30).with_accels(&n, |a| !a.is_fpga()), &n);
        assert!(het.fps > fpga.fps, "het {} vs fpga {}", het.fps, fpga.fps);
        assert!(fpga.fps > neon.fps, "fpga {} vs neon {}", fpga.fps, neon.fps);
    }

    /// A `remote = host:port` cluster member joins the virtual clock with
    /// the latency/B service model: a CONV tile pays the full transport
    /// round trip (`PerfModel::remote.job_overhead_seconds`) the first
    /// time its layer's fetch set ships, and the cached descriptor-only
    /// fraction (`REMOTE_CACHED_OVERHEAD_FRACTION`) on every warm tile
    /// after that; fused batched-FC shares pay the round trip divided by
    /// the fusion width, and the member's partial mask keeps per-request
    /// FC and im2col off the link entirely.
    #[test]
    fn remote_shard_member_serves_conv_and_fused_fc_in_sim() {
        let n = net("mnist");
        let mut hw = HwConfig::default_zc702();
        hw.clusters.push(crate::config::ClusterCfg {
            name: "shard".into(),
            neon: 0,
            big_neon: 0,
            remote: vec!["10.0.0.2:7000".into()],
            pes: Vec::new(),
        });
        let mk_spec = |frames: usize| {
            let mut spec = SimSpec::synergy(&n, frames);
            spec.hw = hw.clone();
            spec.clusters = build_clusters(&hw);
            let assignment = static_map::assign(&n.conv_infos(), &spec.clusters);
            spec.mapping = Mapping::WorkStealing(assignment);
            spec
        };
        let r = simulate(&mk_spec(20).with_fc_batch(4), &n);
        // Work is conserved across the remote-augmented topology, and the
        // run stays deterministic.
        let profile = n.pool_job_profile();
        let expected: usize = profile.iter().sum::<usize>() * 20;
        assert_eq!(r.jobs_executed, expected as u64);
        let r2 = simulate(&mk_spec(20).with_fc_batch(4), &n);
        assert_eq!(r.makespan_s, r2.makespan_s);
        // The shard cluster really worked: the static mapper hands the
        // strongest cluster (the shard, by aggregate rate) conv layers,
        // so its utilization is nonzero.
        assert!(
            r.per_cluster_util[2] > 0.0,
            "remote cluster never utilized: {:?}",
            r.per_cluster_util
        );
        // Remote members never serve the classes outside their mask even
        // when they idle: the whole FC/im2col load fits the local NEONs.
        assert_eq!(
            r.jobs_by_class[JobClass::FcGemmBatch.index()],
            (profile[JobClass::FcGemm.index()] * 20) as u64
        );
        // Amortization: widening the fusion divides the per-job overhead,
        // so wider batches never slow the pipeline down.
        let narrow = simulate(&mk_spec(20), &n);
        assert!(
            r.fps >= narrow.fps * 0.95,
            "fused {} fps vs per-request {} fps",
            r.fps,
            narrow.fps
        );
    }

    #[test]
    fn throughput_in_paper_band() {
        // Paper: 39.5–136.4 fps across the zoo; we accept a widened band
        // (shape-level reproduction; upper edge widened again when the
        // FC/im2col stage work moved off the pipeline cores, PR 3).
        for name in zoo::ZOO {
            let n = net(name);
            let r = simulate(&SimSpec::synergy(&n, 30), &n);
            assert!(
                (25.0..320.0).contains(&r.fps),
                "{name}: fps {}",
                r.fps
            );
        }
    }

    #[test]
    fn utilization_ordering_matches_table6() {
        let n = net("cifar_alex");
        let non = simulate(&SimSpec::synergy(&n, 20).non_pipelined(), &n);
        let sf = simulate(&SimSpec::static_fixed(&n, 40), &n);
        let ws = simulate(&SimSpec::synergy(&n, 40), &n);
        assert!(non.cluster_util < sf.cluster_util);
        assert!(sf.cluster_util <= ws.cluster_util + 0.02);
        assert!(ws.cluster_util > 0.80, "{}", ws.cluster_util);
    }

    #[test]
    fn deterministic() {
        let n = net("mpcnn");
        let a = simulate(&SimSpec::synergy(&n, 10), &n);
        let b = simulate(&SimSpec::synergy(&n, 10), &n);
        assert_eq!(a.makespan_s, b.makespan_s);
        assert_eq!(a.jobs_stolen, b.jobs_stolen);
    }
}
