//! Virtual-clock full-system simulation.
//!
//! The paper's evaluation ran on a physical ZC702 with an FPGA timer; this
//! module is that testbed's stand-in: a discrete-event simulation of the
//! complete Synergy system — layer pipeline (mailbox-connected stages on 2
//! ARM cores), accelerator clusters with job queues, the work-stealing
//! thief, the MMU/DDR memory subsystem, and the board power model.  Every
//! figure/table of §4 is regenerated from [`system::simulate`] runs.

//! [`tiered`] replays scripted SLO-tiered arrival traces against the
//! *real* serving admission queue and micro-batcher on a virtual clock —
//! the deterministic harness behind `tests/serving_tiers.rs`.

pub mod cpu_model;
pub mod power;
pub mod system;
pub mod tiered;

pub use cpu_model::CpuModel;
pub use power::{EnergyBreakdown, PowerModel};
pub use system::{simulate, SimResult, SimSpec};
pub use tiered::{simulate_tiered, Served, TieredArrival, TieredOutcome, TieredSpec};
