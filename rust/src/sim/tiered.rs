//! Deterministic virtual-time model of the SLO-tiered serving front-end.
//!
//! The real serving stack (`serve/`) is thread-driven: admission pops,
//! window expiry, and dispatch all race on the wall clock, so a test that
//! wants to pin *ordering* (tier precedence, EDF, escape slots, expiry
//! pruning) cannot run it directly.  This module replays a scripted
//! arrival trace against the **real** [`AdmissionQueue`] and
//! [`MicroBatcher`] — not copies — through their explicit-`now` entry
//! points (`try_pop_at`, `push(req, now)`, `poll_expired(now)`), with
//! every instant derived from one base `Instant` plus a virtual-microsecond
//! offset.  Two runs of the same spec produce identical traces on any
//! machine at any load: nothing ever reads the wall clock between events.
//!
//! The service model is the minimal one that makes backpressure real: a
//! single virtual server drains staged batches FIFO with a deterministic
//! `base + per_item` service time, and the staging buffer is bounded
//! (`ready_cap`, the analogue of the server's `READY_CAP_PER_NET`) — so
//! under overload requests wait *in the admission lanes*, where tier
//! precedence, per-lane depth, EDF order, and pop-time expiry pruning
//! decide who runs, who waits, and who is dropped, exactly as in
//! production.

use std::time::{Duration, Instant};

use crate::serve::admission::AdmissionQueue;
use crate::serve::batcher::{Batch, BatchCfg, MicroBatcher};
use crate::serve::request::{Request, SloTier};
use crate::serve::stats::TierCounts;
use crate::tensor::Tensor;

/// One scripted arrival, at a virtual-microsecond offset from time zero.
#[derive(Debug, Clone, Copy)]
pub struct TieredArrival {
    pub at_us: u64,
    pub net_id: usize,
    pub stream_id: usize,
    pub tier: SloTier,
    /// Latency budget in virtual µs (None = no deadline).
    pub deadline_us: Option<u64>,
}

/// The scripted workload + serving knobs for one simulation run.
#[derive(Debug, Clone)]
pub struct TieredSpec {
    pub n_nets: usize,
    /// Per-(network, tier) admission lane depth.
    pub lane_depth: usize,
    /// Batch-lane escape ratio (0 = strict precedence).
    pub escape_every: u64,
    pub batch: BatchCfg,
    /// Staged-batch buffer bound (admission backpressure kicks in beyond).
    pub ready_cap: usize,
    /// Fixed virtual service cost per batch…
    pub service_base_us: u64,
    /// …plus this much per request in it.
    pub service_per_item_us: u64,
    /// Must be sorted by `at_us` (ties keep spec order).
    pub arrivals: Vec<TieredArrival>,
}

impl Default for TieredSpec {
    fn default() -> Self {
        TieredSpec {
            n_nets: 1,
            lane_depth: 64,
            escape_every: crate::config::ServeCfg::default().batch_escape_every,
            batch: BatchCfg::default(),
            ready_cap: 1,
            service_base_us: 200,
            service_per_item_us: 100,
            arrivals: Vec::new(),
        }
    }
}

/// One completed request in the virtual trace.
#[derive(Debug, Clone, Copy)]
pub struct Served {
    pub net_id: usize,
    pub stream_id: usize,
    pub seq: u64,
    pub tier: SloTier,
    /// Weight-version analogue is out of scope here (the sim has no
    /// registry); the dispatch order index stands in for "which batch".
    pub batch_index: u64,
    pub submit_us: u64,
    pub finish_us: u64,
    pub due_us: Option<u64>,
}

impl Served {
    /// Virtual end-to-end latency.
    pub fn latency_us(&self) -> u64 {
        self.finish_us - self.submit_us
    }

    /// Finished past its due time?
    pub fn late(&self) -> bool {
        self.due_us.is_some_and(|due| self.finish_us > due)
    }
}

/// The full deterministic trace of one run.
#[derive(Debug, Clone)]
pub struct TieredOutcome {
    /// Completion order (ties broken by dispatch order — deterministic).
    pub served: Vec<Served>,
    /// Admission-side shed + pop-pruned expiry counters.
    pub admission: TierCounts,
    /// Requests that expired between admission pop and batch dispatch.
    pub expired_in_batcher: [u64; SloTier::COUNT],
    /// Adaptive-window (shrinks, widens) performed by the real batcher.
    pub window_events: (u64, u64),
}

impl TieredOutcome {
    pub fn completed_by_tier(&self) -> [u64; SloTier::COUNT] {
        let mut out = [0u64; SloTier::COUNT];
        for s in &self.served {
            out[s.tier.index()] += 1;
        }
        out
    }

    /// Total requests dropped (shed at admission or expired anywhere).
    pub fn dropped(&self) -> u64 {
        self.admission.shed.iter().sum::<u64>()
            + self.admission.expired.iter().sum::<u64>()
            + self.expired_in_batcher.iter().sum::<u64>()
    }
}

/// Signed virtual headroom feed for the adaptive window (ms).
fn headroom_ms(due_us: u64, now_us: u64) -> f64 {
    (due_us as f64 - now_us as f64) / 1e3
}

/// Replay `spec` to completion and return the trace.
pub fn simulate_tiered(spec: &TieredSpec) -> TieredOutcome {
    let t0 = Instant::now();
    let v = |us: u64| t0 + Duration::from_micros(us);
    let back = |i: Instant| i.saturating_duration_since(t0).as_micros() as u64;

    let queue = AdmissionQueue::new(spec.lane_depth).with_escape_every(spec.escape_every);
    let per_net_cap: Vec<Option<usize>> = vec![None; spec.n_nets.max(1)];
    let mut batcher = MicroBatcher::new(spec.batch, &per_net_cap);

    let mut served: Vec<Served> = Vec::new();
    let mut expired_in_batcher = [0u64; SloTier::COUNT];
    // Staged batches waiting for the virtual server, FIFO.
    let mut ready: Vec<(u64, Batch)> = Vec::new(); // (batch_index, batch)
    let mut batches_staged = 0u64;
    // The single virtual server: (finish_us, batch_index, requests).
    let mut in_service: Option<(u64, u64, Vec<Request>)> = None;

    let mut clock: u64 = 0;
    let mut arr_idx = 0usize;
    let mut next_seq_per_stream: std::collections::BTreeMap<usize, u64> =
        std::collections::BTreeMap::new();

    loop {
        // 1. Admit every arrival due by now (spec order on ties).
        while arr_idx < spec.arrivals.len() && spec.arrivals[arr_idx].at_us <= clock {
            let a = spec.arrivals[arr_idx];
            arr_idx += 1;
            let seq = next_seq_per_stream.entry(a.stream_id).or_insert(0);
            let mut req =
                Request::new(a.stream_id, *seq, a.net_id, Tensor::scalar(0.0))
                    .with_tier(a.tier);
            *seq += 1;
            req.submitted = v(a.at_us);
            req.deadline = a.deadline_us.map(Duration::from_micros);
            // Sheds are counted by the queue itself.
            let _ = queue.submit(req);
        }

        // 2. Complete a finished service.
        if let Some((finish, batch_index, reqs)) = in_service.take() {
            if finish <= clock {
                for req in reqs {
                    served.push(Served {
                        net_id: req.net_id,
                        stream_id: req.stream_id,
                        seq: req.seq,
                        tier: req.tier,
                        batch_index,
                        submit_us: back(req.submitted),
                        finish_us: finish,
                        due_us: req.due().map(back),
                    });
                }
            } else {
                in_service = Some((finish, batch_index, reqs));
            }
        }

        // 3. Form + stage batches while the staging buffer has room:
        //    window-expired partials first, then drain the admission
        //    lanes (tier precedence / EDF / escape decided by the REAL
        //    queue at the current virtual instant).
        let mut stage = |batch: Batch,
                         ready: &mut Vec<(u64, Batch)>,
                         batcher: &mut MicroBatcher,
                         now_us: u64| {
            let mut live = Vec::with_capacity(batch.requests.len());
            for req in batch.requests {
                if let Some(due) = req.due() {
                    batcher.record_headroom(req.tier, headroom_ms(back(due), now_us));
                }
                if req.is_expired(v(now_us)) {
                    expired_in_batcher[req.tier.index()] += 1;
                } else {
                    live.push(req);
                }
            }
            if live.is_empty() {
                return;
            }
            ready.push((
                batches_staged,
                Batch {
                    net_id: batch.net_id,
                    tier: batch.tier,
                    requests: live,
                },
            ));
            batches_staged += 1;
        };
        while ready.len() < spec.ready_cap.max(1) {
            let lapsed = batcher.poll_expired(v(clock));
            if !lapsed.is_empty() {
                for b in lapsed {
                    stage(b, &mut ready, &mut batcher, clock);
                }
                continue;
            }
            match queue.try_pop_at(v(clock)) {
                Some(req) => {
                    if let Some(b) = batcher.push(req, v(clock)) {
                        stage(b, &mut ready, &mut batcher, clock);
                    }
                }
                None => break,
            }
        }

        // 4. Start the virtual server on the oldest staged batch — after
        //    the dispatch-time prune (the real batcher's `prune_expired`
        //    before pipeline handoff): deadlines that lapsed while the
        //    batch waited for the server are dropped and counted.
        if in_service.is_none() && !ready.is_empty() {
            let (batch_index, mut batch) = ready.remove(0);
            batch.requests.retain(|req| {
                if req.is_expired(v(clock)) {
                    expired_in_batcher[req.tier.index()] += 1;
                    false
                } else {
                    true
                }
            });
            if batch.requests.is_empty() {
                continue;
            }
            let cost = spec.service_base_us
                + spec.service_per_item_us * batch.requests.len() as u64;
            in_service = Some((clock + cost, batch_index, batch.requests));
            // Freed staging room: loop back at the same instant.
            continue;
        }

        // 5. Advance the clock to the next event.
        let mut next: Option<u64> = None;
        let mut consider = |t: u64| {
            next = Some(next.map_or(t, |n: u64| n.min(t)));
        };
        if arr_idx < spec.arrivals.len() {
            consider(spec.arrivals[arr_idx].at_us);
        }
        if let Some((finish, _, _)) = &in_service {
            consider(*finish);
        }
        if ready.len() < spec.ready_cap.max(1) {
            if let Some(deadline) = batcher.next_deadline() {
                consider(back(deadline));
            }
        }
        match next {
            // Defensive floor: every event at `clock` was handled above,
            // so equal-time candidates must still move the clock.
            Some(t) => clock = t.max(clock + 1),
            None => {
                // No timed events left.  Anything still queued is
                // unreachable only if the staging buffer is full — and it
                // can't be, with the server idle (step 4 drains it).
                if queue.is_empty()
                    && batcher.pending_len() == 0
                    && ready.is_empty()
                    && in_service.is_none()
                {
                    break;
                }
                clock += 1;
            }
        }
    }

    let (shrinks, widens) = batcher.window_events();
    TieredOutcome {
        served,
        admission: queue.tier_counts(),
        expired_in_batcher,
        window_events: (shrinks, widens),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn arrival(at_us: u64, tier: SloTier, stream_id: usize) -> TieredArrival {
        TieredArrival {
            at_us,
            net_id: 0,
            stream_id,
            tier,
            deadline_us: None,
        }
    }

    fn key(s: &Served) -> (usize, usize, u64, u64, u64) {
        (s.net_id, s.stream_id, s.seq, s.submit_us, s.finish_us)
    }

    #[test]
    fn identical_specs_replay_identically() {
        let mut spec = TieredSpec {
            service_base_us: 500,
            service_per_item_us: 250,
            ..TieredSpec::default()
        };
        spec.batch.max_batch = 3;
        for i in 0..24u64 {
            let tier = SloTier::ALL[(i % 3) as usize];
            spec.arrivals.push(TieredArrival {
                at_us: i * 137,
                net_id: 0,
                stream_id: (i % 4) as usize,
                tier,
                deadline_us: (i % 2 == 0).then_some(50_000),
            });
        }
        let a = simulate_tiered(&spec);
        let b = simulate_tiered(&spec);
        let ka: Vec<_> = a.served.iter().map(key).collect();
        let kb: Vec<_> = b.served.iter().map(key).collect();
        assert_eq!(ka, kb, "virtual-time replay must be bit-deterministic");
        assert_eq!(a.admission.shed, b.admission.shed);
        assert_eq!(a.window_events, b.window_events);
        assert_eq!(a.served.len() as u64 + a.dropped(), 24);
    }

    #[test]
    fn strict_precedence_orders_backlogged_tiers() {
        // Everything arrives at t=0 into a deep queue; with escape
        // disabled and batch size 1, dispatch order IS tier order.
        let mut spec = TieredSpec {
            escape_every: 0,
            ..TieredSpec::default()
        };
        spec.batch.max_batch = 1;
        for i in 0..4 {
            spec.arrivals.push(arrival(0, SloTier::Batch, i));
        }
        for i in 0..4 {
            spec.arrivals.push(arrival(0, SloTier::Standard, i));
        }
        for i in 0..4 {
            spec.arrivals.push(arrival(0, SloTier::Interactive, i));
        }
        let out = simulate_tiered(&spec);
        assert_eq!(out.served.len(), 12);
        assert_eq!(out.dropped(), 0);
        let mut by_dispatch = out.served.clone();
        by_dispatch.sort_by_key(|s| s.batch_index);
        let tiers: Vec<SloTier> = by_dispatch.iter().map(|s| s.tier).collect();
        let mut expected = tiers.clone();
        expected.sort(); // SloTier's Ord IS precedence order
        assert_eq!(tiers, expected, "dispatch order must follow tier precedence");
    }

    #[test]
    fn deadline_storm_expires_in_lane_not_silently() {
        // A burst with deadlines shorter than one service time: the head
        // request is served, the tail expires in the lane — counted, and
        // never dispatched.
        let mut spec = TieredSpec {
            service_base_us: 10_000,
            service_per_item_us: 0,
            ..TieredSpec::default()
        };
        spec.batch.max_batch = 1;
        for i in 0..6 {
            spec.arrivals.push(TieredArrival {
                at_us: 0,
                net_id: 0,
                stream_id: i,
                tier: SloTier::Interactive,
                deadline_us: Some(5_000),
            });
        }
        let out = simulate_tiered(&spec);
        let done = out.served.len() as u64;
        let expired: u64 = out.admission.expired.iter().sum::<u64>()
            + out.expired_in_batcher.iter().sum::<u64>();
        assert_eq!(done + expired, 6, "every request accounted for");
        assert!(done >= 1, "the head of the burst must be served");
        assert!(expired >= 4, "the tail must expire, got {out:?}");
        assert_eq!(
            out.admission.expired[SloTier::Interactive.index()]
                + out.expired_in_batcher[SloTier::Interactive.index()],
            expired,
            "expiries land in the arriving tier's counters"
        );
    }
}
