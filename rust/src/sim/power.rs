//! Board power/energy model (paper §4.1 / Fig 10 / Table 3).
//!
//! Component rails calibrated to the paper's measurements on ZC702:
//! * CPU+NEON-only implementations average ≈1.52 W;
//! * the full Synergy system averages ≈2.08 W with the FPGA (fabric +
//!   PEs) accounting for ≈27% of total;
//! * ARM cores + DDR dominate the rest.
//!
//! Energy/frame = P_avg × frame time; the components are integrated from
//! the simulator's busy-time accounting.

/// Static + per-activity power constants (watts).
#[derive(Debug, Clone)]
pub struct PowerModel {
    /// Board + PS static (regulators, clocks, idle logic).
    pub p_static: f64,
    /// Per ARM core while executing.
    pub p_arm_core: f64,
    /// Extra per active NEON unit.
    pub p_neon: f64,
    /// FPGA fabric static once configured.
    pub p_fpga_static: f64,
    /// Per busy PE (dynamic).
    pub p_pe: f64,
    /// DDR power per GB/s of sustained traffic.
    pub p_ddr_per_gbps: f64,
    /// DDR background (refresh, PHY).
    pub p_ddr_static: f64,
}

impl PowerModel {
    pub fn zc702() -> PowerModel {
        PowerModel {
            p_static: 0.40,
            p_arm_core: 0.50,
            p_neon: 0.18,
            p_fpga_static: 0.15,
            p_pe: 0.050,
            p_ddr_per_gbps: 0.22,
            p_ddr_static: 0.18,
        }
    }
}

/// Activity integrals from a simulation run.
#[derive(Debug, Clone, Default)]
pub struct Activity {
    /// Total wall (virtual) time of the run, seconds.
    pub makespan: f64,
    /// Σ over cores of busy seconds.
    pub cpu_busy: f64,
    /// Σ over NEON units of busy seconds.
    pub neon_busy: f64,
    /// Σ over PEs of busy seconds.
    pub pe_busy: f64,
    /// Whether the bitstream is loaded at all (false for CPU/NEON-only).
    pub fpga_configured: bool,
    /// Bytes moved through DDR (FPGA side + estimated CPU-side traffic).
    pub ddr_bytes: u64,
    pub frames: usize,
}

/// Energy/power breakdown (the paper's Fig 10 components).
#[derive(Debug, Clone, PartialEq)]
pub struct EnergyBreakdown {
    pub avg_power_w: f64,
    pub energy_per_frame_mj: f64,
    pub static_w: f64,
    pub arm_w: f64,
    pub neon_w: f64,
    pub fpga_w: f64,
    pub ddr_w: f64,
}

impl EnergyBreakdown {
    /// FPGA share of total average power (paper: ≈27% for Synergy).
    pub fn fpga_fraction(&self) -> f64 {
        if self.avg_power_w > 0.0 {
            self.fpga_w / self.avg_power_w
        } else {
            0.0
        }
    }
}

impl PowerModel {
    /// Integrate activity into average power + per-frame energy.
    pub fn evaluate(&self, act: &Activity) -> EnergyBreakdown {
        let t = act.makespan.max(1e-9);
        let arm_w = self.p_arm_core * (act.cpu_busy / t);
        let neon_w = self.p_neon * (act.neon_busy / t);
        let fpga_w = if act.fpga_configured {
            self.p_fpga_static + self.p_pe * (act.pe_busy / t)
        } else {
            0.0
        };
        let gbps = act.ddr_bytes as f64 / t / 1e9;
        let ddr_w = self.p_ddr_static + self.p_ddr_per_gbps * gbps;
        let avg = self.p_static + arm_w + neon_w + fpga_w + ddr_w;
        let energy_per_frame_mj = if act.frames > 0 {
            avg * t / act.frames as f64 * 1e3
        } else {
            0.0
        };
        EnergyBreakdown {
            avg_power_w: avg,
            energy_per_frame_mj,
            static_w: self.p_static,
            arm_w,
            neon_w,
            fpga_w,
            ddr_w,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cpu_only_operating_point() {
        // 1 core busy 100%, no FPGA, modest DDR → ≈1.3–1.6 W (paper: CPU
        // baseline draws ≈1.4–1.5 W).
        let pm = PowerModel::zc702();
        let act = Activity {
            makespan: 1.0,
            cpu_busy: 1.0,
            neon_busy: 0.0,
            pe_busy: 0.0,
            fpga_configured: false,
            ddr_bytes: 800_000_000, // 0.8 GB/s
            frames: 10,
        };
        let e = pm.evaluate(&act);
        assert!((1.2..1.6).contains(&e.avg_power_w), "{}", e.avg_power_w);
        assert_eq!(e.fpga_w, 0.0);
    }

    #[test]
    fn synergy_operating_point() {
        // 2 cores ≈70% busy, 2 NEONs ≈80%, 8 PEs ≈95%, heavy DDR → ≈2 W
        // with FPGA ≈ 20–30% (paper: 2.08 W, 27%).
        let pm = PowerModel::zc702();
        let act = Activity {
            makespan: 1.0,
            cpu_busy: 1.4,
            neon_busy: 1.6,
            pe_busy: 7.6,
            fpga_configured: true,
            ddr_bytes: 1_500_000_000,
            frames: 100,
        };
        let e = pm.evaluate(&act);
        assert!((1.8..2.5).contains(&e.avg_power_w), "{}", e.avg_power_w);
        assert!(
            (0.18..0.35).contains(&e.fpga_fraction()),
            "fpga frac {}",
            e.fpga_fraction()
        );
    }

    #[test]
    fn energy_per_frame_scales_with_time() {
        let pm = PowerModel::zc702();
        let mut act = Activity {
            makespan: 1.0,
            cpu_busy: 1.0,
            frames: 10,
            ..Default::default()
        };
        let e1 = pm.evaluate(&act).energy_per_frame_mj;
        act.makespan = 2.0;
        act.cpu_busy = 2.0;
        let e2 = pm.evaluate(&act).energy_per_frame_mj;
        assert!((e2 / e1 - 2.0).abs() < 1e-9);
    }

    #[test]
    fn zero_frames_zero_energy() {
        let pm = PowerModel::zc702();
        let e = pm.evaluate(&Activity::default());
        assert_eq!(e.energy_per_frame_mj, 0.0);
    }
}
