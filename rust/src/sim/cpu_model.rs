//! ARM Cortex-A9 cycle model for the CPU-resident layers (paper §3.1.4:
//! pooling, activation, fully-connected, batchnorm, softmax, plus the
//! im2col / normalization preprocessing).
//!
//! Per-element cycle constants are calibrated so the single-threaded
//! CPU-only baseline reproduces the paper's *original Darknet* operating
//! points (Table 3: e.g. MNIST ≈ 112.9 mJ/frame at ≈1.4 W → ≈80 ms/frame).
//! The dominant term is the scalar GEMM at ≈4.8 cycles/MAC — a realistic
//! -O3 figure for an in-order A9 with 32-byte lines and no L2 prefetch.

use crate::config::{LayerSpec, NetConfig};
use crate::nn::{conv_out_hw, network::Shape, pool_out_hw};

/// Cycle-cost constants (cycles per element / per MAC).
#[derive(Debug, Clone)]
pub struct CpuModel {
    pub hz: f64,
    pub gemm_cyc_per_mac: f64,
    pub im2col_cyc_per_elem: f64,
    pub conv_post_cyc_per_elem: f64,
    pub pool_cyc_per_out_elem: f64,
    pub fc_cyc_per_mac: f64,
    pub bn_cyc_per_elem: f64,
    pub softmax_cyc_per_elem: f64,
    pub normalize_cyc_per_elem: f64,
}

impl CpuModel {
    pub fn a9(cpu_mhz: f64) -> CpuModel {
        CpuModel {
            hz: cpu_mhz * 1e6,
            gemm_cyc_per_mac: 4.8,
            im2col_cyc_per_elem: 6.0,
            conv_post_cyc_per_elem: 3.0,
            pool_cyc_per_out_elem: 10.0,
            fc_cyc_per_mac: 4.8,
            bn_cyc_per_elem: 6.0,
            softmax_cyc_per_elem: 30.0,
            normalize_cyc_per_elem: 4.0,
        }
    }

    fn s(&self, cycles: f64) -> f64 {
        cycles / self.hz
    }

    /// im2col of one CONV layer: touches C·K²·OH·OW elements.
    pub fn im2col_seconds(&self, c: usize, ksize: usize, oh: usize, ow: usize) -> f64 {
        self.s(self.im2col_cyc_per_elem * (c * ksize * ksize * oh * ow) as f64)
    }

    /// Bias + activation after the GEMM.
    pub fn conv_post_seconds(&self, oc: usize, oh: usize, ow: usize) -> f64 {
        self.s(self.conv_post_cyc_per_elem * (oc * oh * ow) as f64)
    }

    /// The CONV GEMM itself when it runs on the CPU (the baseline).
    pub fn gemm_seconds(&self, m: usize, n: usize, p: usize) -> f64 {
        self.s(self.gemm_cyc_per_mac * (m * n * p) as f64)
    }

    pub fn pool_seconds(&self, c: usize, oh: usize, ow: usize, size: usize) -> f64 {
        self.s(self.pool_cyc_per_out_elem * (c * oh * ow) as f64 * (size * size) as f64 / 4.0)
    }

    pub fn fc_seconds(&self, n_in: usize, n_out: usize) -> f64 {
        self.s(self.fc_cyc_per_mac * (n_in * n_out) as f64)
    }

    pub fn bn_seconds(&self, elems: usize) -> f64 {
        self.s(self.bn_cyc_per_elem * elems as f64)
    }

    pub fn softmax_seconds(&self, elems: usize) -> f64 {
        self.s(self.softmax_cyc_per_elem * elems as f64)
    }

    pub fn normalize_seconds(&self, elems: usize) -> f64 {
        self.s(self.normalize_cyc_per_elem * elems as f64)
    }

    /// CPU cost of a layer, split into (pre, gemm, post) segments:
    /// * CONV: pre = im2col, gemm = the MM (CPU path only), post = bias+act;
    /// * others: everything in `pre`.
    ///
    /// `in_shape` is the layer's input shape.
    pub fn layer_segments(&self, layer: &LayerSpec, in_shape: Shape) -> (f64, f64, f64) {
        match layer {
            LayerSpec::Conv {
                filters,
                size,
                stride,
                pad,
                ..
            } => {
                let (c, h, w) = match in_shape {
                    Shape::Chw(c, h, w) => (c, h, w),
                    Shape::Flat(_) => unreachable!("validated topology"),
                };
                let (oh, ow) = conv_out_hw(h, w, *size, *stride, *pad);
                (
                    self.im2col_seconds(c, *size, oh, ow),
                    self.gemm_seconds(*filters, c * size * size, oh * ow),
                    self.conv_post_seconds(*filters, oh, ow),
                )
            }
            LayerSpec::MaxPool { size, stride } | LayerSpec::AvgPool { size, stride } => {
                let (c, h, w) = match in_shape {
                    Shape::Chw(c, h, w) => (c, h, w),
                    Shape::Flat(_) => unreachable!(),
                };
                let (oh, ow) = pool_out_hw(h, w, *size, *stride);
                (self.pool_seconds(c, oh, ow, *size), 0.0, 0.0)
            }
            LayerSpec::Connected { output, .. } => {
                (self.fc_seconds(in_shape.len(), *output), 0.0, 0.0)
            }
            LayerSpec::BatchNorm => (self.bn_seconds(in_shape.len()), 0.0, 0.0),
            LayerSpec::Dropout { .. } => (0.0, 0.0, 0.0),
            LayerSpec::Softmax => (self.softmax_seconds(in_shape.len()), 0.0, 0.0),
        }
    }

    /// Total single-threaded CPU-only time per frame (the original-Darknet
    /// baseline of Fig 9 / Table 3).
    pub fn frame_seconds_cpu_only(&self, net: &NetConfig, shapes: &[Shape]) -> f64 {
        let mut total = self.normalize_seconds(net.channels * net.height * net.width);
        let mut cur = Shape::Chw(net.channels, net.height, net.width);
        for (idx, layer) in net.layers.iter().enumerate() {
            let (pre, gemm, post) = self.layer_segments(layer, cur);
            total += pre + gemm + post;
            cur = shapes[idx];
        }
        total
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::zoo;
    use crate::nn::network::infer_shapes;

    #[test]
    fn mnist_baseline_near_paper_operating_point() {
        let cfg = zoo::load("mnist").unwrap();
        let shapes = infer_shapes(&cfg).unwrap();
        let m = CpuModel::a9(667.0);
        let t = m.frame_seconds_cpu_only(&cfg, &shapes);
        // Paper Table 3: ≈80 ms/frame (112.9 mJ at ≈1.4 W).
        assert!((0.06..0.11).contains(&t), "mnist cpu frame {t}s");
    }

    #[test]
    fn zoo_baselines_ordered_by_workload() {
        let m = CpuModel::a9(667.0);
        let t = |name: &str| {
            let cfg = zoo::load(name).unwrap();
            let shapes = infer_shapes(&cfg).unwrap();
            m.frame_seconds_cpu_only(&cfg, &shapes)
        };
        // alex+ is the heaviest, mpcnn the lightest (paper Table 3 energy).
        assert!(t("cifar_alex_plus") > t("cifar_full"));
        assert!(t("cifar_full") > t("mpcnn"));
        assert!(t("mnist") > t("mpcnn"));
    }

    #[test]
    fn conv_segments_dominated_by_gemm() {
        let cfg = zoo::load("mnist").unwrap();
        let m = CpuModel::a9(667.0);
        let (pre, gemm, post) = m.layer_segments(
            &cfg.layers[2],
            Shape::Chw(32, 14, 14),
        );
        assert!(gemm > pre && gemm > post, "{pre} {gemm} {post}");
    }

    #[test]
    fn dropout_free() {
        let m = CpuModel::a9(667.0);
        let (a, b, c) = m.layer_segments(
            &LayerSpec::Dropout { probability: 0.5 },
            Shape::Flat(100),
        );
        assert_eq!((a, b, c), (0.0, 0.0, 0.0));
    }
}
