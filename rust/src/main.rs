//! Synergy CLI — launcher for the coordinator, the simulator, the paper
//! experiments, the cluster DSE, and the hardware architecture generator.
//!
//! ```text
//! synergy models
//! synergy run    --model mnist --frames 20 [--pjrt] [--no-steal]
//! synergy sim    --model mnist --frames 50 --design synergy|sf|cpu|non-pipelined
//! synergy repro  <fig7|fig9|fig10|fig11|fig12|fig13|fig14|table3|table4|table5|table6|all>
//! synergy dse    --model cifar_alex [--frames 16]
//! synergy hwgen  [--config path.hw_config] --out dir
//! ```

use std::path::Path;
use std::sync::Arc;

use anyhow::{anyhow, bail, Result};

use synergy::config::{zoo, HwConfig};
use synergy::experiments as exp;
use synergy::hwgen;
use synergy::nn::Network;
use synergy::rt::{self, ComputeMode, RtOptions};
use synergy::sched::dse;
use synergy::sim::{simulate, SimSpec};
use synergy::tensor::Tensor;
use synergy::util::argparse::Args;
use synergy::util::bench::{fmt, Table};

const USAGE: &str = "\
synergy — HW/SW co-designed CNN inference (Synergy reproduction)

USAGE:
  synergy models
      List the benchmark model zoo (paper Table 2).
  synergy run --model <name> [--frames N] [--pjrt] [--no-steal]
      Stream frames through the REAL threaded pipeline (layer threads,
      cluster queues, delegate threads, thief).  --pjrt executes PE jobs
      through the AOT Pallas kernel on PJRT (requires `make artifacts`).
  synergy sim --model <name> [--frames N] [--design D]
      Virtual-clock full-system simulation on the modelled ZC702.
      D = synergy | sf | cpu | fpga-only | neon-only | non-pipelined
  synergy repro <exp>|all [--frames N]
      Regenerate a paper table/figure (fig7 fig9 fig10 fig11 fig12 fig13
      fig14 table3 table4 table5 table6).
  synergy dse --model <name> [--frames N]
      Exhaustive SC cluster-configuration search (paper Table 5).
  synergy hwgen [--config <file>] --out <dir>
      Run the hardware architecture generator (paper Fig 8).
";

fn main() {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    if raw.is_empty() || raw[0] == "--help" || raw[0] == "-h" {
        print!("{USAGE}");
        return;
    }
    if let Err(e) = dispatch(&raw) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn dispatch(raw: &[String]) -> Result<()> {
    let args = Args::parse(raw, &["pjrt", "no-steal", "verbose"]).map_err(|e| anyhow!(e))?;
    match args.subcommand.as_deref() {
        Some("models") => cmd_models(),
        Some("run") => cmd_run(&args),
        Some("sim") => cmd_sim(&args),
        Some("repro") => cmd_repro(&args),
        Some("dse") => cmd_dse(&args),
        Some("hwgen") => cmd_hwgen(&args),
        Some(other) => bail!("unknown subcommand {other:?}\n{USAGE}"),
        None => bail!("missing subcommand\n{USAGE}"),
    }
}

fn load_net(args: &Args) -> Result<Network> {
    let model = args
        .get("model")
        .ok_or_else(|| anyhow!("--model <name> required (see `synergy models`)"))?;
    let cfg = zoo::load(model)?;
    Network::new(cfg, 32)
}

fn cmd_models() -> Result<()> {
    let mut table = Table::new(&["model", "input", "layers", "CONV", "MOP/frame", "jobs/frame"]);
    for name in zoo::ZOO {
        let net = Network::new(zoo::load(name)?, 32)?;
        let (c, h, w) = net.input_shape();
        let jobs: usize = net.conv_infos().iter().map(|ci| ci.grid.num_jobs()).sum();
        table.row(vec![
            name.to_string(),
            format!("{c}x{h}x{w}"),
            net.config.layers.len().to_string(),
            net.config.num_conv_layers().to_string(),
            format!("{:.1}", net.mops()),
            jobs.to_string(),
        ]);
    }
    table.print();
    Ok(())
}

fn cmd_run(args: &Args) -> Result<()> {
    let net = Arc::new(load_net(args)?);
    let frames_n = args.get_usize("frames", 10).map_err(|e| anyhow!(e))?;
    let options = RtOptions {
        hw: HwConfig::default_zc702(),
        compute: if args.has_flag("pjrt") {
            ComputeMode::Pjrt
        } else {
            ComputeMode::Native
        },
        work_stealing: !args.has_flag("no-steal"),
        mailbox_capacity: 1,
    };
    if options.compute == ComputeMode::Pjrt && !synergy::runtime::PJRT_COMPILED {
        eprintln!("note: built without the `pjrt` feature — PE delegates fall back to native GEMM");
    }
    println!(
        "running {} frames of {} ({} compute, stealing {})",
        frames_n,
        net.config.name,
        if options.compute == ComputeMode::Pjrt { "PJRT" } else { "native" },
        if options.work_stealing { "on" } else { "off" },
    );
    let frames: Vec<(u64, Tensor)> = (0..frames_n as u64)
        .map(|f| (f, net.make_input(f)))
        .collect();
    let report = rt::driver::run_stream(Arc::clone(&net), options, frames)?;
    for (frame, out) in report.outputs.iter().take(3) {
        let (top, p) = out
            .data()
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap();
        println!("  frame {frame}: class {top} (p={p:.4})");
    }
    if report.outputs.len() > 3 {
        println!("  ... {} more frames", report.outputs.len() - 3);
    }
    println!(
        "wall: {:.3}s  throughput: {:.1} frames/s  jobs: {} ({} stolen)",
        report.wall_seconds, report.fps, report.jobs_executed, report.jobs_stolen
    );
    println!("per-accel jobs: {:?}", report.per_accel_jobs);
    let classes: Vec<String> = synergy::mm::JobClass::ALL
        .iter()
        .map(|c| format!("{}={}", c.label(), report.per_class_jobs[c.index()]))
        .collect();
    println!("per-class jobs: {}", classes.join(" "));
    Ok(())
}

fn cmd_sim(args: &Args) -> Result<()> {
    let net = load_net(args)?;
    let frames = args.get_usize("frames", 50).map_err(|e| anyhow!(e))?;
    let design = args.get_or("design", "synergy");
    let spec = match design {
        "synergy" => SimSpec::synergy(&net, frames),
        "sf" => SimSpec::static_fixed(&net, frames),
        "cpu" => SimSpec::cpu_only(&net, frames),
        "fpga-only" => SimSpec::synergy(&net, frames).with_accels(&net, |a| a.is_fpga()),
        "neon-only" => SimSpec::synergy(&net, frames).with_accels(&net, |a| !a.is_fpga()),
        "non-pipelined" => SimSpec::synergy(&net, frames).non_pipelined(),
        other => bail!("unknown --design {other:?}"),
    };
    let r = simulate(&spec, &net);
    let mut table = Table::new(&["metric", "value"]);
    table.row(vec!["throughput (fps)".into(), fmt(r.fps)]);
    table.row(vec!["mean latency (ms)".into(), fmt(r.mean_latency_s * 1e3)]);
    table.row(vec!["cluster utilization".into(), format!("{:.1}%", 100.0 * r.cluster_util)]);
    table.row(vec!["accel occupancy".into(), format!("{:.1}%", 100.0 * r.accel_util)]);
    table.row(vec!["CPU utilization".into(), format!("{:.1}%", 100.0 * r.cpu_util)]);
    table.row(vec!["avg power (W)".into(), fmt(r.energy.avg_power_w)]);
    table.row(vec!["energy (mJ/frame)".into(), fmt(r.energy.energy_per_frame_mj)]);
    table.row(vec!["GOPS".into(), fmt(r.gops)]);
    table.row(vec!["jobs executed".into(), r.jobs_executed.to_string()]);
    table.row(vec!["jobs stolen".into(), r.jobs_stolen.to_string()]);
    table.row(vec!["mem queue time (ms)".into(), fmt(r.mem_queue_s * 1e3)]);
    table.print();
    Ok(())
}

fn cmd_repro(args: &Args) -> Result<()> {
    let which = args
        .positional
        .first()
        .map(|s| s.as_str())
        .ok_or_else(|| anyhow!("repro needs an experiment id or 'all'"))?;
    let frames = args
        .get_usize("frames", exp::PIPELINE_FRAMES)
        .map_err(|e| anyhow!(e))?;
    let reports = match which {
        "all" => exp::run_all(frames),
        "fig7" => vec![exp::fig07_mmu::run()],
        "fig9" => vec![exp::fig09_throughput::run(frames)],
        "fig10" => vec![exp::fig10_power::run(frames)],
        "fig11" => vec![exp::fig11_latency::run(frames)],
        "fig12" => vec![exp::fig12_pipeline::run(frames)],
        "fig13" => vec![exp::fig13_worksteal::run(frames)],
        "fig14" => vec![exp::fig14_balance::run(frames)],
        "table3" => vec![exp::table3_energy::run(frames)],
        "table4" => vec![exp::table4_soa::run(frames)],
        "table5" => vec![exp::table5_sc::run(frames.min(16))],
        "table6" => vec![exp::table6_util::run(frames)],
        other => bail!("unknown experiment {other:?}"),
    };
    for r in reports {
        r.print();
    }
    Ok(())
}

fn cmd_dse(args: &Args) -> Result<()> {
    let net = load_net(args)?;
    let frames = args.get_usize("frames", 16).map_err(|e| anyhow!(e))?;
    let r = dse::explore(&net, frames);
    println!(
        "{}: best of {} configs — cluster0 = {}, cluster1 = {} ({:.1} fps)",
        net.config.name,
        r.evaluated,
        dse::describe_tuple(&r.best[0]),
        dse::describe_tuple(&r.best[1]),
        r.best_fps
    );
    Ok(())
}

fn cmd_hwgen(args: &Args) -> Result<()> {
    let hw = match args.get("config") {
        Some(path) => HwConfig::load(Path::new(path))?,
        None => HwConfig::default_zc702(),
    };
    let out = args
        .get("out")
        .ok_or_else(|| anyhow!("--out <dir> required"))?;
    let design = hwgen::generate(&hw, Path::new(out))?;
    println!("generated design in {}:", design.dir.display());
    for (name, path) in &design.pe_sources {
        println!("  PE source [{name}]: {}", path.display());
    }
    println!("  wiring: {}", design.wiring_manifest.display());
    println!("  bitstream: {} (hash {:#018x})", design.bitstream_manifest.display(), design.bitstream_hash);
    println!();
    print!("{}", design.report.render());
    Ok(())
}
