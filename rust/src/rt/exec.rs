//! The pooled matrix-work executor: adapts the [`Dispatcher`] to the
//! network's [`MatExec`] hooks so a layer thread's CONV GEMMs, FC GEMMs,
//! and im2col lowering all become jobs on the shared heterogeneous pool.
//!
//! One [`PoolRouter`] exists per (network, pool) pairing and carries the
//! static CONV-layer → cluster assignment; [`PoolRouter::frame`] builds a
//! per-frame executor that owns the frame's [`FrameArena`]: packed im2col
//! panels and fused-FC column packs are allocated straight into the arena,
//! CONV-tile jobs carry views that alias the arena chunk on one side and
//! the network's load-time weight prepack on the other, and the whole
//! working set drops when the executor does.  Every job goes through the
//! dispatcher's one generic entry point ([`Dispatcher::execute_job`] /
//! [`Dispatcher::execute_jobs`]) with the layer's placement hint stamped
//! on the job itself; member-level routing guarantees any capable member
//! of any cluster can serve it (a pool with zero capable members is
//! handled — and counted — inside the [`Dispatcher`]).

use std::cell::RefCell;
use std::sync::Arc;

use crate::mm::job::{gather_results, jobs_from_packs, jobs_from_packs_q8, ClassMask, Job};
use crate::mm::{FrameArena, OperandView, TileGrid};
use crate::nn::network::MatExec;
use crate::nn::Network;
use crate::tensor::Tensor;

use super::pool::Dispatcher;

/// Routes one network's matrix work into a [`Dispatcher`].  Cheap to
/// clone (layer threads each hold one).
#[derive(Clone)]
pub struct PoolRouter {
    dispatcher: Dispatcher,
    /// `layer_idx` → destination cluster for CONV layers (from the static
    /// mapping, indexed by network layer).
    conv_cluster: Arc<Vec<Option<usize>>>,
    tile_size: usize,
}

impl PoolRouter {
    /// Build from a network and its CONV-ordinal → cluster `assignment`
    /// (the static mapper's output).
    pub fn new(net: &Network, dispatcher: Dispatcher, assignment: &[usize]) -> PoolRouter {
        let mut conv_cluster = vec![None; net.config.layers.len()];
        for (ord, ci) in net.conv_infos().iter().enumerate() {
            conv_cluster[ci.layer_idx] = Some(assignment[ord]);
        }
        PoolRouter {
            dispatcher,
            conv_cluster: Arc::new(conv_cluster),
            tile_size: net.tile_size(),
        }
    }

    /// Per-frame executor (implements [`MatExec`]) owning the frame's
    /// operand arena.
    pub fn frame(&self, frame_id: u64) -> FrameExec<'_> {
        FrameExec {
            router: self,
            frame_id,
            arena: RefCell::new(FrameArena::new()),
        }
    }
}

/// A [`MatExec`] implementation dispatching one frame's matrix work to
/// the accelerator pool.  Owns the frame's [`FrameArena`]: every packed
/// transient operand (im2col panels, fused-FC columns) lives in the arena
/// and is aliased — not copied — by the jobs the frame emits.
pub struct FrameExec<'a> {
    router: &'a PoolRouter,
    frame_id: u64,
    /// The frame's transient operand buffers.  `RefCell`: a frame executor
    /// belongs to one layer thread; `MatExec` hooks take `&self`.
    arena: RefCell<FrameArena>,
}

impl FrameExec<'_> {
    /// Placement hint for one layer: `Some` only for CONV layers the
    /// static mapper placed.  FC and other unmapped layers carry `None`
    /// and route purely least-loaded instead of being silently biased
    /// toward cluster 0 (the old `unwrap_or(0)` bug).
    fn placement(&self, layer_idx: usize) -> Option<usize> {
        self.router.conv_cluster[layer_idx]
    }

    /// Does `view` alias one of this frame's arena chunks?  (The
    /// zero-copy proof hook the tests pin.)
    pub fn arena_holds(&self, view: &OperandView) -> bool {
        self.arena.borrow().holds(view)
    }

    /// Number of f32 operand chunks this frame has allocated so far.
    pub fn arena_chunks(&self) -> usize {
        self.arena.borrow().chunk_count()
    }

    /// Number of i8 operand chunks (quantized activation planes) this
    /// frame has allocated so far.
    pub fn arena_i8_chunks(&self) -> usize {
        self.arena.borrow().i8_chunk_count()
    }
}

impl MatExec for FrameExec<'_> {
    fn conv_gemm(
        &self,
        layer_idx: usize,
        grid: TileGrid,
        a_tiles: OperandView,
        b_tiles: OperandView,
    ) -> Vec<f32> {
        debug_assert!(
            self.router.conv_cluster[layer_idx].is_some(),
            "conv layer {layer_idx} not placed by the static mapper"
        );
        let placement = self.placement(layer_idx);
        let mut next_id = self
            .router
            .dispatcher
            .reserve_job_ids(grid.num_jobs() as u64);
        // Each job slices its (K,TS,TS) fetch-set windows out of the two
        // packs — refcount bumps and offset arithmetic, no bytes move.
        let jobs: Vec<Job> = jobs_from_packs(
            layer_idx,
            self.frame_id,
            grid,
            a_tiles,
            b_tiles,
            &mut next_id,
        )
        .into_iter()
        .map(|j| j.placed(placement))
        .collect();
        let results = self.router.dispatcher.execute_jobs(jobs);
        gather_results(grid, &results)
    }

    fn pack_cols(&self, _layer_idx: usize, grid: &TileGrid, col: &[f32]) -> OperandView {
        // Pack the im2col matrix straight into the frame arena: the one
        // place a CONV layer's activation bytes are copied per frame.
        self.arena
            .borrow_mut()
            .alloc_with(grid.cols() * grid.panel_elems(), |dst| {
                grid.pack_b_tiles_into(col, dst)
            })
    }

    fn pack_fc_cols(&self, _layer_idx: usize, cols: &[&[f32]]) -> OperandView {
        // The packed (IN,B) operand is adopted by the arena without a
        // second copy; the fused job aliases it.
        self.arena
            .borrow_mut()
            .adopt(crate::mm::job::pack_fc_columns(cols))
    }

    fn fc_gemm(
        &self,
        layer_idx: usize,
        out_n: usize,
        in_n: usize,
        w: OperandView,
        x: OperandView,
    ) -> Vec<f32> {
        let id = self.router.dispatcher.reserve_job_ids(1);
        let job = Job::fc(
            id,
            layer_idx,
            self.frame_id,
            out_n,
            in_n,
            w,
            x,
            self.router.tile_size,
        )
        .placed(self.placement(layer_idx));
        self.router.dispatcher.execute_job(job).data
    }

    fn fc_gemm_batch(
        &self,
        layer_idx: usize,
        out_n: usize,
        in_n: usize,
        batch: usize,
        w: OperandView,
        xb: OperandView,
    ) -> Vec<f32> {
        let id = self.router.dispatcher.reserve_job_ids(1);
        let job = Job::fc_batch(
            id,
            layer_idx,
            self.frame_id,
            out_n,
            in_n,
            batch,
            w,
            xb,
            self.router.tile_size,
        )
        .placed(self.placement(layer_idx));
        self.router.dispatcher.execute_job(job).data
    }

    /// The pool speaks Q8 only when its members cover ALL the int8 twin
    /// classes — a partial claim (e.g. a remote-only pool without
    /// single-column Q8 FC) must push the quantized forward onto the
    /// dequantized f32 classes rather than leak unroutable Q8 jobs into
    /// the counted inline fallback.
    fn supports_q8(&self) -> bool {
        let mut union = ClassMask::NONE;
        for mask in self.router.dispatcher.accept_masks() {
            union = union.union(mask);
        }
        union.intersect(ClassMask::Q8) == ClassMask::Q8
    }

    fn adopt_q8_plane(&self, _layer_idx: usize, codes: Vec<i8>) -> OperandView<i8> {
        // Same zero-copy contract as the f32 planes: the arena owns the
        // codes, Q8 jobs alias them.
        self.arena.borrow_mut().adopt_i8(codes)
    }

    fn conv_gemm_q8(
        &self,
        layer_idx: usize,
        grid: TileGrid,
        a_tiles: OperandView<i8>,
        b_tiles: OperandView<i8>,
        scale: f32,
    ) -> Vec<f32> {
        debug_assert!(
            self.router.conv_cluster[layer_idx].is_some(),
            "conv layer {layer_idx} not placed by the static mapper"
        );
        let placement = self.placement(layer_idx);
        let mut next_id = self
            .router
            .dispatcher
            .reserve_job_ids(grid.num_jobs() as u64);
        let jobs: Vec<Job> = jobs_from_packs_q8(
            layer_idx,
            self.frame_id,
            grid,
            a_tiles,
            b_tiles,
            scale,
            &mut next_id,
        )
        .into_iter()
        .map(|j| j.placed(placement))
        .collect();
        let results = self.router.dispatcher.execute_jobs(jobs);
        gather_results(grid, &results)
    }

    fn fc_gemm_q8(
        &self,
        layer_idx: usize,
        out_n: usize,
        in_n: usize,
        w: OperandView<i8>,
        x: OperandView<i8>,
        scale: f32,
    ) -> Vec<f32> {
        let id = self.router.dispatcher.reserve_job_ids(1);
        let job = Job::fc_q8(
            id,
            layer_idx,
            self.frame_id,
            out_n,
            in_n,
            w,
            x,
            scale,
            self.router.tile_size,
        )
        .placed(self.placement(layer_idx));
        self.router.dispatcher.execute_job(job).data
    }

    #[allow(clippy::too_many_arguments)]
    fn fc_gemm_batch_q8(
        &self,
        layer_idx: usize,
        out_n: usize,
        in_n: usize,
        batch: usize,
        w: OperandView<i8>,
        xb: OperandView<i8>,
        scale: f32,
    ) -> Vec<f32> {
        let id = self.router.dispatcher.reserve_job_ids(1);
        let job = Job::fc_batch_q8(
            id,
            layer_idx,
            self.frame_id,
            out_n,
            in_n,
            batch,
            w,
            xb,
            scale,
            self.router.tile_size,
        )
        .placed(self.placement(layer_idx));
        self.router.dispatcher.execute_job(job).data
    }

    fn im2col_lower(
        &self,
        layer_idx: usize,
        input: Tensor,
        size: usize,
        stride: usize,
        pad: usize,
    ) -> Tensor {
        let shape = input.shape();
        let chw = (shape[0], shape[1], shape[2]);
        let id = self.router.dispatcher.reserve_job_ids(1);
        // The activation buffer moves into the shared job operand — no
        // copy on the layer thread.
        let job = Job::im2col(
            id,
            layer_idx,
            self.frame_id,
            chw,
            size,
            stride,
            pad,
            input.into_vec(),
            self.router.tile_size,
        )
        .placed(self.placement(layer_idx));
        let col = self.router.dispatcher.execute_job(job).data;
        let rows = chw.0 * size * size;
        let cols = col.len() / rows;
        Tensor::from_vec(&[rows, cols], col)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::zoo;
    use crate::mm::job::{JobClass, JobKind};
    use crate::nn::network::NativeExec;
    use crate::rt::pool::{DelegatePool, PoolOptions};
    use crate::rt::ComputeMode;
    use crate::sched::static_map;

    #[test]
    fn routed_forward_matches_reference_and_counts_classes() {
        let net = Network::new(zoo::load("mnist").unwrap(), 32).unwrap();
        let options = PoolOptions::new(
            crate::config::HwConfig::default_zc702(),
            ComputeMode::Native,
            true,
        );
        let pool = DelegatePool::start(&options).unwrap();
        let assignment = static_map::assign(&net.conv_infos(), pool.clusters());
        let router = PoolRouter::new(&net, pool.dispatcher(), &assignment);

        let x = net.make_input(0);
        let exec = router.frame(0);
        let y = net.forward_with(&x, &exec);
        let want = net.forward_reference(&x);
        // The pooled path runs the identical per-tile kernel over the
        // identical packed panels as the reference — bit equality, not
        // tolerance.
        assert_eq!(y.data(), want.data(), "pool path must be bit-identical");
        // One arena chunk per CONV layer (the packed im2col panels); the
        // frame's jobs aliased them instead of owning copies.
        assert_eq!(exec.arena_chunks(), net.conv_infos().len());

        let report = pool.shutdown().unwrap();
        let profile = net.pool_job_profile();
        for class in JobClass::ALL {
            assert_eq!(
                report.per_class_jobs[class.index()],
                profile[class.index()] as u64,
                "{}",
                class.label()
            );
        }
        assert_eq!(
            report.jobs_executed,
            profile.iter().sum::<usize>() as u64
        );
        assert_eq!(report.inline_fallbacks, 0);
        assert_eq!(report.dispatched_by_class, report.per_class_jobs);
    }

    /// The fused batch path through the pool: bit-equal to the reference,
    /// ONE FcGemmBatch job per FC layer for the whole batch, per-request
    /// CONV front-end.
    #[test]
    fn batched_forward_through_pool_fuses_fc_layers() {
        let net = Network::new(zoo::load("mnist").unwrap(), 32).unwrap();
        let options = PoolOptions::new(
            crate::config::HwConfig::default_zc702(),
            ComputeMode::Native,
            true,
        );
        let pool = DelegatePool::start(&options).unwrap();
        let assignment = static_map::assign(&net.conv_infos(), pool.clusters());
        let router = PoolRouter::new(&net, pool.dispatcher(), &assignment);

        let batch = 4usize;
        let xs: Vec<_> = (0..batch as u64).map(|f| net.make_input(f)).collect();
        let exec = router.frame(0);
        let ys = net.forward_batch_with(&xs, &exec);
        for (x, y) in xs.iter().zip(&ys) {
            let want = net.forward_reference(x);
            assert!(y.allclose(&want, 1e-4, 1e-5), "{}", y.max_abs_diff(&want));
        }

        let report = pool.shutdown().unwrap();
        let profile = net.pool_job_profile_batched(batch);
        for class in JobClass::ALL {
            assert_eq!(
                report.per_class_jobs[class.index()],
                profile[class.index()] as u64,
                "{}",
                class.label()
            );
        }
        // mnist: 2 FC layers → exactly 2 fused jobs covering 4 rows each.
        assert_eq!(
            report.per_class_jobs[JobClass::FcGemmBatch.index()],
            net.fc_layer_count() as u64
        );
        assert_eq!(report.per_class_jobs[JobClass::FcGemm.index()], 0);
        assert_eq!(
            report.fused_fc_rows,
            (net.fc_layer_count() * batch) as u64
        );
        assert_eq!(report.inline_fallbacks, 0);
    }

    /// The quantized forward through the pool: every GEMM class moves to
    /// its int8 twin (the f32 classes stay at zero), the result is
    /// bit-identical to the all-native q8 path (integer accumulation both
    /// sides), and nothing runs inline.
    #[test]
    fn quantized_forward_through_pool_dispatches_q8_classes() {
        let net = Network::new(zoo::load("mnist").unwrap(), 32).unwrap();
        let qnet = crate::nn::QuantizedNetwork::calibrate(net, 2);
        let options = PoolOptions::new(
            crate::config::HwConfig::default_zc702(),
            ComputeMode::Native,
            true,
        );
        let pool = DelegatePool::start(&options).unwrap();
        let assignment = static_map::assign(&qnet.net().conv_infos(), pool.clusters());
        let router = PoolRouter::new(qnet.net(), pool.dispatcher(), &assignment);

        let x = qnet.net().make_input(0);
        let exec = router.frame(0);
        assert!(exec.supports_q8(), "default pool members claim Q8");
        let y = qnet.forward_with(&x, &exec);
        let want = qnet.forward_with(&x, &NativeExec);
        assert_eq!(y.data(), want.data(), "pooled q8 must match native q8");
        // The quantized activation planes live in the frame arena's i8
        // side: one chunk per CONV layer + one per FC layer.
        assert_eq!(
            exec.arena_i8_chunks(),
            qnet.net().conv_infos().len() + qnet.net().fc_layer_count()
        );

        let report = pool.shutdown().unwrap();
        let profile = qnet.pool_job_profile_q8();
        for class in JobClass::ALL {
            assert_eq!(
                report.per_class_jobs[class.index()],
                profile[class.index()] as u64,
                "{}",
                class.label()
            );
        }
        assert_eq!(report.per_class_jobs[JobClass::ConvTile.index()], 0);
        assert_eq!(report.per_class_jobs[JobClass::FcGemm.index()], 0);
        assert!(report.per_class_jobs[JobClass::ConvTileQ8.index()] > 0);
        assert!(report.per_class_jobs[JobClass::FcGemmQ8.index()] > 0);
        assert_eq!(report.inline_fallbacks, 0);
        assert_eq!(report.dispatched_by_class, report.per_class_jobs);
    }

    /// Regression for the bogus cluster-0 placement hint on non-CONV
    /// layers: with cluster 0 rebuilt PE-only (CONV-capable only under
    /// PJRT-stub mode) and the NEON members moved to cluster 1, FC and
    /// fused-FC work must route least-loaded onto the NEON-capable
    /// cluster — never inline, never onto cluster 0.
    #[test]
    fn fc_routes_off_pe_only_cluster0() {
        let mut hw = crate::config::HwConfig::default_zc702();
        hw.clusters[0].neon = 0; // cluster 0: 2 S-PE only
        hw.clusters[1].neon = 2; // cluster 1: 6 F-PE + 2 NEON
        let options = PoolOptions::new(hw, ComputeMode::Pjrt, false);
        let pool = DelegatePool::start(&options).unwrap();
        let accels = pool.accels();
        let dispatcher = pool.dispatcher();
        // Cluster 0 cannot accept FC work at all.
        assert!(!dispatcher.accept_masks()[0].supports(JobClass::FcGemm));
        assert_eq!(dispatcher.route(JobClass::FcGemm, None), Some(1));
        assert_eq!(dispatcher.route(JobClass::FcGemmBatch, None), Some(1));

        let net = Network::new(zoo::load("mnist").unwrap(), 32).unwrap();
        let assignment = static_map::assign(&net.conv_infos(), pool.clusters());
        let router = PoolRouter::new(&net, pool.dispatcher(), &assignment);
        let x = net.make_input(0);
        let exec = router.frame(0);
        let y = net.forward_with(&x, &exec);
        let want = net.forward_reference(&x);
        assert!(y.allclose(&want, 1e-4, 1e-5));
        let xs: Vec<_> = (1..3u64).map(|f| net.make_input(f)).collect();
        let _ = net.forward_batch_with(&xs, &router.frame(1));

        let report = pool.shutdown().unwrap();
        assert_eq!(report.inline_fallbacks, 0, "FC must reach the pool");
        let non_conv = |by_class: &[u64; JobClass::COUNT]| {
            by_class[JobClass::FcGemm.index()]
                + by_class[JobClass::Im2col.index()]
                + by_class[JobClass::FcGemmBatch.index()]
        };
        let mut neon_non_conv = 0u64;
        for accel in &accels {
            let by_class = &report.per_accel_by_class[accel.id];
            if accel.is_fpga() {
                assert_eq!(non_conv(by_class), 0, "{} ran non-CONV work", accel.name);
            } else {
                assert_eq!(accel.cluster, 1, "NEON members live on cluster 1");
                neon_non_conv += non_conv(by_class);
            }
        }
        // 3 frames of im2col+FC (per-sample ×1, fused path ×2) all landed
        // on cluster-1 NEON members.
        assert!(neon_non_conv > 0, "NEON members never served FC/im2col");
        assert_eq!(
            report.per_class_jobs[JobClass::FcGemmBatch.index()],
            net.fc_layer_count() as u64
        );
    }

    /// The zero-copy proof (satellite of the operand-plane redesign):
    /// CONV-tile jobs alias the frame arena on the activation side and the
    /// network's load-time weight prepack on the weight side; FC jobs
    /// alias the weight param allocation itself; and the per-layer pack
    /// counter stays at one no matter how many frames run.
    #[test]
    fn dispatched_jobs_alias_arena_and_load_time_prepacks() {
        let net = Network::new(zoo::load("mnist").unwrap(), 32).unwrap();
        let options = PoolOptions::new(
            crate::config::HwConfig::default_zc702(),
            ComputeMode::Native,
            false,
        );
        let pool = DelegatePool::start(&options).unwrap();
        let assignment = static_map::assign(&net.conv_infos(), pool.clusters());
        let router = PoolRouter::new(&net, pool.dispatcher(), &assignment);
        let exec = router.frame(3);

        // Build one CONV layer's jobs exactly as the executor does.
        let info = &net.conv_infos()[0];
        let grid = info.grid;
        let col = vec![0.25f32; grid.n * grid.p];
        let b_tiles = exec.pack_cols(info.layer_idx, &grid, &col);
        assert!(exec.arena_holds(&b_tiles), "packed cols live in the arena");
        let a_tiles = net.conv_pack(info.layer_idx);
        let mut next_id = 0u64;
        let jobs = jobs_from_packs(
            info.layer_idx,
            3,
            grid,
            a_tiles.clone(),
            b_tiles.clone(),
            &mut next_id,
        );
        assert_eq!(jobs.len(), grid.num_jobs());
        for job in &jobs {
            let JobKind::ConvTile {
                a_tiles: ja,
                b_tiles: jb,
            } = &job.kind
            else {
                panic!("conv grid lowered to a non-CONV job");
            };
            assert!(
                Arc::ptr_eq(ja.buffer(), a_tiles.buffer()),
                "weight view must alias the load-time prepack"
            );
            assert!(
                exec.arena_holds(jb),
                "activation view must alias the frame arena"
            );
        }

        // A probing executor proves the same holds on the real forward
        // path: every FC weight view IS the param allocation, every CONV
        // weight view IS the prepack.
        use std::sync::atomic::{AtomicUsize, Ordering};
        struct AliasProbe<'a> {
            net: &'a Network,
            conv_seen: AtomicUsize,
            fc_seen: AtomicUsize,
        }
        impl MatExec for AliasProbe<'_> {
            fn conv_gemm(
                &self,
                layer_idx: usize,
                grid: TileGrid,
                a_tiles: OperandView,
                b_tiles: OperandView,
            ) -> Vec<f32> {
                assert!(
                    Arc::ptr_eq(a_tiles.buffer(), self.net.conv_pack(layer_idx).buffer()),
                    "layer {layer_idx}: weight pack re-materialized"
                );
                self.conv_seen.fetch_add(1, Ordering::SeqCst);
                NativeExec.conv_gemm(layer_idx, grid, a_tiles, b_tiles)
            }
            fn fc_gemm(
                &self,
                layer_idx: usize,
                out_n: usize,
                in_n: usize,
                w: OperandView,
                x: OperandView,
            ) -> Vec<f32> {
                assert!(
                    Arc::ptr_eq(w.buffer(), &self.net.weights_arc(layer_idx)),
                    "layer {layer_idx}: FC weight view must alias the param"
                );
                self.fc_seen.fetch_add(1, Ordering::SeqCst);
                let mut y = vec![0.0f32; out_n];
                crate::mm::gemm::gemm_blocked_into(&w, &x, &mut y, out_n, in_n, 1);
                y
            }
        }
        let probe = AliasProbe {
            net: &net,
            conv_seen: AtomicUsize::new(0),
            fc_seen: AtomicUsize::new(0),
        };
        let _ = net.forward_with(&net.make_input(0), &probe);
        assert_eq!(probe.conv_seen.load(Ordering::SeqCst), net.conv_infos().len());
        assert_eq!(probe.fc_seen.load(Ordering::SeqCst), net.fc_layer_count());
        // Weights were packed exactly once per CONV layer, at load — the
        // frames above added zero packs.
        for info in &net.conv_infos() {
            assert_eq!(net.weight_pack_count(info.layer_idx), 1);
        }

        drop(exec);
        pool.shutdown().unwrap();
    }
}
