//! The pooled matrix-work executor: adapts the [`Dispatcher`] to the
//! network's [`MatExec`] hooks so a layer thread's CONV GEMMs, FC GEMMs,
//! and im2col lowering all become jobs on the shared heterogeneous pool.
//!
//! One [`PoolRouter`] exists per (network, pool) pairing and carries the
//! static CONV-layer → cluster assignment; [`PoolRouter::frame`] stamps a
//! frame id onto a lightweight per-frame executor handed to
//! `Network::forward_layer`.  Every class is dispatched unconditionally:
//! member-level routing guarantees any capable member of any cluster can
//! serve it, so the old per-cluster capability probe and its inline
//! fallback are gone (a pool with zero capable members is handled —
//! and counted — inside the [`Dispatcher`]).

use std::sync::Arc;

use crate::mm::TileGrid;
use crate::nn::network::MatExec;
use crate::nn::Network;
use crate::tensor::Tensor;

use super::pool::{Dispatcher, GemmCtx};

/// Routes one network's matrix work into a [`Dispatcher`].  Cheap to
/// clone (layer threads each hold one).
#[derive(Clone)]
pub struct PoolRouter {
    dispatcher: Dispatcher,
    /// `layer_idx` → destination cluster for CONV layers (from the static
    /// mapping, indexed by network layer).
    conv_cluster: Arc<Vec<Option<usize>>>,
    tile_size: usize,
}

impl PoolRouter {
    /// Build from a network and its CONV-ordinal → cluster `assignment`
    /// (the static mapper's output).
    pub fn new(net: &Network, dispatcher: Dispatcher, assignment: &[usize]) -> PoolRouter {
        let mut conv_cluster = vec![None; net.config.layers.len()];
        for (ord, ci) in net.conv_infos().iter().enumerate() {
            conv_cluster[ci.layer_idx] = Some(assignment[ord]);
        }
        PoolRouter {
            dispatcher,
            conv_cluster: Arc::new(conv_cluster),
            tile_size: net.tile_size(),
        }
    }

    /// Per-frame executor (implements [`MatExec`]).
    pub fn frame(&self, frame_id: u64) -> FrameExec<'_> {
        FrameExec {
            router: self,
            frame_id,
        }
    }
}

/// A [`MatExec`] implementation dispatching one frame's matrix work to
/// the accelerator pool.
pub struct FrameExec<'a> {
    router: &'a PoolRouter,
    frame_id: u64,
}

impl FrameExec<'_> {
    /// Dispatch context for one layer.  The placement hint stays `None`
    /// for layers the static mapper did not place (FC layers, anything
    /// non-CONV): the dispatcher then routes purely least-loaded across
    /// capable clusters instead of being silently biased toward
    /// cluster 0 (the old `unwrap_or(0)` bug).
    fn ctx(&self, layer_idx: usize) -> GemmCtx {
        GemmCtx {
            cluster: self.router.conv_cluster[layer_idx],
            layer_idx,
            frame_id: self.frame_id,
        }
    }
}

impl MatExec for FrameExec<'_> {
    fn conv_gemm(
        &self,
        layer_idx: usize,
        grid: TileGrid,
        a: Arc<Vec<f32>>,
        b: Arc<Vec<f32>>,
    ) -> Vec<f32> {
        debug_assert!(
            self.router.conv_cluster[layer_idx].is_some(),
            "conv layer {layer_idx} not placed by the static mapper"
        );
        self.router
            .dispatcher
            .execute_gemm(self.ctx(layer_idx), grid, a, b)
    }

    fn fc_gemm(
        &self,
        layer_idx: usize,
        out_n: usize,
        in_n: usize,
        w: Arc<Vec<f32>>,
        x: Arc<Vec<f32>>,
    ) -> Vec<f32> {
        let ctx = self.ctx(layer_idx);
        self.router
            .dispatcher
            .execute_fc(ctx, out_n, in_n, w, x, self.router.tile_size)
    }

    fn fc_gemm_batch(
        &self,
        layer_idx: usize,
        out_n: usize,
        in_n: usize,
        batch: usize,
        w: Arc<Vec<f32>>,
        xb: Arc<Vec<f32>>,
    ) -> Vec<f32> {
        let ctx = self.ctx(layer_idx);
        self.router.dispatcher.execute_fc_batch(
            ctx,
            out_n,
            in_n,
            batch,
            w,
            xb,
            self.router.tile_size,
        )
    }

    fn im2col_lower(
        &self,
        layer_idx: usize,
        input: Tensor,
        size: usize,
        stride: usize,
        pad: usize,
    ) -> Tensor {
        let shape = input.shape();
        let chw = (shape[0], shape[1], shape[2]);
        let ctx = self.ctx(layer_idx);
        // The activation buffer moves into the shared job operand — no
        // copy on the layer thread.
        let col = self.router.dispatcher.execute_im2col(
            ctx,
            chw,
            size,
            stride,
            pad,
            Arc::new(input.into_vec()),
            self.router.tile_size,
        );
        let rows = chw.0 * size * size;
        let cols = col.len() / rows;
        Tensor::from_vec(&[rows, cols], col)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::zoo;
    use crate::mm::job::JobClass;
    use crate::rt::pool::{DelegatePool, PoolOptions};
    use crate::rt::ComputeMode;
    use crate::sched::static_map;

    #[test]
    fn routed_forward_matches_reference_and_counts_classes() {
        let net = Network::new(zoo::load("mnist").unwrap(), 32).unwrap();
        let options = PoolOptions::new(
            crate::config::HwConfig::default_zc702(),
            ComputeMode::Native,
            true,
        );
        let pool = DelegatePool::start(&options).unwrap();
        let assignment = static_map::assign(&net.conv_infos(), pool.clusters());
        let router = PoolRouter::new(&net, pool.dispatcher(), &assignment);

        let x = net.make_input(0);
        let exec = router.frame(0);
        let y = net.forward_with(&x, &exec);
        let want = net.forward_reference(&x);
        assert!(y.allclose(&want, 1e-4, 1e-5), "{}", y.max_abs_diff(&want));

        let report = pool.shutdown().unwrap();
        let profile = net.pool_job_profile();
        for class in JobClass::ALL {
            assert_eq!(
                report.per_class_jobs[class.index()],
                profile[class.index()] as u64,
                "{}",
                class.label()
            );
        }
        assert_eq!(
            report.jobs_executed,
            profile.iter().sum::<usize>() as u64
        );
        assert_eq!(report.inline_fallbacks, 0);
        assert_eq!(report.dispatched_by_class, report.per_class_jobs);
    }

    /// The fused batch path through the pool: bit-equal to the reference,
    /// ONE FcGemmBatch job per FC layer for the whole batch, per-request
    /// CONV front-end.
    #[test]
    fn batched_forward_through_pool_fuses_fc_layers() {
        let net = Network::new(zoo::load("mnist").unwrap(), 32).unwrap();
        let options = PoolOptions::new(
            crate::config::HwConfig::default_zc702(),
            ComputeMode::Native,
            true,
        );
        let pool = DelegatePool::start(&options).unwrap();
        let assignment = static_map::assign(&net.conv_infos(), pool.clusters());
        let router = PoolRouter::new(&net, pool.dispatcher(), &assignment);

        let batch = 4usize;
        let xs: Vec<_> = (0..batch as u64).map(|f| net.make_input(f)).collect();
        let exec = router.frame(0);
        let ys = net.forward_batch_with(&xs, &exec);
        for (x, y) in xs.iter().zip(&ys) {
            let want = net.forward_reference(x);
            assert!(y.allclose(&want, 1e-4, 1e-5), "{}", y.max_abs_diff(&want));
        }

        let report = pool.shutdown().unwrap();
        let profile = net.pool_job_profile_batched(batch);
        for class in JobClass::ALL {
            assert_eq!(
                report.per_class_jobs[class.index()],
                profile[class.index()] as u64,
                "{}",
                class.label()
            );
        }
        // mnist: 2 FC layers → exactly 2 fused jobs covering 4 rows each.
        assert_eq!(
            report.per_class_jobs[JobClass::FcGemmBatch.index()],
            net.fc_layer_count() as u64
        );
        assert_eq!(report.per_class_jobs[JobClass::FcGemm.index()], 0);
        assert_eq!(
            report.fused_fc_rows,
            (net.fc_layer_count() * batch) as u64
        );
        assert_eq!(report.inline_fallbacks, 0);
    }

    /// Regression for the bogus cluster-0 placement hint on non-CONV
    /// layers: with cluster 0 rebuilt PE-only (CONV-capable only under
    /// PJRT-stub mode) and the NEON members moved to cluster 1, FC and
    /// fused-FC work must route least-loaded onto the NEON-capable
    /// cluster — never inline, never onto cluster 0.
    #[test]
    fn fc_routes_off_pe_only_cluster0() {
        let mut hw = crate::config::HwConfig::default_zc702();
        hw.clusters[0].neon = 0; // cluster 0: 2 S-PE only
        hw.clusters[1].neon = 2; // cluster 1: 6 F-PE + 2 NEON
        let options = PoolOptions::new(hw, ComputeMode::Pjrt, false);
        let pool = DelegatePool::start(&options).unwrap();
        let accels = pool.accels();
        let dispatcher = pool.dispatcher();
        // Cluster 0 cannot accept FC work at all.
        assert!(!dispatcher.accept_masks()[0].supports(JobClass::FcGemm));
        assert_eq!(dispatcher.route(JobClass::FcGemm, None), Some(1));
        assert_eq!(dispatcher.route(JobClass::FcGemmBatch, None), Some(1));

        let net = Network::new(zoo::load("mnist").unwrap(), 32).unwrap();
        let assignment = static_map::assign(&net.conv_infos(), pool.clusters());
        let router = PoolRouter::new(&net, pool.dispatcher(), &assignment);
        let x = net.make_input(0);
        let exec = router.frame(0);
        let y = net.forward_with(&x, &exec);
        let want = net.forward_reference(&x);
        assert!(y.allclose(&want, 1e-4, 1e-5));
        let xs: Vec<_> = (1..3u64).map(|f| net.make_input(f)).collect();
        let _ = net.forward_batch_with(&xs, &router.frame(1));

        let report = pool.shutdown().unwrap();
        assert_eq!(report.inline_fallbacks, 0, "FC must reach the pool");
        let non_conv = |by_class: &[u64; JobClass::COUNT]| {
            by_class[JobClass::FcGemm.index()]
                + by_class[JobClass::Im2col.index()]
                + by_class[JobClass::FcGemmBatch.index()]
        };
        let mut neon_non_conv = 0u64;
        for accel in &accels {
            let by_class = &report.per_accel_by_class[accel.id];
            if accel.is_fpga() {
                assert_eq!(non_conv(by_class), 0, "{} ran non-CONV work", accel.name);
            } else {
                assert_eq!(accel.cluster, 1, "NEON members live on cluster 1");
                neon_non_conv += non_conv(by_class);
            }
        }
        // 3 frames of im2col+FC (per-sample ×1, fused path ×2) all landed
        // on cluster-1 NEON members.
        assert!(neon_non_conv > 0, "NEON members never served FC/im2col");
        assert_eq!(
            report.per_class_jobs[JobClass::FcGemmBatch.index()],
            net.fc_layer_count() as u64
        );
    }
}
