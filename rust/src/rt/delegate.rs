//! Delegate threads (paper §3.1.2): the software wrappers that stand in
//! for hardware accelerators inside the OS threading model.
//!
//! Each delegate owns one [`Accelerator`] backend (built *inside* the
//! thread — the PJRT engine is `Rc`-backed, and hardware-wise each PE is
//! its own physical kernel instance) and services its cluster's job-queue
//! *bank* through its **own member capability mask**: it pops from the
//! union of per-class sub-queues its backend supports, executes on the
//! backend, and acknowledges the result — the control-FIFO protocol of
//! Fig 5, with the mpsc reply channel standing in for `if_hw2sw`.  A NEON
//! member of a mixed NEON+PE cluster therefore keeps serving FC/im2col
//! jobs while the PE member drains CONV tiles.  Per-class counters feed
//! the pool report's heterogeneous accounting.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::Sender;
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::Result;

use crate::accel::{Accelerator, LinkCost};
use crate::cluster::QueueBank;
use crate::mm::job::{ClassMask, Classed, Job, JobClass, JobResult};
use crate::sched::worksteal::ThiefMsg;

/// A job plus its reply channel (the "acknowledgment" path of Fig 5).
pub struct RtJob {
    pub job: Job,
    pub reply: Sender<JobResult>,
}

impl Classed for RtJob {
    fn class_index(&self) -> usize {
        self.job.class().index()
    }
}

/// Per-delegate counters.
#[derive(Debug, Default)]
pub struct DelegateStats {
    pub jobs: AtomicU64,
    pub ksteps: AtomicU64,
    pub idle_reports: AtomicU64,
    /// Jobs this delegate held when its backend failed and pushed back
    /// onto the cluster bank for surviving members to drain (the
    /// zero-loss requeue path — e.g. a remote shard's transport dropping
    /// mid-batch).
    pub requeued: AtomicU64,
    /// Jobs executed per class ([`JobClass`] dense order).
    pub jobs_by_class: [AtomicU64; JobClass::COUNT],
}

impl DelegateStats {
    pub fn jobs_by_class(&self) -> [u64; JobClass::COUNT] {
        let mut out = [0u64; JobClass::COUNT];
        for (o, c) in out.iter_mut().zip(&self.jobs_by_class) {
            *o = c.load(Ordering::Relaxed);
        }
        out
    }
}

/// Spawn a delegate thread servicing its cluster's `bank` through the
/// member capability mask `caps` (the registry metadata of this member's
/// backend — the delegate only ever sees jobs its backend can execute).
///
/// The backend is built *inside* the thread via `mk_backend` (see the
/// module docs) and driven exclusively through the [`Accelerator`] trait —
/// the delegate has no knowledge of which implementation it holds.
///
/// `rescue` is the union of the capability masks of the members that
/// could still serve this bank if this delegate dies — its cluster mates,
/// plus every other cluster's members when the thief is running (stolen
/// work travels).  On a backend failure the delegate requeues the jobs it
/// holds whose class some survivor covers (the zero-loss path) and drops
/// the rest — dropping closes their reply channels, so blocking callers
/// fail fast instead of waiting on jobs nobody can ever execute.
///
/// `drain_extra` is the number of additional jobs the delegate may grab in
/// one queue visit once it holds a job (0 = strict one-at-a-time, the
/// single-stream driver's sharing-friendly behavior; the batched serving
/// runtime raises it to amortize queue locks over micro-batch job runs).
///
/// `link` is this member's routing cost cell.  A dying delegate *evicts*
/// it before requeueing — the dispatcher, thief, and route tables all read
/// the same cell, so the member disappears from routing the moment its
/// backend fails instead of collecting further jobs that would only be
/// rediscovered dead via requeue.
///
/// The thread exits when the bank is closed and its *eligible* sub-queues
/// are drained.  On queue timeout it reports `ClusterIdle` to the thief
/// (work-stealing trigger).
#[allow(clippy::too_many_arguments)]
pub fn spawn(
    name: String,
    cluster: usize,
    bank: Arc<QueueBank<RtJob>>,
    caps: ClassMask,
    rescue: ClassMask,
    mk_backend: impl FnOnce() -> Result<Box<dyn Accelerator>> + Send + 'static,
    thief: Option<Sender<ThiefMsg>>,
    stats: Arc<DelegateStats>,
    drain_extra: usize,
    link: Option<Arc<LinkCost>>,
) -> JoinHandle<Result<()>> {
    std::thread::Builder::new()
        .name(name)
        .spawn(move || {
            let backend = match mk_backend() {
                Ok(b) => b,
                Err(e) => {
                    // A backend that never came up is as dead as one that
                    // failed mid-run: poison the routing cell first.
                    if let Some(l) = &link {
                        l.evict();
                    }
                    return Err(e);
                }
            };
            delegate_loop(
                cluster,
                bank,
                caps,
                rescue,
                backend,
                thief,
                stats,
                drain_extra,
                link,
            )
        })
        .expect("spawn delegate thread")
}

#[allow(clippy::too_many_arguments)]
fn delegate_loop(
    cluster: usize,
    bank: Arc<QueueBank<RtJob>>,
    caps: ClassMask,
    rescue: ClassMask,
    mut backend: Box<dyn Accelerator>,
    thief: Option<Sender<ThiefMsg>>,
    stats: Arc<DelegateStats>,
    drain_extra: usize,
    link: Option<Arc<LinkCost>>,
) -> Result<()> {
    loop {
        let rt_job = match bank.pop_any_timeout(caps, Duration::from_micros(500)) {
            Ok(Some(j)) => j,
            Ok(None) => return Ok(()), // closed + drained
            Err(()) => {
                // Idle: notify the thief's manager (paper Fig 4 step 1),
                // carrying this member's mask so the thief only steals
                // classes the idle member can actually execute.
                if let Some(tx) = &thief {
                    stats.idle_reports.fetch_add(1, Ordering::Relaxed);
                    let _ = tx.send(ThiefMsg::ClusterIdle(cluster, caps));
                }
                // Longer nap so an empty tail doesn't spin.
                match bank.pop_any_timeout(caps, Duration::from_millis(2)) {
                    Ok(Some(j)) => j,
                    Ok(None) => return Ok(()),
                    Err(()) => continue,
                }
            }
        };
        let mut run = vec![rt_job];
        if drain_extra > 0 {
            run.extend(bank.pop_upto(caps, drain_extra));
        }
        for i in 0..run.len() {
            // Routing + capability-filtered stealing keep unsupported
            // classes off this queue; a violation is a scheduler bug.
            debug_assert!(
                backend.supports(run[i].job.class()),
                "{} delegate received a {} job",
                backend.id(),
                run[i].job.class().label()
            );
            match backend.execute(&run[i].job) {
                Ok(result) => {
                    stats.jobs.fetch_add(1, Ordering::Relaxed);
                    stats.ksteps.fetch_add(run[i].job.ksteps(), Ordering::Relaxed);
                    stats.jobs_by_class[run[i].job.class().index()]
                        .fetch_add(1, Ordering::Relaxed);
                    // Receiver may have gone away on shutdown; that's fine.
                    let _ = run[i].reply.send(result);
                }
                Err(e) => {
                    // Backend failure (e.g. a remote shard's transport
                    // dropping mid-batch).  The failed job was never
                    // observably completed and the rest of the run was
                    // never attempted.  Jobs a surviving member can serve
                    // (`rescue`) go back onto the bank — the zero-loss
                    // path (`tests/remote_shard.rs`, the failure
                    // harness); requeue is safe because jobs are pure: at
                    // worst a job whose result frame died in flight
                    // computes twice, and one result reaches the reply
                    // channel.  Jobs NO survivor covers are dropped
                    // instead, closing their reply channels so blocking
                    // callers fail fast rather than wait forever on work
                    // nobody can execute.  Then die loudly — a backend
                    // that cannot execute is gone, not idle.
                    //
                    // Evict the routing cell FIRST: by the time the
                    // requeued jobs are visible to survivors, the
                    // dispatcher and thief already see this member as
                    // dead (overhead = INFINITY) and route around it.
                    if let Some(l) = &link {
                        l.evict();
                    }
                    let (requeue, orphans): (Vec<RtJob>, Vec<RtJob>) = run
                        .drain(i..)
                        .partition(|rt| rescue.supports(rt.job.class()));
                    stats
                        .requeued
                        .fetch_add(requeue.len() as u64, Ordering::Relaxed);
                    let _ = bank.push_batch(requeue);
                    drop(orphans);
                    if let Some(tx) = &thief {
                        let _ = tx.send(ThiefMsg::ClusterBusy(cluster));
                    }
                    return Err(e);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::NativeGemm;
    use crate::mm::job::jobs_for_gemm;
    use crate::mm::TileGrid;
    use crate::util::rng::XorShift64Star;
    use std::sync::mpsc;

    fn native_backend() -> Result<Box<dyn Accelerator>> {
        Ok(Box::new(NativeGemm))
    }

    #[test]
    fn native_delegate_services_jobs_and_exits_on_close() {
        let queue: Arc<QueueBank<RtJob>> = Arc::new(QueueBank::new());
        let stats = Arc::new(DelegateStats::default());
        let handle = spawn(
            "test-delegate".into(),
            0,
            Arc::clone(&queue),
            ClassMask::all(),
            ClassMask::all(),
            native_backend,
            None,
            Arc::clone(&stats),
            2,
            None,
        );

        let grid = TileGrid::new(40, 50, 60, 32);
        let a = Arc::new(XorShift64Star::new(1).fill_f32(40 * 50, 1.0));
        let b = Arc::new(XorShift64Star::new(2).fill_f32(50 * 60, 1.0));
        let mut id = 0;
        let jobs = jobs_for_gemm(0, 0, grid, a, b, &mut id);
        let n = jobs.len();
        let (tx, rx) = mpsc::channel();
        for job in jobs {
            queue.push(RtJob {
                job,
                reply: tx.clone(),
            });
        }
        let mut results = Vec::new();
        for _ in 0..n {
            results.push(rx.recv_timeout(Duration::from_secs(5)).unwrap());
        }
        queue.close();
        handle.join().unwrap().unwrap();
        assert_eq!(stats.jobs.load(Ordering::Relaxed), n as u64);
        assert_eq!(
            stats.jobs_by_class()[JobClass::ConvTile.index()],
            n as u64
        );
        // every tile distinct
        let mut seen = std::collections::HashSet::new();
        for r in &results {
            assert!(seen.insert((r.desc.t1, r.desc.t2)));
        }
    }

    #[test]
    fn delegate_executes_all_job_classes_and_counts_them() {
        let queue: Arc<QueueBank<RtJob>> = Arc::new(QueueBank::new());
        let stats = Arc::new(DelegateStats::default());
        let handle = spawn(
            "mixed-delegate".into(),
            0,
            Arc::clone(&queue),
            ClassMask::all(),
            ClassMask::all(),
            native_backend,
            None,
            Arc::clone(&stats),
            0,
            None,
        );

        let (tx, rx) = mpsc::channel();
        // One FC job and one im2col job.
        let w = Arc::new(XorShift64Star::new(3).fill_f32(10 * 20, 1.0));
        let x = Arc::new(XorShift64Star::new(4).fill_f32(20, 1.0));
        queue.push(RtJob {
            job: Job::fc(0, 5, 1, 10, 20, w, x, 32),
            reply: tx.clone(),
        });
        let input = Arc::new(XorShift64Star::new(5).fill_f32(3 * 8 * 8, 1.0));
        queue.push(RtJob {
            job: Job::im2col(1, 0, 1, (3, 8, 8), 3, 1, 1, input, 32),
            reply: tx.clone(),
        });
        drop(tx);
        let r1 = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        let r2 = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert_eq!(r1.data.len(), 10); // FC output
        assert_eq!(r2.data.len(), 3 * 3 * 3 * 8 * 8); // im2col matrix
        queue.close();
        handle.join().unwrap().unwrap();
        let by_class = stats.jobs_by_class();
        assert_eq!(by_class[JobClass::FcGemm.index()], 1);
        assert_eq!(by_class[JobClass::Im2col.index()], 1);
        assert_eq!(by_class[JobClass::ConvTile.index()], 0);
        assert_eq!(stats.jobs.load(Ordering::Relaxed), 2);
    }

    #[test]
    fn idle_delegate_reports_to_thief() {
        let queue: Arc<QueueBank<RtJob>> = Arc::new(QueueBank::new());
        let stats = Arc::new(DelegateStats::default());
        let (ttx, trx) = mpsc::channel();
        let handle = spawn(
            "idle-delegate".into(),
            3,
            Arc::clone(&queue),
            ClassMask::all(),
            ClassMask::all(),
            native_backend,
            Some(ttx),
            Arc::clone(&stats),
            0,
            None,
        );
        // No jobs: the delegate must report idleness at least once,
        // carrying its own member mask.
        let msg = trx.recv_timeout(Duration::from_secs(2)).unwrap();
        assert_eq!(msg, ThiefMsg::ClusterIdle(3, ClassMask::all()));
        queue.close();
        handle.join().unwrap().unwrap();
        assert!(stats.idle_reports.load(Ordering::Relaxed) >= 1);
    }

    /// A backend that dies mid-run must requeue the failed job and its
    /// never-attempted drain mates — jobs are conserved for surviving
    /// members, not dropped with their reply channels.
    #[test]
    fn failing_backend_requeues_its_run() {
        struct DiesAfter(usize);
        impl Accelerator for DiesAfter {
            fn id(&self) -> &str {
                "dies-after"
            }
            fn supports(&self, _class: JobClass) -> bool {
                true
            }
            fn execute(&mut self, job: &Job) -> Result<JobResult> {
                if self.0 == 0 {
                    anyhow::bail!("injected backend death");
                }
                self.0 -= 1;
                Ok(job.execute_native())
            }
        }

        let bank: Arc<QueueBank<RtJob>> = Arc::new(QueueBank::new());
        let stats = Arc::new(DelegateStats::default());
        let (tx, rx) = mpsc::channel();
        // 5 FC jobs; drain_extra 4 lets the delegate grab all of them in
        // one visit, then die on the 3rd — mid-batch.
        for i in 0..5u64 {
            let w = Arc::new(XorShift64Star::new(40 + i).fill_f32(6 * 8, 1.0));
            let x = Arc::new(XorShift64Star::new(50 + i).fill_f32(8, 1.0));
            bank.push(RtJob {
                job: Job::fc(i, 0, 0, 6, 8, w, x, 32),
                reply: tx.clone(),
            });
        }
        drop(tx);
        // A teammate covers every class, so the whole run is rescuable.
        let link = LinkCost::fixed(0.25);
        let handle = spawn(
            "dying-delegate".into(),
            0,
            Arc::clone(&bank),
            ClassMask::all(),
            ClassMask::all(),
            || Ok(Box::new(DiesAfter(2)) as Box<dyn Accelerator>),
            None,
            Arc::clone(&stats),
            4,
            Some(Arc::clone(&link)),
        );
        let err = handle.join().unwrap().expect_err("backend must die");
        assert!(err.to_string().contains("injected"), "{err}");
        // The dying delegate poisoned its routing cell before requeueing.
        assert!(!link.is_alive(), "dead member must be evicted from routing");
        assert!(link.overhead_ksteps().is_infinite());
        // 2 executed (replies delivered), 3 requeued — none lost.
        assert_eq!(stats.jobs.load(Ordering::Relaxed), 2);
        assert_eq!(stats.requeued.load(Ordering::Relaxed), 3);
        let mut done = 0;
        while rx.try_recv().is_ok() {
            done += 1;
        }
        assert_eq!(done, 2);
        assert_eq!(bank.class_counts()[JobClass::FcGemm.index()], 3);

        // A healthy teammate drains the requeued jobs to completion.
        let neon_stats = Arc::new(DelegateStats::default());
        let neon = spawn(
            "rescuer".into(),
            0,
            Arc::clone(&bank),
            ClassMask::all(),
            ClassMask::all(),
            native_backend,
            None,
            Arc::clone(&neon_stats),
            0,
            None,
        );
        let deadline = std::time::Instant::now() + Duration::from_secs(5);
        while neon_stats.jobs.load(Ordering::Relaxed) < 3
            && std::time::Instant::now() < deadline
        {
            std::thread::sleep(Duration::from_millis(1));
        }
        bank.close();
        neon.join().unwrap().unwrap();
        assert_eq!(neon_stats.jobs.load(Ordering::Relaxed), 3);
    }

    #[test]
    fn masked_delegate_never_touches_other_classes() {
        // A CONV-only member must leave FC/im2col jobs in the bank for a
        // capable teammate — the member-level routing contract.
        let bank: Arc<QueueBank<RtJob>> = Arc::new(QueueBank::new());
        let conv_stats = Arc::new(DelegateStats::default());
        let conv_handle = spawn(
            "conv-only-delegate".into(),
            0,
            Arc::clone(&bank),
            ClassMask::of(&[JobClass::ConvTile]),
            ClassMask::all(),
            native_backend,
            None,
            Arc::clone(&conv_stats),
            2,
            None,
        );
        let (tx, rx) = mpsc::channel();
        let w = Arc::new(XorShift64Star::new(9).fill_f32(8 * 8, 1.0));
        let x = Arc::new(XorShift64Star::new(10).fill_f32(8, 1.0));
        bank.push(RtJob {
            job: Job::fc(0, 0, 0, 8, 8, w, x, 32),
            reply: tx.clone(),
        });
        // Give the conv-only delegate time to (wrongly) grab it.
        std::thread::sleep(Duration::from_millis(20));
        assert_eq!(bank.class_counts()[JobClass::FcGemm.index()], 1);
        assert_eq!(conv_stats.jobs.load(Ordering::Relaxed), 0);

        // A full-capability teammate on the same bank serves it.
        let neon_stats = Arc::new(DelegateStats::default());
        let neon_handle = spawn(
            "neon-delegate".into(),
            0,
            Arc::clone(&bank),
            ClassMask::all(),
            ClassMask::of(&[JobClass::ConvTile]),
            native_backend,
            None,
            Arc::clone(&neon_stats),
            0,
            None,
        );
        let r = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert_eq!(r.data.len(), 8);
        bank.close();
        conv_handle.join().unwrap().unwrap();
        neon_handle.join().unwrap().unwrap();
        assert_eq!(neon_stats.jobs_by_class()[JobClass::FcGemm.index()], 1);
        assert_eq!(conv_stats.jobs.load(Ordering::Relaxed), 0);
        drop(tx);
    }
}
