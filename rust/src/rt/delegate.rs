//! Delegate threads (paper §3.1.2): the software wrappers that stand in
//! for hardware accelerators inside the OS threading model.
//!
//! Each delegate owns its accelerator's execution backend and services its
//! cluster's job queue: request a job, fetch the operand tiles, execute,
//! acknowledge the result — exactly the control-FIFO protocol of Fig 5,
//! with the mpsc reply channel standing in for `if_hw2sw`.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::Sender;
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::Result;

use crate::cluster::JobQueue;
use crate::mm::job::{Job, JobResult};
use crate::runtime::PeEngine;
use crate::sched::worksteal::ThiefMsg;

/// A job plus its reply channel (the "acknowledgment" path of Fig 5).
pub struct RtJob {
    pub job: Job,
    pub reply: Sender<JobResult>,
}

/// Which backend a delegate drives.
pub enum Backend {
    /// FPGA PE: the AOT Pallas job kernel through PJRT.
    Pjrt(Box<PeEngine>),
    /// NEON: the native blocked GEMM.
    Native,
}

/// Per-delegate counters.
#[derive(Debug, Default)]
pub struct DelegateStats {
    pub jobs: AtomicU64,
    pub ksteps: AtomicU64,
    pub idle_reports: AtomicU64,
}

/// Spawn a delegate thread servicing `queue`.
///
/// The backend is built *inside* the thread via `mk_backend`: the PJRT
/// engine is `Rc`-backed (not `Send`), and hardware-wise each PE is its own
/// physical kernel instance anyway.
///
/// `drain_extra` is the number of additional jobs the delegate may grab in
/// one queue visit once it holds a job (0 = strict one-at-a-time, the
/// single-stream driver's sharing-friendly behavior; the batched serving
/// runtime raises it to amortize queue locks over micro-batch job runs).
///
/// The thread exits when the queue is closed and drained.  On queue
/// timeout it reports `ClusterIdle` to the thief (work-stealing trigger).
pub fn spawn(
    name: String,
    cluster: usize,
    queue: Arc<JobQueue<RtJob>>,
    mk_backend: impl FnOnce() -> Result<Backend> + Send + 'static,
    thief: Option<Sender<ThiefMsg>>,
    stats: Arc<DelegateStats>,
    drain_extra: usize,
) -> JoinHandle<Result<()>> {
    std::thread::Builder::new()
        .name(name)
        .spawn(move || {
            let backend = mk_backend()?;
            delegate_loop(cluster, queue, backend, thief, stats, drain_extra)
        })
        .expect("spawn delegate thread")
}

fn delegate_loop(
    cluster: usize,
    queue: Arc<JobQueue<RtJob>>,
    backend: Backend,
    thief: Option<Sender<ThiefMsg>>,
    stats: Arc<DelegateStats>,
    drain_extra: usize,
) -> Result<()> {
    loop {
        let rt_job = match queue.pop_timeout(Duration::from_micros(500)) {
            Ok(Some(j)) => j,
            Ok(None) => return Ok(()), // closed + drained
            Err(()) => {
                // Idle: notify the thief's manager (paper Fig 4 step 1).
                if let Some(tx) = &thief {
                    stats.idle_reports.fetch_add(1, Ordering::Relaxed);
                    let _ = tx.send(ThiefMsg::ClusterIdle(cluster));
                }
                // Longer nap so an empty tail doesn't spin.
                match queue.pop_timeout(Duration::from_millis(2)) {
                    Ok(Some(j)) => j,
                    Ok(None) => return Ok(()),
                    Err(()) => continue,
                }
            }
        };
        let mut run = vec![rt_job];
        if drain_extra > 0 {
            run.extend(queue.pop_upto(drain_extra));
        }
        for i in 0..run.len() {
            match execute(&backend, &run[i].job) {
                Ok(result) => {
                    stats.jobs.fetch_add(1, Ordering::Relaxed);
                    stats
                        .ksteps
                        .fetch_add(run[i].job.desc.k_tiles() as u64, Ordering::Relaxed);
                    // Receiver may have gone away on shutdown; that's fine.
                    let _ = run[i].reply.send(result);
                }
                Err(e) => {
                    // Drop the never-attempted jobs: their reply senders
                    // close, so waiting layer threads fail fast instead of
                    // blocking on jobs nobody may ever service (this could
                    // be the cluster's only delegate).  An execute error
                    // is fatal to the run either way.
                    drop(run.drain(i + 1..));
                    return Err(e);
                }
            }
        }
    }
}

/// Execute one job on the chosen backend.
pub fn execute(backend: &Backend, job: &Job) -> Result<JobResult> {
    match backend {
        Backend::Native => Ok(job.execute_native()),
        Backend::Pjrt(engine) => {
            let (at, bt) = job.pack_tiles();
            let tile = engine.execute_job(&at, &bt, job.desc.k_tiles())?;
            Ok(JobResult {
                desc: job.desc,
                tile,
            })
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mm::job::jobs_for_gemm;
    use crate::mm::TileGrid;
    use crate::util::rng::XorShift64Star;
    use std::sync::mpsc;

    #[test]
    fn native_delegate_services_jobs_and_exits_on_close() {
        let queue: Arc<JobQueue<RtJob>> = Arc::new(JobQueue::new());
        let stats = Arc::new(DelegateStats::default());
        let handle = spawn(
            "test-delegate".into(),
            0,
            Arc::clone(&queue),
            || Ok(Backend::Native),
            None,
            Arc::clone(&stats),
            2,
        );

        let grid = TileGrid::new(40, 50, 60, 32);
        let a = Arc::new(XorShift64Star::new(1).fill_f32(40 * 50, 1.0));
        let b = Arc::new(XorShift64Star::new(2).fill_f32(50 * 60, 1.0));
        let mut id = 0;
        let jobs = jobs_for_gemm(0, 0, grid, a, b, &mut id);
        let n = jobs.len();
        let (tx, rx) = mpsc::channel();
        for job in jobs {
            queue.push(RtJob {
                job,
                reply: tx.clone(),
            });
        }
        let mut results = Vec::new();
        for _ in 0..n {
            results.push(rx.recv_timeout(Duration::from_secs(5)).unwrap());
        }
        queue.close();
        handle.join().unwrap().unwrap();
        assert_eq!(stats.jobs.load(Ordering::Relaxed), n as u64);
        // every tile distinct
        let mut seen = std::collections::HashSet::new();
        for r in &results {
            assert!(seen.insert((r.desc.t1, r.desc.t2)));
        }
    }

    #[test]
    fn idle_delegate_reports_to_thief() {
        let queue: Arc<JobQueue<RtJob>> = Arc::new(JobQueue::new());
        let stats = Arc::new(DelegateStats::default());
        let (ttx, trx) = mpsc::channel();
        let handle = spawn(
            "idle-delegate".into(),
            3,
            Arc::clone(&queue),
            || Ok(Backend::Native),
            Some(ttx),
            Arc::clone(&stats),
            0,
        );
        // No jobs: the delegate must report idleness at least once.
        let msg = trx.recv_timeout(Duration::from_secs(2)).unwrap();
        assert_eq!(msg, ThiefMsg::ClusterIdle(3));
        queue.close();
        handle.join().unwrap().unwrap();
        assert!(stats.idle_reports.load(Ordering::Relaxed) >= 1);
    }
}
