//! The streaming inference driver: wires layer threads, mailboxes, cluster
//! queues, delegate threads, and the thief into the complete pipelined
//! system of paper Fig 2, then pushes a frame stream through it.

use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;

use crate::accel::AccelSpec;
use crate::config::HwConfig;
use crate::mm::job::JobClass;
use crate::nn::Network;
use crate::pipeline::Mailbox;
use crate::sched::{static_map, Mapping};
use crate::tensor::Tensor;

use super::exec::PoolRouter;
use super::pool::{DelegatePool, PoolOptions};
use super::ComputeMode;

/// Runtime configuration.
#[derive(Clone)]
pub struct RtOptions {
    pub hw: HwConfig,
    pub compute: ComputeMode,
    pub work_stealing: bool,
    /// Mailbox depth between layer stages (1 = strict paper pipeline).
    pub mailbox_capacity: usize,
}

impl Default for RtOptions {
    fn default() -> Self {
        RtOptions {
            hw: HwConfig::default_zc702(),
            compute: ComputeMode::Native,
            work_stealing: true,
            mailbox_capacity: 1,
        }
    }
}

/// Run report: outputs + throughput + scheduler counters.
#[derive(Debug)]
pub struct RtReport {
    /// (frame_id, class probabilities) in arrival order.
    pub outputs: Vec<(u64, Tensor)>,
    pub wall_seconds: f64,
    pub fps: f64,
    pub jobs_executed: u64,
    pub jobs_stolen: u64,
    pub steal_attempts: u64,
    /// jobs per accelerator (by accel id).
    pub per_accel_jobs: Vec<u64>,
    /// jobs per class ([`JobClass`] dense order).
    pub per_class_jobs: [u64; JobClass::COUNT],
    /// Jobs computed inline because no pool member supported the class
    /// (see `rt::pool::DispatchStats`); zero on any realistic pool.
    pub inline_fallbacks: u64,
}

/// The assembled runtime (exists for the duration of one stream).
pub struct RtRuntime {
    net: Arc<Network>,
    pool: DelegatePool,
    assignment: Vec<usize>,
    options: RtOptions,
}

impl RtRuntime {
    /// Build clusters, spawn delegate threads (and the thief).
    pub fn start(net: Arc<Network>, options: RtOptions) -> Result<RtRuntime> {
        let pool = DelegatePool::start(&PoolOptions::new(
            options.hw.clone(),
            options.compute,
            options.work_stealing,
        ))?;
        let assignment = static_map::assign(&net.conv_infos(), pool.clusters());
        Ok(RtRuntime {
            net,
            pool,
            assignment,
            options,
        })
    }

    /// Accelerator specs (for reporting).
    pub fn accels(&self) -> Vec<AccelSpec> {
        self.pool.accels()
    }

    /// The mapping in force.
    pub fn mapping(&self) -> Mapping {
        if self.options.work_stealing {
            Mapping::WorkStealing(self.assignment.clone())
        } else {
            Mapping::Static(self.assignment.clone())
        }
    }

    /// Stream `frames` through the layer pipeline; returns outputs +
    /// measurements, then tears the runtime down.
    pub fn run_stream(self, frames: Vec<(u64, Tensor)>) -> Result<RtReport> {
        let n_layers = self.net.config.layers.len();
        let n_frames = frames.len();
        // Mailboxes: mb[0] = input, mb[i+1] = output of layer i.
        let mailboxes: Vec<Arc<Mailbox<(u64, Tensor)>>> = (0..=n_layers)
            .map(|_| Arc::new(Mailbox::new(self.options.mailbox_capacity)))
            .collect();

        let mut layer_handles = Vec::new();
        let router = PoolRouter::new(&self.net, self.pool.dispatcher(), &self.assignment);
        for layer_idx in 0..n_layers {
            let inbox = Arc::clone(&mailboxes[layer_idx]);
            let outbox = Arc::clone(&mailboxes[layer_idx + 1]);
            let net = Arc::clone(&self.net);
            let router = router.clone();
            // lint: allow(thread-spawn): layer pipeline stages are the
            // runtime's frame transport, not compute — the matrix work each
            // stage generates still routes through DelegatePool jobs.
            let handle = std::thread::Builder::new()
                .name(format!("layer-{layer_idx}"))
                .spawn(move || {
                    while let Some((frame_id, input)) = inbox.recv() {
                        let spec = net.config.layers[layer_idx].clone();
                        // All matrix work (CONV tiles, FC GEMMs, im2col)
                        // becomes pool jobs via the router.
                        let exec = router.frame(frame_id);
                        let out = net.forward_layer(layer_idx, &spec, input, &exec);
                        if !outbox.send((frame_id, out)) {
                            break;
                        }
                    }
                    outbox.close();
                })
                .expect("spawn layer thread");
            layer_handles.push(handle);
        }

        // Feed + collect.
        let t0 = Instant::now();
        let feeder = {
            let inbox = Arc::clone(&mailboxes[0]);
            // lint: allow(thread-spawn): frame feeder — pure mailbox I/O,
            // no compute to route through the pool.
            std::thread::spawn(move || {
                for frame in frames {
                    if !inbox.send(frame) {
                        break;
                    }
                }
                inbox.close();
            })
        };
        let mut outputs = Vec::with_capacity(n_frames);
        let last = Arc::clone(&mailboxes[n_layers]);
        while let Some(out) = last.recv() {
            outputs.push(out);
        }
        let wall = t0.elapsed().as_secs_f64();
        feeder.join().expect("feeder");
        for h in layer_handles {
            h.join().expect("layer thread");
        }

        // Tear down delegates + thief.
        let pool_report = self.pool.shutdown()?;

        Ok(RtReport {
            outputs,
            wall_seconds: wall,
            fps: n_frames as f64 / wall.max(1e-12),
            jobs_executed: pool_report.jobs_executed,
            jobs_stolen: pool_report.jobs_stolen,
            steal_attempts: pool_report.steal_attempts,
            per_accel_jobs: pool_report.per_accel_jobs,
            per_class_jobs: pool_report.per_class_jobs,
            inline_fallbacks: pool_report.inline_fallbacks,
        })
    }
}

/// Convenience: build, run, tear down in one call.
pub fn run_stream(
    net: Arc<Network>,
    options: RtOptions,
    frames: Vec<(u64, Tensor)>,
) -> Result<RtReport> {
    RtRuntime::start(net, options)?.run_stream(frames)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::zoo;

    fn mk_net(name: &str) -> Arc<Network> {
        Arc::new(Network::new(zoo::load(name).unwrap(), 32).unwrap())
    }

    #[test]
    fn native_pipeline_matches_reference_forward() {
        let net = mk_net("mpcnn");
        let frames: Vec<(u64, Tensor)> = (0..6).map(|f| (f, net.make_input(f))).collect();
        let report = run_stream(
            Arc::clone(&net),
            RtOptions::default(),
            frames.clone(),
        )
        .unwrap();
        assert_eq!(report.outputs.len(), frames.len());
        for (frame_id, out) in &report.outputs {
            let want = net.forward_reference(&net.make_input(*frame_id));
            assert!(
                out.allclose(&want, 1e-4, 1e-5),
                "frame {frame_id}: {}",
                out.max_abs_diff(&want)
            );
        }
        // Ordered delivery (mailboxes are FIFO end to end).
        let ids: Vec<u64> = report.outputs.iter().map(|(id, _)| *id).collect();
        let mut sorted = ids.clone();
        sorted.sort_unstable();
        assert_eq!(ids, sorted);
        // All matrix work (CONV tiles + FC GEMMs + im2col) went through
        // the accelerator pool — never inline.
        assert_eq!(report.inline_fallbacks, 0);
        let profile = net.pool_job_profile();
        let expected: usize = profile.iter().sum::<usize>() * frames.len();
        assert_eq!(report.jobs_executed, expected as u64);
        for class in JobClass::ALL {
            assert_eq!(
                report.per_class_jobs[class.index()],
                (profile[class.index()] * frames.len()) as u64,
                "{}",
                class.label()
            );
        }
    }

    #[test]
    fn work_stealing_disabled_still_correct() {
        let net = mk_net("mpcnn");
        let frames: Vec<(u64, Tensor)> = (0..3).map(|f| (f, net.make_input(f))).collect();
        let report = run_stream(
            Arc::clone(&net),
            RtOptions {
                work_stealing: false,
                ..Default::default()
            },
            frames,
        )
        .unwrap();
        assert_eq!(report.jobs_stolen, 0);
        for (frame_id, out) in &report.outputs {
            let want = net.forward_reference(&net.make_input(*frame_id));
            assert!(out.allclose(&want, 1e-4, 1e-5));
        }
    }

    #[test]
    fn stealing_spreads_work_across_clusters() {
        // mnist's heavy conv is mapped to cluster 1; with stealing on,
        // cluster 0's accels should still execute a meaningful share.
        let net = mk_net("mnist");
        let frames: Vec<(u64, Tensor)> = (0..4).map(|f| (f, net.make_input(f))).collect();
        let rt = RtRuntime::start(Arc::clone(&net), RtOptions::default()).unwrap();
        let accels = rt.accels();
        let report = rt.run_stream(frames).unwrap();
        let c0_jobs: u64 = accels
            .iter()
            .filter(|a| a.cluster == 0)
            .map(|a| report.per_accel_jobs[a.id])
            .sum();
        assert!(c0_jobs > 0, "cluster 0 never worked: {:?}", report.per_accel_jobs);
    }
}
