//! The streaming inference driver: wires layer threads, mailboxes, cluster
//! queues, delegate threads, and the thief into the complete pipelined
//! system of paper Fig 2, then pushes a frame stream through it.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::accel::{build_clusters, AccelSpec, ClusterSpec};
use crate::cluster::JobQueue;
use crate::config::HwConfig;
use crate::mm::job::{gather_results, jobs_for_gemm, JobResult};
use crate::nn::Network;
use crate::pipeline::Mailbox;
use crate::runtime::{default_artifacts_dir, PeEngine};
use crate::sched::worksteal::{Thief, ThiefMsg};
use crate::sched::{static_map, Mapping};
use crate::tensor::Tensor;

use super::delegate::{self, Backend, DelegateStats, RtJob};

/// How delegates compute jobs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ComputeMode {
    /// FPGA PEs execute the AOT Pallas kernel through PJRT; NEONs native.
    /// (The production configuration — requires `make artifacts`.)
    Pjrt,
    /// Everything native (no artifacts needed; CI-friendly).
    Native,
}

/// Runtime configuration.
#[derive(Clone)]
pub struct RtOptions {
    pub hw: HwConfig,
    pub compute: ComputeMode,
    pub work_stealing: bool,
    /// Mailbox depth between layer stages (1 = strict paper pipeline).
    pub mailbox_capacity: usize,
}

impl Default for RtOptions {
    fn default() -> Self {
        RtOptions {
            hw: HwConfig::default_zc702(),
            compute: ComputeMode::Native,
            work_stealing: true,
            mailbox_capacity: 1,
        }
    }
}

/// Run report: outputs + throughput + scheduler counters.
#[derive(Debug)]
pub struct RtReport {
    /// (frame_id, class probabilities) in arrival order.
    pub outputs: Vec<(u64, Tensor)>,
    pub wall_seconds: f64,
    pub fps: f64,
    pub jobs_executed: u64,
    pub jobs_stolen: u64,
    pub steal_attempts: u64,
    /// jobs per accelerator (by accel id).
    pub per_accel_jobs: Vec<u64>,
}

/// The assembled runtime (exists for the duration of one stream).
pub struct RtRuntime {
    net: Arc<Network>,
    clusters: Vec<ClusterSpec>,
    assignment: Vec<usize>,
    queues: Vec<Arc<JobQueue<RtJob>>>,
    delegate_stats: Vec<Arc<DelegateStats>>,
    delegate_handles: Vec<std::thread::JoinHandle<Result<()>>>,
    thief: Option<Thief<RtJob>>,
    options: RtOptions,
    job_counter: Arc<AtomicU64>,
}

impl RtRuntime {
    /// Build clusters, spawn delegate threads (and the thief).
    pub fn start(net: Arc<Network>, options: RtOptions) -> Result<RtRuntime> {
        let clusters = build_clusters(&options.hw);
        let queues: Vec<Arc<JobQueue<RtJob>>> = clusters
            .iter()
            .map(|_| Arc::new(JobQueue::new()))
            .collect();
        let thief = if options.work_stealing {
            Some(Thief::spawn(queues.clone()))
        } else {
            None
        };
        let thief_tx = thief.as_ref().map(|t| t.sender());

        // Only the K values this network needs (plus exact-match checks
        // happen inside the engine via next-larger padding).
        let artifacts = default_artifacts_dir();
        let mut delegate_stats = Vec::new();
        let mut delegate_handles = Vec::new();
        for cluster in &clusters {
            for member in &cluster.members {
                let stats = Arc::new(DelegateStats::default());
                delegate_stats.push(Arc::clone(&stats));
                let queue = Arc::clone(&queues[cluster.index]);
                let mode = options.compute;
                let is_fpga = member.is_fpga();
                let art = artifacts.clone();
                let mk = move || -> Result<Backend> {
                    if is_fpga && mode == ComputeMode::Pjrt {
                        let engine = PeEngine::load(&art, None)
                            .context("loading PE engine (run `make artifacts`)")?;
                        Ok(Backend::Pjrt(Box::new(engine)))
                    } else {
                        Ok(Backend::Native)
                    }
                };
                delegate_handles.push(delegate::spawn(
                    format!("delegate-{}", member.name),
                    cluster.index,
                    queue,
                    mk,
                    thief_tx.clone(),
                    stats,
                ));
            }
        }

        let assignment = static_map::assign(&net.conv_infos(), &clusters);
        Ok(RtRuntime {
            net,
            clusters,
            assignment,
            queues,
            delegate_stats,
            delegate_handles,
            thief,
            options,
            job_counter: Arc::new(AtomicU64::new(0)),
        })
    }

    /// Accelerator specs (for reporting).
    pub fn accels(&self) -> Vec<AccelSpec> {
        crate::accel::all_accels(&self.clusters)
    }

    /// The mapping in force.
    pub fn mapping(&self) -> Mapping {
        if self.options.work_stealing {
            Mapping::WorkStealing(self.assignment.clone())
        } else {
            Mapping::Static(self.assignment.clone())
        }
    }

    /// Stream `frames` through the layer pipeline; returns outputs +
    /// measurements, then tears the runtime down.
    pub fn run_stream(self, frames: Vec<(u64, Tensor)>) -> Result<RtReport> {
        let n_layers = self.net.config.layers.len();
        let n_frames = frames.len();
        // Mailboxes: mb[0] = input, mb[i+1] = output of layer i.
        let mailboxes: Vec<Arc<Mailbox<(u64, Tensor)>>> = (0..=n_layers)
            .map(|_| Arc::new(Mailbox::new(self.options.mailbox_capacity)))
            .collect();

        let thief_tx = self.thief.as_ref().map(|t| t.sender());
        let mut layer_handles = Vec::new();
        for layer_idx in 0..n_layers {
            let inbox = Arc::clone(&mailboxes[layer_idx]);
            let outbox = Arc::clone(&mailboxes[layer_idx + 1]);
            let net = Arc::clone(&self.net);
            let queues = self.queues.clone();
            let assignment = self.assignment.clone();
            let job_counter = Arc::clone(&self.job_counter);
            let thief_tx = thief_tx.clone();
            let handle = std::thread::Builder::new()
                .name(format!("layer-{layer_idx}"))
                .spawn(move || {
                    let convs = net.conv_infos();
                    while let Some((frame_id, input)) = inbox.recv() {
                        let spec = net.config.layers[layer_idx].clone();
                        let out = net.forward_layer(
                            layer_idx,
                            &spec,
                            input,
                            &|l_idx, grid, a, b| {
                                // CONV → jobs → cluster queue → gather.
                                let conv_ord = convs
                                    .iter()
                                    .position(|ci| ci.layer_idx == l_idx)
                                    .expect("conv ordinal");
                                let cluster = assignment[conv_ord];
                                let mut next_id =
                                    job_counter.fetch_add(grid.num_jobs() as u64, Ordering::Relaxed);
                                let jobs = jobs_for_gemm(l_idx, frame_id, grid, a, b, &mut next_id);
                                let n = jobs.len();
                                let (tx, rx) = mpsc::channel::<JobResult>();
                                // Batch-push: one lock + one notify_all per
                                // layer instead of per job (§Perf iter 3).
                                let batch: Vec<RtJob> = jobs
                                    .into_iter()
                                    .map(|job| RtJob {
                                        job,
                                        reply: tx.clone(),
                                    })
                                    .collect();
                                queues[cluster].push_batch(batch);
                                if let Some(t) = &thief_tx {
                                    let _ = t.send(ThiefMsg::ClusterBusy(cluster));
                                }
                                drop(tx);
                                let mut results = Vec::with_capacity(n);
                                for _ in 0..n {
                                    results.push(rx.recv().expect("job result"));
                                }
                                gather_results(grid, &results)
                            },
                        );
                        if !outbox.send((frame_id, out)) {
                            break;
                        }
                    }
                    outbox.close();
                })
                .expect("spawn layer thread");
            layer_handles.push(handle);
        }

        // Feed + collect.
        let t0 = Instant::now();
        let feeder = {
            let inbox = Arc::clone(&mailboxes[0]);
            std::thread::spawn(move || {
                for frame in frames {
                    if !inbox.send(frame) {
                        break;
                    }
                }
                inbox.close();
            })
        };
        let mut outputs = Vec::with_capacity(n_frames);
        let last = Arc::clone(&mailboxes[n_layers]);
        while let Some(out) = last.recv() {
            outputs.push(out);
        }
        let wall = t0.elapsed().as_secs_f64();
        feeder.join().expect("feeder");
        for h in layer_handles {
            h.join().expect("layer thread");
        }

        // Tear down delegates + thief.
        for q in &self.queues {
            q.close();
        }
        let mut jobs_executed = 0;
        let mut per_accel_jobs = Vec::new();
        for stats in &self.delegate_stats {
            let j = stats.jobs.load(Ordering::Relaxed);
            per_accel_jobs.push(j);
            jobs_executed += j;
        }
        for h in self.delegate_handles {
            h.join().expect("delegate thread")?;
        }
        let (steal_attempts, _steal_successes, jobs_stolen) = self
            .thief
            .as_ref()
            .map(|t| t.stats.snapshot())
            .unwrap_or((0, 0, 0));
        if let Some(t) = self.thief {
            t.shutdown();
        }

        Ok(RtReport {
            outputs,
            wall_seconds: wall,
            fps: n_frames as f64 / wall.max(1e-12),
            jobs_executed,
            jobs_stolen,
            steal_attempts,
            per_accel_jobs,
        })
    }
}

/// Convenience: build, run, tear down in one call.
pub fn run_stream(
    net: Arc<Network>,
    options: RtOptions,
    frames: Vec<(u64, Tensor)>,
) -> Result<RtReport> {
    RtRuntime::start(net, options)?.run_stream(frames)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::zoo;

    fn mk_net(name: &str) -> Arc<Network> {
        Arc::new(Network::new(zoo::load(name).unwrap(), 32).unwrap())
    }

    #[test]
    fn native_pipeline_matches_reference_forward() {
        let net = mk_net("mpcnn");
        let frames: Vec<(u64, Tensor)> = (0..6).map(|f| (f, net.make_input(f))).collect();
        let report = run_stream(
            Arc::clone(&net),
            RtOptions::default(),
            frames.clone(),
        )
        .unwrap();
        assert_eq!(report.outputs.len(), frames.len());
        for (frame_id, out) in &report.outputs {
            let want = net.forward_reference(&net.make_input(*frame_id));
            assert!(
                out.allclose(&want, 1e-4, 1e-5),
                "frame {frame_id}: {}",
                out.max_abs_diff(&want)
            );
        }
        // Ordered delivery (mailboxes are FIFO end to end).
        let ids: Vec<u64> = report.outputs.iter().map(|(id, _)| *id).collect();
        let mut sorted = ids.clone();
        sorted.sort_unstable();
        assert_eq!(ids, sorted);
        // All conv jobs went through the accelerators.
        let expected: usize = net
            .conv_infos()
            .iter()
            .map(|ci| ci.grid.num_jobs())
            .sum::<usize>()
            * frames.len();
        assert_eq!(report.jobs_executed, expected as u64);
    }

    #[test]
    fn work_stealing_disabled_still_correct() {
        let net = mk_net("mpcnn");
        let frames: Vec<(u64, Tensor)> = (0..3).map(|f| (f, net.make_input(f))).collect();
        let report = run_stream(
            Arc::clone(&net),
            RtOptions {
                work_stealing: false,
                ..Default::default()
            },
            frames,
        )
        .unwrap();
        assert_eq!(report.jobs_stolen, 0);
        for (frame_id, out) in &report.outputs {
            let want = net.forward_reference(&net.make_input(*frame_id));
            assert!(out.allclose(&want, 1e-4, 1e-5));
        }
    }

    #[test]
    fn stealing_spreads_work_across_clusters() {
        // mnist's heavy conv is mapped to cluster 1; with stealing on,
        // cluster 0's accels should still execute a meaningful share.
        let net = mk_net("mnist");
        let frames: Vec<(u64, Tensor)> = (0..4).map(|f| (f, net.make_input(f))).collect();
        let rt = RtRuntime::start(Arc::clone(&net), RtOptions::default()).unwrap();
        let accels = rt.accels();
        let report = rt.run_stream(frames).unwrap();
        let c0_jobs: u64 = accels
            .iter()
            .filter(|a| a.cluster == 0)
            .map(|a| report.per_accel_jobs[a.id])
            .sum();
        assert!(c0_jobs > 0, "cluster 0 never worked: {:?}", report.per_accel_jobs);
    }
}
