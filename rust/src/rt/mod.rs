//! The real threaded runtime — Synergy executing with actual OS threads
//! and actual numerics (no virtual clock).
//!
//! This is the paper's software architecture (Fig 2) materialized:
//! * one **layer thread** per network layer, connected by [`Mailbox`]es in
//!   producer-consumer fashion (frames stream through, inter-frame
//!   parallelism for free);
//! * CONV layer threads lower their GEMM to **jobs** and push them to their
//!   cluster's [`JobQueue`];
//! * **delegate threads** ([`delegate`]) wrap the accelerators: the FPGA-PE
//!   delegates execute the AOT Pallas kernel through PJRT (each owns a
//!   private engine — mirroring one physical kernel instance per PE); the
//!   NEON delegates run the native blocked GEMM;
//! * the **thief thread** (`sched::worksteal`) rebalances queues when a
//!   cluster goes idle.
//!
//! The queues + delegates + thief substrate lives in [`pool`] so both the
//! single-stream driver here and the multi-stream serving runtime
//! (`crate::serve`) share one implementation.
//!
//! Wall-clock numbers from this runtime measure the *coordinator* (L3)
//! overheads — queueing, stealing, mailbox hops, PJRT dispatch — on the
//! host CPU; ZC702-shaped timing comes from `sim/`.
//!
//! [`Mailbox`]: crate::pipeline::Mailbox
//! [`JobQueue`]: crate::cluster::JobQueue

pub mod delegate;
pub mod driver;
pub mod pool;

pub use driver::{RtOptions, RtReport, RtRuntime};
pub use pool::{DelegatePool, Dispatcher, GemmCtx, PoolOptions, PoolReport};

/// How delegates compute jobs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ComputeMode {
    /// FPGA PEs execute the AOT Pallas kernel through PJRT; NEONs native.
    /// (The production configuration — requires `make artifacts` and the
    /// `pjrt` cargo feature; without the feature PEs fall back to native.)
    Pjrt,
    /// Everything native (no artifacts needed; CI-friendly).
    Native,
}
