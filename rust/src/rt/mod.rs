//! The real threaded runtime — Synergy executing with actual OS threads
//! and actual numerics (no virtual clock).
//!
//! This is the paper's software architecture (Fig 2) materialized:
//! * one **layer thread** per network layer, connected by [`Mailbox`]es in
//!   producer-consumer fashion (frames stream through, inter-frame
//!   parallelism for free);
//! * layer threads emit **all** their matrix work — CONV-tile GEMMs, FC
//!   GEMMs, im2col lowering — as jobs on the per-class cluster
//!   [`QueueBank`]s via [`PoolRouter`] (the unified-pool refactor: FC
//!   layers never run inline on the pipeline thread);
//! * **delegate threads** ([`delegate`]) each drive one
//!   [`Accelerator`](crate::accel::Accelerator) backend resolved from the
//!   [`BackendRegistry`](crate::accel::BackendRegistry) — the AOT Pallas
//!   kernel through PJRT (FPGA-PE path, one private engine per delegate —
//!   mirroring one physical kernel instance per PE), the native blocked
//!   GEMM (NEON path), or the multi-threaded big-core GEMM — and pop
//!   through their **member capability mask**, so mixed clusters keep
//!   every member busy on the classes it speaks;
//! * the **thief thread** (`sched::worksteal`) rebalances queues when a
//!   cluster goes idle, ranking victims by the per-sub-queue backlog the
//!   destination can actually accept.
//!
//! The queues + delegates + thief substrate lives in [`pool`] so both the
//! single-stream driver here and the multi-stream serving runtime
//! (`crate::serve`) share one implementation.
//!
//! Wall-clock numbers from this runtime measure the *coordinator* (L3)
//! overheads — queueing, stealing, mailbox hops, PJRT dispatch — on the
//! host CPU; ZC702-shaped timing comes from `sim/`.
//!
//! [`Mailbox`]: crate::pipeline::Mailbox
//! [`QueueBank`]: crate::cluster::QueueBank

pub mod delegate;
pub mod driver;
pub mod exec;
pub mod pool;

pub use driver::{RtOptions, RtReport, RtRuntime};
pub use exec::{FrameExec, PoolRouter};
pub use pool::{
    backend_key, ClusterRoute, DelegatePool, DispatchStats, Dispatcher, MemberCost, PoolOptions,
    PoolReport,
};

/// How delegates compute jobs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ComputeMode {
    /// FPGA PEs execute the AOT Pallas kernel through PJRT; NEONs native.
    /// (The production configuration — requires `make artifacts` and the
    /// `pjrt` cargo feature; without the feature PEs fall back to native.)
    Pjrt,
    /// Everything native (no artifacts needed; CI-friendly).
    Native,
}
