//! The real threaded runtime — Synergy executing with actual OS threads
//! and actual numerics (no virtual clock).
//!
//! This is the paper's software architecture (Fig 2) materialized:
//! * one **layer thread** per network layer, connected by [`Mailbox`]es in
//!   producer-consumer fashion (frames stream through, inter-frame
//!   parallelism for free);
//! * CONV layer threads lower their GEMM to **jobs** and push them to their
//!   cluster's [`JobQueue`];
//! * **delegate threads** ([`delegate`]) wrap the accelerators: the FPGA-PE
//!   delegates execute the AOT Pallas kernel through PJRT (each owns a
//!   private engine — mirroring one physical kernel instance per PE); the
//!   NEON delegates run the native blocked GEMM;
//! * the **thief thread** (`sched::worksteal`) rebalances queues when a
//!   cluster goes idle.
//!
//! Wall-clock numbers from this runtime measure the *coordinator* (L3)
//! overheads — queueing, stealing, mailbox hops, PJRT dispatch — on the
//! host CPU; ZC702-shaped timing comes from `sim/`.

pub mod delegate;
pub mod driver;

pub use driver::{RtOptions, RtReport, RtRuntime, ComputeMode};
