//! The shared accelerator substrate: cluster job queues, delegate threads,
//! and the work-stealing thief, factored out of the single-stream driver so
//! the serving runtime (`serve/`) can host many network pipelines over one
//! physical pool of accelerators.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{self, Sender};
use std::sync::Arc;

use anyhow::Result;

use crate::accel::{build_clusters, AccelSpec, ClusterSpec};
use crate::cluster::JobQueue;
use crate::config::HwConfig;
use crate::mm::job::{gather_results, jobs_for_gemm, JobResult};
use crate::mm::TileGrid;
use crate::runtime::default_artifacts_dir;
use crate::sched::worksteal::{StealPolicy, Thief, ThiefMsg};

use super::delegate::{self, Backend, DelegateStats, RtJob};
use super::ComputeMode;

/// Pool configuration (the runtime-relevant subset of `RtOptions`).
#[derive(Clone)]
pub struct PoolOptions {
    pub hw: HwConfig,
    pub compute: ComputeMode,
    pub work_stealing: bool,
    pub steal_policy: StealPolicy,
    /// Extra jobs a delegate drains per queue visit (see
    /// [`delegate::spawn`]).  0 keeps the single-stream driver's strict
    /// one-at-a-time sharing; the serving runtime raises it.
    pub drain_extra: usize,
}

impl PoolOptions {
    pub fn new(hw: HwConfig, compute: ComputeMode, work_stealing: bool) -> Self {
        PoolOptions {
            hw,
            compute,
            work_stealing,
            steal_policy: StealPolicy::default(),
            drain_extra: 0,
        }
    }
}

/// Counters accumulated over the pool's lifetime.
#[derive(Debug, Clone, Default)]
pub struct PoolReport {
    pub jobs_executed: u64,
    /// Jobs per accelerator (by accel id).
    pub per_accel_jobs: Vec<u64>,
    pub steal_attempts: u64,
    pub jobs_stolen: u64,
}

/// Addressing of one CONV GEMM dispatch (bundled so call sites stay tidy).
#[derive(Debug, Clone, Copy)]
pub struct GemmCtx {
    /// Destination cluster (from the static mapping).
    pub cluster: usize,
    /// Network layer index of the CONV layer.
    pub layer_idx: usize,
    /// Frame / request tag carried through the jobs.
    pub frame_id: u64,
}

/// Cheap cloneable handle that layer threads use to push job batches into
/// the pool and gather results (the paper's job-generator + ack path).
#[derive(Clone)]
pub struct Dispatcher {
    queues: Vec<Arc<JobQueue<RtJob>>>,
    thief_tx: Option<Sender<ThiefMsg>>,
    job_counter: Arc<AtomicU64>,
}

impl Dispatcher {
    /// Lower one GEMM to jobs, enqueue them on the target cluster in one
    /// batch push, hint the thief, and block until every tile is back.
    pub fn execute_gemm(
        &self,
        ctx: GemmCtx,
        grid: TileGrid,
        a: Arc<Vec<f32>>,
        b: Arc<Vec<f32>>,
    ) -> Vec<f32> {
        let mut next_id = self
            .job_counter
            .fetch_add(grid.num_jobs() as u64, Ordering::Relaxed);
        let jobs = jobs_for_gemm(ctx.layer_idx, ctx.frame_id, grid, a, b, &mut next_id);
        let n = jobs.len();
        let (tx, rx) = mpsc::channel::<JobResult>();
        // Batch-push: one lock + one notify_all per layer instead of per
        // job (§Perf iter 3).
        let batch: Vec<RtJob> = jobs
            .into_iter()
            .map(|job| RtJob {
                job,
                reply: tx.clone(),
            })
            .collect();
        self.queues[ctx.cluster].push_batch(batch);
        if let Some(t) = &self.thief_tx {
            let _ = t.send(ThiefMsg::ClusterBusy(ctx.cluster));
        }
        drop(tx);
        let mut results = Vec::with_capacity(n);
        for _ in 0..n {
            results.push(rx.recv().expect("job result"));
        }
        gather_results(grid, &results)
    }

}

/// The running pool: one delegate thread per accelerator, one job queue per
/// cluster, plus (optionally) the thief.
pub struct DelegatePool {
    clusters: Vec<ClusterSpec>,
    queues: Vec<Arc<JobQueue<RtJob>>>,
    delegate_stats: Vec<Arc<DelegateStats>>,
    delegate_handles: Vec<std::thread::JoinHandle<Result<()>>>,
    thief: Option<Thief<RtJob>>,
    job_counter: Arc<AtomicU64>,
}

impl DelegatePool {
    /// Build clusters and spawn delegate threads (and the thief).
    pub fn start(options: &PoolOptions) -> Result<DelegatePool> {
        let clusters = build_clusters(&options.hw);
        let queues: Vec<Arc<JobQueue<RtJob>>> = clusters
            .iter()
            .map(|_| Arc::new(JobQueue::new()))
            .collect();
        let thief = if options.work_stealing {
            Some(Thief::spawn_with(queues.clone(), options.steal_policy))
        } else {
            None
        };
        let thief_tx = thief.as_ref().map(|t| t.sender());

        // PJRT delegates compile every manifest job kernel: the pool is
        // shared across networks, so any K value may arrive.
        let artifacts = default_artifacts_dir();
        let mut delegate_stats = Vec::new();
        let mut delegate_handles = Vec::new();
        for cluster in &clusters {
            for member in &cluster.members {
                let stats = Arc::new(DelegateStats::default());
                delegate_stats.push(Arc::clone(&stats));
                let queue = Arc::clone(&queues[cluster.index]);
                let mode = options.compute;
                let is_fpga = member.is_fpga();
                let art = artifacts.clone();
                let mk = move || -> Result<Backend> {
                    if is_fpga && mode == ComputeMode::Pjrt {
                        #[cfg(feature = "pjrt")]
                        {
                            use anyhow::Context;
                            let engine = crate::runtime::PeEngine::load(&art, None)
                                .context("loading PE engine (run `make artifacts`)")?;
                            return Ok(Backend::Pjrt(Box::new(engine)));
                        }
                        #[cfg(not(feature = "pjrt"))]
                        {
                            // Native-GEMM fallback: the `pjrt` feature is
                            // off, so the PE delegates compute natively.
                            let _ = &art;
                            return Ok(Backend::Native);
                        }
                    }
                    Ok(Backend::Native)
                };
                delegate_handles.push(delegate::spawn(
                    format!("delegate-{}", member.name),
                    cluster.index,
                    queue,
                    mk,
                    thief_tx.clone(),
                    stats,
                    options.drain_extra,
                ));
            }
        }

        Ok(DelegatePool {
            clusters,
            queues,
            delegate_stats,
            delegate_handles,
            thief,
            job_counter: Arc::new(AtomicU64::new(0)),
        })
    }

    pub fn clusters(&self) -> &[ClusterSpec] {
        &self.clusters
    }

    /// Accelerator specs (for reporting).
    pub fn accels(&self) -> Vec<AccelSpec> {
        crate::accel::all_accels(&self.clusters)
    }

    /// Handle for layer threads to dispatch GEMMs through.
    pub fn dispatcher(&self) -> Dispatcher {
        Dispatcher {
            queues: self.queues.clone(),
            thief_tx: self.thief.as_ref().map(|t| t.sender()),
            job_counter: Arc::clone(&self.job_counter),
        }
    }

    /// Live counters (approximate while delegates are still running).
    pub fn snapshot(&self) -> PoolReport {
        fold_report(&self.delegate_stats, self.thief.as_ref())
    }

    /// Close the queues, join every delegate, stop the thief, and return
    /// the final counters.  Callers must have drained their reply channels
    /// (i.e. no in-flight GEMMs) before calling.
    pub fn shutdown(self) -> Result<PoolReport> {
        let DelegatePool {
            queues,
            delegate_stats,
            delegate_handles,
            thief,
            ..
        } = self;
        for q in &queues {
            q.close();
        }
        // Join before reading counters so the report sees every job.
        for h in delegate_handles {
            h.join().expect("delegate thread")?;
        }
        let report = fold_report(&delegate_stats, thief.as_ref());
        if let Some(t) = thief {
            t.shutdown();
        }
        Ok(report)
    }
}

fn fold_report(delegate_stats: &[Arc<DelegateStats>], thief: Option<&Thief<RtJob>>) -> PoolReport {
    let mut report = PoolReport::default();
    for stats in delegate_stats {
        let j = stats.jobs.load(Ordering::Relaxed);
        report.per_accel_jobs.push(j);
        report.jobs_executed += j;
    }
    if let Some(t) = thief {
        let (attempts, _successes, moved) = t.stats.snapshot();
        report.steal_attempts = attempts;
        report.jobs_stolen = moved;
    }
    report
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::XorShift64Star;

    #[test]
    fn pool_executes_a_gemm_end_to_end() {
        let options = PoolOptions::new(HwConfig::default_zc702(), ComputeMode::Native, true);
        let pool = DelegatePool::start(&options).unwrap();
        let dispatcher = pool.dispatcher();
        let grid = TileGrid::new(40, 50, 60, 32);
        let a = Arc::new(XorShift64Star::new(1).fill_f32(40 * 50, 1.0));
        let b = Arc::new(XorShift64Star::new(2).fill_f32(50 * 60, 1.0));
        let ctx = GemmCtx {
            cluster: 0,
            layer_idx: 0,
            frame_id: 0,
        };
        let c = dispatcher.execute_gemm(ctx, grid, Arc::clone(&a), Arc::clone(&b));
        let want = crate::mm::gemm::gemm_blocked(
            &crate::tensor::Tensor::from_vec(&[40, 50], (*a).clone()),
            &crate::tensor::Tensor::from_vec(&[50, 60], (*b).clone()),
        );
        let got = crate::tensor::Tensor::from_vec(&[40, 60], c);
        assert!(want.allclose(&got, 1e-4, 1e-4), "{}", want.max_abs_diff(&got));
        let report = pool.shutdown().unwrap();
        assert_eq!(report.jobs_executed, grid.num_jobs() as u64);
    }
}
