//! The shared accelerator substrate: cluster job queues, delegate threads,
//! and the work-stealing thief, factored out of the single-stream driver so
//! the serving runtime (`serve/`) can host many network pipelines over one
//! physical pool of accelerators.
//!
//! Every delegate drives an [`Accelerator`] backend resolved by name from
//! the [`BackendRegistry`]: `[cluster]` members map to registry keys
//! ([`backend_key`]), their capability masks intersect into per-cluster
//! capabilities, and the [`Dispatcher`] routes each job class only to
//! clusters that can execute it — one heterogeneous pool serving CONV
//! tiles, FC GEMMs, and im2col lowering alike (paper §3.1).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{self, Sender};
use std::sync::Arc;

use anyhow::{anyhow, Result};

use crate::accel::{
    build_clusters, AccelClass, AccelSpec, Accelerator, BackendRegistry, ClusterSpec,
};
use crate::cluster::JobQueue;
use crate::config::HwConfig;
use crate::mm::job::{gather_results, jobs_for_gemm, ClassMask, Job, JobClass, JobResult};
use crate::mm::TileGrid;
use crate::runtime::default_artifacts_dir;
use crate::sched::worksteal::{StealPolicy, Thief, ThiefMsg};

use super::delegate::{self, DelegateStats, RtJob};
use super::ComputeMode;

/// Registry key of the backend driving one accelerator spec under a
/// compute mode: FPGA PEs run the PJRT job kernel in [`ComputeMode::Pjrt`]
/// and the native GEMM otherwise; NEON and big-NEON members always run
/// their native backends.
pub fn backend_key(spec: &AccelSpec, mode: ComputeMode) -> &'static str {
    match (&spec.class, mode) {
        (AccelClass::FpgaPe { .. }, ComputeMode::Pjrt) => "pjrt-pe",
        (AccelClass::FpgaPe { .. }, ComputeMode::Native) => "neon",
        (AccelClass::Neon, _) => "neon",
        (AccelClass::BigNeon, _) => "big-neon",
    }
}

/// Pool configuration (the runtime-relevant subset of `RtOptions`).
#[derive(Clone)]
pub struct PoolOptions {
    pub hw: HwConfig,
    pub compute: ComputeMode,
    pub work_stealing: bool,
    pub steal_policy: StealPolicy,
    /// Extra jobs a delegate drains per queue visit (see
    /// [`delegate::spawn`]).  0 keeps the single-stream driver's strict
    /// one-at-a-time sharing; the serving runtime raises it from the
    /// `[serving]` config.
    pub drain_extra: usize,
    /// Backend registry override; `None` uses
    /// [`BackendRegistry::with_defaults`] (neon, big-neon, pjrt-pe).
    pub registry: Option<Arc<BackendRegistry>>,
}

impl PoolOptions {
    pub fn new(hw: HwConfig, compute: ComputeMode, work_stealing: bool) -> Self {
        PoolOptions {
            hw,
            compute,
            work_stealing,
            steal_policy: StealPolicy::default(),
            drain_extra: 0,
            registry: None,
        }
    }
}

/// Counters accumulated over the pool's lifetime.
#[derive(Debug, Clone, Default)]
pub struct PoolReport {
    pub jobs_executed: u64,
    /// Jobs per accelerator (by accel id).
    pub per_accel_jobs: Vec<u64>,
    /// Jobs per class ([`JobClass`] dense order).
    pub per_class_jobs: [u64; JobClass::COUNT],
    pub steal_attempts: u64,
    pub jobs_stolen: u64,
    /// Stolen jobs per class ([`JobClass`] dense order).
    pub stolen_by_class: [u64; JobClass::COUNT],
}

/// Addressing of one pool dispatch (bundled so call sites stay tidy).
#[derive(Debug, Clone, Copy)]
pub struct GemmCtx {
    /// Destination cluster (from the static mapping).  A hint: class
    /// routing may override it when the cluster lacks the capability.
    pub cluster: usize,
    /// Network layer index of the emitting layer.
    pub layer_idx: usize,
    /// Frame / request tag carried through the jobs.
    pub frame_id: u64,
}

/// Cheap cloneable handle that layer threads use to push job batches into
/// the pool and gather results (the paper's job-generator + ack path).
#[derive(Clone)]
pub struct Dispatcher {
    queues: Vec<Arc<JobQueue<RtJob>>>,
    thief_tx: Option<Sender<ThiefMsg>>,
    job_counter: Arc<AtomicU64>,
    /// Per-cluster capability masks (intersection of member backends).
    cluster_caps: Arc<Vec<ClassMask>>,
    /// Per-cluster aggregate service rates (k-steps/s) for routing ties.
    service_rates: Arc<Vec<f64>>,
}

impl Dispatcher {
    /// Lower one CONV GEMM to tile jobs, enqueue them on the target
    /// cluster in one batch push, hint the thief, and block until every
    /// tile is back.
    pub fn execute_gemm(
        &self,
        ctx: GemmCtx,
        grid: TileGrid,
        a: Arc<Vec<f32>>,
        b: Arc<Vec<f32>>,
    ) -> Vec<f32> {
        // Honor the static mapping when the cluster can run CONV tiles;
        // route around it otherwise (e.g. an FC-only backend's cluster),
        // same as the other job classes.
        let cluster = self
            .route(JobClass::ConvTile, Some(ctx.cluster))
            .expect("no cluster in the pool supports CONV-tile jobs");
        let mut next_id = self
            .job_counter
            .fetch_add(grid.num_jobs() as u64, Ordering::Relaxed);
        let jobs = jobs_for_gemm(ctx.layer_idx, ctx.frame_id, grid, a, b, &mut next_id);
        let n = jobs.len();
        let (tx, rx) = mpsc::channel::<JobResult>();
        // Batch-push: one lock + one notify_all per layer instead of per
        // job (§Perf iter 3).
        let batch: Vec<RtJob> = jobs
            .into_iter()
            .map(|job| RtJob {
                job,
                reply: tx.clone(),
            })
            .collect();
        self.queues[cluster].push_batch(batch);
        if let Some(t) = &self.thief_tx {
            let _ = t.send(ThiefMsg::ClusterBusy(cluster));
        }
        drop(tx);
        let mut results = Vec::with_capacity(n);
        for _ in 0..n {
            results.push(rx.recv().expect("job result"));
        }
        gather_results(grid, &results)
    }

    /// Dispatch one FC GEMM (y = W·x) as a pool job and block for the
    /// result.  Returns `None` when no cluster supports FC jobs (e.g. a
    /// PJRT-only pool) — the caller then computes inline.
    pub fn execute_fc(
        &self,
        ctx: GemmCtx,
        out_n: usize,
        in_n: usize,
        w: Arc<Vec<f32>>,
        x: Arc<Vec<f32>>,
        ts: usize,
    ) -> Option<Vec<f32>> {
        let cluster = self.route(JobClass::FcGemm, None)?;
        let id = self.job_counter.fetch_add(1, Ordering::Relaxed);
        let job = Job::fc(id, ctx.layer_idx, ctx.frame_id, out_n, in_n, w, x, ts);
        Some(self.run_single(cluster, job).data)
    }

    /// Dispatch one im2col lowering as a pool job and block for the col
    /// matrix.  `None` when no cluster supports im2col jobs.
    #[allow(clippy::too_many_arguments)]
    pub fn execute_im2col(
        &self,
        ctx: GemmCtx,
        chw: (usize, usize, usize),
        size: usize,
        stride: usize,
        pad: usize,
        input: Arc<Vec<f32>>,
        ts: usize,
    ) -> Option<Vec<f32>> {
        let cluster = self.route(JobClass::Im2col, Some(ctx.cluster))?;
        let id = self.job_counter.fetch_add(1, Ordering::Relaxed);
        let job = Job::im2col(
            id,
            ctx.layer_idx,
            ctx.frame_id,
            chw,
            size,
            stride,
            pad,
            input,
            ts,
        );
        Some(self.run_single(cluster, job).data)
    }

    /// Pick the destination cluster for a job class: `preferred` if it is
    /// capable, else the capable cluster with the smallest queue backlog
    /// per unit service rate; `None` if no cluster supports the class.
    pub fn route(&self, class: JobClass, preferred: Option<usize>) -> Option<usize> {
        if let Some(p) = preferred {
            if p < self.cluster_caps.len() && self.cluster_caps[p].supports(class) {
                return Some(p);
            }
        }
        (0..self.queues.len())
            .filter(|&c| self.cluster_caps[c].supports(class))
            .min_by(|&a, &b| {
                let la = self.queues[a].len() as f64 / self.service_rates[a].max(1e-12);
                let lb = self.queues[b].len() as f64 / self.service_rates[b].max(1e-12);
                la.partial_cmp(&lb).unwrap_or(std::cmp::Ordering::Equal)
            })
    }

    /// Per-cluster capability masks (for tests and reporting).
    pub fn cluster_caps(&self) -> &[ClassMask] {
        &self.cluster_caps
    }

    fn run_single(&self, cluster: usize, job: Job) -> JobResult {
        let (tx, rx) = mpsc::channel::<JobResult>();
        self.queues[cluster].push(RtJob { job, reply: tx });
        if let Some(t) = &self.thief_tx {
            let _ = t.send(ThiefMsg::ClusterBusy(cluster));
        }
        rx.recv().expect("job result")
    }
}

/// The running pool: one delegate thread per accelerator, one job queue per
/// cluster, plus (optionally) the thief.
pub struct DelegatePool {
    clusters: Vec<ClusterSpec>,
    queues: Vec<Arc<JobQueue<RtJob>>>,
    cluster_caps: Arc<Vec<ClassMask>>,
    service_rates: Arc<Vec<f64>>,
    delegate_stats: Vec<Arc<DelegateStats>>,
    delegate_handles: Vec<std::thread::JoinHandle<Result<()>>>,
    thief: Option<Thief<RtJob>>,
    job_counter: Arc<AtomicU64>,
}

impl DelegatePool {
    /// Build clusters, resolve every member through the backend registry,
    /// and spawn delegate threads (and the thief).
    pub fn start(options: &PoolOptions) -> Result<DelegatePool> {
        let registry = options.registry.clone().unwrap_or_else(|| {
            Arc::new(BackendRegistry::with_defaults(
                default_artifacts_dir(),
                options.hw.big_neon_threads,
            ))
        });
        let clusters = build_clusters(&options.hw);
        let queues: Vec<Arc<JobQueue<RtJob>>> = clusters
            .iter()
            .map(|_| Arc::new(JobQueue::new()))
            .collect();

        // Per-cluster capability = intersection over members: a cluster
        // queue is shared, so a class is routable only if *every* member
        // can execute it.
        let mut cluster_caps = Vec::with_capacity(clusters.len());
        for cluster in &clusters {
            let mut caps = ClassMask::all();
            for member in &cluster.members {
                let key = backend_key(member, options.compute);
                let entry = registry
                    .get(key)
                    .ok_or_else(|| anyhow!("no backend {key:?} in the registry"))?;
                caps = caps.intersect(entry.caps);
            }
            cluster_caps.push(caps);
        }
        let service_rates: Vec<f64> = clusters.iter().map(|c| c.throughput()).collect();

        let thief = if options.work_stealing {
            Some(Thief::spawn_with_caps(
                queues.clone(),
                options.steal_policy,
                cluster_caps.clone(),
                service_rates.clone(),
            ))
        } else {
            None
        };
        let thief_tx = thief.as_ref().map(|t| t.sender());

        let mut delegate_stats = Vec::new();
        let mut delegate_handles = Vec::new();
        for cluster in &clusters {
            for member in &cluster.members {
                let stats = Arc::new(DelegateStats::default());
                delegate_stats.push(Arc::clone(&stats));
                let queue = Arc::clone(&queues[cluster.index]);
                let key = backend_key(member, options.compute);
                let builder = registry
                    .get(key)
                    .expect("resolved above")
                    .builder();
                let mk = move || -> Result<Box<dyn Accelerator>> { builder() };
                delegate_handles.push(delegate::spawn(
                    format!("delegate-{}", member.name),
                    cluster.index,
                    queue,
                    mk,
                    thief_tx.clone(),
                    stats,
                    options.drain_extra,
                ));
            }
        }

        Ok(DelegatePool {
            clusters,
            queues,
            cluster_caps: Arc::new(cluster_caps),
            service_rates: Arc::new(service_rates),
            delegate_stats,
            delegate_handles,
            thief,
            job_counter: Arc::new(AtomicU64::new(0)),
        })
    }

    pub fn clusters(&self) -> &[ClusterSpec] {
        &self.clusters
    }

    /// Accelerator specs (for reporting).
    pub fn accels(&self) -> Vec<AccelSpec> {
        crate::accel::all_accels(&self.clusters)
    }

    /// Handle for layer threads to dispatch matrix work through.
    pub fn dispatcher(&self) -> Dispatcher {
        Dispatcher {
            queues: self.queues.clone(),
            thief_tx: self.thief.as_ref().map(|t| t.sender()),
            job_counter: Arc::clone(&self.job_counter),
            cluster_caps: Arc::clone(&self.cluster_caps),
            service_rates: Arc::clone(&self.service_rates),
        }
    }

    /// Live counters (approximate while delegates are still running).
    pub fn snapshot(&self) -> PoolReport {
        fold_report(&self.delegate_stats, self.thief.as_ref())
    }

    /// Close the queues, join every delegate, stop the thief, and return
    /// the final counters.  Callers must have drained their reply channels
    /// (i.e. no in-flight jobs) before calling.
    pub fn shutdown(self) -> Result<PoolReport> {
        let DelegatePool {
            queues,
            delegate_stats,
            delegate_handles,
            thief,
            ..
        } = self;
        for q in &queues {
            q.close();
        }
        // Join before reading counters so the report sees every job.
        for h in delegate_handles {
            h.join().expect("delegate thread")?;
        }
        let report = fold_report(&delegate_stats, thief.as_ref());
        if let Some(t) = thief {
            t.shutdown();
        }
        Ok(report)
    }
}

fn fold_report(delegate_stats: &[Arc<DelegateStats>], thief: Option<&Thief<RtJob>>) -> PoolReport {
    let mut report = PoolReport::default();
    for stats in delegate_stats {
        let j = stats.jobs.load(Ordering::Relaxed);
        report.per_accel_jobs.push(j);
        report.jobs_executed += j;
        for (acc, n) in report.per_class_jobs.iter_mut().zip(stats.jobs_by_class()) {
            *acc += n;
        }
    }
    if let Some(t) = thief {
        let (attempts, _successes, moved) = t.stats.snapshot();
        report.steal_attempts = attempts;
        report.jobs_stolen = moved;
        report.stolen_by_class = t.stats.moved_by_class();
    }
    report
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::XorShift64Star;

    #[test]
    fn pool_executes_a_gemm_end_to_end() {
        let options = PoolOptions::new(HwConfig::default_zc702(), ComputeMode::Native, true);
        let pool = DelegatePool::start(&options).unwrap();
        let dispatcher = pool.dispatcher();
        let grid = TileGrid::new(40, 50, 60, 32);
        let a = Arc::new(XorShift64Star::new(1).fill_f32(40 * 50, 1.0));
        let b = Arc::new(XorShift64Star::new(2).fill_f32(50 * 60, 1.0));
        let ctx = GemmCtx {
            cluster: 0,
            layer_idx: 0,
            frame_id: 0,
        };
        let c = dispatcher.execute_gemm(ctx, grid, Arc::clone(&a), Arc::clone(&b));
        let want = crate::mm::gemm::gemm_blocked(
            &crate::tensor::Tensor::from_vec(&[40, 50], (*a).clone()),
            &crate::tensor::Tensor::from_vec(&[50, 60], (*b).clone()),
        );
        let got = crate::tensor::Tensor::from_vec(&[40, 60], c);
        assert!(want.allclose(&got, 1e-4, 1e-4), "{}", want.max_abs_diff(&got));
        let report = pool.shutdown().unwrap();
        assert_eq!(report.jobs_executed, grid.num_jobs() as u64);
        assert_eq!(
            report.per_class_jobs[JobClass::ConvTile.index()],
            grid.num_jobs() as u64
        );
    }

    #[test]
    fn pool_executes_fc_and_im2col_jobs() {
        let options = PoolOptions::new(HwConfig::default_zc702(), ComputeMode::Native, false);
        let pool = DelegatePool::start(&options).unwrap();
        let dispatcher = pool.dispatcher();
        // In native mode every cluster supports every class.
        for caps in dispatcher.cluster_caps() {
            for class in JobClass::ALL {
                assert!(caps.supports(class));
            }
        }
        let ctx = GemmCtx {
            cluster: 0,
            layer_idx: 2,
            frame_id: 7,
        };
        let w = Arc::new(XorShift64Star::new(1).fill_f32(16 * 32, 1.0));
        let x = Arc::new(XorShift64Star::new(2).fill_f32(32, 1.0));
        let y = dispatcher
            .execute_fc(ctx, 16, 32, Arc::clone(&w), Arc::clone(&x), 32)
            .expect("native pool supports FC");
        let mut want = vec![0.0f32; 16];
        crate::mm::gemm::gemm_blocked_into(&w, &x, &mut want, 16, 32, 1);
        assert_eq!(y, want);

        let input = Arc::new(XorShift64Star::new(3).fill_f32(3 * 6 * 6, 1.0));
        let col = dispatcher
            .execute_im2col(ctx, (3, 6, 6), 3, 1, 1, Arc::clone(&input), 32)
            .expect("native pool supports im2col");
        let x_t = crate::tensor::Tensor::from_vec(&[3, 6, 6], (*input).clone());
        let want_col = crate::nn::im2col::im2col(&x_t, 3, 1, 1);
        assert_eq!(col, want_col.data());

        let report = pool.shutdown().unwrap();
        assert_eq!(report.per_class_jobs[JobClass::FcGemm.index()], 1);
        assert_eq!(report.per_class_jobs[JobClass::Im2col.index()], 1);
        assert_eq!(report.jobs_executed, 2);
        // Per-accel counters balance the total.
        assert_eq!(report.per_accel_jobs.iter().sum::<u64>(), 2);
    }

    #[test]
    fn route_respects_capabilities() {
        // A registry where FC is only supported by the "neon" backend and
        // the F-PE cluster is CONV-only, mirroring a real PJRT deployment.
        let mut registry = BackendRegistry::with_defaults(
            default_artifacts_dir(),
            2,
        );
        registry.register(
            "conv-only",
            ClassMask::of(&[JobClass::ConvTile]),
            || Ok(Box::new(crate::accel::NativeGemm) as Box<dyn Accelerator>),
        );
        // Hand-build a pool whose cluster-1 members resolve to conv-only:
        // simplest via Dispatcher::route on a live pool is covered above;
        // here check the mask algebra the pool start uses.
        let all = ClassMask::all();
        let conv_only = registry.get("conv-only").unwrap().caps;
        assert!(all.intersect(conv_only).supports(JobClass::ConvTile));
        assert!(!all.intersect(conv_only).supports(JobClass::FcGemm));
    }

    #[test]
    fn unknown_backend_key_fails_cleanly() {
        let mut options =
            PoolOptions::new(HwConfig::default_zc702(), ComputeMode::Native, false);
        // An empty registry knows no backend names at all.
        options.registry = Some(Arc::new(BackendRegistry::new()));
        let err = DelegatePool::start(&options).err().expect("must fail");
        assert!(err.to_string().contains("registry"), "{err}");
    }
}
