//! The shared accelerator substrate: per-cluster job-queue banks, delegate
//! threads, and the work-stealing thief, factored out of the single-stream
//! driver so the serving runtime (`serve/`) can host many network
//! pipelines over one physical pool of accelerators.
//!
//! Every delegate drives an [`Accelerator`] backend resolved by name from
//! the [`BackendRegistry`] and pops jobs through its **own member
//! capability mask** from its cluster's per-class [`QueueBank`]: a NEON
//! member of a mixed NEON+PE cluster serves FC/im2col sub-queues while the
//! PE member drains CONV tiles (paper §3.1 "unified abstraction" — kept
//! true for *every* cluster shape).  The [`Dispatcher`] routes each job
//! class to the cluster whose capable members are least loaded; there is
//! no per-cluster capability intersection and no inline execution on the
//! pipeline thread as long as *any* member of the pool supports the class.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{self, Sender};
use std::sync::Arc;
use std::time::Duration;

use anyhow::{anyhow, Result};

use crate::accel::{
    build_clusters, AccelClass, AccelSpec, Accelerator, BackendRegistry, ClusterSpec, LinkCost,
};
use crate::cluster::QueueBank;
use crate::config::HwConfig;
use crate::mm::job::{ClassMask, Job, JobClass, JobResult};
use crate::runtime::default_artifacts_dir;
use crate::sched::worksteal::{StealPolicy, Thief, ThiefMsg};

use super::delegate::{self, DelegateStats, RtJob};
use super::ComputeMode;

/// Registry key of the backend driving one accelerator spec under a
/// compute mode: FPGA PEs run the PJRT job kernel in [`ComputeMode::Pjrt`]
/// and the native GEMM otherwise; NEON and big-NEON members always run
/// their native backends; remote members resolve to the `remote:<addr>`
/// key their address names — registered out-of-tree (e.g. via
/// `accel::remote::register_config_shards`), never special-cased here.
pub fn backend_key(spec: &AccelSpec, mode: ComputeMode) -> String {
    match (&spec.class, mode) {
        (AccelClass::FpgaPe { .. }, ComputeMode::Pjrt) => "pjrt-pe".to_string(),
        (AccelClass::FpgaPe { .. }, ComputeMode::Native) => "neon".to_string(),
        (AccelClass::Neon, _) => "neon".to_string(),
        (AccelClass::BigNeon, _) => "big-neon".to_string(),
        (AccelClass::Remote { addr }, _) => crate::accel::remote::shard_backend_name(addr),
    }
}

/// Pool configuration (the runtime-relevant subset of `RtOptions`).
#[derive(Clone)]
pub struct PoolOptions {
    pub hw: HwConfig,
    pub compute: ComputeMode,
    pub work_stealing: bool,
    pub steal_policy: StealPolicy,
    /// Extra jobs a delegate drains per queue visit (see
    /// [`delegate::spawn`]).  0 keeps the single-stream driver's strict
    /// one-at-a-time sharing; the serving runtime raises it from the
    /// `[serving]` config.
    pub drain_extra: usize,
    /// Backend registry override; `None` uses
    /// [`BackendRegistry::with_defaults`] (neon, big-neon, pjrt-pe).
    pub registry: Option<Arc<BackendRegistry>>,
    /// Health/cost probe period for remote members, in milliseconds.
    /// `0` (the default) disables the prober threads — the measured
    /// placement loop is opt-in because each `remote = …` member gets its
    /// own probe connection (tests that register a local stand-in under a
    /// remote member's backend key have no listener to dial).  The serving
    /// runtime turns it on from `[serving] probe_interval_ms`.
    pub probe_interval_ms: u64,
}

impl PoolOptions {
    pub fn new(hw: HwConfig, compute: ComputeMode, work_stealing: bool) -> Self {
        PoolOptions {
            hw,
            compute,
            work_stealing,
            steal_policy: StealPolicy::default(),
            drain_extra: 0,
            registry: None,
            probe_interval_ms: 0,
        }
    }
}

/// One member's routing cost: its capability mask, its k-step period, and
/// the shared *live* [`LinkCost`] cell the prober thread, its delegate,
/// and every route reader all hold — measured RTT probes and eviction
/// reach routing through this one cell.
#[derive(Debug, Clone)]
pub struct MemberCost {
    /// The member's capability mask (registry metadata).
    pub caps: ClassMask,
    /// Seconds per k-step — converts link overhead (k-step equivalents)
    /// to seconds and seeds the static service rate.
    pub kstep_seconds: f64,
    /// Live link cost + liveness.  Remote members share the cell of their
    /// shard's [`BackendEntry`](crate::accel::BackendRegistry) (one shard
    /// address = one health/cost identity); local members get a private
    /// cell so one dying instance doesn't evict its siblings.
    pub link: Arc<LinkCost>,
}

impl MemberCost {
    /// k-steps/s this member serves: the shard-reported measured rate
    /// when a probe has delivered one, else the static `1/kstep_seconds`.
    fn rate_ksteps(&self) -> f64 {
        self.link
            .measured_rate_ksteps()
            .unwrap_or(1.0 / self.kstep_seconds)
    }
}

/// Per-cluster routing metadata over the member cost cells.  Every
/// accessor answers from the members' *current* [`LinkCost`] state — an
/// evicted member stops contributing to the accept mask, rates, and
/// overheads the moment its cell flips, so the dispatcher routes around a
/// dead shard without rebuilding anything.
#[derive(Debug, Clone)]
pub struct ClusterRoute {
    members: Vec<MemberCost>,
}

impl ClusterRoute {
    /// Build from one cluster's members, their capability masks, and
    /// their link cost cells (one per member, seeded from the registry's
    /// `overhead_ksteps` metadata).
    pub fn derive(
        cluster: &ClusterSpec,
        member_caps: &[ClassMask],
        member_links: &[Arc<LinkCost>],
    ) -> ClusterRoute {
        debug_assert_eq!(cluster.members.len(), member_caps.len());
        debug_assert_eq!(cluster.members.len(), member_links.len());
        let members = cluster
            .members
            .iter()
            .zip(member_caps)
            .zip(member_links)
            .map(|((member, caps), link)| MemberCost {
                caps: *caps,
                kstep_seconds: member.perf.kstep_seconds,
                link: Arc::clone(link),
            })
            .collect();
        ClusterRoute { members }
    }

    /// The member cost cells (tests and the pool's prober wiring).
    pub fn members(&self) -> &[MemberCost] {
        &self.members
    }

    /// Union of *alive* member masks: the classes some live member can
    /// execute — what the cluster's bank may accept (dispatch and steal
    /// filter).  A cluster whose only capable member was evicted simply
    /// stops accepting, which is exactly "no further route attempts".
    pub fn accept(&self) -> ClassMask {
        let mut accept = ClassMask::NONE;
        for m in &self.members {
            if m.link.is_alive() {
                accept = accept.union(m.caps);
            }
        }
        accept
    }

    /// Does some alive member support `class`?
    pub fn accepts(&self, class: JobClass) -> bool {
        self.members
            .iter()
            .any(|m| m.link.is_alive() && m.caps.supports(class))
    }

    /// Aggregate k-steps/s of the alive members that support class `ci` —
    /// measured shard rates when probes delivered them, static otherwise.
    pub fn class_rate(&self, ci: usize) -> f64 {
        self.members
            .iter()
            .filter(|m| m.link.is_alive() && m.caps.supports_index(ci))
            .map(|m| m.rate_ksteps())
            .sum()
    }

    /// Union of the masks of the alive members that support class `ci` —
    /// the full service set those members drain, i.e. the backlog that
    /// competes with a newly routed job of this class.
    pub fn drain_mask(&self, ci: usize) -> ClassMask {
        let mut mask = ClassMask::NONE;
        for m in &self.members {
            if m.link.is_alive() && m.caps.supports_index(ci) {
                mask = mask.union(m.caps);
            }
        }
        mask
    }

    /// The fixed per-job shipping cost (seconds) of the *cheapest* capable
    /// member for class `ci` — its link overhead (measured RTT once probes
    /// run; the registry's static `overhead_ksteps` before) converted at
    /// that member's k-step rate.  Zero whenever any capable member is
    /// local; a class only remote members serve carries their transport
    /// round trip; `INFINITY` once every capable member was evicted (the
    /// thief's ship gate then prunes the class entirely).  Two consumers:
    /// the dispatcher adds it to the routing load (small jobs stay local
    /// until backlog outweighs the trip) and the thief's class-level ship
    /// gate prunes steals of classes whose backlog drains faster than it
    /// ships (`Thief::spawn_with_costs`).
    pub fn class_overhead_s(&self, ci: usize) -> f64 {
        let mut any_capable = false;
        let mut best = f64::INFINITY;
        for m in &self.members {
            if !m.caps.supports_index(ci) {
                continue;
            }
            any_capable = true;
            best = best.min(m.link.overhead_ksteps() * m.kstep_seconds);
        }
        if !any_capable {
            return 0.0; // no capable member: accept() already bars routing
        }
        best
    }

    /// Members whose link has been evicted (dead shard / dead backend).
    pub fn evicted_members(&self) -> usize {
        self.members.iter().filter(|m| !m.link.is_alive()).count()
    }
}

/// Dispatch-side counters (shared between the pool and its dispatchers).
#[derive(Debug, Default)]
pub struct DispatchStats {
    /// Jobs handed to cluster banks, per class.
    pub dispatched_by_class: [AtomicU64; JobClass::COUNT],
    /// Jobs executed inline on the calling thread because **no member of
    /// any cluster** supports the class (a degenerate pool, e.g. a custom
    /// all-PE registry).  With member-level routing this is the *only*
    /// inline path left — any capable member anywhere keeps it at zero.
    pub inline_fallbacks: AtomicU64,
    /// Requests whose FC work was computed as a fused
    /// [`JobClass::FcGemmBatch`] GEMM (sum of batch sizes over fused
    /// executions — including the counted inline last resort on a
    /// degenerate pool with no FC-capable member, where the fused kernel
    /// still runs, just on the calling thread).  On any pool that
    /// dispatches, fused rows ÷ `dispatched_by_class[FcGemmBatch]` is the
    /// mean fused batch width.
    pub fused_fc_rows: AtomicU64,
}

/// Counters accumulated over the pool's lifetime.
#[derive(Debug, Clone, Default)]
pub struct PoolReport {
    pub jobs_executed: u64,
    /// Jobs per accelerator (by accel id).
    pub per_accel_jobs: Vec<u64>,
    /// Jobs per accelerator per class (accel id → [`JobClass`] dense
    /// order) — proves which *member* executed which class.
    pub per_accel_by_class: Vec<[u64; JobClass::COUNT]>,
    /// Jobs per class ([`JobClass`] dense order).
    pub per_class_jobs: [u64; JobClass::COUNT],
    /// Jobs the dispatcher handed to cluster banks, per class (executed +
    /// still in flight; equal to `per_class_jobs` once drained).
    pub dispatched_by_class: [u64; JobClass::COUNT],
    /// See [`DispatchStats::inline_fallbacks`].  Zero whenever at least
    /// one member of the pool supports every dispatched class.
    pub inline_fallbacks: u64,
    /// See [`DispatchStats::fused_fc_rows`]: requests covered by fused
    /// batched-FC executions, inline last resorts included
    /// (`per_class_jobs` splits fused vs unfused jobs; this adds how many
    /// rows the fused ones carried).
    pub fused_fc_rows: u64,
    /// Jobs that failed delegates pushed back onto their banks for
    /// surviving members (the zero-loss requeue path — e.g. a remote
    /// shard's transport dropping mid-batch).
    pub requeued_jobs: u64,
    /// Delegates whose backend died mid-run (their rescuable jobs were
    /// requeued, the rest dropped fail-fast; see [`DelegatePool::shutdown`]
    /// and the delegate's rescue mask).  Callers that require a fully
    /// healthy pool assert this is zero.
    pub delegate_failures: u64,
    /// Members evicted from routing (dead shard links / dead backends):
    /// their [`LinkCost`] cells report not-alive, so the dispatcher and
    /// thief stopped considering them the moment they died.
    pub evicted_members: u64,
    pub steal_attempts: u64,
    pub jobs_stolen: u64,
    /// Stolen jobs per class ([`JobClass`] dense order).
    pub stolen_by_class: [u64; JobClass::COUNT],
}

/// Cheap cloneable handle that layer threads use to push job batches into
/// the pool and gather results (the paper's job-generator + ack path).
///
/// The whole execution surface is two methods over pre-built [`Job`]s:
/// [`Dispatcher::execute_job`] for one job, [`Dispatcher::execute_jobs`]
/// for a batch (one lock + one thief hint per destination cluster instead
/// of per job).  Job construction — ids via
/// [`Dispatcher::reserve_job_ids`], operands as
/// [`OperandView`](crate::mm::OperandView)s, placement hints via
/// [`Job::placed`] — lives with the caller; the old per-class
/// `execute_gemm` / `execute_fc` / `execute_im2col` / `execute_fc_batch`
/// quartet is gone.
#[derive(Clone)]
pub struct Dispatcher {
    banks: Vec<Arc<QueueBank<RtJob>>>,
    thief_tx: Option<Sender<ThiefMsg>>,
    job_counter: Arc<AtomicU64>,
    routes: Arc<Vec<ClusterRoute>>,
    stats: Arc<DispatchStats>,
}

impl Dispatcher {
    /// Reserve `n` consecutive job ids from this pool's counter and
    /// return the first (the contract `jobs_for_gemm`-style generators
    /// expect for their `next_job_id` cursor).
    pub fn reserve_job_ids(&self, n: u64) -> u64 {
        self.job_counter.fetch_add(n, Ordering::Relaxed)
    }

    /// Pick the destination cluster for a job class: `preferred` if some
    /// member there supports it, else the cluster whose *capable members*
    /// carry the smallest backlog per unit of their aggregate service
    /// rate; `None` only if no member of any cluster supports the class.
    pub fn route(&self, class: JobClass, preferred: Option<usize>) -> Option<usize> {
        if let Some(p) = preferred {
            if p < self.routes.len() && self.routes[p].accepts(class) {
                return Some(p);
            }
        }
        let ci = class.index();
        // Snapshot each capable cluster's load once (one bank lock each):
        // recomputing inside a comparator would double the lock traffic on
        // the per-job dispatch path and compare loads from different
        // instants.
        let mut best: Option<(usize, f64)> = None;
        for c in 0..self.banks.len() {
            if !self.routes[c].accepts(class) {
                continue;
            }
            let load = self.member_load(c, ci);
            if best.is_none_or(|(_, b)| load < b) {
                best = Some((c, load));
            }
        }
        best.map(|(c, _)| c)
    }

    /// Estimated completion cost of a new class-`ci` job on cluster `c`:
    /// the backlog its class-capable members serve normalized by those
    /// members' aggregate rate (shard-*measured* once probes run), plus
    /// the cluster's fixed per-job shipping overhead for the class (zero
    /// for local members; a remote shard's measured transport round trip
    /// otherwise).  The overhead term is what keeps small jobs on idle
    /// local clusters while a deep local backlog tips large CONV-tile /
    /// fused-FC work onto a shard.
    fn member_load(&self, c: usize, ci: usize) -> f64 {
        let route = &self.routes[c];
        self.banks[c].len_where(route.drain_mask(ci)) as f64 / route.class_rate(ci).max(1e-12)
            + route.class_overhead_s(ci)
    }

    /// Per-cluster accept masks — the union over alive member
    /// capabilities (for tests and reporting).
    pub fn accept_masks(&self) -> Vec<ClassMask> {
        self.routes.iter().map(|r| r.accept()).collect()
    }

    /// Dispatch one pre-built job of any class and block for its result —
    /// THE execution entry point (layer executors, the serve pipelines,
    /// and `serve::ShardServer` for jobs arriving from a remote peer all
    /// come through here or its batch form [`Dispatcher::execute_jobs`]).
    ///
    /// Routing honors the job's [`Job::placement`] hint when that cluster
    /// has a capable member, else the least-loaded capable cluster; a
    /// counted inline fallback runs on the calling thread only when no
    /// member anywhere supports the class.  The job keeps its
    /// caller-assigned descriptor (ids from a peer pool are theirs, not
    /// this pool's counter).
    pub fn execute_job(&self, job: Job) -> JobResult {
        let class = job.class();
        if class == JobClass::FcGemmBatch {
            // Fused accounting stays honest when fused jobs arrive whole.
            self.stats
                .fused_fc_rows
                .fetch_add(job.desc.grid.p as u64, Ordering::Relaxed);
        }
        match self.route(class, job.placement) {
            Some(cluster) => {
                self.stats.dispatched_by_class[class.index()].fetch_add(1, Ordering::Relaxed);
                self.run_single(cluster, job)
            }
            None => {
                // Degenerate pool: no member anywhere can execute this
                // class.  Compute on the calling thread and count it —
                // tests pin this counter at zero for every topology with
                // a capable member.
                self.stats.inline_fallbacks.fetch_add(1, Ordering::Relaxed);
                job.execute_native()
            }
        }
    }

    /// Dispatch a batch of pre-built jobs and block until every result is
    /// back, in input order — the batch form of
    /// [`Dispatcher::execute_job`] (same routing, same counters, same
    /// inline last resort per unroutable job).  Jobs bound for the same
    /// cluster are enqueued in ONE batch push with ONE thief hint (one
    /// lock + one notify_all per cluster per layer instead of per job —
    /// §Perf iter 3); all routed jobs share a single reply channel and
    /// results are matched back to their slots by job id, so ids must be
    /// unique within the batch (use [`Dispatcher::reserve_job_ids`]).
    pub fn execute_jobs(&self, jobs: Vec<Job>) -> Vec<JobResult> {
        let n = jobs.len();
        let mut results: Vec<Option<JobResult>> = (0..n).map(|_| None).collect();
        let mut slot_by_id = std::collections::HashMap::with_capacity(n);
        let mut per_cluster: Vec<Vec<RtJob>> = (0..self.banks.len()).map(|_| Vec::new()).collect();
        let (tx, rx) = mpsc::channel::<JobResult>();
        let mut pending = 0usize;
        for (slot, job) in jobs.into_iter().enumerate() {
            let class = job.class();
            if class == JobClass::FcGemmBatch {
                self.stats
                    .fused_fc_rows
                    .fetch_add(job.desc.grid.p as u64, Ordering::Relaxed);
            }
            match self.route(class, job.placement) {
                Some(cluster) => {
                    self.stats.dispatched_by_class[class.index()]
                        .fetch_add(1, Ordering::Relaxed);
                    let prev = slot_by_id.insert(job.desc.job_id, slot);
                    assert!(
                        prev.is_none(),
                        "duplicate job id {} in one dispatch batch",
                        job.desc.job_id
                    );
                    per_cluster[cluster].push(RtJob {
                        job,
                        reply: tx.clone(),
                    });
                    pending += 1;
                }
                None => {
                    self.stats.inline_fallbacks.fetch_add(1, Ordering::Relaxed);
                    results[slot] = Some(job.execute_native());
                }
            }
        }
        for (cluster, batch) in per_cluster.into_iter().enumerate() {
            if batch.is_empty() {
                continue;
            }
            self.banks[cluster].push_batch(batch);
            if let Some(t) = &self.thief_tx {
                let _ = t.send(ThiefMsg::ClusterBusy(cluster));
            }
        }
        drop(tx);
        for _ in 0..pending {
            let r = rx.recv().expect("job result");
            let slot = slot_by_id[&r.desc.job_id];
            results[slot] = Some(r);
        }
        results
            .into_iter()
            .map(|r| r.expect("every batch job resolved"))
            .collect()
    }

    fn run_single(&self, cluster: usize, job: Job) -> JobResult {
        let (tx, rx) = mpsc::channel::<JobResult>();
        self.banks[cluster].push(RtJob { job, reply: tx });
        if let Some(t) = &self.thief_tx {
            let _ = t.send(ThiefMsg::ClusterBusy(cluster));
        }
        rx.recv().expect("job result")
    }
}

/// The running pool: one delegate thread per accelerator popping the
/// cluster's bank through its member mask, plus (optionally) the thief.
pub struct DelegatePool {
    clusters: Vec<ClusterSpec>,
    banks: Vec<Arc<QueueBank<RtJob>>>,
    routes: Arc<Vec<ClusterRoute>>,
    delegate_stats: Vec<Arc<DelegateStats>>,
    delegate_handles: Vec<std::thread::JoinHandle<Result<()>>>,
    thief: Option<Thief<RtJob>>,
    job_counter: Arc<AtomicU64>,
    dispatch_stats: Arc<DispatchStats>,
    prober_stop: Arc<AtomicBool>,
    prober_handles: Vec<std::thread::JoinHandle<()>>,
}

impl DelegatePool {
    /// Build clusters, resolve every member through the backend registry,
    /// and spawn delegate threads (and the thief).
    pub fn start(options: &PoolOptions) -> Result<DelegatePool> {
        let registry = options.registry.clone().unwrap_or_else(|| {
            Arc::new(BackendRegistry::with_defaults(
                default_artifacts_dir(),
                options.hw.big_neon_threads,
            ))
        });
        let clusters = build_clusters(&options.hw);
        let banks: Vec<Arc<QueueBank<RtJob>>> = clusters
            .iter()
            .map(|_| Arc::new(QueueBank::new()))
            .collect();

        // Per-member capability masks + link cost cells from the registry
        // metadata (known before any backend instance exists).  Remote
        // members SHARE their backend entry's cell — one shard address is
        // one health/cost identity, and the prober's measurements land in
        // the registry metadata the ISSUE's placement loop reads.  Local
        // members get a private cell seeded from the entry's overhead so
        // one dying instance doesn't evict its siblings resolving the
        // same backend name.
        let mut member_caps: Vec<Vec<ClassMask>> = Vec::with_capacity(clusters.len());
        let mut member_links: Vec<Vec<Arc<LinkCost>>> = Vec::with_capacity(clusters.len());
        // Per-class steal-cost override: element-wise MAX over the tables
        // the pool's members registered (`BackendSpec::class_cost`), so the
        // thief never under-prices a steal; `None` keeps the policy's own
        // table (the derived `DEFAULT_CLASS_COST`).
        let mut cost_override: Option<[f64; JobClass::COUNT]> = None;
        for cluster in &clusters {
            let mut caps = Vec::with_capacity(cluster.members.len());
            let mut links = Vec::with_capacity(cluster.members.len());
            for member in &cluster.members {
                let key = backend_key(member, options.compute);
                let entry = registry
                    .get(&key)
                    .ok_or_else(|| anyhow!("no backend {key:?} in the registry"))?;
                caps.push(entry.caps);
                if let Some(table) = entry.class_cost() {
                    let acc = cost_override.get_or_insert([0.0; JobClass::COUNT]);
                    for (a, v) in acc.iter_mut().zip(table) {
                        if v > *a {
                            *a = v;
                        }
                    }
                }
                links.push(match &member.class {
                    AccelClass::Remote { .. } => entry.link(),
                    _ => LinkCost::fixed(entry.overhead_ksteps()),
                });
            }
            member_caps.push(caps);
            member_links.push(links);
        }
        let routes: Arc<Vec<ClusterRoute>> = Arc::new(
            clusters
                .iter()
                .zip(member_caps.iter().zip(&member_links))
                .map(|(cluster, (caps, links))| ClusterRoute::derive(cluster, caps, links))
                .collect(),
        );
        let service_rates: Vec<f64> = clusters.iter().map(|c| c.throughput()).collect();

        // Registered member cost tables override the policy's weights,
        // element-wise MAX against the policy so an override can only make
        // the thief MORE reluctant to move a class, never cheaper.
        let mut steal_policy = options.steal_policy;
        if let Some(table) = cost_override {
            for (w, v) in steal_policy.class_cost.iter_mut().zip(table) {
                if v > *w {
                    *w = v;
                }
            }
        }
        let thief = if options.work_stealing {
            let ship_routes = Arc::clone(&routes);
            Some(Thief::spawn_with_costs(
                banks.clone(),
                steal_policy,
                routes.iter().map(|r| r.accept()).collect(),
                service_rates,
                // Live gate: re-read on every stealer pass, so measured
                // probes and shard eviction reach the thief immediately.
                Arc::new(move |c, i| ship_routes[c].class_overhead_s(i)),
            ))
        } else {
            None
        };
        let thief_tx = thief.as_ref().map(|t| t.sender());

        let mut delegate_stats = Vec::new();
        let mut delegate_handles = Vec::new();
        for (cluster, caps) in clusters.iter().zip(&member_caps) {
            for (mi, (member, mcaps)) in cluster.members.iter().zip(caps).enumerate() {
                // Delegate-stats order == accelerator-id order: the report
                // indexes `per_accel_*` by accel id.
                assert_eq!(member.id, delegate_stats.len(), "accel ids not dense");
                // Rescue mask: the classes some OTHER member could still
                // serve if this delegate dies — cluster mates share the
                // bank directly; with the thief running, any cluster's
                // members count (stolen work travels).  A dying delegate
                // requeues only rescuable jobs and drops the rest, so
                // blocking callers fail fast instead of waiting on work
                // nobody can ever execute.
                let mut rescue = ClassMask::NONE;
                for (c2, caps2) in member_caps.iter().enumerate() {
                    for (m2, caps2m) in caps2.iter().enumerate() {
                        let same_cluster = c2 == cluster.index;
                        if same_cluster && m2 == mi {
                            continue; // this member itself
                        }
                        if same_cluster || options.work_stealing {
                            rescue = rescue.union(*caps2m);
                        }
                    }
                }
                let stats = Arc::new(DelegateStats::default());
                delegate_stats.push(Arc::clone(&stats));
                let bank = Arc::clone(&banks[cluster.index]);
                let key = backend_key(member, options.compute);
                let builder = registry.get(&key).expect("resolved above").builder();
                let mk = move || -> Result<Box<dyn Accelerator>> { builder() };
                delegate_handles.push(delegate::spawn(
                    format!("delegate-{}", member.name),
                    cluster.index,
                    bank,
                    *mcaps,
                    rescue,
                    mk,
                    thief_tx.clone(),
                    stats,
                    options.drain_extra,
                    Some(Arc::clone(&member_links[cluster.index][mi])),
                ));
            }
        }

        // Health/cost probes: one thread per remote member, dialing its
        // OWN connection (probes must never interleave with a delegate's
        // job frames on an ordered transport).
        let prober_stop = Arc::new(AtomicBool::new(false));
        let mut prober_handles = Vec::new();
        if options.probe_interval_ms > 0 {
            for (cluster, links) in clusters.iter().zip(&member_links) {
                for (member, link) in cluster.members.iter().zip(links) {
                    if let AccelClass::Remote { addr } = &member.class {
                        prober_handles.push(spawn_prober(
                            addr.clone(),
                            Arc::clone(link),
                            member.perf.kstep_seconds,
                            options.probe_interval_ms,
                            Arc::clone(&prober_stop),
                        ));
                    }
                }
            }
        }

        Ok(DelegatePool {
            clusters,
            banks,
            routes,
            delegate_stats,
            delegate_handles,
            thief,
            job_counter: Arc::new(AtomicU64::new(0)),
            dispatch_stats: Arc::new(DispatchStats::default()),
            prober_stop,
            prober_handles,
        })
    }

    pub fn clusters(&self) -> &[ClusterSpec] {
        &self.clusters
    }

    /// Accelerator specs (for reporting).
    pub fn accels(&self) -> Vec<AccelSpec> {
        crate::accel::all_accels(&self.clusters)
    }

    /// Per-cluster routing metadata (accept masks, per-class rates).
    pub fn routes(&self) -> &[ClusterRoute] {
        &self.routes
    }

    /// Handle for layer threads to dispatch matrix work through.
    pub fn dispatcher(&self) -> Dispatcher {
        Dispatcher {
            banks: self.banks.clone(),
            thief_tx: self.thief.as_ref().map(|t| t.sender()),
            job_counter: Arc::clone(&self.job_counter),
            routes: Arc::clone(&self.routes),
            stats: Arc::clone(&self.dispatch_stats),
        }
    }

    /// Live counters (approximate while delegates are still running).
    pub fn snapshot(&self) -> PoolReport {
        fold_report(
            &self.delegate_stats,
            self.thief.as_ref(),
            &self.dispatch_stats,
            &self.routes,
        )
    }

    /// Close the banks, join every delegate, stop the thief, and return
    /// the final counters.  Callers must have drained their reply channels
    /// (i.e. no in-flight jobs) before calling.
    ///
    /// A delegate whose backend died mid-run (remote transport dropped,
    /// injected fault) does NOT fail the shutdown: its jobs were requeued
    /// to surviving members when it died, so the pool's work is complete
    /// and the report is still the full story — the death is surfaced in
    /// [`PoolReport::delegate_failures`].  Only a panicked delegate
    /// thread (a bug, not a failure) panics the join.
    pub fn shutdown(self) -> Result<PoolReport> {
        let DelegatePool {
            banks,
            routes,
            delegate_stats,
            delegate_handles,
            thief,
            dispatch_stats,
            prober_stop,
            prober_handles,
            ..
        } = self;
        // Stop the probers first: a probe failing because its shard shut
        // down concurrently must not be recorded as an eviction.
        prober_stop.store(true, Ordering::SeqCst);
        for h in prober_handles {
            let _ = h.join();
        }
        for b in &banks {
            b.close();
        }
        // Join before reading counters so the report sees every job.
        let mut failures = 0u64;
        for h in delegate_handles {
            if h.join().expect("delegate thread").is_err() {
                failures += 1;
            }
        }
        let mut report = fold_report(&delegate_stats, thief.as_ref(), &dispatch_stats, &routes);
        report.delegate_failures = failures;
        if let Some(t) = thief {
            t.shutdown();
        }
        Ok(report)
    }
}

/// Background health/cost probe for one remote member (paper-side
/// "measured placement"): dials its own connection to the shard, pings
/// every `interval_ms`, and feeds the measured RTT + shard-reported
/// service rate into the member's shared [`LinkCost`] cell — the same
/// cell the dispatcher's routing penalty and the thief's ship gate read,
/// so placement follows the measured link without any rebuild.  A failed
/// dial or ping *evicts* the link: the shard vanishes from routing (and
/// the next fleet member takes its traffic) instead of being rediscovered
/// dead one job at a time.
fn spawn_prober(
    addr: String,
    link: Arc<LinkCost>,
    kstep_seconds: f64,
    interval_ms: u64,
    stop: Arc<AtomicBool>,
) -> std::thread::JoinHandle<()> {
    std::thread::Builder::new()
        .name(format!("probe-{addr}"))
        .spawn(move || {
            use crate::accel::remote::{probe_shard, TcpTransport};
            let mut transport = match TcpTransport::connect(&addr) {
                Ok(t) => t,
                Err(_) => {
                    link.evict();
                    return;
                }
            };
            let mut seq = 0u64;
            while !stop.load(Ordering::SeqCst) && link.is_alive() {
                match probe_shard(&mut transport, seq) {
                    Ok((rtt_s, rate_ksteps, _served)) => {
                        link.record_probe(rtt_s, kstep_seconds, rate_ksteps);
                    }
                    Err(_) => {
                        // Shutdown races (the shard closing first) are not
                        // health events; anything else is a dead link.
                        if !stop.load(Ordering::SeqCst) {
                            link.evict();
                        }
                        return;
                    }
                }
                seq += 1;
                // Sleep in short slices so shutdown never waits a full
                // probe interval.
                let mut left = interval_ms;
                while left > 0 && !stop.load(Ordering::SeqCst) {
                    let slice = left.min(5);
                    std::thread::sleep(Duration::from_millis(slice));
                    left -= slice;
                }
            }
        })
        .expect("spawn prober thread")
}

fn fold_report(
    delegate_stats: &[Arc<DelegateStats>],
    thief: Option<&Thief<RtJob>>,
    dispatch: &DispatchStats,
    routes: &[ClusterRoute],
) -> PoolReport {
    let mut report = PoolReport::default();
    report.evicted_members = routes.iter().map(|r| r.evicted_members() as u64).sum();
    for stats in delegate_stats {
        let j = stats.jobs.load(Ordering::Relaxed);
        report.per_accel_jobs.push(j);
        report.jobs_executed += j;
        report.requeued_jobs += stats.requeued.load(Ordering::Relaxed);
        let by_class = stats.jobs_by_class();
        report.per_accel_by_class.push(by_class);
        for (acc, n) in report.per_class_jobs.iter_mut().zip(by_class) {
            *acc += n;
        }
    }
    for (acc, ctr) in report
        .dispatched_by_class
        .iter_mut()
        .zip(&dispatch.dispatched_by_class)
    {
        *acc = ctr.load(Ordering::Relaxed);
    }
    report.inline_fallbacks = dispatch.inline_fallbacks.load(Ordering::Relaxed);
    report.fused_fc_rows = dispatch.fused_fc_rows.load(Ordering::Relaxed);
    if let Some(t) = thief {
        let (attempts, _successes, moved) = t.stats.snapshot();
        report.steal_attempts = attempts;
        report.jobs_stolen = moved;
        report.stolen_by_class = t.stats.moved_by_class();
    }
    report
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::BackendSpec;
    use crate::mm::job::{gather_results, jobs_for_gemm};
    use crate::mm::TileGrid;
    use crate::util::rng::XorShift64Star;

    /// Lower one dense GEMM to placed tile jobs, run them through the
    /// generic batch entry, and gather the (M,P) result — what the
    /// retired `execute_gemm` method used to bundle.
    fn run_gemm(
        dispatcher: &Dispatcher,
        grid: TileGrid,
        a: Arc<Vec<f32>>,
        b: Arc<Vec<f32>>,
        placement: Option<usize>,
    ) -> Vec<f32> {
        let mut next_id = dispatcher.reserve_job_ids(grid.num_jobs() as u64);
        let jobs: Vec<Job> = jobs_for_gemm(0, 0, grid, a, b, &mut next_id)
            .into_iter()
            .map(|j| j.placed(placement))
            .collect();
        let results = dispatcher.execute_jobs(jobs);
        gather_results(grid, &results)
    }

    #[test]
    fn pool_executes_a_gemm_end_to_end() {
        let options = PoolOptions::new(HwConfig::default_zc702(), ComputeMode::Native, true);
        let pool = DelegatePool::start(&options).unwrap();
        let dispatcher = pool.dispatcher();
        let grid = TileGrid::new(40, 50, 60, 32);
        let a = Arc::new(XorShift64Star::new(1).fill_f32(40 * 50, 1.0));
        let b = Arc::new(XorShift64Star::new(2).fill_f32(50 * 60, 1.0));
        let c = run_gemm(&dispatcher, grid, Arc::clone(&a), Arc::clone(&b), Some(0));
        let want = crate::mm::gemm::gemm_blocked(
            &crate::tensor::Tensor::from_vec(&[40, 50], (*a).clone()),
            &crate::tensor::Tensor::from_vec(&[50, 60], (*b).clone()),
        );
        let got = crate::tensor::Tensor::from_vec(&[40, 60], c);
        assert!(want.allclose(&got, 1e-4, 1e-4), "{}", want.max_abs_diff(&got));
        let report = pool.shutdown().unwrap();
        assert_eq!(report.jobs_executed, grid.num_jobs() as u64);
        assert_eq!(
            report.per_class_jobs[JobClass::ConvTile.index()],
            grid.num_jobs() as u64
        );
        // Executed == dispatched per class; nothing ran inline.
        assert_eq!(report.dispatched_by_class, report.per_class_jobs);
        assert_eq!(report.inline_fallbacks, 0);
    }

    #[test]
    fn pool_executes_fc_and_im2col_jobs() {
        let options = PoolOptions::new(HwConfig::default_zc702(), ComputeMode::Native, false);
        let pool = DelegatePool::start(&options).unwrap();
        let dispatcher = pool.dispatcher();
        // In native mode every member supports every class.
        for accept in dispatcher.accept_masks() {
            for class in JobClass::ALL {
                assert!(accept.supports(class));
            }
        }
        let w = Arc::new(XorShift64Star::new(1).fill_f32(16 * 32, 1.0));
        let x = Arc::new(XorShift64Star::new(2).fill_f32(32, 1.0));
        let id = dispatcher.reserve_job_ids(1);
        let job = Job::fc(id, 2, 7, 16, 32, Arc::clone(&w), Arc::clone(&x), 32)
            .placed(Some(0));
        let y = dispatcher.execute_job(job).data;
        let mut want = vec![0.0f32; 16];
        crate::mm::gemm::gemm_blocked_into(&w, &x, &mut want, 16, 32, 1);
        assert_eq!(y, want);

        let input = Arc::new(XorShift64Star::new(3).fill_f32(3 * 6 * 6, 1.0));
        let id = dispatcher.reserve_job_ids(1);
        let job = Job::im2col(id, 2, 7, (3, 6, 6), 3, 1, 1, Arc::clone(&input), 32)
            .placed(Some(0));
        let col = dispatcher.execute_job(job).data;
        let x_t = crate::tensor::Tensor::from_vec(&[3, 6, 6], (*input).clone());
        let want_col = crate::nn::im2col::im2col(&x_t, 3, 1, 1);
        assert_eq!(col, want_col.data());

        let report = pool.shutdown().unwrap();
        assert_eq!(report.per_class_jobs[JobClass::FcGemm.index()], 1);
        assert_eq!(report.per_class_jobs[JobClass::Im2col.index()], 1);
        assert_eq!(report.jobs_executed, 2);
        assert_eq!(report.inline_fallbacks, 0);
        // Per-accel counters balance the total, per class too.
        assert_eq!(report.per_accel_jobs.iter().sum::<u64>(), 2);
        let mut by_class = [0u64; JobClass::COUNT];
        for accel in &report.per_accel_by_class {
            for (acc, n) in by_class.iter_mut().zip(accel) {
                *acc += n;
            }
        }
        assert_eq!(by_class, report.per_class_jobs);
    }

    /// The mixed-cluster acceptance scenario at pool level: the default
    /// ZC702 cluster-0 is 2 S-PE + 2 NEON.  Under PJRT(-stub) mode the PE
    /// members are CONV-only, yet the cluster must keep accepting FC and
    /// im2col jobs because its NEON members serve those sub-queues —
    /// the old per-cluster intersection would have degraded it to
    /// CONV-only and run these jobs inline.
    #[test]
    fn mixed_cluster_pjrt_stub_serves_fc_on_neon_members() {
        let options = PoolOptions::new(HwConfig::default_zc702(), ComputeMode::Pjrt, false);
        let pool = DelegatePool::start(&options).unwrap();
        let dispatcher = pool.dispatcher();
        let accels = pool.accels();

        // Cluster 0 (mixed) accepts everything; cluster 1 (pure F-PE)
        // accepts CONV tiles only.
        let accepts = dispatcher.accept_masks();
        assert!(JobClass::ALL.iter().all(|c| accepts[0].supports(*c)));
        assert!(accepts[1].supports(JobClass::ConvTile));
        assert!(!accepts[1].supports(JobClass::FcGemm));
        assert!(!accepts[1].supports(JobClass::Im2col));
        // Routing: FC can only land on the mixed cluster, even when the
        // static hint points at the PE-only one.
        assert_eq!(dispatcher.route(JobClass::FcGemm, None), Some(0));
        assert_eq!(dispatcher.route(JobClass::FcGemm, Some(1)), Some(0));
        assert_eq!(dispatcher.route(JobClass::ConvTile, Some(1)), Some(1));

        // Jobs placed on the PE-only cluster still land on the mixed one:
        // routing overrides a placement hint with no capable member.
        let w = Arc::new(XorShift64Star::new(4).fill_f32(12 * 24, 1.0));
        let x = Arc::new(XorShift64Star::new(5).fill_f32(24, 1.0));
        let id = dispatcher.reserve_job_ids(1);
        let job = Job::fc(id, 0, 0, 12, 24, Arc::clone(&w), Arc::clone(&x), 32)
            .placed(Some(1));
        let y = dispatcher.execute_job(job).data;
        let mut want = vec![0.0f32; 12];
        crate::mm::gemm::gemm_blocked_into(&w, &x, &mut want, 12, 24, 1);
        assert_eq!(y, want);
        let input = Arc::new(XorShift64Star::new(6).fill_f32(3 * 6 * 6, 1.0));
        let id = dispatcher.reserve_job_ids(1);
        let job = Job::im2col(id, 0, 0, (3, 6, 6), 3, 1, 1, input, 32).placed(Some(1));
        let _col = dispatcher.execute_job(job).data;

        let report = pool.shutdown().unwrap();
        assert_eq!(report.inline_fallbacks, 0, "no inline fallback in a mixed pool");
        assert_eq!(report.per_class_jobs[JobClass::FcGemm.index()], 1);
        assert_eq!(report.per_class_jobs[JobClass::Im2col.index()], 1);
        // Only NEON-class members executed the FC/im2col jobs.
        for accel in &accels {
            let by_class = report.per_accel_by_class[accel.id];
            let non_conv =
                by_class[JobClass::FcGemm.index()] + by_class[JobClass::Im2col.index()];
            if accel.is_fpga() {
                assert_eq!(non_conv, 0, "{} ran a non-CONV job", accel.name);
            }
        }
        let neon_non_conv: u64 = accels
            .iter()
            .filter(|a| !a.is_fpga())
            .map(|a| {
                report.per_accel_by_class[a.id][JobClass::FcGemm.index()]
                    + report.per_accel_by_class[a.id][JobClass::Im2col.index()]
            })
            .sum();
        assert_eq!(neon_non_conv, 2, "NEON members must serve FC + im2col");
    }

    /// Only a pool with ZERO capable members anywhere falls back inline —
    /// and the counter records it.
    #[test]
    fn all_pe_pool_counts_inline_fallbacks() {
        let mut hw = HwConfig::default_zc702();
        for cluster in &mut hw.clusters {
            cluster.neon = 0;
            cluster.big_neon = 0;
        }
        let options = PoolOptions::new(hw, ComputeMode::Pjrt, false);
        let pool = DelegatePool::start(&options).unwrap();
        let dispatcher = pool.dispatcher();
        assert_eq!(dispatcher.route(JobClass::FcGemm, None), None);
        let w = Arc::new(XorShift64Star::new(7).fill_f32(8 * 16, 1.0));
        let x = Arc::new(XorShift64Star::new(8).fill_f32(16, 1.0));
        let id = dispatcher.reserve_job_ids(1);
        let job = Job::fc(id, 0, 0, 8, 16, Arc::clone(&w), Arc::clone(&x), 32)
            .placed(Some(0));
        let y = dispatcher.execute_job(job).data;
        let mut want = vec![0.0f32; 8];
        crate::mm::gemm::gemm_blocked_into(&w, &x, &mut want, 8, 16, 1);
        assert_eq!(y, want, "inline fallback must still be correct");
        // The fused batched path degrades the same way: counted, correct.
        let xb = Arc::new(XorShift64Star::new(9).fill_f32(16 * 2, 1.0));
        let id = dispatcher.reserve_job_ids(1);
        let job = Job::fc_batch(id, 0, 0, 8, 16, 2, Arc::clone(&w), Arc::clone(&xb), 32)
            .placed(Some(0));
        let yb = dispatcher.execute_job(job).data;
        let mut want_b = vec![0.0f32; 8 * 2];
        crate::mm::gemm::gemm_blocked_into(&w, &xb, &mut want_b, 8, 16, 2);
        assert_eq!(yb, want_b, "fused inline fallback must still be correct");
        let report = pool.shutdown().unwrap();
        assert_eq!(report.inline_fallbacks, 2);
        assert_eq!(report.dispatched_by_class[JobClass::FcGemm.index()], 0);
        assert_eq!(
            report.dispatched_by_class[JobClass::FcGemmBatch.index()],
            0
        );
        assert_eq!(report.fused_fc_rows, 2);
        assert_eq!(report.jobs_executed, 0);
    }

    /// A registry that strips CONV capability from every member must
    /// degrade to the counted inline path, not panic the layer thread.
    #[test]
    fn conv_incapable_registry_falls_back_inline_for_gemm() {
        let mut hw = HwConfig::default_zc702();
        for cluster in &mut hw.clusters {
            cluster.neon = 0;
            cluster.big_neon = 0;
        }
        let mut options = PoolOptions::new(hw, ComputeMode::Pjrt, false);
        let mut registry = BackendRegistry::new();
        registry.register(
            BackendSpec::new("pjrt-pe", || {
                Ok(Box::new(crate::accel::NativeGemm) as Box<dyn Accelerator>)
            })
            .caps(ClassMask::of(&[JobClass::Im2col])),
        );
        options.registry = Some(Arc::new(registry));
        let pool = DelegatePool::start(&options).unwrap();
        let dispatcher = pool.dispatcher();
        assert_eq!(dispatcher.route(JobClass::ConvTile, Some(0)), None);
        let grid = TileGrid::new(16, 24, 20, 32);
        let a = Arc::new(XorShift64Star::new(9).fill_f32(16 * 24, 1.0));
        let b = Arc::new(XorShift64Star::new(10).fill_f32(24 * 20, 1.0));
        let c = run_gemm(&dispatcher, grid, Arc::clone(&a), Arc::clone(&b), Some(0));
        let want = crate::mm::gemm::gemm_blocked(
            &crate::tensor::Tensor::from_vec(&[16, 24], (*a).clone()),
            &crate::tensor::Tensor::from_vec(&[24, 20], (*b).clone()),
        );
        let got = crate::tensor::Tensor::from_vec(&[16, 20], c);
        assert!(want.allclose(&got, 1e-4, 1e-4));
        let report = pool.shutdown().unwrap();
        assert_eq!(report.inline_fallbacks, grid.num_jobs() as u64);
        assert_eq!(report.jobs_executed, 0);
        assert_eq!(report.dispatched_by_class[JobClass::ConvTile.index()], 0);
    }

    /// The generic single-job entry: every class executes correctly and
    /// lands in the dispatch counters (the shard server's path).
    #[test]
    fn execute_job_dispatches_every_class() {
        let options = PoolOptions::new(HwConfig::default_zc702(), ComputeMode::Native, false);
        let pool = DelegatePool::start(&options).unwrap();
        let dispatcher = pool.dispatcher();
        let w = Arc::new(XorShift64Star::new(21).fill_f32(8 * 16, 1.0));
        let xb = Arc::new(XorShift64Star::new(22).fill_f32(16 * 3, 1.0));
        let fused = Job::fc_batch(77, 1, 0, 8, 16, 3, Arc::clone(&w), xb, 32);
        let want = fused.execute_native();
        let got = dispatcher.execute_job(fused);
        assert_eq!(got.desc.job_id, 77, "caller-assigned ids are kept");
        assert_eq!(got.data, want.data);
        let input = Arc::new(XorShift64Star::new(23).fill_f32(3 * 6 * 6, 1.0));
        let im = Job::im2col(78, 0, 0, (3, 6, 6), 3, 1, 1, input, 32);
        let want = im.execute_native();
        assert_eq!(dispatcher.execute_job(im).data, want.data);
        let report = pool.shutdown().unwrap();
        assert_eq!(report.jobs_executed, 2);
        assert_eq!(report.inline_fallbacks, 0);
        assert_eq!(report.dispatched_by_class[JobClass::FcGemmBatch.index()], 1);
        assert_eq!(report.dispatched_by_class[JobClass::Im2col.index()], 1);
        assert_eq!(report.fused_fc_rows, 3);
        assert_eq!(report.delegate_failures, 0);
        assert_eq!(report.requeued_jobs, 0);
    }

    /// The cost-aware routing penalty: a cluster whose only capable
    /// member carries a fixed shipping overhead (registry metadata, à la
    /// remote shard) loses empty-queue ties to local clusters, and wins
    /// once the local backlog outweighs the trip.
    #[test]
    fn shipping_overhead_routes_small_jobs_local_and_backlog_remote() {
        use std::sync::mpsc;

        let mut hw = HwConfig::default_zc702();
        hw.clusters = vec![
            crate::config::ClusterCfg {
                name: "local".into(),
                neon: 1,
                big_neon: 0,
                remote: Vec::new(),
                pes: Vec::new(),
            },
            crate::config::ClusterCfg {
                name: "shard".into(),
                neon: 0,
                big_neon: 0,
                remote: vec!["127.0.0.1:1".into()],
                pes: Vec::new(),
            },
        ];

        /// A native backend that waits for one gate token per job, so the
        /// test can hold a backlog on the local cluster deterministically.
        struct GatedNative(mpsc::Receiver<()>);
        impl Accelerator for GatedNative {
            fn id(&self) -> &str {
                "gated-neon"
            }
            fn supports(&self, _class: JobClass) -> bool {
                true
            }
            fn execute(&mut self, job: &Job) -> Result<crate::mm::job::JobResult> {
                let _ = self.0.recv(); // released by the test (or teardown)
                Ok(job.execute_native())
            }
        }

        let (gate_tx, gate_rx) = mpsc::channel::<()>();
        let gate = std::sync::Mutex::new(Some(gate_rx));
        let mut registry = BackendRegistry::new();
        registry.register(BackendSpec::new("neon", move || {
            let rx = gate
                .lock()
                .unwrap()
                .take()
                .ok_or_else(|| anyhow!("single gated delegate"))?;
            Ok(Box::new(GatedNative(rx)) as Box<dyn Accelerator>)
        }));
        // "Remote" member: local compute, but registered with the remote
        // mask + shipping overhead — this test is about routing metadata,
        // not transports.
        registry.register(
            BackendSpec::new(
                &crate::accel::remote::shard_backend_name("127.0.0.1:1"),
                || Ok(Box::new(crate::accel::NativeGemm) as Box<dyn Accelerator>),
            )
            .caps(crate::accel::remote::remote_class_mask())
            .overhead_ksteps(crate::accel::remote::REMOTE_OVERHEAD_KSTEPS),
        );

        let mut options = PoolOptions::new(hw, ComputeMode::Native, false);
        options.registry = Some(Arc::new(registry));
        let pool = DelegatePool::start(&options).unwrap();
        let dispatcher = pool.dispatcher();

        // Empty pool: the shipping overhead loses the tie — small jobs
        // stay local; classes outside the remote mask can ONLY go local.
        assert_eq!(dispatcher.route(JobClass::ConvTile, None), Some(0));
        assert_eq!(dispatcher.route(JobClass::FcGemmBatch, None), Some(0));
        assert_eq!(dispatcher.route(JobClass::FcGemm, None), Some(0));
        assert_eq!(dispatcher.route(JobClass::Im2col, None), Some(0));
        let shard_route = &pool.routes()[1];
        assert!(shard_route.class_overhead_s(JobClass::ConvTile.index()) > 0.0);
        assert!(shard_route.class_overhead_s(JobClass::FcGemmBatch.index()) > 0.0);
        // Classes no member there serves carry no overhead (the accept
        // mask already bars routing), and local clusters ship for free.
        assert_eq!(shard_route.class_overhead_s(JobClass::FcGemm.index()), 0.0);
        for class in JobClass::ALL {
            assert_eq!(pool.routes()[0].class_overhead_s(class.index()), 0.0);
        }

        // Pile a 16-tile GEMM onto the local cluster (its only delegate is
        // gated, so the backlog stays put)…
        let grid = TileGrid::new(128, 32, 128, 32);
        let a = Arc::new(XorShift64Star::new(31).fill_f32(128 * 32, 1.0));
        let b = Arc::new(XorShift64Star::new(32).fill_f32(32 * 128, 1.0));
        let helper = {
            let dispatcher = pool.dispatcher();
            let (a, b) = (Arc::clone(&a), Arc::clone(&b));
            std::thread::spawn(move || run_gemm(&dispatcher, grid, a, b, None))
        };
        // …until the backlog outweighs the round trip and routing flips
        // to the shard for the classes it speaks — and ONLY those.
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(5);
        while dispatcher.route(JobClass::ConvTile, None) != Some(1) {
            assert!(
                std::time::Instant::now() < deadline,
                "backlog never tipped routing onto the shard cluster"
            );
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        assert_eq!(dispatcher.route(JobClass::FcGemm, None), Some(0));
        assert_eq!(dispatcher.route(JobClass::Im2col, None), Some(0));

        // Release the gate and finish: results stay correct.
        for _ in 0..grid.num_jobs() {
            gate_tx.send(()).unwrap();
        }
        let c = helper.join().unwrap();
        let want = crate::mm::gemm::gemm_blocked(
            &crate::tensor::Tensor::from_vec(&[128, 32], (*a).clone()),
            &crate::tensor::Tensor::from_vec(&[32, 128], (*b).clone()),
        );
        let got = crate::tensor::Tensor::from_vec(&[128, 128], c);
        assert!(want.allclose(&got, 1e-4, 1e-4));
        drop(gate_tx);
        let report = pool.shutdown().unwrap();
        assert_eq!(report.jobs_executed, grid.num_jobs() as u64);
        assert_eq!(report.delegate_failures, 0);
    }

    /// Evicting a member's link removes its cluster from routing on the
    /// spot: placement hints pointing at it are overridden, the
    /// least-loaded search skips it, and the report counts the eviction —
    /// the deterministic core of "kill a shard, lose nothing, never route
    /// to it again".
    #[test]
    fn evicted_member_disappears_from_routing() {
        let mut hw = HwConfig::default_zc702();
        hw.clusters = vec![
            crate::config::ClusterCfg {
                name: "local".into(),
                neon: 1,
                big_neon: 0,
                remote: Vec::new(),
                pes: Vec::new(),
            },
            crate::config::ClusterCfg {
                name: "shard".into(),
                neon: 0,
                big_neon: 0,
                remote: vec!["127.0.0.1:2".into()],
                pes: Vec::new(),
            },
        ];
        let mut registry = BackendRegistry::new();
        registry.register(BackendSpec::new("neon", || {
            Ok(Box::new(crate::accel::NativeGemm) as Box<dyn Accelerator>)
        }));
        registry.register(
            BackendSpec::new(
                &crate::accel::remote::shard_backend_name("127.0.0.1:2"),
                || Ok(Box::new(crate::accel::NativeGemm) as Box<dyn Accelerator>),
            )
            .caps(crate::accel::remote::remote_class_mask())
            .overhead_ksteps(crate::accel::remote::REMOTE_OVERHEAD_KSTEPS),
        );
        let mut options = PoolOptions::new(hw, ComputeMode::Native, false);
        options.registry = Some(Arc::new(registry));
        let pool = DelegatePool::start(&options).unwrap();
        let dispatcher = pool.dispatcher();

        // Alive: the placement hint onto the shard cluster is honored.
        assert_eq!(dispatcher.route(JobClass::ConvTile, Some(1)), Some(1));
        assert!(pool.routes()[1].accepts(JobClass::ConvTile));
        assert_eq!(pool.snapshot().evicted_members, 0);

        // Evict the shard member's link (what a dying delegate or a
        // failed probe does) — no further route attempts land there.
        assert!(pool.routes()[1].members()[0].link.evict());
        assert!(!pool.routes()[1].accepts(JobClass::ConvTile));
        assert_eq!(dispatcher.route(JobClass::ConvTile, Some(1)), Some(0));
        assert_eq!(dispatcher.route(JobClass::ConvTile, None), Some(0));
        assert!(pool.routes()[1]
            .class_overhead_s(JobClass::ConvTile.index())
            .is_infinite());
        assert_eq!(pool.snapshot().evicted_members, 1);

        // Jobs hinted at the dead cluster still execute, on the survivor.
        let w = Arc::new(XorShift64Star::new(51).fill_f32(8 * 16, 1.0));
        let x = Arc::new(XorShift64Star::new(52).fill_f32(16, 1.0));
        let id = dispatcher.reserve_job_ids(1);
        let job = Job::fc(id, 0, 0, 8, 16, Arc::clone(&w), Arc::clone(&x), 32).placed(Some(1));
        let y = dispatcher.execute_job(job).data;
        let mut want = vec![0.0f32; 8];
        crate::mm::gemm::gemm_blocked_into(&w, &x, &mut want, 8, 16, 1);
        assert_eq!(y, want);
        let report = pool.shutdown().unwrap();
        assert_eq!(report.evicted_members, 1);
        assert_eq!(report.inline_fallbacks, 0);
    }

    #[test]
    fn unknown_backend_key_fails_cleanly() {
        let mut options =
            PoolOptions::new(HwConfig::default_zc702(), ComputeMode::Native, false);
        // An empty registry knows no backend names at all.
        options.registry = Some(Arc::new(BackendRegistry::new()));
        let err = DelegatePool::start(&options).err().expect("must fail");
        assert!(err.to_string().contains("registry"), "{err}");
    }
}
