//! Cluster-configuration design-space exploration — produces the paper's
//! *static-mapping + custom-architecture* (SC) designs of Table 5.
//!
//! Paper §4.3: "In the SC designs, we find the best multi-cluster
//! configuration for each CNN model by exploring all possible cluster
//! configurations."  The resource pool is fixed (2 NEONs, 2 S-PE, 6 F-PE on
//! the ZC702); we enumerate every two-cluster partition, simulate the SC
//! design for the model, and keep the highest-throughput configuration.

use crate::accel::clusters_from_tuples;
use crate::config::HwConfig;
use crate::nn::Network;
use crate::sim::{simulate, SimSpec};

/// One candidate: (neon, s_pe, f_pe) per cluster.
pub type ClusterTuple = (usize, usize, usize);

/// Result of the exploration.
#[derive(Debug, Clone)]
pub struct DseResult {
    pub best: Vec<ClusterTuple>,
    pub best_fps: f64,
    pub evaluated: usize,
}

/// All two-cluster partitions of the pool (both clusters non-empty).
pub fn enumerate_two_cluster_configs(
    neons: usize,
    s_pes: usize,
    f_pes: usize,
) -> Vec<[ClusterTuple; 2]> {
    let mut out = Vec::new();
    for n0 in 0..=neons {
        for s0 in 0..=s_pes {
            for f0 in 0..=f_pes {
                let c0 = (n0, s0, f0);
                let c1 = (neons - n0, s_pes - s0, f_pes - f0);
                if n0 + s0 + f0 == 0 {
                    continue;
                }
                if c1.0 + c1.1 + c1.2 == 0 {
                    continue;
                }
                out.push([c0, c1]);
            }
        }
    }
    out
}

/// Explore all SC configurations for one model, return the best.
pub fn explore(net: &Network, frames: usize) -> DseResult {
    let hw = HwConfig::default_zc702();
    let pool = (hw.total_neons(), 2, 6); // 2 NEONs, 2 S-PE, 6 F-PE
    let configs = enumerate_two_cluster_configs(pool.0, pool.1, pool.2);
    let mut best: Option<(f64, [ClusterTuple; 2])> = None;
    for cfg in &configs {
        let clusters = clusters_from_tuples(&hw, &cfg[..]);
        let spec = SimSpec::static_custom(net, clusters, frames);
        let r = simulate(&spec, net);
        if best.map(|(fps, _)| r.fps > fps).unwrap_or(true) {
            best = Some((r.fps, *cfg));
        }
    }
    let (best_fps, best_cfg) = best.expect("at least one config");
    DseResult {
        best: best_cfg.to_vec(),
        best_fps,
        evaluated: configs.len(),
    }
}

/// Pretty-print a tuple like the paper's Table 5 rows.
pub fn describe_tuple(t: &ClusterTuple) -> String {
    let mut parts = Vec::new();
    if t.0 > 0 {
        parts.push(format!("{} NEON", t.0));
    }
    if t.1 > 0 {
        parts.push(format!("{} S-PE", t.1));
    }
    if t.2 > 0 {
        parts.push(format!("{} F-PE", t.2));
    }
    if parts.is_empty() {
        "-".into()
    } else {
        parts.join(" + ")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::zoo;

    #[test]
    fn enumeration_counts_and_validity() {
        let configs = enumerate_two_cluster_configs(2, 2, 6);
        // 3*3*7 = 63 total splits minus the two all-empty-side cases.
        assert_eq!(configs.len(), 61);
        for [c0, c1] in &configs {
            assert!(c0.0 + c0.1 + c0.2 > 0);
            assert!(c1.0 + c1.1 + c1.2 > 0);
            assert_eq!(c0.0 + c1.0, 2);
            assert_eq!(c0.1 + c1.1, 2);
            assert_eq!(c0.2 + c1.2, 6);
        }
    }

    #[test]
    fn explore_finds_config_at_least_as_good_as_default_sf() {
        let net = Network::new(zoo::load("cifar_alex").unwrap(), 32).unwrap();
        let dse = explore(&net, 12);
        assert_eq!(dse.evaluated, 61);
        // SC (best custom) must beat or match SF (the default split).
        let sf = simulate(&SimSpec::static_fixed(&net, 12), &net);
        assert!(
            dse.best_fps >= sf.fps * 0.999,
            "SC {} < SF {}",
            dse.best_fps,
            sf.fps
        );
    }

    #[test]
    fn describe_tuples() {
        assert_eq!(describe_tuple(&(2, 0, 4)), "2 NEON + 4 F-PE");
        assert_eq!(describe_tuple(&(0, 2, 2)), "2 S-PE + 2 F-PE");
        assert_eq!(describe_tuple(&(0, 0, 0)), "-");
    }
}
