//! The Synergy work-stealing scheduler (paper §3.1.3 / Fig 4).
//!
//! A dedicated *thief thread* hosts three roles:
//! * **manager** — receives idle notifications from clusters and keeps the
//!   *idle book*;
//! * **idle book** — the set of clusters that drained their job queues;
//! * **stealer** — takes jobs from the back of the heaviest victim queue
//!   and deposits them into an idle cluster's queue, then clears the
//!   idle-book entry.
//!
//! With per-class sub-queue banks ([`QueueBank`]) the thief works
//! **per sub-queue**: victim backlogs are snapshot per class (O(classes)
//! per queue — the bank keeps the counts), ranked by the *stealable*
//! cost-weighted backlog — only the classes the **idle member** that
//! reported can execute ([`ThiefMsg::ClusterIdle`] carries its mask) —
//! divided by the victim's service rate (paper §3.3: heterogeneous
//! clusters drain at different speeds, so raw queue length misranks
//! victims).  Steals then pull from the backs of exactly those
//! sub-queues: a CONV-only member never receives an FC job, and FC work
//! is never parked behind a cluster whose FC-capable members are busy.
//!
//! The same victim-selection policy is reused by the virtual-clock
//! simulator (`choose_victim`/`choose_victim_weighted` are pure functions).

use std::collections::HashSet;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{self, RecvTimeoutError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use crate::cluster::QueueBank;
use crate::mm::job::{ClassMask, JobClass};
pub use crate::mm::job::Classed;

/// Live per-destination shipping cost: `ship(cluster, class_index)` returns
/// the seconds it costs to move one job of that class into that cluster
/// *right now*.  The pool backs this with [`ClusterRoute::class_overhead_s`]
/// so measured RTT probes and shard eviction reach the thief without a
/// respawn: a dead remote destination answers `f64::INFINITY` and every
/// class is pruned from its steal mask.
pub type ShipCostFn = Arc<dyn Fn(usize, usize) -> f64 + Send + Sync>;

/// Messages from cluster workers to the thief's manager.
#[derive(Debug, PartialEq, Eq)]
pub enum ThiefMsg {
    /// A member of cluster `.0` found nothing it can execute; `.1` is that
    /// member's capability mask.  The thief steals only classes the idle
    /// member itself can run — pulling, say, FC work into a cluster whose
    /// only FC-capable member is busy would add latency, not parallelism.
    ClusterIdle(usize, ClassMask),
    /// Cluster `idx` got fresh local work (e.g. a layer enqueued jobs).
    ClusterBusy(usize),
    Shutdown,
}

/// Steal accounting (shared, lock-free).
#[derive(Debug, Default)]
pub struct StealStats {
    pub attempts: AtomicU64,
    pub successes: AtomicU64,
    pub jobs_moved: AtomicU64,
    /// Jobs moved per class ([`JobClass`] dense order).
    pub moved_by_class: [AtomicU64; JobClass::COUNT],
}

impl StealStats {
    pub fn snapshot(&self) -> (u64, u64, u64) {
        (
            self.attempts.load(Ordering::Relaxed),
            self.successes.load(Ordering::Relaxed),
            self.jobs_moved.load(Ordering::Relaxed),
        )
    }

    /// Per-class moved-job counters.
    pub fn moved_by_class(&self) -> [u64; JobClass::COUNT] {
        let mut out = [0u64; JobClass::COUNT];
        for (o, c) in out.iter_mut().zip(&self.moved_by_class) {
            *o = c.load(Ordering::Relaxed);
        }
        out
    }
}

/// Tunables for the stealer pass.
///
/// With the serving front-end, queues fill in *batch granularity*: a
/// micro-batch of B requests deposits all its jobs in one `push_batch`.
/// A thief tuned for single-frame streams (steal whenever a victim holds
/// ≥2 jobs) would ping-pong half-batches between clusters, so the idle
/// book's stealer threshold scales with the expected batch job count.
///
/// `class_cost` weighs each job class when ranking victims: an FC-GEMM
/// job is a whole layer's GEMM while a CONV-tile job is one output tile,
/// so equal queue lengths do not mean equal backlogs.  The weights are
/// approximate per-job k-steps, so a cost-weighted backlog divided by a
/// cluster's k-steps/s service rate is a time-to-drain in seconds — the
/// unit the destination shipping costs of [`Thief::spawn_with_costs`]
/// gate against.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StealPolicy {
    /// Minimum victim queue length worth stealing from.
    pub min_victim_len: usize,
    /// Relative service cost of one job per class ([`JobClass`] dense
    /// order: CONV-tile, FC-GEMM, im2col, fused batched FC-GEMM, then
    /// their int8 (Q8) twins).
    pub class_cost: [f64; JobClass::COUNT],
}

/// Default per-class cost weights, DERIVED from
/// [`JobClass::default_steal_cost`] so adding a job class cannot leave the
/// thief with a stale hand-written table: an FC GEMM carries a few tiles'
/// worth of MACs; im2col is pure data movement; a fused batched FC carries
/// a micro-batch's worth of FC columns; the int8 twins cost roughly half
/// their f32 siblings (integer kernel, 4× smaller operands).
pub const DEFAULT_CLASS_COST: [f64; JobClass::COUNT] = {
    let mut cost = [0.0f64; JobClass::COUNT];
    let mut i = 0;
    while i < JobClass::COUNT {
        cost[i] = JobClass::ALL[i].default_steal_cost();
        i += 1;
    }
    cost
};

// The derived table must cover exactly the job-class universe — a compile
// error here means `JobClass::ALL` and `JobClass::COUNT` diverged.
const _: () = assert!(DEFAULT_CLASS_COST.len() == JobClass::ALL.len());

impl Default for StealPolicy {
    fn default() -> Self {
        StealPolicy {
            min_victim_len: 2,
            class_cost: DEFAULT_CLASS_COST,
        }
    }
}

impl StealPolicy {
    /// Policy for batched serving: only steal once a victim holds at least
    /// half a batch's worth of jobs (and never less than the default 2).
    pub fn batched(jobs_per_batch: usize) -> Self {
        StealPolicy {
            min_victim_len: (jobs_per_batch / 2).max(2),
            ..StealPolicy::default()
        }
    }
}

/// Pick the victim: the non-idle cluster with the longest queue (must have
/// at least `min_len` jobs, so we don't ping-pong single jobs).
pub fn choose_victim(queue_lens: &[usize], idle: &HashSet<usize>, min_len: usize) -> Option<usize> {
    queue_lens
        .iter()
        .enumerate()
        .filter(|(i, &len)| !idle.contains(i) && len >= min_len)
        .max_by_key(|(_, &len)| len)
        .map(|(i, _)| i)
}

/// Service-rate-aware victim pick: rank eligible clusters (non-idle, at
/// least `min_len` queued jobs) by `loads` — the cost-weighted backlog
/// divided by the cluster's service rate, i.e. estimated time-to-drain.
pub fn choose_victim_weighted(
    queue_lens: &[usize],
    loads: &[f64],
    idle: &HashSet<usize>,
    min_len: usize,
) -> Option<usize> {
    debug_assert_eq!(queue_lens.len(), loads.len());
    queue_lens
        .iter()
        .enumerate()
        .filter(|(i, &len)| !idle.contains(i) && len >= min_len)
        .max_by(|(a, _), (b, _)| {
            loads[*a]
                .partial_cmp(&loads[*b])
                .unwrap_or(std::cmp::Ordering::Equal)
        })
        .map(|(i, _)| i)
}

/// How many jobs to move: half the victim's queue (classic steal-half).
pub fn steal_amount(victim_len: usize) -> usize {
    victim_len.div_ceil(2)
}

/// The running thief thread.
pub struct Thief<T: Send + 'static> {
    tx: mpsc::Sender<ThiefMsg>,
    handle: Option<JoinHandle<()>>,
    pub stats: Arc<StealStats>,
    _marker: std::marker::PhantomData<T>,
}

impl<T: Send + Classed + 'static> Thief<T> {
    /// Spawn the thief over the cluster queue banks (default policy, every
    /// cluster assumed capable of every job class).
    pub fn spawn(queues: Vec<Arc<QueueBank<T>>>) -> Thief<T> {
        Self::spawn_with(queues, StealPolicy::default())
    }

    /// Spawn the thief with an explicit steal policy (the serving runtime
    /// passes [`StealPolicy::batched`]).
    pub fn spawn_with(queues: Vec<Arc<QueueBank<T>>>, policy: StealPolicy) -> Thief<T> {
        let n = queues.len();
        Self::spawn_with_caps(queues, policy, vec![ClassMask::all(); n], vec![1.0; n])
    }

    /// Per-cluster accept masks + service rates, no shipping costs (every
    /// destination is local).  See [`Thief::spawn_with_costs`].
    pub fn spawn_with_caps(
        queues: Vec<Arc<QueueBank<T>>>,
        policy: StealPolicy,
        caps: Vec<ClassMask>,
        service_rates: Vec<f64>,
    ) -> Thief<T> {
        Self::spawn_with_costs(queues, policy, caps, service_rates, Arc::new(|_, _| 0.0))
    }

    /// Fully-specified spawn: per-cluster *accept* masks (the union of the
    /// destination's member capabilities — stolen jobs are filtered so a
    /// destination only receives classes some member can execute), service
    /// rates (aggregate k-steps/s, normalizing victim backlogs across
    /// heterogeneous clusters), and a live **per-class shipping cost**
    /// function ([`ShipCostFn`]): `ship_s(cluster, class)` is the fixed
    /// cost in seconds of moving a job of that class into that destination
    /// — the cheapest capable member's link overhead, i.e.
    /// `ClusterRoute::class_overhead_s`, re-read on every stealer pass so
    /// measured RTT probes tighten or widen the gate while the thief runs.
    /// This is where `Accelerator::cost`'s constant term finally meets
    /// the stealer: a class whose heaviest victim backlog drains faster
    /// than this destination ships it is pruned from the steal mask (a
    /// remote shard's round trip keeps small fused-FC backlogs local even
    /// when a zero-cost CONV member shares its cluster), while zero-cost
    /// answers (local clusters) keep the classic behavior and an evicted
    /// shard's `INFINITY` removes it as a destination entirely.
    pub fn spawn_with_costs(
        queues: Vec<Arc<QueueBank<T>>>,
        policy: StealPolicy,
        caps: Vec<ClassMask>,
        service_rates: Vec<f64>,
        ship_s: ShipCostFn,
    ) -> Thief<T> {
        assert_eq!(queues.len(), caps.len());
        assert_eq!(queues.len(), service_rates.len());
        let (tx, rx) = mpsc::channel::<ThiefMsg>();
        let stats = Arc::new(StealStats::default());
        let st = Arc::clone(&stats);
        // lint: allow(thread-spawn): the thief IS the work-stealing
        // balancer the containment rule routes everything else through.
        let handle = std::thread::Builder::new()
            .name("thief".into())
            .spawn(move || thief_loop(queues, rx, st, policy, caps, service_rates, ship_s))
            .expect("spawn thief");
        Thief {
            tx,
            handle: Some(handle),
            stats,
            _marker: std::marker::PhantomData,
        }
    }
}

impl<T: Send + 'static> Thief<T> {
    /// Handle for workers to report idleness.
    pub fn sender(&self) -> mpsc::Sender<ThiefMsg> {
        self.tx.clone()
    }

    pub fn shutdown(mut self) {
        let _ = self.tx.send(ThiefMsg::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl<T: Send + 'static> Drop for Thief<T> {
    fn drop(&mut self) {
        let _ = self.tx.send(ThiefMsg::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn thief_loop<T: Send + Classed>(
    queues: Vec<Arc<QueueBank<T>>>,
    rx: mpsc::Receiver<ThiefMsg>,
    stats: Arc<StealStats>,
    policy: StealPolicy,
    caps: Vec<ClassMask>,
    service_rates: Vec<f64>,
    ship_s: ShipCostFn,
) {
    // cluster → union of the capability masks of its members that have
    // reported idle (cleared on local work or a successful deposit).
    let mut idle_book: std::collections::HashMap<usize, ClassMask> =
        std::collections::HashMap::new();
    loop {
        // Wait for a notification (or poll the idle book periodically: a
        // victim may have become stealable after the idle report).
        let msg = if idle_book.is_empty() {
            match rx.recv() {
                Ok(m) => Some(m),
                Err(_) => return,
            }
        } else {
            match rx.recv_timeout(Duration::from_micros(200)) {
                Ok(m) => Some(m),
                Err(RecvTimeoutError::Timeout) => None,
                Err(RecvTimeoutError::Disconnected) => return,
            }
        };
        match msg {
            Some(ThiefMsg::Shutdown) => return,
            Some(ThiefMsg::ClusterIdle(c, mask)) => {
                if c < queues.len() {
                    idle_book
                        .entry(c)
                        .and_modify(|m| *m = m.union(mask))
                        .or_insert(mask);
                }
            }
            Some(ThiefMsg::ClusterBusy(c)) => {
                idle_book.remove(&c);
            }
            None => {}
        }
        // Nothing idle → nothing to steal: skip the per-class backlog
        // snapshot (it locks every queue and walks every queued job, far
        // too expensive to run on each ClusterBusy ping under load).
        if idle_book.is_empty() {
            continue;
        }
        // Stealer pass: service every idle cluster we can.  Sub-queue
        // occupancies are snapshot per class (cheap — the bank keeps the
        // counts) and, *per destination*, reduced to the stealable backlog:
        // only the classes the reporting idle members can execute (their
        // mask unions, intersected with the cluster accept mask as a
        // safety net), weighted by service cost and normalized by each
        // victim's drain rate.
        let counts: Vec<[usize; JobClass::COUNT]> =
            queues.iter().map(|q| q.class_counts()).collect();
        let served: Vec<(usize, ClassMask)> =
            idle_book.iter().map(|(&c, &m)| (c, m)).collect();
        for (idle_c, idle_mask) in served {
            stats.attempts.fetch_add(1, Ordering::Relaxed);
            let mut cap = caps[idle_c].intersect(idle_mask);
            // Class-level ship gate: moving a job of class `i` into this
            // destination costs `ship_s(idle_c, i)` seconds (a remote
            // member's *measured* transport round trip; 0 for local
            // members; INFINITY once the link is evicted).  A class whose
            // HEAVIEST victim backlog drains in place faster than it
            // ships is pruned from the steal mask — per class, so a cheap
            // local CONV member sharing a cluster with a remote fused-FC
            // member doesn't zero the fused-FC gate.
            for class in JobClass::ALL {
                let i = class.index();
                if !cap.supports_index(i) {
                    continue;
                }
                let ship = ship_s(idle_c, i);
                if ship <= 0.0 {
                    continue;
                }
                let heaviest = counts
                    .iter()
                    .zip(&service_rates)
                    .enumerate()
                    .filter(|(v, _)| *v != idle_c)
                    .map(|(_, (c, rate))| {
                        c[i] as f64 * policy.class_cost[i] / rate.max(1e-12)
                    })
                    .fold(0.0f64, f64::max);
                if heaviest <= ship {
                    cap = cap.without(class);
                }
            }
            if cap.is_empty() {
                continue;
            }
            let stealable: Vec<usize> = counts
                .iter()
                .map(|c| {
                    c.iter()
                        .enumerate()
                        .filter(|(i, _)| cap.supports_index(*i))
                        .map(|(_, &n)| n)
                        .sum()
                })
                .collect();
            let loads: Vec<f64> = counts
                .iter()
                .zip(&service_rates)
                .map(|(c, rate)| {
                    let weighted: f64 = c
                        .iter()
                        .enumerate()
                        .filter(|(i, _)| cap.supports_index(*i))
                        .map(|(i, &n)| n as f64 * policy.class_cost[i])
                        .sum();
                    weighted / rate.max(1e-12)
                })
                .collect();
            // Walk victims in descending time-to-drain order: the snapshot
            // may be stale (a victim drained since), so an empty steal
            // must not block stealing from the next-heaviest one.  Only
            // the destination excludes itself: an idle-book entry no
            // longer implies an empty bank (a mixed cluster's PE reports
            // idle while the FC sub-queue is deep), so other idle-book
            // residents stay eligible as victims — the mask-filtered
            // stealable counts weed out the futile ones.
            let mut excluded = HashSet::from([idle_c]);
            while let Some(victim) =
                choose_victim_weighted(&stealable, &loads, &excluded, policy.min_victim_len)
            {
                let n = steal_amount(stealable[victim]);
                let stolen = queues[victim].steal_where(n, cap);
                if stolen.is_empty() {
                    excluded.insert(victim);
                    continue;
                }
                let moved = stolen.len() as u64;
                let mut by_class = [0u64; JobClass::COUNT];
                for t in &stolen {
                    let i = t.class_index();
                    if i < JobClass::COUNT {
                        by_class[i] += 1;
                    }
                }
                if queues[idle_c].push_batch(stolen) {
                    stats.successes.fetch_add(1, Ordering::Relaxed);
                    stats.jobs_moved.fetch_add(moved, Ordering::Relaxed);
                    for (ctr, n) in stats.moved_by_class.iter().zip(by_class) {
                        ctr.fetch_add(n, Ordering::Relaxed);
                    }
                    idle_book.remove(&idle_c);
                }
                break;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn victim_is_longest_non_idle() {
        let lens = vec![0, 5, 3];
        let mut idle = HashSet::new();
        idle.insert(0);
        assert_eq!(choose_victim(&lens, &idle, 2), Some(1));
        idle.insert(1);
        assert_eq!(choose_victim(&lens, &idle, 2), Some(2));
        idle.insert(2);
        assert_eq!(choose_victim(&lens, &idle, 2), None);
    }

    #[test]
    fn victim_respects_min_len() {
        let lens = vec![1, 1];
        let idle = HashSet::new();
        assert_eq!(choose_victim(&lens, &idle, 2), None);
        let v = choose_victim(&lens, &idle, 1);
        assert!(v == Some(0) || v == Some(1));
    }

    #[test]
    fn weighted_victim_respects_service_rates() {
        // Cluster 0: 6 jobs but drains 10× faster than cluster 1's 4 jobs.
        let lens = vec![6, 4];
        let loads = vec![6.0 / 10.0, 4.0 / 1.0];
        let idle = HashSet::new();
        assert_eq!(choose_victim_weighted(&lens, &loads, &idle, 2), Some(1));
        // Raw-length selection would have picked cluster 0.
        assert_eq!(choose_victim(&lens, &idle, 2), Some(0));
    }

    #[test]
    fn weighted_victim_skips_idle_and_short() {
        let lens = vec![5, 1, 5];
        let loads = vec![1.0, 99.0, 2.0];
        let mut idle = HashSet::new();
        idle.insert(2);
        // Cluster 1 is below min_len, cluster 2 is idle → cluster 0.
        assert_eq!(choose_victim_weighted(&lens, &loads, &idle, 2), Some(0));
    }

    #[test]
    fn steal_half() {
        assert_eq!(steal_amount(0), 0);
        assert_eq!(steal_amount(1), 1);
        assert_eq!(steal_amount(7), 4);
        assert_eq!(steal_amount(8), 4);
    }

    #[test]
    fn thief_moves_jobs_to_idle_cluster() {
        let q0: Arc<QueueBank<u32>> = Arc::new(QueueBank::new());
        let q1: Arc<QueueBank<u32>> = Arc::new(QueueBank::new());
        for i in 0..10 {
            q1.push(i);
        }
        let thief = Thief::spawn(vec![Arc::clone(&q0), Arc::clone(&q1)]);
        thief.sender().send(ThiefMsg::ClusterIdle(0, ClassMask::all())).unwrap();
        // Wait for the stealer to act.
        let deadline = std::time::Instant::now() + Duration::from_secs(2);
        while q0.is_empty() && std::time::Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(1));
        }
        assert!(!q0.is_empty(), "thief should have moved jobs");
        let (att, succ, moved) = thief.stats.snapshot();
        assert!(att >= 1 && succ >= 1 && moved >= 1);
        // Per-class accounting balances the total (u32 ⇒ class 0).
        let by_class = thief.stats.moved_by_class();
        assert_eq!(by_class.iter().sum::<u64>(), moved);
        assert_eq!(by_class[0], moved);
        // No duplication, no loss.
        assert_eq!(q0.len() + q1.len(), 10);
        thief.shutdown();
    }

    /// A test job type spanning two classes.
    struct CJob(#[allow(dead_code)] u32, usize);
    impl Classed for CJob {
        fn class_index(&self) -> usize {
            self.1
        }
    }

    #[test]
    fn capability_mask_filters_stolen_classes() {
        let q0: Arc<QueueBank<CJob>> = Arc::new(QueueBank::new());
        let q1: Arc<QueueBank<CJob>> = Arc::new(QueueBank::new());
        // Victim holds a mix of CONV-tile (0) and FC (1) jobs.
        for i in 0..6 {
            q1.push(CJob(i, (i % 2) as usize));
        }
        // Destination cluster 0 only accepts CONV tiles.
        let thief = Thief::spawn_with_caps(
            vec![Arc::clone(&q0), Arc::clone(&q1)],
            StealPolicy::default(),
            vec![ClassMask::of(&[JobClass::ConvTile]), ClassMask::all()],
            vec![1.0, 1.0],
        );
        thief.sender().send(ThiefMsg::ClusterIdle(0, ClassMask::all())).unwrap();
        let deadline = std::time::Instant::now() + Duration::from_secs(2);
        while q0.is_empty() && std::time::Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(1));
        }
        thief.shutdown();
        assert!(!q0.is_empty(), "thief should have moved CONV jobs");
        // Everything deposited on cluster 0 is CONV-class.
        q0.close();
        while let Some(j) = q0.try_pop_any(ClassMask::all()) {
            assert_eq!(j.class_index(), 0, "FC job stolen into CONV-only cluster");
        }
        // No FC job left cluster 1.
        assert_eq!(
            q1.class_counts()[1], 3,
            "FC jobs must stay on the capable cluster"
        );
    }

    #[test]
    fn thief_falls_back_past_unstealable_victims() {
        // Victim 1 ranks heaviest by raw length (all FC jobs) but holds
        // nothing the CONV-only destination accepts — its *stealable*
        // backlog is zero, so the per-sub-queue selection must go straight
        // to victim 2's CONV backlog instead of starving cluster 0.
        let q0: Arc<QueueBank<CJob>> = Arc::new(QueueBank::new());
        let q1: Arc<QueueBank<CJob>> = Arc::new(QueueBank::new());
        let q2: Arc<QueueBank<CJob>> = Arc::new(QueueBank::new());
        for i in 0..8 {
            q1.push(CJob(i, 1)); // FC class
        }
        for i in 0..4 {
            q2.push(CJob(i, 0)); // CONV class
        }
        let thief = Thief::spawn_with_caps(
            vec![Arc::clone(&q0), Arc::clone(&q1), Arc::clone(&q2)],
            StealPolicy::default(),
            vec![
                ClassMask::of(&[JobClass::ConvTile]),
                ClassMask::all(),
                ClassMask::all(),
            ],
            vec![1.0, 1.0, 1.0],
        );
        thief.sender().send(ThiefMsg::ClusterIdle(0, ClassMask::all())).unwrap();
        let deadline = std::time::Instant::now() + Duration::from_secs(2);
        while q0.is_empty() && std::time::Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(1));
        }
        thief.shutdown();
        assert!(!q0.is_empty(), "thief starved behind an unstealable victim");
        q0.close();
        while let Some(j) = q0.try_pop_any(ClassMask::all()) {
            assert_eq!(j.class_index(), 0);
        }
        assert_eq!(q1.len(), 8, "FC backlog must be untouched");
    }

    #[test]
    fn idle_book_residents_with_stealable_backlog_are_still_robbed() {
        // Cluster 1's CONV-only member reports idle while the cluster's
        // FC backlog is deep — an idle-book entry no longer implies an
        // empty bank, so cluster 0's idle NEON must still rob cluster 1
        // (regression: excluding every idle-book cluster as a victim).
        let q0: Arc<QueueBank<CJob>> = Arc::new(QueueBank::new());
        let q1: Arc<QueueBank<CJob>> = Arc::new(QueueBank::new());
        for i in 0..6 {
            q1.push(CJob(i, 1)); // FC backlog
        }
        let thief = Thief::spawn_with_caps(
            vec![Arc::clone(&q0), Arc::clone(&q1)],
            StealPolicy::default(),
            vec![ClassMask::all(), ClassMask::all()],
            vec![1.0, 1.0],
        );
        let conv_only = ClassMask::of(&[JobClass::ConvTile]);
        thief.sender().send(ThiefMsg::ClusterIdle(1, conv_only)).unwrap();
        thief
            .sender()
            .send(ThiefMsg::ClusterIdle(0, ClassMask::all()))
            .unwrap();
        let deadline = std::time::Instant::now() + Duration::from_secs(2);
        while q0.is_empty() && std::time::Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(1));
        }
        thief.shutdown();
        assert!(
            !q0.is_empty(),
            "idle-book exclusion starved a capable idle member"
        );
        assert_eq!(q0.len() + q1.len(), 6, "no loss, no duplication");
    }

    #[test]
    fn steal_filter_honors_idle_members_mask_not_cluster_union() {
        // Destination cluster 0 ACCEPTS everything (it has some FC-capable
        // member), but the member reporting idle is CONV-only — the thief
        // must not park FC work behind cluster 0's busy FC members.
        let q0: Arc<QueueBank<CJob>> = Arc::new(QueueBank::new());
        let q1: Arc<QueueBank<CJob>> = Arc::new(QueueBank::new());
        for i in 0..4 {
            q1.push(CJob(i, 0)); // CONV
        }
        for i in 0..4 {
            q1.push(CJob(10 + i, 1)); // FC
        }
        let thief = Thief::spawn_with_caps(
            vec![Arc::clone(&q0), Arc::clone(&q1)],
            StealPolicy::default(),
            vec![ClassMask::all(), ClassMask::all()],
            vec![1.0, 1.0],
        );
        thief
            .sender()
            .send(ThiefMsg::ClusterIdle(
                0,
                ClassMask::of(&[JobClass::ConvTile]),
            ))
            .unwrap();
        let deadline = std::time::Instant::now() + Duration::from_secs(2);
        while q0.is_empty() && std::time::Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(1));
        }
        thief.shutdown();
        assert!(!q0.is_empty(), "thief should have moved CONV jobs");
        q0.close();
        while let Some(j) = q0.try_pop_any(ClassMask::all()) {
            assert_eq!(j.class_index(), 0, "stole outside the idle member's mask");
        }
        assert_eq!(q1.class_counts()[1], 4, "FC backlog must stay put");
    }

    #[test]
    fn ship_gate_keeps_small_backlogs_off_expensive_destinations() {
        // Destination 0 models a remote shard: stealable work must beat a
        // shipping cost before the thief moves it.  6 conv jobs at unit
        // cost / unit rate = 6 s of backlog.
        let mk = || -> (Arc<QueueBank<u32>>, Arc<QueueBank<u32>>) {
            let q0: Arc<QueueBank<u32>> = Arc::new(QueueBank::new());
            let q1: Arc<QueueBank<u32>> = Arc::new(QueueBank::new());
            for i in 0..6 {
                q1.push(i);
            }
            (q0, q1)
        };

        // Gate above the backlog: nothing moves, ever.
        let (q0, q1) = mk();
        let thief = Thief::spawn_with_costs(
            vec![Arc::clone(&q0), Arc::clone(&q1)],
            StealPolicy::default(),
            vec![ClassMask::all(), ClassMask::all()],
            vec![1.0, 1.0],
            Arc::new(|c, _| if c == 0 { 100.0 } else { 0.0 }),
        );
        thief
            .sender()
            .send(ThiefMsg::ClusterIdle(0, ClassMask::all()))
            .unwrap();
        std::thread::sleep(Duration::from_millis(30));
        assert!(q0.is_empty(), "stole a backlog cheaper than shipping it");
        assert_eq!(q1.len(), 6);
        thief.shutdown();

        // Gate below the backlog: the steal happens as usual.
        let (q0, q1) = mk();
        let thief = Thief::spawn_with_costs(
            vec![Arc::clone(&q0), Arc::clone(&q1)],
            StealPolicy::default(),
            vec![ClassMask::all(), ClassMask::all()],
            vec![1.0, 1.0],
            Arc::new(|c, _| if c == 0 { 2.5 } else { 0.0 }),
        );
        thief
            .sender()
            .send(ThiefMsg::ClusterIdle(0, ClassMask::all()))
            .unwrap();
        let deadline = std::time::Instant::now() + Duration::from_secs(2);
        while q0.is_empty() && std::time::Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(1));
        }
        assert!(!q0.is_empty(), "backlog above the ship gate must move");
        assert_eq!(q0.len() + q1.len(), 6);
        thief.shutdown();
    }

    /// The gate is per class: a destination whose CONV member is local
    /// (free shipping) but whose fused-FC member is remote must keep
    /// stealing CONV work while leaving the fused-FC backlog in place.
    #[test]
    fn ship_gate_is_class_level_in_mixed_destinations() {
        let q0: Arc<QueueBank<CJob>> = Arc::new(QueueBank::new());
        let q1: Arc<QueueBank<CJob>> = Arc::new(QueueBank::new());
        for i in 0..6 {
            q1.push(CJob(i, JobClass::ConvTile.index()));
        }
        for i in 0..6 {
            q1.push(CJob(10 + i, JobClass::FcGemmBatch.index()));
        }
        let mut ship = [0.0; JobClass::COUNT];
        ship[JobClass::FcGemmBatch.index()] = 1e9; // remote-only class
        let thief = Thief::spawn_with_costs(
            vec![Arc::clone(&q0), Arc::clone(&q1)],
            StealPolicy::default(),
            vec![ClassMask::all(), ClassMask::all()],
            vec![1.0, 1.0],
            Arc::new(move |c, i| if c == 0 { ship[i] } else { 0.0 }),
        );
        thief
            .sender()
            .send(ThiefMsg::ClusterIdle(0, ClassMask::all()))
            .unwrap();
        let deadline = std::time::Instant::now() + Duration::from_secs(2);
        while q0.is_empty() && std::time::Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(1));
        }
        thief.shutdown();
        assert!(!q0.is_empty(), "free-shipping CONV work must still move");
        q0.close();
        while let Some(j) = q0.try_pop_any(ClassMask::all()) {
            assert_eq!(
                j.class_index(),
                JobClass::ConvTile.index(),
                "a gated class crossed the ship gate"
            );
        }
        assert_eq!(
            q1.class_counts()[JobClass::FcGemmBatch.index()],
            6,
            "the expensive class must stay local"
        );
    }

    /// The ship cost is a *live* function, re-read on every stealer pass:
    /// a destination that starts evicted (INFINITY — nothing may ship)
    /// must begin stealing the moment its link comes back cheap, without
    /// respawning the thief.
    #[test]
    fn ship_gate_is_live_and_infinity_blocks_all_classes() {
        use std::sync::atomic::AtomicBool;
        let q0: Arc<QueueBank<u32>> = Arc::new(QueueBank::new());
        let q1: Arc<QueueBank<u32>> = Arc::new(QueueBank::new());
        for i in 0..6 {
            q1.push(i);
        }
        let dead = Arc::new(AtomicBool::new(true));
        let gate = Arc::clone(&dead);
        let thief = Thief::spawn_with_costs(
            vec![Arc::clone(&q0), Arc::clone(&q1)],
            StealPolicy::default(),
            vec![ClassMask::all(), ClassMask::all()],
            vec![1.0, 1.0],
            Arc::new(move |c, _| {
                if c == 0 && gate.load(Ordering::SeqCst) {
                    f64::INFINITY
                } else {
                    0.0
                }
            }),
        );
        thief
            .sender()
            .send(ThiefMsg::ClusterIdle(0, ClassMask::all()))
            .unwrap();
        std::thread::sleep(Duration::from_millis(30));
        assert!(q0.is_empty(), "stole toward an evicted destination");
        // Link recovers: the same idle-book entry must now be served.
        dead.store(false, Ordering::SeqCst);
        thief
            .sender()
            .send(ThiefMsg::ClusterIdle(0, ClassMask::all()))
            .unwrap();
        let deadline = std::time::Instant::now() + Duration::from_secs(2);
        while q0.is_empty() && std::time::Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(1));
        }
        assert!(!q0.is_empty(), "revived destination never stole");
        assert_eq!(q0.len() + q1.len(), 6);
        thief.shutdown();
    }

    #[test]
    fn default_class_cost_is_derived_per_class() {
        for class in JobClass::ALL {
            assert_eq!(
                DEFAULT_CLASS_COST[class.index()],
                class.default_steal_cost(),
                "{class:?}"
            );
        }
        // The int8 twins move cheaper than their f32 siblings.
        for (q8, f32c) in [
            (JobClass::ConvTileQ8, JobClass::ConvTile),
            (JobClass::FcGemmQ8, JobClass::FcGemm),
            (JobClass::FcGemmBatchQ8, JobClass::FcGemmBatch),
        ] {
            assert!(DEFAULT_CLASS_COST[q8.index()] < DEFAULT_CLASS_COST[f32c.index()]);
        }
    }

    #[test]
    fn batched_policy_scales_threshold() {
        assert_eq!(StealPolicy::default().min_victim_len, 2);
        assert_eq!(StealPolicy::batched(1).min_victim_len, 2);
        assert_eq!(StealPolicy::batched(16).min_victim_len, 8);
        assert_eq!(StealPolicy::batched(16).class_cost, DEFAULT_CLASS_COST);
    }

    #[test]
    fn batched_policy_thief_leaves_small_victims_alone() {
        let q0: Arc<QueueBank<u32>> = Arc::new(QueueBank::new());
        let q1: Arc<QueueBank<u32>> = Arc::new(QueueBank::new());
        for i in 0..4 {
            q1.push(i);
        }
        // Threshold 8: a 4-deep victim is half a batch — not worth moving.
        let thief = Thief::spawn_with(
            vec![Arc::clone(&q0), Arc::clone(&q1)],
            StealPolicy::batched(16),
        );
        thief.sender().send(ThiefMsg::ClusterIdle(0, ClassMask::all())).unwrap();
        std::thread::sleep(Duration::from_millis(20));
        assert!(q0.is_empty(), "thief stole below the batch threshold");
        assert_eq!(q1.len(), 4);
        thief.shutdown();
    }

    #[test]
    fn thief_ignores_out_of_range_and_shuts_down() {
        let q0: Arc<QueueBank<u32>> = Arc::new(QueueBank::new());
        let thief = Thief::spawn(vec![Arc::clone(&q0)]);
        thief.sender().send(ThiefMsg::ClusterIdle(99, ClassMask::all())).unwrap();
        thief.sender().send(ThiefMsg::ClusterBusy(0)).unwrap();
        std::thread::sleep(Duration::from_millis(5));
        thief.shutdown(); // must not hang
    }
}
