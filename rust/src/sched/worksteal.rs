//! The Synergy work-stealing scheduler (paper §3.1.3 / Fig 4).
//!
//! A dedicated *thief thread* hosts three roles:
//! * **manager** — receives idle notifications from clusters and keeps the
//!   *idle book*;
//! * **idle book** — the set of clusters that drained their job queues;
//! * **stealer** — takes jobs from the back of the busiest victim queue and
//!   deposits them into an idle cluster's queue, then clears the idle-book
//!   entry.
//!
//! The same victim-selection policy is reused by the virtual-clock
//! simulator (`choose_victim` is a pure function).

use std::collections::HashSet;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{self, RecvTimeoutError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use crate::cluster::JobQueue;

/// Messages from cluster workers to the thief's manager.
#[derive(Debug, PartialEq, Eq)]
pub enum ThiefMsg {
    /// Cluster `idx` found its queue empty.
    ClusterIdle(usize),
    /// Cluster `idx` got fresh local work (e.g. a layer enqueued jobs).
    ClusterBusy(usize),
    Shutdown,
}

/// Steal accounting (shared, lock-free).
#[derive(Debug, Default)]
pub struct StealStats {
    pub attempts: AtomicU64,
    pub successes: AtomicU64,
    pub jobs_moved: AtomicU64,
}

impl StealStats {
    pub fn snapshot(&self) -> (u64, u64, u64) {
        (
            self.attempts.load(Ordering::Relaxed),
            self.successes.load(Ordering::Relaxed),
            self.jobs_moved.load(Ordering::Relaxed),
        )
    }
}

/// Tunables for the stealer pass.
///
/// With the serving front-end, queues fill in *batch granularity*: a
/// micro-batch of B requests deposits all its jobs in one `push_batch`.
/// A thief tuned for single-frame streams (steal whenever a victim holds
/// ≥2 jobs) would ping-pong half-batches between clusters, so the idle
/// book's stealer threshold scales with the expected batch job count.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StealPolicy {
    /// Minimum victim queue length worth stealing from.
    pub min_victim_len: usize,
}

impl Default for StealPolicy {
    fn default() -> Self {
        StealPolicy { min_victim_len: 2 }
    }
}

impl StealPolicy {
    /// Policy for batched serving: only steal once a victim holds at least
    /// half a batch's worth of jobs (and never less than the default 2).
    pub fn batched(jobs_per_batch: usize) -> Self {
        StealPolicy {
            min_victim_len: (jobs_per_batch / 2).max(2),
        }
    }
}

/// Pick the victim: the non-idle cluster with the longest queue (must have
/// at least `min_len` jobs, so we don't ping-pong single jobs).
pub fn choose_victim(queue_lens: &[usize], idle: &HashSet<usize>, min_len: usize) -> Option<usize> {
    queue_lens
        .iter()
        .enumerate()
        .filter(|(i, &len)| !idle.contains(i) && len >= min_len)
        .max_by_key(|(_, &len)| len)
        .map(|(i, _)| i)
}

/// How many jobs to move: half the victim's queue (classic steal-half).
pub fn steal_amount(victim_len: usize) -> usize {
    victim_len.div_ceil(2)
}

/// The running thief thread.
pub struct Thief<T: Send + 'static> {
    tx: mpsc::Sender<ThiefMsg>,
    handle: Option<JoinHandle<()>>,
    pub stats: Arc<StealStats>,
    _marker: std::marker::PhantomData<T>,
}

impl<T: Send + 'static> Thief<T> {
    /// Spawn the thief over the cluster queues (default policy).
    pub fn spawn(queues: Vec<Arc<JobQueue<T>>>) -> Thief<T> {
        Self::spawn_with(queues, StealPolicy::default())
    }

    /// Spawn the thief with an explicit steal policy (the serving runtime
    /// passes [`StealPolicy::batched`]).
    pub fn spawn_with(queues: Vec<Arc<JobQueue<T>>>, policy: StealPolicy) -> Thief<T> {
        let (tx, rx) = mpsc::channel::<ThiefMsg>();
        let stats = Arc::new(StealStats::default());
        let st = Arc::clone(&stats);
        let handle = std::thread::Builder::new()
            .name("thief".into())
            .spawn(move || thief_loop(queues, rx, st, policy))
            .expect("spawn thief");
        Thief {
            tx,
            handle: Some(handle),
            stats,
            _marker: std::marker::PhantomData,
        }
    }

    /// Handle for workers to report idleness.
    pub fn sender(&self) -> mpsc::Sender<ThiefMsg> {
        self.tx.clone()
    }

    pub fn shutdown(mut self) {
        let _ = self.tx.send(ThiefMsg::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl<T: Send + 'static> Drop for Thief<T> {
    fn drop(&mut self) {
        let _ = self.tx.send(ThiefMsg::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

fn thief_loop<T: Send>(
    queues: Vec<Arc<JobQueue<T>>>,
    rx: mpsc::Receiver<ThiefMsg>,
    stats: Arc<StealStats>,
    policy: StealPolicy,
) {
    let mut idle_book: HashSet<usize> = HashSet::new();
    loop {
        // Wait for a notification (or poll the idle book periodically: a
        // victim may have become stealable after the idle report).
        let msg = if idle_book.is_empty() {
            match rx.recv() {
                Ok(m) => Some(m),
                Err(_) => return,
            }
        } else {
            match rx.recv_timeout(Duration::from_micros(200)) {
                Ok(m) => Some(m),
                Err(RecvTimeoutError::Timeout) => None,
                Err(RecvTimeoutError::Disconnected) => return,
            }
        };
        match msg {
            Some(ThiefMsg::Shutdown) => return,
            Some(ThiefMsg::ClusterIdle(c)) => {
                if c < queues.len() {
                    idle_book.insert(c);
                }
            }
            Some(ThiefMsg::ClusterBusy(c)) => {
                idle_book.remove(&c);
            }
            None => {}
        }
        // Stealer pass: service every idle cluster we can.
        let lens: Vec<usize> = queues.iter().map(|q| q.len()).collect();
        let served: Vec<usize> = idle_book.iter().copied().collect();
        for idle_c in served {
            stats.attempts.fetch_add(1, Ordering::Relaxed);
            if let Some(victim) = choose_victim(&lens, &idle_book, policy.min_victim_len) {
                let n = steal_amount(queues[victim].len());
                let stolen = queues[victim].steal(n);
                if !stolen.is_empty() {
                    let moved = stolen.len() as u64;
                    if queues[idle_c].push_batch(stolen) {
                        stats.successes.fetch_add(1, Ordering::Relaxed);
                        stats.jobs_moved.fetch_add(moved, Ordering::Relaxed);
                        idle_book.remove(&idle_c);
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn victim_is_longest_non_idle() {
        let lens = vec![0, 5, 3];
        let mut idle = HashSet::new();
        idle.insert(0);
        assert_eq!(choose_victim(&lens, &idle, 2), Some(1));
        idle.insert(1);
        assert_eq!(choose_victim(&lens, &idle, 2), Some(2));
        idle.insert(2);
        assert_eq!(choose_victim(&lens, &idle, 2), None);
    }

    #[test]
    fn victim_respects_min_len() {
        let lens = vec![1, 1];
        let idle = HashSet::new();
        assert_eq!(choose_victim(&lens, &idle, 2), None);
        let v = choose_victim(&lens, &idle, 1);
        assert!(v == Some(0) || v == Some(1));
    }

    #[test]
    fn steal_half() {
        assert_eq!(steal_amount(0), 0);
        assert_eq!(steal_amount(1), 1);
        assert_eq!(steal_amount(7), 4);
        assert_eq!(steal_amount(8), 4);
    }

    #[test]
    fn thief_moves_jobs_to_idle_cluster() {
        let q0: Arc<JobQueue<u32>> = Arc::new(JobQueue::new());
        let q1: Arc<JobQueue<u32>> = Arc::new(JobQueue::new());
        for i in 0..10 {
            q1.push(i);
        }
        let thief = Thief::spawn(vec![Arc::clone(&q0), Arc::clone(&q1)]);
        thief.sender().send(ThiefMsg::ClusterIdle(0)).unwrap();
        // Wait for the stealer to act.
        let deadline = std::time::Instant::now() + Duration::from_secs(2);
        while q0.is_empty() && std::time::Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(1));
        }
        assert!(!q0.is_empty(), "thief should have moved jobs");
        let (att, succ, moved) = thief.stats.snapshot();
        assert!(att >= 1 && succ >= 1 && moved >= 1);
        // No duplication, no loss.
        assert_eq!(q0.len() + q1.len(), 10);
        thief.shutdown();
    }

    #[test]
    fn batched_policy_scales_threshold() {
        assert_eq!(StealPolicy::default().min_victim_len, 2);
        assert_eq!(StealPolicy::batched(1).min_victim_len, 2);
        assert_eq!(StealPolicy::batched(16).min_victim_len, 8);
    }

    #[test]
    fn batched_policy_thief_leaves_small_victims_alone() {
        let q0: Arc<JobQueue<u32>> = Arc::new(JobQueue::new());
        let q1: Arc<JobQueue<u32>> = Arc::new(JobQueue::new());
        for i in 0..4 {
            q1.push(i);
        }
        // Threshold 8: a 4-deep victim is half a batch — not worth moving.
        let thief = Thief::spawn_with(
            vec![Arc::clone(&q0), Arc::clone(&q1)],
            StealPolicy::batched(16),
        );
        thief.sender().send(ThiefMsg::ClusterIdle(0)).unwrap();
        std::thread::sleep(Duration::from_millis(20));
        assert!(q0.is_empty(), "thief stole below the batch threshold");
        assert_eq!(q1.len(), 4);
        thief.shutdown();
    }

    #[test]
    fn thief_ignores_out_of_range_and_shuts_down() {
        let q0: Arc<JobQueue<u32>> = Arc::new(JobQueue::new());
        let thief = Thief::spawn(vec![Arc::clone(&q0)]);
        thief.sender().send(ThiefMsg::ClusterIdle(99)).unwrap();
        thief.sender().send(ThiefMsg::ClusterBusy(0)).unwrap();
        std::thread::sleep(Duration::from_millis(5));
        thief.shutdown(); // must not hang
    }
}
