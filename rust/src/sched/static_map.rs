//! Static CONV-layer → cluster assignment (the SF/SC baselines, §4.3).
//!
//! Paper: "Mapping of CONV layers and clusters is decided by the number of
//! jobs a CONV layer has.  A CONV layer with less workload will be mapped
//! onto a less powerful cluster and vice-versa."  We implement that as a
//! weighted longest-processing-time greedy: layers in decreasing work order
//! are placed on the cluster that finishes them earliest given its
//! aggregate throughput and current load.

use crate::accel::ClusterSpec;
use crate::nn::network::ConvLayerInfo;

/// Estimated work of one CONV layer in k-steps (jobs × K).
pub fn layer_ksteps(info: &ConvLayerInfo) -> f64 {
    (info.grid.num_jobs() * info.grid.k_tiles()) as f64
}

/// Compute the static assignment: `result[conv_idx] = cluster index`.
pub fn assign(convs: &[ConvLayerInfo], clusters: &[ClusterSpec]) -> Vec<usize> {
    assert!(!clusters.is_empty());
    let throughputs: Vec<f64> = clusters.iter().map(|c| c.throughput().max(1e-12)).collect();
    // loads[c] = assigned k-steps
    let mut loads = vec![0.0f64; clusters.len()];
    let mut order: Vec<usize> = (0..convs.len()).collect();
    order.sort_by(|&a, &b| {
        layer_ksteps(&convs[b])
            .partial_cmp(&layer_ksteps(&convs[a]))
            .unwrap()
    });
    let mut assignment = vec![0usize; convs.len()];
    for idx in order {
        let work = layer_ksteps(&convs[idx]);
        // earliest-finish cluster
        let best = (0..clusters.len())
            .min_by(|&a, &b| {
                let fa = (loads[a] + work) / throughputs[a];
                let fb = (loads[b] + work) / throughputs[b];
                fa.partial_cmp(&fb).unwrap()
            })
            .unwrap();
        loads[best] += work;
        assignment[idx] = best;
    }
    assignment
}

/// Imbalance of an assignment: max/min cluster finish-time ratio (1.0 =
/// perfectly balanced).  Used by tests and the DSE ranking.
pub fn imbalance(
    convs: &[ConvLayerInfo],
    clusters: &[ClusterSpec],
    assignment: &[usize],
) -> f64 {
    let throughputs: Vec<f64> = clusters.iter().map(|c| c.throughput().max(1e-12)).collect();
    let mut finish = vec![0.0f64; clusters.len()];
    for (ci, info) in convs.iter().enumerate() {
        finish[assignment[ci]] += layer_ksteps(info) / throughputs[assignment[ci]];
    }
    let max = finish.iter().cloned().fold(0.0, f64::max);
    let min = finish
        .iter()
        .cloned()
        .filter(|&f| f > 0.0)
        .fold(f64::INFINITY, f64::min);
    if min.is_finite() && min > 0.0 {
        max / min
    } else {
        f64::INFINITY
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::build_clusters;
    use crate::config::{zoo, HwConfig};
    use crate::nn::Network;

    fn setup(name: &str) -> (Vec<ConvLayerInfo>, Vec<crate::accel::ClusterSpec>) {
        let net = Network::new(zoo::load(name).unwrap(), 32).unwrap();
        let clusters = build_clusters(&HwConfig::default_zc702());
        (net.conv_infos(), clusters)
    }

    #[test]
    fn assignment_in_range_and_total() {
        for name in zoo::ZOO {
            let (convs, clusters) = setup(name);
            let a = assign(&convs, &clusters);
            assert_eq!(a.len(), convs.len(), "{name}");
            assert!(a.iter().all(|&c| c < clusters.len()), "{name}");
        }
    }

    #[test]
    fn heaviest_layer_goes_to_strongest_cluster() {
        let (convs, clusters) = setup("cifar_alex");
        let a = assign(&convs, &clusters);
        let heaviest = (0..convs.len())
            .max_by(|&x, &y| {
                layer_ksteps(&convs[x])
                    .partial_cmp(&layer_ksteps(&convs[y]))
                    .unwrap()
            })
            .unwrap();
        // Cluster 1 (6 F-PE) is the strongest in the default config.
        assert_eq!(a[heaviest], 1);
    }

    #[test]
    fn greedy_beats_all_on_one_cluster() {
        let (convs, clusters) = setup("cifar_darknet");
        let a = assign(&convs, &clusters);
        let all_on_one = vec![1usize; convs.len()];
        let makespan = |asg: &[usize]| -> f64 {
            let thr: Vec<f64> = clusters.iter().map(|c| c.throughput()).collect();
            let mut finish = vec![0.0f64; clusters.len()];
            for (ci, info) in convs.iter().enumerate() {
                finish[asg[ci]] += layer_ksteps(info) / thr[asg[ci]];
            }
            finish.iter().cloned().fold(0.0, f64::max)
        };
        assert!(makespan(&a) <= makespan(&all_on_one) * 1.001);
    }

    #[test]
    fn ksteps_match_grid() {
        let (convs, _) = setup("mnist");
        // mnist conv1: 25 jobs × 1 kstep; conv2: 14 jobs × 25.
        assert_eq!(layer_ksteps(&convs[0]) as usize, 25);
        assert_eq!(
            layer_ksteps(&convs[1]) as usize,
            convs[1].grid.num_jobs() * 25
        );
    }
}
