//! Scheduling: CONV-layer → cluster mapping policies.
//!
//! * [`static_map`] — the SF/SC static assignment of paper §4.3 (each CONV
//!   layer pinned to one cluster, balanced by workload estimate);
//! * [`worksteal`] — the Synergy thief thread (manager, idle book, stealer)
//!   that rebalances job queues at runtime (paper §3.1.3 / Fig 4);
//! * [`dse`] — exhaustive cluster-configuration search for the SC designs
//!   (paper Table 5).

pub mod dse;
pub mod static_map;
pub mod worksteal;

/// How CONV layers' jobs reach clusters.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Mapping {
    /// SF/SC: layer `l` sends all jobs to `assignment[l]` (indexed by CONV
    /// ordinal, not network layer index); no stealing.
    Static(Vec<usize>),
    /// Synergy: same initial assignment, but idle clusters steal.
    WorkStealing(Vec<usize>),
}

impl Mapping {
    pub fn assignment(&self) -> &[usize] {
        match self {
            Mapping::Static(a) | Mapping::WorkStealing(a) => a,
        }
    }

    pub fn steals(&self) -> bool {
        matches!(self, Mapping::WorkStealing(_))
    }
}
