//! The serving front-end wired together: client streams submit into the
//! tiered admission queue; a batcher thread coalesces per-(network, tier)
//! micro-batches and feeds them into per-network layer pipelines; every
//! CONV stage lowers its batch to jobs on the shared accelerator pool;
//! completion threads stamp latencies and collect responses.
//!
//! One [`rt::DelegatePool`] serves all networks — heterogeneous models
//! compete for the same clusters exactly like the paper's multi-CNN
//! scenario, with the thief rebalancing at batch granularity.
//!
//! Weight hot-swap: each network's weights live in a versioned registry
//! slot ([`NetRegistry`]); [`Server::hot_swap`] flips the slot pointer
//! after validating the replacement shares the incumbent's architecture.
//! Batches pin `(version, weights)` **at batch formation** and drain on
//! the pinned version — zero requests lost, responses bit-identical per
//! version.  The pool routing is geometry-only (cluster assignment + tile
//! size), so the launch-time routers keep serving every version.
//!
//! [`rt::DelegatePool`]: crate::rt::DelegatePool

use std::collections::VecDeque;
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{ensure, Result};

use crate::config::{HwConfig, ServeCfg};
use crate::nn::Network;
use crate::pipeline::Mailbox;
use crate::rt::{ComputeMode, DelegatePool, PoolOptions, PoolRouter};
use crate::sched::static_map;
use crate::sched::worksteal::StealPolicy;
use crate::tensor::Tensor;

use super::admission::AdmissionQueue;
use super::batcher::{Batch, BatchCfg, MicroBatcher};
use super::registry::NetRegistry;
use super::request::{Request, Response, SloTier};
use super::stats::{ServerStats, StatsCollector};

/// Serving configuration (defaults come from `HwConfig::serving`).
#[derive(Clone)]
pub struct ServeOptions {
    pub hw: HwConfig,
    pub compute: ComputeMode,
    pub work_stealing: bool,
    /// Mailbox depth, in batches, between pipeline stages.
    pub mailbox_capacity: usize,
    pub batch: BatchCfg,
    /// Bounded admission depth per (network, tier) lane (requests beyond
    /// a lane's depth are shed; other lanes are unaffected).
    pub admission_depth: usize,
    /// Backend registry override for the shared pool; `None` uses the
    /// in-tree defaults.  Deployments with out-of-tree members — e.g.
    /// `[cluster] remote = host:port` shards registered via
    /// `accel::remote::register_config_shards` — pass their registry
    /// here; the server itself never special-cases a backend.
    pub registry: Option<Arc<crate::accel::BackendRegistry>>,
}

impl ServeOptions {
    /// Derive serving knobs from a hardware config's `[serving]` section.
    pub fn from_hw(hw: HwConfig) -> ServeOptions {
        let batch = BatchCfg {
            max_batch: hw.serving.max_batch,
            window: Duration::from_micros(hw.serving.batch_window_us),
            window_min: Duration::from_micros(hw.serving.batch_window_min_us),
            headroom_samples: hw.serving.headroom_samples,
        };
        let admission_depth = hw.serving.admission_depth;
        ServeOptions {
            hw,
            compute: ComputeMode::Native,
            work_stealing: true,
            mailbox_capacity: 1,
            batch,
            admission_depth,
            registry: None,
        }
    }
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions::from_hw(HwConfig::default_zc702())
    }
}

/// A micro-batch in flight through one network's pipeline: each request
/// rides with its current activation, and the whole batch rides the
/// `(version, weights)` pinned at batch formation — a concurrent hot-swap
/// never changes the weights a dispatched batch computes against.  The
/// batch size is always `items.len()` — deadline pruning shrinks both
/// together, so the batch-size histogram can never count requests that
/// never ran.
struct InFlight {
    net_id: usize,
    /// Weight version pinned at batch formation.
    version: u64,
    /// The pinned weights themselves (kept alive across a swap).
    net: Arc<Network>,
    items: Vec<(Request, Tensor)>,
}

/// The running server.
pub struct Server {
    nets: Vec<Arc<Network>>,
    versions: Arc<NetRegistry>,
    serving: ServeCfg,
    admission: Arc<AdmissionQueue>,
    collector: Arc<StatsCollector>,
    batcher_handle: JoinHandle<()>,
    layer_handles: Vec<JoinHandle<()>>,
    completion_handles: Vec<JoinHandle<Vec<Response>>>,
    pool: DelegatePool,
    started: Instant,
}

impl Server {
    /// Spin up the full serving stack over `nets`.
    pub fn start(nets: Vec<Arc<Network>>, options: ServeOptions) -> Result<Server> {
        ensure!(!nets.is_empty(), "server needs at least one network");
        ensure!(options.batch.max_batch >= 1, "max_batch must be ≥ 1");

        // Shared accelerator substrate.  A cluster queue grows by one
        // request's one CONV layer lowered to jobs per push, so the
        // thief's steal threshold scales with that push unit (half the
        // smallest one across the served networks) — enough to avoid
        // ping-ponging sub-push fragments without suppressing stealing.
        // `[serving] steal_min_victim` overrides the derivation; the
        // delegate drain depth comes from `[serving] drain_extra` (both
        // swept in `benches/serve_throughput.rs`).
        let min_jobs_per_push = nets
            .iter()
            .flat_map(|n| {
                n.conv_infos()
                    .into_iter()
                    .map(|ci| ci.grid.num_jobs())
            })
            .min()
            .unwrap_or(1)
            .max(1);
        let mut pool_options = PoolOptions::new(
            options.hw.clone(),
            options.compute,
            options.work_stealing,
        );
        pool_options.steal_policy = if options.hw.serving.steal_min_victim > 0 {
            StealPolicy {
                min_victim_len: options.hw.serving.steal_min_victim,
                ..StealPolicy::default()
            }
        } else {
            StealPolicy::batched(min_jobs_per_push)
        };
        // Amortize queue locks over micro-batch job runs.
        pool_options.drain_extra = options.hw.serving.drain_extra;
        pool_options.registry = options.registry.clone();
        // Measured placement: probe remote members' RTT + service rate
        // into their routing links ([serving] probe_interval_ms).
        pool_options.probe_interval_ms = options.hw.serving.probe_interval_ms;
        let pool = DelegatePool::start(&pool_options)?;

        let serving = options.hw.serving.clone();
        let admission = Arc::new(
            AdmissionQueue::new(options.admission_depth)
                .with_escape_every(serving.batch_escape_every),
        );
        let collector = Arc::new(StatsCollector::default());
        let versions = Arc::new(NetRegistry::new(&nets));

        // Per-network pipelines: mb[0] = batch inbox, mb[i+1] = output of
        // layer i; the last mailbox feeds that net's completion thread.
        let mut inboxes: Vec<Arc<Mailbox<InFlight>>> = Vec::new();
        let mut layer_handles = Vec::new();
        let mut completion_handles = Vec::new();
        for (net_id, net) in nets.iter().enumerate() {
            let n_layers = net.config.layers.len();
            let mailboxes: Vec<Arc<Mailbox<InFlight>>> = (0..=n_layers)
                .map(|_| Arc::new(Mailbox::new(options.mailbox_capacity)))
                .collect();
            inboxes.push(Arc::clone(&mailboxes[0]));
            // Routing is geometry-only (cluster assignment per CONV layer
            // + tile size); hot-swap enforces identical architecture, so
            // one launch-time router serves every weight version.
            let assignment = static_map::assign(&net.conv_infos(), pool.clusters());
            let router = PoolRouter::new(net, pool.dispatcher(), &assignment);
            for layer_idx in 0..n_layers {
                let inbox = Arc::clone(&mailboxes[layer_idx]);
                let outbox = Arc::clone(&mailboxes[layer_idx + 1]);
                let net = Arc::clone(net);
                let router = router.clone();
                let handle = std::thread::Builder::new()
                    .name(format!("serve-n{net_id}-l{layer_idx}"))
                    .spawn(move || {
                        let is_fc = matches!(
                            net.config.layers[layer_idx],
                            crate::config::LayerSpec::Connected { .. }
                        );
                        while let Some(mut batch) = inbox.recv() {
                            // Compute against the batch's pinned weights —
                            // the architecture (and thus the layer spec)
                            // is swap-invariant by contract.
                            let bnet = Arc::clone(&batch.net);
                            let spec = bnet.config.layers[layer_idx].clone();
                            let items = std::mem::take(&mut batch.items);
                            batch.items = if is_fc {
                                // Fused FC stage: the whole micro-batch
                                // becomes ONE FcGemmBatch pool job — the
                                // big-NEON team fans it out once per
                                // batch instead of once per request.
                                // The job carries the first request's
                                // frame tag.
                                let frame =
                                    items.first().map(|(r, _)| r.frame).unwrap_or(0);
                                let exec = router.frame(frame);
                                let (reqs, acts): (Vec<Request>, Vec<Tensor>) =
                                    items.into_iter().unzip();
                                let outs = bnet
                                    .forward_layer_batch(layer_idx, &spec, acts, &exec);
                                reqs.into_iter().zip(outs).collect()
                            } else {
                                // CONV front-end and element-wise stages
                                // run per request (each keeps its own
                                // frame tag on its jobs).
                                items
                                    .into_iter()
                                    .map(|(req, act)| {
                                        let exec = router.frame(req.frame);
                                        let out = bnet
                                            .forward_layer(layer_idx, &spec, act, &exec);
                                        (req, out)
                                    })
                                    .collect()
                            };
                            if !outbox.send(batch) {
                                break;
                            }
                        }
                        outbox.close();
                    })
                    .expect("spawn serve layer thread");
                layer_handles.push(handle);
            }
            // Completion thread: stamp latencies, collect responses.
            let outlet = Arc::clone(&mailboxes[n_layers]);
            let collector_c = Arc::clone(&collector);
            completion_handles.push(
                std::thread::Builder::new()
                    .name(format!("serve-n{net_id}-done"))
                    .spawn(move || {
                        let mut responses = Vec::new();
                        while let Some(batch) = outlet.recv() {
                            let net_id = batch.net_id;
                            let version = batch.version;
                            let batch_size = batch.items.len();
                            for (req, out) in batch.items {
                                let latency = req.submitted.elapsed();
                                collector_c.record_response(req.tier, latency);
                                responses.push(Response {
                                    stream_id: req.stream_id,
                                    seq: req.seq,
                                    net_id,
                                    frame: req.frame,
                                    output: out,
                                    latency,
                                    batch_size,
                                    tier: req.tier,
                                    version,
                                });
                            }
                        }
                        responses
                    })
                    .expect("spawn completion thread"),
            );
        }

        // Batcher thread: admission → micro-batches → pipeline inboxes.
        let batcher_handle = {
            let admission = Arc::clone(&admission);
            let collector = Arc::clone(&collector);
            let versions = Arc::clone(&versions);
            let per_net_cap: Vec<Option<usize>> =
                nets.iter().map(|n| n.config.max_batch).collect();
            let batch_cfg = options.batch;
            std::thread::Builder::new()
                .name("serve-batcher".into())
                .spawn(move || {
                    batcher_loop(admission, collector, versions, batch_cfg, per_net_cap, inboxes)
                })
                .expect("spawn batcher thread")
        };

        Ok(Server {
            nets,
            versions,
            serving,
            admission,
            collector,
            batcher_handle,
            layer_handles,
            completion_handles,
            pool,
            started: Instant::now(),
        })
    }

    pub fn nets(&self) -> &[Arc<Network>] {
        &self.nets
    }

    /// Submit one request (stamps the arrival time).  A request without an
    /// explicit deadline inherits its tier's default latency budget from
    /// `[serving]` (`interactive_deadline_ms` etc.; 0 = none).  Returns
    /// false when the request names an unknown network or the admission
    /// queue shed it.
    pub fn submit(&self, mut req: Request) -> bool {
        if req.net_id >= self.nets.len() {
            return false;
        }
        req.submitted = Instant::now();
        if req.deadline.is_none() {
            let default_ms = match req.tier {
                SloTier::Interactive => self.serving.interactive_deadline_ms,
                SloTier::Standard => self.serving.standard_deadline_ms,
                SloTier::Batch => self.serving.batch_deadline_ms,
            };
            if default_ms > 0 {
                req.deadline = Some(Duration::from_millis(default_ms));
            }
        }
        self.admission.submit(req)
    }

    /// Zero-downtime weight swap: validate that `net` shares the
    /// incumbent's architecture (layer specs, tile size, input shape),
    /// then flip the registry pointer.  Batches formed before the flip
    /// drain on their pinned version; batches formed after compute on the
    /// new weights.  Returns the new version number.
    pub fn hot_swap(&self, net_id: usize, net: Arc<Network>) -> Result<u64> {
        ensure!(net_id < self.nets.len(), "hot_swap: unknown network {net_id}");
        let base = &self.nets[net_id];
        ensure!(
            net.config.layers == base.config.layers,
            "hot_swap: replacement must share the incumbent's layer architecture"
        );
        ensure!(
            net.tile_size() == base.tile_size(),
            "hot_swap: replacement must share the incumbent's tile size"
        );
        ensure!(
            net.input_shape() == base.input_shape(),
            "hot_swap: replacement must share the incumbent's input shape"
        );
        let version = self.versions.swap(net_id, net);
        self.collector.record_hot_swap();
        Ok(version)
    }

    /// Current weight version of one network (0 until the first swap).
    pub fn net_version(&self, net_id: usize) -> u64 {
        self.versions.version(net_id)
    }

    /// Requests completed so far (live gauge).
    pub fn completed(&self) -> u64 {
        self.collector.completed_count()
    }

    /// Drain everything in flight, stop all threads, and report.
    /// Responses arrive in completion order, grouped per network.
    pub fn shutdown(self) -> Result<(ServerStats, Vec<Response>)> {
        self.admission.close();
        self.batcher_handle.join().expect("batcher thread");
        for h in self.layer_handles {
            h.join().expect("serve layer thread");
        }
        let mut responses = Vec::new();
        for h in self.completion_handles {
            responses.extend(h.join().expect("completion thread"));
        }
        let wall = self.started.elapsed().as_secs_f64();
        let pool_report = self.pool.shutdown()?;
        let stats = self
            .collector
            .report(wall, &self.admission.tier_counts(), &pool_report);
        Ok((stats, responses))
    }
}

/// Signed deadline headroom in milliseconds (negative once `now` is past
/// `due`) — the sample the adaptive batch window feeds on.
fn headroom_ms(due: Instant, now: Instant) -> f64 {
    if due >= now {
        due.saturating_duration_since(now).as_secs_f64() * 1e3
    } else {
        -(now.saturating_duration_since(due).as_secs_f64() * 1e3)
    }
}

/// The batcher thread body: pop tier-ordered from admission, coalesce per
/// (network, tier), dispatch full batches immediately and partial ones on
/// window expiry; on close, drain + flush and shut the pipelines down.
///
/// Batch handoff to the pipelines is *non-blocking* (`Mailbox::try_send`)
/// through per-net `ready` buffers: window-expiry dispatch and handoff to
/// the other networks keep running while one pipeline is stalled.  Each
/// network's buffered backlog is bounded by `READY_CAP_PER_NET`; a
/// network at its cap becomes *ineligible* and the batcher stops draining
/// only **its** admission lanes (`pop_timeout_eligible`), so a stalled
/// pipeline backs pressure up into its own lanes — where overload sheds at
/// `submit()` — while every other network keeps flowing.  Admitted
/// requests are never dropped (except by their own deadlines).
fn batcher_loop(
    admission: Arc<AdmissionQueue>,
    collector: Arc<StatsCollector>,
    versions: Arc<NetRegistry>,
    batch_cfg: BatchCfg,
    per_net_cap: Vec<Option<usize>>,
    inboxes: Vec<Arc<Mailbox<InFlight>>>,
) {
    /// Buffered batches per network before its lane goes ineligible.
    const READY_CAP_PER_NET: usize = 2;
    let mut batcher = MicroBatcher::new(batch_cfg, &per_net_cap);
    let mut ready: Vec<VecDeque<InFlight>> =
        inboxes.iter().map(|_| VecDeque::new()).collect();
    loop {
        // Hand buffered batches to any pipeline with capacity, dropping
        // requests whose deadline lapsed while they waited in the
        // backlog — overload is exactly when executing them anyway would
        // waste the scarcest accelerator time.
        for (net_id, queue) in ready.iter_mut().enumerate() {
            while let Some(mut batch) = queue.pop_front() {
                prune_expired(&collector, &mut batch);
                if batch.items.is_empty() {
                    continue;
                }
                // Histogram the size that actually dispatches — post-prune,
                // never the size the batch was staged with.
                let size = batch.items.len();
                match inboxes[net_id].try_send(batch) {
                    Ok(()) => collector.record_batch(size),
                    Err(batch) => {
                        queue.push_front(batch);
                        break;
                    }
                }
            }
        }
        let backlog: usize = ready.iter().map(|q| q.len()).sum();
        // Sleep until the next window deadline, a handoff retry, or a
        // coarse idle tick.
        let timeout = if backlog > 0 {
            Duration::from_micros(200)
        } else {
            match batcher.next_deadline() {
                Some(deadline) => deadline
                    .saturating_duration_since(Instant::now())
                    .max(Duration::from_micros(50)),
                None => Duration::from_millis(5),
            }
        };
        // Per-net eligibility: a network whose ready backlog hit its cap
        // stops draining *its own* admission lanes; the rest keep flowing.
        let eligible: Vec<bool> = ready
            .iter()
            .map(|q| q.len() < READY_CAP_PER_NET)
            .collect();
        if eligible.iter().any(|&e| e) {
            match admission.pop_timeout_eligible(timeout, &eligible) {
                Ok(Some(req)) => {
                    let now = Instant::now();
                    collector.observe_queue_depth(admission.len() + 1);
                    if req.is_expired(now) {
                        // Rare: expired between the admission-side prune
                        // and this instant.
                        collector.record_expired(req.tier);
                    } else if let Some(batch) = batcher.push(req, now) {
                        stage(&collector, &versions, &mut batcher, &mut ready, batch);
                    }
                }
                Ok(None) => {
                    // Closed + drained: flush stragglers and stop.
                    for batch in batcher.flush_all() {
                        stage(&collector, &versions, &mut batcher, &mut ready, batch);
                    }
                    break;
                }
                Err(()) => {}
            }
        } else {
            // Every pipeline saturated: retry the handoff shortly while
            // the admission lanes absorb (and beyond their depth, shed)
            // the load.
            std::thread::sleep(timeout);
        }
        for batch in batcher.poll_expired(Instant::now()) {
            stage(&collector, &versions, &mut batcher, &mut ready, batch);
        }
    }
    let (shrinks, widens) = batcher.window_events();
    collector.set_window_events(shrinks, widens);
    // Shutdown: guaranteed delivery of everything buffered (the layer
    // threads are still draining), then close the pipelines.  The same
    // prune-then-record rule applies here — a deadline that lapsed while
    // the batch waited must not inflate the histogram or ship dead work.
    for (net_id, queue) in ready.iter_mut().enumerate() {
        for mut batch in queue.drain(..) {
            prune_expired(&collector, &mut batch);
            if batch.items.is_empty() {
                continue;
            }
            collector.record_batch(batch.items.len());
            inboxes[net_id].send(batch);
        }
    }
    for inbox in &inboxes {
        inbox.close();
    }
}

/// Convert a finished batch to its in-flight form and buffer it for
/// handoff to its network's pipeline.  This is **batch formation**: the
/// weight `(version, net)` is pinned here, once for the whole batch, and
/// rides with it to completion — a hot-swap after this point cannot touch
/// it.  Every deadlined request feeds its remaining headroom (negative if
/// lapsed) into the adaptive-window estimator; requests that expired while
/// pending in the micro-batcher are dropped (and counted per tier).  The
/// input tensor is moved out of each request to seed its activation, so
/// the pipeline carries one copy, not two.  Batch-size stats are recorded
/// at dispatch, not here — a buffered batch may still shrink (or vanish)
/// to deadline pruning before it reaches the pipeline.
fn stage(
    collector: &StatsCollector,
    versions: &NetRegistry,
    batcher: &mut MicroBatcher,
    ready: &mut [VecDeque<InFlight>],
    batch: Batch,
) {
    let now = Instant::now();
    let net_id = batch.net_id;
    let (version, net) = versions.current(net_id);
    let mut items = Vec::with_capacity(batch.requests.len());
    for mut req in batch.requests {
        if let Some(due) = req.due() {
            batcher.record_headroom(req.tier, headroom_ms(due, now));
        }
        if req.is_expired(now) {
            collector.record_expired(req.tier);
        } else {
            let act = std::mem::replace(&mut req.input, Tensor::zeros(&[0]));
            items.push((req, act));
        }
    }
    if items.is_empty() {
        return;
    }
    ready[net_id].push_back(InFlight {
        net_id,
        version,
        net,
        items,
    });
}

/// Drop (and count, per tier) the requests of a buffered batch whose
/// deadline passed while it waited for pipeline capacity.  The surviving
/// `items.len()` IS the batch size — there is no separate counter to
/// fall out of sync.
fn prune_expired(collector: &StatsCollector, inflight: &mut InFlight) {
    let now = Instant::now();
    if inflight.items.iter().any(|(req, _)| req.is_expired(now)) {
        let items = std::mem::take(&mut inflight.items);
        for (req, act) in items {
            if req.is_expired(now) {
                collector.record_expired(req.tier);
            } else {
                inflight.items.push((req, act));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::zoo;
    use crate::rt::PoolReport;
    use crate::serve::stats::TierCounts;

    fn mk_net() -> Arc<Network> {
        Arc::new(Network::new(zoo::load("mnist").unwrap(), 32).unwrap())
    }

    /// A request whose deadline has (or has not) already lapsed.
    fn req(seq: u64, expired: bool) -> Request {
        let mut r = Request::new(0, seq, 0, Tensor::scalar(0.0));
        if expired {
            r.submitted = Instant::now() - Duration::from_millis(50);
            r.deadline = Some(Duration::from_millis(1));
        } else {
            r.deadline = Some(Duration::from_secs(3600));
        }
        r
    }

    /// The satellite regression: a batch that went half-expired while
    /// buffered must dispatch with `items.len()` as its size — the lapsed
    /// request is counted as expired, never in the batch histogram.
    #[test]
    fn prune_expired_half_expired_batch_keeps_size_consistent() {
        let collector = StatsCollector::default();
        let net = mk_net();
        let mut inflight = InFlight {
            net_id: 0,
            version: 0,
            net,
            items: vec![
                (req(0, true), Tensor::scalar(0.0)),
                (req(1, false), Tensor::scalar(1.0)),
            ],
        };
        prune_expired(&collector, &mut inflight);
        assert_eq!(inflight.items.len(), 1, "lapsed request must be dropped");
        assert_eq!(inflight.items[0].0.seq, 1, "survivor is the live request");
        let stats = collector.report(1.0, &TierCounts::default(), &PoolReport::default());
        assert_eq!(stats.expired, 1);
        assert_eq!(stats.expired_by_tier, [0, 1, 0], "standard-tier expiry");
        // What dispatch records is exactly the surviving size.
        collector.record_batch(inflight.items.len());
        let stats = collector.report(1.0, &TierCounts::default(), &PoolReport::default());
        assert_eq!(stats.batches, 1);
        assert_eq!(stats.max_batch, 1, "histogram must not see the staged size");
    }

    #[test]
    fn stage_drops_expired_and_sizes_by_survivors() {
        let collector = StatsCollector::default();
        let net = mk_net();
        let versions = NetRegistry::new(std::slice::from_ref(&net));
        let mut batcher = MicroBatcher::new(BatchCfg::default(), &[None]);
        let mut ready: Vec<VecDeque<InFlight>> = vec![VecDeque::new()];
        stage(
            &collector,
            &versions,
            &mut batcher,
            &mut ready,
            Batch {
                net_id: 0,
                tier: SloTier::Standard,
                requests: vec![req(0, true), req(1, false), req(2, false)],
            },
        );
        assert_eq!(ready[0].len(), 1);
        assert_eq!(ready[0][0].items.len(), 2);
        assert_eq!(ready[0][0].version, 0, "pinned at formation");
        assert!(Arc::ptr_eq(&ready[0][0].net, &net));
        // An all-expired batch stages nothing at all.
        stage(
            &collector,
            &versions,
            &mut batcher,
            &mut ready,
            Batch {
                net_id: 0,
                tier: SloTier::Standard,
                requests: vec![req(3, true)],
            },
        );
        assert_eq!(ready[0].len(), 1, "all-expired batch must vanish");
        let stats = collector.report(1.0, &TierCounts::default(), &PoolReport::default());
        assert_eq!(stats.expired, 2);
    }

    /// A batch staged before a swap pins version 0; one staged after pins
    /// version 1 — the formation instant decides, nothing else.
    #[test]
    fn stage_pins_version_current_at_formation() {
        let collector = StatsCollector::default();
        let v0 = mk_net();
        let versions = NetRegistry::new(std::slice::from_ref(&v0));
        let mut batcher = MicroBatcher::new(BatchCfg::default(), &[None]);
        let mut ready: Vec<VecDeque<InFlight>> = vec![VecDeque::new()];
        let one = |seq| Batch {
            net_id: 0,
            tier: SloTier::Standard,
            requests: vec![req(seq, false)],
        };
        stage(&collector, &versions, &mut batcher, &mut ready, one(0));
        let v1 = {
            let mut cfg = zoo::load("mnist").unwrap();
            cfg.name = "mnist_v2".into();
            Arc::new(Network::new(cfg, 32).unwrap())
        };
        versions.swap(0, Arc::clone(&v1));
        stage(&collector, &versions, &mut batcher, &mut ready, one(1));
        assert_eq!(ready[0].len(), 2);
        assert_eq!(ready[0][0].version, 0);
        assert!(Arc::ptr_eq(&ready[0][0].net, &v0), "old batch keeps old weights");
        assert_eq!(ready[0][1].version, 1);
        assert!(Arc::ptr_eq(&ready[0][1].net, &v1));
    }

    #[test]
    fn headroom_is_signed() {
        let now = Instant::now();
        let h = headroom_ms(now + Duration::from_millis(10), now);
        assert!((h - 10.0).abs() < 1.0);
        let lapsed = headroom_ms(now, now + Duration::from_millis(10));
        assert!(lapsed < 0.0, "lapsed deadline yields negative headroom");
    }
}
