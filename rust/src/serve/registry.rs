//! Versioned network registration — the zero-downtime weight hot-swap
//! slot table.
//!
//! Weights have been `Arc`-backed since the scheduler rework, so a swap
//! is a pointer flip: the registry holds one `(version, Arc<Network>)`
//! slot per served network, and `swap` replaces the pointer and bumps the
//! version under a short mutex.  Consumers pin `(version, net)` **once
//! per micro-batch at batch formation** and ride that pinned version to
//! completion — in-flight batches drain on the weights they started with
//! (bit-identical responses per version), new batches pick up the new
//! weights, and no request is ever lost or recomputed.  Each `Network`
//! packs its CONV weights once at load (`weight_pack_count` stays 1 per
//! version), so a swap never repacks on the serving path.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::nn::Network;
use crate::util::sync::{lock_clean, Mutex};

struct Slot {
    current: Mutex<(u64, Arc<Network>)>,
}

/// Per-network versioned weight slots (see module docs).
pub struct NetRegistry {
    slots: Vec<Slot>,
    swaps: AtomicU64,
}

impl NetRegistry {
    /// Register the launch-time networks as version 0.
    pub fn new(nets: &[Arc<Network>]) -> NetRegistry {
        NetRegistry {
            slots: nets
                .iter()
                .map(|n| Slot {
                    current: Mutex::new((0, Arc::clone(n))),
                })
                .collect(),
            swaps: AtomicU64::new(0),
        }
    }

    pub fn len(&self) -> usize {
        self.slots.len()
    }

    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }

    /// The current `(version, weights)` of one network — read atomically
    /// together, so a concurrent swap can never tear the pair.
    pub fn current(&self, net_id: usize) -> (u64, Arc<Network>) {
        let g = lock_clean(&self.slots[net_id].current);
        (g.0, Arc::clone(&g.1))
    }

    pub fn version(&self, net_id: usize) -> u64 {
        lock_clean(&self.slots[net_id].current).0
    }

    /// Flip the pointer, bump the version, return it.  Validation
    /// (architecture equality etc.) is the caller's job — the registry
    /// is just the atomic slot.
    pub fn swap(&self, net_id: usize, net: Arc<Network>) -> u64 {
        let mut g = lock_clean(&self.slots[net_id].current);
        g.0 += 1;
        g.1 = net;
        self.swaps.fetch_add(1, Ordering::Relaxed);
        g.0
    }

    /// Total swaps across all slots.
    pub fn swap_count(&self) -> u64 {
        self.swaps.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::zoo;

    fn mk_net(name: &str) -> Arc<Network> {
        let mut cfg = zoo::load("mnist").unwrap();
        cfg.name = name.to_string();
        Arc::new(Network::new(cfg, 32).unwrap())
    }

    #[test]
    fn swap_bumps_version_and_flips_pointer() {
        let v0 = mk_net("mnist");
        let r = NetRegistry::new(std::slice::from_ref(&v0));
        assert_eq!(r.len(), 1);
        let (ver, cur) = r.current(0);
        assert_eq!(ver, 0);
        assert!(Arc::ptr_eq(&cur, &v0));
        // Old readers keep their pinned Arc; new readers see v1.
        let v1 = mk_net("mnist_v2");
        assert_eq!(r.swap(0, Arc::clone(&v1)), 1);
        let (ver, cur) = r.current(0);
        assert_eq!(ver, 1);
        assert!(Arc::ptr_eq(&cur, &v1));
        assert!(!Arc::ptr_eq(&cur, &v0));
        assert_eq!(r.swap_count(), 1);
        assert_eq!(r.version(0), 1);
        // The displaced version is still alive through the pinned Arc.
        assert_eq!(v0.config.layers, v1.config.layers);
    }

    #[test]
    fn swapped_weights_pack_once_per_version() {
        let v0 = mk_net("mnist");
        let r = NetRegistry::new(std::slice::from_ref(&v0));
        r.swap(0, mk_net("mnist_v2"));
        let (_, cur) = r.current(0);
        for (idx, layer) in cur.config.layers.iter().enumerate() {
            if layer.is_conv() {
                assert_eq!(cur.weight_pack_count(idx), 1);
                assert_eq!(v0.weight_pack_count(idx), 1);
            }
        }
    }
}
