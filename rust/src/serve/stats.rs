//! Serving metrics: tail latency, sustained throughput, batch-size and
//! per-SLO-tier shed/expiry accounting — computed through `util::stats`
//! and rendered with the shared table builder.

use crate::util::sync::{lock_clean, Mutex};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::time::Duration;

use crate::mm::job::JobClass;
use crate::rt::PoolReport;
use crate::util::bench::{fmt, Table};
use crate::util::stats::{mean, percentile};

use super::request::SloTier;

/// Per-tier shed + expiry counters snapshotted from the admission queue
/// at report time (`AdmissionQueue::tier_counts`).
#[derive(Debug, Default, Clone, Copy)]
pub struct TierCounts {
    /// Requests shed at admission, per tier.
    pub shed: [u64; SloTier::COUNT],
    /// Requests pruned at admission pop because their deadline lapsed.
    pub expired: [u64; SloTier::COUNT],
}

/// Thread-safe sample sink shared by the batcher / completion threads.
#[derive(Default)]
pub struct StatsCollector {
    latencies_ms: Mutex<Vec<f64>>,
    tier_latencies_ms: Mutex<[Vec<f64>; SloTier::COUNT]>,
    batch_sizes: Mutex<Vec<f64>>,
    completed: AtomicU64,
    completed_by_tier: [AtomicU64; SloTier::COUNT],
    /// Batcher-side expirations (batch formation / dispatch pruning) —
    /// admission-pop pruning is counted by the queue itself and merged
    /// at report time.
    expired_by_tier: [AtomicU64; SloTier::COUNT],
    window_shrinks: AtomicU64,
    window_widens: AtomicU64,
    hot_swaps: AtomicU64,
    max_queue_depth: AtomicUsize,
}

impl StatsCollector {
    pub fn record_response(&self, tier: SloTier, latency: Duration) {
        let ms = latency.as_secs_f64() * 1e3;
        lock_clean(&self.latencies_ms).push(ms);
        lock_clean(&self.tier_latencies_ms)[tier.index()].push(ms);
        self.completed.fetch_add(1, Ordering::Relaxed);
        self.completed_by_tier[tier.index()].fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_batch(&self, size: usize) {
        lock_clean(&self.batch_sizes).push(size as f64);
    }

    /// A request dropped by the batcher because its deadline passed.
    pub fn record_expired(&self, tier: SloTier) {
        self.expired_by_tier[tier.index()].fetch_add(1, Ordering::Relaxed);
    }

    /// One zero-downtime weight swap performed.
    pub fn record_hot_swap(&self) {
        self.hot_swaps.fetch_add(1, Ordering::Relaxed);
    }

    /// Final adaptive-window event totals (stored by the batcher thread
    /// on exit).
    pub fn set_window_events(&self, shrinks: u64, widens: u64) {
        self.window_shrinks.store(shrinks, Ordering::Relaxed);
        self.window_widens.store(widens, Ordering::Relaxed);
    }

    /// Admission backlog gauge (high-water mark).
    pub fn observe_queue_depth(&self, depth: usize) {
        self.max_queue_depth.fetch_max(depth, Ordering::Relaxed);
    }

    pub fn completed_count(&self) -> u64 {
        self.completed.load(Ordering::Relaxed)
    }

    /// Fold everything into the final report.  `admission` carries the
    /// queue-side per-tier shed/expiry counters; batcher-side expirations
    /// recorded here are merged in per tier.
    pub fn report(
        &self,
        wall_seconds: f64,
        admission: &TierCounts,
        pool: &PoolReport,
    ) -> ServerStats {
        // Poison-tolerant locks: the report must come out even if a worker
        // thread died mid-record — a partial latency vector beats a wedged
        // shutdown with no report at all.
        let lat = lock_clean(&self.latencies_ms).clone();
        let tier_lat = lock_clean(&self.tier_latencies_ms).clone();
        let batches = lock_clean(&self.batch_sizes).clone();
        let completed = self.completed.load(Ordering::Relaxed);
        let max_batch = batches.iter().fold(0.0f64, |a, &b| a.max(b)) as usize;
        let expired_by_tier: [u64; SloTier::COUNT] = std::array::from_fn(|i| {
            admission.expired[i] + self.expired_by_tier[i].load(Ordering::Relaxed)
        });
        ServerStats {
            completed,
            shed: admission.shed.iter().sum(),
            expired: expired_by_tier.iter().sum(),
            wall_seconds,
            throughput_rps: completed as f64 / wall_seconds.max(1e-12),
            mean_ms: mean(&lat),
            p50_ms: percentile(&lat, 50.0),
            p95_ms: percentile(&lat, 95.0),
            p99_ms: percentile(&lat, 99.0),
            shed_by_tier: admission.shed,
            expired_by_tier,
            completed_by_tier: std::array::from_fn(|i| {
                self.completed_by_tier[i].load(Ordering::Relaxed)
            }),
            tier_p50_ms: std::array::from_fn(|i| percentile(&tier_lat[i], 50.0)),
            tier_p99_ms: std::array::from_fn(|i| percentile(&tier_lat[i], 99.0)),
            window_shrinks: self.window_shrinks.load(Ordering::Relaxed),
            window_widens: self.window_widens.load(Ordering::Relaxed),
            hot_swaps: self.hot_swaps.load(Ordering::Relaxed),
            batches: batches.len() as u64,
            mean_batch: mean(&batches),
            max_batch,
            max_queue_depth: self.max_queue_depth.load(Ordering::Relaxed),
            jobs_executed: pool.jobs_executed,
            per_class_jobs: pool.per_class_jobs,
            inline_fallbacks: pool.inline_fallbacks,
            fused_fc_rows: pool.fused_fc_rows,
            jobs_stolen: pool.jobs_stolen,
            steal_attempts: pool.steal_attempts,
        }
    }
}

/// Final serving report (the serving-side analogue of `rt::RtReport`).
#[derive(Debug, Clone)]
pub struct ServerStats {
    /// Requests fully served.
    pub completed: u64,
    /// Requests shed at admission (bounded lane full), all tiers.
    pub shed: u64,
    /// Requests dropped because their deadline expired pre-dispatch
    /// (admission-pop pruning + batcher pruning), all tiers.
    pub expired: u64,
    pub wall_seconds: f64,
    /// Sustained completions per second over the server's lifetime.
    pub throughput_rps: f64,
    pub mean_ms: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    /// Per-tier admission sheds ([`SloTier`] dense order).
    pub shed_by_tier: [u64; SloTier::COUNT],
    /// Per-tier deadline expirations.
    pub expired_by_tier: [u64; SloTier::COUNT],
    /// Per-tier completions.
    pub completed_by_tier: [u64; SloTier::COUNT],
    /// Per-tier p50 latency (ms; 0 when a tier served nothing).
    pub tier_p50_ms: [f64; SloTier::COUNT],
    /// Per-tier p99 latency (ms).
    pub tier_p99_ms: [f64; SloTier::COUNT],
    /// Adaptive batch-window shrink events.
    pub window_shrinks: u64,
    /// Adaptive batch-window re-widen events.
    pub window_widens: u64,
    /// Zero-downtime weight swaps performed.
    pub hot_swaps: u64,
    /// Micro-batches dispatched.
    pub batches: u64,
    pub mean_batch: f64,
    /// Largest micro-batch observed.
    pub max_batch: usize,
    /// Admission backlog high-water mark.
    pub max_queue_depth: usize,
    pub jobs_executed: u64,
    /// Jobs per class ([`JobClass`] dense order).
    pub per_class_jobs: [u64; JobClass::COUNT],
    /// Jobs computed inline because no pool member supported the class —
    /// zero on any pool with a NEON-class member.
    pub inline_fallbacks: u64,
    /// Requests whose FC work was computed fused (`fc-gemm-batch`),
    /// counting the degenerate inline last resort too.  With
    /// `per_class_jobs` this splits FC work into fused vs unfused; on a
    /// pool that dispatches (any realistic one), fused rows ÷ fused jobs
    /// is the realized amortization width.
    pub fused_fc_rows: u64,
    pub jobs_stolen: u64,
    pub steal_attempts: u64,
}

impl ServerStats {
    /// Markdown table (same format as the experiment reports).
    pub fn render(&self) -> String {
        let mut t = Table::new(&["metric", "value"]);
        t.row(vec!["requests completed".into(), self.completed.to_string()]);
        t.row(vec!["requests shed".into(), self.shed.to_string()]);
        t.row(vec!["requests expired".into(), self.expired.to_string()]);
        t.row(vec!["wall (s)".into(), fmt(self.wall_seconds)]);
        t.row(vec!["throughput (req/s)".into(), fmt(self.throughput_rps)]);
        t.row(vec!["latency mean (ms)".into(), fmt(self.mean_ms)]);
        t.row(vec!["latency p50 (ms)".into(), fmt(self.p50_ms)]);
        t.row(vec!["latency p95 (ms)".into(), fmt(self.p95_ms)]);
        t.row(vec!["latency p99 (ms)".into(), fmt(self.p99_ms)]);
        for tier in SloTier::ALL {
            let i = tier.index();
            t.row(vec![
                format!("tier {} done/shed/expired", tier.label()),
                format!(
                    "{}/{}/{}",
                    self.completed_by_tier[i], self.shed_by_tier[i], self.expired_by_tier[i]
                ),
            ]);
            t.row(vec![
                format!("tier {} p50/p99 (ms)", tier.label()),
                format!("{}/{}", fmt(self.tier_p50_ms[i]), fmt(self.tier_p99_ms[i])),
            ]);
        }
        t.row(vec![
            "window shrinks/widens".into(),
            format!("{}/{}", self.window_shrinks, self.window_widens),
        ]);
        t.row(vec!["hot swaps".into(), self.hot_swaps.to_string()]);
        t.row(vec!["micro-batches".into(), self.batches.to_string()]);
        t.row(vec!["mean batch size".into(), fmt(self.mean_batch)]);
        t.row(vec!["max batch size".into(), self.max_batch.to_string()]);
        t.row(vec![
            "max queue depth".into(),
            self.max_queue_depth.to_string(),
        ]);
        t.row(vec!["jobs executed".into(), self.jobs_executed.to_string()]);
        for class in JobClass::ALL {
            t.row(vec![
                format!("jobs {}", class.label()),
                self.per_class_jobs[class.index()].to_string(),
            ]);
        }
        t.row(vec![
            "jobs inline-fallback".into(),
            self.inline_fallbacks.to_string(),
        ]);
        t.row(vec![
            "fc rows fused".into(),
            self.fused_fc_rows.to_string(),
        ]);
        t.row(vec!["jobs stolen".into(), self.jobs_stolen.to_string()]);
        t.row(vec![
            "steal attempts".into(),
            self.steal_attempts.to_string(),
        ]);
        t.render()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_and_counters_roll_up() {
        let c = StatsCollector::default();
        for i in 1..=100 {
            c.record_response(SloTier::Standard, Duration::from_millis(i));
        }
        c.record_batch(2);
        c.record_batch(4);
        c.record_expired(SloTier::Standard);
        c.observe_queue_depth(3);
        c.observe_queue_depth(9);
        c.observe_queue_depth(5);
        let pool = PoolReport {
            jobs_executed: 42,
            per_accel_jobs: vec![42],
            per_class_jobs: [38, 1, 1, 2],
            fused_fc_rows: 8,
            steal_attempts: 7,
            jobs_stolen: 3,
            ..Default::default()
        };
        let admission = TierCounts {
            shed: [0, 5, 0],
            expired: [0, 0, 0],
        };
        let s = c.report(10.0, &admission, &pool);
        assert_eq!(s.completed, 100);
        assert_eq!(s.shed, 5);
        assert_eq!(s.expired, 1);
        assert!((s.throughput_rps - 10.0).abs() < 1e-9);
        assert!((s.p50_ms - 50.0).abs() <= 1.0);
        assert!(s.p99_ms >= 99.0);
        assert_eq!(s.max_batch, 4);
        assert_eq!(s.batches, 2);
        assert_eq!(s.max_queue_depth, 9);
        assert_eq!(s.jobs_executed, 42);
        assert_eq!(s.per_class_jobs, [38, 1, 1, 2]);
        assert_eq!(s.fused_fc_rows, 8);
        let rendered = s.render();
        assert!(rendered.contains("latency p99"));
        assert!(rendered.contains("max batch size"));
        assert!(rendered.contains("jobs fc-gemm"));
        assert!(rendered.contains("jobs fc-gemm-batch"));
        assert!(rendered.contains("fc rows fused"));
    }

    #[test]
    fn tier_counters_split_and_merge() {
        let c = StatsCollector::default();
        c.record_response(SloTier::Interactive, Duration::from_millis(5));
        c.record_response(SloTier::Interactive, Duration::from_millis(7));
        c.record_response(SloTier::Batch, Duration::from_millis(400));
        // One batcher-side expiry + admission-side counters to merge.
        c.record_expired(SloTier::Interactive);
        c.record_hot_swap();
        c.set_window_events(3, 2);
        let admission = TierCounts {
            shed: [0, 0, 11],
            expired: [2, 0, 0],
        };
        let s = c.report(1.0, &admission, &PoolReport::default());
        assert_eq!(s.completed, 3);
        assert_eq!(s.completed_by_tier, [2, 0, 1]);
        assert_eq!(s.shed, 11);
        assert_eq!(s.shed_by_tier, [0, 0, 11]);
        assert_eq!(s.expired, 3, "admission + batcher expirations merge");
        assert_eq!(s.expired_by_tier, [3, 0, 0]);
        assert!(s.tier_p99_ms[SloTier::Interactive.index()] <= 7.5);
        assert!(s.tier_p50_ms[SloTier::Batch.index()] >= 399.0);
        assert_eq!(s.tier_p50_ms[SloTier::Standard.index()], 0.0);
        assert_eq!(s.window_shrinks, 3);
        assert_eq!(s.window_widens, 2);
        assert_eq!(s.hot_swaps, 1);
        let rendered = s.render();
        assert!(rendered.contains("tier interactive done/shed/expired"));
        assert!(rendered.contains("tier batch p50/p99"));
        assert!(rendered.contains("hot swaps"));
        assert!(rendered.contains("window shrinks/widens"));
    }
}
