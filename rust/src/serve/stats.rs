//! Serving metrics: tail latency, sustained throughput, batch-size and
//! shed accounting — computed through `util::stats` and rendered with the
//! shared table builder.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::Duration;

use crate::mm::job::JobClass;
use crate::rt::PoolReport;
use crate::util::bench::{fmt, Table};
use crate::util::stats::{mean, percentile};

/// Thread-safe sample sink shared by the batcher / completion threads.
#[derive(Default)]
pub struct StatsCollector {
    latencies_ms: Mutex<Vec<f64>>,
    batch_sizes: Mutex<Vec<f64>>,
    completed: AtomicU64,
    expired: AtomicU64,
    max_queue_depth: AtomicUsize,
}

impl StatsCollector {
    pub fn record_response(&self, latency: Duration) {
        self.latencies_ms
            .lock()
            .unwrap()
            .push(latency.as_secs_f64() * 1e3);
        self.completed.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_batch(&self, size: usize) {
        self.batch_sizes.lock().unwrap().push(size as f64);
    }

    /// A request dropped by the batcher because its deadline passed.
    pub fn record_expired(&self) {
        self.expired.fetch_add(1, Ordering::Relaxed);
    }

    /// Admission backlog gauge (high-water mark).
    pub fn observe_queue_depth(&self, depth: usize) {
        self.max_queue_depth.fetch_max(depth, Ordering::Relaxed);
    }

    pub fn completed_count(&self) -> u64 {
        self.completed.load(Ordering::Relaxed)
    }

    /// Fold everything into the final report.
    pub fn report(&self, wall_seconds: f64, shed: u64, pool: &PoolReport) -> ServerStats {
        let lat = self.latencies_ms.lock().unwrap().clone();
        let batches = self.batch_sizes.lock().unwrap().clone();
        let completed = self.completed.load(Ordering::Relaxed);
        let max_batch = batches.iter().fold(0.0f64, |a, &b| a.max(b)) as usize;
        ServerStats {
            completed,
            shed,
            expired: self.expired.load(Ordering::Relaxed),
            wall_seconds,
            throughput_rps: completed as f64 / wall_seconds.max(1e-12),
            mean_ms: mean(&lat),
            p50_ms: percentile(&lat, 50.0),
            p95_ms: percentile(&lat, 95.0),
            p99_ms: percentile(&lat, 99.0),
            batches: batches.len() as u64,
            mean_batch: mean(&batches),
            max_batch,
            max_queue_depth: self.max_queue_depth.load(Ordering::Relaxed),
            jobs_executed: pool.jobs_executed,
            per_class_jobs: pool.per_class_jobs,
            inline_fallbacks: pool.inline_fallbacks,
            fused_fc_rows: pool.fused_fc_rows,
            jobs_stolen: pool.jobs_stolen,
            steal_attempts: pool.steal_attempts,
        }
    }
}

/// Final serving report (the serving-side analogue of `rt::RtReport`).
#[derive(Debug, Clone)]
pub struct ServerStats {
    /// Requests fully served.
    pub completed: u64,
    /// Requests shed at admission (bounded queue full).
    pub shed: u64,
    /// Requests dropped because their deadline expired pre-dispatch.
    pub expired: u64,
    pub wall_seconds: f64,
    /// Sustained completions per second over the server's lifetime.
    pub throughput_rps: f64,
    pub mean_ms: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    /// Micro-batches dispatched.
    pub batches: u64,
    pub mean_batch: f64,
    /// Largest micro-batch observed.
    pub max_batch: usize,
    /// Admission backlog high-water mark.
    pub max_queue_depth: usize,
    pub jobs_executed: u64,
    /// Jobs per class ([`JobClass`] dense order).
    pub per_class_jobs: [u64; JobClass::COUNT],
    /// Jobs computed inline because no pool member supported the class —
    /// zero on any pool with a NEON-class member.
    pub inline_fallbacks: u64,
    /// Requests whose FC work was computed fused (`fc-gemm-batch`),
    /// counting the degenerate inline last resort too.  With
    /// `per_class_jobs` this splits FC work into fused vs unfused; on a
    /// pool that dispatches (any realistic one), fused rows ÷ fused jobs
    /// is the realized amortization width.
    pub fused_fc_rows: u64,
    pub jobs_stolen: u64,
    pub steal_attempts: u64,
}

impl ServerStats {
    /// Markdown table (same format as the experiment reports).
    pub fn render(&self) -> String {
        let mut t = Table::new(&["metric", "value"]);
        t.row(vec!["requests completed".into(), self.completed.to_string()]);
        t.row(vec!["requests shed".into(), self.shed.to_string()]);
        t.row(vec!["requests expired".into(), self.expired.to_string()]);
        t.row(vec!["wall (s)".into(), fmt(self.wall_seconds)]);
        t.row(vec!["throughput (req/s)".into(), fmt(self.throughput_rps)]);
        t.row(vec!["latency mean (ms)".into(), fmt(self.mean_ms)]);
        t.row(vec!["latency p50 (ms)".into(), fmt(self.p50_ms)]);
        t.row(vec!["latency p95 (ms)".into(), fmt(self.p95_ms)]);
        t.row(vec!["latency p99 (ms)".into(), fmt(self.p99_ms)]);
        t.row(vec!["micro-batches".into(), self.batches.to_string()]);
        t.row(vec!["mean batch size".into(), fmt(self.mean_batch)]);
        t.row(vec!["max batch size".into(), self.max_batch.to_string()]);
        t.row(vec![
            "max queue depth".into(),
            self.max_queue_depth.to_string(),
        ]);
        t.row(vec!["jobs executed".into(), self.jobs_executed.to_string()]);
        for class in JobClass::ALL {
            t.row(vec![
                format!("jobs {}", class.label()),
                self.per_class_jobs[class.index()].to_string(),
            ]);
        }
        t.row(vec![
            "jobs inline-fallback".into(),
            self.inline_fallbacks.to_string(),
        ]);
        t.row(vec![
            "fc rows fused".into(),
            self.fused_fc_rows.to_string(),
        ]);
        t.row(vec!["jobs stolen".into(), self.jobs_stolen.to_string()]);
        t.row(vec![
            "steal attempts".into(),
            self.steal_attempts.to_string(),
        ]);
        t.render()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_and_counters_roll_up() {
        let c = StatsCollector::default();
        for i in 1..=100 {
            c.record_response(Duration::from_millis(i));
        }
        c.record_batch(2);
        c.record_batch(4);
        c.record_expired();
        c.observe_queue_depth(3);
        c.observe_queue_depth(9);
        c.observe_queue_depth(5);
        let pool = PoolReport {
            jobs_executed: 42,
            per_accel_jobs: vec![42],
            per_class_jobs: [38, 1, 1, 2],
            fused_fc_rows: 8,
            steal_attempts: 7,
            jobs_stolen: 3,
            ..Default::default()
        };
        let s = c.report(10.0, 5, &pool);
        assert_eq!(s.completed, 100);
        assert_eq!(s.shed, 5);
        assert_eq!(s.expired, 1);
        assert!((s.throughput_rps - 10.0).abs() < 1e-9);
        assert!((s.p50_ms - 50.0).abs() <= 1.0);
        assert!(s.p99_ms >= 99.0);
        assert_eq!(s.max_batch, 4);
        assert_eq!(s.batches, 2);
        assert_eq!(s.max_queue_depth, 9);
        assert_eq!(s.jobs_executed, 42);
        assert_eq!(s.per_class_jobs, [38, 1, 1, 2]);
        assert_eq!(s.fused_fc_rows, 8);
        let rendered = s.render();
        assert!(rendered.contains("latency p99"));
        assert!(rendered.contains("max batch size"));
        assert!(rendered.contains("jobs fc-gemm"));
        assert!(rendered.contains("jobs fc-gemm-batch"));
        assert!(rendered.contains("fc rows fused"));
    }
}
