//! Bounded, stream-fair admission control with shed-on-overload semantics.
//!
//! A serving front-end that blocks producers on overload just moves the
//! queue into the clients; one that drops newest-first starves whoever is
//! unlucky.  This queue does neither: depth is bounded (`submit` sheds and
//! reports), and the consumer side drains streams round-robin so one
//! chatty client cannot starve the others.

use std::collections::{BTreeMap, VecDeque};
use std::ops::Bound;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Condvar, Mutex};
use std::time::Duration;

use super::request::Request;

struct Inner {
    per_stream: BTreeMap<usize, VecDeque<Request>>,
    len: usize,
    last_served: Option<usize>,
    closed: bool,
}

/// MPMC admission queue: producers are client streams, the consumer is the
/// micro-batcher thread.
pub struct AdmissionQueue {
    inner: Mutex<Inner>,
    not_empty: Condvar,
    capacity: usize,
    admitted: AtomicU64,
    shed: AtomicU64,
}

impl AdmissionQueue {
    pub fn new(capacity: usize) -> AdmissionQueue {
        AdmissionQueue {
            inner: Mutex::new(Inner {
                per_stream: BTreeMap::new(),
                len: 0,
                last_served: None,
                closed: false,
            }),
            not_empty: Condvar::new(),
            capacity: capacity.max(1),
            admitted: AtomicU64::new(0),
            shed: AtomicU64::new(0),
        }
    }

    /// Admit or shed.  Returns false when the queue is full or closed (the
    /// request is dropped and counted — overload never blocks a client).
    pub fn submit(&self, req: Request) -> bool {
        let mut g = self.inner.lock().unwrap();
        if g.closed || g.len >= self.capacity {
            drop(g);
            self.shed.fetch_add(1, Ordering::Relaxed);
            return false;
        }
        g.per_stream.entry(req.stream_id).or_default().push_back(req);
        g.len += 1;
        drop(g);
        self.admitted.fetch_add(1, Ordering::Relaxed);
        self.not_empty.notify_all();
        true
    }

    /// Fair pop: round-robin across streams (within a stream, FIFO).
    /// `Ok(None)` = closed and drained, `Err(())` = timed out.
    pub fn pop_timeout(&self, timeout: Duration) -> Result<Option<Request>, ()> {
        let mut g = self.inner.lock().unwrap();
        loop {
            if g.len > 0 {
                return Ok(Some(take_fair(&mut g)));
            }
            if g.closed {
                return Ok(None);
            }
            let (guard, res) = self.not_empty.wait_timeout(g, timeout).unwrap();
            g = guard;
            if res.timed_out() {
                if g.len > 0 {
                    return Ok(Some(take_fair(&mut g)));
                }
                if g.closed {
                    return Ok(None);
                }
                return Err(());
            }
        }
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().len
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Close: submissions shed, pops drain the remainder then return None.
    pub fn close(&self) {
        self.inner.lock().unwrap().closed = true;
        self.not_empty.notify_all();
    }

    pub fn admitted_count(&self) -> u64 {
        self.admitted.load(Ordering::Relaxed)
    }

    pub fn shed_count(&self) -> u64 {
        self.shed.load(Ordering::Relaxed)
    }
}

/// Pick the next stream after `last_served` (wrapping), pop its oldest
/// request.  Invariant: every map entry holds a non-empty deque.
fn take_fair(g: &mut Inner) -> Request {
    let next_sid = match g.last_served {
        Some(last) => g
            .per_stream
            .range((Bound::Excluded(last), Bound::Unbounded))
            .map(|(sid, _)| *sid)
            .next(),
        None => None,
    };
    let sid = match next_sid {
        Some(sid) => sid,
        None => *g.per_stream.keys().next().expect("len > 0 implies a stream"),
    };
    let queue = g.per_stream.get_mut(&sid).expect("stream present");
    let req = queue.pop_front().expect("stream queue non-empty");
    if queue.is_empty() {
        g.per_stream.remove(&sid);
    }
    g.last_served = Some(sid);
    g.len -= 1;
    req
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;

    fn req(stream_id: usize, seq: u64) -> Request {
        Request::new(stream_id, seq, 0, Tensor::scalar(0.0))
    }

    fn pop(q: &AdmissionQueue) -> Request {
        q.pop_timeout(Duration::from_millis(100)).unwrap().unwrap()
    }

    #[test]
    fn sheds_at_capacity_without_blocking() {
        let q = AdmissionQueue::new(2);
        assert!(q.submit(req(0, 0)));
        assert!(q.submit(req(0, 1)));
        assert!(!q.submit(req(0, 2)), "third submit must shed");
        assert_eq!(q.admitted_count(), 2);
        assert_eq!(q.shed_count(), 1);
        // Draining frees capacity again.
        let _ = pop(&q);
        assert!(q.submit(req(0, 3)));
    }

    #[test]
    fn round_robin_across_streams() {
        let q = AdmissionQueue::new(16);
        // Stream 0 floods; stream 1 and 2 trickle.
        for seq in 0..4 {
            q.submit(req(0, seq));
        }
        q.submit(req(1, 0));
        q.submit(req(2, 0));
        let order: Vec<usize> = (0..6).map(|_| pop(&q).stream_id).collect();
        // Fair interleave: each of the 3 streams served within the first 3.
        let mut first3 = order[..3].to_vec();
        first3.sort_unstable();
        assert_eq!(first3, vec![0, 1, 2], "unfair order: {order:?}");
        // Per-stream FIFO preserved for the flood.
        let s0: Vec<u64> = {
            let q2 = AdmissionQueue::new(16);
            for seq in 0..3 {
                q2.submit(req(0, seq));
            }
            (0..3).map(|_| pop(&q2).seq).collect()
        };
        assert_eq!(s0, vec![0, 1, 2]);
    }

    #[test]
    fn close_drains_then_ends() {
        let q = AdmissionQueue::new(4);
        q.submit(req(0, 0));
        q.close();
        assert!(!q.submit(req(0, 1)), "post-close submit sheds");
        assert_eq!(pop(&q).seq, 0);
        assert!(q.pop_timeout(Duration::from_millis(5)).unwrap().is_none());
    }

    #[test]
    fn pop_times_out_when_empty() {
        let q = AdmissionQueue::new(4);
        assert!(q.pop_timeout(Duration::from_millis(5)).is_err());
    }
}
