//! Bounded, SLO-tiered, per-network-lane admission control with
//! shed-on-overload semantics.
//!
//! A serving front-end that blocks producers on overload just moves the
//! queue into the clients; one that drops newest-first starves whoever is
//! unlucky.  This queue does neither: depth is bounded (`submit` sheds and
//! reports), and the consumer side drains fairly so one chatty client
//! cannot starve the others.
//!
//! Admission is organized as **one lane per (network, SLO tier)**, created
//! on first use, each with its own depth bound — so bulk batch-tier
//! traffic can fill its own lane to the brim without ever causing an
//! interactive-tier shed (the tiers never share a depth budget).  Pops
//! follow strict tier precedence ([`SloTier::ALL`] order) with one escape
//! hatch: every `escape_every`-th pop serves the batch lane even while
//! higher tiers have work, so bulk traffic is starvation-proof under a
//! sustained foreground flood.
//!
//! Inside a lane, requests that carry a deadline pop in EDF order
//! (earliest absolute due time first, arrival order as the deterministic
//! tie-break) and always precede deadline-less requests (a finite due time
//! sorts before an infinite one); deadline-less requests keep the original
//! stream-fair round-robin.  Requests whose deadline already lapsed are
//! pruned **at pop time** — counted per tier, never handed to the batcher.
//!
//! A stalled network backs up — and sheds — only its own lanes, while the
//! other networks' traffic keeps flowing: the consumer passes an
//! eligibility filter (`pop_timeout_eligible`) naming the networks whose
//! pipelines currently have capacity, and the pop round-robins across
//! eligible networks within the chosen tier.

use std::collections::{BTreeMap, BinaryHeap, VecDeque};
use std::ops::Bound;
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{Duration, Instant};

use crate::util::sync::{lock_clean, wait_timeout_clean, Condvar, Mutex};

use super::request::{Request, SloTier};
use super::stats::TierCounts;

/// EDF heap entry: max-heap on reversed (due, arrival) yields the
/// earliest due time, oldest arrival first on ties — deterministic for
/// the virtual-time tests.
struct EdfEntry {
    due: Instant,
    arrival: u64,
    req: Request,
}

impl PartialEq for EdfEntry {
    fn eq(&self, other: &Self) -> bool {
        self.due == other.due && self.arrival == other.arrival
    }
}

impl Eq for EdfEntry {}

impl PartialOrd for EdfEntry {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for EdfEntry {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        other
            .due
            .cmp(&self.due)
            .then_with(|| other.arrival.cmp(&self.arrival))
    }
}

/// Stream-fair FIFO for deadline-less requests: round-robin across
/// streams, FIFO within a stream.
#[derive(Default)]
struct StreamFair {
    per_stream: BTreeMap<usize, VecDeque<Request>>,
    len: usize,
    last_served: Option<usize>,
}

impl StreamFair {
    fn push(&mut self, req: Request) {
        self.per_stream
            .entry(req.stream_id)
            .or_default()
            .push_back(req);
        self.len += 1;
    }

    /// Round-robin across streams (within a stream, FIFO).
    fn take_fair(&mut self) -> Request {
        let next_sid = match self.last_served {
            Some(last) => self
                .per_stream
                .range((Bound::Excluded(last), Bound::Unbounded))
                .map(|(sid, _)| *sid)
                .next(),
            None => None,
        };
        let sid = match next_sid {
            Some(sid) => sid,
            None => *self
                .per_stream
                .keys()
                .next()
                .expect("len > 0 implies a stream"),
        };
        let queue = self.per_stream.get_mut(&sid).expect("stream present");
        let req = queue.pop_front().expect("stream queue non-empty");
        if queue.is_empty() {
            self.per_stream.remove(&sid);
        }
        self.last_served = Some(sid);
        self.len -= 1;
        req
    }
}

/// One (network, tier) lane: EDF heap for deadlined requests, stream-fair
/// FIFO for the rest.  Deadlined requests always pop first — a finite due
/// time precedes an infinite one.
#[derive(Default)]
struct TierLane {
    edf: BinaryHeap<EdfEntry>,
    fair: StreamFair,
}

impl TierLane {
    fn len(&self) -> usize {
        self.edf.len() + self.fair.len
    }

    fn push(&mut self, req: Request, arrival: u64) {
        match req.due() {
            Some(due) => self.edf.push(EdfEntry { due, arrival, req }),
            None => self.fair.push(req),
        }
    }

    fn pop(&mut self) -> Option<Request> {
        if let Some(entry) = self.edf.pop() {
            return Some(entry.req);
        }
        (self.fair.len > 0).then(|| self.fair.take_fair())
    }
}

/// One network's lanes, one per SLO tier.
#[derive(Default)]
struct NetLane {
    tiers: [TierLane; SloTier::COUNT],
}

struct Inner {
    lanes: BTreeMap<usize, NetLane>,
    total_len: usize,
    /// Per-tier network round-robin cursor.
    last_served_net: [Option<usize>; SloTier::COUNT],
    /// Live requests handed out (escape-ratio accounting; pruned
    /// expirations don't count — they never reach the batcher).
    pops: u64,
    /// Monotonic admission counter (EDF tie-break).
    arrivals: u64,
    closed: bool,
}

/// MPMC admission queue: producers are client streams, the consumer is the
/// micro-batcher thread.  Capacity is enforced *per (network, tier) lane*.
pub struct AdmissionQueue {
    inner: Mutex<Inner>,
    not_empty: Condvar,
    lane_capacity: usize,
    escape_every: u64,
    admitted: AtomicU64,
    shed: [AtomicU64; SloTier::COUNT],
    expired: [AtomicU64; SloTier::COUNT],
}

impl AdmissionQueue {
    /// `lane_capacity` bounds each (network, tier) lane independently.
    /// The batch-lane escape ratio defaults to the platform `[serving]`
    /// default; override it with [`AdmissionQueue::with_escape_every`].
    pub fn new(lane_capacity: usize) -> AdmissionQueue {
        AdmissionQueue {
            inner: Mutex::new(Inner {
                lanes: BTreeMap::new(),
                total_len: 0,
                last_served_net: [None; SloTier::COUNT],
                pops: 0,
                arrivals: 0,
                closed: false,
            }),
            not_empty: Condvar::new(),
            lane_capacity: lane_capacity.max(1),
            escape_every: crate::config::ServeCfg::default().batch_escape_every,
            admitted: AtomicU64::new(0),
            shed: std::array::from_fn(|_| AtomicU64::new(0)),
            expired: std::array::from_fn(|_| AtomicU64::new(0)),
        }
    }

    /// Serve the batch lane on every `n`-th pop even while higher tiers
    /// have work (0 = strict precedence, batch runs only when the higher
    /// lanes are drained).
    pub fn with_escape_every(mut self, n: u64) -> AdmissionQueue {
        self.escape_every = n;
        self
    }

    pub fn escape_every(&self) -> u64 {
        self.escape_every
    }

    /// Admit or shed.  Returns false when the request's (network, tier)
    /// lane is full or the queue is closed (the request is dropped and
    /// counted — overload never blocks a client, never spills into other
    /// networks' lanes, and never lets bulk tiers displace foreground
    /// tiers: each tier owns its own depth budget).
    pub fn submit(&self, req: Request) -> bool {
        let ti = req.tier.index();
        let mut g = lock_clean(&self.inner);
        if g.closed {
            drop(g);
            self.shed[ti].fetch_add(1, Ordering::Relaxed);
            return false;
        }
        g.arrivals += 1;
        let arrival = g.arrivals;
        let lane = g.lanes.entry(req.net_id).or_default();
        if lane.tiers[ti].len() >= self.lane_capacity {
            drop(g);
            self.shed[ti].fetch_add(1, Ordering::Relaxed);
            return false;
        }
        lane.tiers[ti].push(req, arrival);
        g.total_len += 1;
        drop(g);
        self.admitted.fetch_add(1, Ordering::Relaxed);
        self.not_empty.notify_all();
        true
    }

    /// Tiered pop across all lanes: `Ok(None)` = closed and drained,
    /// `Err(())` = timed out.
    pub fn pop_timeout(&self, timeout: Duration) -> Result<Option<Request>, ()> {
        self.pop_timeout_filtered(timeout, |_| true)
    }

    /// Tiered pop restricted to eligible networks (`eligible[net_id]`;
    /// nets beyond the slice count as eligible).  Requests of ineligible
    /// lanes stay queued — their backpressure never blocks this pop.
    pub fn pop_timeout_eligible(
        &self,
        timeout: Duration,
        eligible: &[bool],
    ) -> Result<Option<Request>, ()> {
        self.pop_timeout_filtered(timeout, |net| *eligible.get(net).unwrap_or(&true))
    }

    /// Non-blocking pop at an explicit instant — the virtual-time entry
    /// point the deterministic tier tests and the tiered-arrival
    /// simulator drive (expiry pruning happens against `now`, not the
    /// wall clock).
    pub fn try_pop_at(&self, now: Instant) -> Option<Request> {
        let mut g = lock_clean(&self.inner);
        self.take_at(&mut g, &|_| true, now)
    }

    /// [`AdmissionQueue::try_pop_at`] with a network eligibility filter.
    pub fn try_pop_at_eligible(&self, now: Instant, eligible: &[bool]) -> Option<Request> {
        let mut g = lock_clean(&self.inner);
        self.take_at(&mut g, &|net| *eligible.get(net).unwrap_or(&true), now)
    }

    fn pop_timeout_filtered(
        &self,
        timeout: Duration,
        eligible: impl Fn(usize) -> bool,
    ) -> Result<Option<Request>, ()> {
        // Fixed deadline, not a per-wakeup timeout: submissions into
        // *ineligible* lanes notify this condvar without producing a
        // takeable request, and re-arming the full timeout on each such
        // wakeup would postpone the caller's batch-window deadline for as
        // long as the stalled lane keeps receiving traffic.
        let deadline = Instant::now() + timeout;
        let mut g = lock_clean(&self.inner);
        loop {
            if let Some(req) = self.take_at(&mut g, &eligible, Instant::now()) {
                return Ok(Some(req));
            }
            if g.closed && g.total_len == 0 {
                return Ok(None);
            }
            let remaining = deadline.saturating_duration_since(Instant::now());
            if remaining.is_zero() {
                return Err(());
            }
            let (guard, _timed_out) = wait_timeout_clean(&self.not_empty, g, remaining);
            g = guard;
        }
    }

    /// The tiered take: pick the tier (strict precedence, batch-escape
    /// every Nth pop), round-robin across eligible networks within it,
    /// EDF/stream-fair within the lane — and prune already-expired
    /// requests on the way out (counted per tier, never returned, never
    /// charged against the escape ratio).
    fn take_at(
        &self,
        g: &mut Inner,
        eligible: &impl Fn(usize) -> bool,
        now: Instant,
    ) -> Option<Request> {
        loop {
            if g.total_len == 0 {
                return None;
            }
            let tier_nonempty = |g: &Inner, ti: usize| {
                g.lanes
                    .iter()
                    .any(|(id, lane)| lane.tiers[ti].len() > 0 && eligible(*id))
            };
            let batch_ti = SloTier::Batch.index();
            let escape_due =
                self.escape_every > 0 && (g.pops + 1) % self.escape_every == 0;
            let ti = if escape_due && tier_nonempty(g, batch_ti) {
                batch_ti
            } else {
                match (0..SloTier::COUNT).find(|&ti| tier_nonempty(g, ti)) {
                    Some(ti) => ti,
                    None => return None,
                }
            };
            let candidate = |(id, lane): (&usize, &NetLane)| -> Option<usize> {
                (lane.tiers[ti].len() > 0 && eligible(*id)).then_some(*id)
            };
            let net = match g.last_served_net[ti] {
                Some(last) => g
                    .lanes
                    .range((Bound::Excluded(last), Bound::Unbounded))
                    .find_map(candidate)
                    .or_else(|| g.lanes.iter().find_map(candidate)),
                None => g.lanes.iter().find_map(candidate),
            }
            .expect("non-empty tier implies a candidate lane");
            let lane = g.lanes.get_mut(&net).expect("lane present");
            let req = lane.tiers[ti].pop().expect("candidate lane non-empty");
            g.last_served_net[ti] = Some(net);
            g.total_len -= 1;
            if req.is_expired(now) {
                // Prune at pop: a lapsed request never reaches the
                // batcher and never consumes a served-pop slot.
                self.expired[ti].fetch_add(1, Ordering::Relaxed);
                continue;
            }
            g.pops += 1;
            return Some(req);
        }
    }

    pub fn len(&self) -> usize {
        lock_clean(&self.inner).total_len
    }

    /// Queued requests across one network's tier lanes.
    pub fn lane_len(&self, net_id: usize) -> usize {
        lock_clean(&self.inner)
            .lanes
            .get(&net_id)
            .map_or(0, |l| l.tiers.iter().map(|t| t.len()).sum())
    }

    /// Queued requests of one (network, tier) lane.
    pub fn tier_len(&self, net_id: usize, tier: SloTier) -> usize {
        lock_clean(&self.inner)
            .lanes
            .get(&net_id)
            .map_or(0, |l| l.tiers[tier.index()].len())
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Close: submissions shed, pops drain the remainder then return None.
    /// Broadcast so every batcher thread parked in `pop_timeout` observes
    /// the close rather than one lucky waiter.
    pub fn close(&self) {
        lock_clean(&self.inner).closed = true;
        self.not_empty.notify_all();
    }

    pub fn admitted_count(&self) -> u64 {
        self.admitted.load(Ordering::Relaxed)
    }

    /// Total sheds across tiers.
    pub fn shed_count(&self) -> u64 {
        self.shed.iter().map(|c| c.load(Ordering::Relaxed)).sum()
    }

    pub fn shed_by_tier(&self) -> [u64; SloTier::COUNT] {
        std::array::from_fn(|i| self.shed[i].load(Ordering::Relaxed))
    }

    /// Requests pruned at pop time because their deadline had lapsed.
    pub fn expired_by_tier(&self) -> [u64; SloTier::COUNT] {
        std::array::from_fn(|i| self.expired[i].load(Ordering::Relaxed))
    }

    /// Per-tier shed + pop-pruned-expiry snapshot for the stats report.
    pub fn tier_counts(&self) -> TierCounts {
        TierCounts {
            shed: self.shed_by_tier(),
            expired: self.expired_by_tier(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;

    fn req(stream_id: usize, seq: u64) -> Request {
        Request::new(stream_id, seq, 0, Tensor::scalar(0.0))
    }

    fn req_net(net_id: usize, stream_id: usize, seq: u64) -> Request {
        Request::new(stream_id, seq, net_id, Tensor::scalar(0.0))
    }

    fn req_tier(tier: SloTier, stream_id: usize, seq: u64) -> Request {
        Request::new(stream_id, seq, 0, Tensor::scalar(0.0)).with_tier(tier)
    }

    fn pop(q: &AdmissionQueue) -> Request {
        q.pop_timeout(Duration::from_millis(100)).unwrap().unwrap()
    }

    #[test]
    fn sheds_at_capacity_without_blocking() {
        let q = AdmissionQueue::new(2);
        assert!(q.submit(req(0, 0)));
        assert!(q.submit(req(0, 1)));
        assert!(!q.submit(req(0, 2)), "third submit must shed");
        assert_eq!(q.admitted_count(), 2);
        assert_eq!(q.shed_count(), 1);
        assert_eq!(q.shed_by_tier(), [0, 1, 0], "standard-tier shed");
        // Draining frees capacity again.
        let _ = pop(&q);
        assert!(q.submit(req(0, 3)));
    }

    #[test]
    fn lanes_isolate_per_net_overload() {
        let q = AdmissionQueue::new(2);
        // Net 0 floods its lane full.
        assert!(q.submit(req_net(0, 0, 0)));
        assert!(q.submit(req_net(0, 0, 1)));
        assert!(!q.submit(req_net(0, 0, 2)), "net 0 lane full");
        // Net 1 still has its own depth budget.
        assert!(q.submit(req_net(1, 1, 0)));
        assert!(q.submit(req_net(1, 1, 1)));
        assert_eq!(q.lane_len(0), 2);
        assert_eq!(q.lane_len(1), 2);
        assert_eq!(q.len(), 4);
    }

    #[test]
    fn tier_lanes_isolate_depth_budgets() {
        let q = AdmissionQueue::new(2);
        // Batch tier floods its lane full…
        assert!(q.submit(req_tier(SloTier::Batch, 0, 0)));
        assert!(q.submit(req_tier(SloTier::Batch, 0, 1)));
        assert!(!q.submit(req_tier(SloTier::Batch, 0, 2)), "batch lane full");
        // …and interactive still has its own untouched depth budget.
        assert!(q.submit(req_tier(SloTier::Interactive, 1, 0)));
        assert!(q.submit(req_tier(SloTier::Interactive, 1, 1)));
        assert_eq!(q.tier_len(0, SloTier::Batch), 2);
        assert_eq!(q.tier_len(0, SloTier::Interactive), 2);
        assert_eq!(q.shed_by_tier(), [0, 0, 1]);
    }

    #[test]
    fn strict_tier_precedence_between_escapes() {
        // Escape disabled: interactive > standard > batch, always.
        let q = AdmissionQueue::new(16).with_escape_every(0);
        q.submit(req_tier(SloTier::Batch, 0, 0));
        q.submit(req_tier(SloTier::Standard, 1, 0));
        q.submit(req_tier(SloTier::Interactive, 2, 0));
        q.submit(req_tier(SloTier::Interactive, 2, 1));
        let tiers: Vec<SloTier> = (0..4).map(|_| pop(&q).tier).collect();
        assert_eq!(
            tiers,
            vec![
                SloTier::Interactive,
                SloTier::Interactive,
                SloTier::Standard,
                SloTier::Batch
            ]
        );
    }

    #[test]
    fn batch_escape_serves_every_nth_pop() {
        let q = AdmissionQueue::new(64).with_escape_every(3);
        for seq in 0..6 {
            q.submit(req_tier(SloTier::Interactive, 0, seq));
        }
        for seq in 0..2 {
            q.submit(req_tier(SloTier::Batch, 1, seq));
        }
        let tiers: Vec<SloTier> = (0..8).map(|_| pop(&q).tier).collect();
        // Pops 3 and 6 (1-indexed) are escape slots → batch.
        assert_eq!(tiers[2], SloTier::Batch, "order: {tiers:?}");
        assert_eq!(tiers[5], SloTier::Batch, "order: {tiers:?}");
        assert_eq!(
            tiers.iter().filter(|t| **t == SloTier::Batch).count(),
            2,
            "only the escape slots serve batch while interactive has work"
        );
    }

    #[test]
    fn edf_orders_deadlined_before_fair_within_a_lane() {
        let q = AdmissionQueue::new(16).with_escape_every(0);
        let now = Instant::now();
        // Same tier, mixed deadlines: EDF order, deadline-less last.
        let mut a = req(0, 0);
        a.submitted = now;
        a.deadline = Some(Duration::from_secs(300));
        let mut b = req(0, 1);
        b.submitted = now;
        b.deadline = Some(Duration::from_secs(100));
        let c = req(1, 2); // no deadline
        q.submit(a);
        q.submit(c);
        q.submit(b);
        let seqs: Vec<u64> = (0..3).map(|_| pop(&q).seq).collect();
        assert_eq!(seqs, vec![1, 0, 2], "earliest due first, fair FIFO last");
    }

    #[test]
    fn expired_pruned_at_pop_and_counted_per_tier() {
        let q = AdmissionQueue::new(16);
        let t0 = Instant::now();
        // Half-expired lane: seq 0/2 lapse before the pop instant, 1/3 live.
        for seq in 0..4 {
            let mut r = req_tier(SloTier::Interactive, 0, seq);
            r.submitted = t0;
            r.deadline = Some(if seq % 2 == 0 {
                Duration::from_millis(10)
            } else {
                Duration::from_secs(3600)
            });
            q.submit(r);
        }
        let later = t0 + Duration::from_millis(50);
        let mut live = Vec::new();
        while let Some(r) = q.try_pop_at(later) {
            live.push(r.seq);
        }
        assert_eq!(live, vec![1, 3], "only unexpired requests surface");
        assert_eq!(q.expired_by_tier(), [2, 0, 0]);
        assert_eq!(q.len(), 0, "pruned requests leave the queue");
        let counts = q.tier_counts();
        assert_eq!(counts.expired, [2, 0, 0]);
        assert_eq!(counts.shed, [0, 0, 0]);
    }

    #[test]
    fn eligible_filter_skips_stalled_nets() {
        let q = AdmissionQueue::new(8);
        q.submit(req_net(0, 0, 0));
        q.submit(req_net(1, 1, 0));
        q.submit(req_net(0, 0, 1));
        // Net 0 ineligible (its pipeline is stalled): only net 1 pops.
        let r = q
            .pop_timeout_eligible(Duration::from_millis(50), &[false, true])
            .unwrap()
            .unwrap();
        assert_eq!(r.net_id, 1);
        // Nothing else eligible → timeout, net-0 requests stay queued.
        assert!(q
            .pop_timeout_eligible(Duration::from_millis(5), &[false, true])
            .is_err());
        assert_eq!(q.lane_len(0), 2);
        // Re-enable net 0: both drain in FIFO order.
        assert_eq!(pop(&q).seq, 0);
        assert_eq!(pop(&q).seq, 1);
    }

    #[test]
    fn round_robin_across_nets_and_streams() {
        let q = AdmissionQueue::new(16);
        // Net 0 floods; net 1 trickles.
        for seq in 0..4 {
            q.submit(req_net(0, 0, seq));
        }
        q.submit(req_net(1, 1, 0));
        let nets: Vec<usize> = (0..5).map(|_| pop(&q).net_id).collect();
        // Fair interleave: net 1 served within the first two pops.
        assert!(nets[..2].contains(&1), "unfair order: {nets:?}");
    }

    #[test]
    fn round_robin_across_streams() {
        let q = AdmissionQueue::new(16);
        // Stream 0 floods; stream 1 and 2 trickle.
        for seq in 0..4 {
            q.submit(req(0, seq));
        }
        q.submit(Request::new(1, 0, 0, Tensor::scalar(0.0)));
        q.submit(Request::new(2, 0, 0, Tensor::scalar(0.0)));
        let order: Vec<usize> = (0..6).map(|_| pop(&q).stream_id).collect();
        // Fair interleave: each of the 3 streams served within the first 3.
        let mut first3 = order[..3].to_vec();
        first3.sort_unstable();
        assert_eq!(first3, vec![0, 1, 2], "unfair order: {order:?}");
        // Per-stream FIFO preserved for the flood.
        let s0: Vec<u64> = {
            let q2 = AdmissionQueue::new(16);
            for seq in 0..3 {
                q2.submit(req(0, seq));
            }
            (0..3).map(|_| pop(&q2).seq).collect()
        };
        assert_eq!(s0, vec![0, 1, 2]);
    }

    #[test]
    fn close_drains_then_ends() {
        let q = AdmissionQueue::new(4);
        q.submit(req(0, 0));
        q.close();
        assert!(!q.submit(req(0, 1)), "post-close submit sheds");
        assert_eq!(pop(&q).seq, 0);
        assert!(q.pop_timeout(Duration::from_millis(5)).unwrap().is_none());
    }

    #[test]
    fn pop_times_out_when_empty() {
        let q = AdmissionQueue::new(4);
        assert!(q.pop_timeout(Duration::from_millis(5)).is_err());
    }
}
