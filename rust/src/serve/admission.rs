//! Bounded, per-network-lane, stream-fair admission control with
//! shed-on-overload semantics.
//!
//! A serving front-end that blocks producers on overload just moves the
//! queue into the clients; one that drops newest-first starves whoever is
//! unlucky.  This queue does neither: depth is bounded (`submit` sheds and
//! reports), and the consumer side drains fairly so one chatty client
//! cannot starve the others.
//!
//! Admission is organized as **one lane per network** (created on first
//! use), each with its own depth bound.  A stalled network therefore
//! backs up — and sheds — only its own lane, while the other networks'
//! traffic keeps flowing: the consumer passes an eligibility filter
//! (`pop_timeout_eligible`) naming the networks whose pipelines currently
//! have capacity, and the pop round-robins across eligible lanes, then
//! across streams within the lane.

use std::collections::{BTreeMap, VecDeque};
use std::ops::Bound;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Condvar, Mutex};
use std::time::Duration;

use super::request::Request;

/// One network's admission lane.
#[derive(Default)]
struct Lane {
    per_stream: BTreeMap<usize, VecDeque<Request>>,
    len: usize,
    last_served: Option<usize>,
}

impl Lane {
    /// Round-robin across streams (within a stream, FIFO).
    fn take_fair(&mut self) -> Request {
        let next_sid = match self.last_served {
            Some(last) => self
                .per_stream
                .range((Bound::Excluded(last), Bound::Unbounded))
                .map(|(sid, _)| *sid)
                .next(),
            None => None,
        };
        let sid = match next_sid {
            Some(sid) => sid,
            None => *self
                .per_stream
                .keys()
                .next()
                .expect("len > 0 implies a stream"),
        };
        let queue = self.per_stream.get_mut(&sid).expect("stream present");
        let req = queue.pop_front().expect("stream queue non-empty");
        if queue.is_empty() {
            self.per_stream.remove(&sid);
        }
        self.last_served = Some(sid);
        self.len -= 1;
        req
    }
}

struct Inner {
    lanes: BTreeMap<usize, Lane>,
    total_len: usize,
    last_served_net: Option<usize>,
    closed: bool,
}

/// MPMC admission queue: producers are client streams, the consumer is the
/// micro-batcher thread.  Capacity is enforced *per network lane*.
pub struct AdmissionQueue {
    inner: Mutex<Inner>,
    not_empty: Condvar,
    lane_capacity: usize,
    admitted: AtomicU64,
    shed: AtomicU64,
}

impl AdmissionQueue {
    /// `lane_capacity` bounds each network's lane independently.
    pub fn new(lane_capacity: usize) -> AdmissionQueue {
        AdmissionQueue {
            inner: Mutex::new(Inner {
                lanes: BTreeMap::new(),
                total_len: 0,
                last_served_net: None,
                closed: false,
            }),
            not_empty: Condvar::new(),
            lane_capacity: lane_capacity.max(1),
            admitted: AtomicU64::new(0),
            shed: AtomicU64::new(0),
        }
    }

    /// Admit or shed.  Returns false when the request's network lane is
    /// full or the queue is closed (the request is dropped and counted —
    /// overload never blocks a client, and never spills into other
    /// networks' lanes).
    pub fn submit(&self, req: Request) -> bool {
        let mut g = self.inner.lock().unwrap();
        if g.closed {
            drop(g);
            self.shed.fetch_add(1, Ordering::Relaxed);
            return false;
        }
        let lane = g.lanes.entry(req.net_id).or_default();
        if lane.len >= self.lane_capacity {
            drop(g);
            self.shed.fetch_add(1, Ordering::Relaxed);
            return false;
        }
        lane.per_stream
            .entry(req.stream_id)
            .or_default()
            .push_back(req);
        lane.len += 1;
        g.total_len += 1;
        drop(g);
        self.admitted.fetch_add(1, Ordering::Relaxed);
        self.not_empty.notify_all();
        true
    }

    /// Fair pop across all lanes: `Ok(None)` = closed and drained,
    /// `Err(())` = timed out.
    pub fn pop_timeout(&self, timeout: Duration) -> Result<Option<Request>, ()> {
        self.pop_timeout_filtered(timeout, |_| true)
    }

    /// Fair pop restricted to eligible networks (`eligible[net_id]`;
    /// nets beyond the slice count as eligible).  Requests of ineligible
    /// lanes stay queued — their backpressure never blocks this pop.
    pub fn pop_timeout_eligible(
        &self,
        timeout: Duration,
        eligible: &[bool],
    ) -> Result<Option<Request>, ()> {
        self.pop_timeout_filtered(timeout, |net| *eligible.get(net).unwrap_or(&true))
    }

    fn pop_timeout_filtered(
        &self,
        timeout: Duration,
        eligible: impl Fn(usize) -> bool,
    ) -> Result<Option<Request>, ()> {
        // Fixed deadline, not a per-wakeup timeout: submissions into
        // *ineligible* lanes notify this condvar without producing a
        // takeable request, and re-arming the full timeout on each such
        // wakeup would postpone the caller's batch-window deadline for as
        // long as the stalled lane keeps receiving traffic.
        let deadline = std::time::Instant::now() + timeout;
        let mut g = self.inner.lock().unwrap();
        loop {
            if let Some(req) = take_fair(&mut g, &eligible) {
                return Ok(Some(req));
            }
            if g.closed && g.total_len == 0 {
                return Ok(None);
            }
            let remaining = deadline.saturating_duration_since(std::time::Instant::now());
            if remaining.is_zero() {
                return Err(());
            }
            let (guard, _res) = self.not_empty.wait_timeout(g, remaining).unwrap();
            g = guard;
        }
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().total_len
    }

    /// Queued requests of one network's lane.
    pub fn lane_len(&self, net_id: usize) -> usize {
        self.inner
            .lock()
            .unwrap()
            .lanes
            .get(&net_id)
            .map_or(0, |l| l.len)
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Close: submissions shed, pops drain the remainder then return None.
    pub fn close(&self) {
        self.inner.lock().unwrap().closed = true;
        self.not_empty.notify_all();
    }

    pub fn admitted_count(&self) -> u64 {
        self.admitted.load(Ordering::Relaxed)
    }

    pub fn shed_count(&self) -> u64 {
        self.shed.load(Ordering::Relaxed)
    }
}

/// Pick the next eligible non-empty lane after `last_served_net`
/// (wrapping), then round-robin within it.  Returns None when no eligible
/// lane holds a request.
fn take_fair(g: &mut Inner, eligible: &impl Fn(usize) -> bool) -> Option<Request> {
    if g.total_len == 0 {
        return None;
    }
    let candidate = |(id, lane): (&usize, &Lane)| -> Option<usize> {
        (lane.len > 0 && eligible(*id)).then_some(*id)
    };
    let net = match g.last_served_net {
        Some(last) => g
            .lanes
            .range((Bound::Excluded(last), Bound::Unbounded))
            .find_map(candidate)
            .or_else(|| g.lanes.iter().find_map(candidate)),
        None => g.lanes.iter().find_map(candidate),
    }?;
    let lane = g.lanes.get_mut(&net).expect("lane present");
    let req = lane.take_fair();
    g.last_served_net = Some(net);
    g.total_len -= 1;
    Some(req)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;

    fn req(stream_id: usize, seq: u64) -> Request {
        Request::new(stream_id, seq, 0, Tensor::scalar(0.0))
    }

    fn req_net(net_id: usize, stream_id: usize, seq: u64) -> Request {
        Request::new(stream_id, seq, net_id, Tensor::scalar(0.0))
    }

    fn pop(q: &AdmissionQueue) -> Request {
        q.pop_timeout(Duration::from_millis(100)).unwrap().unwrap()
    }

    #[test]
    fn sheds_at_capacity_without_blocking() {
        let q = AdmissionQueue::new(2);
        assert!(q.submit(req(0, 0)));
        assert!(q.submit(req(0, 1)));
        assert!(!q.submit(req(0, 2)), "third submit must shed");
        assert_eq!(q.admitted_count(), 2);
        assert_eq!(q.shed_count(), 1);
        // Draining frees capacity again.
        let _ = pop(&q);
        assert!(q.submit(req(0, 3)));
    }

    #[test]
    fn lanes_isolate_per_net_overload() {
        let q = AdmissionQueue::new(2);
        // Net 0 floods its lane full.
        assert!(q.submit(req_net(0, 0, 0)));
        assert!(q.submit(req_net(0, 0, 1)));
        assert!(!q.submit(req_net(0, 0, 2)), "net 0 lane full");
        // Net 1 still has its own depth budget.
        assert!(q.submit(req_net(1, 1, 0)));
        assert!(q.submit(req_net(1, 1, 1)));
        assert_eq!(q.lane_len(0), 2);
        assert_eq!(q.lane_len(1), 2);
        assert_eq!(q.len(), 4);
    }

    #[test]
    fn eligible_filter_skips_stalled_nets() {
        let q = AdmissionQueue::new(8);
        q.submit(req_net(0, 0, 0));
        q.submit(req_net(1, 1, 0));
        q.submit(req_net(0, 0, 1));
        // Net 0 ineligible (its pipeline is stalled): only net 1 pops.
        let r = q
            .pop_timeout_eligible(Duration::from_millis(50), &[false, true])
            .unwrap()
            .unwrap();
        assert_eq!(r.net_id, 1);
        // Nothing else eligible → timeout, net-0 requests stay queued.
        assert!(q
            .pop_timeout_eligible(Duration::from_millis(5), &[false, true])
            .is_err());
        assert_eq!(q.lane_len(0), 2);
        // Re-enable net 0: both drain in FIFO order.
        assert_eq!(pop(&q).seq, 0);
        assert_eq!(pop(&q).seq, 1);
    }

    #[test]
    fn round_robin_across_nets_and_streams() {
        let q = AdmissionQueue::new(16);
        // Net 0 floods; net 1 trickles.
        for seq in 0..4 {
            q.submit(req_net(0, 0, seq));
        }
        q.submit(req_net(1, 1, 0));
        let nets: Vec<usize> = (0..5).map(|_| pop(&q).net_id).collect();
        // Fair interleave: net 1 served within the first two pops.
        assert!(nets[..2].contains(&1), "unfair order: {nets:?}");
    }

    #[test]
    fn round_robin_across_streams() {
        let q = AdmissionQueue::new(16);
        // Stream 0 floods; stream 1 and 2 trickle.
        for seq in 0..4 {
            q.submit(req(0, seq));
        }
        q.submit(Request::new(1, 0, 0, Tensor::scalar(0.0)));
        q.submit(Request::new(2, 0, 0, Tensor::scalar(0.0)));
        let order: Vec<usize> = (0..6).map(|_| pop(&q).stream_id).collect();
        // Fair interleave: each of the 3 streams served within the first 3.
        let mut first3 = order[..3].to_vec();
        first3.sort_unstable();
        assert_eq!(first3, vec![0, 1, 2], "unfair order: {order:?}");
        // Per-stream FIFO preserved for the flood.
        let s0: Vec<u64> = {
            let q2 = AdmissionQueue::new(16);
            for seq in 0..3 {
                q2.submit(req(0, seq));
            }
            (0..3).map(|_| pop(&q2).seq).collect()
        };
        assert_eq!(s0, vec![0, 1, 2]);
    }

    #[test]
    fn close_drains_then_ends() {
        let q = AdmissionQueue::new(4);
        q.submit(req(0, 0));
        q.close();
        assert!(!q.submit(req(0, 1)), "post-close submit sheds");
        assert_eq!(pop(&q).seq, 0);
        assert!(q.pop_timeout(Duration::from_millis(5)).unwrap().is_none());
    }

    #[test]
    fn pop_times_out_when_empty() {
        let q = AdmissionQueue::new(4);
        assert!(q.pop_timeout(Duration::from_millis(5)).is_err());
    }
}
