//! Per-network micro-batching: coalesce compatible requests into batched
//! jobs before they enter the layer pipeline.
//!
//! Policy is the classic size-or-time rule: a batch is dispatched as soon
//! as it reaches the network's `max_batch`, or once its oldest member has
//! waited out the batching `window` — bounded added latency in exchange
//! for better accelerator occupancy.

use std::time::{Duration, Instant};

use super::request::Request;

/// Platform-wide batching policy (per-network caps may lower `max_batch`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BatchCfg {
    /// Upper bound on requests coalesced into one batch.
    pub max_batch: usize,
    /// Max time the oldest request of a partial batch waits.
    pub window: Duration,
}

impl Default for BatchCfg {
    fn default() -> Self {
        // Single source of truth: the platform `[serving]` defaults.
        let serving = crate::config::ServeCfg::default();
        BatchCfg {
            max_batch: serving.max_batch,
            window: Duration::from_micros(serving.batch_window_us),
        }
    }
}

/// A dispatched micro-batch: requests of one network, oldest first.
#[derive(Debug)]
pub struct Batch {
    pub net_id: usize,
    pub requests: Vec<Request>,
}

impl Batch {
    pub fn len(&self) -> usize {
        self.requests.len()
    }

    pub fn is_empty(&self) -> bool {
        self.requests.is_empty()
    }
}

struct Pending {
    reqs: Vec<Request>,
    /// When the oldest pending request entered the batcher.
    open_since: Option<Instant>,
}

/// The coalescing core.  Single-threaded by design (owned by the batcher
/// thread); all time is passed in explicitly so policies unit-test without
/// sleeping.
pub struct MicroBatcher {
    window: Duration,
    /// Effective cap per network (platform cap ∧ per-net override).
    caps: Vec<usize>,
    pending: Vec<Pending>,
}

impl MicroBatcher {
    /// `per_net_cap[i]` optionally lowers `cfg.max_batch` for network `i`
    /// (from `max_batch` in the model's `.cfg`).
    pub fn new(cfg: BatchCfg, per_net_cap: &[Option<usize>]) -> MicroBatcher {
        let caps = per_net_cap
            .iter()
            .map(|c| c.unwrap_or(cfg.max_batch).clamp(1, cfg.max_batch.max(1)))
            .collect();
        let pending = per_net_cap
            .iter()
            .map(|_| Pending {
                reqs: Vec::new(),
                open_since: None,
            })
            .collect();
        MicroBatcher {
            window: cfg.window,
            caps,
            pending,
        }
    }

    pub fn n_nets(&self) -> usize {
        self.pending.len()
    }

    /// Effective batch cap for one network.
    pub fn cap(&self, net_id: usize) -> usize {
        self.caps[net_id]
    }

    /// Requests currently waiting in partial batches.
    pub fn pending_len(&self) -> usize {
        self.pending.iter().map(|p| p.reqs.len()).sum()
    }

    /// Queue a request; returns a full batch once the cap is reached.
    pub fn push(&mut self, req: Request, now: Instant) -> Option<Batch> {
        let net_id = req.net_id;
        let p = &mut self.pending[net_id];
        if p.reqs.is_empty() {
            p.open_since = Some(now);
        }
        p.reqs.push(req);
        if p.reqs.len() >= self.caps[net_id] {
            return Some(take_batch(p, net_id));
        }
        None
    }

    /// Dispatch every partial batch whose window has expired at `now`.
    pub fn poll_expired(&mut self, now: Instant) -> Vec<Batch> {
        let window = self.window;
        let mut out = Vec::new();
        for (net_id, p) in self.pending.iter_mut().enumerate() {
            let expired = p
                .open_since
                .is_some_and(|t| now.saturating_duration_since(t) >= window);
            if expired {
                out.push(take_batch(p, net_id));
            }
        }
        out
    }

    /// Earliest window deadline among partial batches (sleep hint).
    pub fn next_deadline(&self) -> Option<Instant> {
        self.pending
            .iter()
            .filter_map(|p| p.open_since)
            .min()
            .map(|t| t + self.window)
    }

    /// Dispatch everything still pending (shutdown path).
    pub fn flush_all(&mut self) -> Vec<Batch> {
        let mut out = Vec::new();
        for (net_id, p) in self.pending.iter_mut().enumerate() {
            if !p.reqs.is_empty() {
                out.push(take_batch(p, net_id));
            }
        }
        out
    }
}

fn take_batch(p: &mut Pending, net_id: usize) -> Batch {
    p.open_since = None;
    Batch {
        net_id,
        requests: std::mem::take(&mut p.reqs),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;

    fn req(net_id: usize, seq: u64) -> Request {
        Request::new(0, seq, net_id, Tensor::scalar(0.0))
    }

    fn cfg(max_batch: usize, window_ms: u64) -> BatchCfg {
        BatchCfg {
            max_batch,
            window: Duration::from_millis(window_ms),
        }
    }

    #[test]
    fn coalesces_up_to_max_batch() {
        let mut b = MicroBatcher::new(cfg(3, 100), &[None]);
        let t = Instant::now();
        assert!(b.push(req(0, 0), t).is_none());
        assert!(b.push(req(0, 1), t).is_none());
        let batch = b.push(req(0, 2), t).expect("full batch");
        assert_eq!(batch.net_id, 0);
        assert_eq!(batch.len(), 3);
        let seqs: Vec<u64> = batch.requests.iter().map(|r| r.seq).collect();
        assert_eq!(seqs, vec![0, 1, 2], "oldest-first order");
        assert_eq!(b.pending_len(), 0);
    }

    #[test]
    fn window_expiry_dispatches_partial_batch() {
        let mut b = MicroBatcher::new(cfg(8, 10), &[None]);
        let t0 = Instant::now();
        assert!(b.push(req(0, 0), t0).is_none());
        assert!(b.push(req(0, 1), t0).is_none());
        // Before the window: nothing to dispatch.
        assert!(b.poll_expired(t0 + Duration::from_millis(5)).is_empty());
        assert_eq!(b.next_deadline(), Some(t0 + Duration::from_millis(10)));
        // At/after the window: the partial batch goes out.
        let expired = b.poll_expired(t0 + Duration::from_millis(10));
        assert_eq!(expired.len(), 1);
        assert_eq!(expired[0].len(), 2);
        assert_eq!(b.pending_len(), 0);
        assert_eq!(b.next_deadline(), None);
    }

    #[test]
    fn window_restarts_with_next_request() {
        let mut b = MicroBatcher::new(cfg(8, 10), &[None]);
        let t0 = Instant::now();
        b.push(req(0, 0), t0);
        let _ = b.poll_expired(t0 + Duration::from_millis(10));
        // A new request opens a fresh window anchored at its own arrival.
        let t1 = t0 + Duration::from_millis(20);
        b.push(req(0, 1), t1);
        assert!(b.poll_expired(t1 + Duration::from_millis(9)).is_empty());
        assert_eq!(b.poll_expired(t1 + Duration::from_millis(10)).len(), 1);
    }

    #[test]
    fn nets_batch_independently_and_respect_per_net_caps() {
        // Net 0 capped at 2 by its model config; net 1 uses the platform 4.
        let mut b = MicroBatcher::new(cfg(4, 100), &[Some(2), None]);
        assert_eq!(b.cap(0), 2);
        assert_eq!(b.cap(1), 4);
        let t = Instant::now();
        assert!(b.push(req(0, 0), t).is_none());
        assert!(b.push(req(1, 0), t).is_none());
        let batch = b.push(req(0, 1), t).expect("net 0 full at 2");
        assert_eq!(batch.net_id, 0);
        assert_eq!(batch.len(), 2);
        assert_eq!(b.pending_len(), 1, "net 1 still pending");
    }

    #[test]
    fn per_net_cap_cannot_exceed_platform_cap() {
        let b = MicroBatcher::new(cfg(4, 100), &[Some(64)]);
        assert_eq!(b.cap(0), 4);
    }

    #[test]
    fn flush_all_empties_every_net() {
        let mut b = MicroBatcher::new(cfg(8, 100), &[None, None]);
        let t = Instant::now();
        b.push(req(0, 0), t);
        b.push(req(1, 0), t);
        b.push(req(1, 1), t);
        let mut flushed = b.flush_all();
        flushed.sort_by_key(|x| x.net_id);
        assert_eq!(flushed.len(), 2);
        assert_eq!(flushed[0].len(), 1);
        assert_eq!(flushed[1].len(), 2);
        assert_eq!(b.pending_len(), 0);
        assert!(b.flush_all().is_empty());
    }
}
