//! Per-(network, SLO-tier) micro-batching: coalesce compatible requests
//! into batched jobs before they enter the layer pipeline.
//!
//! Policy is the classic size-or-time rule: a batch is dispatched as soon
//! as it reaches the network's `max_batch`, or once its oldest member has
//! waited out the tier's batching window — bounded added latency in
//! exchange for better accelerator occupancy.
//!
//! The window is **adaptive per tier**: the batcher thread feeds each
//! dispatched request's *deadline headroom* (ms of budget left) into a
//! rolling low-quantile estimator ([`crate::util::stats::RollingQuantile`]).
//! When a tier's tail headroom shrinks to within a couple of windows —
//! batching delay is now eating the SLO budget — the tier's window halves
//! (down to `window_min`); when the tail recovers with ample slack, it
//! doubles back toward the configured base.  Tiers adapt independently:
//! an interactive deadline storm tightens only the interactive window
//! while batch-tier work keeps amortizing at full width.

use std::time::{Duration, Instant};

use crate::util::stats::RollingQuantile;

use super::request::{Request, SloTier};

/// Samples required before the window adapts (guards the estimator's
/// warm-up jitter).
const ADAPT_MIN_SAMPLES: usize = 8;
/// Shrink when the low-quantile headroom falls within this many current
/// windows.
const SHRINK_HEADROOM_WINDOWS: f64 = 2.0;
/// Re-widen when the low-quantile headroom exceeds this many *base*
/// windows — comfortably above the shrink threshold, so a steady tail
/// cannot oscillate the window.
const WIDEN_HEADROOM_WINDOWS: f64 = 8.0;

/// Platform-wide batching policy (per-network caps may lower `max_batch`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BatchCfg {
    /// Upper bound on requests coalesced into one batch.
    pub max_batch: usize,
    /// Base (and maximum) time the oldest request of a partial batch
    /// waits; the adaptive policy only ever shrinks below this.
    pub window: Duration,
    /// Floor the adaptive per-tier window can shrink to.
    pub window_min: Duration,
    /// Rolling sample count of the per-tier deadline-headroom estimator.
    pub headroom_samples: usize,
}

impl Default for BatchCfg {
    fn default() -> Self {
        // Single source of truth: the platform `[serving]` defaults.
        let serving = crate::config::ServeCfg::default();
        BatchCfg {
            max_batch: serving.max_batch,
            window: Duration::from_micros(serving.batch_window_us),
            window_min: Duration::from_micros(serving.batch_window_min_us),
            headroom_samples: serving.headroom_samples,
        }
    }
}

/// A dispatched micro-batch: requests of one network and one SLO tier,
/// oldest first.
#[derive(Debug)]
pub struct Batch {
    pub net_id: usize,
    pub tier: SloTier,
    pub requests: Vec<Request>,
}

impl Batch {
    pub fn len(&self) -> usize {
        self.requests.len()
    }

    pub fn is_empty(&self) -> bool {
        self.requests.is_empty()
    }
}

#[derive(Default)]
struct Pending {
    reqs: Vec<Request>,
    /// When the oldest pending request entered the batcher.
    open_since: Option<Instant>,
}

/// The coalescing core.  Single-threaded by design (owned by the batcher
/// thread); all time is passed in explicitly so policies unit-test without
/// sleeping.
pub struct MicroBatcher {
    base_window: Duration,
    min_window: Duration,
    /// Effective cap per network (platform cap ∧ per-net override).
    caps: Vec<usize>,
    /// `pending[net_id][tier.index()]` — tiers never share a batch.
    pending: Vec<[Pending; SloTier::COUNT]>,
    /// Current adaptive window per tier, in `[min_window, base_window]`.
    windows: [Duration; SloTier::COUNT],
    headroom: [RollingQuantile; SloTier::COUNT],
    shrinks: u64,
    widens: u64,
}

impl MicroBatcher {
    /// `per_net_cap[i]` optionally lowers `cfg.max_batch` for network `i`
    /// (from `max_batch` in the model's `.cfg`).
    pub fn new(cfg: BatchCfg, per_net_cap: &[Option<usize>]) -> MicroBatcher {
        let caps = per_net_cap
            .iter()
            .map(|c| c.unwrap_or(cfg.max_batch).clamp(1, cfg.max_batch.max(1)))
            .collect();
        let pending = per_net_cap
            .iter()
            .map(|_| std::array::from_fn(|_| Pending::default()))
            .collect();
        MicroBatcher {
            base_window: cfg.window,
            min_window: cfg.window_min.min(cfg.window),
            caps,
            pending,
            windows: [cfg.window; SloTier::COUNT],
            headroom: std::array::from_fn(|_| {
                RollingQuantile::new(cfg.headroom_samples.max(1))
            }),
            shrinks: 0,
            widens: 0,
        }
    }

    pub fn n_nets(&self) -> usize {
        self.pending.len()
    }

    /// Effective batch cap for one network.
    pub fn cap(&self, net_id: usize) -> usize {
        self.caps[net_id]
    }

    /// Current adaptive window of one tier.
    pub fn window(&self, tier: SloTier) -> Duration {
        self.windows[tier.index()]
    }

    /// `(shrinks, widens)` the adaptive policy has performed.
    pub fn window_events(&self) -> (u64, u64) {
        (self.shrinks, self.widens)
    }

    /// Requests currently waiting in partial batches.
    pub fn pending_len(&self) -> usize {
        self.pending
            .iter()
            .flat_map(|tiers| tiers.iter())
            .map(|p| p.reqs.len())
            .sum()
    }

    /// Queue a request; returns a full batch once the cap is reached.
    pub fn push(&mut self, req: Request, now: Instant) -> Option<Batch> {
        let net_id = req.net_id;
        let tier = req.tier;
        let p = &mut self.pending[net_id][tier.index()];
        if p.reqs.is_empty() {
            p.open_since = Some(now);
        }
        p.reqs.push(req);
        if p.reqs.len() >= self.caps[net_id] {
            return Some(take_batch(p, net_id, tier));
        }
        None
    }

    /// Feed one dispatched (or lapsed — negative) deadline-headroom
    /// sample, in milliseconds, and adapt the tier's window: halve it
    /// when the rolling low-quantile headroom falls within
    /// [`SHRINK_HEADROOM_WINDOWS`] current windows, double it back toward
    /// the base once the tail recovers past [`WIDEN_HEADROOM_WINDOWS`]
    /// base windows.
    pub fn record_headroom(&mut self, tier: SloTier, headroom_ms: f64) {
        let ti = tier.index();
        self.headroom[ti].push(headroom_ms);
        if self.headroom[ti].len() < ADAPT_MIN_SAMPLES.min(self.headroom[ti].cap()) {
            return;
        }
        let Some(low) = self.headroom[ti].quantile(1.0) else {
            return;
        };
        let cur = self.windows[ti];
        let cur_ms = cur.as_secs_f64() * 1e3;
        let base_ms = self.base_window.as_secs_f64() * 1e3;
        if low <= SHRINK_HEADROOM_WINDOWS * cur_ms {
            let next = (cur / 2).max(self.min_window);
            if next < cur {
                self.windows[ti] = next;
                self.shrinks += 1;
            }
        } else if low >= WIDEN_HEADROOM_WINDOWS * base_ms && cur < self.base_window {
            self.windows[ti] = (cur * 2).min(self.base_window);
            self.widens += 1;
        }
    }

    /// Dispatch every partial batch whose tier window has expired at `now`.
    pub fn poll_expired(&mut self, now: Instant) -> Vec<Batch> {
        let windows = self.windows;
        let mut out = Vec::new();
        for (net_id, tiers) in self.pending.iter_mut().enumerate() {
            for (ti, p) in tiers.iter_mut().enumerate() {
                let expired = p
                    .open_since
                    .is_some_and(|t| now.saturating_duration_since(t) >= windows[ti]);
                if expired {
                    out.push(take_batch(p, net_id, SloTier::ALL[ti]));
                }
            }
        }
        out
    }

    /// Earliest window deadline among partial batches (sleep hint).
    pub fn next_deadline(&self) -> Option<Instant> {
        self.pending
            .iter()
            .flat_map(|tiers| tiers.iter().enumerate())
            .filter_map(|(ti, p)| p.open_since.map(|t| t + self.windows[ti]))
            .min()
    }

    /// Dispatch everything still pending (shutdown path).
    pub fn flush_all(&mut self) -> Vec<Batch> {
        let mut out = Vec::new();
        for (net_id, tiers) in self.pending.iter_mut().enumerate() {
            for (ti, p) in tiers.iter_mut().enumerate() {
                if !p.reqs.is_empty() {
                    out.push(take_batch(p, net_id, SloTier::ALL[ti]));
                }
            }
        }
        out
    }
}

fn take_batch(p: &mut Pending, net_id: usize, tier: SloTier) -> Batch {
    p.open_since = None;
    Batch {
        net_id,
        tier,
        requests: std::mem::take(&mut p.reqs),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;

    fn req(net_id: usize, seq: u64) -> Request {
        Request::new(0, seq, net_id, Tensor::scalar(0.0))
    }

    fn cfg(max_batch: usize, window_ms: u64) -> BatchCfg {
        BatchCfg {
            max_batch,
            window: Duration::from_millis(window_ms),
            window_min: Duration::from_micros(100),
            headroom_samples: 64,
        }
    }

    #[test]
    fn coalesces_up_to_max_batch() {
        let mut b = MicroBatcher::new(cfg(3, 100), &[None]);
        let t = Instant::now();
        assert!(b.push(req(0, 0), t).is_none());
        assert!(b.push(req(0, 1), t).is_none());
        let batch = b.push(req(0, 2), t).expect("full batch");
        assert_eq!(batch.net_id, 0);
        assert_eq!(batch.tier, SloTier::Standard);
        assert_eq!(batch.len(), 3);
        let seqs: Vec<u64> = batch.requests.iter().map(|r| r.seq).collect();
        assert_eq!(seqs, vec![0, 1, 2], "oldest-first order");
        assert_eq!(b.pending_len(), 0);
    }

    #[test]
    fn window_expiry_dispatches_partial_batch() {
        let mut b = MicroBatcher::new(cfg(8, 10), &[None]);
        let t0 = Instant::now();
        assert!(b.push(req(0, 0), t0).is_none());
        assert!(b.push(req(0, 1), t0).is_none());
        // Before the window: nothing to dispatch.
        assert!(b.poll_expired(t0 + Duration::from_millis(5)).is_empty());
        assert_eq!(b.next_deadline(), Some(t0 + Duration::from_millis(10)));
        // At/after the window: the partial batch goes out.
        let expired = b.poll_expired(t0 + Duration::from_millis(10));
        assert_eq!(expired.len(), 1);
        assert_eq!(expired[0].len(), 2);
        assert_eq!(b.pending_len(), 0);
        assert_eq!(b.next_deadline(), None);
    }

    #[test]
    fn window_restarts_with_next_request() {
        let mut b = MicroBatcher::new(cfg(8, 10), &[None]);
        let t0 = Instant::now();
        b.push(req(0, 0), t0);
        let _ = b.poll_expired(t0 + Duration::from_millis(10));
        // A new request opens a fresh window anchored at its own arrival.
        let t1 = t0 + Duration::from_millis(20);
        b.push(req(0, 1), t1);
        assert!(b.poll_expired(t1 + Duration::from_millis(9)).is_empty());
        assert_eq!(b.poll_expired(t1 + Duration::from_millis(10)).len(), 1);
    }

    #[test]
    fn nets_batch_independently_and_respect_per_net_caps() {
        // Net 0 capped at 2 by its model config; net 1 uses the platform 4.
        let mut b = MicroBatcher::new(cfg(4, 100), &[Some(2), None]);
        assert_eq!(b.cap(0), 2);
        assert_eq!(b.cap(1), 4);
        let t = Instant::now();
        assert!(b.push(req(0, 0), t).is_none());
        assert!(b.push(req(1, 0), t).is_none());
        let batch = b.push(req(0, 1), t).expect("net 0 full at 2");
        assert_eq!(batch.net_id, 0);
        assert_eq!(batch.len(), 2);
        assert_eq!(b.pending_len(), 1, "net 1 still pending");
    }

    #[test]
    fn tiers_never_share_a_batch() {
        let mut b = MicroBatcher::new(cfg(2, 100), &[None]);
        let t = Instant::now();
        // One interactive + one batch request on the same net: neither
        // fills a batch (cap 2 within a tier lane).
        assert!(b
            .push(req(0, 0).with_tier(SloTier::Interactive), t)
            .is_none());
        assert!(b.push(req(0, 1).with_tier(SloTier::Batch), t).is_none());
        assert_eq!(b.pending_len(), 2);
        // A second interactive request completes ONLY the interactive batch.
        let batch = b
            .push(req(0, 2).with_tier(SloTier::Interactive), t)
            .expect("interactive tier full at 2");
        assert_eq!(batch.tier, SloTier::Interactive);
        assert_eq!(batch.len(), 2);
        assert_eq!(b.pending_len(), 1, "batch-tier request still pending");
        let mut flushed = b.flush_all();
        assert_eq!(flushed.len(), 1);
        let last = flushed.pop().unwrap();
        assert_eq!(last.tier, SloTier::Batch);
        assert_eq!(last.len(), 1);
    }

    #[test]
    fn per_net_cap_cannot_exceed_platform_cap() {
        let b = MicroBatcher::new(cfg(4, 100), &[Some(64)]);
        assert_eq!(b.cap(0), 4);
    }

    #[test]
    fn flush_all_empties_every_net() {
        let mut b = MicroBatcher::new(cfg(8, 100), &[None, None]);
        let t = Instant::now();
        b.push(req(0, 0), t);
        b.push(req(1, 0), t);
        b.push(req(1, 1), t);
        let mut flushed = b.flush_all();
        flushed.sort_by_key(|x| x.net_id);
        assert_eq!(flushed.len(), 2);
        assert_eq!(flushed[0].len(), 1);
        assert_eq!(flushed[1].len(), 2);
        assert_eq!(b.pending_len(), 0);
        assert!(b.flush_all().is_empty());
    }

    #[test]
    fn window_shrinks_on_vanishing_headroom_and_rewidens_on_recovery() {
        let mut b = MicroBatcher::new(cfg(4, 10), &[None]);
        let base = Duration::from_millis(10);
        assert_eq!(b.window(SloTier::Interactive), base);
        // Tail headroom collapses to ~1 window: shrink toward the floor.
        for _ in 0..16 {
            b.record_headroom(SloTier::Interactive, 10.0);
        }
        let tightened = b.window(SloTier::Interactive);
        assert!(tightened < base, "window must shrink under deadline pressure");
        assert_eq!(b.window(SloTier::Batch), base, "tiers adapt independently");
        let (shrinks, _) = b.window_events();
        assert!(shrinks >= 1);
        // Recovery: ample headroom re-widens back to (never past) the base.
        for _ in 0..64 {
            b.record_headroom(SloTier::Interactive, 10_000.0);
        }
        assert_eq!(b.window(SloTier::Interactive), base);
        let (_, widens) = b.window_events();
        assert!(widens >= 1);
        // The base window is the ceiling: more slack changes nothing.
        b.record_headroom(SloTier::Interactive, 10_000.0);
        assert_eq!(b.window(SloTier::Interactive), base);
    }

    #[test]
    fn window_never_shrinks_below_floor() {
        let mut b = MicroBatcher::new(
            BatchCfg {
                max_batch: 4,
                window: Duration::from_millis(10),
                window_min: Duration::from_millis(2),
                headroom_samples: 16,
            },
            &[None],
        );
        for _ in 0..256 {
            b.record_headroom(SloTier::Interactive, 0.0);
        }
        assert_eq!(b.window(SloTier::Interactive), Duration::from_millis(2));
    }

    #[test]
    fn tier_windows_drive_poll_expiry_independently() {
        let mut b = MicroBatcher::new(cfg(8, 10), &[None]);
        // Shrink the interactive window to the 100µs floor.
        for _ in 0..64 {
            b.record_headroom(SloTier::Interactive, 0.0);
        }
        let tight = b.window(SloTier::Interactive);
        assert!(tight < Duration::from_millis(10));
        let t0 = Instant::now();
        b.push(req(0, 0).with_tier(SloTier::Interactive), t0);
        b.push(req(0, 1).with_tier(SloTier::Batch), t0);
        // At the tight deadline the interactive partial goes out alone.
        let out = b.poll_expired(t0 + tight);
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].tier, SloTier::Interactive);
        assert_eq!(b.pending_len(), 1);
        // The batch-tier partial still waits for the full base window.
        assert!(b.poll_expired(t0 + Duration::from_millis(9)).is_empty());
        assert_eq!(b.poll_expired(t0 + Duration::from_millis(10)).len(), 1);
    }
}
