//! Request/response currency of the serving runtime, plus a deterministic
//! open-loop client-stream generator for load tests and benches.

use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::nn::Network;
use crate::tensor::Tensor;
use crate::util::rng::XorShift64Star;

/// Frame tag carried through the job system: unique per (stream, seq) so
/// batched jobs from different requests never collide.
pub fn frame_tag(stream_id: usize, seq: u64) -> u64 {
    ((stream_id as u64) << 32) | (seq & 0xFFFF_FFFF)
}

/// One inference request from one client stream.
#[derive(Debug)]
pub struct Request {
    pub stream_id: usize,
    /// Per-stream sequence number.
    pub seq: u64,
    /// Index into the server's network table.
    pub net_id: usize,
    /// Deterministic input tag (see [`frame_tag`]).
    pub frame: u64,
    pub input: Tensor,
    /// Arrival timestamp (stamped by the server at admission).
    pub submitted: Instant,
    /// Optional latency budget; expired requests are shed by the batcher.
    pub deadline: Option<Duration>,
}

impl Request {
    pub fn new(stream_id: usize, seq: u64, net_id: usize, input: Tensor) -> Request {
        Request {
            stream_id,
            seq,
            net_id,
            frame: frame_tag(stream_id, seq),
            input,
            submitted: Instant::now(),
            deadline: None,
        }
    }

    pub fn with_deadline(mut self, deadline: Duration) -> Request {
        self.deadline = Some(deadline);
        self
    }

    pub fn is_expired(&self, now: Instant) -> bool {
        match self.deadline {
            Some(d) => now.saturating_duration_since(self.submitted) > d,
            None => false,
        }
    }
}

/// One served inference result.
#[derive(Debug)]
pub struct Response {
    pub stream_id: usize,
    pub seq: u64,
    pub net_id: usize,
    pub frame: u64,
    /// Class probabilities.
    pub output: Tensor,
    /// Admission-to-completion latency.
    pub latency: Duration,
    /// Size of the micro-batch this request rode in.
    pub batch_size: usize,
}

/// Deterministic open-loop client: emits `n_requests` requests for one
/// network with exponential inter-arrival gaps at `rate_rps`, inputs drawn
/// from the network's synthetic frame generator.
pub struct RequestStream {
    pub stream_id: usize,
    pub net_id: usize,
    net: Arc<Network>,
    rng: XorShift64Star,
    mean_gap: Duration,
    deadline: Option<Duration>,
    next_seq: u64,
    remaining: u64,
}

impl RequestStream {
    pub fn new(
        stream_id: usize,
        net_id: usize,
        net: Arc<Network>,
        rate_rps: f64,
        n_requests: u64,
    ) -> RequestStream {
        let mean_gap = Duration::from_secs_f64(1.0 / rate_rps.max(1e-6));
        RequestStream {
            stream_id,
            net_id,
            net,
            rng: XorShift64Star::new(0xC0FF_EE00 + stream_id as u64),
            mean_gap,
            deadline: None,
            next_seq: 0,
            remaining: n_requests,
        }
    }

    /// Attach a latency budget to every request of this stream.
    pub fn with_deadline(mut self, deadline: Duration) -> RequestStream {
        self.deadline = Some(deadline);
        self
    }

    /// Next arrival: the gap to wait before submitting, plus the request.
    /// (`Request::submitted` is re-stamped by the server at admission.)
    pub fn next_arrival(&mut self) -> Option<(Duration, Request)> {
        if self.remaining == 0 {
            return None;
        }
        self.remaining -= 1;
        let seq = self.next_seq;
        self.next_seq += 1;
        // Exponential inter-arrival gap (open-loop Poisson client).
        let u = self.rng.next_f64().clamp(1e-6, 1.0 - 1e-6);
        let gap = self
            .mean_gap
            .mul_f64(-(1.0 - u).ln())
            .max(Duration::from_nanos(1));
        let frame = frame_tag(self.stream_id, seq);
        let mut req = Request::new(self.stream_id, seq, self.net_id, self.net.make_input(frame));
        req.deadline = self.deadline;
        Some((gap, req))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::zoo;

    fn mk_net() -> Arc<Network> {
        Arc::new(Network::new(zoo::load("mpcnn").unwrap(), 32).unwrap())
    }

    #[test]
    fn frame_tags_unique_across_streams() {
        assert_ne!(frame_tag(0, 5), frame_tag(1, 5));
        assert_ne!(frame_tag(2, 0), frame_tag(2, 1));
        assert_eq!(frame_tag(3, 7), frame_tag(3, 7));
    }

    #[test]
    fn stream_emits_n_requests_with_positive_gaps() {
        let mut s = RequestStream::new(1, 0, mk_net(), 100.0, 5);
        let mut count = 0;
        let mut last_seq = None;
        while let Some((gap, req)) = s.next_arrival() {
            assert!(gap > Duration::ZERO);
            assert_eq!(req.stream_id, 1);
            if let Some(prev) = last_seq {
                assert_eq!(req.seq, prev + 1);
            }
            last_seq = Some(req.seq);
            count += 1;
        }
        assert_eq!(count, 5);
    }

    #[test]
    fn stream_is_deterministic() {
        let gaps = |sid: usize| -> Vec<Duration> {
            let mut s = RequestStream::new(sid, 0, mk_net(), 50.0, 4);
            let mut v = Vec::new();
            while let Some((gap, _)) = s.next_arrival() {
                v.push(gap);
            }
            v
        };
        assert_eq!(gaps(7), gaps(7));
        assert_ne!(gaps(7), gaps(8));
    }

    #[test]
    fn deadline_expiry() {
        let net = mk_net();
        let req = Request::new(0, 0, 0, net.make_input(0))
            .with_deadline(Duration::from_millis(10));
        assert!(!req.is_expired(req.submitted));
        assert!(req.is_expired(req.submitted + Duration::from_millis(11)));
        let fresh = Request::new(0, 1, 0, net.make_input(1));
        assert!(!fresh.is_expired(fresh.submitted + Duration::from_secs(3600)));
    }
}
