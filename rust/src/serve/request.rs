//! Request/response currency of the serving runtime, plus a deterministic
//! open-loop client-stream generator for load tests and benches.

use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::nn::Network;
use crate::tensor::Tensor;
use crate::util::rng::XorShift64Star;

/// Frame tag carried through the job system: unique per (stream, seq) so
/// batched jobs from different requests never collide.
pub fn frame_tag(stream_id: usize, seq: u64) -> u64 {
    ((stream_id as u64) << 32) | (seq & 0xFFFF_FFFF)
}

/// Service-level-objective tier of a request.  Admission keeps one lane
/// per (network, tier): higher tiers pop strictly first (with a
/// starvation-proof escape ratio for [`SloTier::Batch`]), and each tier
/// has its own depth budget, so bulk traffic can never shed foreground
/// traffic.  Declaration order IS precedence order.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub enum SloTier {
    /// Tight-deadline foreground traffic: always served first.
    Interactive,
    /// The default tier — the original queue's stream-fair semantics.
    #[default]
    Standard,
    /// Bulk/offline work: lowest precedence, starvation-proofed by the
    /// admission queue's batch-lane escape ratio.
    Batch,
}

impl SloTier {
    pub const COUNT: usize = 3;
    /// Precedence order, highest first.
    pub const ALL: [SloTier; SloTier::COUNT] =
        [SloTier::Interactive, SloTier::Standard, SloTier::Batch];

    /// Dense index (0 = interactive … 2 = batch).
    pub fn index(self) -> usize {
        self as usize
    }

    pub fn label(self) -> &'static str {
        match self {
            SloTier::Interactive => "interactive",
            SloTier::Standard => "standard",
            SloTier::Batch => "batch",
        }
    }
}

/// One inference request from one client stream.
#[derive(Debug)]
pub struct Request {
    pub stream_id: usize,
    /// Per-stream sequence number.
    pub seq: u64,
    /// Index into the server's network table.
    pub net_id: usize,
    /// Deterministic input tag (see [`frame_tag`]).
    pub frame: u64,
    pub input: Tensor,
    /// Arrival timestamp (stamped by the server at admission).
    pub submitted: Instant,
    /// Optional latency budget; expired requests are dropped (and
    /// counted) at admission pop and again at batch formation/dispatch.
    pub deadline: Option<Duration>,
    /// SLO tier (defaults to [`SloTier::Standard`]).
    pub tier: SloTier,
}

impl Request {
    pub fn new(stream_id: usize, seq: u64, net_id: usize, input: Tensor) -> Request {
        Request {
            stream_id,
            seq,
            net_id,
            frame: frame_tag(stream_id, seq),
            input,
            submitted: Instant::now(),
            deadline: None,
            tier: SloTier::default(),
        }
    }

    pub fn with_deadline(mut self, deadline: Duration) -> Request {
        self.deadline = Some(deadline);
        self
    }

    pub fn with_tier(mut self, tier: SloTier) -> Request {
        self.tier = tier;
        self
    }

    /// Absolute due time, when a deadline is attached.
    pub fn due(&self) -> Option<Instant> {
        self.deadline.map(|d| self.submitted + d)
    }

    pub fn is_expired(&self, now: Instant) -> bool {
        match self.deadline {
            Some(d) => now.saturating_duration_since(self.submitted) > d,
            None => false,
        }
    }
}

/// One served inference result.
#[derive(Debug)]
pub struct Response {
    pub stream_id: usize,
    pub seq: u64,
    pub net_id: usize,
    pub frame: u64,
    /// Class probabilities.
    pub output: Tensor,
    /// Admission-to-completion latency.
    pub latency: Duration,
    /// Size of the micro-batch this request rode in.
    pub batch_size: usize,
    /// SLO tier the request was served under.
    pub tier: SloTier,
    /// Weight version the request was computed against (hot-swap pins
    /// each in-flight batch to the version current at batch formation).
    pub version: u64,
}

/// Deterministic open-loop client: emits `n_requests` requests for one
/// network with exponential inter-arrival gaps at `rate_rps`, inputs drawn
/// from the network's synthetic frame generator.
pub struct RequestStream {
    pub stream_id: usize,
    pub net_id: usize,
    net: Arc<Network>,
    rng: XorShift64Star,
    mean_gap: Duration,
    deadline: Option<Duration>,
    tier: SloTier,
    next_seq: u64,
    remaining: u64,
}

impl RequestStream {
    pub fn new(
        stream_id: usize,
        net_id: usize,
        net: Arc<Network>,
        rate_rps: f64,
        n_requests: u64,
    ) -> RequestStream {
        let mean_gap = Duration::from_secs_f64(1.0 / rate_rps.max(1e-6));
        RequestStream {
            stream_id,
            net_id,
            net,
            rng: XorShift64Star::new(0xC0FF_EE00 + stream_id as u64),
            mean_gap,
            deadline: None,
            tier: SloTier::default(),
            next_seq: 0,
            remaining: n_requests,
        }
    }

    /// Attach a latency budget to every request of this stream.
    pub fn with_deadline(mut self, deadline: Duration) -> RequestStream {
        self.deadline = Some(deadline);
        self
    }

    /// Tag every request of this stream with an SLO tier.
    pub fn with_tier(mut self, tier: SloTier) -> RequestStream {
        self.tier = tier;
        self
    }

    /// Next arrival: the gap to wait before submitting, plus the request.
    /// (`Request::submitted` is re-stamped by the server at admission.)
    pub fn next_arrival(&mut self) -> Option<(Duration, Request)> {
        if self.remaining == 0 {
            return None;
        }
        self.remaining -= 1;
        let seq = self.next_seq;
        self.next_seq += 1;
        // Exponential inter-arrival gap (open-loop Poisson client).
        let u = self.rng.next_f64().clamp(1e-6, 1.0 - 1e-6);
        let gap = self
            .mean_gap
            .mul_f64(-(1.0 - u).ln())
            .max(Duration::from_nanos(1));
        let frame = frame_tag(self.stream_id, seq);
        let mut req = Request::new(self.stream_id, seq, self.net_id, self.net.make_input(frame));
        req.deadline = self.deadline;
        req.tier = self.tier;
        Some((gap, req))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::zoo;

    fn mk_net() -> Arc<Network> {
        Arc::new(Network::new(zoo::load("mpcnn").unwrap(), 32).unwrap())
    }

    #[test]
    fn frame_tags_unique_across_streams() {
        assert_ne!(frame_tag(0, 5), frame_tag(1, 5));
        assert_ne!(frame_tag(2, 0), frame_tag(2, 1));
        assert_eq!(frame_tag(3, 7), frame_tag(3, 7));
    }

    #[test]
    fn stream_emits_n_requests_with_positive_gaps() {
        let mut s = RequestStream::new(1, 0, mk_net(), 100.0, 5);
        let mut count = 0;
        let mut last_seq = None;
        while let Some((gap, req)) = s.next_arrival() {
            assert!(gap > Duration::ZERO);
            assert_eq!(req.stream_id, 1);
            if let Some(prev) = last_seq {
                assert_eq!(req.seq, prev + 1);
            }
            last_seq = Some(req.seq);
            count += 1;
        }
        assert_eq!(count, 5);
    }

    #[test]
    fn stream_is_deterministic() {
        let gaps = |sid: usize| -> Vec<Duration> {
            let mut s = RequestStream::new(sid, 0, mk_net(), 50.0, 4);
            let mut v = Vec::new();
            while let Some((gap, _)) = s.next_arrival() {
                v.push(gap);
            }
            v
        };
        assert_eq!(gaps(7), gaps(7));
        assert_ne!(gaps(7), gaps(8));
    }

    #[test]
    fn tiers_index_densely_in_precedence_order() {
        assert_eq!(SloTier::COUNT, SloTier::ALL.len());
        for (i, t) in SloTier::ALL.iter().enumerate() {
            assert_eq!(t.index(), i, "ALL must be precedence-ordered");
        }
        assert_eq!(SloTier::default(), SloTier::Standard);
        assert!(SloTier::Interactive < SloTier::Standard);
        assert!(SloTier::Standard < SloTier::Batch);
    }

    #[test]
    fn stream_tags_tier_and_request_builder_sets_due() {
        let net = mk_net();
        let mut s = RequestStream::new(0, 0, Arc::clone(&net), 100.0, 2)
            .with_tier(SloTier::Interactive)
            .with_deadline(Duration::from_millis(20));
        let (_, req) = s.next_arrival().unwrap();
        assert_eq!(req.tier, SloTier::Interactive);
        assert_eq!(req.deadline, Some(Duration::from_millis(20)));
        assert_eq!(req.due(), Some(req.submitted + Duration::from_millis(20)));
        let plain = Request::new(0, 0, 0, net.make_input(0));
        assert_eq!(plain.tier, SloTier::Standard);
        assert_eq!(plain.due(), None);
    }

    #[test]
    fn deadline_expiry() {
        let net = mk_net();
        let req = Request::new(0, 0, 0, net.make_input(0))
            .with_deadline(Duration::from_millis(10));
        assert!(!req.is_expired(req.submitted));
        assert!(req.is_expired(req.submitted + Duration::from_millis(11)));
        let fresh = Request::new(0, 1, 0, net.make_input(1));
        assert!(!fresh.is_expired(fresh.submitted + Duration::from_secs(3600)));
    }
}
