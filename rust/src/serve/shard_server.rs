//! The remote end of a shard link: a TCP server hosting a second
//! [`DelegatePool`] that executes jobs shipped by peers' `RemoteShard`
//! backends (`accel::remote`).
//!
//! One listener accepts connections; each connection gets its own service
//! thread running [`serve_transport`] over the length-prefixed framing,
//! executing every decoded job through the pool's generic
//! `Dispatcher::execute_job` path — the shard is just another Synergy pool
//! whose "clients" happen to be other pools.  Peers that only speak the
//! remote class mask ship CONV tiles and fused batched-FC GEMMs, but the
//! server is class-agnostic: anything the wire carries routes through the
//! same capability logic as local work (including the counted inline
//! fallback on a degenerate shard pool).
//!
//! Shutdown order matters and mirrors deployment reality: clients
//! disconnect (their pools shut down) *before* the shard stops — a
//! connection thread exits when its peer hangs up, and
//! [`ShardServer::shutdown`] joins them before closing the pool.

use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{Context, Result};

use crate::accel::remote::{serve_shard_transport, ShardCache, ShardCacheStats, TcpTransport};
use crate::rt::{DelegatePool, Dispatcher, PoolOptions, PoolReport};

/// A running shard server: listener + per-connection service threads over
/// one hosted [`DelegatePool`] and ONE shared operand cache — clients that
/// reconnect (or a client pool's several delegates) hit the same cached
/// fetch sets, so a panel ships once per shard, not once per connection.
pub struct ShardServer {
    pool: DelegatePool,
    cache: Arc<ShardCache>,
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept_handle: Option<JoinHandle<Vec<JoinHandle<Result<u64>>>>>,
}

impl ShardServer {
    /// Bind `bind` (e.g. `"127.0.0.1:0"` for an ephemeral test port),
    /// start the hosted pool, and begin accepting shard clients.  The
    /// operand cache is sized from `[serving] shard_cache_mb` of the
    /// hosted pool's config; probe replies advertise the pool's aggregate
    /// static service rate so clients can weight fleet placement.
    pub fn start(bind: &str, options: &PoolOptions) -> Result<ShardServer> {
        let pool = DelegatePool::start(options)?;
        let dispatcher = pool.dispatcher();
        let cache = ShardCache::with_capacity_mb(options.hw.serving.shard_cache_mb.max(1));
        let rate_ksteps: f64 = pool.clusters().iter().map(|c| c.throughput()).sum();
        let listener = TcpListener::bind(bind)
            .with_context(|| format!("binding shard server to {bind}"))?;
        let addr = listener.local_addr().context("shard server local addr")?;
        // Non-blocking accept so shutdown can stop the loop without a
        // wake-up connection.
        listener
            .set_nonblocking(true)
            .context("shard listener non-blocking")?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop_accept = Arc::clone(&stop);
        let conn_cache = Arc::clone(&cache);
        let accept_handle = std::thread::Builder::new()
            .name("shard-accept".into())
            .spawn(move || {
                let mut connections: Vec<JoinHandle<Result<u64>>> = Vec::new();
                while !stop_accept.load(Ordering::Relaxed) {
                    match listener.accept() {
                        Ok((stream, peer)) => {
                            let dispatcher = dispatcher.clone();
                            let cache = Arc::clone(&conn_cache);
                            let handle = std::thread::Builder::new()
                                .name(format!("shard-conn-{peer}"))
                                .spawn(move || {
                                    serve_stream(stream, dispatcher, cache, rate_ksteps)
                                })
                                .expect("spawn shard connection thread");
                            connections.push(handle);
                        }
                        Err(e)
                            if e.kind() == std::io::ErrorKind::WouldBlock
                                || e.kind() == std::io::ErrorKind::Interrupted =>
                        {
                            std::thread::sleep(Duration::from_millis(2));
                        }
                        Err(e) => {
                            // A non-transient accept failure ends the
                            // listener; say so instead of dying silently
                            // behind a healthy-looking pool.
                            eprintln!(
                                "shard-accept: fatal accept error, \
                                 refusing new peers: {e}"
                            );
                            break;
                        }
                    }
                }
                connections
            })
            .expect("spawn shard accept thread");
        Ok(ShardServer {
            pool,
            cache,
            addr,
            stop,
            accept_handle: Some(accept_handle),
        })
    }

    /// The bound address (resolves the ephemeral port of a `:0` bind —
    /// what clients put in their `[cluster] remote = …` line).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Live counters of the hosted pool.
    pub fn snapshot(&self) -> PoolReport {
        self.pool.snapshot()
    }

    /// Operand-cache counters (hits, misses, evictions, occupancy) of the
    /// shared shard cache — the server side of the wire-byte ledger.
    pub fn cache_stats(&self) -> ShardCacheStats {
        self.cache.stats()
    }

    /// Stop accepting, join the connection threads (each exits when its
    /// peer disconnects — shut client pools down first), and tear the
    /// hosted pool down.  Returns the pool's final counters: the shard's
    /// side of the ledger, which a test can balance against the clients'
    /// per-accelerator remote counts.
    pub fn shutdown(mut self) -> Result<PoolReport> {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(handle) = self.accept_handle.take() {
            let connections = handle.join().expect("shard accept thread");
            for conn in connections {
                // A protocol error on one connection is that peer's
                // problem; the shard's report is still valid.
                let _ = conn.join().expect("shard connection thread");
            }
        }
        self.pool.shutdown()
    }
}

/// One connection's service loop: decode → execute on the pool → reply,
/// resolving descriptor-only CONV frames through the shared operand cache
/// and answering probes with the shard's aggregate service rate.
fn serve_stream(
    stream: TcpStream,
    dispatcher: Dispatcher,
    cache: Arc<ShardCache>,
    rate_ksteps: f64,
) -> Result<u64> {
    let mut transport = TcpTransport::from_stream(stream);
    serve_shard_transport(&mut transport, &cache, rate_ksteps, |job| {
        Ok(dispatcher.execute_job(job.clone()))
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::remote::{remote_class_mask, wire, REMOTE_OVERHEAD_KSTEPS};
    use crate::accel::{Accelerator, RemoteShard};
    use crate::config::{ClusterCfg, HwConfig};
    use crate::mm::job::{jobs_from_packs_q8, ClassMask, Job};
    use crate::mm::TileGrid;
    use crate::rt::ComputeMode;
    use crate::util::rng::XorShift64Star;
    use std::sync::Arc;

    fn one_neon_options() -> PoolOptions {
        let mut hw = HwConfig::default_zc702();
        hw.clusters = vec![ClusterCfg {
            name: "shard".into(),
            neon: 2,
            big_neon: 0,
            remote: Vec::new(),
            pes: Vec::new(),
        }];
        PoolOptions::new(hw, ComputeMode::Native, false)
    }

    #[test]
    fn shard_server_executes_shipped_jobs_over_tcp() {
        let server = ShardServer::start("127.0.0.1:0", &one_neon_options()).unwrap();
        let addr = server.addr().to_string();

        // Two concurrent clients, mixed classes.
        let mut clients = Vec::new();
        for c in 0..2u64 {
            let addr = addr.clone();
            clients.push(std::thread::spawn(move || {
                let transport = TcpTransport::connect(&addr).unwrap();
                let mut shard = RemoteShard::new(
                    format!("remote:{addr}"),
                    ClassMask::all(),
                    REMOTE_OVERHEAD_KSTEPS,
                    Box::new(transport),
                );
                for i in 0..4u64 {
                    let w = Arc::new(
                        XorShift64Star::new(100 * c + i).fill_f32(12 * 20, 1.0),
                    );
                    let xb =
                        Arc::new(XorShift64Star::new(200 * c + i).fill_f32(20 * 3, 1.0));
                    let job = Job::fc_batch(c * 10 + i, 0, c, 12, 20, 3, w, xb, 32);
                    let got = shard.execute(&job).unwrap();
                    assert_eq!(got.data, job.execute_native().data);
                }
            }));
        }
        for c in clients {
            c.join().unwrap();
        }
        let report = server.shutdown().unwrap();
        assert_eq!(report.jobs_executed, 8);
        assert_eq!(report.inline_fallbacks, 0);
        assert_eq!(report.fused_fc_rows, 8 * 3);
        assert_eq!(report.delegate_failures, 0);
    }

    #[test]
    fn shard_server_executes_quantized_jobs_over_tcp() {
        // The hosted pool's NEON members claim the Q8 classes, so shipped
        // int8 work routes through the same capability logic as f32: a
        // cached quantized CONV layer PUTs its two i8 code planes once and
        // ships fixed-size descriptor frames per tile, and a fused q8 FC
        // batch ships inline — zero inline fallbacks server-side.
        let server = ShardServer::start("127.0.0.1:0", &one_neon_options()).unwrap();
        let addr = server.addr().to_string();
        let transport = TcpTransport::connect(&addr).unwrap();
        let mut shard = RemoteShard::new(
            format!("remote:{addr}"),
            remote_class_mask(),
            REMOTE_OVERHEAD_KSTEPS,
            Box::new(transport),
        );
        let codes = |seed: u64, n: usize| -> Vec<i8> {
            XorShift64Star::new(seed)
                .fill_f32(n, 1.0)
                .iter()
                .map(|&v| (v * 127.0).round().clamp(-127.0, 127.0) as i8)
                .collect()
        };
        let grid = TileGrid::new(40, 50, 60, 32);
        let panel = grid.panel_elems();
        let mut id = 0;
        let mut jobs = jobs_from_packs_q8(
            0,
            0,
            grid,
            codes(51, grid.rows() * panel).into(),
            codes(52, grid.cols() * panel).into(),
            0.02,
            &mut id,
        );
        jobs.push(Job::fc_batch_q8(
            id,
            1,
            0,
            12,
            20,
            3,
            codes(53, 12 * 20),
            codes(54, 20 * 3),
            0.05,
            32,
        ));
        for job in &jobs {
            let got = shard.execute(job).unwrap();
            assert_eq!(got.data, job.execute_native().data);
        }
        let stats = shard.cache_stats();
        assert_eq!(stats.puts, 2, "two i8 code planes, shipped once");
        assert_eq!(stats.misses, 0);
        let cache = server.cache_stats();
        assert_eq!(cache.entries, 2);
        assert_eq!(cache.misses, 0);
        drop(shard);
        let report = server.shutdown().unwrap();
        assert_eq!(report.jobs_executed, jobs.len() as u64);
        assert_eq!(report.inline_fallbacks, 0);
    }

    #[test]
    fn shard_server_survives_garbage_and_abrupt_disconnects() {
        let server = ShardServer::start("127.0.0.1:0", &one_neon_options()).unwrap();
        let addr = server.addr().to_string();
        // A peer that sends garbage: its connection dies, the shard lives.
        {
            let mut t = TcpTransport::connect(&addr).unwrap();
            t.send(&[0xde, 0xad, 0xbe, 0xef]).unwrap();
            // Either an error frame or a hangup — both are acceptable.
            let _ = t.recv();
        }
        // A peer that connects and silently leaves.
        drop(TcpTransport::connect(&addr).unwrap());
        // A well-behaved peer still gets served after both.
        let mut t = TcpTransport::connect(&addr).unwrap();
        let w = Arc::new(XorShift64Star::new(1).fill_f32(8 * 8, 1.0));
        let x = Arc::new(XorShift64Star::new(2).fill_f32(8, 1.0));
        let job = Job::fc(0, 0, 0, 8, 8, w, x, 32);
        t.send(&wire::encode_job(&job)).unwrap();
        let result = wire::decode_result(&t.recv().unwrap()).unwrap();
        assert_eq!(result.data, job.execute_native().data);
        drop(t);
        let report = server.shutdown().unwrap();
        assert_eq!(report.jobs_executed, 1);
    }
}
