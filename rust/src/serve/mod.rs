//! Multi-stream batched serving runtime — the admission/batching layer
//! above the accelerator clusters.
//!
//! The paper's pipeline (Fig 2) drives one model with one frame stream.
//! Real deployments (NEURAghe; Wang et al., *Neural Network Inference on
//! Mobile SoCs*) win sustained throughput **above** the accelerators: by
//! admitting many client streams, coalescing compatible requests into
//! micro-batches, and only then entering the layer pipeline.  This module
//! is that front-end:
//!
//! ```text
//!  clients ──► AdmissionQueue ──► MicroBatcher ──► per-net layer pipeline
//!  (streams)   (bounded depth,    (max_batch,      (Mailbox-connected
//!              stream-fair,        batching         stages, batched jobs)
//!              shed on overload)   window)               │
//!                                                        ▼
//!                                             shared DelegatePool
//!                                        (cluster queues + delegates
//!                                         + work-stealing thief)
//! ```
//!
//! * [`request`] — request/response currency + synthetic client streams;
//! * [`admission`] — bounded per-network lanes, stream-fair within a lane,
//!   shed-on-overload (a stalled network backs up and sheds only its own
//!   lane);
//! * [`batcher`] — per-network micro-batching (size + window policy);
//! * [`server`] — thread wiring over `rt::DelegatePool` (every layer's
//!   matrix work — CONV tiles, FC GEMMs, im2col — dispatched as pool
//!   jobs via `rt::PoolRouter`; FC stages fuse their whole micro-batch
//!   into one `FcGemmBatch` job per layer);
//! * [`stats`] — latency percentiles / throughput / batch / per-class job
//!   accounting;
//! * [`shard_server`] — the remote end of a shard link: a TCP server
//!   hosting a second `DelegatePool` that executes jobs shipped by peers'
//!   `RemoteShard` backends (`accel::remote`) — the serving stack's first
//!   piece of multi-machine sharding.

pub mod admission;
pub mod batcher;
pub mod request;
pub mod server;
pub mod shard_server;
pub mod stats;

pub use admission::AdmissionQueue;
pub use batcher::{Batch, BatchCfg, MicroBatcher};
pub use request::{Request, RequestStream, Response};
pub use server::{ServeOptions, Server};
pub use shard_server::ShardServer;
pub use stats::{ServerStats, StatsCollector};
