//! Multi-stream batched serving runtime — the admission/batching layer
//! above the accelerator clusters.
//!
//! The paper's pipeline (Fig 2) drives one model with one frame stream.
//! Real deployments (NEURAghe; Wang et al., *Neural Network Inference on
//! Mobile SoCs*) win sustained throughput **above** the accelerators: by
//! admitting many client streams, coalescing compatible requests into
//! micro-batches, and only then entering the layer pipeline.  This module
//! is that front-end:
//!
//! ```text
//!  clients ──► AdmissionQueue ──► MicroBatcher ──► per-net layer pipeline
//!  (streams,   (per-(net,tier)    (per-(net,tier)  (Mailbox-connected
//!   SLO tier)   lanes, EDF +       adaptive         stages, batched jobs,
//!               tier precedence,   windows,         weights pinned per
//!               shed on overload)  size-or-time)    version)   │
//!                                                              ▼
//!                                                   shared DelegatePool
//!                                              (cluster queues + delegates
//!                                               + work-stealing thief)
//! ```
//!
//! * [`request`] — request/response currency ([`SloTier`] lives here) +
//!   synthetic client streams;
//! * [`admission`] — bounded per-(network, tier) lanes: strict tier
//!   precedence with a starvation-proof batch-lane escape ratio, EDF
//!   ordering within a lane, expired requests pruned at pop, stream-fair
//!   for deadline-less traffic, shed-on-overload (a stalled network backs
//!   up and sheds only its own lanes);
//! * [`batcher`] — per-(network, tier) micro-batching (size + window
//!   policy, windows adapt per tier to measured deadline headroom);
//! * [`registry`] — versioned weight slots behind zero-downtime hot-swap
//!   (pointer flip + drain; batches pin their version at formation);
//! * [`server`] — thread wiring over `rt::DelegatePool` (every layer's
//!   matrix work — CONV tiles, FC GEMMs, im2col — dispatched as pool
//!   jobs via `rt::PoolRouter`; FC stages fuse their whole micro-batch
//!   into one `FcGemmBatch` job per layer);
//! * [`stats`] — latency percentiles / throughput / batch / per-tier and
//!   per-class job accounting;
//! * [`shard_server`] — the remote end of a shard link: a TCP server
//!   hosting a second `DelegatePool` that executes jobs shipped by peers'
//!   `RemoteShard` backends (`accel::remote`) — the serving stack's first
//!   piece of multi-machine sharding.

pub mod admission;
pub mod batcher;
pub mod registry;
pub mod request;
pub mod server;
pub mod shard_server;
pub mod stats;

pub use admission::AdmissionQueue;
pub use batcher::{Batch, BatchCfg, MicroBatcher};
pub use registry::NetRegistry;
pub use request::{Request, RequestStream, Response, SloTier};
pub use server::{ServeOptions, Server};
pub use shard_server::ShardServer;
pub use stats::{ServerStats, StatsCollector, TierCounts};
