//! The benchmark model zoo (paper Table 2): seven CNNs loaded from
//! `configs/*.cfg`.  The configs are also embedded so binaries work from
//! any working directory.

use anyhow::Result;

use super::net_config::NetConfig;

/// Model names in paper Table 2 order.
pub const ZOO: [&str; 7] = [
    "cifar_darknet",
    "cifar_alex",
    "cifar_alex_plus",
    "cifar_full",
    "mnist",
    "svhn",
    "mpcnn",
];

macro_rules! embedded {
    ($name:literal) => {
        ($name, include_str!(concat!("../../../configs/", $name, ".cfg")))
    };
}

const EMBEDDED: [(&str, &str); 7] = [
    embedded!("cifar_darknet"),
    embedded!("cifar_alex"),
    embedded!("cifar_alex_plus"),
    embedded!("cifar_full"),
    embedded!("mnist"),
    embedded!("svhn"),
    embedded!("mpcnn"),
];

/// Load one zoo model by name (embedded copy of `configs/<name>.cfg`).
pub fn load(name: &str) -> Result<NetConfig> {
    for (n, text) in EMBEDDED {
        if n == name {
            return NetConfig::parse(name, text);
        }
    }
    anyhow::bail!("unknown zoo model {name:?}; available: {ZOO:?}")
}

/// Load the full Table 2 zoo.
pub fn load_all() -> Result<Vec<NetConfig>> {
    ZOO.iter().map(|n| load(n)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zoo_loads_and_matches_table2() {
        // (conv layers, total layers) exactly as paper Table 2.
        let expect = [
            ("cifar_darknet", 4, 9),
            ("cifar_alex", 3, 8),
            ("cifar_alex_plus", 3, 9),
            ("cifar_full", 3, 9),
            ("mnist", 2, 7),
            ("svhn", 3, 8),
            ("mpcnn", 3, 9),
        ];
        for (name, convs, total) in expect {
            let net = load(name).unwrap();
            assert_eq!(net.num_conv_layers(), convs, "{name}");
            assert_eq!(net.layers.len(), total, "{name}");
        }
    }

    #[test]
    fn unknown_model_errors() {
        assert!(load("resnet152").is_err());
    }

    #[test]
    fn load_all_gives_seven() {
        assert_eq!(load_all().unwrap().len(), 7);
    }
}
