//! `.hw_config` — the hardware architecture description of paper Fig 8.
//!
//! Drives both the hardware architecture generator (`hwgen/`) and the
//! timing/contention models (`accel/`, `memsub/`).  The embedded
//! [`HwConfig::default_zc702`] is the paper's evaluation configuration:
//! two clusters (Cluster-0: 2 NEON + 2 S-PE, Cluster-1: 6 F-PE), tile size
//! 32, fabric at 100 MHz, one MMU per two PEs.

use anyhow::{anyhow, bail, Context, Result};

/// PE micro-architecture class (paper §4.1: F-PE vs S-PE).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PeKind {
    /// "Faster" PE: loop pipelining at loop2 → II=1 on the merged TS² loop.
    Fast,
    /// "Slower" PE: unroll factor 2 + pipelining at loop3.
    Slow,
}

/// One `[pe_type]` section: HLS pragma configuration of a PE template.
#[derive(Debug, Clone, PartialEq)]
pub struct PeTypeCfg {
    pub name: String,
    pub kind: PeKind,
    /// Initiation interval of the pipelined loop (cycles).
    pub ii: usize,
    /// Loop unroll factor applied to loop3.
    pub unroll: usize,
    /// BRAM bank count from array partitioning (ports = 2 reads/bank).
    pub array_partition: usize,
    /// Which loop carries the pipeline pragma ("loop2" or "loop3").
    pub pipeline_loop: String,
}

/// One `[cluster]` section: accelerator grouping (paper §3.1.1).
#[derive(Debug, Clone, PartialEq)]
pub struct ClusterCfg {
    pub name: String,
    /// NEON software accelerators assigned to this cluster.
    pub neon: usize,
    /// Big-core NEON cluster accelerators (each drives the multi-threaded
    /// tiled-SIMD GEMM backend with `big_neon_threads` cores).
    pub big_neon: usize,
    /// Remote accelerator shards (`remote = host:port`, repeatable): each
    /// address spawns one member whose delegate ships jobs to a peer
    /// machine's pool over the transport registered under the
    /// `remote:<addr>` backend key (`accel::remote`).
    pub remote: Vec<String>,
    /// (pe_type name, count) pairs.
    pub pes: Vec<(String, usize)>,
}

impl ClusterCfg {
    pub fn total_pes(&self) -> usize {
        self.pes.iter().map(|(_, n)| n).sum()
    }

    pub fn total_accels(&self) -> usize {
        self.total_pes() + self.neon + self.big_neon + self.remote.len()
    }
}

/// `[memory]` section: memory subsystem shape (paper §3.2.2).
#[derive(Debug, Clone, PartialEq)]
pub struct MemSubCfg {
    /// Number of MMU + MEM-controller pairs instantiated.
    pub mmus: usize,
    /// Max PEs sharing one MMU (paper: 2).
    pub pes_per_mmu: usize,
    /// TLB entries per MMU.
    pub tlb_entries: usize,
    /// DDR peak bandwidth in bytes/cycle at fabric clock (shared).
    pub ddr_bytes_per_cycle: f64,
    /// DDR random-access latency in fabric cycles (first beat of a burst).
    pub ddr_latency_cycles: usize,
    /// AXI burst length in beats (64-bit beats).
    pub burst_beats: usize,
}

/// `[serving]` section: deployment-side knobs for the multi-stream serving
/// runtime (`serve/`) — how requests are admitted and micro-batched before
/// they reach the accelerator clusters.
#[derive(Debug, Clone, PartialEq)]
pub struct ServeCfg {
    /// Upper bound on requests coalesced into one batched job (networks may
    /// lower it per-model via `max_batch` in their `.cfg`).
    pub max_batch: usize,
    /// Batching window: a partially-filled batch is dispatched once its
    /// oldest request has waited this many microseconds.
    pub batch_window_us: u64,
    /// Bounded admission depth *per network lane*; requests beyond a
    /// lane's depth are shed (one stalled network sheds only its own
    /// traffic).
    pub admission_depth: usize,
    /// Extra jobs a delegate drains per queue visit while serving
    /// (amortizes queue locks over micro-batch job runs; see
    /// `rt::delegate::spawn`).  Default 3 is provisional — 0 forfeits the
    /// lock amortization, large values hold jobs away from the thief.
    /// Retune with the `serve_throughput` bench sweep on real hardware.
    pub drain_extra: usize,
    /// Minimum victim queue length the thief steals from.  0 = derive it
    /// from the served networks' batch job counts
    /// (`StealPolicy::batched`); a positive value overrides the
    /// derivation.  Sweep alongside `drain_extra`.
    pub steal_min_victim: usize,
    /// Health/cost probe period for `remote = …` members, in
    /// milliseconds: each remote member gets a prober thread measuring
    /// RTT + shard service rate into its routing link (and evicting it on
    /// failure).  0 disables probing — routing then runs on the static
    /// registry overhead, as non-serving pools do by default.
    pub probe_interval_ms: u64,
    /// Capacity of a shard server's shared operand cache, in MiB of f32
    /// payload (content-addressed packed panels / prepacked weights that
    /// peers reference with descriptor-only CONV frames).
    pub shard_cache_mb: usize,
    /// Starvation-proof escape ratio for the batch SLO tier: every Nth
    /// admission pop serves the batch lane even while higher tiers have
    /// work (strict precedence otherwise).  0 disables the escape —
    /// batch work then only runs when higher lanes are drained.
    pub batch_escape_every: u64,
    /// Floor (µs) the adaptive per-tier batch window can shrink to when a
    /// tier's tail deadline headroom vanishes.
    pub batch_window_min_us: u64,
    /// Rolling sample count of the per-tier deadline-headroom estimator
    /// that drives the adaptive batch window (≥ 1).
    pub headroom_samples: usize,
    /// Default latency budget (ms) stamped on interactive-tier requests
    /// that arrive without an explicit deadline.  0 = no default.
    pub interactive_deadline_ms: u64,
    /// Default latency budget (ms) for standard-tier requests.  0 (the
    /// default) preserves the original no-deadline semantics.
    pub standard_deadline_ms: u64,
    /// Default latency budget (ms) for batch-tier requests.  0 = none.
    pub batch_deadline_ms: u64,
}

/// `[quant]` section: int8 quantized-inference knobs (`nn/quant`) — how
/// per-layer activation scales are calibrated and whether networks run
/// the Q8 job classes by default.
#[derive(Debug, Clone, PartialEq)]
pub struct QuantCfg {
    /// Deterministic zoo input frames each network's calibration walks to
    /// record per-layer activation max-abs (≥ 1).  More samples widen the
    /// observed activation range; the zoo inputs are synthetic and
    /// stationary, so small counts converge.
    pub calibration_samples: usize,
    /// Run quantized (int8) inference for served networks by default.
    /// Off preserves the f32 path exactly; individual call sites can
    /// still build a `QuantizedNetwork` explicitly.
    pub enable: bool,
}

impl Default for QuantCfg {
    fn default() -> Self {
        QuantCfg {
            calibration_samples: 4,
            enable: false,
        }
    }
}

impl Default for ServeCfg {
    fn default() -> Self {
        ServeCfg {
            max_batch: 4,
            batch_window_us: 2000,
            admission_depth: 64,
            drain_extra: 3,
            steal_min_victim: 0,
            probe_interval_ms: 25,
            shard_cache_mb: 64,
            batch_escape_every: 8,
            batch_window_min_us: 100,
            headroom_samples: 64,
            interactive_deadline_ms: 50,
            standard_deadline_ms: 0,
            batch_deadline_ms: 0,
        }
    }
}

/// Full hardware architecture description.
#[derive(Debug, Clone, PartialEq)]
pub struct HwConfig {
    pub device: String,
    pub fpga_mhz: f64,
    pub cpu_mhz: f64,
    pub tile_size: usize,
    /// Cores per big-NEON cluster accelerator (`[cluster] big_neon`
    /// instances fan GEMMs across this many threads).
    pub big_neon_threads: usize,
    pub pe_types: Vec<PeTypeCfg>,
    pub clusters: Vec<ClusterCfg>,
    pub memsub: MemSubCfg,
    pub serving: ServeCfg,
    pub quant: QuantCfg,
}

impl HwConfig {
    /// The paper's default ZC702 architecture (§4.1).
    pub fn default_zc702() -> HwConfig {
        HwConfig::parse("default_zc702", DEFAULT_ZC702).expect("embedded default parses")
    }

    pub fn pe_type(&self, name: &str) -> Option<&PeTypeCfg> {
        self.pe_types.iter().find(|t| t.name == name)
    }

    pub fn total_pes(&self) -> usize {
        self.clusters.iter().map(|c| c.total_pes()).sum()
    }

    pub fn total_neons(&self) -> usize {
        self.clusters.iter().map(|c| c.neon).sum()
    }

    pub fn total_big_neons(&self) -> usize {
        self.clusters.iter().map(|c| c.big_neon).sum()
    }

    /// Validate cross-references and invariants.
    pub fn validate(&self) -> Result<()> {
        if self.clusters.is_empty() {
            bail!("at least one cluster required");
        }
        if self.tile_size == 0 || !self.tile_size.is_power_of_two() {
            bail!("tile_size must be a power of two, got {}", self.tile_size);
        }
        for c in &self.clusters {
            if c.total_accels() == 0 {
                bail!("cluster {} has no accelerators", c.name);
            }
            for (t, _) in &c.pes {
                if self.pe_type(t).is_none() {
                    bail!("cluster {} references unknown pe_type {t:?}", c.name);
                }
            }
            for addr in &c.remote {
                // host:port shape; the port must at least parse.  The dial
                // happens at pool start, inside the delegate's builder.
                let port_ok = addr
                    .rsplit_once(':')
                    .is_some_and(|(host, port)| !host.is_empty() && port.parse::<u16>().is_ok());
                if !port_ok {
                    bail!(
                        "cluster {} remote shard {addr:?} is not host:port",
                        c.name
                    );
                }
            }
        }
        if self.memsub.mmus == 0 {
            bail!("memory subsystem needs at least one MMU");
        }
        let needed_mmus = self.total_pes().div_ceil(self.memsub.pes_per_mmu.max(1));
        if self.memsub.mmus < needed_mmus {
            bail!(
                "{} PEs need ≥{} MMUs at {} PEs/MMU, got {}",
                self.total_pes(),
                needed_mmus,
                self.memsub.pes_per_mmu,
                self.memsub.mmus
            );
        }
        if self.serving.max_batch == 0 {
            bail!("serving max_batch must be ≥ 1");
        }
        if self.serving.admission_depth == 0 {
            bail!("serving admission_depth must be ≥ 1");
        }
        if self.serving.headroom_samples == 0 {
            bail!("serving headroom_samples must be ≥ 1");
        }
        if self.serving.batch_window_min_us > self.serving.batch_window_us {
            bail!(
                "serving batch_window_min_us ({}) must not exceed batch_window_us ({})",
                self.serving.batch_window_min_us,
                self.serving.batch_window_us
            );
        }
        if self.big_neon_threads == 0 {
            bail!("big_neon_threads must be ≥ 1");
        }
        if self.quant.calibration_samples == 0 {
            bail!("quant calibration_samples must be ≥ 1");
        }
        Ok(())
    }

    /// Parse `.hw_config` text (INI-style with repeated sections).
    pub fn parse(name: &str, text: &str) -> Result<HwConfig> {
        let mut device = "xc7z020".to_string();
        let mut fpga_mhz = 100.0;
        let mut cpu_mhz = 667.0;
        let mut tile_size = 32;
        let mut big_neon_threads = 4;
        let mut pe_types = Vec::new();
        let mut clusters = Vec::new();
        let mut memsub = MemSubCfg {
            mmus: 4,
            pes_per_mmu: 2,
            tlb_entries: 8,
            ddr_bytes_per_cycle: 8.0,
            ddr_latency_cycles: 20,
            burst_beats: 64,
        };
        let mut serving = ServeCfg::default();
        let mut quant = QuantCfg::default();

        #[derive(PartialEq, Clone, Copy)]
        enum Sec {
            None,
            Device,
            Cluster,
            PeType,
            Memory,
            Serving,
            Quant,
        }
        let mut sec = Sec::None;

        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            if let Some(stripped) = line.strip_prefix('[') {
                let kind = stripped
                    .strip_suffix(']')
                    .ok_or_else(|| anyhow!("{name}:{}: malformed section", lineno + 1))?
                    .trim()
                    .to_lowercase();
                sec = match kind.as_str() {
                    "device" => Sec::Device,
                    "cluster" => {
                        clusters.push(ClusterCfg {
                            name: format!("cluster{}", clusters.len()),
                            neon: 0,
                            big_neon: 0,
                            remote: Vec::new(),
                            pes: Vec::new(),
                        });
                        Sec::Cluster
                    }
                    "pe_type" => {
                        pe_types.push(PeTypeCfg {
                            name: String::new(),
                            kind: PeKind::Fast,
                            ii: 1,
                            unroll: 1,
                            array_partition: 1,
                            pipeline_loop: "loop2".into(),
                        });
                        Sec::PeType
                    }
                    "memory" => Sec::Memory,
                    "serving" => Sec::Serving,
                    "quant" => Sec::Quant,
                    other => bail!("{name}:{}: unknown section [{other}]", lineno + 1),
                };
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| anyhow!("{name}:{}: expected key=value", lineno + 1))?;
            let (k, v) = (k.trim(), v.trim());
            let parse_usize =
                || -> Result<usize> { v.parse().with_context(|| format!("{name}:{}: {k}={v}", lineno + 1)) };
            let parse_f64 =
                || -> Result<f64> { v.parse().with_context(|| format!("{name}:{}: {k}={v}", lineno + 1)) };
            match sec {
                Sec::Device => match k {
                    "name" => device = v.to_string(),
                    "fpga_mhz" => fpga_mhz = parse_f64()?,
                    "cpu_mhz" => cpu_mhz = parse_f64()?,
                    "tile_size" => tile_size = parse_usize()?,
                    "big_neon_threads" => big_neon_threads = parse_usize()?,
                    other => bail!("{name}:{}: unknown device key {other}", lineno + 1),
                },
                Sec::Cluster => {
                    let c = clusters.last_mut().unwrap();
                    match k {
                        "name" => c.name = v.to_string(),
                        "neon" => c.neon = parse_usize()?,
                        "big_neon" => c.big_neon = parse_usize()?,
                        "remote" => c.remote.push(v.to_string()),
                        "pe" => {
                            // pe=F-PE:6 (repeatable)
                            let (t, n) = v
                                .split_once(':')
                                .ok_or_else(|| anyhow!("{name}:{}: pe=TYPE:COUNT", lineno + 1))?;
                            c.pes.push((
                                t.trim().to_string(),
                                n.trim()
                                    .parse()
                                    .with_context(|| format!("{name}:{}: pe count", lineno + 1))?,
                            ));
                        }
                        other => bail!("{name}:{}: unknown cluster key {other}", lineno + 1),
                    }
                }
                Sec::PeType => {
                    let t = pe_types.last_mut().unwrap();
                    match k {
                        "name" => t.name = v.to_string(),
                        "kind" => {
                            t.kind = match v {
                                "fast" => PeKind::Fast,
                                "slow" => PeKind::Slow,
                                other => bail!("{name}:{}: kind must be fast|slow, got {other}", lineno + 1),
                            }
                        }
                        "ii" => t.ii = parse_usize()?,
                        "unroll" => t.unroll = parse_usize()?,
                        "array_partition" => t.array_partition = parse_usize()?,
                        "pipeline_loop" => t.pipeline_loop = v.to_string(),
                        other => bail!("{name}:{}: unknown pe_type key {other}", lineno + 1),
                    }
                }
                Sec::Memory => match k {
                    "mmus" => memsub.mmus = parse_usize()?,
                    "pes_per_mmu" => memsub.pes_per_mmu = parse_usize()?,
                    "tlb_entries" => memsub.tlb_entries = parse_usize()?,
                    "ddr_bytes_per_cycle" => memsub.ddr_bytes_per_cycle = parse_f64()?,
                    "ddr_latency_cycles" => memsub.ddr_latency_cycles = parse_usize()?,
                    "burst_beats" => memsub.burst_beats = parse_usize()?,
                    other => bail!("{name}:{}: unknown memory key {other}", lineno + 1),
                },
                Sec::Serving => match k {
                    "max_batch" => serving.max_batch = parse_usize()?,
                    "batch_window_us" => serving.batch_window_us = parse_usize()? as u64,
                    "admission_depth" => serving.admission_depth = parse_usize()?,
                    "drain_extra" => serving.drain_extra = parse_usize()?,
                    "steal_min_victim" => serving.steal_min_victim = parse_usize()?,
                    "probe_interval_ms" => serving.probe_interval_ms = parse_usize()? as u64,
                    "shard_cache_mb" => serving.shard_cache_mb = parse_usize()?,
                    "batch_escape_every" => serving.batch_escape_every = parse_usize()? as u64,
                    "batch_window_min_us" => {
                        serving.batch_window_min_us = parse_usize()? as u64
                    }
                    "headroom_samples" => serving.headroom_samples = parse_usize()?,
                    "interactive_deadline_ms" => {
                        serving.interactive_deadline_ms = parse_usize()? as u64
                    }
                    "standard_deadline_ms" => {
                        serving.standard_deadline_ms = parse_usize()? as u64
                    }
                    "batch_deadline_ms" => serving.batch_deadline_ms = parse_usize()? as u64,
                    other => bail!("{name}:{}: unknown serving key {other}", lineno + 1),
                },
                Sec::Quant => match k {
                    "calibration_samples" => quant.calibration_samples = parse_usize()?,
                    "enable" => quant.enable = parse_usize()? != 0,
                    other => bail!("{name}:{}: unknown quant key {other}", lineno + 1),
                },
                Sec::None => bail!("{name}:{}: key outside a section", lineno + 1),
            }
        }

        let cfg = HwConfig {
            device,
            fpga_mhz,
            cpu_mhz,
            tile_size,
            big_neon_threads,
            pe_types,
            clusters,
            memsub,
            serving,
            quant,
        };
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn load(path: &std::path::Path) -> Result<HwConfig> {
        let name = path.file_stem().and_then(|s| s.to_str()).unwrap_or("hw");
        let text =
            std::fs::read_to_string(path).with_context(|| format!("reading {}", path.display()))?;
        Self::parse(name, &text)
    }

    /// Build a custom two-cluster config (used by the SC design-space
    /// exploration of paper Table 5): `(neon, s_pe, f_pe)` per cluster.
    pub fn two_clusters(c0: (usize, usize, usize), c1: (usize, usize, usize)) -> HwConfig {
        let mut base = HwConfig::default_zc702();
        let mk = |name: &str, (neon, spe, fpe): (usize, usize, usize)| {
            let mut pes = Vec::new();
            if spe > 0 {
                pes.push(("S-PE".to_string(), spe));
            }
            if fpe > 0 {
                pes.push(("F-PE".to_string(), fpe));
            }
            ClusterCfg {
                name: name.to_string(),
                neon,
                big_neon: 0,
                remote: Vec::new(),
                pes,
            }
        };
        base.clusters = vec![mk("cluster0", c0), mk("cluster1", c1)];
        base
    }
}

/// The paper's ZC702 evaluation architecture (§4.1): 6 F-PE + 2 S-PE,
/// 2 NEONs, two clusters, 4 MMUs with ≤2 PEs each.
pub const DEFAULT_ZC702: &str = "
[device]
name = xc7z020
fpga_mhz = 100
cpu_mhz = 667
tile_size = 32

[pe_type]
name = F-PE
kind = fast
pipeline_loop = loop2
ii = 1
unroll = 1
array_partition = 16

[pe_type]
name = S-PE
kind = slow
pipeline_loop = loop3
ii = 1
unroll = 2
array_partition = 12

[cluster]
name = cluster0
neon = 2
pe = S-PE:2

[cluster]
name = cluster1
pe = F-PE:6

[memory]
mmus = 4
pes_per_mmu = 2
tlb_entries = 8
ddr_bytes_per_cycle = 8
ddr_latency_cycles = 20
burst_beats = 64

[serving]
max_batch = 4
batch_window_us = 2000
admission_depth = 64
drain_extra = 3
steal_min_victim = 0
probe_interval_ms = 25
shard_cache_mb = 64
batch_escape_every = 8
batch_window_min_us = 100
headroom_samples = 64
interactive_deadline_ms = 50
standard_deadline_ms = 0
batch_deadline_ms = 0

[quant]
calibration_samples = 4
enable = 0
";

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_parses_and_matches_paper() {
        let hw = HwConfig::default_zc702();
        assert_eq!(hw.tile_size, 32);
        assert_eq!(hw.fpga_mhz, 100.0);
        assert_eq!(hw.clusters.len(), 2);
        // Cluster-0: 2 NEONs + 2 S-PE; Cluster-1: 6 F-PE.
        assert_eq!(hw.clusters[0].neon, 2);
        assert_eq!(hw.clusters[0].pes, vec![("S-PE".to_string(), 2)]);
        assert_eq!(hw.clusters[1].pes, vec![("F-PE".to_string(), 6)]);
        assert_eq!(hw.total_pes(), 8);
        assert_eq!(hw.total_neons(), 2);
        assert!(hw.validate().is_ok());
        let fpe = hw.pe_type("F-PE").unwrap();
        assert_eq!(fpe.kind, PeKind::Fast);
        assert_eq!(fpe.ii, 1);
    }

    #[test]
    fn validation_failures() {
        let mut hw = HwConfig::default_zc702();
        hw.clusters.clear();
        assert!(hw.validate().is_err());

        let mut hw = HwConfig::default_zc702();
        hw.tile_size = 33;
        assert!(hw.validate().is_err());

        let mut hw = HwConfig::default_zc702();
        hw.memsub.mmus = 1; // 8 PEs need 4 MMUs at 2 per MMU
        assert!(hw.validate().is_err());

        let mut hw = HwConfig::default_zc702();
        hw.clusters[0].pes[0].0 = "NOPE".into();
        assert!(hw.validate().is_err());
    }

    #[test]
    fn serving_section_parses_and_validates() {
        let hw = HwConfig::default_zc702();
        assert_eq!(hw.serving, ServeCfg::default());

        let text = "
[device]
tile_size = 32
[pe_type]
name = F-PE
[cluster]
name = c0
pe = F-PE:1
[memory]
mmus = 1
[serving]
max_batch = 8
batch_window_us = 500
admission_depth = 128
drain_extra = 5
steal_min_victim = 6
probe_interval_ms = 10
shard_cache_mb = 16
batch_escape_every = 4
batch_window_min_us = 50
headroom_samples = 32
interactive_deadline_ms = 20
standard_deadline_ms = 200
batch_deadline_ms = 5000
";
        let hw = HwConfig::parse("t", text).unwrap();
        assert_eq!(hw.serving.max_batch, 8);
        assert_eq!(hw.serving.batch_window_us, 500);
        assert_eq!(hw.serving.admission_depth, 128);
        assert_eq!(hw.serving.drain_extra, 5);
        assert_eq!(hw.serving.steal_min_victim, 6);
        assert_eq!(hw.serving.probe_interval_ms, 10);
        assert_eq!(hw.serving.shard_cache_mb, 16);
        assert_eq!(hw.serving.batch_escape_every, 4);
        assert_eq!(hw.serving.batch_window_min_us, 50);
        assert_eq!(hw.serving.headroom_samples, 32);
        assert_eq!(hw.serving.interactive_deadline_ms, 20);
        assert_eq!(hw.serving.standard_deadline_ms, 200);
        assert_eq!(hw.serving.batch_deadline_ms, 5000);

        let mut bad = HwConfig::default_zc702();
        bad.serving.max_batch = 0;
        assert!(bad.validate().is_err());
        let mut bad = HwConfig::default_zc702();
        bad.serving.admission_depth = 0;
        assert!(bad.validate().is_err());
        let mut bad = HwConfig::default_zc702();
        bad.serving.headroom_samples = 0;
        assert!(bad.validate().is_err());
        let mut bad = HwConfig::default_zc702();
        bad.serving.batch_window_min_us = bad.serving.batch_window_us + 1;
        assert!(bad.validate().is_err());
        assert!(HwConfig::parse("t", "[serving]\nbogus = 1\n").is_err());
    }

    #[test]
    fn quant_section_parses_and_validates() {
        let hw = HwConfig::default_zc702();
        assert_eq!(hw.quant, QuantCfg::default());
        assert_eq!(hw.quant.calibration_samples, 4);
        assert!(!hw.quant.enable);

        let text = "
[device]
tile_size = 32
[pe_type]
name = F-PE
[cluster]
name = c0
pe = F-PE:1
[memory]
mmus = 1
[quant]
calibration_samples = 2
enable = 1
";
        let hw = HwConfig::parse("t", text).unwrap();
        assert_eq!(hw.quant.calibration_samples, 2);
        assert!(hw.quant.enable);

        let mut bad = HwConfig::default_zc702();
        bad.quant.calibration_samples = 0;
        assert!(bad.validate().is_err());
        assert!(HwConfig::parse("t", "[quant]\nbogus = 1\n").is_err());
    }

    #[test]
    fn big_neon_cluster_parses() {
        let text = "
[device]
tile_size = 32
big_neon_threads = 2
[pe_type]
name = F-PE
[cluster]
name = c0
neon = 1
big_neon = 1
pe = F-PE:1
[memory]
mmus = 1
";
        let hw = HwConfig::parse("t", text).unwrap();
        assert_eq!(hw.big_neon_threads, 2);
        assert_eq!(hw.clusters[0].big_neon, 1);
        assert_eq!(hw.clusters[0].total_accels(), 3);
        assert_eq!(hw.total_big_neons(), 1);

        let mut bad = hw.clone();
        bad.big_neon_threads = 0;
        assert!(bad.validate().is_err());
    }

    #[test]
    fn remote_shard_members_parse_and_validate() {
        let text = "
[device]
tile_size = 32
[pe_type]
name = F-PE
[cluster]
name = c0
neon = 1
remote = 10.0.0.2:7000
remote = shard-b.local:7001
[memory]
mmus = 1
";
        let hw = HwConfig::parse("t", text).unwrap();
        assert_eq!(
            hw.clusters[0].remote,
            vec!["10.0.0.2:7000".to_string(), "shard-b.local:7001".to_string()]
        );
        assert_eq!(hw.clusters[0].total_accels(), 3);

        // A remote-only cluster is a valid cluster.
        let only = "
[device]
tile_size = 32
[pe_type]
name = F-PE
[cluster]
name = c0
remote = 127.0.0.1:9000
[memory]
mmus = 1
";
        assert!(HwConfig::parse("t", only).is_ok());

        // Malformed addresses are rejected up front, not at dial time.
        for bad in ["nocolon", ":7000", "host:", "host:notaport"] {
            let mut hw = HwConfig::default_zc702();
            hw.clusters[0].remote.push(bad.to_string());
            assert!(hw.validate().is_err(), "{bad:?} accepted");
        }
    }

    #[test]
    fn two_clusters_builder() {
        let hw = HwConfig::two_clusters((2, 2, 2), (0, 0, 4));
        assert_eq!(hw.clusters[0].neon, 2);
        assert_eq!(hw.clusters[0].total_pes(), 4);
        assert_eq!(hw.clusters[1].total_pes(), 4);
        assert!(hw.validate().is_ok());
    }

    #[test]
    fn parse_errors() {
        assert!(HwConfig::parse("t", "[bogus]\n").is_err());
        assert!(HwConfig::parse("t", "key=1\n").is_err());
        assert!(HwConfig::parse("t", "[cluster]\npe=F-PE\n").is_err());
        assert!(HwConfig::parse("t", "[pe_type]\nkind=medium\n").is_err());
    }

    #[test]
    fn empty_cluster_rejected() {
        let text = "
[device]
tile_size = 32
[pe_type]
name = F-PE
[cluster]
name = c0
pe = F-PE:1
[cluster]
name = empty
[memory]
mmus = 1
";
        assert!(HwConfig::parse("t", text).is_err());
    }
}
