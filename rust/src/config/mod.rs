//! Configuration: darknet-style network `.cfg` files (paper: "network
//! configuration file"), the `.hw_config` hardware architecture description
//! (paper Fig 8), and the benchmark model zoo (paper Table 2).

pub mod hw_config;
pub mod net_config;
pub mod zoo;

pub use hw_config::{ClusterCfg, HwConfig, MemSubCfg, PeKind, PeTypeCfg, QuantCfg, ServeCfg};
pub use net_config::{Activation, LayerSpec, NetConfig};
