//! Darknet-style `.cfg` parser — Rust twin of `python/compile/netcfg.py`.
//! Both sides parse the same `configs/*.cfg`, keeping the model zoo single-
//! sourced.

use anyhow::{anyhow, bail, Context, Result};

/// Activation functions supported by the zoo (darknet names).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Activation {
    Linear,
    Relu,
    Leaky,
    Sigmoid,
    Tanh,
}

impl Activation {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "linear" => Activation::Linear,
            "relu" => Activation::Relu,
            "leaky" => Activation::Leaky,
            "sigmoid" => Activation::Sigmoid,
            "tanh" => Activation::Tanh,
            other => bail!("unknown activation {other:?}"),
        })
    }

    #[inline]
    pub fn apply(self, x: f32) -> f32 {
        match self {
            Activation::Linear => x,
            Activation::Relu => x.max(0.0),
            Activation::Leaky => {
                if x > 0.0 {
                    x
                } else {
                    0.1 * x
                }
            }
            Activation::Sigmoid => 1.0 / (1.0 + (-x).exp()),
            Activation::Tanh => x.tanh(),
        }
    }
}

/// One layer of a network, as parsed from a `[section]`.
#[derive(Debug, Clone, PartialEq)]
pub enum LayerSpec {
    Conv {
        filters: usize,
        size: usize,
        stride: usize,
        pad: usize,
        activation: Activation,
    },
    MaxPool {
        size: usize,
        stride: usize,
    },
    AvgPool {
        size: usize,
        stride: usize,
    },
    Connected {
        output: usize,
        activation: Activation,
    },
    BatchNorm,
    Dropout {
        probability: f64,
    },
    Softmax,
}

impl LayerSpec {
    /// Short human name (used by traces / metrics).
    pub fn kind(&self) -> &'static str {
        match self {
            LayerSpec::Conv { .. } => "conv",
            LayerSpec::MaxPool { .. } => "maxpool",
            LayerSpec::AvgPool { .. } => "avgpool",
            LayerSpec::Connected { .. } => "connected",
            LayerSpec::BatchNorm => "batchnorm",
            LayerSpec::Dropout { .. } => "dropout",
            LayerSpec::Softmax => "softmax",
        }
    }

    pub fn is_conv(&self) -> bool {
        matches!(self, LayerSpec::Conv { .. })
    }
}

/// Parsed network: input geometry + ordered layers.
#[derive(Debug, Clone)]
pub struct NetConfig {
    pub name: String,
    pub height: usize,
    pub width: usize,
    pub channels: usize,
    /// Per-model cap on serving micro-batch size (`max_batch` in `[net]`);
    /// None = use the platform-wide `[serving]` limit.
    pub max_batch: Option<usize>,
    pub layers: Vec<LayerSpec>,
}

impl NetConfig {
    /// Input shape as (C, H, W).
    pub fn input_shape(&self) -> (usize, usize, usize) {
        (self.channels, self.height, self.width)
    }

    pub fn num_conv_layers(&self) -> usize {
        self.layers.iter().filter(|l| l.is_conv()).count()
    }

    /// Parse darknet-style cfg text.
    pub fn parse(name: &str, text: &str) -> Result<NetConfig> {
        #[derive(Default)]
        struct Section {
            kind: String,
            options: Vec<(String, String)>,
        }
        let mut sections: Vec<Section> = Vec::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            if let Some(stripped) = line.strip_prefix('[') {
                let kind = stripped
                    .strip_suffix(']')
                    .ok_or_else(|| anyhow!("{name}:{}: malformed section {raw:?}", lineno + 1))?
                    .trim()
                    .to_lowercase();
                sections.push(Section {
                    kind,
                    options: Vec::new(),
                });
            } else {
                let (k, v) = line
                    .split_once('=')
                    .ok_or_else(|| anyhow!("{name}:{}: expected key=value, got {raw:?}", lineno + 1))?;
                sections
                    .last_mut()
                    .ok_or_else(|| anyhow!("{name}:{}: option outside a section", lineno + 1))?
                    .options
                    .push((k.trim().to_string(), v.trim().to_string()));
            }
        }

        let first = sections
            .first()
            .filter(|s| s.kind == "net")
            .ok_or_else(|| anyhow!("{name}: first section must be [net]"))?;
        let geti = |sec: &Section, key: &str, default: usize| -> Result<usize> {
            match sec.options.iter().rev().find(|(k, _)| k == key) {
                None => Ok(default),
                Some((_, v)) => v
                    .parse()
                    .with_context(|| format!("{name}: bad integer for {key}={v}")),
            }
        };
        let gets = |sec: &Section, key: &str, default: &str| -> String {
            sec.options
                .iter()
                .rev()
                .find(|(k, _)| k == key)
                .map(|(_, v)| v.clone())
                .unwrap_or_else(|| default.to_string())
        };

        let height = geti(first, "height", 0)?;
        let width = geti(first, "width", 0)?;
        let channels = geti(first, "channels", 0)?;
        if height == 0 || width == 0 || channels == 0 {
            bail!("{name}: [net] must define height/width/channels > 0");
        }
        let max_batch = match geti(first, "max_batch", 0)? {
            0 => None,
            n => Some(n),
        };

        let mut layers = Vec::new();
        for sec in &sections[1..] {
            let layer = match sec.kind.as_str() {
                "convolutional" => {
                    let size = geti(sec, "size", 1)?;
                    LayerSpec::Conv {
                        filters: geti(sec, "filters", 0)?,
                        size,
                        stride: geti(sec, "stride", 1)?,
                        pad: geti(sec, "pad", 0)?,
                        activation: Activation::parse(&gets(sec, "activation", "linear"))?,
                    }
                }
                "maxpool" => {
                    let size = geti(sec, "size", 2)?;
                    LayerSpec::MaxPool {
                        size,
                        stride: geti(sec, "stride", size)?,
                    }
                }
                "avgpool" => {
                    let size = geti(sec, "size", 2)?;
                    LayerSpec::AvgPool {
                        size,
                        stride: geti(sec, "stride", size)?,
                    }
                }
                "connected" => LayerSpec::Connected {
                    output: geti(sec, "output", 0)?,
                    activation: Activation::parse(&gets(sec, "activation", "linear"))?,
                },
                "batchnorm" => LayerSpec::BatchNorm,
                "dropout" => LayerSpec::Dropout {
                    probability: gets(sec, "probability", "0.5").parse()?,
                },
                "softmax" => LayerSpec::Softmax,
                other => bail!("{name}: unknown layer section [{other}]"),
            };
            if let LayerSpec::Conv { filters, size, .. } = &layer {
                if *filters == 0 || *size == 0 {
                    bail!("{name}: convolutional layer needs filters>0 and size>0");
                }
            }
            layers.push(layer);
        }
        Ok(NetConfig {
            name: name.to_string(),
            height,
            width,
            channels,
            max_batch,
            layers,
        })
    }

    /// Load `path` with the stem as model name.
    pub fn load(path: &std::path::Path) -> Result<NetConfig> {
        let name = path
            .file_stem()
            .and_then(|s| s.to_str())
            .unwrap_or("network")
            .to_string();
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&name, &text)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const MINI: &str = "
[net]
height=8
width=8
channels=1

[convolutional]
filters=4
size=3
stride=1
pad=1
activation=relu

[maxpool]
size=2
stride=2

[connected]
output=10
activation=linear

[softmax]
";

    #[test]
    fn parse_mini() {
        let net = NetConfig::parse("mini", MINI).unwrap();
        assert_eq!(net.input_shape(), (1, 8, 8));
        assert_eq!(net.layers.len(), 4);
        assert_eq!(net.num_conv_layers(), 1);
        assert!(matches!(
            net.layers[0],
            LayerSpec::Conv {
                filters: 4,
                size: 3,
                stride: 1,
                pad: 1,
                activation: Activation::Relu
            }
        ));
        assert!(matches!(net.layers[1], LayerSpec::MaxPool { size: 2, stride: 2 }));
    }

    #[test]
    fn max_batch_optional() {
        let net = NetConfig::parse("mini", MINI).unwrap();
        assert_eq!(net.max_batch, None);
        let net = NetConfig::parse(
            "t",
            "[net]\nheight=4\nwidth=4\nchannels=1\nmax_batch=8\n[softmax]\n",
        )
        .unwrap();
        assert_eq!(net.max_batch, Some(8));
    }

    #[test]
    fn maxpool_stride_defaults_to_size() {
        let net = NetConfig::parse(
            "t",
            "[net]\nheight=4\nwidth=4\nchannels=1\n[maxpool]\nsize=3\n",
        )
        .unwrap();
        assert!(matches!(net.layers[0], LayerSpec::MaxPool { size: 3, stride: 3 }));
    }

    #[test]
    fn comments_ignored() {
        let net = NetConfig::parse(
            "t",
            "# hi\n[net]\nheight=4 # trailing\nwidth=4\nchannels=2\n[softmax]\n",
        )
        .unwrap();
        assert_eq!(net.channels, 2);
    }

    #[test]
    fn error_cases() {
        assert!(NetConfig::parse("t", "[convolutional]\nfilters=1\n").is_err());
        assert!(NetConfig::parse("t", "[net]\nheight=0\nwidth=1\nchannels=1\n").is_err());
        assert!(NetConfig::parse("t", "[net]\nheight=1\nwidth=1\nchannels=1\n[bogus]\n").is_err());
        assert!(NetConfig::parse("t", "key=1\n").is_err());
        assert!(NetConfig::parse("t", "[net]\nheight 3\n").is_err());
        assert!(NetConfig::parse(
            "t",
            "[net]\nheight=1\nwidth=1\nchannels=1\n[convolutional]\nfilters=0\n"
        )
        .is_err());
    }

    #[test]
    fn activations_eval() {
        assert_eq!(Activation::Relu.apply(-1.0), 0.0);
        assert_eq!(Activation::Relu.apply(2.0), 2.0);
        assert!((Activation::Leaky.apply(-1.0) + 0.1).abs() < 1e-7);
        assert_eq!(Activation::Linear.apply(-3.0), -3.0);
        let s = Activation::Sigmoid.apply(0.0);
        assert!((s - 0.5).abs() < 1e-7);
        assert!(Activation::parse("bogus").is_err());
    }
}
