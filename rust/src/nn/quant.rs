//! Per-layer symmetric int8 quantization: calibration over zoo inputs,
//! prepacked i8 weight planes, and the quantized forward pass.
//!
//! The scheme is the classic symmetric linear one the DPU lineage
//! (ZynqNet, NEURAghe) gets its embedded throughput from: `v = code ·
//! scale` with `code` clamped to `[-127, 127]` and ONE scale per operand
//! per layer — `w_scale` from the weight tensor's max-abs, `x_scale` from
//! calibration passes over deterministic zoo inputs.  A layer GEMM then
//! runs entirely in integers (`i8×i8` accumulated exactly in `i32`) and
//! pays a single `· (w_scale·x_scale)` dequantize multiply at the layer
//! boundary; bias, activation, pooling, batch-norm, and softmax stay f32.
//! Requantization at the NEXT layer boundary is implicit: that layer
//! quantizes its own input with its own calibrated `x_scale`.
//!
//! [`QuantizedNetwork`] wraps a [`Network`] with the calibrated scales
//! plus two weight planes per GEMM layer, both built once at calibration:
//! the i8 codes (a [`TileGrid::pack_a_tiles`]-layout prepack for CONV,
//! the dense matrix for FC) and an f32 image of those codes for the
//! **dequantized fallback path** — a pool whose members lack the Q8
//! capability bits ([`crate::mm::ClassMask::Q8`]) runs the same integer
//! codes through the plain f32 job classes and applies the scale after,
//! so quantized nets still route through capability masking with zero
//! inline fallbacks.

use std::sync::Arc;

use crate::config::{LayerSpec, QuantCfg};
use crate::mm::job::{pack_fc_columns_q8, unpack_fc_columns};
use crate::mm::{JobClass, OperandView, TileGrid};
use crate::tensor::Tensor;

use super::conv;
use super::network::{MatExec, Network};

/// Symmetric scale for `data`: max-abs mapped onto the i8 code range
/// `[-127, 127]`.  An all-zero operand gets scale 1.0 (its codes are all
/// zero anyway and division by zero must not occur).
pub fn quantize_scale(data: &[f32]) -> f32 {
    let max_abs = data.iter().fold(0.0f32, |m, v| m.max(v.abs()));
    if max_abs > 0.0 {
        max_abs / 127.0
    } else {
        1.0
    }
}

/// Quantize to i8 codes: `round(v / scale)` clamped to `[-127, 127]`
/// (symmetric — the -128 code is never produced, so negation stays
/// closed).
pub fn quantize(data: &[f32], scale: f32) -> Vec<i8> {
    assert!(scale > 0.0, "quantization scale must be positive");
    data.iter()
        .map(|&v| (v / scale).round().clamp(-127.0, 127.0) as i8)
        .collect()
}

/// Dequantize codes back to f32: `code · scale`.
pub fn dequantize(codes: &[i8], scale: f32) -> Vec<f32> {
    codes.iter().map(|&c| c as f32 * scale).collect()
}

/// Calibrated per-layer quantization parameters of one GEMM layer.
#[derive(Debug, Clone, Copy)]
pub struct LayerQuant {
    /// Weight scale (from the layer's weight tensor, known at load).
    pub w_scale: f32,
    /// Input-activation scale (max-abs over the calibration passes).
    pub x_scale: f32,
}

impl LayerQuant {
    /// The layer's dequantize factor: one integer accumulator times this
    /// is the f32 GEMM output.
    pub fn scale(&self) -> f32 {
        self.w_scale * self.x_scale
    }
}

/// A [`Network`] plus everything int8 inference needs: calibrated scales
/// and prepacked i8 (and fallback f32-code) weight planes per GEMM layer.
pub struct QuantizedNetwork {
    net: Network,
    /// Per layer (network indexing): quant params for CONV/FC layers.
    layers: Vec<Option<LayerQuant>>,
    /// CONV weight codes in the blocked (rows·K,TS,TS) job layout — the
    /// Q8 twin of `Network`'s load-time f32 prepack, built once here.
    conv_packs_q8: Vec<Option<Arc<Vec<i8>>>>,
    /// The same CONV code values as f32 (dequantized-path operand).
    conv_packs_deq: Vec<Option<Arc<Vec<f32>>>>,
    /// FC weight codes, dense (OUT,IN) row-major.
    fc_weights_q8: Vec<Option<Arc<Vec<i8>>>>,
    /// The same FC code values as f32.
    fc_weights_deq: Vec<Option<Arc<Vec<f32>>>>,
}

impl QuantizedNetwork {
    /// Calibrate `net` with `samples` deterministic zoo input frames
    /// (`Network::make_input(0..samples)`): per-layer `x_scale` is the
    /// max-abs the layer's input reaches across the passes, `w_scale`
    /// comes straight from the weights, and both weight planes are
    /// quantized and packed once, here.
    pub fn calibrate(net: Network, samples: usize) -> QuantizedNetwork {
        assert!(samples >= 1, "calibration needs at least one sample");
        let n_layers = net.config.layers.len();
        let mut x_maxabs = vec![0.0f32; n_layers];
        for frame in 0..samples {
            let mut cur = net.make_input(frame as u64);
            for (idx, layer) in net.config.layers.iter().enumerate() {
                if matches!(
                    layer,
                    LayerSpec::Conv { .. } | LayerSpec::Connected { .. }
                ) {
                    // CONV quantizes its im2col matrix, whose entries are
                    // copies of the input activations (plus zero padding),
                    // so the input max-abs IS the operand max-abs.
                    let m = cur.data().iter().fold(0.0f32, |m, v| m.max(v.abs()));
                    x_maxabs[idx] = x_maxabs[idx].max(m);
                }
                cur = net.forward_layer(idx, layer, cur, &super::network::NativeExec);
            }
        }

        let mut layers = vec![None; n_layers];
        let mut conv_packs_q8 = vec![None; n_layers];
        let mut conv_packs_deq = vec![None; n_layers];
        let mut fc_weights_q8 = vec![None; n_layers];
        let mut fc_weights_deq = vec![None; n_layers];
        for (idx, layer) in net.config.layers.iter().enumerate() {
            match layer {
                LayerSpec::Conv { .. } => {
                    let pack = net.conv_pack(idx);
                    let w_scale = quantize_scale(&pack);
                    let x_scale = if x_maxabs[idx] > 0.0 {
                        x_maxabs[idx] / 127.0
                    } else {
                        1.0
                    };
                    // Quantizing the packed buffer element-wise equals
                    // packing the quantized dense weights: the pack is a
                    // permutation plus zero padding, and 0.0 codes to 0.
                    let codes = quantize(&pack, w_scale);
                    conv_packs_deq[idx] =
                        Some(Arc::new(codes.iter().map(|&c| c as f32).collect()));
                    conv_packs_q8[idx] = Some(Arc::new(codes));
                    layers[idx] = Some(LayerQuant { w_scale, x_scale });
                }
                LayerSpec::Connected { .. } => {
                    let w = net.weights_arc(idx);
                    let w_scale = quantize_scale(&w);
                    let x_scale = if x_maxabs[idx] > 0.0 {
                        x_maxabs[idx] / 127.0
                    } else {
                        1.0
                    };
                    let codes = quantize(&w, w_scale);
                    fc_weights_deq[idx] =
                        Some(Arc::new(codes.iter().map(|&c| c as f32).collect()));
                    fc_weights_q8[idx] = Some(Arc::new(codes));
                    layers[idx] = Some(LayerQuant { w_scale, x_scale });
                }
                LayerSpec::MaxPool { .. }
                | LayerSpec::AvgPool { .. }
                | LayerSpec::BatchNorm
                | LayerSpec::Dropout { .. }
                | LayerSpec::Softmax => {}
            }
        }
        QuantizedNetwork {
            net,
            layers,
            conv_packs_q8,
            conv_packs_deq,
            fc_weights_q8,
            fc_weights_deq,
        }
    }

    /// Calibrate with the `[quant]` knobs from a hardware config.
    pub fn from_config(net: Network, cfg: &QuantCfg) -> QuantizedNetwork {
        QuantizedNetwork::calibrate(net, cfg.calibration_samples)
    }

    /// The wrapped f32 network.
    pub fn net(&self) -> &Network {
        &self.net
    }

    /// Calibrated quant params of a layer (None for non-GEMM layers).
    pub fn layer_quant(&self, layer: usize) -> Option<LayerQuant> {
        self.layers[layer]
    }

    /// View of a CONV layer's i8 weight prepack (blocked job layout,
    /// stable `Arc` — remote shards cache it by identity like the f32
    /// pack).  Panics for layers without one.
    pub fn conv_pack_q8(&self, layer: usize) -> OperandView<i8> {
        OperandView::full(Arc::clone(
            self.conv_packs_q8[layer]
                .as_ref()
                .expect("conv layer has a q8 weight prepack"),
        ))
    }

    /// View of an FC layer's dense i8 weight codes.
    pub fn fc_weights_q8(&self, layer: usize) -> OperandView<i8> {
        OperandView::full(Arc::clone(
            self.fc_weights_q8[layer]
                .as_ref()
                .expect("fc layer has q8 weights"),
        ))
    }

    /// Pool jobs one quantized frame generates per [`JobClass`]: the GEMM
    /// classes move to their Q8 twins, im2col lowering stays f32.
    pub fn pool_job_profile_q8(&self) -> [usize; JobClass::COUNT] {
        let base = self.net.pool_job_profile();
        let mut profile = [0usize; JobClass::COUNT];
        profile[JobClass::ConvTileQ8.index()] = base[JobClass::ConvTile.index()];
        profile[JobClass::Im2col.index()] = base[JobClass::Im2col.index()];
        profile[JobClass::FcGemmQ8.index()] = base[JobClass::FcGemm.index()];
        profile
    }

    /// Quantized forward pass.  GEMM layers run int8 when `exec` claims
    /// the capability ([`MatExec::supports_q8`]); otherwise the SAME
    /// integer codes flow through the f32 job classes and the scale is
    /// applied after (the dequantized fallback — identical routing
    /// machinery, no inline execution).  All other layers match
    /// [`Network::forward_with`] exactly.
    pub fn forward_with(&self, x: &Tensor, exec: &dyn MatExec) -> Tensor {
        let (c, h, w) = self.net.input_shape();
        assert_eq!(x.shape(), &[c, h, w], "input shape mismatch");
        let mut cur = x.clone();
        for (idx, layer) in self.net.config.layers.iter().enumerate() {
            cur = self.forward_layer(idx, layer, cur, exec);
        }
        cur
    }

    /// Quantized batched forward: per-frame CONV front-end, FC layers
    /// fused across the batch into one Q8 (or fallback f32) batched GEMM.
    pub fn forward_batch_with(&self, xs: &[Tensor], exec: &dyn MatExec) -> Vec<Tensor> {
        let (c, h, w) = self.net.input_shape();
        for x in xs {
            assert_eq!(x.shape(), &[c, h, w], "input shape mismatch");
        }
        let mut cur: Vec<Tensor> = xs.to_vec();
        for (idx, layer) in self.net.config.layers.iter().enumerate() {
            cur = if matches!(layer, LayerSpec::Connected { .. }) && !cur.is_empty() {
                self.forward_fc_batch(idx, layer, cur, exec)
            } else {
                cur.into_iter()
                    .map(|x| self.forward_layer(idx, layer, x, exec))
                    .collect()
            };
        }
        cur
    }

    /// Execute one layer of the quantized forward.
    pub fn forward_layer(
        &self,
        idx: usize,
        layer: &LayerSpec,
        input: Tensor,
        exec: &dyn MatExec,
    ) -> Tensor {
        match layer {
            LayerSpec::Conv {
                filters,
                size,
                stride,
                pad,
                activation,
            } => {
                let lq = self.layers[idx].expect("conv layer calibrated");
                let (cin, h, w) = (input.shape()[0], input.shape()[1], input.shape()[2]);
                let (oh, ow) = super::conv_out_hw(h, w, *size, *stride, *pad);
                let col = exec.im2col_lower(idx, input, *size, *stride, *pad);
                let grid = TileGrid::new(
                    *filters,
                    cin * size * size,
                    oh * ow,
                    self.net.tile_size(),
                );
                // Stage the blocked B pack in f32, then quantize the
                // packed buffer: element-wise quantization commutes with
                // the pack permutation (and zero padding codes to zero).
                let b_f32 = grid.pack_b_tiles(col.data());
                let b_codes = quantize(&b_f32, lq.x_scale);
                let c_mat = if exec.supports_q8() {
                    let b_q8 = exec.adopt_q8_plane(idx, b_codes);
                    exec.conv_gemm_q8(idx, grid, self.conv_pack_q8(idx), b_q8, lq.scale())
                } else {
                    // Dequantized fallback: same codes, f32 job class,
                    // scale applied after the GEMM.
                    let b_deq: Vec<f32> = b_codes.iter().map(|&c| c as f32).collect();
                    let a_deq = OperandView::full(Arc::clone(
                        self.conv_packs_deq[idx].as_ref().expect("deq conv pack"),
                    ));
                    let mut c =
                        exec.conv_gemm(idx, grid, a_deq, OperandView::from(b_deq));
                    for v in c.iter_mut() {
                        *v *= lq.scale();
                    }
                    c
                };
                let bias = self.net.layer_param(idx, "bias").expect("conv bias");
                let mut out = Tensor::from_vec(&[*filters, oh, ow], c_mat);
                for o in 0..*filters {
                    let plane = &mut out.data_mut()[o * oh * ow..(o + 1) * oh * ow];
                    let bv = bias.data()[o];
                    for v in plane {
                        *v += bv;
                    }
                }
                conv::activate(&mut out, *activation);
                out
            }
            LayerSpec::Connected { activation, .. } => {
                let lq = self.layers[idx].expect("fc layer calibrated");
                let w = self.net.layer_param(idx, "weights").expect("fc weights");
                let b = self.net.layer_param(idx, "bias").expect("fc bias");
                let (out_n, in_n) = (w.shape()[0], w.shape()[1]);
                assert_eq!(input.len(), in_n, "input length mismatch");
                let x_codes = quantize(input.data(), lq.x_scale);
                let mut out = if exec.supports_q8() {
                    let xv = exec.adopt_q8_plane(idx, x_codes);
                    exec.fc_gemm_q8(idx, out_n, in_n, self.fc_weights_q8(idx), xv, lq.scale())
                } else {
                    let x_deq: Vec<f32> = x_codes.iter().map(|&c| c as f32).collect();
                    let w_deq = OperandView::full(Arc::clone(
                        self.fc_weights_deq[idx].as_ref().expect("deq fc weights"),
                    ));
                    let mut y = exec.fc_gemm(idx, out_n, in_n, w_deq, OperandView::from(x_deq));
                    for v in y.iter_mut() {
                        *v *= lq.scale();
                    }
                    y
                };
                for (v, bv) in out.iter_mut().zip(b.data()) {
                    *v = activation.apply(*v + *bv);
                }
                let n = out.len();
                Tensor::from_vec(&[n], out)
            }
            LayerSpec::MaxPool { .. }
            | LayerSpec::AvgPool { .. }
            | LayerSpec::BatchNorm
            | LayerSpec::Dropout { .. }
            | LayerSpec::Softmax => self.net.forward_layer(idx, layer, input, exec),
        }
    }

    /// Fused batched FC over quantized columns (Q8 twin of
    /// [`Network::forward_layer_batch`]'s Connected arm).
    fn forward_fc_batch(
        &self,
        idx: usize,
        layer: &LayerSpec,
        inputs: Vec<Tensor>,
        exec: &dyn MatExec,
    ) -> Vec<Tensor> {
        let LayerSpec::Connected { activation, .. } = layer else {
            unreachable!("forward_fc_batch on a non-FC layer");
        };
        let lq = self.layers[idx].expect("fc layer calibrated");
        let w = self.net.layer_param(idx, "weights").expect("fc weights");
        let b = self.net.layer_param(idx, "bias").expect("fc bias");
        let (out_n, in_n) = (w.shape()[0], w.shape()[1]);
        let batch = inputs.len();
        let code_cols: Vec<Vec<i8>> = inputs
            .iter()
            .map(|t| {
                assert_eq!(t.len(), in_n, "input length mismatch");
                quantize(t.data(), lq.x_scale)
            })
            .collect();
        let c = if exec.supports_q8() {
            let cols: Vec<&[i8]> = code_cols.iter().map(|c| c.as_slice()).collect();
            let xb = exec.adopt_q8_plane(idx, pack_fc_columns_q8(&cols));
            exec.fc_gemm_batch_q8(
                idx,
                out_n,
                in_n,
                batch,
                self.fc_weights_q8(idx),
                xb,
                lq.scale(),
            )
        } else {
            let deq_cols: Vec<Vec<f32>> = code_cols
                .iter()
                .map(|c| c.iter().map(|&v| v as f32).collect())
                .collect();
            let cols: Vec<&[f32]> = deq_cols.iter().map(|c| c.as_slice()).collect();
            let xb = exec.pack_fc_cols(idx, &cols);
            let w_deq = OperandView::full(Arc::clone(
                self.fc_weights_deq[idx].as_ref().expect("deq fc weights"),
            ));
            let mut y = exec.fc_gemm_batch(idx, out_n, in_n, batch, w_deq, xb);
            for v in y.iter_mut() {
                *v *= lq.scale();
            }
            y
        };
        unpack_fc_columns(&c, out_n, batch)
            .into_iter()
            .map(|mut y| {
                for (v, bv) in y.iter_mut().zip(b.data()) {
                    *v = activation.apply(*v + *bv);
                }
                Tensor::from_vec(&[out_n], y)
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::zoo;
    use crate::nn::network::NativeExec;

    fn mk(name: &str) -> Network {
        Network::new(zoo::load(name).unwrap(), 32).unwrap()
    }

    #[test]
    fn scale_maps_max_abs_onto_127() {
        let data = [0.5f32, -2.54, 1.0];
        let s = quantize_scale(&data);
        assert!((s - 2.54 / 127.0).abs() < 1e-9);
        let codes = quantize(&data, s);
        assert_eq!(codes[1], -127);
        assert_eq!(quantize_scale(&[0.0, 0.0]), 1.0, "all-zero operand");
    }

    #[test]
    fn quantize_clamps_outliers_symmetrically() {
        let codes = quantize(&[10.0, -10.0, 0.0], 0.01);
        assert_eq!(codes, vec![127, -127, 0]);
    }

    #[test]
    fn calibration_is_deterministic_and_packs_once() {
        let qa = QuantizedNetwork::calibrate(mk("mnist"), 2);
        let qb = QuantizedNetwork::calibrate(mk("mnist"), 2);
        for idx in 0..qa.net().config.layers.len() {
            match (qa.layer_quant(idx), qb.layer_quant(idx)) {
                (Some(a), Some(b)) => {
                    assert_eq!(a.w_scale, b.w_scale, "layer {idx}");
                    assert_eq!(a.x_scale, b.x_scale, "layer {idx}");
                    assert!(a.w_scale > 0.0 && a.x_scale > 0.0);
                }
                (None, None) => {}
                _ => panic!("layer {idx}: calibration disagreement"),
            }
        }
        // CONV q8 packs share geometry with the f32 prepack and repeated
        // accessors alias one allocation.
        for info in qa.net().conv_infos() {
            let pack = qa.conv_pack_q8(info.layer_idx);
            assert_eq!(pack.len(), qa.net().conv_pack(info.layer_idx).len());
            assert!(Arc::ptr_eq(
                pack.buffer(),
                qa.conv_pack_q8(info.layer_idx).buffer()
            ));
        }
    }

    #[test]
    fn quantized_forward_stays_close_to_reference() {
        let q = QuantizedNetwork::calibrate(mk("mnist"), 2);
        let x = q.net().make_input(5);
        let want = q.net().forward_reference(&x);
        let got = q.forward_with(&x, &NativeExec);
        assert_eq!(got.shape(), &[10]);
        let sum: f32 = got.data().iter().sum();
        assert!((sum - 1.0).abs() < 1e-4, "softmax sum {sum}");
        // Output distributions agree to quantization precision.
        assert!(
            got.allclose(&want, 0.1, 0.1),
            "q8 drifted: {}",
            got.max_abs_diff(&want)
        );
    }

    #[test]
    fn fallback_path_equals_q8_path_on_small_layers() {
        // A q8-blind executor forces the dequantized f32 classes over the
        // SAME integer codes.  mnist layer K values keep every f32 code
        // sum exactly representable, so the two paths agree bitwise.
        struct NoQ8;
        impl MatExec for NoQ8 {
            fn conv_gemm(
                &self,
                layer_idx: usize,
                grid: TileGrid,
                a: OperandView,
                b: OperandView,
            ) -> Vec<f32> {
                NativeExec.conv_gemm(layer_idx, grid, a, b)
            }
            fn supports_q8(&self) -> bool {
                false
            }
        }
        let q = QuantizedNetwork::calibrate(mk("mnist"), 1);
        let x = q.net().make_input(1);
        let a = q.forward_with(&x, &NativeExec);
        let b = q.forward_with(&x, &NoQ8);
        assert!(
            a.allclose(&b, 1e-5, 1e-5),
            "fallback drifted from q8: {}",
            a.max_abs_diff(&b)
        );
    }

    #[test]
    fn batched_q8_matches_per_sample_q8_bitwise() {
        let q = QuantizedNetwork::calibrate(mk("mnist"), 1);
        let xs: Vec<Tensor> = (0..3).map(|f| q.net().make_input(f)).collect();
        let got = q.forward_batch_with(&xs, &NativeExec);
        for (j, x) in xs.iter().enumerate() {
            let want = q.forward_with(x, &NativeExec);
            assert_eq!(got[j].data(), want.data(), "item {j} not bit-exact");
        }
    }

    #[test]
    fn q8_job_profile_moves_gemm_classes_to_q8() {
        let q = QuantizedNetwork::calibrate(mk("mnist"), 1);
        let base = q.net().pool_job_profile();
        let prof = q.pool_job_profile_q8();
        assert_eq!(
            prof[JobClass::ConvTileQ8.index()],
            base[JobClass::ConvTile.index()]
        );
        assert_eq!(prof[JobClass::Im2col.index()], base[JobClass::Im2col.index()]);
        assert_eq!(prof[JobClass::FcGemmQ8.index()], base[JobClass::FcGemm.index()]);
        assert_eq!(prof[JobClass::ConvTile.index()], 0);
        assert_eq!(prof[JobClass::FcGemm.index()], 0);
        assert_eq!(prof[JobClass::FcGemmBatchQ8.index()], 0);
    }

    #[test]
    #[should_panic(expected = "at least one sample")]
    fn calibration_rejects_zero_samples() {
        let _ = QuantizedNetwork::calibrate(mk("mnist"), 0);
    }
}
