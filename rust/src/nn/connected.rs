//! Fully-connected layer — the paper's §3.1.4 ARM-cores reference kernel.
//!
//! The forward pass no longer calls this directly: FC GEMMs flow through
//! [`MatExec::fc_gemm`](crate::nn::network::MatExec::fc_gemm) so the
//! accelerator pool can execute them as jobs.  This scalar implementation
//! stays as the independent oracle; a test below pins the executor path
//! against it so the two cannot drift.

use crate::tensor::Tensor;

/// y = W·x + b, W: (OUT, IN) row-major, x: flat (IN,).
pub fn connected(x: &[f32], w: &Tensor, bias: &[f32]) -> Vec<f32> {
    let out_n = w.shape()[0];
    let in_n = w.shape()[1];
    assert_eq!(x.len(), in_n, "input length mismatch");
    assert_eq!(bias.len(), out_n);
    let wd = w.data();
    let mut out = vec![0.0f32; out_n];
    for o in 0..out_n {
        let row = &wd[o * in_n..(o + 1) * in_n];
        // 4-way unrolled dot product (NEON-ish shape; autovectorizes).
        let mut acc0 = 0.0f32;
        let mut acc1 = 0.0f32;
        let mut acc2 = 0.0f32;
        let mut acc3 = 0.0f32;
        let chunks = in_n / 4;
        for i in 0..chunks {
            let j = i * 4;
            acc0 += row[j] * x[j];
            acc1 += row[j + 1] * x[j + 1];
            acc2 += row[j + 2] * x[j + 2];
            acc3 += row[j + 3] * x[j + 3];
        }
        let mut acc = acc0 + acc1 + acc2 + acc3;
        for j in chunks * 4..in_n {
            acc += row[j] * x[j];
        }
        out[o] = acc + bias[o];
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_values() {
        let w = Tensor::from_vec(&[2, 3], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let y = connected(&[1.0, 1.0, 1.0], &w, &[0.5, -0.5]);
        assert_eq!(y, vec![6.5, 14.5]);
    }

    #[test]
    fn unroll_tail_handled() {
        // IN=6 exercises both the unrolled body and the tail loop.
        let w = Tensor::from_vec(&[1, 6], vec![1.0; 6]);
        let y = connected(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0], &w, &[0.0]);
        assert_eq!(y, vec![21.0]);
    }

    #[test]
    #[should_panic(expected = "input length mismatch")]
    fn length_mismatch_panics() {
        let w = Tensor::from_vec(&[1, 3], vec![0.0; 3]);
        connected(&[1.0], &w, &[0.0]);
    }

    /// Pin the executor FC path (`MatExec::fc_gemm` default = the same
    /// kernel pool jobs run) against this scalar oracle.
    #[test]
    fn fc_gemm_executor_matches_connected_oracle() {
        use crate::nn::network::{MatExec, NativeExec};
        use crate::util::rng::XorShift64Star;
        use std::sync::Arc;
        let (out_n, in_n) = (13, 37);
        let wv = XorShift64Star::new(1).fill_f32(out_n * in_n, 1.0);
        let xv = XorShift64Star::new(2).fill_f32(in_n, 1.0);
        let bias = vec![0.25f32; out_n];
        let w = Tensor::from_vec(&[out_n, in_n], wv.clone());
        let want = connected(&xv, &w, &bias);
        let mut got = NativeExec.fc_gemm(0, out_n, in_n, Arc::new(wv).into(), Arc::new(xv).into());
        for (g, b) in got.iter_mut().zip(&bias) {
            *g += *b;
        }
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 1e-4, "{g} vs {w}");
        }
    }
}
