//! Fully-connected layer (paper §3.1.4: runs on the ARM cores).

use crate::tensor::Tensor;

/// y = W·x + b, W: (OUT, IN) row-major, x: flat (IN,).
pub fn connected(x: &[f32], w: &Tensor, bias: &[f32]) -> Vec<f32> {
    let out_n = w.shape()[0];
    let in_n = w.shape()[1];
    assert_eq!(x.len(), in_n, "input length mismatch");
    assert_eq!(bias.len(), out_n);
    let wd = w.data();
    let mut out = vec![0.0f32; out_n];
    for o in 0..out_n {
        let row = &wd[o * in_n..(o + 1) * in_n];
        // 4-way unrolled dot product (NEON-ish shape; autovectorizes).
        let mut acc0 = 0.0f32;
        let mut acc1 = 0.0f32;
        let mut acc2 = 0.0f32;
        let mut acc3 = 0.0f32;
        let chunks = in_n / 4;
        for i in 0..chunks {
            let j = i * 4;
            acc0 += row[j] * x[j];
            acc1 += row[j + 1] * x[j + 1];
            acc2 += row[j + 2] * x[j + 2];
            acc3 += row[j + 3] * x[j + 3];
        }
        let mut acc = acc0 + acc1 + acc2 + acc3;
        for j in chunks * 4..in_n {
            acc += row[j] * x[j];
        }
        out[o] = acc + bias[o];
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_values() {
        let w = Tensor::from_vec(&[2, 3], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let y = connected(&[1.0, 1.0, 1.0], &w, &[0.5, -0.5]);
        assert_eq!(y, vec![6.5, 14.5]);
    }

    #[test]
    fn unroll_tail_handled() {
        // IN=6 exercises both the unrolled body and the tail loop.
        let w = Tensor::from_vec(&[1, 6], vec![1.0; 6]);
        let y = connected(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0], &w, &[0.0]);
        assert_eq!(y, vec![21.0]);
    }

    #[test]
    #[should_panic(expected = "input length mismatch")]
    fn length_mismatch_panics() {
        let w = Tensor::from_vec(&[1, 3], vec![0.0; 3]);
        connected(&[1.0], &w, &[0.0]);
    }
}
