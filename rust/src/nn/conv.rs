//! Convolution: the direct reference implementation and the Synergy
//! GEMM-lowered path (im2col + matrix multiply, paper §3.1.1).

use crate::config::Activation;
use crate::mm::gemm;
use crate::tensor::Tensor;

use super::{conv_out_hw, im2col::im2col};

/// Direct (nested-loop) convolution — the correctness oracle.
/// x: (C,H,W); w: (OC, C·K·K) row-major flattened; bias: (OC,) → (OC,OH,OW).
pub fn conv_direct(
    x: &Tensor,
    w: &Tensor,
    bias: &[f32],
    ksize: usize,
    stride: usize,
    pad: usize,
) -> Tensor {
    let (c, h, wd) = (x.shape()[0], x.shape()[1], x.shape()[2]);
    let oc = w.shape()[0];
    assert_eq!(w.shape()[1], c * ksize * ksize);
    let (oh, ow) = conv_out_hw(h, wd, ksize, stride, pad);
    let mut out = Tensor::zeros(&[oc, oh, ow]);
    for o in 0..oc {
        for oy in 0..oh {
            for ox in 0..ow {
                let mut acc = bias[o];
                for ci in 0..c {
                    for ki in 0..ksize {
                        let iy = (oy * stride + ki) as isize - pad as isize;
                        if iy < 0 || iy >= h as isize {
                            continue;
                        }
                        for kj in 0..ksize {
                            let ix = (ox * stride + kj) as isize - pad as isize;
                            if ix < 0 || ix >= wd as isize {
                                continue;
                            }
                            let widx = (ci * ksize + ki) * ksize + kj;
                            acc += w.at2(o, widx) * x.at3(ci, iy as usize, ix as usize);
                        }
                    }
                }
                out.set3(o, oy, ox, acc);
            }
        }
    }
    out
}

/// Synergy CONV lowering: im2col then a single (un-tiled) GEMM.  The tiled,
/// job-based path lives in `mm::job` and is exercised by the coordinator;
/// this function is the intermediate oracle between direct conv and jobs.
pub fn conv_gemm(
    x: &Tensor,
    w: &Tensor,
    bias: &[f32],
    ksize: usize,
    stride: usize,
    pad: usize,
) -> Tensor {
    let (_, h, wd) = (x.shape()[0], x.shape()[1], x.shape()[2]);
    let (oh, ow) = conv_out_hw(h, wd, ksize, stride, pad);
    let col = im2col(x, ksize, stride, pad); // (C·K², OH·OW)
    let oc = w.shape()[0];
    let mut out = gemm::gemm_blocked(w, &col); // (OC, OH·OW)
    for o in 0..oc {
        let row = &mut out.data_mut()[o * oh * ow..(o + 1) * oh * ow];
        for v in row {
            *v += bias[o];
        }
    }
    out.reshaped(&[oc, oh, ow])
}

/// Apply an activation in place over a tensor (the darknet post-conv step).
pub fn activate(t: &mut Tensor, act: Activation) {
    if act == Activation::Linear {
        return;
    }
    for v in t.data_mut() {
        *v = act.apply(*v);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::XorShift64Star;

    fn rand_tensor(shape: &[usize], seed: u64) -> Tensor {
        let n = shape.iter().product();
        Tensor::from_vec(shape, XorShift64Star::new(seed).fill_f32(n, 2.0))
    }

    #[test]
    fn gemm_path_matches_direct() {
        for (c, h, w, oc, k, s, p) in [
            (1usize, 8usize, 8usize, 4usize, 3usize, 1usize, 1usize),
            (3, 9, 7, 5, 3, 2, 1),
            (2, 6, 6, 3, 1, 1, 0),
            (4, 10, 10, 8, 5, 1, 2),
            (2, 12, 12, 7, 3, 3, 0),
        ] {
            let x = rand_tensor(&[c, h, w], 1 + c as u64);
            let wt = rand_tensor(&[oc, c * k * k], 77 + k as u64);
            let bias: Vec<f32> = XorShift64Star::new(5).fill_f32(oc, 0.2);
            let d = conv_direct(&x, &wt, &bias, k, s, p);
            let g = conv_gemm(&x, &wt, &bias, k, s, p);
            assert!(
                d.allclose(&g, 1e-4, 1e-4),
                "mismatch at c={c} h={h} w={w} oc={oc} k={k} s={s} p={p}: {}",
                d.max_abs_diff(&g)
            );
        }
    }

    #[test]
    fn identity_kernel_1x1() {
        // 1x1 conv with identity weights = channel passthrough.
        let x = rand_tensor(&[2, 3, 3], 9);
        let w = Tensor::from_vec(&[2, 2], vec![1.0, 0.0, 0.0, 1.0]);
        let out = conv_gemm(&x, &w, &[0.0, 0.0], 1, 1, 0);
        assert!(out.allclose(&x, 1e-6, 1e-6));
    }

    #[test]
    fn bias_added() {
        let x = Tensor::zeros(&[1, 2, 2]);
        let w = Tensor::from_vec(&[1, 1], vec![1.0]);
        let out = conv_gemm(&x, &w, &[3.5], 1, 1, 0);
        assert!(out.data().iter().all(|&v| v == 3.5));
    }

    #[test]
    fn activation_applied() {
        let mut t = Tensor::from_vec(&[3], vec![-1.0, 0.0, 2.0]);
        activate(&mut t, Activation::Relu);
        assert_eq!(t.data(), &[0.0, 0.0, 2.0]);
        let mut t = Tensor::from_vec(&[2], vec![-1.0, 2.0]);
        activate(&mut t, Activation::Leaky);
        assert_eq!(t.data(), &[-0.1, 2.0]);
    }
}
