//! Max / average pooling (darknet semantics: valid padding, floor output).

use crate::tensor::Tensor;

use super::pool_out_hw;

pub fn maxpool(x: &Tensor, size: usize, stride: usize) -> Tensor {
    let (c, h, w) = (x.shape()[0], x.shape()[1], x.shape()[2]);
    let (oh, ow) = pool_out_hw(h, w, size, stride);
    let mut out = Tensor::zeros(&[c, oh, ow]);
    for ci in 0..c {
        for oy in 0..oh {
            for ox in 0..ow {
                let mut best = f32::NEG_INFINITY;
                for ky in 0..size {
                    for kx in 0..size {
                        best = best.max(x.at3(ci, oy * stride + ky, ox * stride + kx));
                    }
                }
                out.set3(ci, oy, ox, best);
            }
        }
    }
    out
}

pub fn avgpool(x: &Tensor, size: usize, stride: usize) -> Tensor {
    let (c, h, w) = (x.shape()[0], x.shape()[1], x.shape()[2]);
    let (oh, ow) = pool_out_hw(h, w, size, stride);
    let inv = 1.0 / (size * size) as f32;
    let mut out = Tensor::zeros(&[c, oh, ow]);
    for ci in 0..c {
        for oy in 0..oh {
            for ox in 0..ow {
                let mut acc = 0.0f32;
                for ky in 0..size {
                    for kx in 0..size {
                        acc += x.at3(ci, oy * stride + ky, ox * stride + kx);
                    }
                }
                out.set3(ci, oy, ox, acc * inv);
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn maxpool_known() {
        let x = Tensor::from_vec(&[1, 4, 4], (0..16).map(|i| i as f32).collect());
        let y = maxpool(&x, 2, 2);
        assert_eq!(y.shape(), &[1, 2, 2]);
        assert_eq!(y.data(), &[5.0, 7.0, 13.0, 15.0]);
    }

    #[test]
    fn avgpool_known() {
        let x = Tensor::from_vec(&[1, 4, 4], (0..16).map(|i| i as f32).collect());
        let y = avgpool(&x, 2, 2);
        assert_eq!(y.data(), &[2.5, 4.5, 10.5, 12.5]);
    }

    #[test]
    fn ragged_input_floors() {
        // 5x5 input, 2x2/2 pool → 2x2 output (last row/col dropped).
        let x = Tensor::from_vec(&[1, 5, 5], (0..25).map(|i| i as f32).collect());
        let y = maxpool(&x, 2, 2);
        assert_eq!(y.shape(), &[1, 2, 2]);
        assert_eq!(y.data(), &[6.0, 8.0, 16.0, 18.0]);
    }

    #[test]
    fn multichannel_independent() {
        let mut x = Tensor::zeros(&[2, 2, 2]);
        x.set3(0, 0, 0, 5.0);
        x.set3(1, 1, 1, 7.0);
        let y = maxpool(&x, 2, 2);
        assert_eq!(y.data(), &[5.0, 7.0]);
    }

    #[test]
    fn overlapping_stride_one() {
        let x = Tensor::from_vec(&[1, 3, 3], (0..9).map(|i| i as f32).collect());
        let y = maxpool(&x, 2, 1);
        assert_eq!(y.shape(), &[1, 2, 2]);
        assert_eq!(y.data(), &[4.0, 5.0, 7.0, 8.0]);
    }
}
