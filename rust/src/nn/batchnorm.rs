//! Inference-time batch normalization (the paper's "normalization" layer).

use crate::tensor::Tensor;

pub const BN_EPS: f32 = 1e-5;

/// y = gamma·(x-mean)/sqrt(var+eps) + beta, per channel of (C,H,W).
pub fn batchnorm(x: &Tensor, gamma: &[f32], beta: &[f32], mean: &[f32], var: &[f32]) -> Tensor {
    let (c, h, w) = (x.shape()[0], x.shape()[1], x.shape()[2]);
    assert!(gamma.len() == c && beta.len() == c && mean.len() == c && var.len() == c);
    let mut out = x.clone();
    for ci in 0..c {
        let inv = gamma[ci] / (var[ci] + BN_EPS).sqrt();
        let shift = beta[ci] - mean[ci] * inv;
        let plane = &mut out.data_mut()[ci * h * w..(ci + 1) * h * w];
        for v in plane {
            *v = *v * inv + shift;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_params_near_identity() {
        let x = Tensor::from_vec(&[1, 2, 2], vec![1.0, -2.0, 3.0, 0.5]);
        let y = batchnorm(&x, &[1.0], &[0.0], &[0.0], &[1.0]);
        assert!(y.allclose(&x, 1e-4, 1e-4));
    }

    #[test]
    fn normalizes_shift_and_scale() {
        let x = Tensor::from_vec(&[1, 1, 2], vec![10.0, 14.0]);
        // mean 12, var 4 → normalized ±1, then gamma 2 beta 1 → -1, 3
        let y = batchnorm(&x, &[2.0], &[1.0], &[12.0], &[4.0]);
        assert!((y.data()[0] + 1.0).abs() < 1e-3);
        assert!((y.data()[1] - 3.0).abs() < 1e-3);
    }

    #[test]
    fn per_channel_independent() {
        let x = Tensor::from_vec(&[2, 1, 1], vec![1.0, 1.0]);
        let y = batchnorm(&x, &[1.0, 5.0], &[0.0, 0.0], &[0.0, 0.0], &[1.0, 1.0]);
        assert!((y.data()[0] - 1.0).abs() < 1e-4);
        assert!((y.data()[1] - 5.0).abs() < 1e-4);
    }
}
