//! Darknet-layout im2col — the data layout transformation of paper §3.1.1
//! that turns a CONV layer into a matrix multiplication.
//!
//! Layout contract (shared with `python/compile/kernels/ref.py::im2col_ref`):
//! output is (C·K·K, OH·OW), row index varies (c, ki, kj) c-major, column
//! index is (oy·OW + ox).

use crate::tensor::Tensor;

use super::conv_out_hw;

/// im2col on a (C,H,W) tensor → (C·K·K, OH·OW) matrix.
pub fn im2col(x: &Tensor, ksize: usize, stride: usize, pad: usize) -> Tensor {
    let (c, h, w) = (x.shape()[0], x.shape()[1], x.shape()[2]);
    let (oh, ow) = conv_out_hw(h, w, ksize, stride, pad);
    let out = im2col_slice(x.data(), (c, h, w), ksize, stride, pad);
    Tensor::from_vec(&[c * ksize * ksize, oh * ow], out)
}

/// Slice-level im2col core: `src` is the (C,H,W) activation row-major.
/// Shared by the tensor wrapper above and the pool's im2col jobs (which
/// carry `Arc<Vec<f32>>` buffers and must not rebuild a tensor copy).
pub fn im2col_slice(
    src: &[f32],
    (c, h, w): (usize, usize, usize),
    ksize: usize,
    stride: usize,
    pad: usize,
) -> Vec<f32> {
    debug_assert_eq!(src.len(), c * h * w, "im2col input size");
    let (oh, ow) = conv_out_hw(h, w, ksize, stride, pad);
    let cols = oh * ow;
    let rows = c * ksize * ksize;
    let mut out = vec![0.0f32; rows * cols];

    for ci in 0..c {
        let chan = &src[ci * h * w..(ci + 1) * h * w];
        for ki in 0..ksize {
            for kj in 0..ksize {
                let row = (ci * ksize + ki) * ksize + kj;
                let dst = &mut out[row * cols..(row + 1) * cols];
                for oy in 0..oh {
                    let iy = (oy * stride + ki) as isize - pad as isize;
                    if iy < 0 || iy >= h as isize {
                        // whole output row reads padding → stays zero
                        continue;
                    }
                    let src_row = &chan[iy as usize * w..(iy as usize + 1) * w];
                    let base = oy * ow;
                    for ox in 0..ow {
                        let ix = (ox * stride + kj) as isize - pad as isize;
                        if ix >= 0 && ix < w as isize {
                            dst[base + ox] = src_row[ix as usize];
                        }
                    }
                }
            }
        }
    }
    out
}

/// The number of f32 elements im2col touches (used by the ARM cycle model).
pub fn im2col_work(c: usize, ksize: usize, oh: usize, ow: usize) -> usize {
    c * ksize * ksize * oh * ow
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_values_2x2_kernel() {
        // Mirrors python/tests/test_model.py::test_im2col_known_values.
        let x = Tensor::from_vec(&[1, 3, 3], (0..9).map(|i| i as f32).collect());
        let col = im2col(&x, 2, 1, 0);
        assert_eq!(col.shape(), &[4, 4]);
        assert_eq!(&col.data()[0..4], &[0.0, 1.0, 3.0, 4.0]);
        assert_eq!(&col.data()[4..8], &[1.0, 2.0, 4.0, 5.0]);
        assert_eq!(&col.data()[8..12], &[3.0, 4.0, 6.0, 7.0]);
        assert_eq!(&col.data()[12..16], &[4.0, 5.0, 7.0, 8.0]);
    }

    #[test]
    fn padding_zero_fills() {
        let x = Tensor::from_vec(&[1, 2, 2], vec![1.0; 4]);
        let col = im2col(&x, 3, 1, 1);
        assert_eq!(col.shape(), &[9, 4]);
        // (ki=0,kj=0) at output (0,0) reads the padded corner
        assert_eq!(col.at2(0, 0), 0.0);
        // center tap reads real data
        assert_eq!(col.at2(4, 0), 1.0);
    }

    #[test]
    fn stride_two() {
        let x = Tensor::from_vec(&[1, 4, 4], (0..16).map(|i| i as f32).collect());
        let col = im2col(&x, 2, 2, 0);
        assert_eq!(col.shape(), &[4, 4]);
        // output (0,0) patch = [0,1,4,5]; row0 = tap (0,0) over outputs
        assert_eq!(&col.data()[0..4], &[0.0, 2.0, 8.0, 10.0]);
    }

    #[test]
    fn multichannel_row_order() {
        let mut data = vec![0.0f32; 2 * 2 * 2];
        for (i, v) in data.iter_mut().enumerate() {
            *v = i as f32;
        }
        let x = Tensor::from_vec(&[2, 2, 2], data);
        let col = im2col(&x, 1, 1, 0);
        assert_eq!(col.shape(), &[2, 4]);
        // row 0 = channel 0 flattened, row 1 = channel 1 flattened
        assert_eq!(&col.data()[0..4], &[0.0, 1.0, 2.0, 3.0]);
        assert_eq!(&col.data()[4..8], &[4.0, 5.0, 6.0, 7.0]);
    }
}
