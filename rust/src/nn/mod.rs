//! CNN layer implementations — the "other layers and preprocessing
//! functions" of paper §3.1.4 (executed on the ARM cores), plus the
//! reference CONV path used to validate the accelerator path.

pub mod batchnorm;
pub mod conv;
pub mod connected;
pub mod im2col;
pub mod network;
pub mod pool;
pub mod quant;
pub mod softmax;

pub use network::{GemmExecFn, MatExec, NativeExec, Network};
pub use quant::{dequantize, quantize, quantize_scale, LayerQuant, QuantizedNetwork};

/// Output spatial dims of a convolution.
pub fn conv_out_hw(h: usize, w: usize, ksize: usize, stride: usize, pad: usize) -> (usize, usize) {
    (
        (h + 2 * pad - ksize) / stride + 1,
        (w + 2 * pad - ksize) / stride + 1,
    )
}

/// Output spatial dims of a pool (darknet semantics: valid, floor).
pub fn pool_out_hw(h: usize, w: usize, size: usize, stride: usize) -> (usize, usize) {
    ((h - size) / stride + 1, (w - size) / stride + 1)
}
